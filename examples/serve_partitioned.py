"""Serving driver: batched requests partitioned across two replica groups by
the paper's frontier — the file-transfer experiment (Figs 5/6) as a serving
system. Real tiny-model generation per group (--execute), simulated
replica-speed physics, online learning of the split.

Run:  PYTHONPATH=src python examples/serve_partitioned.py --batches 60 --execute

``--engine`` demos the continuous-batching tier instead: many concurrent
workflow instances (mixed templates, SLO deadlines) admitted from a queue,
every dirty instance's remaining stages priced by ONE stacked launch per
completion-time family per tick, including a mid-trace kill/restore through
the checkpoint manifest.

Run:  PYTHONPATH=src python examples/serve_partitioned.py --engine
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


def run_engine_demo(ticks: int = 30) -> None:
    """Continuous batching + SLO urgency + kill/restore, end to end."""
    import tempfile

    from repro.ckpt.store import restore_pipeline, save_pipeline
    from repro.serve import WorkflowEngine
    from repro.workflow.dag import Stage, StageDAG, linear_edges

    templates = {
        "etl": StageDAG([
            Stage("extract", mus=[1.0, 1.4, 1.9], sigmas=[0.2, 0.25, 0.35]),
            Stage("load", mus=[1.3, 1.8], sigmas=[0.25, 0.35]),
        ], edges=linear_edges(["extract", "load"])),
        "train": StageDAG([
            Stage("prep", mus=[1.5, 2.0, 2.6], sigmas=[0.3, 0.4, 0.5],
                  family="lognormal"),
            Stage("fit", mus=[2.4, 3.1, 3.9, 4.8],
                  sigmas=[0.5, 0.6, 0.7, 0.9], family="lognormal"),
        ], edges=linear_edges(["prep", "fit"])),
    }
    eng = WorkflowEngine(templates, max_live=32, lam_var=0.02, prior_obs=4)
    rng = np.random.default_rng(7)
    names = list(templates)
    with tempfile.TemporaryDirectory() as ckpt:
        for t in range(ticks):
            arrivals = [(names[int(rng.integers(2))],
                         float(rng.uniform(1.5, 4.0)))
                        for _ in range(int(rng.poisson(4.0)))]
            out = eng.tick(arrivals)
            save_pipeline(ckpt, eng.tick_count, eng)
            if t == ticks // 2:
                # the crash: drop the engine mid-flight, restore the
                # manifest — live instances, queue, heads, sims and all
                print(f"-- kill/restore at tick {out['tick']} "
                      f"({out['live']} instances in flight) --")
                eng, _, _ = restore_pipeline(ckpt, templates=templates)
            if t % 5 == 0:
                print(f"tick {out['tick']:3d}: live={out['live']} "
                      f"queue={out['queue']} rows={out['rows']} "
                      f"launches={out['launches']}")
    s = eng.telemetry.summary()
    c = s["counters"]
    print(f"\nengine summary: {c['retired']} retired / {c['admitted']} "
          f"admitted, {c['slo_misses']} SLO misses")
    print(f"join latency p50 {s['join_latency_s']['p50']:.3f}s "
          f"p99 {s['join_latency_s']['p99']:.3f}s; "
          f"{c['launches']} stacked launches over {c['ticks']} ticks "
          f"(rows/launch p50 {s['rows_per_launch']['p50']:.0f})")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batches", type=int, default=60)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--execute", action="store_true",
                    help="actually run generation on tiny models")
    # PR 4 estimation-loop knobs, exposed end-to-end: the balancer behind
    # the batcher accepts these; the example now lets you drive them
    ap.add_argument("--family", default="normal",
                    choices=("normal", "lognormal", "drift", "auto"),
                    help="completion-time family (auto = BIC-select online)")
    ap.add_argument("--risk-lam", type=float, default=0.0,
                    help="estimation-fragility weight in candidate scoring")
    ap.add_argument("--adaptive-refresh", action="store_true",
                    help="sensitivity-sized re-solve cadence")
    ap.add_argument("--refresh-every", type=int, default=1,
                    help="re-solve cadence (cap when adaptive)")
    ap.add_argument("--engine", action="store_true",
                    help="demo the continuous-batching WorkflowEngine "
                         "(admission queue, stacked launches, kill/restore)")
    ap.add_argument("--ticks", type=int, default=30,
                    help="engine mode: trace length")
    args = ap.parse_args()

    if args.engine:
        run_engine_demo(ticks=args.ticks)
        return

    import jax

    from repro.configs import get_config
    from repro.models import build_model
    from repro.serve import PartitionedBatcher, ReplicaGroup, ServeEngine
    from repro.sim import Channel, ClusterSim

    cfg = get_config("smollm-360m").tiny().replace(remat=False)
    groups = [ReplicaGroup("overlay-path"), ReplicaGroup("direct-path")]
    if args.execute:
        for g in groups:
            m = build_model(cfg)
            g.engine = ServeEngine(m, cfg)
            g.params = m.init(jax.random.PRNGKey(0))

    results = {}
    for policy in ("equal", "frontier"):
        sim = ClusterSim([Channel(24.0, 1.6), Channel(18.0, 4.8)], seed=11)
        batcher = PartitionedBatcher(groups, lam=0.08, policy=policy, sim=sim,
                                     family=args.family,
                                     risk_lam=args.risk_lam,
                                     adaptive_refresh=args.adaptive_refresh,
                                     refresh_every=args.refresh_every)
        rng = np.random.default_rng(0)
        lat = []
        for i in range(args.batches):
            prompts = rng.integers(0, cfg.vocab_size,
                                   (args.requests, 12)).astype(np.int32)
            t, counts, resp = batcher.run_batch(
                prompts, max_new=args.max_new,
                execute=args.execute and policy == "frontier" and i < 2)
            lat.append(t)
            if i % 20 == 0:
                tick = batcher.last_tick
                print(f"[{policy}] batch {i:3d}: split={counts.tolist()} "
                      f"join={t:.2f}s family={tick['family']} "
                      f"refresh={tick['effective_refresh']}")
        lat = np.asarray(lat[10:])
        results[policy] = lat
        print(f"[{policy}] mean={lat.mean():.3f}s var={lat.var():.4f} "
              f"p99={np.percentile(lat, 99):.3f}s\n")

    imp_mu = 1 - results["frontier"].mean() / results["equal"].mean()
    imp_var = 1 - results["frontier"].var() / results["equal"].var()
    print(f"frontier vs equal: mean latency -{imp_mu:.1%}, variance -{imp_var:.1%}")


if __name__ == "__main__":
    main()
