"""Serving driver: batched requests partitioned across two replica groups by
the paper's frontier — the file-transfer experiment (Figs 5/6) as a serving
system. Real tiny-model generation per group (--execute), simulated
replica-speed physics, online learning of the split.

Run:  PYTHONPATH=src python examples/serve_partitioned.py --batches 60 --execute
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batches", type=int, default=60)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--execute", action="store_true",
                    help="actually run generation on tiny models")
    # PR 4 estimation-loop knobs, exposed end-to-end: the balancer behind
    # the batcher accepts these; the example now lets you drive them
    ap.add_argument("--family", default="normal",
                    choices=("normal", "lognormal", "drift", "auto"),
                    help="completion-time family (auto = BIC-select online)")
    ap.add_argument("--risk-lam", type=float, default=0.0,
                    help="estimation-fragility weight in candidate scoring")
    ap.add_argument("--adaptive-refresh", action="store_true",
                    help="sensitivity-sized re-solve cadence")
    ap.add_argument("--refresh-every", type=int, default=1,
                    help="re-solve cadence (cap when adaptive)")
    args = ap.parse_args()

    import jax

    from repro.configs import get_config
    from repro.models import build_model
    from repro.serve import PartitionedBatcher, ReplicaGroup, ServeEngine
    from repro.sim import Channel, ClusterSim

    cfg = get_config("smollm-360m").tiny().replace(remat=False)
    groups = [ReplicaGroup("overlay-path"), ReplicaGroup("direct-path")]
    if args.execute:
        for g in groups:
            m = build_model(cfg)
            g.engine = ServeEngine(m, cfg)
            g.params = m.init(jax.random.PRNGKey(0))

    results = {}
    for policy in ("equal", "frontier"):
        sim = ClusterSim([Channel(24.0, 1.6), Channel(18.0, 4.8)], seed=11)
        batcher = PartitionedBatcher(groups, lam=0.08, policy=policy, sim=sim,
                                     family=args.family,
                                     risk_lam=args.risk_lam,
                                     adaptive_refresh=args.adaptive_refresh,
                                     refresh_every=args.refresh_every)
        rng = np.random.default_rng(0)
        lat = []
        for i in range(args.batches):
            prompts = rng.integers(0, cfg.vocab_size,
                                   (args.requests, 12)).astype(np.int32)
            t, counts, resp = batcher.run_batch(
                prompts, max_new=args.max_new,
                execute=args.execute and policy == "frontier" and i < 2)
            lat.append(t)
            if i % 20 == 0:
                tick = batcher.last_tick
                print(f"[{policy}] batch {i:3d}: split={counts.tolist()} "
                      f"join={t:.2f}s family={tick['family']} "
                      f"refresh={tick['effective_refresh']}")
        lat = np.asarray(lat[10:])
        results[policy] = lat
        print(f"[{policy}] mean={lat.mean():.3f}s var={lat.var():.4f} "
              f"p99={np.percentile(lat, 99):.3f}s\n")

    imp_mu = 1 - results["frontier"].mean() / results["equal"].mean()
    imp_var = 1 - results["frontier"].var() / results["equal"].var()
    print(f"frontier vs equal: mean latency -{imp_mu:.1%}, variance -{imp_var:.1%}")


if __name__ == "__main__":
    main()
