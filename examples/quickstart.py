"""Quickstart: the paper's partitioning procedure in five minutes.

1. Build the theory curves mu(f), sigma^2(f) for two uncertain channels
   (paper Fig 1 parameters).
2. Extract the efficient frontier and pick a split.
3. Watch the online Bayesian scheduler discover the same split from noisy
   observations alone, beating equal-split on both mean and variance.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import frontier_2ch, optimize_2ch, select_on_frontier
from repro.sched import UncertaintyAwareBalancer
from repro.sim import Channel, ClusterSim


def main():
    # ---- 1. theory (paper Fig 1: mu_i=30 sg_i=2, mu_j=20 sg_j=6)
    res = frontier_2ch(30.0, 2.0, 20.0, 6.0, num_f=101)
    i_mu, i_var = np.argmin(res.mu), np.argmin(res.var)
    print("=== Paper theory (Fig 1/2) ===")
    print(f"fastest single channel        : mu=20.00, var=36.00")
    print(f"min-mu split   f={res.f[i_mu]:.2f}      : mu={res.mu[i_mu]:.2f}, "
          f"var={res.var[i_mu]:.2f}")
    print(f"min-var split  f={res.f[i_var]:.2f}      : mu={res.mu[i_var]:.2f}, "
          f"var={res.var[i_var]:.2f}")
    print(f"efficient frontier: {int(res.efficient.sum())} points between "
          f"f={res.f[res.efficient].min():.2f} and f={res.f[res.efficient].max():.2f}")

    _, (f_star, mu_star, var_star) = select_on_frontier(res, lam=0.1)
    print(f"scalarized pick (lam=0.1)     : f={f_star:.2f} -> mu={mu_star:.2f}, "
          f"var={var_star:.2f}\n")

    # ---- 2. direct optimizer API
    dec = optimize_2ch(30.0, 2.0, 20.0, 6.0, lam=0.1)
    print(f"optimize_2ch -> weights={np.round(dec.weights, 3)}, "
          f"predicted mu={dec.mu:.2f} var={dec.var:.2f}\n")

    # ---- 3. online: scheduler learns the channels from observations
    print("=== Online Bayesian scheduler vs equal split ===")
    for policy in ("equal", "frontier"):
        sim = ClusterSim([Channel(30.0, 2.0), Channel(20.0, 6.0)], seed=0)
        bal = UncertaintyAwareBalancer(2, lam=0.1, policy=policy)
        times = []
        for i in range(250):
            w = bal.weights()
            t, durs = sim.run_step(w)
            bal.observe(durs, w)
            if i >= 50:
                times.append(t)
        times = np.asarray(times)
        w = bal.weights()
        print(f"{policy:9s}: final split={np.round(w, 2)}  "
              f"join mean={times.mean():6.2f}  var={times.var():6.2f}  "
              f"p99={np.percentile(times, 99):6.2f}")


if __name__ == "__main__":
    main()
