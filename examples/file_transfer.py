"""The paper's second scenario as a workflow DAG: a large file split over
parallel network channels (heavy-tailed lognormal transfer times — the WAN
regime), then reassembled/verified — a 2-stage split -> join StageDAG.

Stage "transfer": K parallel network paths, each with its own per-MB
(mu, sigma); the stage's completion is the slowest shard (the paper's join).
Stage "assemble": a single integrity-check/reassembly channel released only
when every shard has landed (the DAG edge).

The joint solver optimizes the shard split for the END-TO-END makespan and
the printout compares against the single-channel baseline (all bytes down
the fastest path) and the equal split — the paper's Figs 5/6 story with the
lognormal family and the composition layered on.

Run:  PYTHONPATH=src python examples/file_transfer.py --trials 4000
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--trials", type=int, default=4000,
                    help="Monte-Carlo trials validating the composed moments")
    ap.add_argument("--channels", type=int, default=4)
    args = ap.parse_args()

    from repro.sim import WorkflowSim
    from repro.workflow import Stage, StageDAG, evaluate_dag, solve_dag

    # per-MB transfer stats (seconds): one fast-but-jittery trans-Pacific
    # path, progressively steadier overlay routes — the paper's measured
    # heavy-tail regime, hence the lognormal family
    k = args.channels
    mus = np.linspace(16.0, 30.0, k)
    sigmas = np.asarray([7.0] + [2.2] * (k - 1))[:k]
    transfer = Stage("transfer", mus, sigmas, family="lognormal")
    # reassembly + checksum: one local channel, fast and steady
    assemble = Stage("assemble", np.asarray([3.0]), np.asarray([0.3]),
                     family="lognormal")
    dag = StageDAG([transfer, assemble], [("transfer", "assemble")])

    dec = solve_dag(dag, lam_var=0.05, steps=150, restarts=2, num_t=1024)
    w = dec.weights["transfer"]

    # baselines: all bytes down the single fastest path / equal shards
    single = np.zeros(k)
    single[int(np.argmin(mus))] = 1.0
    base = evaluate_dag(dag, {"transfer": single, "assemble": np.ones(1)})
    equal = evaluate_dag(dag, {"transfer": np.full(k, 1.0 / k),
                               "assemble": np.ones(1)})

    print(f"paths: mu={mus.round(1).tolist()} "
          f"sigma={sigmas.round(1).tolist()} (s per file, lognormal)")
    print(f"optimized shard split: {np.round(w, 3).tolist()}")
    rows = [("single fastest path", base), ("equal shards", equal),
            ("joint DAG solve", dec)]
    for name, d in rows:
        print(f"  {name:22s} E[T]={d.makespan_mu:7.3f}s  "
              f"Var[T]={d.makespan_var:7.3f}")
    assert dec.makespan_mu < base.makespan_mu, "split must beat one channel"
    assert dec.makespan_mu <= equal.makespan_mu + 1e-6

    # Monte-Carlo validation of the composed prediction (release = shard
    # max, assemble rides after — the discrete-event ground truth)
    sim = WorkflowSim.from_dag(dag, seed=7)
    rng = np.random.default_rng(11)
    ts = [sim.run_dag_step(dag, dec.weights, rng=rng)[0]
          for _ in range(args.trials)]
    ts = np.asarray(ts)
    rel = abs(ts.mean() - dec.makespan_mu) / dec.makespan_mu
    print(f"MC check ({args.trials} trials): empirical E[T]={ts.mean():.3f}s "
          f"Var={ts.var():.3f} (predicted {dec.makespan_mu:.3f}/"
          f"{dec.makespan_var:.3f}, rel mu err {rel:.1%})")
    speedup = base.makespan_mu / dec.makespan_mu
    print(f"speedup vs single channel: {speedup:.2f}x "
          f"(variance {base.makespan_var / max(dec.makespan_var, 1e-9):.1f}x"
          f" lower)" if dec.makespan_var < base.makespan_var else
          f"speedup vs single channel: {speedup:.2f}x")


if __name__ == "__main__":
    main()
