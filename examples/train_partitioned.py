"""End-to-end driver: train a language model for a few hundred steps with the
paper's uncertainty-aware partitioner scheduling per-pod microbatch counts.

The model is a reduced SmolLM config by default so a few hundred steps fit in
CPU minutes; pass --full-360m to train the real smollm-360m config (same
code path — sized for a real pod). Two simulated heterogeneous pods supply
the step-time physics; the gradient math is real (per-pod variable-trip-count
accumulation under shard_map + cross-pod psum), the loss goes down, and the
scheduler's split converges while join-time mean AND variance beat the
equal-split baseline run.

Run:  PYTHONPATH=src python examples/train_partitioned.py --steps 300
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--policy", default="frontier",
                    choices=("frontier", "equal", "inverse_mu"))
    ap.add_argument("--full-360m", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.launch.mesh import make_local_mesh
    from repro.models import build_model
    from repro.models.transformer import ShardCtx
    from repro.train import Trainer, TrainerConfig

    cfg = get_config("smollm-360m")
    if not args.full_360m:
        cfg = cfg.tiny()
    cfg = cfg.replace(remat=False)

    mesh = make_local_mesh(("pod", "data", "model"))
    ctx = ShardCtx(mesh=mesh, batch_axes=("data",))
    model = build_model(cfg, ctx)

    tcfg = TrainerConfig(
        steps=args.steps, batch=args.batch, seq=args.seq, lr=1e-3,
        ckpt_dir=args.ckpt_dir, ckpt_interval=100, log_every=25,
        partitioned=True, num_pods=2, microbatch=2, max_micro=6,
        policy=args.policy,
        sim_mus=(0.9, 1.5), sim_sigmas=(0.05, 0.45),
    )
    trainer = Trainer(model, cfg, tcfg, mesh=mesh)
    state, hist = trainer.run()

    losses = [h["loss"] for h in hist]
    joins = np.asarray([h["sim_join_time"] for h in hist if "sim_join_time" in h])
    k_last = hist[-1].get("k_pods")
    print("\n=== summary ===")
    print(f"policy={args.policy}")
    print(f"loss: first10={np.mean(losses[:10]):.3f}  "
          f"last10={np.mean(losses[-10:]):.3f}")
    print(f"simulated join time: mean={joins[20:].mean():.3f}s  "
          f"var={joins[20:].var():.4f}  p99={np.percentile(joins[20:], 99):.3f}s")
    print(f"final per-pod microbatch split: {k_last}")
    assert np.mean(losses[-10:]) < np.mean(losses[:10]), "loss must decrease"


if __name__ == "__main__":
    main()
