"""Fault-tolerance / elasticity demo at fleet scale (beyond paper).

A 16-channel fleet processes partitioned workloads while the run injects:
  * a 4x slowdown on one channel  (straggler -> quarantined by z-score),
  * a hard failure on another     (heartbeat loss -> elastic removal),
  * two new channels joining      (elastic scale-up with weak priors).
Throughout, the paper's partitioner keeps re-solving the frontier over the
surviving channel set; join-time statistics stay controlled.

Run:  PYTHONPATH=src python examples/elastic_fleet.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.sched import StragglerPolicy, UncertaintyAwareBalancer
from repro.sim import Channel, ClusterSim


def main():
    n = 16
    sim = ClusterSim.heterogeneous(n, mu_range=(8.0, 16.0), seed=5)
    bal = UncertaintyAwareBalancer(n, lam=0.03)
    pol = StragglerPolicy(bal, z_threshold=3.0, quarantine_after=2,
                          probation_period=30)

    window = []
    for step in range(240):
        w = pol.weights()
        t, durs = sim.run_step(w)
        pol.record(durs, w)
        window.append(t)

        if step == 60:
            sim.inject_slowdown(3, 4.0)
            print(f"step {step}: >>> channel 3 degrades 4x (contention)")
        if step == 120:
            sim.inject_failure(7)
            pol.fail(7)
            del sim.channels[7]
            print(f"step {step}: >>> channel 7 hard-fails; removed "
                  f"(fleet={bal.num_channels})")
        if step == 160:
            for _ in range(2):
                sim.channels.append(Channel(mu=9.0, sigma=0.8))
                pol.join(prior_mean=10.0)
            print(f"step {step}: >>> 2 channels join (fleet={bal.num_channels})")

        if step % 40 == 39:
            w_ = np.asarray(window[-40:])
            q = sorted(pol.quarantined)
            print(f"step {step}: join mean={w_.mean():.2f} var={w_.var():.3f} "
                  f"p99={np.percentile(w_, 99):.2f} quarantined={q}")

    tail = np.asarray(window[-40:])
    head = np.asarray(window[20:60])
    print("\n=== summary ===")
    print(f"pre-chaos  join: mean={head.mean():.2f} var={head.var():.3f}")
    print(f"post-chaos join: mean={tail.mean():.2f} var={tail.var():.3f}")
    print("scheduler absorbed a straggler, a failure and two joins.")


if __name__ == "__main__":
    main()
