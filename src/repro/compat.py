"""Version compatibility shims for the installed jax.

The codebase targets the current jax API surface; this module backfills the
pieces that moved or were renamed so it also runs on jax 0.4.x:

* ``shard_map`` — promoted to ``jax.shard_map`` in 0.5 with ``axis_names``
  (axes to run Manual) and ``check_vma``; 0.4.x has
  ``jax.experimental.shard_map.shard_map`` with the complementary ``auto``
  set and ``check_rep``.
"""
from __future__ import annotations

import jax

__all__ = ["shard_map"]


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
              check_vma: bool = False):
    """``jax.shard_map`` facade that also drives the 0.4.x experimental API.

    ``axis_names``: mesh axes mapped Manual inside ``f`` (None = all of them),
    matching the jax >= 0.5 keyword. ``check_vma`` maps to 0.4.x ``check_rep``.
    """
    if hasattr(jax, "shard_map"):
        kwargs = {} if axis_names is None else {"axis_names": set(axis_names)}
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma,
                             **kwargs)
    from jax.experimental.shard_map import shard_map as _shard_map

    auto = (frozenset(mesh.axis_names) - frozenset(axis_names)
            if axis_names is not None else frozenset())
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma, auto=auto)
