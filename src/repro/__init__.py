"""repro: Partitioning Uncertain Workflows (Huberman & Chua, 2015) as a
multi-pod JAX training/serving framework. See DESIGN.md."""
__version__ = "1.0.0"
