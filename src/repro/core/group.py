"""Choosing the *number* of channels (paper's group-testing extension, refs [23,24]).

Splitting across more channels shrinks each share (means scale with w) but the
max over more fluctuating channels grows with K, and every extra channel adds a
join cost. Given a fleet of candidate channels (mu_i, sigma_i) and an optional
per-channel enlistment overhead, select the subset to enlist.

Strategy (two-stage, in the spirit of Dorfman/Mezard group testing): a cheap
stage ranks channels by a scalar score; an exact stage evaluates nested prefix
groups with the full partitioner and keeps the best scalarized objective.
Exhaustive subset search is provided for small fleets as the oracle.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

from .distributions import defective_moments_np, resolve_family
from .partitioner import PartitionDecision, optimize_weights, predict_moments

__all__ = ["GroupChoice", "select_channels", "select_channels_exhaustive"]


def _expected_attempts(dist_id: str, extra, idx: np.ndarray) -> np.ndarray:
    """Per-channel expected attempt count of a candidate subset.

    The enlistment overhead (``join_cost``) is paid per ATTEMPT a channel
    makes, not per channel enlisted: a defective channel with per-attempt
    failure probability p joins E[attempts] = 1/(1-p) times (dispatch,
    health-check, re-enlist on every retry). Families without failure
    physics are always-up — exactly one attempt each, which reduces the
    failure-aware objective to the classic ``join_cost * k``.
    """
    if dist_id != "defective":
        return np.ones(len(idx), np.float64)
    p = np.clip(np.asarray(extra[0], np.float64)[idx], 0.0, 1.0 - 1e-9)
    return 1.0 / (1.0 - p)


def _ranking_stats(mus: np.ndarray, sigmas: np.ndarray, dist_id: str,
                   extra) -> tuple:
    """Stats the cheap ranking stage scores — retry-inflated for defective.

    A fast-but-flaky channel must rank by what it actually costs: the
    defective family's moment-matched per-unit ``(a, b)`` (mean inflated by
    expected retries, variance by retry dispersion) replace the raw
    ``(mu, sigma)`` so the prefix order the exact stage explores already
    prices failures. Other families pass through unchanged.
    """
    if dist_id != "defective":
        return mus, sigmas
    a, b = defective_moments_np(mus, sigmas,
                                np.asarray(extra[0], np.float64),
                                np.asarray(extra[1], np.float64))
    return a, b


@dataclass(frozen=True)
class GroupChoice:
    indices: np.ndarray          # selected channel ids (into the fleet arrays)
    decision: PartitionDecision  # split over the selected channels
    objective: float


# repro: allow[RPA001] family-agnostic ranking heuristic; the exact stage
# re-scores every prefix with the caller's family through optimize_weights
def _score(mus: np.ndarray, sigmas: np.ndarray) -> np.ndarray:
    """Cheap ranking: fast channels first, variance-penalized.

    1/mu is throughput; sigma/mu is the relative jitter penalty.
    """
    return 1.0 / mus - 0.5 * sigmas / (mus * mus)


def _subset_decision(idx: np.ndarray, mus: np.ndarray, sigmas: np.ndarray,
                     dist_id: str, extra, lam: float,
                     pgd_steps: int) -> PartitionDecision:
    """Solve (or close-form) the split over one candidate subset, keeping the
    family's per-channel extras aligned with the subset."""
    sub_family = (dist_id, extra[:, idx])
    if len(idx) == 1:
        if dist_id == "normal":
            # max over one normal channel IS the channel: exact, no quadrature
            return PartitionDecision(weights=np.ones(1), mu=float(mus[idx[0]]),
                                     var=float(sigmas[idx[0]] ** 2),
                                     method="single")
        m, v = predict_moments(np.ones(1), mus[idx], sigmas[idx],
                               family=sub_family)
        return PartitionDecision(weights=np.ones(1), mu=m, var=v,
                                 method="single")
    return optimize_weights(mus[idx], sigmas[idx], lam=lam, steps=pgd_steps,
                            family=sub_family)


def select_channels(mus: Sequence[float], sigmas: Sequence[float], lam: float = 0.0,
                    join_cost: float = 0.0, max_k: Optional[int] = None,
                    pgd_steps: int = 120, family="normal") -> GroupChoice:
    """Greedy nested-prefix selection of how many (and which) channels to use.

    join_cost models the per-channel overhead of joining outputs (the paper's
    "pieced together" step); it makes the objective non-monotone in K so an
    interior K* exists. ``family`` selects the completion-time family for the
    exact stage (per-channel extras are subset alongside the statistics).
    Under the defective family the selection is failure-aware: ranking uses
    retry-inflated stats and the enlistment term charges expected ATTEMPTS
    (``join_cost * sum 1/(1-p_i)``) instead of treating channels as
    always-up — a flaky channel must buy its way in past its retries.
    """
    mus = np.asarray(mus, np.float64)
    sigmas = np.asarray(sigmas, np.float64)
    dist_id, extra = resolve_family(family, len(mus))
    extra = np.asarray(extra)
    order = np.argsort(-_score(*_ranking_stats(mus, sigmas, dist_id, extra)))
    max_k = max_k or len(mus)

    best: Optional[GroupChoice] = None
    for k in range(1, min(max_k, len(mus)) + 1):
        idx = np.asarray(order[:k])
        dec = _subset_decision(idx, mus, sigmas, dist_id, extra, lam, pgd_steps)
        obj = dec.mu + lam * dec.var \
            + join_cost * float(_expected_attempts(dist_id, extra, idx).sum())
        if best is None or obj < best.objective:
            best = GroupChoice(indices=idx, decision=dec, objective=float(obj))
    assert best is not None
    return best


def select_channels_exhaustive(mus: Sequence[float], sigmas: Sequence[float],
                               lam: float = 0.0, join_cost: float = 0.0,
                               pgd_steps: int = 120,
                               family="normal") -> GroupChoice:
    """Oracle subset search (exponential — small fleets only, used in tests)."""
    mus = np.asarray(mus, np.float64)
    sigmas = np.asarray(sigmas, np.float64)
    dist_id, extra = resolve_family(family, len(mus))
    extra = np.asarray(extra)
    n = len(mus)
    best: Optional[GroupChoice] = None
    for k in range(1, n + 1):
        for combo in itertools.combinations(range(n), k):
            idx = np.asarray(combo)
            dec = _subset_decision(idx, mus, sigmas, dist_id, extra, lam,
                                   pgd_steps)
            obj = dec.mu + lam * dec.var \
                + join_cost * float(_expected_attempts(dist_id, extra,
                                                       idx).sum())
            if best is None or obj < best.objective:
                best = GroupChoice(indices=idx, decision=dec, objective=float(obj))
    assert best is not None
    return best
