"""Moments of the *joint* (max-over-channels) completion time.

The paper (Eq. 1) defines the workflow completion time ``T = max_i T_i`` with
``T_i ~ N(w_i mu_i, (w_i sigma_i)^2)`` independent. The max of Gaussians has no
closed-form density, so the paper computes

    mu(w)      = int_0^inf [1 - F(t)] dt
    E[T^2](w)  = 2 int_0^inf t [1 - F(t)] dt
    sigma^2(w) = E[T^2] - mu^2

with F(t) = prod_i CDF_i(t). Three evaluators are provided:

* :func:`max_moments_quad` — the numerical-integration oracle (trapezoid on an
  adaptive [0, tmax] grid). Exact up to grid resolution for any K. This is the
  reference implementation of the paper's method.
* :func:`clark_max_moments_2` — *closed form* first two moments for K=2
  (Clark 1961; exact for two independent Gaussians).
* :func:`clark_max_moments_seq` — sequential Clark moment-matching for K>2
  (fast approximation; the max of >2 Gaussians is not Gaussian, so this is
  approximate — the oracle bounds its error in tests).
* :func:`max_moments_mc` — Monte-Carlo validator.

All functions are jit/vmap/grad friendly. ``w_i = 0`` channels are handled as
"already finished" (contribute CDF 1), matching the semantics of assigning a
channel no work.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from ..analysis import sanitize as _san
from . import distributions as dists
from .distributions import Phi, phi, safe_cdf

__all__ = [
    "joint_cdf",
    "joint_cdf_w",
    "max_moments_quad",
    "max_moments_quad_w",
    "clark_max_moments_2",
    "clark_max_moments_seq",
    "max_moments_mc",
    "time_grid",
]


def joint_cdf(t, means, stds):
    """P(T <= t) = prod_i P(T_i <= t) for independent channels (paper Eq. 1).

    ``t`` may be any shape; means/stds are (K,). Broadcasts over a trailing
    channel axis added to ``t``.
    """
    t = jnp.asarray(t)[..., None]
    return jnp.prod(safe_cdf(t, means, stds), axis=-1)


def joint_cdf_w(t, w, mus, sigmas, family="normal"):
    """Family-generic joint CDF: P(max_i T_i(w_i) <= t), w/mus/sigmas (K,).

    Unlike :func:`joint_cdf` this takes the *split* and per-unit statistics
    (not pre-scaled means/stds) because non-scale families (drift) are not
    linear in w.
    """
    dist_id, extra = dists.resolve_family(family, jnp.asarray(w).shape[-1])
    t = jnp.asarray(t)[..., None]
    cdf = dists.family_cdf(dist_id, t, jnp.asarray(w), jnp.asarray(mus),
                           jnp.asarray(sigmas), jnp.asarray(extra))
    return jnp.prod(cdf, axis=-1)


def time_grid(means, stds, num: int = 2048, z: float = 10.0):
    """Integration grid covering [0, max_i(mean_i + z*std_i)].

    A fixed-size grid keeps the function jit-able; z=10 puts the truncation
    error far below the trapezoid error.
    """
    tmax = jnp.max(means + z * stds)
    tmax = jnp.maximum(tmax, 1e-12)  # all-zero work edge case
    return jnp.linspace(0.0, tmax, num)


@partial(jax.jit, static_argnames=("num",))
def max_moments_quad(means, stds, num: int = 2048) -> Tuple[jax.Array, jax.Array]:
    """(mean, variance) of max_i N(means_i, stds_i^2) by survival integration.

    Implements the paper's
        mu    = ∫ (1 - F) dt,   E[T^2] = 2 ∫ t (1 - F) dt
    on a trapezoid grid. Channels with stds==0 and means==0 (zero work) drop out.
    """
    means = jnp.asarray(means, jnp.float64 if jax.config.read("jax_enable_x64") else jnp.float32)
    stds = jnp.asarray(stds, means.dtype)
    ts = time_grid(means, stds, num=num)
    surv = 1.0 - joint_cdf(ts, means, stds)  # (num,)
    mu = jnp.trapezoid(surv, ts)
    m2 = 2.0 * jnp.trapezoid(ts * surv, ts)
    var = jnp.maximum(m2 - mu * mu, 0.0)
    return mu, var


def max_moments_quad_w(w, mus, sigmas, num: int = 2048,
                       family="normal") -> Tuple[jax.Array, jax.Array]:
    """Family-generic single-split oracle: (mean, var) of max_i T_i(w_i).

    Same survival integral as :func:`max_moments_quad`, with the per-channel
    completion-time distribution drawn from ``family`` (the grid reach uses
    the family's effective moments). This is the candidate-evaluation oracle
    the batched kernel path is tested against for every family.
    """
    dtype = jnp.float64 if jax.config.read("jax_enable_x64") else jnp.float32
    w = jnp.asarray(w, dtype)
    dist_id, extra = dists.resolve_family(family, w.shape[-1])
    mus = jnp.asarray(mus, dtype)
    sigmas = jnp.asarray(sigmas, dtype)
    extra = jnp.asarray(extra, dtype)
    _san.check_fold_inputs(mus, sigmas)
    m_eff, s_eff = dists.family_effective_moments(dist_id, w, mus, sigmas,
                                                  extra)
    ts = time_grid(m_eff, s_eff, num=num)
    if _san.enabled() and _san.all_concrete(ts):
        _san.assert_monotone_grid("max_moments_quad_w", ts)
    cdf = dists.family_cdf(dist_id, ts[:, None], w, mus, sigmas, extra)
    surv = 1.0 - jnp.prod(cdf, axis=-1)
    mu = jnp.trapezoid(surv, ts)
    m2 = 2.0 * jnp.trapezoid(ts * surv, ts)
    var = jnp.maximum(m2 - mu * mu, 0.0)
    return mu, var


def clark_max_moments_2(mu1, s1, mu2, s2) -> Tuple[jax.Array, jax.Array]:
    """Exact first two moments of max(X, Y), X~N(mu1,s1^2) ⫫ Y~N(mu2,s2^2).

    Clark (1961): with a^2 = s1^2 + s2^2, alpha = (mu1-mu2)/a,
        E[M]   = mu1 Φ(α) + mu2 Φ(−α) + a φ(α)
        E[M^2] = (mu1²+s1²) Φ(α) + (mu2²+s2²) Φ(−α) + (mu1+mu2) a φ(α)
    Degenerate a→0 (both deterministic or identical) handled by a where-guard.
    """
    mu1, s1 = jnp.asarray(mu1, jnp.float32), jnp.asarray(s1, jnp.float32)
    mu2, s2 = jnp.asarray(mu2, jnp.float32), jnp.asarray(s2, jnp.float32)
    a2 = s1 * s1 + s2 * s2
    a = jnp.sqrt(jnp.maximum(a2, 0.0))
    ok = a > 0.0
    alpha = (mu1 - mu2) / jnp.where(ok, a, 1.0)
    cdf_a = jnp.where(ok, Phi(alpha), (mu1 >= mu2).astype(a.dtype))
    pdf_a = jnp.where(ok, phi(alpha), 0.0)
    m1 = mu1 * cdf_a + mu2 * (1.0 - cdf_a) + a * pdf_a
    m2 = ((mu1 * mu1 + s1 * s1) * cdf_a
          + (mu2 * mu2 + s2 * s2) * (1.0 - cdf_a)
          + (mu1 + mu2) * a * pdf_a)
    var = jnp.maximum(m2 - m1 * m1, 0.0)
    return m1, var


def clark_max_moments_seq(means, stds) -> Tuple[jax.Array, jax.Array]:
    """Sequential Clark approximation for K channels.

    Folds channels left-to-right, moment-matching the running max to a Gaussian
    at each step. Exact for K<=2; approximation error for K>2 is small when
    channel means are well separated (verified against the quad oracle).
    Implemented as a lax.scan so K may be large (1000+ channels).
    """
    _san.check_fold_inputs(means, stds)
    means = jnp.asarray(means)
    stds = jnp.asarray(stds)

    def fold(carry, ms):
        m_run, v_run = carry
        m_i, s_i = ms
        m_new, v_new = clark_max_moments_2(m_run, jnp.sqrt(v_run), m_i, s_i)
        return (m_new, v_new), None

    init = (means[0], stds[0] ** 2)
    (m, v), _ = jax.lax.scan(fold, init, (means[1:], stds[1:]))
    return m, v


@partial(jax.jit, static_argnames=("num_samples",))
def max_moments_mc(key, means, stds, num_samples: int = 200_000):
    """Monte-Carlo (mean, var) of the max — used as an independent validator."""
    samp = means + stds * jax.random.normal(key, (num_samples, means.shape[-1]), dtype=means.dtype)
    t = jnp.max(samp, axis=-1)
    return jnp.mean(t), jnp.var(t)
