"""On-the-fly estimation of channel statistics (paper's extension, ref [22]).

The paper assumes (mu_i, sigma_i) are known; in deployment they must be
estimated from observed completion times. We use the conjugate
Normal-Inverse-Gamma (NIG) model from Murphy (2007), the exact reference the
paper cites:

    mu, sigma^2 ~ NIG(m, kappa, alpha, beta)
    t | mu, sigma^2 ~ N(mu, sigma^2)

Observations are *normalized rates*: a channel that processed work fraction w
in time t contributes the sample t/w ~ N(mu_i, sigma_i^2) under the paper's
scaling model. Updates are O(1), jit-able, and vectorized over channels so a
1000-node scheduler refreshes all posteriors in one fused kernel.

Two closed-loop extensions live here alongside the conjugate updates:

* **Estimation uncertainty** (:func:`nig_estimate_ses`): the standard errors
  of the point estimates the solver consumes. Composed with the solve's
  parameter adjoints (``core.sensitivity``) they give the delta-method
  spread of the predicted completion time under estimation error — the
  Bayesian loop of arXiv:1511.00613.
* **Online model selection** (:func:`score_families`): the distribution
  family itself is chosen from the observed (rate, work) history by BIC —
  NIG-Normal vs moment-matched lognormal vs the drift regression vs a
  per-channel GMM (arXiv:1607.04334's adapt-the-model argument). The
  scheduler's ``family="auto"`` mode consumes these scores with hysteresis.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["NIGState", "nig_init", "nig_update", "nig_update_batch",
           "nig_point_estimates", "nig_estimate_ses",
           "FamilyScores", "score_families", "fit_selected_family",
           "AUTO_FAMILIES"]


class NIGState(NamedTuple):
    """Per-channel Normal-Inverse-Gamma posterior parameters, shape (K,)."""

    m: jax.Array      # posterior mean location
    kappa: jax.Array  # pseudo-observations on the mean
    alpha: jax.Array  # IG shape
    beta: jax.Array   # IG scale


def nig_init(k: int, m0: float = 1.0, kappa0: float = 1e-3,
             alpha0: float = 1.5, beta0: float = 0.5) -> NIGState:
    """Weak prior: alpha0>1 so E[sigma^2] exists from the first update.

    kappa0 small => the first observation dominates the location.
    """
    f = jnp.float32
    ones = jnp.ones((k,), f)
    return NIGState(m=ones * m0, kappa=ones * kappa0, alpha=ones * alpha0, beta=ones * beta0)


@jax.jit
def nig_update(state: NIGState, channel: jax.Array, rate: jax.Array) -> NIGState:
    """Single-observation update for one channel (jit'd; scatter-style).

    rate = observed_time / work_fraction, the normalized per-unit-work time.
    """
    onehot = jax.nn.one_hot(channel, state.m.shape[0], dtype=state.m.dtype)
    kappa_n = state.kappa + onehot
    m_n = (state.kappa * state.m + onehot * rate) / kappa_n
    alpha_n = state.alpha + 0.5 * onehot
    beta_n = state.beta + 0.5 * onehot * (state.kappa / kappa_n) * (rate - state.m) ** 2
    # untouched channels: onehot==0 leaves all four parameters unchanged
    return NIGState(m=m_n, kappa=kappa_n, alpha=alpha_n, beta=beta_n)


@jax.jit
def nig_update_batch(state: NIGState, rates: jax.Array, mask: jax.Array) -> NIGState:
    """Simultaneous update of every channel with one observation each.

    rates: (K,) normalized rates; mask: (K,) 1.0 where a channel reported this
    round (failed/idle channels report nothing). This is the per-step scheduler
    path: one fused update for the whole fleet.
    """
    kappa_n = state.kappa + mask
    m_n = (state.kappa * state.m + mask * rates) / kappa_n
    alpha_n = state.alpha + 0.5 * mask
    beta_n = state.beta + 0.5 * mask * (state.kappa / kappa_n) * (rates - state.m) ** 2
    return NIGState(m=m_n, kappa=kappa_n, alpha=alpha_n, beta=beta_n)


@jax.jit
def nig_point_estimates(state: NIGState):
    """(mu_hat, sigma_hat) for the partitioner.

    mu_hat = posterior mean of mu; sigma_hat^2 = posterior-predictive variance
    (Student-t matched), i.e. E[sigma^2]*(1 + 1/kappa) * nu/(nu-2) correction —
    we use the standard E[sigma^2] = beta/(alpha-1) plus mean-uncertainty
    inflation beta/(alpha-1)/kappa, which converges to sigma^2 as data accrues
    and stays finite for alpha>1.
    """
    ev = state.beta / jnp.maximum(state.alpha - 1.0, 1e-3)
    sigma2 = ev * (1.0 + 1.0 / jnp.maximum(state.kappa, 1e-6))
    return state.m, jnp.sqrt(sigma2)


@jax.jit
def nig_estimate_ses(state: NIGState):
    """Standard errors ``(se_mu, se_sigma)`` of the point estimates.

    ``se_mu``: the posterior sd of the location — the marginal of mu under
    NIG is Student-t with variance ``beta / ((alpha - 1) kappa)``.
    ``se_sigma``: delta-method sd of ``sigma_hat = sqrt(E[sigma^2])`` from
    the IG posterior of sigma^2 (``Var[sigma^2] =
    beta^2 / ((alpha-1)^2 (alpha-2))``), floored for the weak-prior regime
    alpha <= 2 where the IG variance is infinite — there the estimate is
    "one observation's worth" uncertain, so we cap the relative se at 1.

    These are what :mod:`core.sensitivity` contracts against the solve's
    parameter adjoints to price estimation risk; both shrink ~ 1/sqrt(n) as
    observations accrue, which is what lets the balancer stretch its refresh
    cadence as posteriors firm up.
    """
    am1 = jnp.maximum(state.alpha - 1.0, 1e-3)
    kap = jnp.maximum(state.kappa, 1e-6)
    se_mu = jnp.sqrt(state.beta / (am1 * kap))
    _, sigma_hat = nig_point_estimates(state)
    # sigma_hat^2 = (1 + 1/kappa) * E[sigma^2], so its sd carries the same
    # (1 + 1/kappa) factor as the point estimate — dropping it would
    # understate the young-posterior (kappa ~ 1) uncertainty by ~2x, exactly
    # the regime the adaptive refresh exists for
    sd_sig2 = ((1.0 + 1.0 / kap) * state.beta
               / (am1 * jnp.sqrt(jnp.maximum(state.alpha - 2.0, 1e-3))))
    se_sigma = jnp.minimum(sd_sig2 / jnp.maximum(2.0 * sigma_hat, 1e-12),
                           sigma_hat)
    return se_mu, se_sigma


# --------------------------------------------------------------------------
# online family selection: BIC over the observed (rate, work) history
# --------------------------------------------------------------------------

AUTO_FAMILIES = ("normal", "lognormal", "drift", "empirical")

# free parameters per channel for the BIC penalty k*ln(n)
_FAMILY_DOF = {"normal": 2.0, "lognormal": 2.0, "drift": 3.0}
_LOG_2PI = float(np.log(2.0 * np.pi))


@dataclass(frozen=True)
class FamilyScores:
    """Result of one BIC scoring pass over the rate history.

    ``bics`` maps family name -> total BIC (summed over scoreable channels;
    lower is better); ``winner`` is the argmin. ``rho`` is the drift
    regression's per-channel rate estimate and ``gmm`` the fitted
    ``(weights, means, stds)`` mixture — kept so the selected family can be
    instantiated without refitting (:func:`fit_selected_family`).
    """

    bics: Dict[str, float]
    winner: str
    n_channels: int            # channels with enough history to score
    rho: np.ndarray            # (K,) drift-rate estimates (clipped >= 0)
    gmm: tuple                 # (W, M, S) each (C, K)


def _masked_moments(x: np.ndarray, mask: np.ndarray):
    """Per-channel (n, mean, var) of ``x`` (N, K) under ``mask`` (N, K)."""
    n = mask.sum(axis=0)
    safe_n = np.maximum(n, 1.0)
    mean = (x * mask).sum(axis=0) / safe_n
    var = (((x - mean) ** 2) * mask).sum(axis=0) / safe_n
    return n, mean, var


def _gauss_loglik(n: np.ndarray, var: np.ndarray, floor: np.ndarray):
    """ln L of per-channel Gaussian MLE fits: -n/2 (ln 2 pi var + 1)."""
    v = np.maximum(var, floor)
    return -0.5 * n * (_LOG_2PI + np.log(v) + 1.0)


def _em_batch(x: np.ndarray, mask: np.ndarray, C: int = 3, iters: int = 16,
              var_floor_frac: float = 1e-3):
    """Vectorized per-channel 1-D Gaussian-mixture EM under a sample mask.

    The batched twin of ``distributions._em_1d`` (same quantile init, fixed
    iteration count, floored variances, deterministic — no RNG), run on
    (N, K) arrays at once so scoring a 1024-channel fleet's history is a few
    dozen numpy passes instead of K python EM loops. The E-step runs in
    float32 (the (C, N, K) responsibility tensor is the cost) with the
    log-likelihood accumulated in float64 — BIC selection needs relative
    likelihoods, not converged mixtures, which is also why the default
    iteration count is lower than the solver-grade ``_em_1d`` fit. Returns
    ``(W, M, S, loglik)`` with the mixtures (C, K) and per-channel ln L (K,).
    """
    x = np.asarray(x, np.float32)
    N, K = x.shape
    m = mask.astype(np.float32)
    n_valid = m.sum(axis=0)
    has_data = n_valid >= 1.0
    n = np.maximum(n_valid, 1.0).astype(np.float32)
    _, mean, var = _masked_moments(x, m)
    spread = np.maximum(np.sqrt(var), np.maximum(np.abs(mean) * 1e-6, 1e-12))
    # channels with no valid samples (idle the whole window) get a benign
    # unit-variance placeholder so no -inf/NaN can leak out of the E-step;
    # their log-likelihood is exactly 0 (no samples) and the caller
    # substitutes real parameters for them (see score_families)
    floor = np.where(has_data, (var_floor_frac * spread) ** 2,
                     1.0).astype(np.float32)
    # masked quantile init: sort with masked-out entries pushed to +inf, pick
    # evenly spaced order statistics of each channel's valid prefix
    xs = np.where(m > 0, x, np.inf)
    xs = np.sort(xs, axis=0)
    qidx = ((np.arange(C)[:, None] + 0.5) / C * n[None, :]).astype(np.int64)
    qidx = np.minimum(qidx, np.maximum(n.astype(np.int64) - 1, 0))
    mus = np.take_along_axis(xs, qidx, axis=0)                # (C, K)
    mus = np.where(np.isfinite(mus), mus, 0.0).astype(np.float32)
    vars_ = np.maximum(np.broadcast_to(var / C, (C, K)), floor
                       ).astype(np.float32)
    pis = np.full((C, K), 1.0 / C, np.float32)
    ll = np.zeros(K)
    for _ in range(iters):
        logp = (-0.5 * (x[None] - mus[:, None]) ** 2 / vars_[:, None]
                - 0.5 * np.log(2 * np.pi * vars_[:, None])
                + np.log(np.maximum(pis[:, None], 1e-30)))    # (C, N, K)
        mx = logp.max(axis=0)
        r = np.exp(logp - mx)
        tot = np.maximum(r.sum(axis=0), 1e-30)
        # select-then-sum (no multiply): a masked sample's -inf/NaN term must
        # not poison the channel's log-likelihood via inf * 0
        ll = np.where(m > 0, (mx + np.log(tot)).astype(np.float64),
                      0.0).sum(axis=0)
        r = r / tot * m[None]
        nk = np.maximum(r.sum(axis=1), 1e-12)                 # (C, K)
        mus = (r * x[None]).sum(axis=1) / nk
        vars_ = np.maximum((r * x[None] ** 2).sum(axis=1) / nk - mus ** 2,
                           floor)
        pis = nk / n[None, :]
    order = np.argsort(mus, axis=0)
    take = lambda a: np.take_along_axis(a, order, axis=0)
    return take(pis), take(mus), np.sqrt(take(vars_)), ll


def score_families(rates: np.ndarray, works: np.ndarray, mask: np.ndarray,
                   min_obs: int = 8, max_rho: float = 8.0,
                   families=AUTO_FAMILIES) -> Optional[FamilyScores]:
    """BIC-score the candidate completion-time families on observed history.

    ``rates``/``works``/``mask``: (N, K) windows of normalized per-unit-work
    rates, the work shares they were observed under, and observation
    validity. Models, each fit per channel by (closed-form or EM) maximum
    likelihood, BIC = k ln n - 2 ln L summed over scoreable channels:

    * ``normal``     rate ~ N(mu, sigma^2)                       (k = 2)
    * ``lognormal``  log rate ~ N(m, s^2)                        (k = 2)
    * ``drift``      rate ~ N(mu (1 + rho w / 2), sigma^2)       (k = 3)
      — linear regression of rate on work share: under within-work straggle
      the *normalized* rate still rises with the share (T/w = r + rho mu w/2),
      which is exactly the signature an iid fit cannot see.
    * ``empirical``  rate ~ GMM_3                                (k = 8)

    Returns None when no channel has ``min_obs`` valid observations yet (the
    caller should keep its current family). Channels below ``min_obs`` are
    excluded from every family's total so the comparison stays apples-to-
    apples.
    """
    rates = np.asarray(rates, np.float64)
    works = np.asarray(works, np.float64)
    mask = np.asarray(mask, np.float64)
    n_all = mask.sum(axis=0)
    ok = n_all >= min_obs
    if not ok.any():
        return None
    m = mask * ok[None, :]
    n, mean, var = _masked_moments(rates, m)
    spread2 = np.maximum(var, (np.abs(mean) * 1e-6 + 1e-12) ** 2)
    floor = spread2 * 1e-8
    logn = np.log(np.maximum(n, 2.0))
    bics: Dict[str, float] = {}

    def total(k_dof, ll):
        return float(((k_dof * logn - 2.0 * ll) * ok).sum())

    if "normal" in families:
        bics["normal"] = total(_FAMILY_DOF["normal"],
                               _gauss_loglik(n, var, floor))

    if "lognormal" in families:
        pos = rates > 0
        logs = np.log(np.where(pos, rates, 1.0))
        m_ln = m * pos
        n_ln, _, var_ln = _masked_moments(logs, m_ln)
        # the Jacobian term sum(-log r) converts log-space likelihood back to
        # rate space; nonpositive rates are impossible under a lognormal, so
        # each one costs a large fixed log-likelihood deficit. The variance
        # floor must be LOG-space (scale-free: var_ln ~ CoV^2 regardless of
        # rate magnitude) — the rate-space floor would clamp var_ln whenever
        # rates are numerically large and silently disqualify the family.
        floor_ln = np.full_like(var_ln, 1e-10)
        jac = (-logs * m_ln).sum(axis=0)
        ll_ln = (_gauss_loglik(n_ln, var_ln, floor_ln) + jac
                 - 1e3 * np.maximum(n - n_ln, 0.0))
        bics["lognormal"] = total(_FAMILY_DOF["lognormal"], ll_ln)

    rho_hat = np.zeros(rates.shape[1])
    if "drift" in families:
        # per-channel least squares rate = a + b w; rho = 2 b / a, clipped to
        # the physical (nonnegative) range — a negative slope refits as b=0,
        # collapsing to the normal model (BIC then penalizes the extra dof)
        nw = n
        sw = (works * m).sum(axis=0)
        sww = (works * works * m).sum(axis=0)
        sr = (rates * m).sum(axis=0)
        swr = (works * rates * m).sum(axis=0)
        det = nw * sww - sw * sw
        det_ok = det > 1e-12 * np.maximum(nw * sww, 1e-300)
        safe_det = np.where(det_ok, det, 1.0)
        b = np.where(det_ok, (nw * swr - sw * sr) / safe_det, 0.0)
        b = np.maximum(b, 0.0)
        a = np.where(nw > 0, (sr - b * sw) / np.maximum(nw, 1.0), 1.0)
        resid = rates - (a[None, :] + b[None, :] * works)
        var_d = ((resid ** 2) * m).sum(axis=0) / np.maximum(nw, 1.0)
        rho_hat = np.clip(np.where(a > 1e-12, 2.0 * b / np.maximum(a, 1e-12),
                                   0.0), 0.0, max_rho)
        bics["drift"] = total(_FAMILY_DOF["drift"],
                              _gauss_loglik(nw, var_d, floor))

    gmm = None
    if "empirical" in families:
        from .distributions import EMP_COMPONENTS
        Wg, Mg, Sg, ll_g = _em_batch(rates, m, C=EMP_COMPONENTS)
        # channels below min_obs are excluded from the BIC totals, but their
        # mixture columns still reach the solver if empirical wins — give
        # them a single pooled-fleet component instead of a starved EM fit
        # (an idle channel must not look like a point mass at 0)
        if not ok.all():
            pool_n = max(float((mask * ok[None, :]).sum()), 1.0)
            pool_mean = float((rates * mask * ok[None, :]).sum() / pool_n)
            pool_var = float((((rates - pool_mean) ** 2) * mask
                              * ok[None, :]).sum() / pool_n)
            pool_sd = max(np.sqrt(pool_var), abs(pool_mean) * 1e-3, 1e-6)
            bad = ~ok
            Wg[:, bad] = np.array([[1.0]] + [[0.0]] * (EMP_COMPONENTS - 1))
            Mg[:, bad] = pool_mean
            Sg[:, bad] = pool_sd
        gmm = (Wg, Mg, Sg)
        k_gmm = 3.0 * EMP_COMPONENTS - 1.0
        bics["empirical"] = total(k_gmm, ll_g)

    winner = min(bics, key=bics.get)
    return FamilyScores(bics=bics, winner=winner, n_channels=int(ok.sum()),
                        rho=rho_hat, gmm=gmm)


def fit_selected_family(scores: FamilyScores, winner: Optional[str] = None):
    """Instantiate the ChannelFamily a scoring pass selected (no refitting)."""
    from .distributions import Drift, Empirical, get_family

    name = winner or scores.winner
    if name == "drift":
        return Drift(np.asarray(scores.rho, np.float32))
    if name == "empirical":
        Wg, Mg, Sg = scores.gmm
        return Empirical(Wg, Mg, Sg)
    return get_family(name)
