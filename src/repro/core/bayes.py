"""On-the-fly estimation of channel statistics (paper's extension, ref [22]).

The paper assumes (mu_i, sigma_i) are known; in deployment they must be
estimated from observed completion times. We use the conjugate
Normal-Inverse-Gamma (NIG) model from Murphy (2007), the exact reference the
paper cites:

    mu, sigma^2 ~ NIG(m, kappa, alpha, beta)
    t | mu, sigma^2 ~ N(mu, sigma^2)

Observations are *normalized rates*: a channel that processed work fraction w
in time t contributes the sample t/w ~ N(mu_i, sigma_i^2) under the paper's
scaling model. Updates are O(1), jit-able, and vectorized over channels so a
1000-node scheduler refreshes all posteriors in one fused kernel.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["NIGState", "nig_init", "nig_update", "nig_update_batch", "nig_point_estimates"]


class NIGState(NamedTuple):
    """Per-channel Normal-Inverse-Gamma posterior parameters, shape (K,)."""

    m: jax.Array      # posterior mean location
    kappa: jax.Array  # pseudo-observations on the mean
    alpha: jax.Array  # IG shape
    beta: jax.Array   # IG scale


def nig_init(k: int, m0: float = 1.0, kappa0: float = 1e-3,
             alpha0: float = 1.5, beta0: float = 0.5) -> NIGState:
    """Weak prior: alpha0>1 so E[sigma^2] exists from the first update.

    kappa0 small => the first observation dominates the location.
    """
    f = jnp.float32
    ones = jnp.ones((k,), f)
    return NIGState(m=ones * m0, kappa=ones * kappa0, alpha=ones * alpha0, beta=ones * beta0)


@jax.jit
def nig_update(state: NIGState, channel: jax.Array, rate: jax.Array) -> NIGState:
    """Single-observation update for one channel (jit'd; scatter-style).

    rate = observed_time / work_fraction, the normalized per-unit-work time.
    """
    onehot = jax.nn.one_hot(channel, state.m.shape[0], dtype=state.m.dtype)
    kappa_n = state.kappa + onehot
    m_n = (state.kappa * state.m + onehot * rate) / kappa_n
    alpha_n = state.alpha + 0.5 * onehot
    beta_n = state.beta + 0.5 * onehot * (state.kappa / kappa_n) * (rate - state.m) ** 2
    # untouched channels: onehot==0 leaves all four parameters unchanged
    return NIGState(m=m_n, kappa=kappa_n, alpha=alpha_n, beta=beta_n)


@jax.jit
def nig_update_batch(state: NIGState, rates: jax.Array, mask: jax.Array) -> NIGState:
    """Simultaneous update of every channel with one observation each.

    rates: (K,) normalized rates; mask: (K,) 1.0 where a channel reported this
    round (failed/idle channels report nothing). This is the per-step scheduler
    path: one fused update for the whole fleet.
    """
    kappa_n = state.kappa + mask
    m_n = (state.kappa * state.m + mask * rates) / kappa_n
    alpha_n = state.alpha + 0.5 * mask
    beta_n = state.beta + 0.5 * mask * (state.kappa / kappa_n) * (rates - state.m) ** 2
    return NIGState(m=m_n, kappa=kappa_n, alpha=alpha_n, beta=beta_n)


@jax.jit
def nig_point_estimates(state: NIGState):
    """(mu_hat, sigma_hat) for the partitioner.

    mu_hat = posterior mean of mu; sigma_hat^2 = posterior-predictive variance
    (Student-t matched), i.e. E[sigma^2]*(1 + 1/kappa) * nu/(nu-2) correction —
    we use the standard E[sigma^2] = beta/(alpha-1) plus mean-uncertainty
    inflation beta/(alpha-1)/kappa, which converges to sigma^2 as data accrues
    and stays finite for alpha>1.
    """
    ev = state.beta / jnp.maximum(state.alpha - 1.0, 1e-3)
    sigma2 = ev * (1.0 + 1.0 / jnp.maximum(state.kappa, 1e-6))
    return state.m, jnp.sqrt(sigma2)
