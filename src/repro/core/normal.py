"""Compat shim: the Normal-distribution primitives moved to
``repro.core.distributions`` when the channel completion-time model became a
pluggable family (normal / lognormal / drift / empirical). Import from there;
this module re-exports the original names so existing call sites keep working.
"""
from __future__ import annotations

from .distributions import (  # noqa: F401
    Phi,
    Phi_c,
    log_Phi,
    phi,
    point_mass_cdf,
    safe_cdf,
    scaled_channel_params,
)

__all__ = [
    "phi",
    "Phi",
    "Phi_c",
    "log_Phi",
    "point_mass_cdf",
    "scaled_channel_params",
    "safe_cdf",
]
