"""Normal-distribution primitives used throughout the partitioning core.

Everything is pure jnp, float64-safe when x64 is enabled, and vmap/jit friendly.
The paper models per-channel completion time of a channel ``i`` processing a
work fraction ``w`` as ``N(w * mu_i, (w * sigma_i)^2)``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "phi",
    "Phi",
    "Phi_c",
    "log_Phi",
    "scaled_channel_params",
    "safe_cdf",
]

_SQRT2 = 1.4142135623730951
_SQRT_2PI = 2.5066282746310002


def phi(x: jax.Array) -> jax.Array:
    """Standard normal pdf."""
    return jnp.exp(-0.5 * x * x) / _SQRT_2PI


def Phi(x: jax.Array) -> jax.Array:
    """Standard normal cdf via erf (TPU/VPU friendly; no erfc tables)."""
    return 0.5 * (1.0 + jax.lax.erf(x / _SQRT2))


def Phi_c(x: jax.Array) -> jax.Array:
    """Standard normal survival function 1 - Phi(x), numerically stable tail."""
    return 0.5 * jax.lax.erfc(x / _SQRT2)


def log_Phi(x: jax.Array) -> jax.Array:
    """log CDF, stable for moderately negative x (sufficient for our grids)."""
    return jnp.log(jnp.clip(Phi(x), 1e-300, 1.0))


def scaled_channel_params(w, mu, sigma):
    """Per-channel completion-time params when channel gets work fraction ``w``.

    T_i ~ N(w*mu_i, (w*sigma_i)^2)  (paper's scaling assumption).
    Accepts broadcastable arrays.
    """
    w = jnp.asarray(w)
    return w * mu, w * sigma


def safe_cdf(t, mean, std):
    """CDF of N(mean, std^2) evaluated at t, treating std==0 (zero work) as a
    point mass at ``mean`` — i.e. a channel with no work has finished for t>=mean.

    For w=0 channels mean is also 0, so the channel contributes CDF 1 for t>=0.
    """
    std_ok = std > 0.0
    z = (t - mean) / jnp.where(std_ok, std, 1.0)
    point = (t >= mean).astype(z.dtype)
    return jnp.where(std_ok, Phi(z), point)
