"""DEPRECATED compat shim: the Normal-distribution primitives moved to
``repro.core.distributions`` when the channel completion-time model became a
pluggable family (normal / lognormal / drift / empirical).

Importing this module emits a :class:`DeprecationWarning`; it will be removed
once external callers have migrated. Every name here is a re-export —
``from repro.core.distributions import ...`` (or ``from repro.core import
...``) is the supported spelling, and no in-repo module imports this shim
anymore.
"""
from __future__ import annotations

import warnings

warnings.warn(
    "repro.core.normal is deprecated: import these primitives from "
    "repro.core.distributions (they moved when the completion-time model "
    "became a pluggable ChannelFamily). In-repo imports of this shim are "
    "flagged by lint rule RPA050 (scripts/lint.py).",
    DeprecationWarning,
    stacklevel=2,
)

from .distributions import (  # noqa: F401,E402
    Phi,
    Phi_c,
    log_Phi,
    phi,
    point_mass_cdf,
    safe_cdf,
    scaled_channel_params,
)

__all__ = [
    "phi",
    "Phi",
    "Phi_c",
    "log_Phi",
    "point_mass_cdf",
    "scaled_channel_params",
    "safe_cdf",
]
