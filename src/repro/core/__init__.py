"""repro.core — the paper's contribution: partitioning uncertain workflows.

Public API:
    frontier_2ch / curve_2ch     — paper Figs 1 & 2 (curves + efficient frontier)
    frontier_kch                 — K-channel frontier (batched kernel sweep)
    optimize_2ch                 — the paper's split procedure for two channels
    optimize_weights             — K-channel simplex generalization
    max_moments_quad             — survival-integral oracle (paper's integrals)
    clark_max_moments_2 / _seq   — closed-form / sequential moment matching
    NIGState, nig_*              — Bayesian on-the-fly channel estimation
    score_families               — online BIC family selection (family="auto")
    moment_sensitivity / posterior_sensitivity — d(solve)/d(posterior params)
    select_channels              — how many channels to enlist (group testing ext.)
    ChannelFamily / get_family   — pluggable completion-time families
                                   (normal | lognormal | drift | empirical |
                                    defective)
    remaining_work_stats         — sunk-work rescaling for mid-flight re-solves
"""
from .distributions import (
    FAMILIES,
    ChannelFamily,
    Defective,
    Drift,
    Empirical,
    LogNormal,
    Normal,
    Phi,
    Phi_c,
    defective_moments_np,
    family_from_extra,
    get_family,
    phi,
    point_mass_cdf,
    remaining_work_stats,
    resolve_family,
    safe_cdf,
    scaled_channel_params,
)
from .maxstat import (
    clark_max_moments_2,
    clark_max_moments_seq,
    joint_cdf,
    joint_cdf_w,
    max_moments_mc,
    max_moments_quad,
    max_moments_quad_w,
    time_grid,
)
from .frontier import (
    FrontierResult,
    curve_2ch,
    curve_weights,
    frontier_2ch,
    frontier_kch,
    moments_for_split,
    pareto_mask,
    select_on_frontier,
    simplex_candidates,
)
from .partitioner import (
    PartitionDecision,
    equal_split,
    inverse_mu_split,
    objective,
    optimize_2ch,
    optimize_weights,
    predict_moments,
)
from .bayes import (
    AUTO_FAMILIES,
    FamilyScores,
    NIGState,
    fit_selected_family,
    nig_estimate_ses,
    nig_init,
    nig_point_estimates,
    nig_update,
    nig_update_batch,
    score_families,
)
from .sensitivity import (
    MomentSensitivity,
    PosteriorSensitivity,
    estimation_fragility,
    fragility_batch,
    moment_sensitivity,
    posterior_sensitivity,
)
from .group import GroupChoice, select_channels, select_channels_exhaustive

__all__ = [k for k in dir() if not k.startswith("_")]
