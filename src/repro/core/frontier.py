"""Efficient-frontier computation over workflow splits (paper Figs 1 & 2).

For two channels the split is a scalar ``f`` (channel i gets f, channel j gets
1-f); for K channels it is a simplex weight vector ``w``. For every candidate
split we evaluate the joint-completion moments (mu, sigma^2) and extract the
Pareto-efficient subset — the paper's bolded red frontier.

All candidate evaluation is batched: the tracer builds an (F, K) candidate
matrix and hands it to ``repro.kernels.ops.frontier_moments`` in ONE launch
(``impl`` selects the pure-XLA path or the Pallas TPU kernel), instead of
re-running the survival integral per split via vmap and bouncing (F, T, K)
intermediates through HBM.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops
from .distributions import resolve_family
from .maxstat import max_moments_quad_w

__all__ = [
    "FrontierResult",
    "moments_for_split",
    "simplex_candidates",
    "curve_2ch",
    "curve_weights",
    "pareto_mask",
    "frontier_2ch",
    "frontier_kch",
    "select_on_frontier",
]


@dataclass(frozen=True)
class FrontierResult:
    """μ(f), σ²(f) samples plus the Pareto-efficient subset."""

    f: np.ndarray          # (F,) or (F,K) candidate splits
    mu: np.ndarray         # (F,)
    var: np.ndarray        # (F,)
    efficient: np.ndarray  # (F,) bool — Pareto-efficient in (mu, var)

    @property
    def f_min_mu(self) -> float:
        return float(np.asarray(self.f)[int(np.argmin(self.mu))] if np.ndim(self.f) == 1
                     else np.argmin(self.mu))

    @property
    def f_min_var(self) -> float:
        return float(np.asarray(self.f)[int(np.argmin(self.var))] if np.ndim(self.f) == 1
                     else np.argmin(self.var))


def moments_for_split(w, mus, sigmas, num: int = 2048,
                      family="normal") -> Tuple[jax.Array, jax.Array]:
    """(mu, var) of the joint completion time for one split vector ``w``.

    Single-split oracle (survival-integral quadrature); batched candidate
    sweeps go through :func:`curve_weights` / ``ops.frontier_moments``.
    """
    return max_moments_quad_w(w, mus, sigmas, num=num, family=family)


@partial(jax.jit, static_argnames=("num_t", "impl", "block_f", "dist_id"))
def _batched_moments(W, mus, sigmas, extra, num_t: int, impl: str,
                     block_f: Optional[int] = None, dist_id: str = "normal"):
    return ops.frontier_moments(W, mus, sigmas, num_t=num_t, impl=impl,
                                block_f=block_f, family=(dist_id, extra))


def curve_2ch(mu_i, sigma_i, mu_j, sigma_j, num_f: int = 201, num_t: int = 2048,
              impl: str = "xla", family="normal"):
    """μ(f), σ²(f) for f in [0,1]: channel i gets f, channel j gets 1-f.

    Matches the paper's Figure 1 setup exactly (``family`` swaps the
    completion-time model; "normal" is the paper's). Returns (f, mu, var)
    arrays. The whole f-grid is evaluated as one (num_f, 2) batch in a single
    ``frontier_moments`` launch.
    """
    fs = jnp.linspace(0.0, 1.0, num_f)
    W = jnp.stack([fs, 1.0 - fs], axis=1)
    mus = jnp.stack([jnp.asarray(mu_i, jnp.float32), jnp.asarray(mu_j, jnp.float32)])
    sgs = jnp.stack([jnp.asarray(sigma_i, jnp.float32), jnp.asarray(sigma_j, jnp.float32)])
    dist_id, extra = resolve_family(family, 2)
    mu, var = _batched_moments(W, mus, sgs, jnp.asarray(extra, jnp.float32),
                               num_t, impl, None, dist_id)
    return fs, mu, var


def curve_weights(W, mus, sigmas, num_t: int = 2048, impl: str = "xla",
                  block_f: Optional[int] = None, family="normal"):
    """Batched (mu, var) over K-channel weight vectors W: (F, K)."""
    W = jnp.asarray(W, jnp.float32)
    dist_id, extra = resolve_family(family, W.shape[1])
    return _batched_moments(W,
                            jnp.asarray(mus, jnp.float32),
                            jnp.asarray(sigmas, jnp.float32),
                            jnp.asarray(extra, jnp.float32),
                            num_t, impl, block_f, dist_id)


def pareto_mask(mu: np.ndarray, var: np.ndarray) -> np.ndarray:
    """Boolean mask of Pareto-efficient points (minimize both mu and var).

    Fully vectorized O(F log F): sort by mu (var tie-break), then a point is
    efficient iff its var beats the running minimum of every point sorted
    before it (``np.minimum.accumulate``) — no interpreted per-point loop,
    which at F=4096 was O(F) Python work inside every frontier call.
    Ties handled so duplicated points are both kept only if non-dominated.
    """
    mu = np.asarray(mu)
    var = np.asarray(var)
    order = np.lexsort((var, mu))  # primary mu, tie-break var
    v_sorted = var[order]
    prev_best = np.concatenate(([np.inf], np.minimum.accumulate(v_sorted)[:-1]))
    eff = np.zeros(mu.shape[0], dtype=bool)
    eff[order] = v_sorted < prev_best - 1e-15
    return eff


def frontier_2ch(mu_i, sigma_i, mu_j, sigma_j, num_f: int = 201,
                 num_t: int = 2048, impl: str = "xla",
                 family="normal") -> FrontierResult:
    """Full paper pipeline for two channels: curves + efficient frontier."""
    fs, mu, var = curve_2ch(mu_i, sigma_i, mu_j, sigma_j, num_f=num_f,
                            num_t=num_t, impl=impl, family=family)
    fs, mu, var = np.asarray(fs), np.asarray(mu), np.asarray(var)
    return FrontierResult(f=fs, mu=mu, var=var, efficient=pareto_mask(mu, var))


def _with_fixed(W: np.ndarray, fixed: np.ndarray) -> np.ndarray:
    """Append any ``fixed`` rows (vertices, centroid) missing from ``W``."""
    missing = [v for v in fixed if not (np.abs(W - v).sum(axis=1) < 1e-12).any()]
    return np.concatenate([W, np.stack(missing)], axis=0) if missing else W


def _triangular_grid(num_f: int) -> np.ndarray:
    """Structured 3-simplex grid with at least ``num_f`` points."""
    m = 1
    while (m + 1) * (m + 2) // 2 < num_f:
        m += 1
    pts = [(i / m, j / m, (m - i - j) / m)
           for i in range(m + 1) for j in range(m + 1 - i)]
    return np.asarray(pts, np.float64)


def simplex_candidates(k: int, num_f: int,
                       key: Optional[jax.Array] = None) -> np.ndarray:
    """(F, k) candidate splits covering the probability simplex.

    K<=3 uses a structured grid (F rounds up to a full grid); larger K uses a
    Sobol low-discrepancy sequence mapped to the simplex via exponential
    spacings (falls back to Dirichlet sampling without scipy). Vertices and
    the centroid are always included so single-channel assignments and the
    equal split are exact candidates.
    """
    if k == 1:
        return np.ones((1, 1))
    fixed = np.concatenate([np.eye(k), np.full((1, k), 1.0 / k)], axis=0)
    if k == 2:
        fs = np.linspace(0.0, 1.0, max(num_f, 2))
        return _with_fixed(np.stack([fs, 1.0 - fs], axis=1), fixed)
    if k == 3:
        return _with_fixed(_triangular_grid(num_f), fixed)
    n_rand = max(num_f - fixed.shape[0], 0)
    if n_rand == 0:
        return fixed
    try:
        from scipy.stats import qmc

        # power-of-2 draw keeps the Sobol balance guarantees; truncate after
        n_pow2 = 1 << (n_rand - 1).bit_length()
        u = qmc.Sobol(d=k, scramble=True, seed=0).random(n_pow2)[:n_rand]
        e = -np.log1p(-np.clip(u, 0.0, 1.0 - 1e-12))  # Exp(1) spacings
        rand = e / e.sum(axis=1, keepdims=True)
    except ImportError:  # pragma: no cover - depends on environment
        rng_key = key if key is not None else jax.random.PRNGKey(0)
        rand = np.asarray(jax.random.dirichlet(rng_key, jnp.ones((k,)), (n_rand,)))
    return np.concatenate([fixed, rand], axis=0)


def frontier_kch(mus, sigmas, num_f: int = 512, num_t: int = 1024,
                 lam: float = 0.0, impl: str = "xla",
                 block_f: Optional[int] = None,
                 key: Optional[jax.Array] = None, include_pgd: bool = True,
                 pgd_steps: int = 120, family="normal") -> FrontierResult:
    """K-channel efficient frontier (beyond the paper's 2-channel exposition).

    Generates simplex candidates (structured grid for K<=3, Sobol/Dirichlet
    for larger K, plus the PGD solution of the scalarized objective so the
    frontier always contains an optimized point), evaluates all of them under
    the requested completion-time ``family`` in one batched
    ``frontier_moments`` launch, and extracts the Pareto subset.
    """
    mus = np.asarray(mus, np.float64)
    sigmas = np.asarray(sigmas, np.float64)
    k = mus.shape[0]
    W = simplex_candidates(k, num_f, key=key)
    if include_pgd and k > 1:
        from .partitioner import optimize_weights  # lazy: avoids import cycle

        dec = optimize_weights(mus, sigmas, lam=lam, steps=pgd_steps,
                               num_t=num_t, restarts=0, impl=impl,
                               family=family)
        W = np.concatenate([W, dec.weights[None, :]], axis=0)
    mu, var = curve_weights(W, mus, sigmas, num_t=num_t, impl=impl,
                            block_f=block_f, family=family)
    mu, var = np.asarray(mu), np.asarray(var)
    return FrontierResult(f=W, mu=mu, var=var, efficient=pareto_mask(mu, var))


def select_on_frontier(result: FrontierResult, lam: float = 0.0):
    """Pick the frontier point minimizing mu + lam * var.

    lam=0 reproduces "fastest expected completion"; large lam prioritizes
    certainty. Only efficient points are eligible (the paper leaves the final
    choice on the frontier to the operator; this is the scalarized default).
    """
    idx_all = np.nonzero(result.efficient)[0]
    if idx_all.size == 0:  # degenerate: single point
        idx_all = np.arange(result.mu.shape[0])
    score = result.mu[idx_all] + lam * result.var[idx_all]
    pick = idx_all[int(np.argmin(score))]
    return pick, (np.asarray(result.f)[pick], result.mu[pick], result.var[pick])
