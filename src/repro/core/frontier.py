"""Efficient-frontier computation over workflow splits (paper Figs 1 & 2).

For two channels the split is a scalar ``f`` (channel i gets f, channel j gets
1-f); for K channels it is a simplex weight vector ``w``. For every candidate
split we evaluate the joint-completion moments (mu, sigma^2) and extract the
Pareto-efficient subset — the paper's bolded red frontier.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .maxstat import max_moments_quad
from .normal import scaled_channel_params

__all__ = [
    "FrontierResult",
    "moments_for_split",
    "curve_2ch",
    "curve_weights",
    "pareto_mask",
    "frontier_2ch",
    "select_on_frontier",
]


@dataclass(frozen=True)
class FrontierResult:
    """μ(f), σ²(f) samples plus the Pareto-efficient subset."""

    f: np.ndarray          # (F,) or (F,K) candidate splits
    mu: np.ndarray         # (F,)
    var: np.ndarray        # (F,)
    efficient: np.ndarray  # (F,) bool — Pareto-efficient in (mu, var)

    @property
    def f_min_mu(self) -> float:
        return float(np.asarray(self.f)[int(np.argmin(self.mu))] if np.ndim(self.f) == 1
                     else np.argmin(self.mu))

    @property
    def f_min_var(self) -> float:
        return float(np.asarray(self.f)[int(np.argmin(self.var))] if np.ndim(self.f) == 1
                     else np.argmin(self.var))


def moments_for_split(w, mus, sigmas, num: int = 2048) -> Tuple[jax.Array, jax.Array]:
    """(mu, var) of the joint completion time for one split vector ``w``."""
    means, stds = scaled_channel_params(w, mus, sigmas)
    return max_moments_quad(means, stds, num=num)


@partial(jax.jit, static_argnames=("num_f", "num_t"))
def curve_2ch(mu_i, sigma_i, mu_j, sigma_j, num_f: int = 201, num_t: int = 2048):
    """μ(f), σ²(f) for f in [0,1]: channel i gets f, channel j gets 1-f.

    Matches the paper's Figure 1 setup exactly. Returns (f, mu, var) arrays.
    """
    fs = jnp.linspace(0.0, 1.0, num_f)

    mus = jnp.stack([jnp.asarray(mu_i, jnp.float32), jnp.asarray(mu_j, jnp.float32)])
    sgs = jnp.stack([jnp.asarray(sigma_i, jnp.float32), jnp.asarray(sigma_j, jnp.float32)])

    def one(f):
        w = jnp.stack([f, 1.0 - f])
        return moments_for_split(w, mus, sgs, num=num_t)

    mu, var = jax.vmap(one)(fs)
    return fs, mu, var


@partial(jax.jit, static_argnames=("num_t",))
def curve_weights(W, mus, sigmas, num_t: int = 2048):
    """Vectorized (mu, var) over a batch of K-channel weight vectors W: (F, K)."""
    def one(w):
        return moments_for_split(w, mus, sigmas, num=num_t)
    return jax.vmap(one)(W)


def pareto_mask(mu: np.ndarray, var: np.ndarray) -> np.ndarray:
    """Boolean mask of Pareto-efficient points (minimize both mu and var).

    O(F log F): sort by mu then sweep keeping a running min of var.
    Ties handled so duplicated points are both kept only if non-dominated.
    """
    mu = np.asarray(mu)
    var = np.asarray(var)
    order = np.lexsort((var, mu))  # primary mu, tie-break var
    eff = np.zeros(mu.shape[0], dtype=bool)
    best_var = np.inf
    for idx in order:
        if var[idx] < best_var - 1e-15:
            eff[idx] = True
            best_var = var[idx]
    return eff


def frontier_2ch(mu_i, sigma_i, mu_j, sigma_j, num_f: int = 201, num_t: int = 2048) -> FrontierResult:
    """Full paper pipeline for two channels: curves + efficient frontier."""
    fs, mu, var = curve_2ch(mu_i, sigma_i, mu_j, sigma_j, num_f=num_f, num_t=num_t)
    fs, mu, var = np.asarray(fs), np.asarray(mu), np.asarray(var)
    return FrontierResult(f=fs, mu=mu, var=var, efficient=pareto_mask(mu, var))


def select_on_frontier(result: FrontierResult, lam: float = 0.0):
    """Pick the frontier point minimizing mu + lam * var.

    lam=0 reproduces "fastest expected completion"; large lam prioritizes
    certainty. Only efficient points are eligible (the paper leaves the final
    choice on the frontier to the operator; this is the scalarized default).
    """
    idx_all = np.nonzero(result.efficient)[0]
    if idx_all.size == 0:  # degenerate: single point
        idx_all = np.arange(result.mu.shape[0])
    score = result.mu[idx_all] + lam * result.var[idx_all]
    pick = idx_all[int(np.argmin(score))]
    return pick, (np.asarray(result.f)[pick], result.mu[pick], result.var[pick])
