"""Split optimizers: choose the work partition across uncertain channels.

Three tiers, all pure JAX:

* :func:`optimize_2ch` — dense-grid + local refinement over scalar f (exactly
  the paper's procedure: trace the curve, pick from the frontier).
* :func:`optimize_weights` — K-channel simplex optimization of the scalarized
  objective ``mu(w) + lam * var(w)`` by projected gradient through the
  survival-integral moments (beyond-paper: the integral is differentiable).
* Baselines: :func:`equal_split` (map-reduce style, the paper's foil) and
  :func:`inverse_mu_split` (deterministic load balancing that ignores variance).

Every candidate-moment evaluation routes through
``repro.kernels.ops.frontier_moments``: each PGD step consumes the fused
analytic moments+gradient launch (``frontier_moments_with_grads`` — no
autodiff replay through the quadrature), multi-start solutions are scored in
a single batched launch, and ``impl`` selects XLA vs the Pallas TPU kernel
for the solve itself, gradients included.

The scheduler layer (repro.sched) consumes these to assign integer workloads.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..analysis import sanitize as _san
from ..kernels import ops
from .distributions import (remaining_work_stats, resolve_family,
                            scaled_channel_params)
from .frontier import frontier_2ch, select_on_frontier
from .maxstat import clark_max_moments_seq, max_moments_quad_w

__all__ = [
    "PartitionDecision",
    "equal_split",
    "inverse_mu_split",
    "optimize_2ch",
    "optimize_weights",
    "objective",
]


@dataclass(frozen=True)
class PartitionDecision:
    """The chosen split plus its predicted joint moments."""

    weights: np.ndarray  # (K,) nonneg, sums to 1
    mu: float            # predicted E[completion]
    var: float           # predicted Var[completion]
    method: str

    def speedup_vs(self, other: "PartitionDecision") -> float:
        return float(other.mu / max(self.mu, 1e-12))


def equal_split(k: int) -> jnp.ndarray:
    """Map-reduce baseline: equal shares regardless of channel statistics."""
    return jnp.full((k,), 1.0 / k)


def inverse_mu_split(mus) -> jnp.ndarray:
    """Deterministic balance: w_i ∝ 1/mu_i equalizes *expected* finish times.

    Optimal if sigmas were all zero; ignores uncertainty (the paper's point is
    that this is not enough).
    """
    inv = 1.0 / jnp.asarray(mus)
    return inv / jnp.sum(inv)


def objective(w, mus, sigmas, lam: float, num_t: int = 1024,
              family="normal"):
    """Scalarized mean-variance objective on the joint completion time.

    Evaluated as a one-row batch through ``frontier_moments``; differentiable
    on every impl via the registered analytic custom VJP, so ``jax.grad`` of
    this function descends exactly the fused-kernel gradients the PGD solver
    consumes directly.
    """
    mu, var = ops.frontier_moments(jnp.asarray(w)[None, :], mus, sigmas,
                                   num_t=num_t, impl="xla", family=family)
    return (mu + lam * var)[0]


def optimize_2ch(mu_i, sigma_i, mu_j, sigma_j, lam: float = 0.0,
                 num_f: int = 401, num_t: int = 2048,
                 impl: str = "xla", family="normal") -> PartitionDecision:
    """Paper's two-channel procedure: dense f-grid, frontier, scalarized pick."""
    res = frontier_2ch(mu_i, sigma_i, mu_j, sigma_j, num_f=num_f, num_t=num_t,
                       impl=impl, family=family)
    _, (f, mu, var) = select_on_frontier(res, lam=lam)
    w = np.asarray([f, 1.0 - f], dtype=np.float64)
    return PartitionDecision(weights=w, mu=float(mu), var=float(var), method="grid-2ch")


def _project_simplex(v):
    """Euclidean projection of v onto the probability simplex (Held et al.)."""
    k = v.shape[-1]
    u = jnp.sort(v)[::-1]
    css = jnp.cumsum(u) - 1.0
    idx = jnp.arange(1, k + 1, dtype=v.dtype)
    cond = u - css / idx > 0
    rho = jnp.max(jnp.where(cond, jnp.arange(k), -1))
    theta = css[rho] / (rho + 1.0)
    return jnp.maximum(v - theta, 0.0)


@partial(jax.jit, static_argnames=("steps", "num_t", "impl", "block_f",
                                   "dist_id", "sanitize"))
def _pgd_multi(W0, mus, sigmas, extra, lam, steps: int = 200, num_t: int = 1024,
               lr: float = 0.05, impl: str = "xla",
               block_f: Optional[int] = None, dist_id: str = "normal",
               sanitize: bool = False):
    """All starts solved as ONE batched PGD on the fused kernel.

    Each step evaluates the whole (S, K) iterate stack through
    ``frontier_moments_with_grads`` — one fused launch returns moments and
    analytic adjoints, so there is no autodiff replay, no per-start vmap, and
    the compiled Pallas path is usable inside the optimizer (``impl`` selects
    the backend for the gradient evaluations themselves; the static
    ``dist_id`` + traced ``extra`` select the completion-time family without
    retracing when only family parameters move).

    Static ``sanitize=True`` plants checkify invariant checks on the iterate
    and gradients each step; legal only under ``analysis.sanitize.run_checked``
    (an unwrapped checkify.check inside jit is a trace-time error).
    """
    proj = jax.vmap(_project_simplex)

    def body(i, W):
        _, _, dmu, dvar = ops.frontier_moments_with_grads(
            W, mus, sigmas, num_t=num_t, impl=impl, block_f=block_f,
            family=(dist_id, extra))
        g = dmu + lam * dvar
        if sanitize:
            _san.check_finite(g, "PGD gradient")
        # normalize gradient scale so lr is unitless across problem magnitudes
        g = g / (jnp.linalg.norm(g, axis=-1, keepdims=True) + 1e-12)
        step = lr * 0.5 * (1.0 + jnp.cos(jnp.pi * i / steps))
        W = proj(W - step * g)
        if sanitize:
            _san.check_weight_rows(W, "PGD iterate")
        return W

    return jax.lax.fori_loop(0, steps, body, W0)


def optimize_weights(mus, sigmas, lam: float = 0.0, steps: int = 200,
                     num_t: int = 1024, restarts: int = 3,
                     key: Optional[jax.Array] = None, impl: str = "xla",
                     warm_start: Optional[np.ndarray] = None,
                     block_f: Optional[int] = None,
                     family="normal", risk_lam: float = 0.0,
                     posterior=None,
                     return_sensitivity: bool = False,
                     done=None,
                     eval_num_t: Optional[int] = None):
    """K-channel simplex optimization (beyond paper's 2-channel exposition).

    Multi-start PGD: deterministic starts at equal-split and inverse-mu, an
    optional ``warm_start`` (e.g. the balancer's previous solve — posteriors
    move a little per refresh tick, so the old optimum is a near-solution),
    plus random Dirichlet restarts. All starts advance together as one
    batched fused moments+gradient evaluation per PGD step (analytic
    adjoints, no autodiff replay) under the requested ``impl``, and the final
    candidates are scored in a single batched ``frontier_moments`` launch.
    ``block_f=None`` defers the launch shape to ``kernels.autotune``.

    Closed-loop extensions (the channel statistics are *estimates*):

    * ``risk_lam > 0`` (needs ``posterior``, the balancer's ``NIGState``):
      final candidates are scored by the risk-adjusted objective
      ``mu + lam var + risk_lam * fragility(w)``, where fragility is the
      delta-method sd of the predicted mean under the posterior's estimation
      error (``core.sensitivity.fragility_batch`` — one extra fused
      full-parameter launch over the finalists). This penalizes splits whose
      optimum is fragile to estimation error: two near-tied candidates
      resolve toward the one whose prediction survives the posterior moving.
    * ``return_sensitivity=True``: returns ``(decision, report)`` where the
      report is a ``core.sensitivity.PosteriorSensitivity`` at the chosen
      split when ``posterior`` is given (closed-form d(moments)/d(m, kappa,
      alpha, beta)), else a ``MomentSensitivity`` (d(moments)/d(mus, sigmas,
      rho)).
    * ``done`` (per-channel completed work fractions): the sunk-work
      mid-flight re-solve. Channel statistics are rescaled to the remaining
      work ``r = 1 - sum(done)`` through
      ``distributions.remaining_work_stats`` (drift channels keep their
      inflated instantaneous rate — see there for the per-family algebra),
      and the returned weights are shares OF THE REMAINING WORK: channel k
      executes ``weights[k] * r`` more units of the original job. The
      predicted moments are for the remaining work only — add the caller's
      elapsed wall time for an end-to-end estimate.
    * ``eval_num_t``: quadrature resolution the finalists are scored at —
      the winner's moments are reused for the reported decision (no extra
      re-launch). Default max(num_t, 2048); callers on a coarse fidelity
      rung (``workflow.solve_dag_greedy``) pass their own.
    """
    mus = jnp.asarray(mus, jnp.float32)
    sigmas = jnp.asarray(sigmas, jnp.float32)
    k = mus.shape[0]
    dist_id, extra = resolve_family(family, k)
    if done is not None:
        mus_r, sigmas_r, extra_r, r = remaining_work_stats(
            dist_id, np.asarray(mus), np.asarray(sigmas), np.asarray(extra),
            done)
        if r <= 0.0:
            # nothing left to solve: degenerate all-done decision
            return PartitionDecision(weights=np.zeros(k), mu=0.0, var=0.0,
                                     method="pgd-simplex-done")
        mus = jnp.asarray(mus_r, jnp.float32)
        sigmas = jnp.asarray(sigmas_r, jnp.float32)
        extra = extra_r
    extra = jnp.asarray(extra, jnp.float32)
    starts = [equal_split(k), inverse_mu_split(mus)]
    if warm_start is not None:
        ws = jnp.asarray(warm_start, jnp.float32)
        starts.insert(0, jnp.maximum(ws, 0.0) / jnp.maximum(jnp.sum(ws), 1e-12))
    if restarts > 0:
        key = key if key is not None else jax.random.PRNGKey(0)
        dirichlet = jax.random.dirichlet(key, jnp.ones((k,)), (restarts,))
        starts += [dirichlet[i] for i in range(restarts)]

    W0 = jnp.stack(starts)
    if _san.enabled():
        # sanitizer tier: eager boundary validation, then the jitted solver
        # under checkify so the in-loop invariant checks are functionalized
        _san.check_frontier_inputs(W0, mus, sigmas, extra, dist_id=dist_id)
        Wf = _san.run_checked(
            partial(_pgd_multi, steps=steps, num_t=num_t, impl=impl,
                    block_f=block_f, dist_id=dist_id, sanitize=True),
            W0, mus, sigmas, extra, jnp.float32(lam))
    else:
        Wf = _pgd_multi(W0, mus, sigmas, extra, jnp.float32(lam), steps=steps,
                        num_t=num_t, impl=impl, block_f=block_f,
                        dist_id=dist_id)
    # finalists are scored ONCE at evaluation resolution and the winner's
    # moments are reused for the reported decision — the old extra
    # single-row "oracle" re-launch is gone (same fidelity, one launch less)
    et = eval_num_t if eval_num_t is not None else max(num_t, 2048)
    mu_c, var_c = ops.frontier_moments(Wf, mus, sigmas, num_t=et,
                                       impl=impl, block_f=block_f,
                                       family=(dist_id, extra))
    score = np.asarray(mu_c) + lam * np.asarray(var_c)
    method = "pgd-simplex"
    if risk_lam > 0.0 and posterior is not None:
        from .sensitivity import fragility_batch  # lazy: avoids import cycle

        frag = fragility_batch(Wf, mus, sigmas, posterior,
                               family=(dist_id, extra), num_t=num_t,
                               impl=impl, block_f=block_f)
        score = score + risk_lam * frag
        method = "pgd-simplex-risk"
    bi = int(np.argmin(score))
    best_w = Wf[bi]
    decision = PartitionDecision(weights=np.asarray(best_w, np.float64),
                                 mu=float(mu_c[bi]), var=float(var_c[bi]),
                                 method=method)
    if not return_sensitivity:
        return decision
    from .sensitivity import moment_sensitivity, posterior_sensitivity

    sens = moment_sensitivity(decision.weights, mus, sigmas,
                              family=(dist_id, extra), num_t=num_t,
                              impl=impl, block_f=block_f)
    report = (posterior_sensitivity(sens, posterior)
              if posterior is not None else sens)
    return decision, report


def predict_moments(w, mus, sigmas, exact: bool = True, num_t: int = 2048,
                    family="normal") -> Tuple[float, float]:
    """Predicted (mu, var) for an arbitrary split; Clark fast-path optional
    (Clark moment-matching is Normal-only — non-normal families always take
    the family-generic quadrature oracle)."""
    fam_id = resolve_family(family, jnp.asarray(w).shape[-1])[0]
    if exact or fam_id != "normal":
        mu, var = max_moments_quad_w(w, mus, sigmas, num=num_t, family=family)
    else:
        means, stds = scaled_channel_params(jnp.asarray(w), jnp.asarray(mus),
                                            jnp.asarray(sigmas))
        mu, var = clark_max_moments_seq(means, stds)
    return float(mu), float(var)
