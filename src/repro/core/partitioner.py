"""Split optimizers: choose the work partition across uncertain channels.

Three tiers, all pure JAX:

* :func:`optimize_2ch` — dense-grid + local refinement over scalar f (exactly
  the paper's procedure: trace the curve, pick from the frontier).
* :func:`optimize_weights` — K-channel simplex optimization of the scalarized
  objective ``mu(w) + lam * var(w)`` by projected gradient through the
  survival-integral moments (beyond-paper: the integral is differentiable).
* Baselines: :func:`equal_split` (map-reduce style, the paper's foil) and
  :func:`inverse_mu_split` (deterministic load balancing that ignores variance).

The scheduler layer (repro.sched) consumes these to assign integer workloads.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .frontier import frontier_2ch, select_on_frontier
from .maxstat import clark_max_moments_seq, max_moments_quad
from .normal import scaled_channel_params

__all__ = [
    "PartitionDecision",
    "equal_split",
    "inverse_mu_split",
    "optimize_2ch",
    "optimize_weights",
    "objective",
]


@dataclass(frozen=True)
class PartitionDecision:
    """The chosen split plus its predicted joint moments."""

    weights: np.ndarray  # (K,) nonneg, sums to 1
    mu: float            # predicted E[completion]
    var: float           # predicted Var[completion]
    method: str

    def speedup_vs(self, other: "PartitionDecision") -> float:
        return float(other.mu / max(self.mu, 1e-12))


def equal_split(k: int) -> jnp.ndarray:
    """Map-reduce baseline: equal shares regardless of channel statistics."""
    return jnp.full((k,), 1.0 / k)


def inverse_mu_split(mus) -> jnp.ndarray:
    """Deterministic balance: w_i ∝ 1/mu_i equalizes *expected* finish times.

    Optimal if sigmas were all zero; ignores uncertainty (the paper's point is
    that this is not enough).
    """
    inv = 1.0 / jnp.asarray(mus)
    return inv / jnp.sum(inv)


def objective(w, mus, sigmas, lam: float, num_t: int = 1024):
    """Scalarized mean-variance objective on the joint completion time."""
    means, stds = scaled_channel_params(w, mus, sigmas)
    mu, var = max_moments_quad(means, stds, num=num_t)
    return mu + lam * var


def optimize_2ch(mu_i, sigma_i, mu_j, sigma_j, lam: float = 0.0,
                 num_f: int = 401, num_t: int = 2048) -> PartitionDecision:
    """Paper's two-channel procedure: dense f-grid, frontier, scalarized pick."""
    res = frontier_2ch(mu_i, sigma_i, mu_j, sigma_j, num_f=num_f, num_t=num_t)
    _, (f, mu, var) = select_on_frontier(res, lam=lam)
    w = np.asarray([f, 1.0 - f], dtype=np.float64)
    return PartitionDecision(weights=w, mu=float(mu), var=float(var), method="grid-2ch")


def _project_simplex(v):
    """Euclidean projection of v onto the probability simplex (Held et al.)."""
    k = v.shape[-1]
    u = jnp.sort(v)[::-1]
    css = jnp.cumsum(u) - 1.0
    idx = jnp.arange(1, k + 1, dtype=v.dtype)
    cond = u - css / idx > 0
    rho = jnp.max(jnp.where(cond, jnp.arange(k), -1))
    theta = css[rho] / (rho + 1.0)
    return jnp.maximum(v - theta, 0.0)


@partial(jax.jit, static_argnames=("steps", "num_t"))
def _pgd(w0, mus, sigmas, lam, steps: int = 200, num_t: int = 1024, lr: float = 0.05):
    """Projected gradient descent on the simplex with cosine-decayed step."""
    grad_fn = jax.grad(objective)

    def body(i, w):
        g = grad_fn(w, mus, sigmas, lam, num_t)
        # normalize gradient scale so lr is unitless across problem magnitudes
        g = g / (jnp.linalg.norm(g) + 1e-12)
        step = lr * 0.5 * (1.0 + jnp.cos(jnp.pi * i / steps))
        return _project_simplex(w - step * g)

    return jax.lax.fori_loop(0, steps, body, w0)


def optimize_weights(mus, sigmas, lam: float = 0.0, steps: int = 200,
                     num_t: int = 1024, restarts: int = 3,
                     key: Optional[jax.Array] = None) -> PartitionDecision:
    """K-channel simplex optimization (beyond paper's 2-channel exposition).

    Multi-start PGD: deterministic starts at equal-split and inverse-mu plus
    random Dirichlet restarts; returns the best by scalarized objective.
    """
    mus = jnp.asarray(mus, jnp.float32)
    sigmas = jnp.asarray(sigmas, jnp.float32)
    k = mus.shape[0]
    starts = [equal_split(k), inverse_mu_split(mus)]
    if restarts > 0:
        key = key if key is not None else jax.random.PRNGKey(0)
        dirichlet = jax.random.dirichlet(key, jnp.ones((k,)), (restarts,))
        starts += [dirichlet[i] for i in range(restarts)]

    best_w, best_obj = None, np.inf
    for w0 in starts:
        w = _pgd(w0, mus, sigmas, jnp.float32(lam), steps=steps, num_t=num_t)
        val = float(objective(w, mus, sigmas, lam, num_t))
        if val < best_obj:
            best_obj, best_w = val, w

    means, stds = scaled_channel_params(best_w, mus, sigmas)
    mu, var = max_moments_quad(means, stds, num=2048)
    return PartitionDecision(weights=np.asarray(best_w, np.float64),
                             mu=float(mu), var=float(var), method="pgd-simplex")


def predict_moments(w, mus, sigmas, exact: bool = True, num_t: int = 2048) -> Tuple[float, float]:
    """Predicted (mu, var) for an arbitrary split; Clark fast-path optional."""
    means, stds = scaled_channel_params(jnp.asarray(w), jnp.asarray(mus), jnp.asarray(sigmas))
    if exact:
        mu, var = max_moments_quad(means, stds, num=num_t)
    else:
        mu, var = clark_max_moments_seq(means, stds)
    return float(mu), float(var)
