"""Posterior-sensitivity analysis: differentiate the frontier solve through
the *learned* channel statistics (the Bayesian loop of arXiv:1511.00613).

The solver consumes posterior point estimates ``(mu_hat, sigma_hat)`` (and,
for the drift family, per-channel ``rho``). Those estimates carry error, and
a split that is optimal at the point estimates can be fragile: a small move
of one channel's statistics can swing the predicted join time far more than
the optimality gap between candidate splits. This module closes the loop:

1. :func:`moment_sensitivity` — the solve's analytic parameter adjoints
   ``d(mu, var)/d(mus, sigmas, rho)`` at a split, straight from the fused
   full-parameter kernel launch (``ops.frontier_moments_with_grads`` with
   ``param_grads=True``; one launch on every impl).
2. :func:`posterior_sensitivity` — chains those adjoints through the NIG
   posterior parameterization ``(m, kappa, alpha, beta)`` of ``core.bayes``:
   closed-form ``d(completion moments)/d(posterior params)``.
3. :func:`estimation_fragility` — contracts the adjoints against the
   posterior standard errors (:func:`core.bayes.nig_estimate_ses`): the
   first-order (delta-method) sd of the predicted completion mean under
   estimation error. This is the *risk-adjusted objective*'s penalty term
   (``optimize_weights(..., risk_lam=...)``) and what the balancer's
   adaptive refresh sizes its cadence by — fragile solves refresh often,
   firm ones stretch.

Chain rule used by :func:`posterior_sensitivity` (see ``bayes.py``):

    mu_hat      = m                                  -> d mu_hat/dm = 1
    sigma_hat^2 = (beta/(alpha-1)) (1 + 1/kappa)
      d sigma_hat/dkappa = -(beta/(alpha-1)) / kappa^2 / (2 sigma_hat)
      d sigma_hat/dalpha = -sigma_hat^2/(alpha-1)    / (2 sigma_hat)
      d sigma_hat/dbeta  =  sigma_hat^2/beta         / (2 sigma_hat)

All arrays are host numpy (this sits on the scheduler thread, next to the
balancer); the kernel launch inside is the only device work.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from ..kernels import ops
from .bayes import NIGState, nig_estimate_ses

__all__ = [
    "MomentSensitivity",
    "PosteriorSensitivity",
    "moment_sensitivity",
    "posterior_sensitivity",
    "estimation_fragility",
    "fragility_batch",
]


@dataclass(frozen=True)
class MomentSensitivity:
    """Adjoints of the joint-completion moments at one split.

    Everything is (K,) except the scalars; ``d*_dextra`` is the cotangent of
    the family's ``extra`` row 0 (drift's per-channel rho — zeros for
    families without a differentiable shape parameter).
    """

    weights: np.ndarray
    mu: float
    var: float
    dmu_dw: np.ndarray
    dvar_dw: np.ndarray
    dmu_dmus: np.ndarray
    dvar_dmus: np.ndarray
    dmu_dsigmas: np.ndarray
    dvar_dsigmas: np.ndarray
    dmu_dextra: np.ndarray
    dvar_dextra: np.ndarray


@dataclass(frozen=True)
class PosteriorSensitivity:
    """``d(completion moments)/d(NIG posterior params)`` plus the fragility.

    The closed-form Bayesian loop: how the solve's output moves per unit
    change of each channel's posterior ``(m, kappa, alpha, beta)``, and the
    delta-method sd of the predicted mean under the current estimation
    error (``fragility``, in the same time units as ``mu``).
    """

    sens: MomentSensitivity
    dmu_dm: np.ndarray
    dmu_dkappa: np.ndarray
    dmu_dalpha: np.ndarray
    dmu_dbeta: np.ndarray
    dvar_dm: np.ndarray
    dvar_dkappa: np.ndarray
    dvar_dalpha: np.ndarray
    dvar_dbeta: np.ndarray
    fragility: float

    @property
    def relative_fragility(self) -> float:
        """Fragility as a fraction of the predicted mean (refresh sizing)."""
        return float(self.fragility / max(self.sens.mu, 1e-12))


def moment_sensitivity(w, mus, sigmas, family="normal", num_t: int = 1024,
                       impl: str = "xla", block_f: Optional[int] = None,
                       z: float = 10.0) -> MomentSensitivity:
    """Full parameter adjoints of the solve at split ``w`` (one launch)."""
    w = np.asarray(w, np.float64)
    outs = ops.frontier_moments_with_grads(
        w[None, :].astype(np.float32), mus, sigmas, num_t=num_t, impl=impl,
        block_f=block_f, z=z, family=family, param_grads=True)
    (mu, var, dw, dvw, dm, dvm, ds, dvs, de, dve) = \
        (np.asarray(o, np.float64) for o in outs)
    return MomentSensitivity(
        weights=w, mu=float(mu[0]), var=float(var[0]),
        dmu_dw=dw[0], dvar_dw=dvw[0], dmu_dmus=dm[0], dvar_dmus=dvm[0],
        dmu_dsigmas=ds[0], dvar_dsigmas=dvs[0],
        dmu_dextra=de[0], dvar_dextra=dve[0])


def _nig_chain(nig: NIGState):
    """d(mu_hat, sigma_hat)/d(m, kappa, alpha, beta), each (K,)."""
    m = np.asarray(nig.m, np.float64)
    kappa = np.maximum(np.asarray(nig.kappa, np.float64), 1e-6)
    alpha = np.asarray(nig.alpha, np.float64)
    beta = np.asarray(nig.beta, np.float64)
    am1 = np.maximum(alpha - 1.0, 1e-3)
    ev = beta / am1
    sigma2 = ev * (1.0 + 1.0 / kappa)
    sigma_hat = np.sqrt(np.maximum(sigma2, 1e-24))
    inv2s = 1.0 / (2.0 * sigma_hat)
    dsig_dkappa = -(ev / (kappa * kappa)) * inv2s
    dsig_dalpha = -(sigma2 / am1) * inv2s
    dsig_dbeta = (sigma2 / np.maximum(beta, 1e-12)) * inv2s
    return dsig_dkappa, dsig_dalpha, dsig_dbeta


def posterior_sensitivity(sens: MomentSensitivity,
                          nig: NIGState) -> PosteriorSensitivity:
    """Chain the solve adjoints through the NIG posterior parameters."""
    dsig_dkappa, dsig_dalpha, dsig_dbeta = _nig_chain(nig)
    return PosteriorSensitivity(
        sens=sens,
        # mu_hat = m exactly, so the m-cotangent IS the mus adjoint
        dmu_dm=sens.dmu_dmus.copy(),
        dmu_dkappa=sens.dmu_dsigmas * dsig_dkappa,
        dmu_dalpha=sens.dmu_dsigmas * dsig_dalpha,
        dmu_dbeta=sens.dmu_dsigmas * dsig_dbeta,
        dvar_dm=sens.dvar_dmus.copy(),
        dvar_dkappa=sens.dvar_dsigmas * dsig_dkappa,
        dvar_dalpha=sens.dvar_dsigmas * dsig_dalpha,
        dvar_dbeta=sens.dvar_dsigmas * dsig_dbeta,
        fragility=estimation_fragility(sens, nig))


def estimation_fragility(sens: MomentSensitivity, nig: NIGState) -> float:
    """Delta-method sd of the predicted completion mean under estimation
    error: ``sqrt(sum_k (dmu/dmu_k se_mu_k)^2 + (dmu/dsigma_k se_sig_k)^2)``.

    Channel posteriors are independent, so the first-order variance is the
    sum of squared per-channel contributions. Units: time (same as mu), so
    ``mu + risk_lam * fragility`` is a coherent risk-adjusted objective.
    """
    se_mu, se_sigma = (np.asarray(s, np.float64)
                       for s in nig_estimate_ses(nig))
    return float(np.sqrt(
        np.sum((sens.dmu_dmus * se_mu) ** 2)
        + np.sum((sens.dmu_dsigmas * se_sigma) ** 2)))


def fragility_batch(W, mus, sigmas, nig: NIGState, family="normal",
                    num_t: int = 1024, impl: str = "xla",
                    block_f: Optional[int] = None) -> np.ndarray:
    """Fragility of every candidate row of ``W`` (F, K) in one fused launch.

    The batched form :func:`estimation_fragility` — what the risk-adjusted
    candidate scoring inside ``optimize_weights`` consumes.
    """
    outs = ops.frontier_moments_with_grads(
        W, mus, sigmas, num_t=num_t, impl=impl, block_f=block_f,
        family=family, param_grads=True)
    dmu_m = np.asarray(outs[4], np.float64)       # (F, K)
    dmu_s = np.asarray(outs[6], np.float64)
    se_mu, se_sigma = (np.asarray(s, np.float64)
                       for s in nig_estimate_ses(nig))
    return np.sqrt(((dmu_m * se_mu) ** 2).sum(axis=1)
                   + ((dmu_s * se_sigma) ** 2).sum(axis=1))
