"""Pluggable channel completion-time distribution families.

The paper's two scenarios — convex optimization on contended VMs and bulk
file transfer over the Internet — have very different completion-time
statistics, but the original stack hard-coded the Gaussian scaling model
``T_i ~ N(w mu_i, (w sigma_i)^2)`` from the core down through the quadrature
kernels. This module makes the per-channel distribution a *family* selected
by a static ``dist_id`` so every layer (survival-integral oracles, the Pallas
kernels and their fused analytic adjoints, the PGD solver, the scheduler, the
simulator and the serving batcher) can run any of:

``normal``
    The paper's model: ``T(w) ~ N(w mu, (w sigma)^2)``.
``lognormal``
    Heavy-tailed service times (WAN transfers, GC pauses): ``T(w) = w R`` with
    ``R`` log-normal *moment-matched* to ``(mu, sigma)`` — the frontier is
    driven by the same two posterior statistics, only the shape changes.
``drift``
    Straggler model: the channel's per-unit rate inflates linearly over the
    course of the work it executes, so the mean is super-linear in the share,
    ``T(w) ~ N(w mu (1 + rho w / 2), (w sigma)^2)`` — a channel drifting at
    ``rho`` per unit work. ``rho = 0`` reduces exactly to ``normal``;
    per-channel ``rho`` lets the scheduler keep a detected straggler enlisted
    (with the drift priced in) instead of quarantining it.
``empirical``
    No parametric assumption: a C-component Gaussian mixture fitted to the
    observed per-unit rates (EM, deterministic init), evaluated exactly.
``defective``
    Failure-aware channels: each attempt fails with per-channel probability
    ``p`` and is re-run, a failed attempt costing ``lam`` of an attempt
    (``lam = 1`` retry pricing: all sunk work lost; ``lam = 0.5`` resume
    pricing: continuous mid-attempt checkpointing loses half an attempt in
    expectation). The completion time, conditioned on eventual success, is
    the geometric compound ``T = A_0 + lam * sum_{i<=N} A_i`` with
    ``N ~ Geom`` failures; the family's law is the Gaussian moment-matched
    to its retry-inflated moments ``a = mu (1 + lam p/q)``,
    ``b^2 = sigma^2 (1 + lam^2 p/q) + lam^2 mu^2 p/q^2`` (``q = 1 - p``) —
    a pure scale family in ``w``, so the whole analytic adjoint structure
    (including ``d/dp``, the failure-probability gradient in ``extra`` row
    0) stays inside the affine feature basis below. ``p = 0`` reduces
    exactly to ``normal``. :func:`family_sample` draws the PHYSICAL retry
    process (failures actually injected): per-channel moments match the
    law exactly, join moments to the Gaussian-shape approximation (same
    status as the Clark fold).

Kernel-facing contract
----------------------

Every family is described to the kernels by ``(dist_id, extra)`` where
``extra`` is a dense ``(E, K)`` float32 array of per-channel shape parameters
(``E = extra_rows(dist_id)``; families without parameters carry one zero row
so launch signatures stay uniform). The math the generalized survival-integral
adjoint needs factors, for every family above, into

    d log C_k / d w_k (t)  =  gate(t) * D_k(t) / C_k(t) * (alpha_k + beta_k t)
    d log C_k / d t   (t)  =  gate(t) * D_k(t) / C_k(t) * (gamma0_k + gamma1_k t) / t

with ``D_k`` a pdf-like per-grid-point numerator and
``alpha/beta/gamma0/gamma1`` per-channel constants (see
``kernels/frontier_grid.py`` for the derivation). That affine-in-``t``
structure is what keeps the fused kernel a two-pass streaming computation: at
most four per-channel accumulators (``P0/P1/Pv0/Pv1``), with the pure scale
families (normal, empirical) and lognormal needing only two — the
per-family accumulator count is part of the autotune working-set model.

Point-mass convention (single-sourced here): a degenerate channel — zero
work, zero spread, or both — is a point mass at its effective mean, and its
CDF is **right-continuous**: ``P(T <= t) = 1`` iff ``t >= mean`` (so a w=0
channel has "already finished" for every ``t >= 0``). Both the strict side
(``t < mean -> 0``) and the non-strict side (``t >= mean -> 1``) follow from
the one expression in :func:`point_mass_cdf`; the quadrature oracles and both
Pallas kernels share it rather than re-deriving the comparison locally.

All functions are pure jnp, broadcasting-agnostic (the vectorized (F, T, K)
reference path and the Pallas kernels' (block_f, T) per-channel slices call
the same code) and differentiable where the math is.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "FAMILIES",
    "EMP_COMPONENTS",
    "phi",
    "Phi",
    "Phi_c",
    "log_Phi",
    "scaled_channel_params",
    "point_mass_cdf",
    "safe_cdf",
    "extra_rows",
    "family_effective_moments",
    "family_cdf",
    "family_pdf_parts",
    "family_adjoint_parts",
    "family_coeffs",
    "family_param_coeffs",
    "family_accumulators",
    "family_features",
    "family_has_extra_grads",
    "family_dreach",
    "family_dreach_params",
    "family_sample",
    "ChannelFamily",
    "Normal",
    "LogNormal",
    "Drift",
    "Empirical",
    "Defective",
    "defective_moments_np",
    "remaining_work_stats",
    "get_family",
    "resolve_family",
    "family_from_extra",
]

FAMILIES = ("normal", "lognormal", "drift", "empirical", "defective")

# Static mixture size for the empirical family: big enough for bimodal
# contention profiles, small enough that the kernel's per-channel inner loop
# stays register-resident.
EMP_COMPONENTS = 3

_SQRT2 = 1.4142135623730951
_SQRT_2PI = 2.5066282746310002
_TINY = 1e-20  # safe-log floor; anything below the t-grid's resolution

# Survival-probability floor for the defective family: p is clamped to
# 1 - _Q_FLOOR so the p -> 1 limit (expected retries diverge) stays finite
# in every kernel; at the clamp the channel is priced as ~1e6 expected
# retries, which any solver already treats as "never assign work here".
_Q_FLOOR = 1e-6


# --------------------------------------------------------------------------
# standard-normal primitives (moved verbatim from core/normal.py; that module
# re-exports these for compatibility)
# --------------------------------------------------------------------------

def phi(x: jax.Array) -> jax.Array:
    """Standard normal pdf."""
    return jnp.exp(-0.5 * x * x) / _SQRT_2PI


def Phi(x: jax.Array) -> jax.Array:
    """Standard normal cdf via erf (TPU/VPU friendly; no erfc tables)."""
    return 0.5 * (1.0 + jax.lax.erf(x / _SQRT2))


def Phi_c(x: jax.Array) -> jax.Array:
    """Standard normal survival function 1 - Phi(x), numerically stable tail."""
    return 0.5 * jax.lax.erfc(x / _SQRT2)


def log_Phi(x: jax.Array) -> jax.Array:
    """log CDF, stable for moderately negative x (sufficient for our grids)."""
    return jnp.log(jnp.clip(Phi(x), 1e-300, 1.0))


def scaled_channel_params(w, mu, sigma):
    """Per-channel Normal completion-time params for work fraction ``w``.

    T_i ~ N(w*mu_i, (w*sigma_i)^2)  (the paper's scaling assumption; other
    families go through :func:`family_effective_moments`).
    """
    w = jnp.asarray(w)
    return w * mu, w * sigma


def point_mass_cdf(t, mean):
    """CDF of a point mass at ``mean``: right-continuous, 1 iff ``t >= mean``.

    THE degenerate-channel convention. Every call site (safe_cdf, the
    reference quadratures, both Pallas kernel bodies) uses this expression so
    the strict side (t < mean -> 0) and the non-strict side (t >= mean -> 1)
    can never drift apart between layers.
    """
    t = jnp.asarray(t)
    return (t >= mean).astype(t.dtype if jnp.issubdtype(t.dtype, jnp.floating)
                              else jnp.float32)


def safe_cdf(t, mean, std):
    """CDF of N(mean, std^2) at t, treating std==0 as a point mass at ``mean``.

    For w=0 channels mean is also 0, so the channel contributes CDF 1 for
    t>=0 ("no work -> already finished"). The degenerate branch follows
    :func:`point_mass_cdf` (right-continuous at t == mean).
    """
    std_ok = std > 0.0
    z = (t - mean) / jnp.where(std_ok, std, 1.0)
    return jnp.where(std_ok, Phi(z), point_mass_cdf(t, mean))


# --------------------------------------------------------------------------
# family math, selected by static dist_id
# --------------------------------------------------------------------------

def _check_dist(dist_id: str) -> None:
    if dist_id not in FAMILIES:
        raise ValueError(f"dist_id must be one of {FAMILIES}, got {dist_id!r}")


def extra_rows(dist_id: str) -> int:
    """Rows of the (E, K) ``extra`` parameter array each family carries.

    Families without shape parameters still carry one zero row so the kernel
    launch signature (and its BlockSpec) is uniform across families.
    """
    _check_dist(dist_id)
    if dist_id == "empirical":
        return 3 * EMP_COMPONENTS
    if dist_id == "defective":
        return 2  # row 0: failure prob p (differentiable); row 1: pricing lam
    return 1


def _mixture_stats(extra):
    """(m_mix, s_mix) of the per-unit-rate Gaussian mixture in ``extra``.

    extra rows: [pi_0..pi_{C-1}, m_0..m_{C-1}, s_0..s_{C-1}].
    """
    C = EMP_COMPONENTS
    pis = [extra[c] for c in range(C)]
    ms = [extra[C + c] for c in range(C)]
    ss = [extra[2 * C + c] for c in range(C)]
    m_mix = sum(p * m for p, m in zip(pis, ms))
    e2 = sum(p * (s * s + m * m) for p, m, s in zip(pis, ms, ss))
    s_mix = jnp.sqrt(jnp.maximum(e2 - m_mix * m_mix, 0.0))
    return m_mix, s_mix


def lognormal_shape_np(mu, sigma):
    """Numpy twin of :func:`_lognormal_shape` for host-side samplers.

    Returns ``(s_l, base)`` with ``R ~ LN(base, s_l^2)`` moment-matched to
    ``(mu, sigma)``. The simulator and :func:`family_sample` both draw
    through this, so ground truth and the solver's quadrature can only share
    one definition of the moment matching.
    """
    mu = np.maximum(np.asarray(mu, np.float64), 1e-300)
    s2 = np.log1p((np.asarray(sigma, np.float64) / mu) ** 2)
    return np.sqrt(s2), np.log(mu) - 0.5 * s2


def _lognormal_shape(mu, sigma):
    """(s_l, base) of the moment-matched log-normal per-unit rate.

    R ~ LN(log(mu) - s_l^2/2, s_l^2) has mean mu and std sigma when
    s_l^2 = log(1 + (sigma/mu)^2); the CoV is scale-free, so s_l does not
    depend on the work share w. ``base = log(mu) - s_l^2/2`` (add log(w) for
    the scaled completion time).
    """
    mu_ok = mu > 0.0
    safe_mu = jnp.where(mu_ok, mu, 1.0)
    s2 = jnp.log1p(jnp.square(sigma / safe_mu))
    s_l = jnp.sqrt(s2)
    base = jnp.log(safe_mu) - 0.5 * s2
    return s_l, base


def _drift_mean_scale(w, extra):
    """g(w) = w (1 + rho w / 2): the drift family's mean multiplier."""
    rho = extra[0]
    return w * (1.0 + 0.5 * rho * w)


def defective_moments_np(mu, sigma, p, lam):
    """Numpy twin of :func:`_defective_ab` for host-side samplers.

    Returns the retry-inflated per-unit moments ``(a, b)`` of the defective
    family: with ``q = 1 - p`` (floored at ``1e-6``) and failed attempts
    costing ``lam`` of an attempt,

        a   = mu * (1 + lam p/q)
        b^2 = sigma^2 (1 + lam^2 p/q) + lam^2 mu^2 p/q^2

    exactly the mean/variance of ``T = A_0 + lam sum_{i<=N} A_i`` with
    ``A_i ~ N(mu, sigma^2)`` iid and ``N ~ Geom(q)`` failures-before-success
    (``E N = p/q``, ``Var N = p/q^2``). The simulator's retry injection and
    :func:`family_sample` draw that physical process, so the law and its
    ground truth share this one derivation.
    """
    mu = np.asarray(mu, np.float64)
    sigma = np.asarray(sigma, np.float64)
    p = np.clip(np.asarray(p, np.float64), 0.0, 1.0 - _Q_FLOOR)
    lam = np.asarray(lam, np.float64)
    q = 1.0 - p
    ratio = p / q
    a = mu * (1.0 + lam * ratio)
    b2 = sigma * sigma * (1.0 + lam * lam * ratio) \
        + (lam * mu) ** 2 * ratio / q
    return a, np.sqrt(np.maximum(b2, 0.0))


def _defective_ab(mu, sigma, extra):
    """Retry-inflated per-unit moments (a, b) of the defective family.

    ``extra[0] = p`` (per-attempt failure probability, clamped to
    ``[0, 1 - _Q_FLOOR]``), ``extra[1] = lam`` (pricing: fraction of an
    attempt a failure costs). See :func:`defective_moments_np` for the
    derivation; ``T(w) ~ N(w a, (w b)^2)`` — a pure scale family, so every
    kernel treats it exactly like ``normal`` with ``(a, b)`` substituted.
    ``p = 0`` gives ``(a, b) = (mu, sigma)`` identically.

    Only the UPPER side is clamped: clamping at 0 would put the valid
    boundary value ``p = 0`` on a max-tie, where autodiff splits the
    cotangent 0.5/0.5 and the analytic adjoint would disagree with it by
    exactly 2x. Negative ``p`` is rejected at the API boundary
    (:class:`Defective`) and by the sanitizer instead.
    """
    p = jnp.minimum(extra[0], 1.0 - _Q_FLOOR)
    lam = extra[1]
    q = 1.0 - p
    ratio = p / q
    a = mu * (1.0 + lam * ratio)
    b2 = sigma * sigma * (1.0 + lam * lam * ratio) \
        + jnp.square(lam * mu) * ratio / q
    return a, jnp.sqrt(jnp.maximum(b2, 0.0))


def family_effective_moments(dist_id: str, w, mu, sigma, extra):
    """(mean, std) of the completion time T(w) under the family.

    This is what the integration reach ``tmax = max_k(mean_k + z std_k)``
    and the scheduler's moment predictions consume. Lognormal is
    moment-matched by construction, so its effective moments equal the
    normal family's.
    """
    _check_dist(dist_id)
    if dist_id in ("normal", "lognormal"):
        return w * mu, w * sigma
    if dist_id == "drift":
        return mu * _drift_mean_scale(w, extra), w * sigma
    if dist_id == "defective":
        a, b = _defective_ab(mu, sigma, extra)
        return w * a, w * b
    m_mix, s_mix = _mixture_stats(extra)
    return w * m_mix, w * s_mix


def _raw_cdf(dist_id: str, t, w, mu, sigma, extra, ok, safe_w):
    """Family CDF with degenerate denominators substituted (gate with ``ok``)."""
    if dist_id == "normal":
        std = w * sigma
        z = (t - w * mu) / jnp.where(ok, std, 1.0)
        return Phi(z)
    if dist_id == "lognormal":
        s_l, base = _lognormal_shape(mu, sigma)
        s_safe = jnp.where(ok, s_l, 1.0)
        z = (jnp.log(jnp.maximum(t, _TINY)) - jnp.log(safe_w) - base) / s_safe
        return Phi(z)
    if dist_id == "drift":
        m_d = mu * _drift_mean_scale(w, extra)
        std = w * sigma
        z = (t - m_d) / jnp.where(ok, std, 1.0)
        return Phi(z)
    if dist_id == "defective":
        a, b = _defective_ab(mu, sigma, extra)
        z = (t - w * a) / jnp.where(ok, w * b, 1.0)
        return Phi(z)
    # empirical mixture: sum_c pi_c Phi((t - w m_c)/(w s_c)); a zero-spread
    # component degenerates to its own (right-continuous) point mass
    C = EMP_COMPONENTS
    acc = 0.0
    for c in range(C):
        pi_c, m_c, s_c = extra[c], extra[C + c], extra[2 * C + c]
        c_ok = ok & (s_c > 0.0)
        z_c = (t - w * m_c) / jnp.where(c_ok, w * s_c, 1.0)
        cdf_c = jnp.where(c_ok, Phi(z_c), point_mass_cdf(t, w * m_c))
        acc = acc + pi_c * cdf_c
    return acc


def _family_ok(dist_id: str, w, mu, sigma, extra):
    """Non-degenerate mask: channels with an absolutely continuous T(w)."""
    if dist_id == "lognormal":
        return (w > 0.0) & (sigma > 0.0) & (mu > 0.0)
    if dist_id == "empirical":
        _, s_mix = _mixture_stats(extra)
        return (w > 0.0) & (s_mix > 0.0)
    if dist_id == "defective":
        # b can be positive even when sigma == 0 (retry variance from mu)
        _, b = _defective_ab(mu, sigma, extra)
        return (w * b) > 0.0
    return (w * sigma) > 0.0


def family_cdf(dist_id: str, t, w, mu, sigma, extra):
    """P(T(w) <= t) for one channel (broadcasting over any leading shape).

    Degenerate channels (w=0, sigma=0, or a spread-free mixture) are a point
    mass at the family's effective mean, right-continuous per
    :func:`point_mass_cdf`.
    """
    _check_dist(dist_id)
    ok = _family_ok(dist_id, w, mu, sigma, extra)
    safe_w = jnp.where(w > 0.0, w, 1.0)
    raw = _raw_cdf(dist_id, t, w, mu, sigma, extra, ok, safe_w)
    m_eff, _ = family_effective_moments(dist_id, w, mu, sigma, extra)
    return jnp.where(ok, raw, point_mass_cdf(t, m_eff))


def family_adjoint_parts(dist_id: str, t, w, mu, sigma, extra):
    """Per-grid-point adjoint pieces: ``(cdf_raw, D, ok, z)``.

    ``cdf_raw`` is the un-substituted CDF (drives the clip/saturation gates),
    ``D`` the pdf-like numerator with ``dC/dw = D * (alpha + beta t)`` and
    ``dC/dt = D * (gamma0 + gamma1 t) / t`` for the per-channel constants
    from :func:`family_coeffs`, and ``ok`` the non-degenerate mask (False
    rows contribute no direct gradient — a point mass is flat a.e.).
    ``z`` is the family's standardized score at each grid point — the third
    basis feature the *parameter* adjoints of the lognormal family contract
    against (``dz/dmu`` and ``dz/dsigma`` are affine in z, not in t, because
    the shape parameter ``s_l`` itself moves with (mu, sigma)); families that
    never use the z feature return zeros (empirical has no single z).
    """
    _check_dist(dist_id)
    ok = _family_ok(dist_id, w, mu, sigma, extra)
    safe_w = jnp.where(w > 0.0, w, 1.0)
    cdf_raw = _raw_cdf(dist_id, t, w, mu, sigma, extra, ok, safe_w)
    if dist_id == "normal":
        z = (t - w * mu) / jnp.where(ok, w * sigma, 1.0)
        D = phi(z)
    elif dist_id == "lognormal":
        s_l, base = _lognormal_shape(mu, sigma)
        z = (jnp.log(jnp.maximum(t, _TINY)) - jnp.log(safe_w)
             - base) / jnp.where(ok, s_l, 1.0)
        D = phi(z)
    elif dist_id == "drift":
        m_d = mu * _drift_mean_scale(w, extra)
        z = (t - m_d) / jnp.where(ok, w * sigma, 1.0)
        D = phi(z)
    elif dist_id == "defective":
        a, b = _defective_ab(mu, sigma, extra)
        z = (t - w * a) / jnp.where(ok, w * b, 1.0)
        D = phi(z)
    else:  # empirical: D = sum_c pi_c phi(z_c) / s_c; no single z score
        C = EMP_COMPONENTS
        D = 0.0
        for c in range(C):
            pi_c, m_c, s_c = extra[c], extra[C + c], extra[2 * C + c]
            c_ok = ok & (s_c > 0.0)
            z_c = (t - w * m_c) / jnp.where(c_ok, w * s_c, 1.0)
            D = D + jnp.where(c_ok, pi_c / jnp.where(c_ok, s_c, 1.0), 0.0) \
                * phi(z_c)
        z = jnp.zeros_like(D)
    return cdf_raw, D, ok, z


def family_pdf_parts(dist_id: str, t, w, mu, sigma, extra):
    """Back-compat wrapper over :func:`family_adjoint_parts` without ``z``."""
    cdf_raw, D, ok, _ = family_adjoint_parts(dist_id, t, w, mu, sigma, extra)
    return cdf_raw, D, ok


def family_coeffs(dist_id: str, w, mu, sigma, extra):
    """Per-channel adjoint constants ``(alpha, beta, gamma0, gamma1)``.

    With ``D`` from :func:`family_pdf_parts`:

        dC/dw |_t  = D(t) * (alpha + beta * t)          (fixed-grid term)
        dC/dt |_t  = D(t) * (gamma0 + gamma1 * t) / t   (moving-grid term)

    The companion :func:`family_dreach` supplies ``d(mean + z*std)/dw`` for
    the tmax cotangent on the argmax channel. Degenerate channels get
    all-zero constants (their
    point-mass CDF is flat a.e.; they still receive the grid-path gradient
    through ``dreach`` when they set the integration end). Note gamma* are
    defined so the kernels' accumulators contract them exactly:
    ``sum_j a_jk t_j * (dC/dt)/D = gamma0 * P0 + gamma1 * P1``.
    """
    _check_dist(dist_id)
    ok = _family_ok(dist_id, w, mu, sigma, extra)
    zero = jnp.zeros_like(w * mu)

    def guard(x):
        return jnp.where(ok, x, 0.0)

    if dist_id == "normal":
        inv_w2s = 1.0 / jnp.where(ok, w * w * sigma, 1.0)
        inv_s = 1.0 / jnp.where(ok, w * sigma, 1.0)
        return zero, guard(-inv_w2s), zero, guard(inv_s)
    if dist_id == "lognormal":
        s_l, _ = _lognormal_shape(mu, sigma)
        inv_ws = 1.0 / jnp.where(ok, w * s_l, 1.0)
        # dz/dw = -1/(w s_l) (t-free); dz/dt = 1/(t s_l): gamma0 contracts P0
        inv_sl = 1.0 / jnp.where(ok, s_l, 1.0)
        return guard(-inv_ws), zero, guard(inv_sl), zero
    if dist_id == "drift":
        rho = extra[0]
        inv_w2s = 1.0 / jnp.where(ok, w * w * sigma, 1.0)
        inv_s = 1.0 / jnp.where(ok, w * sigma, 1.0)
        # z = (t - mu g(w)) / (w sigma), g = w(1 + rho w/2):
        # dz/dw = -mu g'/(w s) - z/w collapses to -(rho mu)/(2 sigma) - t/(w^2 s)
        alpha = guard(-0.5 * rho * mu / jnp.where(ok, sigma, 1.0))
        return alpha, guard(-inv_w2s), zero, guard(inv_s)
    if dist_id == "defective":
        # pure scale family: identical to normal with (a, b) substituted
        _, b = _defective_ab(mu, sigma, extra)
        inv_w2b = 1.0 / jnp.where(ok, w * w * b, 1.0)
        inv_b = 1.0 / jnp.where(ok, w * b, 1.0)
        return zero, guard(-inv_w2b), zero, guard(inv_b)
    # empirical: scale family in w -> dC/dw = -(t/w) pdf, dC/dt = pdf = D/w
    inv_w2 = 1.0 / jnp.where(ok, w * w, 1.0)
    inv_w = 1.0 / jnp.where(ok, w, 1.0)
    return zero, guard(-inv_w2), zero, guard(inv_w)


def family_accumulators(dist_id: str) -> Tuple[bool, bool]:
    """Which per-channel accumulator pairs the W-only fused adjoint needs.

    Returns ``(use_p0, use_p1)``: P0/Pv0 contract the t-free (alpha, gamma0)
    coefficients, P1/Pv1 the t-weighted (beta, gamma1) ones. Pure scale
    families (normal, empirical) and drift keep P1; lognormal's log-space
    z-score is t-free in dw and needs P0 instead; drift's affine dz/dw needs
    both — 4 live (block_f, K) accumulators instead of 2, which is why the
    family is part of the autotune working-set model and cache key. The
    full-parameter adjoint needs the wider :func:`family_features` basis.
    """
    use_1, use_t, _ = family_features(dist_id, params=False)
    return use_1, use_t


def family_features(dist_id: str, params: bool = False
                    ) -> Tuple[bool, bool, bool]:
    """Accumulator basis the fused adjoint contracts against.

    Returns ``(use_1, use_t, use_z)``: every live feature f costs a
    ``(block_f, K)`` accumulator pair (``Pf`` for the mu cotangent, ``Pvf``
    for the fused var cotangent). With ``params=False`` (W-gradients only —
    the PGD path) this is the legacy :func:`family_accumulators` set; with
    ``params=True`` the mus/sigmas/extra adjoints widen the basis:

    * ``normal``/``drift``: dz/dmu is t-free and dz/dsigma = -z/sigma expands
      to an affine-in-t form, so the {1, t} basis covers every parameter.
    * ``lognormal``: the moment-matched shape ``s_l(mu, sigma)`` makes
      dz/dmu and dz/dsigma affine in **z** itself (not t) — the z feature
      joins the basis, and that family alone contracts Pz/Pvz.
    * ``empirical``: the channel's (mu, sigma) never enter the mixture CDF —
      no parameter adjoints, the {t} basis stays.
    * ``defective``: the W-adjoint is the normal family's with (a, b)
      substituted ({t} basis); the parameter adjoints move the composite
      spread ``b(mu, sigma, p)``, so dz/dmu and dz/dp pick up -z (db/d.)/b
      terms — the z feature joins and all three features go live, the
      widest working set of any family (part of the autotune model).
    """
    _check_dist(dist_id)
    if not params:
        return {
            "normal": (False, True, False),
            "lognormal": (True, False, False),
            "drift": (True, True, False),
            "empirical": (False, True, False),
            "defective": (False, True, False),
        }[dist_id]
    return {
        "normal": (True, True, False),
        "lognormal": (True, False, True),
        "drift": (True, True, False),
        "empirical": (False, True, False),
        "defective": (True, True, True),
    }[dist_id]


def family_has_extra_grads(dist_id: str) -> bool:
    """Whether the family's ``extra`` row 0 carries a differentiable shape
    parameter (drift's per-channel ``rho``, defective's failure probability
    ``p``). The empirical mixture's fitted parameters are solve constants by
    contract (re-fit, not descended), and the defective family's pricing
    constant ``lam`` (extra row 1) is a mode switch, not a statistic — its
    cotangent is documented-zero."""
    _check_dist(dist_id)
    return dist_id in ("drift", "defective")


def family_param_coeffs(dist_id: str, w, mu, sigma, extra):
    """Per-channel adjoint constants for the *channel-statistic* parameters.

    Returns ``(c_mu, c_sigma, c_rho)``, each a triple ``(a, b, c)`` of
    per-channel coefficient arrays against the (1, t, z) feature basis of
    :func:`family_features`:

        d log C_k / d theta_k |_t = g_jk * (a_k + b_k t + c_k z_jk)

    with ``g_jk`` the same gated inverse-Mills ratio the W-adjoint uses, and
    ``z_jk`` the standardized score from :func:`family_adjoint_parts`.
    ``c_rho`` is the coefficient triple for ``extra`` row 0 and is all-zero
    unless :func:`family_has_extra_grads` (drift). Degenerate (point-mass)
    channels get all-zero constants, exactly like :func:`family_coeffs` —
    they still receive the moving-grid term through
    :func:`family_dreach_params` when they set the integration end.

    Derivations (z-scores as in :func:`family_adjoint_parts`):

    * normal, z = (t - w mu)/(w sigma):
        dz/dmu    = -1/sigma                              -> (a, 0, 0)
        dz/dsigma = -z/sigma = mu/sigma^2 - t/(w sigma^2) -> (a, b, 0)
    * lognormal, z = (log t - log w - base)/s_l with v = (sigma/mu)^2,
      s_l^2 = log(1+v), base = log mu - s_l^2/2:
        ds_l/dmu    = -v/(mu (1+v) s_l),  dbase/dmu    = 1/mu + v/(mu (1+v))
        ds_l/dsigma =  v/(sigma (1+v) s_l), dbase/dsigma = -v/(sigma (1+v))
        dz/dtheta = -(dbase/dtheta)/s_l - z (ds_l/dtheta)/s_l -> (a, 0, c)
    * drift, z = (t - mu g(w))/(w sigma), g = w(1 + rho w/2):
        dz/dmu    = -g/(w sigma)                          -> (a, 0, 0)
        dz/dsigma = -z/sigma = mu g/(w sigma^2) - t/(w sigma^2) -> (a, b, 0)
        dz/drho   = -mu w/(2 sigma)                       -> (a, 0, 0)
    * defective, z = (t - w a)/(w b) with q = 1-p, r = p/q,
      a = mu (1 + lam r), b^2 = sigma^2 (1 + lam^2 r) + lam^2 mu^2 r/q:
      every parameter theta gives dz/dtheta = -(da/dtheta)/b
      - z (db/dtheta)/b, so each is an (a, 0, c) pair against {1, z}:
        da/dmu = 1 + lam r,   db/dmu    = lam^2 mu (r/q) / b
        da/dsigma = 0,        db/dsigma = sigma (1 + lam^2 r) / b
        da/dp = mu lam / q^2,
        d(b^2)/dp = lam^2 (sigma^2/q^2 + mu^2 (1+p)/q^3),
        db/dp = d(b^2)/dp / (2 b)
      ``c_rho`` is the coefficient for p (extra row 0); lam (row 1) is a
      pricing constant with documented-zero cotangent.
    * empirical: all zero (mus/sigmas unused; mixture params are constants).
    """
    _check_dist(dist_id)
    ok = _family_ok(dist_id, w, mu, sigma, extra)
    zero = jnp.zeros_like(w * mu)

    def guard(x):
        return jnp.where(ok, x, 0.0)

    z3 = (zero, zero, zero)
    if dist_id == "normal":
        inv_s = 1.0 / jnp.where(ok, sigma, 1.0)
        inv_ws2 = 1.0 / jnp.where(ok, w * sigma * sigma, 1.0)
        c_mu = (guard(-inv_s), zero, zero)
        c_sigma = (guard(mu * inv_s * inv_s), guard(-inv_ws2), zero)
        return c_mu, c_sigma, z3
    if dist_id == "lognormal":
        mu_ok = mu > 0.0
        safe_mu = jnp.where(mu_ok, mu, 1.0)
        safe_sg = jnp.where(sigma > 0.0, sigma, 1.0)
        v = jnp.square(sigma / safe_mu)
        s_l, _ = _lognormal_shape(mu, sigma)
        s_safe = jnp.where(ok, s_l, 1.0)
        r = v / (1.0 + v)                      # = d s_l^2 scale factor
        dbase_dmu = (1.0 + r) / safe_mu
        dsl_dmu = -r / (safe_mu * s_safe)
        dbase_dsg = -r / safe_sg
        dsl_dsg = r / (safe_sg * s_safe)
        c_mu = (guard(-dbase_dmu / s_safe), zero,
                guard(-dsl_dmu / s_safe))
        c_sigma = (guard(-dbase_dsg / s_safe), zero,
                   guard(-dsl_dsg / s_safe))
        return c_mu, c_sigma, z3
    if dist_id == "drift":
        g = _drift_mean_scale(w, extra)
        inv_ws = 1.0 / jnp.where(ok, w * sigma, 1.0)
        inv_ws2 = 1.0 / jnp.where(ok, w * sigma * sigma, 1.0)
        c_mu = (guard(-g * inv_ws), zero, zero)
        c_sigma = (guard(mu * g * inv_ws2), guard(-inv_ws2), zero)
        c_rho = (guard(-0.5 * mu * w / jnp.where(ok, sigma, 1.0)), zero, zero)
        return c_mu, c_sigma, c_rho
    if dist_id == "defective":
        p = jnp.minimum(extra[0], 1.0 - _Q_FLOOR)
        lam = extra[1]
        q = 1.0 - p
        ratio = p / q
        _, b = _defective_ab(mu, sigma, extra)
        inv_b = 1.0 / jnp.where(ok, b, 1.0)
        inv_b2 = inv_b * inv_b
        da_dmu = 1.0 + lam * ratio
        db_dmu_b = lam * lam * mu * (ratio / q) * inv_b2   # (db/dmu)/b
        db_dsg_b = sigma * (1.0 + lam * lam * ratio) * inv_b2
        da_dp = mu * lam / (q * q)
        db2_dp = lam * lam * (sigma * sigma / (q * q)
                              + mu * mu * (1.0 + p) / (q * q * q))
        db_dp_b = 0.5 * db2_dp * inv_b2                    # (db/dp)/b
        c_mu = (guard(-da_dmu * inv_b), zero, guard(-db_dmu_b))
        c_sigma = (zero, zero, guard(-db_dsg_b))
        c_p = (guard(-da_dp * inv_b), zero, guard(-db_dp_b))
        return c_mu, c_sigma, c_p
    # empirical: the mixture CDF never reads (mu, sigma); extra is a constant
    return z3, z3, z3


def family_dreach(dist_id: str, w, mu, sigma, extra, z: float):
    """d(reach)/dw per channel, reach = effective mean + z * effective std."""
    _check_dist(dist_id)
    if dist_id in ("normal", "lognormal"):
        return mu + z * sigma
    if dist_id == "drift":
        rho = extra[0]
        return mu * (1.0 + rho * w) + z * sigma
    if dist_id == "defective":
        a, b = _defective_ab(mu, sigma, extra)
        return a + z * b
    m_mix, s_mix = _mixture_stats(extra)
    return (m_mix + z * s_mix) * jnp.ones_like(w)


def family_dreach_params(dist_id: str, w, mu, sigma, extra, z: float):
    """``(d reach/dmu, d reach/dsigma, d reach/drho)`` per channel.

    The parameter twin of :func:`family_dreach`: when a channel's statistic
    moves, the integration end ``tmax = max_k reach_k`` moves with it on the
    argmax channel, so every parameter adjoint carries the same moving-grid
    term the W-adjoint does. ``reach = mean_eff + z * std_eff``:

    * normal / lognormal: mean = w mu, std = w sigma -> (w, z w, 0)
    * drift: mean = mu g(w) with g = w(1 + rho w/2), std = w sigma
      -> (g(w), z w, mu w^2/2)
    * defective: mean = w a, std = w b -> w (da/d. + z db/d.) with the
      chain-rule pieces from :func:`family_param_coeffs`; db-terms are
      gated on b > 0 (a spread-free channel's reach moves only through a).
    * empirical: the mixture stats ignore (mu, sigma) -> all zero.
    """
    _check_dist(dist_id)
    ones = jnp.ones_like(w * mu)
    zero = jnp.zeros_like(ones)
    if dist_id in ("normal", "lognormal"):
        return w * ones, z * w * ones, zero
    if dist_id == "drift":
        g = _drift_mean_scale(w, extra)
        return g * ones, z * w * ones, 0.5 * mu * w * w * ones
    if dist_id == "defective":
        p = jnp.minimum(extra[0], 1.0 - _Q_FLOOR)
        lam = extra[1]
        q = 1.0 - p
        ratio = p / q
        _, b = _defective_ab(mu, sigma, extra)
        b_ok = b > 0.0
        inv_b = 1.0 / jnp.where(b_ok, b, 1.0)
        db_dmu = jnp.where(b_ok, lam * lam * mu * (ratio / q) * inv_b, 0.0)
        db_dsg = jnp.where(b_ok, sigma * (1.0 + lam * lam * ratio) * inv_b,
                           0.0)
        db2_dp = lam * lam * (sigma * sigma / (q * q)
                              + mu * mu * (1.0 + p) / (q * q * q))
        db_dp = jnp.where(b_ok, 0.5 * db2_dp * inv_b, 0.0)
        d_mu = w * ((1.0 + lam * ratio) + z * db_dmu)
        d_sg = w * z * db_dsg
        d_p = w * (mu * lam / (q * q) + z * db_dp)
        return d_mu * ones, d_sg * ones, d_p * ones
    return zero, zero, zero


def family_sample(dist_id: str, rng: np.random.Generator, w, mu, sigma, extra,
                  size: int) -> np.ndarray:
    """Draw ``size`` completion-time samples T(w) per channel (numpy, host).

    Shapes: w/mu/sigma (K,), extra (E, K) -> (size, K). The Monte-Carlo
    ground truth for the family: the oracle tests sample through this, and
    ``sim.ClusterSim`` mirrors the same formulas (via
    :func:`lognormal_shape_np` and the drift mean term) with stream-shaped
    per-fleet draws.
    """
    _check_dist(dist_id)
    w = np.asarray(w, np.float64)
    mu = np.asarray(mu, np.float64)
    sigma = np.asarray(sigma, np.float64)
    extra = np.asarray(extra, np.float64)
    if dist_id == "normal":
        return w * rng.normal(mu, sigma, size=(size, w.shape[0]))
    if dist_id == "lognormal":
        s_l, base = lognormal_shape_np(mu, sigma)
        r = rng.lognormal(base, s_l, size=(size, w.shape[0]))
        return w * r
    if dist_id == "drift":
        rho = extra[0]
        base = w * rng.normal(mu, sigma, size=(size, w.shape[0]))
        return base + 0.5 * rho * mu * w * w  # deterministic mean inflation
    if dist_id == "defective":
        # the PHYSICAL retry process, failures actually injected:
        # T = w (A_0 + lam sum_{i<=N} A_i), A_i ~ N(mu, sigma^2) iid,
        # N ~ Geom failures-before-success. Per-channel moments match the
        # family's (a, b) exactly; the JOIN inherits the Gaussian shape
        # approximation (the model law is the moment-matched normal).
        p = np.clip(extra[0], 0.0, 1.0 - _Q_FLOOR)
        lam = extra[1]
        K = w.shape[0]
        succ = rng.normal(mu, sigma, size=(size, K))
        nfail = rng.geometric(1.0 - p, size=(size, K)) - 1
        # sum of N iid normals drawn exactly: N(N mu, N sigma^2)
        lost = nfail * mu + np.sqrt(nfail.astype(np.float64)) * sigma \
            * rng.standard_normal((size, K))
        return w * (succ + lam * lost)
    C = EMP_COMPONENTS
    pis = extra[:C].T                       # (K, C)
    ms, ss = extra[C:2 * C].T, extra[2 * C:3 * C].T
    K = w.shape[0]
    out = np.empty((size, K))
    for k in range(K):
        comp = rng.choice(C, size=size, p=pis[k] / pis[k].sum())
        out[:, k] = w[k] * rng.normal(ms[k][comp], ss[k][comp])
    return out


# --------------------------------------------------------------------------
# the ChannelFamily objects (host-side API surface)
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class ChannelFamily:
    """A completion-time distribution family: static ``dist_id`` + params.

    Instances are what the user-facing layers accept (``family=`` on
    ``frontier_moments``, ``frontier_kch``, ``optimize_weights``,
    ``UncertaintyAwareBalancer``, ``PartitionedBatcher``); plain family-name
    strings are accepted everywhere too and resolved via :func:`get_family`.
    :func:`resolve_family` lowers either form to the kernel-facing
    ``(dist_id, extra)`` pair.
    """

    dist_id: str = "normal"

    def extra(self, k: int) -> np.ndarray:
        """(E, K) float32 per-channel shape parameters for the kernels."""
        return np.zeros((extra_rows(self.dist_id), k), np.float32)

    def state_dict(self) -> dict:
        return {"dist_id": self.dist_id}


class Normal(ChannelFamily):
    def __init__(self):
        super().__init__(dist_id="normal")


class LogNormal(ChannelFamily):
    def __init__(self):
        super().__init__(dist_id="lognormal")


@dataclass(frozen=True)
class Drift(ChannelFamily):
    """Straggler family: per-channel drift rate ``rho`` (scalar broadcasts).

    ``rho[k] = 0`` reduces channel k to the normal family exactly, so one
    Drift family covers a mixed fleet — which is how the straggler policy
    prices detected stragglers instead of dropping them.
    """

    rho: object = 0.0

    def __init__(self, rho=0.0):
        super().__init__(dist_id="drift")
        object.__setattr__(self, "rho", np.asarray(rho, np.float32))

    def extra(self, k: int) -> np.ndarray:
        rho = np.broadcast_to(np.asarray(self.rho, np.float32), (k,))
        return rho[None, :].copy()

    def state_dict(self) -> dict:
        return {"dist_id": "drift", "rho": np.asarray(self.rho).tolist()}


# Failure pricing modes: the fraction of an attempt a failed attempt costs.
# "retry" re-runs from scratch (all sunk work lost); "resume" assumes
# continuous mid-attempt checkpointing, losing half an attempt in expectation
# (failure point uniform over the attempt).
DEFECTIVE_PRICING = {"retry": 1.0, "resume": 0.5}


@dataclass(frozen=True)
class Defective(ChannelFamily):
    """Failure-aware family: per-channel attempt-failure probability ``p``.

    Each attempt on channel k fails independently with probability ``p[k]``
    and is re-run; the pricing mode fixes how much of an attempt a failure
    costs (``"retry"``: 1.0, ``"resume"``: 0.5, or any float in [0, 1]).
    ``p`` may be a scalar (broadcast) or per-channel. ``p = 0`` reduces the
    channel to the normal family exactly, so one Defective family covers a
    fleet where only some channels are flaky — and the solver prices both
    the mean inflation and the retry variance instead of discovering the
    failures as realized stragglers.
    """

    p: object = 0.0
    lam: object = 1.0

    def __init__(self, p=0.0, pricing="retry"):
        super().__init__(dist_id="defective")
        if isinstance(pricing, str):
            if pricing not in DEFECTIVE_PRICING:
                raise ValueError(f"pricing must be one of "
                                 f"{sorted(DEFECTIVE_PRICING)} or a float in "
                                 f"[0, 1], got {pricing!r}")
            lam = DEFECTIVE_PRICING[pricing]
        else:
            lam = float(pricing)
            if not 0.0 <= lam <= 1.0:
                raise ValueError(f"pricing fraction must lie in [0, 1], "
                                 f"got {lam}")
        p_arr = np.asarray(p, np.float32)
        if p_arr.size and (float(p_arr.min()) < 0.0
                           or float(p_arr.max()) > 1.0):
            raise ValueError("failure probabilities must lie in [0, 1], got "
                             f"range [{float(p_arr.min())}, "
                             f"{float(p_arr.max())}]")
        object.__setattr__(self, "p", p_arr)
        object.__setattr__(self, "lam", np.float32(lam))

    def extra(self, k: int) -> np.ndarray:
        p = np.broadcast_to(np.asarray(self.p, np.float32), (k,))
        lam = np.full((k,), self.lam, np.float32)
        return np.stack([p, lam])

    def state_dict(self) -> dict:
        return {"dist_id": "defective", "p": np.asarray(self.p).tolist(),
                "lam": float(self.lam)}


@dataclass(frozen=True)
class Empirical(ChannelFamily):
    """Gaussian-mixture fit of observed per-unit rates (C components/channel).

    ``weights/means/stds`` are (C, K). Build from raw observations with
    :meth:`from_samples` (deterministic quantile-initialized EM, variance
    floored so the kernels never see a spread-free component unless the data
    is literally constant).
    """

    weights: np.ndarray = None
    means: np.ndarray = None
    stds: np.ndarray = None

    def __init__(self, weights, means, stds):
        super().__init__(dist_id="empirical")
        w = np.asarray(weights, np.float32)
        if w.ndim == 1:
            w, means, stds = (np.asarray(a, np.float32)[:, None]
                              for a in (weights, means, stds))
        else:
            means = np.asarray(means, np.float32)
            stds = np.asarray(stds, np.float32)
        if w.shape[0] != EMP_COMPONENTS:
            raise ValueError(f"expected {EMP_COMPONENTS} mixture components, "
                             f"got {w.shape[0]}")
        w = w / np.maximum(w.sum(axis=0, keepdims=True), 1e-12)
        object.__setattr__(self, "weights", w)
        object.__setattr__(self, "means", means)
        object.__setattr__(self, "stds", np.asarray(stds, np.float32))

    @classmethod
    def from_samples(cls, samples, iters: int = 40,
                     var_floor_frac: float = 1e-3) -> "Empirical":
        """Fit per-channel mixtures from observed rates.

        ``samples``: (N, K) array or length-K sequence of 1-D arrays of
        per-unit-work durations. Deterministic: quantile init, fixed EM
        iteration count, no RNG.
        """
        if isinstance(samples, np.ndarray) and samples.ndim == 2:
            cols = [samples[:, k] for k in range(samples.shape[1])]
        else:
            cols = [np.asarray(s, np.float64).ravel() for s in samples]
        C = EMP_COMPONENTS
        W = np.empty((C, len(cols)))
        M = np.empty((C, len(cols)))
        S = np.empty((C, len(cols)))
        for k, x in enumerate(cols):
            W[:, k], M[:, k], S[:, k] = _em_1d(np.asarray(x, np.float64),
                                               C, iters, var_floor_frac)
        return cls(W, M, S)

    def extra(self, k: int) -> np.ndarray:
        if self.weights.shape[1] == 1 and k > 1:
            tile = lambda a: np.broadcast_to(a, (EMP_COMPONENTS, k))
            return np.concatenate([tile(self.weights), tile(self.means),
                                   tile(self.stds)], axis=0).astype(np.float32)
        if self.weights.shape[1] != k:
            raise ValueError(f"family fitted for K={self.weights.shape[1]} "
                             f"channels, asked for K={k}")
        return np.concatenate([self.weights, self.means, self.stds],
                              axis=0).astype(np.float32)

    def state_dict(self) -> dict:
        return {"dist_id": "empirical", "weights": self.weights.tolist(),
                "means": self.means.tolist(), "stds": self.stds.tolist()}


def _em_1d(x: np.ndarray, C: int, iters: int, var_floor_frac: float):
    """Deterministic 1-D Gaussian-mixture EM (quantile init, floored vars)."""
    n = x.shape[0]
    if n == 0:
        raise ValueError("cannot fit an empirical family from zero samples")
    spread = max(float(x.std()), abs(float(x.mean())) * 1e-6, 1e-12)
    floor = (var_floor_frac * spread) ** 2
    mus = np.quantile(x, (np.arange(C) + 0.5) / C)
    vars_ = np.full(C, max(spread ** 2 / C, floor))
    pis = np.full(C, 1.0 / C)
    for _ in range(iters):
        # E-step in log space for stability
        logp = (-0.5 * ((x[None, :] - mus[:, None]) ** 2) / vars_[:, None]
                - 0.5 * np.log(2 * np.pi * vars_[:, None])
                + np.log(np.maximum(pis[:, None], 1e-300)))
        logp -= logp.max(axis=0, keepdims=True)
        r = np.exp(logp)
        r /= np.maximum(r.sum(axis=0, keepdims=True), 1e-300)
        nk = np.maximum(r.sum(axis=1), 1e-12)
        mus = (r @ x) / nk
        vars_ = np.maximum((r @ (x ** 2)) / nk - mus ** 2, floor)
        pis = nk / n
    order = np.argsort(mus)
    return pis[order], mus[order], np.sqrt(vars_[order])


_SINGLETONS = {"normal": Normal(), "lognormal": LogNormal(),
               "drift": Drift(0.0)}


def get_family(family) -> ChannelFamily:
    """Accept a family name or a ChannelFamily instance; return the instance."""
    if isinstance(family, ChannelFamily):
        return family
    if family is None:
        return _SINGLETONS["normal"]
    if isinstance(family, str):
        if family == "empirical":
            raise ValueError("the empirical family carries fitted parameters; "
                             "build it with Empirical.from_samples(...) "
                             "instead of the bare name")
        if family == "defective":
            raise ValueError("the defective family carries failure "
                             "probabilities; build it with Defective(p, "
                             "pricing=...) instead of the bare name")
        if family in _SINGLETONS:
            return _SINGLETONS[family]
        raise ValueError(f"unknown family {family!r}; expected one of "
                         f"{FAMILIES} or a ChannelFamily instance")
    if isinstance(family, dict):  # state_dict round-trip
        d = dict(family)
        dist = d.pop("dist_id")
        if dist == "drift":
            return Drift(np.asarray(d["rho"], np.float32))
        if dist == "empirical":
            return Empirical(np.asarray(d["weights"]), np.asarray(d["means"]),
                             np.asarray(d["stds"]))
        if dist == "defective":
            return Defective(np.asarray(d["p"], np.float32),
                             pricing=float(d.get("lam", 1.0)))
        return _SINGLETONS[dist]
    raise TypeError(f"cannot interpret {type(family).__name__} as a family")


def resolve_family(family, k: int) -> Tuple[str, np.ndarray]:
    """Lower a family spec to the kernel-facing ``(dist_id, extra (E,K))``.

    Accepts a family name, a ChannelFamily instance, a state_dict, or an
    already-lowered ``(dist_id, extra)`` pair — the latter passes traced
    ``extra`` arrays straight through, which is what jitted solvers use to
    avoid retracing when only the family parameters move. A pre-lowered
    ``extra`` may also be the per-row (E, F, K) stack (each candidate row
    its own fleet — the workflow solver's stage axis).
    """
    if isinstance(family, tuple) and len(family) == 2:
        dist_id, extra = family
        _check_dist(dist_id)
        shape = tuple(extra.shape)
        ok2 = shape == (extra_rows(dist_id), k)
        ok3 = (len(shape) == 3 and shape[0] == extra_rows(dist_id)
               and shape[2] == k)
        if not (ok2 or ok3):
            raise ValueError(f"extra for {dist_id!r} must be "
                             f"({extra_rows(dist_id)}, {k}) or "
                             f"({extra_rows(dist_id)}, F, {k}), got {shape}")
        return dist_id, extra
    fam = get_family(family)
    return fam.dist_id, fam.extra(k)


def family_from_extra(dist_id: str, extra) -> ChannelFamily:
    """Raise a lowered ``(dist_id, extra (E, K))`` pair back to a
    ChannelFamily instance — the inverse of :func:`resolve_family` for
    concrete (non-traced) extras. Used by layers that transform the lowered
    parameters (e.g. the sunk-work remaining-stats rescaling) and then need
    a family object for API boundaries that validate specs (Stage, checks)."""
    _check_dist(dist_id)
    ex = np.asarray(extra, np.float32)
    if dist_id == "normal":
        return _SINGLETONS["normal"]
    if dist_id == "lognormal":
        return _SINGLETONS["lognormal"]
    if dist_id == "drift":
        return Drift(ex[0])
    if dist_id == "defective":
        lam = float(ex[1].flat[0]) if ex[1].size else 1.0
        return Defective(np.clip(ex[0], 0.0, 1.0), pricing=lam)
    C = EMP_COMPONENTS
    return Empirical(ex[0:C], ex[C:2 * C], ex[2 * C:3 * C])


def remaining_work_stats(dist_id: str, mus, sigmas, extra, done):
    """Channel statistics for the *remaining* work after sunk progress.

    The mid-flight re-solve contract (host-side, numpy): ``done`` is the
    per-channel work fraction already completed, ``r = max(1 - sum(done), 0)``
    the total remaining work, and the re-solve optimizes a fresh unit simplex
    over statistics rescaled so that assigning remaining-share ``w'`` means
    executing ``w' * r`` units of original work:

    * scale families (normal, lognormal, defective, empirical): completion
      time of ``s`` units is ``s``-linear, so ``(mu, sigma) -> (r mu,
      r sigma)`` (mixture rows likewise); shape parameters (``p``, ``lam``,
      mixture weights) are per-attempt physics and do not rescale.
    * drift: a channel that already executed ``d_k`` units sits at inflated
      instantaneous rate ``mu (1 + rho d_k)``; the residual completion time
      of ``s`` more units is ``N(s mu (1 + rho d_k)(1 + rho' s/2),
      (s sigma)^2)`` with ``rho' = rho / (1 + rho d_k)``. Substituting
      ``s = w' r`` gives ``mu' = r mu (1 + rho d_k)``, ``sigma' = r sigma``,
      ``rho'' = rho r / (1 + rho d_k)``.

    Returns ``(mus_r, sigmas_r, extra_r, r)`` as float64 numpy arrays plus
    the scalar remaining fraction. ``r == 0`` returns all-zero stats — the
    caller should short-circuit (nothing left to solve).
    """
    _check_dist(dist_id)
    mus = np.asarray(mus, np.float64)
    sigmas = np.asarray(sigmas, np.float64)
    extra = np.asarray(extra, np.float64)
    done = np.asarray(done, np.float64)
    if done.shape != mus.shape:
        raise ValueError(f"done must be per-channel {mus.shape}, "
                         f"got {done.shape}")
    if done.size and (float(done.min()) < -1e-9
                      or float(done.sum()) > 1.0 + 1e-6):
        raise ValueError("done fractions must be nonnegative with total "
                         f"<= 1, got sum {float(done.sum()):.6f}, "
                         f"min {float(done.min()):.3e}")
    r = float(max(1.0 - done.sum(), 0.0))
    extra_r = extra.copy()
    if dist_id == "drift":
        rho = extra[0]
        inflate = 1.0 + rho * done
        mus_r = r * mus * inflate
        sigmas_r = r * sigmas
        extra_r[0] = rho * r / np.maximum(inflate, 1e-12)
        return mus_r, sigmas_r, extra_r, r
    if dist_id == "empirical":
        C = EMP_COMPONENTS
        extra_r[C:3 * C] *= r  # component means and stds scale; weights don't
        return r * mus, r * sigmas, extra_r, r
    # normal / lognormal / defective: pure scale families, shape params fixed
    return r * mus, r * sigmas, extra_r, r
