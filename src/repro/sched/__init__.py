"""Scheduler layer: the paper's partitioner wired into the runtime."""
from .balancer import UncertaintyAwareBalancer, integerize
from .straggler import StragglerPolicy

__all__ = ["UncertaintyAwareBalancer", "integerize", "StragglerPolicy"]
