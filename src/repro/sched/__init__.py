"""Scheduler layer: the paper's partitioner wired into the runtime."""
from .balancer import (UncertaintyAwareBalancer, WorkflowBalancer,
                       integerize)
from .straggler import StragglerPolicy

__all__ = ["UncertaintyAwareBalancer", "WorkflowBalancer", "integerize",
           "StragglerPolicy"]
