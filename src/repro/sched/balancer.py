"""UncertaintyAwareBalancer: the paper's partitioner driving real work splits.

Maintains per-channel Normal-Inverse-Gamma posteriors over *per-unit-work*
completion time (seconds per microbatch / per MB / per request), converts the
posterior point estimates into frontier weights via repro.core, and emits
integer work assignments (microbatch counts, request shards).

Closed-loop estimation (this is where the whole estimation stack meets the
solver):

* ``family="auto"`` — the completion-time model itself is selected online:
  the balancer keeps a bounded (rate, work) history and periodically
  BIC-scores NIG-Normal vs moment-matched lognormal vs the drift regression
  vs a per-channel empirical GMM (``core.bayes.score_families``). A
  challenger family must win ``hysteresis`` consecutive scoring passes
  before the balancer switches — a switch is a model change and always
  invalidates the cached solve.
* ``adaptive_refresh=True`` — the refresh cadence is sized by posterior
  sensitivity: after each fresh solve the balancer computes the delta-method
  fragility of the predicted mean under estimation error
  (``core.sensitivity``) and refreshes sooner while the solve is fragile
  (young/posteriors moving) and stretches toward ``refresh_every`` as
  estimates firm up.
* ``risk_lam > 0`` — candidate splits are scored by the risk-adjusted
  objective ``mu + lam var + risk_lam * fragility`` so the chosen split is
  robust to estimation error, not just optimal at the point estimates.

This is the object the training loop and the serving batcher talk to; it is
deliberately free of any jax device state so it runs on the host scheduler
thread and serializes into checkpoints (meta.json). ``state_dict`` /
``from_state_dict`` round-trip the FULL estimation state — NIG posteriors,
selected family (with fitted parameters), hysteresis counters, rate history,
cached solve and refresh phase — so a restored balancer resumes identical
ticks.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence

import numpy as np

from ..core import (NIGState, get_family, nig_init, nig_point_estimates,
                    nig_update_batch, equal_split, inverse_mu_split,
                    optimize_2ch, optimize_weights, predict_moments,
                    fit_selected_family, score_families)
from ..obs import events as obs_events
from ..obs import names as obs_names
from ..obs import trace as obs

__all__ = ["integerize", "UncertaintyAwareBalancer", "WorkflowBalancer",
           "InstanceHeads"]


def _cadence_from_fragility(rel_fragility: float, cap: int,
                            target_rel: float) -> int:
    """Map relative solve fragility to a refresh cadence in [1, cap].

    The solve drifts roughly in proportion to the estimation error, so
    cadence ~ tolerated drift / current fragility: a solve whose prediction
    is (say) 10% uncertain refreshes every tick, one whose posteriors have
    firmed to 0.1% stretches to the configured maximum. Shared by the
    single-workload and workflow balancers — one sizing rule.
    """
    cap = max(cap, 1)
    if rel_fragility <= 0.0:
        return cap
    return int(np.clip(round(target_rel / rel_fragility), 1, cap))


def integerize(weights: np.ndarray, total: int) -> np.ndarray:
    """Largest-remainder rounding of simplex weights into integer counts
    summing to ``total``. Guarantees nonnegative counts."""
    w = np.maximum(np.asarray(weights, np.float64), 0.0)
    w = w / max(w.sum(), 1e-12)
    raw = w * total
    base = np.floor(raw).astype(np.int64)
    rem = total - int(base.sum())
    if rem > 0:
        order = np.argsort(-(raw - base))
        base[order[:rem]] += 1
    return base


@dataclass
class UncertaintyAwareBalancer:
    """Online paper-partitioner over K channels.

    lam     — mean-variance tradeoff on the frontier (0 = pure speed).
    policy  — "frontier" (the paper), "equal" (map-reduce baseline),
              "inverse_mu" (deterministic balance baseline).
    family  — completion-time family for the solve: a name, a
              ``ChannelFamily`` instance, or "auto" (online BIC selection).
    """

    num_channels: int
    lam: float = 0.05
    policy: str = "frontier"
    prior_mean: float = 1.0
    min_weight: float = 0.0
    refresh_every: int = 1      # re-solve the frontier every N observations
    pgd_steps: int = 150        # K-channel solver budget (warm-started)
    impl: str = "xla"           # frontier_moments backend: xla | pallas[_interpret]
    num_t: int = 1024           # survival-integral resolution per candidate
    block_f: Optional[int] = None  # kernel launch shape; None = autotuned
    family: object = "normal"   # completion-time family ("auto" = select online)
    risk_lam: float = 0.0       # fragility weight in the candidate scoring
    adaptive_refresh: bool = False  # size the refresh cadence by sensitivity
    refresh_target_rel: float = 0.02  # tolerated relative predicted-mean drift
    history_window: int = 128   # (rate, work) observations kept per channel
    auto_every: int = 8         # BIC-score cadence, in observations
    auto_min_obs: int = 12      # history needed before scoring starts
    hysteresis: int = 3         # consecutive wins before a family switch
    explore: float = 0.15       # auto-mode probe amplitude (see weights())
    _nig: NIGState = field(default=None, repr=False)
    _cached_w: np.ndarray = field(default=None, repr=False)
    _cached_family_key: object = field(default=None, repr=False)
    _obs_count: int = 0
    _selected_family: object = field(default=None, repr=False)
    _challenger: Optional[str] = field(default=None, repr=False)
    _challenger_count: int = 0
    _last_scores: object = field(default=None, repr=False)
    _effective_refresh: Optional[int] = field(default=None, repr=False)
    _last_fragility: Optional[float] = field(default=None, repr=False)
    _last_rel_fragility: Optional[float] = field(default=None, repr=False)
    _hist_rates: list = field(default_factory=list, repr=False)
    _hist_work: list = field(default_factory=list, repr=False)
    _hist_mask: list = field(default_factory=list, repr=False)

    def __post_init__(self):
        if self._nig is None:
            self._nig = nig_init(self.num_channels, m0=self.prior_mean)
        if self._selected_family is None:
            self._selected_family = get_family(
                None if self._is_auto else self.family)
        if self._effective_refresh is None:
            self._effective_refresh = max(self.refresh_every, 1)

    @property
    def _is_auto(self) -> bool:
        return isinstance(self.family, str) and self.family == "auto"

    @property
    def selected_family(self):
        """The ChannelFamily the next frontier solve will run under."""
        return (self._selected_family if self._is_auto
                else get_family(self.family))

    @property
    def family_scores(self):
        """Last ``core.bayes.FamilyScores`` (None before the first pass)."""
        return self._last_scores

    @property
    def effective_refresh(self) -> int:
        """Current refresh cadence (== refresh_every unless adaptive)."""
        return int(self._effective_refresh or max(self.refresh_every, 1))

    # ------------------------------------------------------------ feedback
    def observe(self, durations: Sequence[float], work: Sequence[float]):
        """Report per-channel durations for assigned work fractions.

        work==0 entries (idle/failed channels) are masked out. Feeds both the
        NIG posteriors and, under ``family="auto"``, the bounded history the
        BIC family selection scores.
        """
        import jax.numpy as jnp
        d = np.asarray(durations, np.float64)
        w = np.asarray(work, np.float64)
        mask = (w > 0).astype(np.float32)
        rates = np.where(w > 0, d / np.maximum(w, 1e-12), 0.0).astype(np.float32)
        self._nig = nig_update_batch(self._nig, jnp.asarray(rates),
                                     jnp.asarray(mask))
        self._obs_count += 1
        if self._is_auto:
            # the (rate, work) window only feeds the BIC family selection —
            # fixed-family balancers skip it (and keep checkpoints lean)
            self._hist_rates.append(rates)
            self._hist_work.append(w.astype(np.float32))
            self._hist_mask.append(mask)
            if len(self._hist_rates) > self.history_window:
                del self._hist_rates[0], self._hist_work[0], \
                    self._hist_mask[0]
            if self._obs_count % max(self.auto_every, 1) == 0:
                self._auto_select()

    def _auto_select(self):
        """One BIC scoring pass + hysteresis; switches invalidate the solve."""
        if len(self._hist_rates) < self.auto_min_obs:
            return
        scores = score_families(np.stack(self._hist_rates),
                                np.stack(self._hist_work),
                                np.stack(self._hist_mask),
                                min_obs=self.auto_min_obs)
        if scores is None:
            return
        self._last_scores = scores
        current = self._selected_family.dist_id
        if scores.winner == current:
            # the incumbent re-won: reset any challenger streak. Re-fit the
            # parametric extras in place (drift rates / mixture components
            # track the data) WITHOUT treating it as a switch — the family
            # key change alone invalidates the cached solve when they move.
            self._challenger, self._challenger_count = None, 0
            if current in ("drift", "empirical"):
                self._selected_family = fit_selected_family(scores)
            return
        if scores.winner != self._challenger:
            self._challenger, self._challenger_count = scores.winner, 1
        else:
            self._challenger_count += 1
        if self._challenger_count >= max(self.hysteresis, 1):
            obs_events.family_switch(current, scores.winner, scores.bics,
                                     streak=self._challenger_count)
            self._selected_family = fit_selected_family(scores)
            self._challenger, self._challenger_count = None, 0
            self._cached_w = None        # model change: re-solve immediately

    def estimates(self):
        mu, sigma = nig_point_estimates(self._nig)
        return np.asarray(mu, np.float64), np.asarray(sigma, np.float64)

    # ------------------------------------------------------------ decisions
    @staticmethod
    def _family_key(fam) -> str:
        """Canonical fingerprint of a family spec (cache-invalidation key).

        A JSON string so it survives ``state_dict`` round-trips *verbatim*:
        a cached solve made under a per-call family override (e.g. the
        straggler policy's Drift) must still read as stale after a restore,
        exactly as it would have in the original process.
        """
        import json
        fam = get_family(fam)
        items = {k: (np.asarray(v).ravel().tolist() if not isinstance(v, str)
                     else v)
                 for k, v in fam.state_dict().items()}
        return json.dumps([fam.dist_id, items], sort_keys=True)

    def _size_refresh(self, rel_fragility: float):
        """Adaptive cadence: see :func:`_cadence_from_fragility`."""
        self._effective_refresh = _cadence_from_fragility(
            rel_fragility, self.refresh_every, self.refresh_target_rel)

    def weights(self, family=None) -> np.ndarray:
        """Current split decision; ``family`` overrides the configured
        completion-time family for this solve (e.g. the straggler policy
        passing a Drift family with per-channel rates)."""
        mus, sigmas = self.estimates()
        k = self.num_channels
        fam = self.selected_family if family is None else family
        if self.policy == "equal":
            w = np.asarray(equal_split(k))
        elif self.policy == "inverse_mu":
            w = np.asarray(inverse_mu_split(mus))
        else:
            # frontier: cached between refreshes (the solve is the scheduler
            # tick cost — it must stay off the per-step critical path). A
            # family change (straggler detected -> drift priced in, or the
            # auto-selector switching models) is a model change: it always
            # invalidates the cached solve.
            fam_key = self._family_key(fam)
            cadence = (self.effective_refresh if self.adaptive_refresh
                       else max(self.refresh_every, 1))
            stale = (self._cached_w is None
                     or len(self._cached_w) != k
                     or fam_key != self._cached_family_key
                     or self._obs_count % cadence == 0)
            if not stale:
                # fall through to the min_weight floor below: cached and
                # fresh ticks must return identical post-processing
                w = self._cached_w.copy()
            elif k == 2 and self.risk_lam <= 0 and not self.adaptive_refresh:
                w = optimize_2ch(mus[0], sigmas[0], mus[1], sigmas[1],
                                 lam=self.lam, impl=self.impl,
                                 family=fam).weights
            else:
                restarts = 2 if k <= 16 else 0
                # warm-start from the previous solve: posteriors move a
                # little per tick, so the old optimum is a near-solution
                warm = (self._cached_w
                        if self._cached_w is not None
                        and len(self._cached_w) == k else None)
                # refresh tick rides the fused moments+gradient path: every
                # PGD step inside is one analytic forward+grad launch
                with obs.span(obs_names.SPAN_SCHED_REFRESH, kind="fleet",
                              k=k, warm=warm is not None):
                    out = optimize_weights(
                        mus, sigmas, lam=self.lam,
                        steps=self.pgd_steps,
                        restarts=restarts,
                        num_t=self.num_t, impl=self.impl,
                        warm_start=warm,
                        block_f=self.block_f,
                        family=fam,
                        risk_lam=self.risk_lam,
                        posterior=(self._nig if self.risk_lam > 0
                                   or self.adaptive_refresh
                                   else None),
                        return_sensitivity=self.adaptive_refresh)
                if self.adaptive_refresh:
                    dec, report = out
                    self._last_fragility = report.fragility
                    self._last_rel_fragility = report.relative_fragility
                    self._size_refresh(report.relative_fragility)
                else:
                    dec = out
                w = dec.weights
            self._cached_w = np.asarray(w, np.float64)
            self._cached_family_key = fam_key
        if self._is_auto and self.explore > 0 and self.policy == "frontier":
            # active identification: a converged (static) split makes
            # within-work drift unidentifiable from a shifted normal — the
            # drift regression needs per-channel spread in the work shares.
            # Probe with a deterministic +-explore alternating pattern (each
            # channel sees both levels on consecutive ticks, so the design
            # matrix has spread e*w by construction). Under the iid families
            # the rate is independent of w, so the probe adds no false
            # signal; the cost is a bounded optimality gap while in auto
            # mode — the standard identification/performance trade. Applied
            # BEFORE the min_weight floor: the floor is a hard invariant the
            # probe must never undercut.
            sign = 1.0 - 2.0 * ((np.arange(k) + self._obs_count) % 2)
            w = w * (1.0 + self.explore * sign)
            w = np.maximum(w, 0.0)
            w = w / max(w.sum(), 1e-12)
        if self.min_weight > 0:
            w = np.maximum(w, self.min_weight)
            w = w / w.sum()
        return np.asarray(w, np.float64)

    def assign(self, total_units: int) -> np.ndarray:
        """Integer work assignment (e.g. microbatch counts per pod)."""
        return integerize(self.weights(), total_units)

    def resolve_inflight(self, done, failed=None) -> np.ndarray:
        """Sunk-work-aware mid-flight re-solve (the failure-recovery tick).

        ``done`` is the per-channel work fraction already completed (of the
        WHOLE job, so ``sum(done) <= 1``); ``failed`` an optional iterable of
        channel indices currently dead — they are excluded from the re-solve
        and receive exactly zero share. Returns shares of the REMAINING work
        ``r = 1 - sum(done)``: channel k should execute ``out[k] * r`` more
        units. The cached full-work solve is untouched (this decision is
        about a partially-executed instance, not the steady-state split).

        The re-solve is warm-started from the previous solve minus the sunk
        progress, and fragility-gated: with no failures, an adaptive-refresh
        balancer whose last solve was firm (relative fragility at or under
        ``refresh_target_rel``) skips the PGD entirely — the warm start IS
        the answer to within-tolerance, exactly the cadence logic the
        steady-state tick uses. Any failure always forces the solve: losing
        a channel is a model change, never absorbable drift.
        """
        from ..core.distributions import remaining_work_stats, resolve_family

        done = np.asarray(done, np.float64)
        k = self.num_channels
        active = np.ones(k, bool)
        if failed is not None:
            failed = np.asarray(sorted(set(int(i) for i in failed)), int)
            active[failed] = False
        r = float(max(1.0 - done.sum(), 0.0))
        if r <= 0.0 or not active.any():
            return np.zeros(k)
        mus, sigmas = self.estimates()
        dist_id, extra = resolve_family(self.selected_family, k)
        mus_r, sigmas_r, extra_r, _ = remaining_work_stats(
            dist_id, mus, sigmas, np.asarray(extra), done)
        prev = (self._cached_w
                if self._cached_w is not None and len(self._cached_w) == k
                else None)
        if prev is not None:
            warm = np.maximum(np.asarray(prev, np.float64) - done, 0.0)
            warm *= active
        else:
            warm = active.astype(np.float64)
        s = warm.sum()
        warm = warm / s if s > 0 else active / active.sum()
        if (active.all() and prev is not None and self.adaptive_refresh
                and self._last_rel_fragility is not None
                and self._last_rel_fragility <= self.refresh_target_rel):
            return warm
        idx = np.flatnonzero(active)
        dec = optimize_weights(
            mus_r[idx], sigmas_r[idx], lam=self.lam, steps=self.pgd_steps,
            restarts=0, num_t=self.num_t, impl=self.impl,
            block_f=self.block_f,
            family=(dist_id, np.asarray(extra_r, np.float32)[:, idx]),
            warm_start=warm[idx])
        out = np.zeros(k)
        out[idx] = dec.weights
        return out

    def predicted_moments(self, weights: Optional[np.ndarray] = None,
                          family=None):
        mus, sigmas = self.estimates()
        w = self.weights() if weights is None else weights
        fam = self.selected_family if family is None else family
        return predict_moments(w, mus, sigmas, family=fam)

    # ------------------------------------------------------------ elasticity
    def add_channel(self, prior_mean: Optional[float] = None):
        """Enlist a new channel (elastic scale-up) with a weak prior."""
        import jax.numpy as jnp
        mus, _ = self.estimates()
        m0 = prior_mean if prior_mean is not None else float(np.mean(mus))
        old = self._nig
        new = nig_init(self.num_channels + 1, m0=m0)
        self._nig = NIGState(
            m=jnp.concatenate([old.m, new.m[-1:]]),
            kappa=jnp.concatenate([old.kappa, new.kappa[-1:]]),
            alpha=jnp.concatenate([old.alpha, new.alpha[-1:]]),
            beta=jnp.concatenate([old.beta, new.beta[-1:]]))
        self.num_channels += 1
        self._reset_after_resize()

    def remove_channel(self, idx: int):
        """Drop a failed/retired channel (elastic scale-down)."""
        import jax.numpy as jnp
        keep = [i for i in range(self.num_channels) if i != idx]
        sel = jnp.asarray(keep)
        o = self._nig
        self._nig = NIGState(m=o.m[sel], kappa=o.kappa[sel],
                             alpha=o.alpha[sel], beta=o.beta[sel])
        self.num_channels -= 1
        self._reset_after_resize()

    def _reset_after_resize(self):
        """A fleet-shape change invalidates the solve, the per-channel
        history (column counts no longer line up) and any auto-family
        parametric fit sized to the old K."""
        self._cached_w = None
        self._hist_rates, self._hist_work, self._hist_mask = [], [], []
        self._challenger, self._challenger_count = None, 0
        self._last_scores = None   # rho/gmm arrays are sized to the old K
        if self._is_auto and self._selected_family.dist_id in ("drift",
                                                               "empirical"):
            self._selected_family = get_family("normal")

    # ------------------------------------------------------------ persistence
    def state_dict(self) -> dict:
        """Full estimation state: a restored balancer resumes identical
        ticks (same solves on the same observations in the same phase)."""
        return {
            "num_channels": self.num_channels, "lam": self.lam,
            "policy": self.policy, "impl": self.impl, "num_t": self.num_t,
            "min_weight": self.min_weight,
            "refresh_every": self.refresh_every,
            "pgd_steps": self.pgd_steps,
            "risk_lam": self.risk_lam,
            "adaptive_refresh": self.adaptive_refresh,
            "refresh_target_rel": self.refresh_target_rel,
            "history_window": self.history_window,
            "auto_every": self.auto_every,
            "auto_min_obs": self.auto_min_obs,
            "hysteresis": self.hysteresis,
            "explore": self.explore,
            "family": ("auto" if self._is_auto
                       else get_family(self.family).state_dict()),
            "selected_family": self._selected_family.state_dict(),
            "challenger": self._challenger,
            "challenger_count": self._challenger_count,
            "obs_count": self._obs_count,
            "effective_refresh": self._effective_refresh,
            "last_fragility": self._last_fragility,
            "last_rel_fragility": self._last_rel_fragility,
            "cached_w": (None if self._cached_w is None
                         else np.asarray(self._cached_w).tolist()),
            "cached_family_key": self._cached_family_key,
            "history": {
                "rates": np.asarray(self._hist_rates, np.float64).tolist(),
                "work": np.asarray(self._hist_work, np.float64).tolist(),
                "mask": np.asarray(self._hist_mask, np.float64).tolist(),
            },
            "nig": {k: np.asarray(v).tolist()
                    for k, v in self._nig._asdict().items()},
        }

    @classmethod
    def from_state_dict(cls, d: dict) -> "UncertaintyAwareBalancer":
        import jax.numpy as jnp
        fam_spec = d.get("family", "normal")
        fam = "auto" if fam_spec == "auto" else get_family(fam_spec)
        b = cls(num_channels=d["num_channels"], lam=d["lam"],
                policy=d["policy"],
                impl=d.get("impl", "xla"), num_t=d.get("num_t", 1024),
                min_weight=d.get("min_weight", 0.0),
                refresh_every=d.get("refresh_every", 1),
                pgd_steps=d.get("pgd_steps", 150),
                risk_lam=d.get("risk_lam", 0.0),
                adaptive_refresh=d.get("adaptive_refresh", False),
                refresh_target_rel=d.get("refresh_target_rel", 0.02),
                history_window=d.get("history_window", 128),
                auto_every=d.get("auto_every", 8),
                auto_min_obs=d.get("auto_min_obs", 12),
                hysteresis=d.get("hysteresis", 3),
                explore=d.get("explore", 0.15),
                family=fam)
        b._nig = NIGState(**{k: jnp.asarray(v, jnp.float32)
                             for k, v in d["nig"].items()})
        if "selected_family" in d:
            b._selected_family = get_family(d["selected_family"])
        b._challenger = d.get("challenger")
        b._challenger_count = d.get("challenger_count", 0)
        b._obs_count = d.get("obs_count", 0)
        b._effective_refresh = d.get("effective_refresh",
                                     max(b.refresh_every, 1))
        b._last_fragility = d.get("last_fragility")
        b._last_rel_fragility = d.get("last_rel_fragility")
        if d.get("cached_w") is not None:
            b._cached_w = np.asarray(d["cached_w"], np.float64)
            key = d.get("cached_family_key")
            # the key round-trips verbatim (it is a canonical JSON string);
            # a legacy boolean marker falls back to recomputing from the
            # selected family — conservative for override-cached solves
            b._cached_family_key = (cls._family_key(b.selected_family)
                                    if key is True else key)
        hist = d.get("history")
        if hist and len(hist.get("rates", [])):
            b._hist_rates = [np.asarray(r, np.float32)
                             for r in hist["rates"]]
            b._hist_work = [np.asarray(r, np.float32) for r in hist["work"]]
            b._hist_mask = [np.asarray(r, np.float32) for r in hist["mask"]]
        return b


@dataclass
class WorkflowBalancer:
    """Joint DAG partitioner: the paper's loop lifted to a stage graph.

    Holds one estimation head per stage — a policy-less
    :class:`UncertaintyAwareBalancer` reused purely for its NIG posteriors
    and (with ``family="auto"``) the online BIC family selection — and
    re-solves ALL stage splits jointly through ``workflow.solve.solve_dag``
    per refresh tick, warm-started from the previous solve. Every moment
    evaluation inside a tick is one stacked kernel launch per family present
    in the graph, never a per-stage loop.

    ``dag`` supplies the graph structure and per-stage fleet sizes; its
    stage statistics are treated as priors — the live solve always runs on
    the posterior point estimates (and each stage's currently selected
    family). Cache semantics mirror the single-stage balancer: a family
    switch on ANY stage, a structure change, or the refresh cadence expiring
    invalidates the cached solve; ``adaptive_refresh`` sizes the cadence by
    the composed makespan fragility (delta-method through the DAG).

    **Incremental re-solves (PR 8).** The balancer snapshots the per-stage
    statistics each solve ran on. When ``incremental`` is on AND the last
    solve reported a composed fragility at or under ``refresh_target_rel``
    (the fragility gate — a fragile solve means the posteriors are still
    moving the optimum globally, so freezing rows on it would lock in
    noise), a refresh tick re-solves only the DIRTY stages: those whose
    posterior point estimates drifted more than ``dirty_tol`` (relative)
    from their snapshot, or whose selected family changed. Frozen stages'
    rows pass through the solve bitwise (``solve_dag(dirty=...)``); an
    empty dirty set skips the solver call entirely and the cached split
    stands. Snapshots update only for the stages a solve actually moved,
    so drift on frozen stages accumulates against the solve that last
    placed them. The multi-fidelity knobs (``presolve_num_t``,
    ``prune_margin``, ``plateau_tol``/``plateau_patience``) thread through
    every solver call.
    """

    dag: object                      # workflow.StageDAG
    lam_var: float = 0.0             # makespan variance weight
    family: object = "auto"          # per-stage family mode (see balancer)
    refresh_every: int = 1
    pgd_steps: int = 60
    restarts: int = 1
    impl: str = "xla"
    num_t: int = 512
    block_f: Optional[int] = None
    risk_lam: float = 0.0
    adaptive_refresh: bool = False
    refresh_target_rel: float = 0.02
    prior_mean: float = 1.0
    min_weight: float = 0.0
    presolve_num_t: Optional[int] = None   # coarse ladder rung (None: solver default)
    prune_margin: Optional[float] = 5e-3
    plateau_tol: float = 1e-6
    plateau_patience: Optional[int] = 8
    incremental: bool = True
    dirty_tol: float = 0.05                # relative posterior drift that dirties a stage
    _est: dict = field(default=None, repr=False)
    _cached: object = field(default=None, repr=False)
    _cached_key: object = field(default=None, repr=False)
    _obs_count: int = 0
    _effective_refresh: Optional[int] = field(default=None, repr=False)
    _last_decision: object = field(default=None, repr=False)
    _last_rel_frag: Optional[float] = field(default=None, repr=False)
    _failed: dict = field(default_factory=dict, repr=False)
    _solve_stats: dict = field(default_factory=dict, repr=False)
    _solve_fams: dict = field(default_factory=dict, repr=False)

    def __post_init__(self):
        if self._est is None:
            # per-stage estimation heads: NIG posteriors + auto family
            # selection; their solve path (weights()) is never used, so the
            # exploration probe is off
            self._est = {
                s.name: UncertaintyAwareBalancer(
                    num_channels=s.k, family=self.family,
                    prior_mean=self.prior_mean, explore=0.0)
                for s in self.dag.stages}
        if self._effective_refresh is None:
            self._effective_refresh = max(self.refresh_every, 1)

    @property
    def effective_refresh(self) -> int:
        return int(self._effective_refresh or max(self.refresh_every, 1))

    @property
    def last_decision(self):
        """The DAGDecision of the most recent fresh solve (None before)."""
        return self._last_decision

    def selected_families(self) -> dict:
        """dist_id per stage the next joint solve will run under."""
        return {n: e.selected_family.dist_id for n, e in self._est.items()}

    # ------------------------------------------------------------ feedback
    def observe(self, durations: dict, work: dict):
        """Per-stage feedback: {stage: per-channel durations / work shares}.

        Stages absent from a tick (not released yet in a pipelined trace)
        are simply skipped; each present stage feeds its own posterior and
        family-selection history.
        """
        for name, durs in durations.items():
            self._est[name].observe(durs, work[name])
        self._obs_count += 1

    # ------------------------------------------------------------- failures
    def handle_failure(self, stage: str, idx: int):
        """A sim/operator failure event: channel ``idx`` of ``stage`` is
        dead. It receives exactly zero share from every subsequent
        ``weights()`` call (the remainder renormalized within the stage)
        until :meth:`handle_recovery`. Invalidate the cached solve so the
        next tick re-solves against the shrunken fleet."""
        if not any(s.name == stage for s in self.dag.stages):
            raise KeyError(f"unknown stage {stage!r}")
        self._failed.setdefault(stage, set()).add(int(idx))
        self._cached = None
        obs_events.churn("fail", idx, "balancer", detail=stage)

    def handle_recovery(self, stage: str, idx: int):
        """Re-admit a recovered channel (no-op if it was never failed)."""
        bad = self._failed.get(stage)
        if bad is not None:
            bad.discard(int(idx))
            if not bad:
                self._failed.pop(stage)
        self._cached = None
        obs_events.churn("recover", idx, "balancer", detail=stage)

    def failed_channels(self) -> dict:
        """{stage: sorted failed channel indices} — empty when healthy."""
        return {n: sorted(v) for n, v in self._failed.items() if v}

    def _mask_failed(self, name: str, w: np.ndarray) -> np.ndarray:
        """Zero dead channels and renormalize the survivors' shares."""
        bad = self._failed.get(name)
        if not bad:
            return w
        w = w.copy()
        w[sorted(bad)] = 0.0
        s = w.sum()
        if s > 0:
            return w / s
        alive = np.ones(len(w))
        alive[sorted(bad)] = 0.0
        return alive / max(alive.sum(), 1.0)

    # ------------------------------------------------------------ decisions
    def _live_dag(self):
        mus, sigmas, fams = {}, {}, {}
        for s in self.dag.stages:
            est = self._est[s.name]
            mus[s.name], sigmas[s.name] = est.estimates()
            fams[s.name] = est.selected_family
        return self.dag.with_stats(mus, sigmas, fams)

    def _solve_key(self) -> str:
        fams = [UncertaintyAwareBalancer._family_key(
            self._est[s.name].selected_family) for s in self.dag.stages]
        key = "|".join(fams)
        if self._failed:
            # a failure/recovery event is a model change, not drift: the key
            # shifts so any cached solve from the old fleet shape goes stale
            bad = ";".join(f"{n}:{sorted(v)}"
                           for n, v in sorted(self._failed.items()) if v)
            key += f"|failed[{bad}]"
        return key

    def _dirty_stages(self, live):
        """Fragility-gated dirty set for an incremental re-solve.

        ``None`` demands a full joint solve; otherwise a (possibly empty)
        set of stage names whose estimation state moved past ``dirty_tol``
        since their snapshot. The gate: an incremental solve is only
        trusted when the last solve reported a composed relative fragility
        at or under ``refresh_target_rel`` — a fragile solve means the
        posteriors are still moving the optimum globally, so freezing rows
        on it would lock in noise. (Fragility is only computed when
        posteriors ride the solve — ``risk_lam > 0`` or
        ``adaptive_refresh`` — so a plain balancer always full-solves.)
        """
        if not self.incremental or self._cached is None \
                or not self._solve_stats:
            return None
        rel = self._last_rel_frag
        if rel is None or rel > self.refresh_target_rel:
            obs_events.fragility_gate(False, rel, self.refresh_target_rel)
            return None
        obs_events.fragility_gate(True, rel, self.refresh_target_rel)
        dirty = set()
        for s in live.stages:
            snap = self._solve_stats.get(s.name)
            fkey = UncertaintyAwareBalancer._family_key(
                self._est[s.name].selected_family)
            if snap is None or self._solve_fams.get(s.name) != fkey:
                dirty.add(s.name)
                obs_events.dirty("workflow", s.name, "family")
                continue
            mu0, sg0 = snap
            mu = np.asarray(s.mus, np.float64)
            sg = np.asarray(s.sigmas, np.float64)
            drift = max(
                float(np.max(np.abs(mu - mu0)
                             / np.maximum(np.abs(mu0), 1e-9))),
                float(np.max(np.abs(sg - sg0)
                             / np.maximum(np.abs(sg0), 1e-9))))
            if drift > self.dirty_tol:
                dirty.add(s.name)
                obs_events.dirty("workflow", s.name, "drift", drift)
        if len(dirty) == len(live.stages):
            return None      # everything moved: a plain full solve
        return dirty

    def _snapshot(self, live, dirty):
        """Record the per-stage statistics this solve ran on. An incremental
        solve updates only its dirty stages' snapshots: frozen stages keep
        the snapshot of the solve that last MOVED them, so posterior drift
        accumulates against it and eventually crosses ``dirty_tol``."""
        for s in live.stages:
            if dirty is not None and s.name not in dirty:
                continue
            self._solve_stats[s.name] = (
                np.asarray(s.mus, np.float64).copy(),
                np.asarray(s.sigmas, np.float64).copy())
            self._solve_fams[s.name] = UncertaintyAwareBalancer._family_key(
                self._est[s.name].selected_family)

    def weights(self) -> dict:
        """Current per-stage splits; re-solves jointly when stale — and,
        when the fragility gate allows it, only over the dirty stages."""
        key = self._solve_key()
        cadence = (self.effective_refresh if self.adaptive_refresh
                   else max(self.refresh_every, 1))
        stale = (self._cached is None or key != self._cached_key
                 or self._obs_count % cadence == 0)
        if stale:
            from ..workflow.solve import solve_dag  # lazy: layering

            live = self._live_dag()
            dirty = self._dirty_stages(live)
            if dirty is not None and not dirty:
                # every stage within dirty_tol of its snapshot and the last
                # solve was firm: the cached split stands — no solver call
                self._cached_key = key
            else:
                posteriors = None
                if self.risk_lam > 0 or self.adaptive_refresh:
                    posteriors = {s.name: self._est[s.name]._nig
                                  for s in self.dag.stages}
                warm = (self._cached if self._cached is not None else None)
                with obs.span(obs_names.SPAN_SCHED_REFRESH, kind="workflow",
                              stages=len(live.stages),
                              dirty=(-1 if dirty is None else len(dirty)),
                              warm=warm is not None):
                    dec = solve_dag(live, lam_var=self.lam_var,
                                    steps=self.pgd_steps,
                                    restarts=self.restarts,
                                    num_t=self.num_t, impl=self.impl,
                                    block_f=self.block_f, warm_start=warm,
                                    risk_lam=self.risk_lam,
                                    posteriors=posteriors,
                                    presolve_num_t=self.presolve_num_t,
                                    prune_margin=self.prune_margin,
                                    plateau_tol=self.plateau_tol,
                                    plateau_patience=self.plateau_patience,
                                    dirty=dirty)
                self._last_decision = dec
                self._last_rel_frag = dec.relative_fragility
                if (self.adaptive_refresh
                        and dec.relative_fragility is not None):
                    self._effective_refresh = _cadence_from_fragility(
                        dec.relative_fragility, self.refresh_every,
                        self.refresh_target_rel)
                self._cached = {n: np.asarray(w, np.float64)
                                for n, w in dec.weights.items()}
                self._cached_key = key
                self._snapshot(live, dirty)
        out = {}
        for n, w in self._cached.items():
            w = self._mask_failed(n, w.copy())
            if self.min_weight > 0:
                # floor only the live channels — a dead channel's zero share
                # is a hard constraint, not a starvation to fix
                bad = self._failed.get(n)
                live = np.ones(len(w), bool)
                if bad:
                    live[sorted(bad)] = False
                w = np.where(live, np.maximum(w, self.min_weight), 0.0)
                w = w / w.sum()
            out[n] = w
        return out

    def assign(self, total_units) -> dict:
        """Integer work assignment per stage; ``total_units`` is an int
        (every stage moves the same batch) or a {stage: int} dict."""
        ws = self.weights()
        if not isinstance(total_units, dict):
            total_units = {n: int(total_units) for n in ws}
        return {n: integerize(w, total_units[n]) for n, w in ws.items()}

    def predicted_moments(self):
        """Composed (makespan mu, var) at the current splits."""
        from ..workflow.solve import evaluate_dag  # lazy: layering

        dec = evaluate_dag(self._live_dag(), self.weights(),
                           num_t=max(self.num_t, 2048), impl=self.impl)
        return dec.makespan_mu, dec.makespan_var

    def resolve_inflight(self, done: dict) -> dict:
        """Sunk-work-aware joint re-solve of a partially executed pipeline.

        ``done`` maps stage name -> per-channel fraction of that stage's
        work already completed (``sum <= 1`` per stage; stages absent are
        untouched). Returns {stage: shares of that stage's REMAINING work},
        warm-started from the cached solve; dead channels (from
        :meth:`handle_failure`) get exactly zero share. The steady-state
        cache is untouched — this prices one wounded instance, not the
        fleet's long-run split.

        When the fragility gate admits an incremental solve (see
        :meth:`_dirty_stages`), only the stages with sunk work plus those
        whose posteriors drifted are re-solved; the rest of the warm split
        rides through frozen. No failed channels may ride a frozen row —
        the warm rows are masked first, and a failure event invalidates
        the cache (forcing the full path) anyway.
        """
        from ..workflow.solve import solve_dag  # lazy: layering

        warm = (None if self._cached is None
                else {n: self._mask_failed(n, w.copy())
                      for n, w in self._cached.items()})
        live = self._live_dag()
        dirty = self._dirty_stages(live)
        if dirty is not None:
            dirty = dirty | set(done)
            if len(dirty) >= len(live.stages):
                dirty = None
        dec = solve_dag(live, lam_var=self.lam_var,
                        steps=self.pgd_steps, restarts=0,
                        num_t=self.num_t, impl=self.impl,
                        block_f=self.block_f, warm_start=warm,
                        done=done,
                        presolve_num_t=self.presolve_num_t,
                        prune_margin=self.prune_margin,
                        plateau_tol=self.plateau_tol,
                        plateau_patience=self.plateau_patience,
                        dirty=dirty)
        return {n: self._mask_failed(n, np.asarray(w, np.float64))
                for n, w in dec.weights.items()}

    # ------------------------------------------------------------ persistence
    def state_dict(self) -> dict:
        """Everything but the DAG structure: a balancer restored against
        the same DAG resumes identical ticks (same per-stage posteriors,
        family selections, cached solve, cadence phase and failure set).
        The DAG itself is code-side configuration and is passed back into
        :meth:`from_state_dict` by the caller."""
        return {
            "kind": "workflow",
            "lam_var": self.lam_var,
            "family": ("auto" if (isinstance(self.family, str)
                                  and self.family == "auto")
                       else get_family(self.family).state_dict()),
            "refresh_every": self.refresh_every,
            "pgd_steps": self.pgd_steps,
            "restarts": self.restarts,
            "impl": self.impl, "num_t": self.num_t,
            "block_f": self.block_f,
            "risk_lam": self.risk_lam,
            "adaptive_refresh": self.adaptive_refresh,
            "refresh_target_rel": self.refresh_target_rel,
            "prior_mean": self.prior_mean,
            "min_weight": self.min_weight,
            "presolve_num_t": self.presolve_num_t,
            "prune_margin": self.prune_margin,
            "plateau_tol": self.plateau_tol,
            "plateau_patience": self.plateau_patience,
            "incremental": self.incremental,
            "dirty_tol": self.dirty_tol,
            "obs_count": self._obs_count,
            "effective_refresh": self._effective_refresh,
            "last_rel_fragility": self._last_rel_frag,
            "cached": (None if self._cached is None
                       else {n: np.asarray(w).tolist()
                             for n, w in self._cached.items()}),
            "cached_key": self._cached_key,
            "failed": {n: sorted(v) for n, v in self._failed.items() if v},
            # the incremental-solve snapshots: without them a restored
            # replica would full-solve where the original went incremental,
            # breaking kill/restore tick parity
            "solve_stats": {n: [m.tolist(), sg.tolist()]
                            for n, (m, sg) in self._solve_stats.items()},
            "solve_fams": dict(self._solve_fams),
            "est": {n: e.state_dict() for n, e in self._est.items()},
        }

    @classmethod
    def from_state_dict(cls, d: dict, dag) -> "WorkflowBalancer":
        fam_spec = d.get("family", "auto")
        fam = "auto" if fam_spec == "auto" else get_family(fam_spec)
        b = cls(dag=dag, lam_var=d.get("lam_var", 0.0), family=fam,
                refresh_every=d.get("refresh_every", 1),
                pgd_steps=d.get("pgd_steps", 60),
                restarts=d.get("restarts", 1),
                impl=d.get("impl", "xla"), num_t=d.get("num_t", 512),
                block_f=d.get("block_f"),
                risk_lam=d.get("risk_lam", 0.0),
                adaptive_refresh=d.get("adaptive_refresh", False),
                refresh_target_rel=d.get("refresh_target_rel", 0.02),
                prior_mean=d.get("prior_mean", 1.0),
                min_weight=d.get("min_weight", 0.0),
                presolve_num_t=d.get("presolve_num_t"),
                prune_margin=d.get("prune_margin", 5e-3),
                plateau_tol=d.get("plateau_tol", 1e-6),
                plateau_patience=d.get("plateau_patience", 8),
                incremental=d.get("incremental", True),
                dirty_tol=d.get("dirty_tol", 0.05))
        est = d.get("est", {})
        for name, sd in est.items():
            if name not in b._est:
                raise ValueError(
                    f"state_dict stage {name!r} not in the supplied DAG "
                    f"(stages: {[s.name for s in dag.stages]})")
            b._est[name] = UncertaintyAwareBalancer.from_state_dict(sd)
        b._obs_count = d.get("obs_count", 0)
        b._effective_refresh = d.get("effective_refresh",
                                     max(b.refresh_every, 1))
        if d.get("cached") is not None:
            b._cached = {n: np.asarray(w, np.float64)
                         for n, w in d["cached"].items()}
            b._cached_key = d.get("cached_key")
        b._failed = {n: set(int(i) for i in v)
                     for n, v in d.get("failed", {}).items() if v}
        b._last_rel_frag = d.get("last_rel_fragility")
        b._solve_stats = {n: (np.asarray(m, np.float64),
                              np.asarray(sg, np.float64))
                          for n, (m, sg) in d.get("solve_stats",
                                                  {}).items()}
        b._solve_fams = dict(d.get("solve_fams", {}))
        return b


class InstanceHeads:
    """Per-instance estimation heads for the continuous-batching engine.

    The serving engine prices every live workflow *instance* from its own
    posterior: two instances of the same template admitted at different
    times have seen different service, so their rows of the shared stacked
    launch deserve different ``(mus, sigmas)``. This bank keeps one
    PROTOTYPE head per ``"template/stage"`` key — the fleet-wide posterior
    that keeps learning across all traffic — and forks it at admission into
    a private per-instance copy (a ``state_dict`` round-trip, so the fork
    is an exact snapshot). Observations feed BOTH heads: the instance's
    (its rows drift with its own service history) and the prototype (so
    the next admission starts from everything the fleet has seen).

    Heads are policy-less :class:`UncertaintyAwareBalancer` instances
    (``explore=0``) used purely for their posteriors and family state —
    their solve path is never called; the engine's batched tick is the
    solver.
    """

    def __init__(self, prototypes: dict):
        self.prototypes = dict(prototypes)
        self._bank: dict = {}

    # ------------------------------------------------------------ lifecycle
    def admit(self, iid: int, keys) -> None:
        """Fork the prototype of every ``key`` for instance ``iid``."""
        iid = int(iid)
        if iid in self._bank:
            raise ValueError(f"instance {iid} already admitted")
        bank = {}
        for key in keys:
            proto = self.prototypes[key]
            bank[key] = UncertaintyAwareBalancer.from_state_dict(
                proto.state_dict())
        self._bank[iid] = bank

    def retire(self, iid: int) -> None:
        self._bank.pop(int(iid), None)

    @property
    def live(self):
        return tuple(sorted(self._bank))

    # ------------------------------------------------------------ accessors
    def observe(self, iid: int, key: str, durations, work) -> None:
        """One stage execution's feedback: instance head AND prototype."""
        self._bank[int(iid)][key].observe(durations, work)
        self.prototypes[key].observe(durations, work)

    def estimates(self, iid: int, key: str):
        return self._bank[int(iid)][key].estimates()

    def family(self, iid: int, key: str):
        return self._bank[int(iid)][key].selected_family

    # ------------------------------------------------------------ state
    def state_dict(self) -> dict:
        return {
            "prototypes": {k: p.state_dict()
                           for k, p in self.prototypes.items()},
            "bank": {str(iid): {k: h.state_dict() for k, h in heads.items()}
                     for iid, heads in self._bank.items()},
        }

    @classmethod
    def from_state_dict(cls, d: dict) -> "InstanceHeads":
        obj = cls({k: UncertaintyAwareBalancer.from_state_dict(sd)
                   for k, sd in d["prototypes"].items()})
        obj._bank = {int(iid): {k: UncertaintyAwareBalancer.from_state_dict(sd)
                                for k, sd in heads.items()}
                     for iid, heads in d.get("bank", {}).items()}
        return obj
