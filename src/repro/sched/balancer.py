"""UncertaintyAwareBalancer: the paper's partitioner driving real work splits.

Maintains per-channel Normal-Inverse-Gamma posteriors over *per-unit-work*
completion time (seconds per microbatch / per MB / per request), converts the
posterior point estimates into frontier weights via repro.core, and emits
integer work assignments (microbatch counts, request shards).

This is the object the training loop and the serving batcher talk to; it is
deliberately free of any jax device state so it runs on the host scheduler
thread and serializes into checkpoints (meta.json).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence

import numpy as np

from ..core import (NIGState, get_family, nig_init, nig_point_estimates,
                    nig_update_batch, equal_split, inverse_mu_split,
                    optimize_2ch, optimize_weights, predict_moments)

__all__ = ["integerize", "UncertaintyAwareBalancer"]


def integerize(weights: np.ndarray, total: int) -> np.ndarray:
    """Largest-remainder rounding of simplex weights into integer counts
    summing to ``total``. Guarantees nonnegative counts."""
    w = np.maximum(np.asarray(weights, np.float64), 0.0)
    w = w / max(w.sum(), 1e-12)
    raw = w * total
    base = np.floor(raw).astype(np.int64)
    rem = total - int(base.sum())
    if rem > 0:
        order = np.argsort(-(raw - base))
        base[order[:rem]] += 1
    return base


@dataclass
class UncertaintyAwareBalancer:
    """Online paper-partitioner over K channels.

    lam     — mean-variance tradeoff on the frontier (0 = pure speed).
    policy  — "frontier" (the paper), "equal" (map-reduce baseline),
              "inverse_mu" (deterministic balance baseline).
    """

    num_channels: int
    lam: float = 0.05
    policy: str = "frontier"
    prior_mean: float = 1.0
    min_weight: float = 0.0
    refresh_every: int = 1      # re-solve the frontier every N observations
    pgd_steps: int = 150        # K-channel solver budget (warm-started)
    impl: str = "xla"           # frontier_moments backend: xla | pallas[_interpret]
    num_t: int = 1024           # survival-integral resolution per candidate
    block_f: Optional[int] = None  # kernel launch shape; None = autotuned
    family: object = "normal"   # completion-time family for the solve
    _nig: NIGState = field(default=None, repr=False)
    _cached_w: np.ndarray = field(default=None, repr=False)
    _cached_family_key: object = field(default=None, repr=False)
    _obs_count: int = 0

    def __post_init__(self):
        if self._nig is None:
            self._nig = nig_init(self.num_channels, m0=self.prior_mean)

    # ------------------------------------------------------------ feedback
    def observe(self, durations: Sequence[float], work: Sequence[float]):
        """Report per-channel durations for assigned work fractions.

        work==0 entries (idle/failed channels) are masked out.
        """
        import jax.numpy as jnp
        d = np.asarray(durations, np.float64)
        w = np.asarray(work, np.float64)
        mask = (w > 0).astype(np.float32)
        rates = np.where(w > 0, d / np.maximum(w, 1e-12), 0.0).astype(np.float32)
        self._nig = nig_update_batch(self._nig, jnp.asarray(rates),
                                     jnp.asarray(mask))
        self._obs_count += 1

    def estimates(self):
        mu, sigma = nig_point_estimates(self._nig)
        return np.asarray(mu, np.float64), np.asarray(sigma, np.float64)

    # ------------------------------------------------------------ decisions
    @staticmethod
    def _family_key(fam) -> tuple:
        """Hashable fingerprint of a family spec (cache-invalidation key)."""
        fam = get_family(fam)
        extra_items = tuple(sorted(
            (k, tuple(np.asarray(v).ravel().tolist()) if not isinstance(v, str)
             else v)
            for k, v in fam.state_dict().items()))
        return (fam.dist_id, extra_items)

    def weights(self, family=None) -> np.ndarray:
        """Current split decision; ``family`` overrides the configured
        completion-time family for this solve (e.g. the straggler policy
        passing a Drift family with per-channel rates)."""
        mus, sigmas = self.estimates()
        k = self.num_channels
        fam = self.family if family is None else family
        if self.policy == "equal":
            w = np.asarray(equal_split(k))
        elif self.policy == "inverse_mu":
            w = np.asarray(inverse_mu_split(mus))
        else:
            # frontier: cached between refreshes (the solve is the scheduler
            # tick cost — it must stay off the per-step critical path). A
            # family change (straggler detected -> drift priced in) is a
            # model change: it always invalidates the cached solve.
            fam_key = self._family_key(fam)
            stale = (self._cached_w is None
                     or len(self._cached_w) != k
                     or fam_key != self._cached_family_key
                     or self._obs_count % max(self.refresh_every, 1) == 0)
            if not stale:
                # fall through to the min_weight floor below: cached and
                # fresh ticks must return identical post-processing
                w = self._cached_w.copy()
            elif k == 2:
                w = optimize_2ch(mus[0], sigmas[0], mus[1], sigmas[1],
                                 lam=self.lam, impl=self.impl,
                                 family=fam).weights
            else:
                restarts = 2 if k <= 16 else 0
                # warm-start from the previous solve: posteriors move a
                # little per tick, so the old optimum is a near-solution
                warm = (self._cached_w
                        if self._cached_w is not None
                        and len(self._cached_w) == k else None)
                # refresh tick rides the fused moments+gradient path: every
                # PGD step inside is one analytic forward+grad launch
                w = optimize_weights(mus, sigmas, lam=self.lam,
                                     steps=self.pgd_steps,
                                     restarts=restarts,
                                     num_t=self.num_t, impl=self.impl,
                                     warm_start=warm,
                                     block_f=self.block_f,
                                     family=fam).weights
            self._cached_w = np.asarray(w, np.float64)
            self._cached_family_key = fam_key
        if self.min_weight > 0:
            w = np.maximum(w, self.min_weight)
            w = w / w.sum()
        return np.asarray(w, np.float64)

    def assign(self, total_units: int) -> np.ndarray:
        """Integer work assignment (e.g. microbatch counts per pod)."""
        return integerize(self.weights(), total_units)

    def predicted_moments(self, weights: Optional[np.ndarray] = None,
                          family=None):
        mus, sigmas = self.estimates()
        w = self.weights() if weights is None else weights
        fam = self.family if family is None else family
        return predict_moments(w, mus, sigmas, family=fam)

    # ------------------------------------------------------------ elasticity
    def add_channel(self, prior_mean: Optional[float] = None):
        """Enlist a new channel (elastic scale-up) with a weak prior."""
        import jax.numpy as jnp
        mus, _ = self.estimates()
        m0 = prior_mean if prior_mean is not None else float(np.mean(mus))
        old = self._nig
        new = nig_init(self.num_channels + 1, m0=m0)
        self._nig = NIGState(
            m=jnp.concatenate([old.m, new.m[-1:]]),
            kappa=jnp.concatenate([old.kappa, new.kappa[-1:]]),
            alpha=jnp.concatenate([old.alpha, new.alpha[-1:]]),
            beta=jnp.concatenate([old.beta, new.beta[-1:]]))
        self.num_channels += 1
        self._cached_w = None

    def remove_channel(self, idx: int):
        """Drop a failed/retired channel (elastic scale-down)."""
        import jax.numpy as jnp
        keep = [i for i in range(self.num_channels) if i != idx]
        sel = jnp.asarray(keep)
        o = self._nig
        self._nig = NIGState(m=o.m[sel], kappa=o.kappa[sel],
                             alpha=o.alpha[sel], beta=o.beta[sel])
        self.num_channels -= 1
        self._cached_w = None

    # ------------------------------------------------------------ persistence
    def state_dict(self) -> dict:
        return {"num_channels": self.num_channels, "lam": self.lam,
                "policy": self.policy, "impl": self.impl, "num_t": self.num_t,
                "family": get_family(self.family).state_dict(),
                "nig": {k: np.asarray(v).tolist() for k, v in self._nig._asdict().items()}}

    @classmethod
    def from_state_dict(cls, d: dict) -> "UncertaintyAwareBalancer":
        import jax.numpy as jnp
        b = cls(num_channels=d["num_channels"], lam=d["lam"], policy=d["policy"],
                impl=d.get("impl", "xla"), num_t=d.get("num_t", 1024),
                family=get_family(d.get("family", "normal")))
        b._nig = NIGState(**{k: jnp.asarray(v, jnp.float32)
                             for k, v in d["nig"].items()})
        return b
