"""Straggler detection & mitigation policy on top of the balancer.

The paper's mechanism *is* the mitigation: a slowing channel's posterior mean
rises and the frontier moves work away from it. This module adds the
operational edges a 1000-node deployment needs:

  * z-score detection of acute stragglers (vs the fleet's posterior mix),
  * quarantine (weight -> 0) after repeated offenses, with probation retries,
  * hard-failure handling (missed heartbeat -> elastic removal).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Sequence

import numpy as np

from .balancer import UncertaintyAwareBalancer

__all__ = ["StragglerPolicy"]


@dataclass
class StragglerPolicy:
    balancer: UncertaintyAwareBalancer
    z_threshold: float = 3.0          # acute-straggler z score
    quarantine_after: int = 3         # offenses before weight->0
    probation_period: int = 20        # steps before a quarantined node retries
    offenses: Dict[int, int] = field(default_factory=dict)
    quarantined: Dict[int, int] = field(default_factory=dict)  # idx -> step
    step: int = 0

    def record(self, durations: Sequence[float], work: Sequence[float]) -> List[int]:
        """Feed observations; returns indices flagged as acute stragglers."""
        self.step += 1
        self.balancer.observe(durations, work)
        mus, sigmas = self.balancer.estimates()
        d = np.asarray(durations, np.float64)
        w = np.asarray(work, np.float64)
        flagged = []
        for i in range(len(d)):
            if w[i] <= 0:
                continue
            rate = d[i] / w[i]
            z = (rate - mus[i]) / max(sigmas[i], 1e-9)
            if z > self.z_threshold:
                self.offenses[i] = self.offenses.get(i, 0) + 1
                flagged.append(i)
                if self.offenses[i] >= self.quarantine_after:
                    self.quarantined[i] = self.step
            else:
                self.offenses[i] = max(0, self.offenses.get(i, 0) - 1)
        # probation: let quarantined nodes back in for re-evaluation
        for i, since in list(self.quarantined.items()):
            if self.step - since >= self.probation_period:
                del self.quarantined[i]
                self.offenses[i] = 0
        return flagged

    def weights(self) -> np.ndarray:
        w = self.balancer.weights()
        for i in self.quarantined:
            w[i] = 0.0
        s = w.sum()
        return w / s if s > 0 else np.full_like(w, 1.0 / len(w))

    def assign(self, total_units: int) -> np.ndarray:
        from .balancer import integerize
        return integerize(self.weights(), total_units)

    def fail(self, idx: int):
        """Hard failure (missed heartbeat): remove the channel entirely."""
        self.balancer.remove_channel(idx)
        self.offenses = {i - (i > idx): c for i, c in self.offenses.items() if i != idx}
        self.quarantined = {i - (i > idx): s for i, s in self.quarantined.items()
                            if i != idx}

    def join(self, prior_mean=None):
        """Elastic scale-up."""
        self.balancer.add_channel(prior_mean)
