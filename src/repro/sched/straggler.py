"""Straggler detection & mitigation policy on top of the balancer.

The paper's mechanism *is* the mitigation: a slowing channel's posterior mean
rises and the frontier moves work away from it. This module adds the
operational edges a 1000-node deployment needs:

  * z-score detection of acute stragglers (vs the fleet's posterior mix),
  * two mitigation modes:
      - ``"quarantine"``: weight -> 0 after repeated offenses, with probation
        retries (the blunt classic);
      - ``"drift"``: straggler-aware frontiers — a detected straggler is NOT
        dropped; it gets the ``drift`` completion-time family with a
        per-channel drift rate estimated from its observed slowdown, so the
        solver prices the straggle into the survival integral and keeps the
        (discounted) capacity enlisted. Channels that behave again decay
        back to rho=0, i.e. the plain normal family.
  * hard-failure handling (missed heartbeat -> elastic removal).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from ..core import Drift
from .balancer import UncertaintyAwareBalancer

__all__ = ["StragglerPolicy"]


@dataclass
class StragglerPolicy:
    balancer: UncertaintyAwareBalancer
    z_threshold: float = 3.0          # acute-straggler z score
    quarantine_after: int = 3         # offenses before weight->0 (quarantine mode)
    probation_period: int = 20        # steps before a quarantined node retries
    mitigation: str = "quarantine"    # "quarantine" | "drift"
    drift_decay: float = 0.5          # per-clean-step multiplicative rho decay
    max_rho: float = 4.0              # cap on the estimated drift rate
    offenses: Dict[int, int] = field(default_factory=dict)
    quarantined: Dict[int, int] = field(default_factory=dict)  # idx -> step
    drift_rhos: Dict[int, float] = field(default_factory=dict)  # idx -> rho
    failed: set = field(default_factory=set)   # soft-failed (recoverable)
    step: int = 0
    _sim: object = field(default=None, repr=False)

    def __post_init__(self):
        if self.mitigation not in ("quarantine", "drift"):
            raise ValueError(f"mitigation must be 'quarantine' or 'drift', "
                             f"got {self.mitigation!r}")

    def record(self, durations: Sequence[float], work: Sequence[float]) -> List[int]:
        """Feed observations; returns indices flagged as acute stragglers."""
        self.step += 1
        self.balancer.observe(durations, work)
        mus, sigmas = self.balancer.estimates()
        d = np.asarray(durations, np.float64)
        w = np.asarray(work, np.float64)
        flagged = []
        for i in range(len(d)):
            if w[i] <= 0:
                continue
            rate = d[i] / w[i]
            z = (rate - mus[i]) / max(sigmas[i], 1e-9)
            if z > self.z_threshold:
                self.offenses[i] = self.offenses.get(i, 0) + 1
                flagged.append(i)
                if self.mitigation == "drift":
                    # estimated per-unit-work drift: the observed mean excess
                    # over the posterior, as a fraction of the posterior mean
                    # (matches the drift family's E[T] = w mu (1 + rho w / 2)
                    # with the observed share). EMA over repeat offenses.
                    excess = max(rate / max(mus[i], 1e-9) - 1.0, 0.0)
                    rho_obs = min(2.0 * excess / max(w[i], 1e-6), self.max_rho)
                    old = self.drift_rhos.get(i, 0.0)
                    self.drift_rhos[i] = min(0.5 * old + 0.5 * rho_obs,
                                             self.max_rho)
                elif self.offenses[i] >= self.quarantine_after:
                    self.quarantined[i] = self.step
            else:
                self.offenses[i] = max(0, self.offenses.get(i, 0) - 1)
                if i in self.drift_rhos:
                    # behaving again: decay the priced-in drift toward normal
                    rho = self.drift_rhos[i] * self.drift_decay
                    if rho < 1e-3:
                        del self.drift_rhos[i]
                    else:
                        self.drift_rhos[i] = rho
        # probation: let quarantined nodes back in for re-evaluation
        for i, since in list(self.quarantined.items()):
            if self.step - since >= self.probation_period:
                del self.quarantined[i]
                self.offenses[i] = 0
        return flagged

    def family(self) -> Optional[Drift]:
        """The Drift family pricing current stragglers, or None when clean."""
        if self.mitigation != "drift" or not self.drift_rhos:
            return None
        rho = np.zeros(self.balancer.num_channels, np.float32)
        for i, r in self.drift_rhos.items():
            if i < rho.shape[0]:
                rho[i] = r
        return Drift(rho)

    def weights(self) -> np.ndarray:
        fam = self.family()
        w = self.balancer.weights(family=fam) if fam is not None \
            else self.balancer.weights()
        for i in self.quarantined:
            w[i] = 0.0
        for i in self.failed:
            w[i] = 0.0
        s = w.sum()
        return w / s if s > 0 else np.full_like(w, 1.0 / len(w))

    def assign(self, total_units: int) -> np.ndarray:
        from .balancer import integerize
        return integerize(self.weights(), total_units)

    def fail(self, idx: int, remove: bool = True):
        """Channel failure. ``remove=True`` (missed heartbeat, default) is
        the elastic path: the channel and its posterior are deleted and every
        index above shifts down. ``remove=False`` is a *soft* failure — the
        channel keeps its posterior and index but receives zero weight from
        :meth:`weights` until :meth:`recover`; this is the path the sim's
        churn schedules drive, where a failed node is expected back."""
        if not remove:
            self.failed.add(int(idx))
            if self._sim is not None:
                self._sim.inject_failure(idx)
            return
        self.balancer.remove_channel(idx)
        self.offenses = {i - (i > idx): c for i, c in self.offenses.items() if i != idx}
        self.quarantined = {i - (i > idx): s for i, s in self.quarantined.items()
                            if i != idx}
        self.drift_rhos = {i - (i > idx): r for i, r in self.drift_rhos.items()
                           if i != idx}
        self.failed = {i - (i > idx) for i in self.failed if i != idx}

    def recover(self, idx: int):
        """Re-admit a soft-failed channel (posterior intact, weight > 0 on
        the next tick)."""
        self.failed.discard(int(idx))
        if self._sim is not None:
            self._sim.recover(idx)

    def bind_sim(self, sim):
        """Two-way wiring to a :class:`sim.cluster.ClusterSim`: ``fail(idx,
        remove=False)`` / ``recover(idx)`` propagate to the sim's failure
        flags, and :meth:`sync_with_sim` pulls sim-side churn (schedules,
        direct ``inject_failure`` calls) back into the policy."""
        self._sim = sim

    def sync_with_sim(self) -> set:
        """Adopt the bound sim's current failure flags as the soft-fail set.

        Call once per tick after ``run_step`` so churn-schedule events the
        policy never saw (the sim killed a node mid-trace) still zero that
        channel's weight on the next decision. Returns the new set."""
        if self._sim is None:
            raise RuntimeError("no sim bound; call bind_sim(sim) first")
        self.failed = {i for i, c in enumerate(self._sim.channels)
                       if getattr(c, "failed", False)}
        return set(self.failed)

    def join(self, prior_mean=None):
        """Elastic scale-up."""
        self.balancer.add_channel(prior_mean)
