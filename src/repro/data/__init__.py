"""Deterministic synthetic data pipeline."""
from .pipeline import Batch, SyntheticStream

__all__ = ["Batch", "SyntheticStream"]
