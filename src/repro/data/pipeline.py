"""Deterministic synthetic data pipeline.

Stateless-by-construction: the batch for global step ``t`` is a pure function
of (seed, t), so checkpoint resume and elastic re-sharding need only the step
counter — no cursor files, no skew between restarted workers. Each host slices
its shard of the global batch by (host_id, num_hosts).

The token stream is a mixture of Zipf-distributed ids with short repeated
motifs so tiny models have learnable structure (loss visibly decreases in
examples/train_partitioned.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from ..configs.base import ModelConfig

__all__ = ["SyntheticStream", "Batch"]


@dataclass(frozen=True)
class Batch:
    tokens: np.ndarray                 # (B, S) int32 inputs
    labels: np.ndarray                 # (B, S) int32 targets (-1 = masked)
    extra_embeds: Optional[np.ndarray] = None  # (B, Np/F, d) modality stub


class SyntheticStream:
    def __init__(self, cfg: ModelConfig, seq_len: int, global_batch: int,
                 seed: int = 0, host_id: int = 0, num_hosts: int = 1):
        assert global_batch % num_hosts == 0
        self.cfg = cfg
        self.seq = seq_len
        self.gb = global_batch
        self.lb = global_batch // num_hosts
        self.seed = seed
        self.host_id = host_id

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_id]))

    def batch_at(self, step: int) -> Batch:
        cfg = self.cfg
        rng = self._rng(step)
        V = cfg.vocab_size
        S = self.seq + 1
        # zipf-ish marginal + motif repetition for learnable structure
        base = rng.zipf(1.3, size=(self.lb, S)).astype(np.int64) % V
        motif_len = 8
        motif = rng.integers(0, V, size=(self.lb, motif_len))
        reps = S // (2 * motif_len)
        for r in range(reps):
            start = 2 * motif_len * r + motif_len
            base[:, start:start + motif_len] = motif
        tokens = base[:, :-1].astype(np.int32)
        labels = base[:, 1:].astype(np.int32)

        extra = None
        if cfg.num_patches:
            extra = rng.standard_normal(
                (self.lb, cfg.num_patches, cfg.d_model)).astype(np.float32)
            pad = np.full((self.lb, cfg.num_patches), -1, np.int32)
            labels = np.concatenate([pad, labels], axis=1)  # no loss on patches
        elif cfg.is_encoder_decoder:
            extra = rng.standard_normal(
                (self.lb, cfg.encoder_seq, cfg.d_model)).astype(np.float32)
        return Batch(tokens=tokens, labels=labels, extra_embeds=extra)
