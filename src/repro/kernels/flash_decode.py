"""Flash-decode Pallas kernel: single-token attention over a long KV cache.

The §Perf decode analysis (EXPERIMENTS.md) shows XLA-naive decode is
memory-bound at <0.1% of roofline because the (B, Hkv, G, S) score chain
materializes in HBM per layer. This kernel streams the cache through VMEM in
blocks with running max/sum (online softmax) — HBM traffic collapses to one
read of the cache plus O(B*H*d) — the ~70x analytic headroom claimed there.

Layout: q (B, Hkv, G, D) [G = grouped query heads per kv head],
k/v (B, Hkv, S, D), valid (S,) slot-validity mask. Grid (B, Hkv, S/block):
the cache-block axis iterates sequentially; scratch carries the (G, D) f32
accumulator and the (G, 1) running max / normalizer, finalized on the last
block. D and block sizes should be 128-multiples on real TPUs (MXU/lane
alignment); interpret mode (CPU tests) accepts any shape.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_decode"]

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, valid_ref, o_ref, m_scr, l_scr, acc_scr,
                   *, sm_scale: float, num_blocks: int):
    ib = pl.program_id(2)

    @pl.when(ib == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)          # (G, D)
    k = k_ref[0, 0].astype(jnp.float32)          # (bs, D)
    v = v_ref[0, 0].astype(jnp.float32)          # (bs, D)
    ok = valid_ref[...]                          # (bs,)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * sm_scale
    s = jnp.where(ok[None, :], s, NEG_INF)       # (G, bs)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    dead = m_new <= NEG_INF * 0.5
    p = jnp.exp(s - jnp.where(dead, 0.0, m_new))
    p = jnp.where(ok[None, :], p, 0.0)
    alpha = jnp.where(m_prev <= NEG_INF * 0.5, 0.0,
                      jnp.exp(m_prev - jnp.where(dead, 0.0, m_new)))
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ib == num_blocks - 1)
    def _done():
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("sm_scale", "block_s", "interpret"))
def flash_decode(q, k, v, valid, *, sm_scale=None, block_s: int = 512,
                 interpret: bool = False):
    """q: (B, Hkv, G, D); k, v: (B, Hkv, S, D); valid: (S,) bool.

    Returns (B, Hkv, G, D). S must divide block_s (callers pad the ring
    buffer; cache lengths here are powers of two).
    """
    B, Hkv, G, D = q.shape
    S = k.shape[2]
    block_s = min(block_s, S)
    assert S % block_s == 0, (S, block_s)
    nb = S // block_s
    scale = sm_scale if sm_scale is not None else 1.0 / (D ** 0.5)

    kernel = functools.partial(_decode_kernel, sm_scale=scale, num_blocks=nb)
    return pl.pallas_call(
        kernel,
        grid=(B, Hkv, nb),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, ib: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_s, D), lambda b, h, ib: (b, h, ib, 0)),
            pl.BlockSpec((1, 1, block_s, D), lambda b, h, ib: (b, h, ib, 0)),
            pl.BlockSpec((block_s,), lambda b, h, ib: (ib,)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, ib: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, valid)
