"""block_f autotuning for the frontier kernels.

PR 1 hard-coded ``block_f=128`` for every launch. That was safe when a
program's working set was one (block_f, T) survival tile plus a (block_f, K)
weight tile; the fused moments+gradient kernel holds ~3x that (two per-channel
accumulators and two (block_f, K) gradient outputs live in the same VMEM
tile), so the right block size now depends on (K, num_t, backend, fused) —
too big overflows VMEM on TPU (or blows the per-block peak-memory budget of
the chunked XLA path on CPU), too small wastes launches on grid overhead.

Three layers, cheapest first:

1. A VMEM/working-set **budget model** (:func:`pick_block_f`) — pure
   arithmetic, used whenever ``ops.frontier_moments`` is called without an
   explicit ``block_f``. Deterministic per shape, safe to consult at trace
   time inside jit.
2. An **in-process cache** keyed by ``(F, K, num_t, backend, fused, dist_id)``
   so the model (or a sweep result) is computed once per process.
3. A **timed sweep** (:func:`sweep`) over ``block_f in {32..512}`` x the
   requested ``num_t`` that benchmarks the real kernel on synthetic data and
   persists the winner to ``experiments/bench/autotune_cache.json`` — run by
   ``benchmarks/cluster_scale.py`` (and ``scripts/bench_smoke.sh``) so tuned
   configs survive across processes and ride along in the repo.

The completion-time family is part of the key AND the model: the fused
adjoint carries two per-channel accumulator pairs for the ``drift`` family
(vs one for the scale-like families), and the ``empirical`` mixture streams
3C extra CDF tiles per channel — different working sets, different safe
block sizes. So is the launch *mode*: ``fwd`` (forward moments only),
``grad`` (fused W-adjoints — the PGD tick) and ``pgrad`` (full-parameter
adjoints for the estimation loop: up to six accumulator pairs plus six more
(block_f, K) output tiles, the largest working set of the three). Cache keys
are versioned (``v3:``); v2 (family-aware, fused-flag) keys and legacy
un-versioned keys from the pre-family schema are migrated on load — v2
``fused0/fused1`` map to ``fwd``/``grad`` (``pgrad`` shapes never existed
before v3), un-versioned keys additionally pick up the normal family — so an
existing JSON cache survives both schema bumps.
"""
from __future__ import annotations

import json
import logging
import os
import re
import threading
import time
from typing import Dict, Optional, Sequence, Tuple

_log = logging.getLogger(__name__)

__all__ = ["BLOCK_F_CANDIDATES", "ROW_BUCKETS", "vmem_bytes", "pick_block_f",
           "bucket_rows", "lookup", "sweep", "clear_cache",
           "default_cache_path", "cache_state", "load_cache_state"]

BLOCK_F_CANDIDATES: Tuple[int, ...] = (32, 64, 128, 256, 512)

# serving row-count buckets: the continuous-batching engine pads its stacked
# row axis UP to one of these before the launch (see bucket_rows)
ROW_BUCKETS: Tuple[int, ...] = (8, 16, 32, 64, 128, 256, 512, 1024, 2048,
                                4096)

# v5e-class VMEM is ~16 MB/core; leave headroom for double buffering and the
# compiler's own temporaries
_VMEM_BUDGET_BYTES = int(16 * 1024 * 1024 * 0.75)
# the XLA path is bounded by host/device peak memory per lax.map block, not
# VMEM — a much looser working-set ceiling (the (bf, T, K) intermediates)
_XLA_BLOCK_BUDGET_BYTES = 1024 * 1024 * 1024

_KEY_VERSION = "v3"  # v3: mode-aware keys (fwd | grad | pgrad)

_CACHE: Dict[str, dict] = {}
_JSON_LOADED: set = set()


def default_cache_path() -> str:
    root = os.path.dirname(os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__)))))
    return os.path.join(root, "experiments", "bench", "autotune_cache.json")


def _mode(fused: bool, params: bool) -> str:
    if not fused:
        return "fwd"
    return "pgrad" if params else "grad"


def _key(F: int, K: int, num_t: int, backend: str, fused: bool,
         dist_id: str = "normal", params: bool = False,
         stacked: bool = False) -> str:
    # the stacked (per-row statistics) layout holds 2+E more (bf, K) input
    # tiles per program; its suffix is additive so every existing v3 key
    # stays valid verbatim — no migration needed
    suffix = ":stk" if stacked else ""
    return (f"{_KEY_VERSION}:{backend}:F{F}:K{K}:T{num_t}"
            f":mode{_mode(fused, params)}:fam{dist_id}{suffix}")


_V2_RE = re.compile(r"^v2:(?P<body>.*):fused(?P<fused>[01]):fam(?P<fam>\w+)$")
_LEGACY_RE = re.compile(r"^(?P<body>[^:]+:F\d+:K\d+:T\d+):fused(?P<fused>[01])$")


def _migrate_key(k: str) -> str:
    """Lift a v2 (fused-flag) or legacy (pre-family, un-versioned) key to v3.

    v2 ``fused0``/``fused1`` become ``modefwd``/``modegrad`` (the pgrad mode
    is new in v3, so no v2 entry can alias it); un-versioned legacy keys are
    additionally normal-family.
    """
    if k.startswith(f"{_KEY_VERSION}:"):
        return k
    m = _V2_RE.match(k)
    if m:
        mode = "grad" if m.group("fused") == "1" else "fwd"
        return (f"{_KEY_VERSION}:{m.group('body')}:mode{mode}"
                f":fam{m.group('fam')}")
    m = _LEGACY_RE.match(k)
    if m:
        mode = "grad" if m.group("fused") == "1" else "fwd"
        return f"{_KEY_VERSION}:{m.group('body')}:mode{mode}:famnormal"
    return k  # unknown schema: keep verbatim (never collides with v3 keys)


def _grad_acc_pairs(dist_id: str, params: bool = False) -> int:
    # local import: distributions sits above kernels in the package DAG but
    # this module must stay importable before repro.core finishes init
    from repro.core.distributions import family_features
    use_1, use_t, use_z = family_features(dist_id, params=params)
    return int(use_1) + int(use_t) + int(use_z)


def _mix_tiles(dist_id: str) -> int:
    from repro.core.distributions import EMP_COMPONENTS
    # transient per-component z/cdf tiles the mixture family keeps live
    return EMP_COMPONENTS - 1 if dist_id == "empirical" else 0


def vmem_bytes(block_f: int, num_k: int, num_t: int, fused: bool = False,
               dist_id: str = "normal", params: bool = False,
               stacked: bool = False) -> int:
    """Working-set model of one kernel program, in bytes (f32).

    Forward: W/means/stds (bf, K) tiles + ts/logF/surv/tsurv (bf, T) tiles.
    Fused adds the per-channel accumulators and both gradient outputs in
    (bf, K) plus the weighted-CDF / t(t-mu) work tiles in (bf, T). The family
    moves both axes: ``drift`` carries FOUR accumulators (P0/P1/Pv0/Pv1)
    where the scale-like families carry two, and the ``empirical`` mixture
    holds C-1 extra per-component tiles live per channel step — which is why
    the family is part of the autotune key. Full-parameter mode (``params``)
    widens the basis again (the z feature of lognormal and defective: up to
    three accumulator pairs, six live (bf, K) accumulators — defective's
    {1, t, z} basis is the widest of any family) and adds the six
    channel-statistic gradient output tiles — the ``pgrad`` key mode. The ``stacked``
    (per-row statistics) layout grows the mus/sigmas tiles from (1, K) to
    (bf, K) and the extra tile to (E, bf, K): 1 + E more (bf, K)-equivalents
    per program (one of the two stat tiles was already counted).
    """
    acc = 2 * _grad_acc_pairs(dist_id, params)  # accumulators + grad outputs
    per_fk = (6 + acc + (6 if params else 0)) if fused else 3
    if stacked:
        from repro.core.distributions import extra_rows
        per_fk += 1 + extra_rows(dist_id)
    per_ft = (6 if fused else 4) + _mix_tiles(dist_id)
    return 4 * block_f * (per_fk * num_k + per_ft * num_t)


def _xla_block_bytes(block_f: int, num_k: int, num_t: int, fused: bool,
                     dist_id: str = "normal", params: bool = False) -> int:
    # the pure-jnp path materializes (bf, T, K) zscore/cdf/phi intermediates;
    # the mixture family adds per-component copies of them, the z-feature
    # accumulators of full-parameter mode one more. The stacked layout's
    # extra stat rows are (bf, K) — noise against these and not modeled.
    live = (5 if fused else 3) + _mix_tiles(dist_id) + (1 if params else 0)
    return 4 * block_f * num_t * num_k * live


def _fits(block_f: int, K: int, num_t: int, backend: str, fused: bool,
          dist_id: str = "normal", params: bool = False,
          stacked: bool = False) -> bool:
    if backend == "xla":
        return (_xla_block_bytes(block_f, K, num_t, fused, dist_id, params)
                <= _XLA_BLOCK_BUDGET_BYTES)
    return (vmem_bytes(block_f, K, num_t, fused, dist_id, params, stacked)
            <= _VMEM_BUDGET_BYTES)


def pick_block_f(F: int, K: int, num_t: int, backend: str = "xla",
                 fused: bool = False,
                 candidates: Sequence[int] = BLOCK_F_CANDIDATES,
                 dist_id: str = "normal", params: bool = False,
                 stacked: bool = False) -> int:
    """Largest candidate block_f that fits the backend's budget model."""
    feasible = [bf for bf in candidates
                if _fits(bf, K, num_t, backend, fused, dist_id, params,
                         stacked)]
    pick = max(feasible) if feasible else min(candidates)
    return max(min(pick, F), 1)


def bucket_rows(F: int, buckets: Sequence[int] = ROW_BUCKETS) -> int:
    """Round a stacked row count UP to the next serving working-set bucket.

    A continuous-batching tick stacks a fluctuating number of
    (instance, stage) rows per family launch; keying the ``:stk`` cache —
    and the jit cache above it — at the raw count would re-key (and
    recompile) nearly every tick as instances admit and retire. Callers pad
    the row axis to the bucket by repeating a real row and slice the pad
    rows off after the launch, so every family x fidelity keeps at most one
    compiled program per bucket. Counts past the last bucket pass through
    unchanged (that scale should be sharded, not padded further).
    """
    F = int(F)
    for b in buckets:
        if F <= b:
            return int(b)
    return F


def _load_json(cache_path: str) -> None:
    if cache_path in _JSON_LOADED:
        return
    _JSON_LOADED.add(cache_path)
    try:
        with open(cache_path) as f:
            disk = json.load(f)
    except (OSError, ValueError):
        return
    for k, v in disk.items():
        k = _migrate_key(k)
        # sweep results on disk outrank anything model-derived in-process
        if k not in _CACHE or _CACHE[k].get("source") != "sweep":
            _CACHE[k] = v


# per-thread record of how the most recent lookup resolved, read by the
# kernel-launch span emitters (repro.obs) — a return-channel attribute, so
# lookup's signature and call sites stay unchanged
_LOOKUP_LOCAL = threading.local()


def last_outcome() -> str:
    """``"hit"`` | ``"miss"`` for this thread's latest :func:`lookup`."""
    return getattr(_LOOKUP_LOCAL, "outcome", "none")


def lookup(F: int, K: int, num_t: int, backend: str = "xla",
           fused: bool = False, cache_path: Optional[str] = None,
           dist_id: str = "normal", params: bool = False,
           stacked: bool = False) -> int:
    """block_f for a launch shape: in-process cache -> JSON cache -> model.

    This is what ``ops.frontier_moments`` consults when ``block_f`` is not
    explicitly passed. Never runs a timed sweep itself (deterministic and
    trace-safe); :func:`sweep` feeds better-than-model entries into the same
    caches. ``params`` selects the full-parameter-adjoint (``pgrad``) launch
    mode the estimation loop's custom VJP uses; ``stacked`` the per-row
    statistics layout (its own key suffix — a block tuned for broadcast
    stats must not be handed to the larger stacked working set).
    """
    _load_json(cache_path or default_cache_path())
    key = _key(F, K, num_t, backend, fused, dist_id, params, stacked)
    hit = _CACHE.get(key)
    if hit is not None:
        _LOOKUP_LOCAL.outcome = "hit"
        return max(min(int(hit["block_f"]), F), 1)
    _LOOKUP_LOCAL.outcome = "miss"
    bf = pick_block_f(F, K, num_t, backend, fused, dist_id=dist_id,
                      params=params, stacked=stacked)
    _log.debug(
        "autotune cache miss: F=%d K=%d num_t=%d backend=%s dist_id=%s "
        "mode=%s stacked=%s -> model block_f=%d (run autotune.sweep to "
        "replace the model pick with a timed one)",
        F, K, num_t, backend, dist_id, _mode(fused, params), stacked, bf)
    _CACHE[key] = {"block_f": bf, "source": "model"}
    return bf


def sweep(F: int, K: int, num_t: int, backend: str = "xla",
          fused: bool = False, repeats: int = 2, seed: int = 0,
          candidates: Sequence[int] = BLOCK_F_CANDIDATES,
          cache_path: Optional[str] = None, dist_id: str = "normal",
          params: bool = False) -> dict:
    """Time the real kernel across feasible block_f values; cache the winner.

    Returns the winning entry ``{"block_f", "source": "sweep", "us", "timings"}``
    and persists it (in-process + JSON) under
    ``(F, K, num_t, backend, fused, dist_id, params)``.
    """
    import jax
    import numpy as np

    from repro.core.distributions import Drift, extra_rows
    from . import ops

    rng = np.random.default_rng(seed)
    e = rng.exponential(size=(F, K))
    W = (e / e.sum(1, keepdims=True)).astype(np.float32)
    mus = rng.uniform(10, 40, K).astype(np.float32)
    sgs = (mus * rng.uniform(0.02, 0.3, K)).astype(np.float32)
    if dist_id == "drift":
        family = Drift(rng.uniform(0.0, 0.5, K).astype(np.float32))
    elif dist_id == "empirical":
        from repro.core.distributions import Empirical
        family = Empirical.from_samples(
            rng.normal(mus[None, :], sgs[None, :], size=(256, K)))
    elif dist_id == "defective":
        from repro.core.distributions import Defective
        family = Defective(rng.uniform(0.0, 0.3, K).astype(np.float32))
    else:
        family = dist_id

    feasible = [bf for bf in candidates
                if _fits(bf, K, num_t, backend, fused, dist_id, params)]
    if not feasible:
        feasible = [min(candidates)]
    timings = {}
    for bf in feasible:
        def run(bf=bf):
            if fused:
                out = ops.frontier_moments_with_grads(
                    W, mus, sgs, num_t=num_t, impl=backend, block_f=bf,
                    family=family, param_grads=params)
            else:
                out = ops.frontier_moments(
                    W, mus, sgs, num_t=num_t, impl=backend, block_f=bf,
                    family=family)
            jax.block_until_ready(out)
        run()  # compile + warm
        samples = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            run()
            samples.append((time.perf_counter() - t0) * 1e6)
        timings[bf] = sorted(samples)[len(samples) // 2]
    best_bf = min(timings, key=timings.get)
    entry = {"block_f": int(best_bf), "source": "sweep",
             "us": float(timings[best_bf]),
             "timings": {str(k): float(v) for k, v in timings.items()}}
    key = _key(F, K, num_t, backend, fused, dist_id, params)
    _CACHE[key] = entry
    path = cache_path or default_cache_path()
    disk = {}
    try:
        with open(path) as f:
            # normalize any legacy keys on rewrite so the file converges to v2
            disk = {_migrate_key(k): v for k, v in json.load(f).items()}
    except (OSError, ValueError):
        pass
    disk[key] = entry
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(disk, f, indent=1, sort_keys=True)
    return entry


def clear_cache() -> None:
    """Drop the in-process cache (tests use this to exercise JSON round-trips)."""
    _CACHE.clear()
    _JSON_LOADED.clear()


def cache_state() -> dict:
    """Snapshot the in-process cache for a pipeline checkpoint manifest.

    The kill/restore tick-parity contract (see ``ckpt.store``) includes the
    autotune cache: a restored replica that re-derives block_f from the model
    while the original process held a sweep result would launch a different
    kernel shape — numerically identical, but a different compile and a
    different performance cliff. Snapshotting the cache (entries are small
    JSON-able dicts) makes the restored process pick identical launches.
    """
    return {k: dict(v) for k, v in _CACHE.items()}


def load_cache_state(state: dict) -> None:
    """Restore a :func:`cache_state` snapshot (keys migrated like the JSON
    cache; sweep entries outrank model-derived in-process ones)."""
    for k, v in state.items():
        k = _migrate_key(k)
        if k not in _CACHE or _CACHE[k].get("source") != "sweep":
            _CACHE[k] = dict(v)
