"""Flash attention (causal / sliding-window / GQA) as a Pallas TPU kernel.

TPU adaptation notes (vs the CUDA flash-attention formulation):
  * Tiling is chosen for VMEM residency and MXU alignment: block_q x head_dim
    and block_k x head_dim tiles with block sizes that are multiples of 128 on
    the lane dimension (head_dim is padded to 128 by callers; blocks default
    to 128x128 so every matmul hits the 128x128 systolic array natively).
  * The softmax running max/sum rescaling lives in f32 VMEM scratch that
    persists across the innermost (kv) grid dimension — Pallas TPU guarantees
    sequential iteration over the trailing grid axis, which replaces the CUDA
    per-CTA loop over KV tiles.
  * GQA is expressed in the BlockSpec index_map (kv head = q head // group),
    so no repeated K/V materialization in HBM.

The grid is (batch, q_heads, num_q_blocks, num_kv_blocks).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention"]

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 sm_scale: float, causal: bool, window: Optional[int],
                 block_q: int, block_k: int, num_kv_blocks: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)  # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)  # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)  # (bk, d)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * sm_scale  # (bq, bk)

    qpos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                      # (bq, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    # guard fully-masked rows (all NEG_INF): exp(NEG_INF - NEG_INF) would be 1
    row_dead = m_new <= NEG_INF * 0.5
    p = jnp.exp(s - jnp.where(row_dead, 0.0, m_new))
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - jnp.where(row_dead, 0.0, m_new))
    alpha = jnp.where(m_prev <= NEG_INF * 0.5, 0.0, alpha)

    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ik == num_kv_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "sm_scale",
                                             "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: Optional[int] = None,
                    sm_scale: Optional[float] = None, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False):
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D) with Hq % Hkv == 0.

    Rectangular Sq != Sk supported only for non-causal, window=None use
    (cross-attention); sequence lengths must divide the block sizes.
    """
    B, Hq, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    assert Hq % Hkv == 0, (Hq, Hkv)
    assert Sq == Sk or (not causal and window is None), "rectangular => non-causal"
    group = Hq // Hkv
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, Sk, block_q, block_k)
    nq, nk = Sq // block_q, Sk // block_k
    scale = sm_scale if sm_scale is not None else 1.0 / (D ** 0.5)

    kernel = functools.partial(
        _attn_kernel, sm_scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, num_kv_blocks=nk)

    return pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, iq, ik, g=group: (b, h // g, ik, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, iq, ik, g=group: (b, h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
