"""Mamba2 SSD (state-space duality) chunked scan as a Pallas TPU kernel.

TPU adaptation of the Mamba2 block decomposition (Dao & Gu 2024): the sequence
is tiled into chunks of length L. Within a chunk the output is an attention-
like (L x L) masked matmul (MXU work); across chunks a (P x N) state is carried
in VMEM scratch through the sequential trailing grid axis — the TPU-native
replacement for the CUDA warp-level scan.

    y_t = exp(cum_t) * C_t . state_prev                      (inter-chunk)
        + sum_{s<=t} exp(cum_t - cum_s) dt_s (C_t.B_s) x_s   (intra-chunk)
    state' = exp(cum_L) state_prev + sum_s exp(cum_L - cum_s) dt_s B_s (x) x_s

with cum_t the inclusive cumsum of a_t = dt_t * A_h (A negative => all exps
<= 1, numerically safe in f32).

Grid: (batch, heads, num_chunks); chunk axis iterates sequentially so the
state scratch persists. Blocks keep the (L, N) / (L, P) tiles MXU-aligned
(L, N, P multiples of 128/64 per v5e tiling).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ssd_scan"]


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, dskip_ref, y_ref, state_scr, *,
                chunk: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, :, 0, :].astype(jnp.float32)    # (L, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)     # (L,)
    A = a_ref[0].astype(jnp.float32)             # scalar
    Bm = b_ref[0, :, 0, :].astype(jnp.float32)   # (L, N)
    Cm = c_ref[0, :, 0, :].astype(jnp.float32)   # (L, N)
    Dh = dskip_ref[0].astype(jnp.float32)        # scalar

    a = dt * A                                   # (L,)
    cum = jnp.cumsum(a)                          # inclusive, (L,)

    state_prev = state_scr[...]                  # (P, N)

    # inter-chunk: exp(cum_t) * C_t . state_prev
    y_inter = jnp.exp(cum)[:, None] * jax.lax.dot_general(
        Cm, state_prev, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)  # (L, P)

    # intra-chunk attention-like term
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (L, L) = C_t . B_s
    tpos = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    spos = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    causal = tpos >= spos
    # exponent clamped at 0: exact on the causal region (cum is decreasing),
    # prevents masked-entry overflow (and NaN cotangents on the XLA twin)
    decay = jnp.exp(jnp.minimum(cum[:, None] - cum[None, :], 0.0))
    g = jnp.where(causal, cb * decay * dt[None, :], 0.0)  # (L, L)
    y_intra = jax.lax.dot_general(g, x, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)  # (L, P)

    y_ref[0, :, 0, :] = (y_inter + y_intra + Dh * x).astype(y_ref.dtype)

    # state update
    w = jnp.exp(cum[-1] - cum) * dt                       # (L,)
    state_new = jnp.exp(cum[-1]) * state_prev + jax.lax.dot_general(
        x, Bm * w[:, None], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)               # (P, N)
    state_scr[...] = state_new


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, Bm, Cm, D_skip, *, chunk: int = 128, interpret: bool = False):
    """Chunked SSD scan. Shapes as in ref.ssd_scan_ref; S % chunk == 0.

    x: (B,S,H,P); dt: (B,S,H); A,D_skip: (H,); Bm,Cm: (B,S,G,N), H % G == 0.
    """
    B, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    assert H % G == 0
    rep = H // G
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, chunk, 1, N), lambda b, h, c, r=rep: (b, c, h // r, 0)),
            pl.BlockSpec((1, chunk, 1, N), lambda b, h, c, r=rep: (b, c, h // r, 0)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
        ],
        out_specs=pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, H, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, Bm, Cm, D_skip)
