"""Jit'd dispatch wrappers for the kernel package.

Models call these with an ``impl`` string from the run config:

    "xla"              — pure-jnp reference path (CPU dry-run / correctness; XLA
                         fuses these well and it is the portable fallback)
    "pallas"           — compiled Pallas TPU kernel (real-hardware path)
    "pallas_interpret" — Pallas kernel body executed in Python (CPU validation)

The wrappers own padding/shape glue so kernels can assume aligned shapes.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..analysis import sanitize as _san
from ..obs import events as _obs_events
from ..obs import names as _obs_names
from ..obs import trace as _obs
from . import autotune as _at
from . import flash_attention as _fa
from . import flash_decode as _fd
from . import frontier_grid as _fg
from . import rmsnorm as _rn
from . import ssd_scan as _ssd
from . import ref

__all__ = ["attention", "decode_attention", "ssd", "rmsnorm",
           "frontier_moments", "frontier_moments_with_grads", "IMPLS"]

IMPLS = ("xla", "pallas", "pallas_interpret")


def _check(impl: str) -> None:
    if impl not in IMPLS:
        raise ValueError(f"impl must be one of {IMPLS}, got {impl!r}")


def attention(q, k, v, *, causal: bool = True, window: Optional[int] = None,
              sm_scale: Optional[float] = None, impl: str = "xla",
              block_q: int = 128, block_k: int = 128, xla_q_chunk: int = 512):
    """GQA flash attention. q: (B,Hq,S,D); k,v: (B,Hkv,S,D).

    The "xla" path switches to a scan-over-query-chunks formulation beyond
    ``xla_q_chunk`` so long-context cells never materialize (S, S) logits;
    sliding-window configs additionally restrict keys to the band.
    """
    _check(impl)
    if impl == "xla":
        Sq = q.shape[2]
        if Sq <= xla_q_chunk or Sq != k.shape[2] or Sq % xla_q_chunk:
            return ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                           sm_scale=sm_scale)
        return _xla_chunked_attention(q, k, v, causal=causal, window=window,
                                      sm_scale=sm_scale, q_chunk=xla_q_chunk)
    S = q.shape[2]
    bq, bk = min(block_q, S), min(block_k, S)
    if S % bq or S % bk:  # pad sequence to block multiple; extra keys masked by causal
        raise ValueError(f"seq {S} must be divisible by blocks ({bq},{bk})")
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               sm_scale=sm_scale, block_q=bq, block_k=bk,
                               interpret=(impl == "pallas_interpret"))


def _xla_chunked_attention(q, k, v, *, causal, window, sm_scale, q_chunk):
    """Memory-bounded attention in pure XLA: lax.scan over query chunks.

    Peak intermediate is (B, Hq, q_chunk, Skv) instead of (B, Hq, S, S).
    For sliding-window attention only the (window + q_chunk) key band is
    gathered per chunk, making 32k-seq SWA prefill O(S * window).
    """
    import jax

    B, Hq, S, D = q.shape
    Hkv, Dv = k.shape[1], v.shape[-1]
    group = Hq // Hkv
    scale = sm_scale if sm_scale is not None else 1.0 / (D ** 0.5)
    nq = S // q_chunk
    kx = jnp.repeat(k, group, axis=1)
    vx = jnp.repeat(v, group, axis=1)

    band = window + q_chunk if window is not None else None

    def chunk(start, qc):
        qf = qc.astype(jnp.float32)
        qpos = start + jnp.arange(q_chunk)
        if band is not None and band < S:
            kstart = jnp.clip(start - window, 0, S - band)
            kc = jax.lax.dynamic_slice_in_dim(kx, kstart, band, axis=2)
            vc = jax.lax.dynamic_slice_in_dim(vx, kstart, band, axis=2)
            kpos = kstart + jnp.arange(band)
        else:
            kc, vc, kpos = kx, vx, jnp.arange(S)
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kc.astype(jnp.float32)) * scale
        mask = jnp.ones((q_chunk, kpos.shape[0]), bool)
        if causal:
            mask &= qpos[:, None] >= kpos[None, :]
        if window is not None:
            mask &= (qpos[:, None] - kpos[None, :]) < window
        s = jnp.where(mask[None, None], s, -1e30)
        p_ = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bhqk,bhkd->bhqd", p_, vc.astype(jnp.float32)).astype(q.dtype)

    qs = q.reshape(B, Hq, nq, q_chunk, D).transpose(2, 0, 1, 3, 4)
    starts = jnp.arange(nq) * q_chunk
    outs = jax.lax.scan(lambda _, xs: (None, chunk(xs[0], xs[1])), None,
                        (starts, qs))[1]
    return outs.transpose(1, 2, 0, 3, 4).reshape(B, Hq, S, Dv)


def ssd(x, dt, A, Bm, Cm, D_skip, *, chunk: int = 128, impl: str = "xla",
        return_final_state: bool = False):
    """Mamba2 SSD scan. See ref.ssd_scan_ref for shapes.

    return_final_state: also return the (B,H,P,N) state after the last token
    (prefill path; uses the XLA chunked implementation, which carries it).
    """
    _check(impl)
    if return_final_state or impl == "xla":
        return _ssd_xla_chunked(x, dt, A, Bm, Cm, D_skip, chunk=chunk,
                                return_final_state=return_final_state)
    return _ssd.ssd_scan(x, dt, A, Bm, Cm, D_skip, chunk=chunk,
                         interpret=(impl == "pallas_interpret"))


def _ssd_xla_chunked(x, dt, A, Bm, Cm, D_skip, *, chunk: int = 128,
                     return_final_state: bool = False):
    """XLA path: same chunked block decomposition as the kernel, expressed in
    jnp (scan over chunks) — O(S·L) not O(S^2), so long_500k prefill lowers.
    """
    import jax

    B, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    L = min(chunk, S)
    pad = (-S) % L
    if pad:  # dt=0, x=0 padding is exact: padded steps leave state unchanged
        zf = lambda a: jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
        x, dt, Bm, Cm = zf(x), zf(dt), zf(Bm), zf(Cm)
        S_out, S = S, S + pad
    else:
        S_out = S
    nc = S // L
    f32 = jnp.float32

    xf = x.astype(f32).reshape(B, nc, L, H, P)
    dtf = dt.astype(f32).reshape(B, nc, L, H)
    Bh = jnp.repeat(Bm.astype(f32), rep, axis=2).reshape(B, nc, L, H, N)
    Ch = jnp.repeat(Cm.astype(f32), rep, axis=2).reshape(B, nc, L, H, N)
    Af = A.astype(f32)

    a = dtf * Af  # (B,nc,L,H)
    cum = jnp.cumsum(a, axis=2)
    tpos = jnp.arange(L)[:, None]
    spos = jnp.arange(L)[None, :]
    causal = (tpos >= spos)[None, :, :, None]  # (1,L,L,1)

    def chunk_step(state, inp):
        # state: (B,H,P,N); inp per-chunk slices
        xc, dtc, cumc, bc, cc = inp  # (B,L,H,P),(B,L,H),(B,L,H),(B,L,H,N),(B,L,H,N)
        y_inter = jnp.exp(cumc)[..., None] * jnp.einsum("blhn,bhpn->blhp", cc, state)
        cb = jnp.einsum("blhn,bshn->blsh", cc, bc)  # (B,L,L,H)
        # clamp the exponent: cum_t - cum_s <= 0 on the causal region; the
        # masked t<s entries would overflow exp and NaN the where-gradient
        decay = jnp.exp(jnp.minimum(cumc[:, :, None, :] - cumc[:, None, :, :], 0.0))
        g = jnp.where(causal, cb * decay * dtc[:, None, :, :], 0.0)
        y_intra = jnp.einsum("blsh,bshp->blhp", g, xc)
        w = jnp.exp(cumc[:, -1:, :] - cumc) * dtc  # (B,L,H)
        state = (jnp.exp(cumc[:, -1, :])[..., None, None] * state
                 + jnp.einsum("blhp,blhn->bhpn", xc * w[..., None], bc))
        return state, y_inter + y_intra

    state0 = jnp.zeros((B, H, P, N), f32)
    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0), jnp.moveaxis(cum, 1, 0),
          jnp.moveaxis(Bh, 1, 0), jnp.moveaxis(Ch, 1, 0))
    final_state, ys = jax.lax.scan(chunk_step, state0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, H, P)
    y = y + D_skip.astype(f32)[None, None, :, None] * x.astype(f32)
    y = y.astype(x.dtype)[:, :S_out]
    if return_final_state:
        return y, final_state
    return y


def rmsnorm(x, w, *, eps: float = 1e-6, impl: str = "xla"):
    _check(impl)
    if impl == "xla":
        return ref.rmsnorm_ref(x, w, eps=eps)
    return _rn.rmsnorm(x, w, eps=eps, interpret=(impl == "pallas_interpret"))


def _resolve_block_f(F: int, K: int, num_t: int, impl: str,
                     block_f: Optional[int], fused: bool,
                     dist_id: str = "normal", params: bool = False,
                     stacked: bool = False) -> int:
    """Explicit block_f wins; otherwise consult the autotune cache/model."""
    if block_f is not None:
        return max(min(block_f, F), 1)
    return _at.lookup(F, K, num_t, backend=impl, fused=fused, dist_id=dist_id,
                      params=params, stacked=stacked)


def _resolve_family(family, K: int):
    """Lower a family spec to (static dist_id, traced extra array).

    ``extra`` is (E, K) for a shared fleet, or (E, F, K) when the caller
    pre-lowered a per-row stack (the workflow solver's stage axis)."""
    from repro.core.distributions import resolve_family

    dist_id, extra = resolve_family(family, K)
    return dist_id, jnp.asarray(extra, jnp.float32)


def _stack_extra(extra, F: int):
    """Lift a shared (E, K) extra to the per-row (E, F, K) layout."""
    if extra.ndim == 3:
        return extra
    return jnp.broadcast_to(extra[:, None, :],
                            (extra.shape[0], F, extra.shape[1]))


# repro: allow[RPA001] layout-only padding glue: never evaluates a CDF, the
# family rides in the sibling dist_id argument of every caller
def _pad_rows(pad, W, mus, sigmas, extra):
    """Pad the candidate axis with copies of row 0 (sliced off after).

    Per-row statistics (mus.ndim == 2) ride the same padding so padded rows
    stay self-consistent (they recompute row 0's stage under row 0's fleet).
    """
    W = jnp.concatenate([W, jnp.tile(W[:1], (pad, 1))], 0)
    if mus.ndim == 2:
        mus = jnp.concatenate([mus, jnp.tile(mus[:1], (pad, 1))], 0)
        sigmas = jnp.concatenate([sigmas, jnp.tile(sigmas[:1], (pad, 1))], 0)
        extra = jnp.concatenate(
            [extra, jnp.tile(extra[:, :1], (1, pad, 1))], 1)
    return W, mus, sigmas, extra


# repro: allow[RPA001] layout-only chunking glue: reshapes stat tiles for
# lax.map, family dispatch happens in the per-block ref call of the caller
def _row_blocks(bf, W, mus, sigmas, extra):
    """Reshape aligned rows into lax.map blocks + a per-block ref thunk."""
    K = W.shape[1]
    if mus.ndim == 2:
        # stats chunk alongside W; extra goes (E, F, K) -> (nb, bf, E, K)
        xs = (W.reshape(-1, bf, K), mus.reshape(-1, bf, K),
              sigmas.reshape(-1, bf, K),
              jnp.moveaxis(extra, 0, 1).reshape(-1, bf, extra.shape[0], K))
        unpack = lambda b: (b[0], b[1], b[2], jnp.moveaxis(b[3], 1, 0))
    else:
        xs = (W.reshape(-1, bf, K),)
        unpack = lambda b: (b[0], mus, sigmas, extra)
    return xs, unpack


def _moments_fwd(W, mus, sigmas, extra, num_t, impl, bf, z, dist_id):
    """Forward-only batched moments on aligned shapes (bf resolved)."""
    F = W.shape[0]
    pad = (-F) % bf
    if impl == "xla":
        if F <= bf:
            return ref.frontier_grid_ref(W, mus, sigmas, num_t=num_t, z=z,
                                         dist_id=dist_id, extra=extra)
        if pad:
            W, mus, sigmas, extra = _pad_rows(pad, W, mus, sigmas, extra)
        xs, unpack = _row_blocks(bf, W, mus, sigmas, extra)

        def block(b):
            wb, mb, sb, eb = unpack(b)
            return ref.frontier_grid_ref(wb, mb, sb, num_t=num_t, z=z,
                                         dist_id=dist_id, extra=eb)

        mu, var = jax.lax.map(block, xs)
        return mu.reshape(-1)[:F], var.reshape(-1)[:F]
    if pad:
        W, mus, sigmas, extra = _pad_rows(pad, W, mus, sigmas, extra)
    mu, var = _fg.frontier_grid(W, mus, sigmas, extra, num_t=num_t, z=z,
                                block_f=bf, dist_id=dist_id,
                                interpret=(impl == "pallas_interpret"))
    return mu[:F], var[:F]


def _moments_grads(W, mus, sigmas, extra, num_t, impl, bf, z, dist_id,
                   param_grads: bool = False):
    """Fused (mu, var, dmu_dW, dvar_dW[, param adjoints]) on aligned shapes.

    ``param_grads`` switches both backends to the full-parameter launch: six
    extra (F, K) outputs (mus/sigmas/extra-row-0 adjoints of both moments) —
    still ONE kernel launch on the Pallas paths.
    """
    F = W.shape[0]
    pad = (-F) % bf
    if impl == "xla":
        if F <= bf:
            return ref.frontier_grid_with_grads_ref(
                W, mus, sigmas, num_t=num_t, z=z, dist_id=dist_id,
                extra=extra, param_grads=param_grads)
        if pad:
            W, mus, sigmas, extra = _pad_rows(pad, W, mus, sigmas, extra)
        xs, unpack = _row_blocks(bf, W, mus, sigmas, extra)

        def block(b):
            wb, mb, sb, eb = unpack(b)
            return ref.frontier_grid_with_grads_ref(
                wb, mb, sb, num_t=num_t, z=z, dist_id=dist_id,
                extra=eb, param_grads=param_grads)

        outs = jax.lax.map(block, xs)
        K = W.shape[1]
        return tuple(o.reshape(-1)[:F] if o.ndim == 2
                     else o.reshape(-1, K)[:F] for o in outs)
    if pad:
        W, mus, sigmas, extra = _pad_rows(pad, W, mus, sigmas, extra)
    outs = _fg.frontier_grid_with_grads(
        W, mus, sigmas, extra, num_t=num_t, z=z, block_f=bf, dist_id=dist_id,
        interpret=(impl == "pallas_interpret"), param_grads=param_grads)
    return tuple(o[:F] for o in outs)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8))
def _frontier_moments_vjp(W, mus, sigmas, extra, num_t, impl, bfs, z, dist_id):
    return _moments_fwd(W, mus, sigmas, extra, num_t, impl, bfs[0], z, dist_id)


def _frontier_moments_vjp_fwd(W, mus, sigmas, extra, num_t, impl, bfs, z,
                              dist_id):
    # bfs = (forward block_f, pgrad block_f): the full-parameter fused launch
    # holds ~4x the accumulators, so a forward-tuned block can overflow its
    # budget. The VJP's forward pass runs the param_grads kernel — one launch
    # yields every residual the backward needs, W and channel-statistic
    # adjoints alike (the closed estimation loop's differentiation surface).
    outs = _moments_grads(W, mus, sigmas, extra, num_t, impl, bfs[1], z,
                          dist_id, param_grads=True)
    mu, var, dmu, dvar, dmu_m, dvar_m, dmu_s, dvar_s, dmu_e, dvar_e = outs
    return (mu, var), (dmu, dvar, dmu_m, dvar_m, dmu_s, dvar_s,
                       dmu_e, dvar_e, extra)


def _frontier_moments_vjp_bwd(num_t, impl, bfs, z, dist_id, res, cts):
    (dmu, dvar, dmu_m, dvar_m, dmu_s, dvar_s, dmu_e, dvar_e, extra) = res
    g_mu, g_var = cts
    dW = g_mu[:, None] * dmu + g_var[:, None] * dvar
    if extra.ndim == 3:
        # per-row statistics (the stage-stacked layout): every row owns its
        # fleet, so the cotangents stay per-row — no cross-row reduction
        d_mus = g_mu[:, None] * dmu_m + g_var[:, None] * dvar_m
        d_sigmas = g_mu[:, None] * dmu_s + g_var[:, None] * dvar_s
        d_extra = jnp.zeros_like(extra)
        d_extra = d_extra.at[0].set(g_mu[:, None] * dmu_e
                                    + g_var[:, None] * dvar_e)
        return dW, d_mus, d_sigmas, d_extra
    # channel statistics are shared across candidate rows: sum the per-row
    # adjoints against the output cotangents
    d_mus = g_mu @ dmu_m + g_var @ dvar_m
    d_sigmas = g_mu @ dmu_s + g_var @ dvar_s
    # extra cotangent: row 0 carries the differentiable shape parameter
    # (drift's rho, defective's failure probability p); remaining rows (the
    # defective pricing constant lam, the empirical mixture parameters, and
    # all rows for the other families) are solve constants with zero
    # cotangent by contract
    d_extra = jnp.zeros_like(extra)
    d_extra = d_extra.at[0].set(g_mu @ dmu_e + g_var @ dvar_e)
    return dW, d_mus, d_sigmas, d_extra


_frontier_moments_vjp.defvjp(_frontier_moments_vjp_fwd,
                             _frontier_moments_vjp_bwd)


def frontier_moments(W, mus, sigmas, *, num_t: int = 1024, impl: str = "xla",
                     block_f: Optional[int] = None, z: float = 10.0,
                     family="normal"):
    """Batched (mu, var) over candidate splits W: (F, K).

    The single entry point for candidate-split moment evaluation: the frontier
    tracers, the PGD objective, the balancer tick and the fleet benchmarks all
    route here. ``family`` selects the per-channel completion-time
    distribution — a name in {normal, lognormal, drift} or a
    ``core.distributions.ChannelFamily`` instance (Drift with per-channel
    rates, a fitted Empirical mixture, Defective with per-channel failure
    probabilities); it lowers to a static ``dist_id`` so
    each family compiles to its own specialized kernel. F is padded to a
    ``block_f`` multiple internally (padding rows repeat row 0 and are sliced
    off), so callers never see the kernel's divisibility requirement. When
    ``block_f`` is None the launch shape is resolved through
    ``kernels.autotune`` (VMEM-budget model + cached sweep results, keyed by
    family). The "xla" path streams candidates through lax.map over
    ``block_f``-row blocks, bounding peak memory at O(block_f * num_t * K)
    instead of materializing the full (F, T, K) intermediate — that is what
    lets a K=1024 x F=4096 tick run at all.

    Differentiable on every impl via a registered ``jax.custom_vjp`` that
    backprops through the analytic adjoint of the (family-parametric)
    survival integral (see ``frontier_grid.py``) instead of
    autodiff-replaying the quadrature — in the split weights ``W`` AND in
    the channel statistics: ``mus``, ``sigmas`` and, for the drift and
    defective families, ``extra`` row 0 (per-channel ``rho`` / failure
    probability ``p``) all receive nonzero analytic cotangents, which is what lets ``core.sensitivity`` chain the solve
    through the NIG posterior parameters (the closed estimation loop of
    arXiv:1511.00613). The empirical family's mixture parameters remain
    solve constants (re-fit from data, never descended): their cotangents
    are zero by contract.

    Stage-stacked layout: ``mus``/``sigmas`` may also be (F, K) — each
    candidate row carries its OWN channel fleet (and the family's ``extra``
    may be (E, F, K) per-row). This is what lets the workflow subsystem
    evaluate every stage of a DAG — different fleets, one family — as rows
    of a single launch instead of a per-stage Python loop over kernel
    launches. A shared (E, K) ``extra`` combined with per-row mus/sigmas is
    broadcast to the per-row layout here. The VJP keeps the per-row
    cotangent structure (no cross-row reduction for per-row statistics).
    """
    _check(impl)
    W = jnp.asarray(W, jnp.float32)
    mus = jnp.asarray(mus, jnp.float32)
    sigmas = jnp.asarray(sigmas, jnp.float32)
    F, K = W.shape
    dist_id, extra = _resolve_family(family, K)
    _san.check_frontier_inputs(W, mus, sigmas, extra, dist_id=dist_id)
    stacked = mus.ndim == 2
    if stacked:
        extra = _stack_extra(extra, F)
    # resolve BOTH launch shapes up front: the primal runs the forward
    # kernel, but under jax.grad the VJP's forward pass runs the fused
    # full-parameter one, whose working set is ~4x larger (smaller safe
    # block_f). An explicit block_f binds the forward launch verbatim; the
    # fused launch it implies is still clamped by the budget model — the
    # caller sized the block they asked for, not the 4x-bigger one
    # differentiation swaps in.
    bf_fwd = _resolve_block_f(F, K, num_t, impl, block_f, fused=False,
                              dist_id=dist_id, stacked=stacked)
    trace_on = _obs.enabled()
    at_out = None
    if trace_on:
        at_out = "explicit" if block_f is not None else _at.last_outcome()
    bf_fused = _resolve_block_f(F, K, num_t, impl, None, fused=True,
                                dist_id=dist_id, params=True, stacked=stacked)
    if block_f is not None:
        bf_fused = min(max(min(block_f, F), 1), bf_fused)
    if trace_on:
        # span only on concrete (host-side) launches: recording at trace
        # time would log once per COMPILE, not per launch, and the tracer
        # must never plant effects inside a traced computation — a tracer
        # hit is logged as a compile audit event instead
        if _san.all_concrete(W, mus, sigmas, extra):
            with _obs.span(_obs_names.SPAN_KERNEL_LAUNCH, family=dist_id,
                           mode="fwd", F=F, K=K, num_t=num_t,
                           block_f=bf_fwd, impl=impl, stacked=stacked,
                           autotune=at_out):
                return _frontier_moments_vjp(W, mus, sigmas, extra, num_t,
                                             impl, (bf_fwd, bf_fused), z,
                                             dist_id)
        _obs_events.kernel_compile("fwd", F, K, num_t, impl)
    return _frontier_moments_vjp(W, mus, sigmas, extra, num_t, impl,
                                 (bf_fwd, bf_fused), z, dist_id)


def frontier_moments_with_grads(W, mus, sigmas, *, num_t: int = 1024,
                                impl: str = "xla",
                                block_f: Optional[int] = None,
                                z: float = 10.0, family="normal",
                                param_grads: bool = False):
    """Fused (mu, var, dmu_dW, dvar_dW) over candidate splits W: (F, K).

    One launch returns the moments and their analytic adjoints w.r.t. every
    split weight — what the PGD solver consumes directly each step (no
    autodiff replay, no second launch). ``param_grads=True`` widens the same
    launch to the full-parameter adjoint 10-tuple

        (mu, var, dmu_dW, dvar_dW, dmu_dmus, dvar_dmus,
         dmu_dsigmas, dvar_dsigmas, dmu_dex, dvar_dex)

    (``d*_dex`` = extra row 0, drift's ``rho`` or defective's ``p``;
    zeros for other families) —
    the surface ``core.sensitivity`` and the posterior-sensitivity analysis
    consume. Family/padding/autotune glue matches :func:`frontier_moments`,
    including the stage-stacked per-row statistics layout (``mus``/``sigmas``
    (F, K), ``extra`` (E, F, K)) the workflow solver's joint PGD consumes;
    the two gradient modes autotune independently (``grad`` vs ``pgrad``
    cache keys — the parameter mode's working set is larger).
    """
    _check(impl)
    W = jnp.asarray(W, jnp.float32)
    mus = jnp.asarray(mus, jnp.float32)
    sigmas = jnp.asarray(sigmas, jnp.float32)
    dist_id, extra = _resolve_family(family, W.shape[1])
    _san.check_frontier_inputs(W, mus, sigmas, extra, dist_id=dist_id)
    stacked = mus.ndim == 2
    if stacked:
        extra = _stack_extra(extra, W.shape[0])
    bf = _resolve_block_f(W.shape[0], W.shape[1], num_t, impl, block_f,
                          fused=True, dist_id=dist_id, params=param_grads,
                          stacked=stacked)
    if _obs.enabled():
        mode = "pgrad" if param_grads else "grad"
        if _san.all_concrete(W, mus, sigmas, extra):
            at_out = ("explicit" if block_f is not None
                      else _at.last_outcome())
            with _obs.span(_obs_names.SPAN_KERNEL_LAUNCH, family=dist_id,
                           mode=mode, F=int(W.shape[0]), K=int(W.shape[1]),
                           num_t=num_t, block_f=bf, impl=impl,
                           stacked=stacked, autotune=at_out):
                return _moments_grads(W, mus, sigmas, extra, num_t, impl,
                                      bf, z, dist_id,
                                      param_grads=param_grads)
        _obs_events.kernel_compile(mode, int(W.shape[0]), int(W.shape[1]),
                                   num_t, impl)
    return _moments_grads(W, mus, sigmas, extra, num_t, impl, bf, z, dist_id,
                          param_grads=param_grads)


def decode_attention(q, k_cache, v_cache, valid, *, sm_scale=None,
                     impl: str = "xla", block_s: int = 512):
    """Single-token attention over a KV cache (online-softmax streaming).

    q: (B, Hkv, G, D); caches: (B, Hkv, S, D); valid: (S,) bool.
    The Pallas path is the fix for the decode memory wall (EXPERIMENTS
    §Perf D2): one pass over the cache instead of a materialized score chain.
    """
    _check(impl)
    if impl == "xla":
        return ref.decode_attention_ref(q, k_cache, v_cache, valid,
                                        sm_scale=sm_scale)
    return _fd.flash_decode(q, k_cache, v_cache, valid, sm_scale=sm_scale,
                            block_s=block_s,
                            interpret=(impl == "pallas_interpret"))
