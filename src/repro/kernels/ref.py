"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics; the kernels must match them (asserted by
tests/test_kernels.py across shape/dtype sweeps, kernels run in
interpret=True on CPU).

The frontier oracles are family-generic: every completion-time family in
``core.distributions.FAMILIES`` — normal, lognormal, drift, empirical,
defective — flows through the ``dists.family_*`` dispatch on the static
``dist_id``; there are no per-family branches in the quadrature itself.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["frontier_grid_ref", "frontier_grid_with_grads_ref",
           "flash_attention_ref", "ssd_scan_ref", "rmsnorm_ref",
           "decode_attention_ref"]

# log-CDF clamp floor. Must be a NORMAL f32 (>= 1.18e-38): XLA CPU flushes
# subnormals to zero, and a flushed floor turns the log/clip VJP into
# inf * 0 = NaN — the PGD solver differentiates through this function.
_CDF_FLOOR = 1e-37

_INV_SQRT2PI = 0.3989422804014327  # 1/sqrt(2*pi) (dists.phi's constant; kept
# exported — kernel-parity tests and external callers reference it)

# Constants above must precede this import: repro.core's init transitively
# re-imports this module (core.frontier -> kernels.ops -> frontier_grid ->
# ref._CDF_FLOOR), so the re-entrant import must find them already bound.
from repro.core import distributions as dists  # noqa: E402


def _family_args(dist_id, extra, K):
    if extra is None:
        extra = jnp.zeros((dists.extra_rows(dist_id), K), jnp.float32)
    return jnp.asarray(extra, jnp.float32)


# repro: allow[RPA001] layout-only axis alignment: family dispatch happens in
# the family_cdf call of the caller, which holds the static dist_id
def _stat_bcast(mus, sigmas, extra):
    """Broadcast shapes for the (F, T, K) grid calls.

    Shared statistics (``mus``/``sigmas`` (K,), ``extra`` (E, K)) broadcast
    against the (F, T, K) grid as-is. Per-row statistics — the stage-stacked
    layout where row f carries its own channel fleet: ``mus``/``sigmas``
    (F, K), ``extra`` (E, F, K) — need an explicit time axis inserted so the
    row axis lines up with F rather than T.
    """
    if mus.ndim == 2:
        return mus[:, None, :], sigmas[:, None, :], extra[:, :, None, :]
    return mus, sigmas, extra


def frontier_grid_ref(W, mus, sigmas, num_t: int = 1024, z: float = 10.0,
                      dist_id: str = "normal", extra=None):
    """(mu, var) of the joint max-completion time for each candidate split.

    W: (F, K) rows on the simplex; mus/sigmas: (K,) shared across rows, or
    (F, K) per-row (the stage-stacked layout: every candidate row carries its
    own channel fleet — what lets one launch serve a whole workflow DAG); the
    per-channel completion-time distribution is the family named by static
    ``dist_id`` with per-channel shape parameters ``extra`` ((E, K), or
    (E, F, K) per-row, see ``core.distributions``). Per-candidate integration
    grid [0, max_i(mean_i(w) + z*std_i(w))], num_t pts, on the family's
    effective moments. Mirrors repro.core.maxstat.max_moments_quad but with a
    per-row grid so the whole batch is one fused computation (the kernel's
    contract).
    """
    W = jnp.asarray(W, jnp.float32)
    mus = jnp.asarray(mus, jnp.float32)
    sigmas = jnp.asarray(sigmas, jnp.float32)
    extra = _family_args(dist_id, extra, W.shape[1])
    means_eff, stds_eff = dists.family_effective_moments(
        dist_id, W, mus, sigmas, extra)                          # (F, K)
    tmax = jnp.maximum(jnp.max(means_eff + z * stds_eff, axis=-1), 1e-12)
    ts = tmax[:, None] * jnp.linspace(0.0, 1.0, num_t)[None, :]  # (F, T)

    mus_b, sgs_b, ex_b = _stat_bcast(mus, sigmas, extra)
    cdf = dists.family_cdf(dist_id, ts[:, :, None], W[:, None, :],
                           mus_b, sgs_b, ex_b)                   # (F, T, K)
    logF = jnp.sum(jnp.log(jnp.clip(cdf, _CDF_FLOOR, 1.0)), axis=-1)  # (F, T)
    surv = 1.0 - jnp.exp(logF)

    dt = tmax / (num_t - 1)
    mu = (jnp.sum(surv, -1) - 0.5 * (surv[:, 0] + surv[:, -1])) * dt
    tsurv = ts * surv
    m2 = 2.0 * (jnp.sum(tsurv, -1) - 0.5 * (tsurv[:, 0] + tsurv[:, -1])) * dt
    var = jnp.maximum(m2 - mu * mu, 0.0)
    return mu, var


def frontier_grid_with_grads_ref(W, mus, sigmas, num_t: int = 1024,
                                 z: float = 10.0, dist_id: str = "normal",
                                 extra=None, param_grads: bool = False):
    """Fused oracle: ``(mu, var, dmu_dW, dvar_dW)`` for candidate splits W.

    Same forward contract as :func:`frontier_grid_ref` (family selected by
    static ``dist_id``; ``mus``/``sigmas``/``extra`` may be shared across
    rows or per-row exactly as there), plus the analytic adjoints of both
    moments w.r.t. every split weight, computed in the same pass — the
    semantics the fused Pallas kernel must match and the function the
    ``frontier_moments`` custom VJP rides. Per-row statistics change nothing
    in the adjoint math: every contraction is already per-row, the shared
    case was just broadcasting one fleet over all rows.

    With ``param_grads=True`` the adjoint basis widens to the full channel
    statistics and the return is the 10-tuple

        (mu, var, dmu_dW, dvar_dW, dmu_dmus, dvar_dmus,
         dmu_dsigmas, dvar_dsigmas, dmu_dex, dvar_dex)

    where ``dmu_dmus[f, k] = d mu_f / d mu_k`` etc. and ``d*_dex`` is the
    cotangent of ``extra`` **row 0** — drift's per-channel ``rho``, the
    defective family's failure probability ``p``; zero for every other
    family (the empirical mixture's fitted parameters, like defective's
    pricing constant ``lam`` in row 1, are solve constants by contract, see
    ``distributions.family_has_extra_grads``).
    This is the estimation-loop surface: the ``frontier_moments`` custom VJP
    and ``core.sensitivity`` ride these outputs to differentiate the solve
    through the posterior point estimates.

    The adjoint must agree with ``jax.grad`` through the quadrature graph, so
    it replicates autodiff's boundary conventions exactly:

    * ``jnp.clip(cdf, floor, 1)`` passes gradient 1 strictly inside the
      bounds, 0.5 at a saturated bound (f32 CDF hits exactly 1.0 for
      z >= ~5.3), and 0 outside. The f32 cancellation in ``0.5*(1+erf)``
      means the lower clip only ever activates at cdf == 0, never at a tie.
    * ``jnp.max`` over channels splits the tmax cotangent evenly over ties.
    * degenerate (point-mass) channels take the non-differentiable branch, so
      their direct gradient is 0 — they still receive the grid-path gradient
      when they set ``tmax``.

    The family enters through the affine decomposition
    ``dC/dtheta = D(t) (a + b t + c z)`` over the per-family feature basis of
    ``core.distributions.family_features`` (see ``frontier_grid.py`` for the
    derivation): the t-sums contract into at most six per-channel
    accumulators (P0/P1/Pz and their Pv* twins), of which each
    (family, param mode) pair statically needs a subset.
    """
    W = jnp.asarray(W, jnp.float32)
    mus = jnp.asarray(mus, jnp.float32)
    sigmas = jnp.asarray(sigmas, jnp.float32)
    extra = _family_args(dist_id, extra, W.shape[1])
    means_eff, stds_eff = dists.family_effective_moments(
        dist_id, W, mus, sigmas, extra)                      # (F, K)
    reach = means_eff + z * stds_eff
    amax = jnp.max(reach, axis=-1)        # (F,) unclamped grid end
    tmax = jnp.maximum(amax, 1e-12)
    ts = tmax[:, None] * jnp.linspace(0.0, 1.0, num_t)[None, :]  # (F, T)

    mus_b, sgs_b, ex_b = _stat_bcast(mus, sigmas, extra)
    cdf_raw, D, ok, zsc = dists.family_adjoint_parts(
        dist_id, ts[:, :, None], W[:, None, :], mus_b, sgs_b, ex_b)  # (F,T,K)
    cdf = jnp.where(ok, cdf_raw,
                    dists.point_mass_cdf(ts[:, :, None], means_eff[:, None, :]))
    Cc = jnp.clip(cdf, _CDF_FLOOR, 1.0)
    F_t = jnp.exp(jnp.sum(jnp.log(Cc), axis=-1))     # joint CDF (F, T)
    surv = 1.0 - F_t

    dt = tmax / (num_t - 1)
    wq = jnp.ones((num_t,), jnp.float32).at[0].set(0.5).at[-1].set(0.5)
    mu = jnp.sum(wq * surv, -1) * dt
    m2 = 2.0 * jnp.sum(wq * ts * surv, -1) * dt
    var_raw = m2 - mu * mu
    var = jnp.maximum(var_raw, 0.0)

    # d logF / d w_k |_t = gate * D/Cc * (alpha_k + beta_k t), gated by the
    # clip conventions (family-generic inverse-Mills ratio)
    gate = (jnp.where(cdf_raw >= 1.0, 0.5, 1.0)
            * (cdf_raw > _CDF_FLOOR) * ok)
    r = gate * D / Cc                                # (F, T, K)
    a = (wq[None, :, None] * F_t[:, :, None]) * r    # trapezoid-weighted
    use_1, use_t, use_z = dists.family_features(dist_id, params=param_grads)
    ones_t = jnp.ones_like(ts)
    # var accumulators combine the m2 and -2*mu*mu cotangents PER GRID POINT
    # (t_j - mu), exactly as autodiff's backward does — accumulating them
    # separately and subtracting after the reduction loses ~3 digits to
    # cancellation when var << mu^2
    tmu = ts - mu[:, None]
    P0 = jnp.einsum("ftk,ft->fk", a, ones_t) if use_1 else 0.0
    Pv0 = jnp.einsum("ftk,ft->fk", a, tmu) if use_1 else 0.0
    P1 = jnp.einsum("ftk,ft->fk", a, ts) if use_t else 0.0
    Pv1 = jnp.einsum("ftk,ft->fk", a, ts * tmu) if use_t else 0.0
    # the z feature rides inside the (F, T, K)-shaped a*z product (z varies
    # per channel), so its accumulators contract without the shared-t einsum
    Pz = jnp.sum(a * zsc, axis=1) if use_z else 0.0
    Pvz = jnp.sum(a * zsc * tmu[:, :, None], axis=1) if use_z else 0.0

    alpha, beta, gamma0, gamma1 = dists.family_coeffs(
        dist_id, W, mus, sigmas, extra)              # (F, K) each

    # grid terms: every z_jk moves with tmax, and dt scales with tmax, so
    # dmu/dtmax = mu/tmax - (dt/tmax) sum_k (gamma0 P0 + gamma1 P1)_k
    # and dvar/dtmax = 2 (var - dt sum_k (gamma0 Pv0 + gamma1 Pv1)_k) / tmax
    b_mu = (mu - dt * jnp.sum(gamma0 * P0 + gamma1 * P1, -1)) / tmax
    b_var = 2.0 * (var_raw
                   - dt * jnp.sum(gamma0 * Pv0 + gamma1 * Pv1, -1)) / tmax
    # dtmax/dtheta_k = dreach_k/dtheta on argmax channels (ties split evenly)
    ind = (reach == amax[:, None]).astype(jnp.float32)
    tie = ind / jnp.sum(ind, -1, keepdims=True) * (amax > 1e-12)[:, None]
    var_pos = (var_raw > 0.0)[:, None]

    def contract(coeff_1, coeff_t, coeff_z, dreach):
        """Fixed-grid + moving-grid adjoint for one parameter axis."""
        gvec = dreach * tie
        dmu_th = (-dt[:, None] * (coeff_1 * P0 + coeff_t * P1 + coeff_z * Pz)
                  + b_mu[:, None] * gvec)
        dvar_th = jnp.where(
            var_pos,
            -2.0 * dt[:, None] * (coeff_1 * Pv0 + coeff_t * Pv1
                                  + coeff_z * Pvz)
            + b_var[:, None] * gvec, 0.0)
        return dmu_th, dvar_th

    dreach_w = dists.family_dreach(dist_id, W, mus, sigmas, extra, z)
    zero_fk = jnp.zeros_like(W * mus)
    dmu, dvar = contract(alpha, beta, zero_fk, dreach_w)
    if not param_grads:
        return mu, var, dmu, dvar

    c_mu, c_sigma, c_rho = dists.family_param_coeffs(
        dist_id, W, mus, sigmas, extra)
    dr_mu, dr_sigma, dr_rho = dists.family_dreach_params(
        dist_id, W, mus, sigmas, extra, z)
    dmu_m, dvar_m = contract(*c_mu, dr_mu)
    dmu_s, dvar_s = contract(*c_sigma, dr_sigma)
    if dists.family_has_extra_grads(dist_id):
        dmu_e, dvar_e = contract(*c_rho, dr_rho)
    else:
        dmu_e, dvar_e = zero_fk, zero_fk
    return (mu, var, dmu, dvar, dmu_m, dvar_m, dmu_s, dvar_s, dmu_e, dvar_e)


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None, sm_scale: Optional[float] = None):
    """Reference GQA attention. q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D).

    Rectangular Sq != Sk supported (cross-attention); causal then aligns the
    last query with the last key (standard self-attn when Sq == Sk).
    """
    B, Hq, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    group = Hq // Hkv
    scale = sm_scale if sm_scale is not None else 1.0 / (D ** 0.5)
    kx = jnp.repeat(k, group, axis=1)
    vx = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        kx.astype(jnp.float32)) * scale
    qpos = jnp.arange(Sq)[:, None] + (Sk - Sq)
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    logits = jnp.where(mask, logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vx.astype(jnp.float32))
    return out.astype(q.dtype)


def ssd_scan_ref(x, dt, A, Bm, Cm, D_skip=None):
    """Naive sequential Mamba2 SSD recurrence (the semantics oracle).

    x:  (B, S, H, P)   inputs per head
    dt: (B, S, H)      positive step sizes (softplus already applied)
    A:  (H,)           negative per-head decay rates
    Bm: (B, S, G, N)   input projections (G groups, H % G == 0)
    Cm: (B, S, G, N)   output projections
    D_skip: (H,) or None — skip connection
    Returns y: (B, S, H, P).

        state_t = exp(dt_t A_h) state_{t-1} + dt_t * (B_t ⊗ x_t)
        y_t     = C_t · state_t (+ D_h x_t)
    """
    B, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=2)  # (B,S,H,N)
    Ch = jnp.repeat(Cm, rep, axis=2)

    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Af = A.astype(jnp.float32)

    def step(state, inp):
        x_t, dt_t, b_t, c_t = inp  # (B,H,P) (B,H) (B,H,N) (B,H,N)
        dA = jnp.exp(dt_t * Af)  # (B,H)
        state = state * dA[..., None, None] + (dt_t[..., None, None]
                                               * x_t[..., :, None] * b_t[..., None, :])
        y_t = jnp.einsum("bhpn,bhn->bhp", state, c_t)
        return state, y_t

    state0 = jnp.zeros((B, H, P, N), jnp.float32)
    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
          jnp.moveaxis(Bh.astype(jnp.float32), 1, 0), jnp.moveaxis(Ch.astype(jnp.float32), 1, 0))
    _, ys = jax.lax.scan(step, state0, xs)
    y = jnp.moveaxis(ys, 0, 1)  # (B,S,H,P)
    if D_skip is not None:
        y = y + D_skip.astype(jnp.float32)[None, None, :, None] * xf
    return y.astype(x.dtype)


def rmsnorm_ref(x, w, eps: float = 1e-6):
    """RMSNorm over the last axis."""
    xf = x.astype(jnp.float32)
    rms = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * rms * w.astype(jnp.float32)).astype(x.dtype)


def decode_attention_ref(q, k_cache, v_cache, valid, sm_scale=None):
    """Single-token GQA attention oracle. q: (B, Hkv, G, D); caches
    (B, Hkv, S, D); valid: (S,) bool -> (B, Hkv, G, D)."""
    D = q.shape[-1]
    scale = sm_scale if sm_scale is not None else 1.0 / (D ** 0.5)
    s = jnp.einsum("bkgd,bksd->bkgs", q.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * scale
    s = jnp.where(valid[None, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bksd->bkgd", p, v_cache.astype(jnp.float32))
    return o.astype(q.dtype)
