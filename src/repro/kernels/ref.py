"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics; the kernels must match them (asserted by
tests/test_kernels.py across shape/dtype sweeps, kernels run in
interpret=True on CPU).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["frontier_grid_ref", "flash_attention_ref", "ssd_scan_ref", "rmsnorm_ref", "decode_attention_ref"]

# log-CDF clamp floor. Must be a NORMAL f32 (>= 1.18e-38): XLA CPU flushes
# subnormals to zero, and a flushed floor turns the log/clip VJP into
# inf * 0 = NaN — the PGD solver differentiates through this function.
_CDF_FLOOR = 1e-37


def frontier_grid_ref(W, mus, sigmas, num_t: int = 1024, z: float = 10.0):
    """(mu, var) of the joint max-completion time for each candidate split.

    W: (F, K) rows on the simplex; mus/sigmas: (K,).
    Per-candidate integration grid [0, max_i(w_i*(mu_i + z*sigma_i))], num_t pts.
    Mirrors repro.core.maxstat.max_moments_quad but with a per-row grid so the
    whole batch is one fused computation (this is the kernel's contract).
    """
    W = jnp.asarray(W, jnp.float32)
    mus = jnp.asarray(mus, jnp.float32)
    sigmas = jnp.asarray(sigmas, jnp.float32)
    means = W * mus  # (F, K)
    stds = W * sigmas
    tmax = jnp.maximum(jnp.max(means + z * stds, axis=-1), 1e-12)  # (F,)
    ts = tmax[:, None] * jnp.linspace(0.0, 1.0, num_t)[None, :]  # (F, T)

    zscore = (ts[:, :, None] - means[:, None, :]) / jnp.where(stds[:, None, :] > 0,
                                                              stds[:, None, :], 1.0)
    cdf = 0.5 * (1.0 + jax.lax.erf(zscore / jnp.sqrt(2.0).astype(jnp.float32)))
    point = (ts[:, :, None] >= means[:, None, :]).astype(jnp.float32)
    cdf = jnp.where(stds[:, None, :] > 0, cdf, point)
    logF = jnp.sum(jnp.log(jnp.clip(cdf, _CDF_FLOOR, 1.0)), axis=-1)  # (F, T)
    surv = 1.0 - jnp.exp(logF)

    dt = tmax / (num_t - 1)
    mu = (jnp.sum(surv, -1) - 0.5 * (surv[:, 0] + surv[:, -1])) * dt
    tsurv = ts * surv
    m2 = 2.0 * (jnp.sum(tsurv, -1) - 0.5 * (tsurv[:, 0] + tsurv[:, -1])) * dt
    var = jnp.maximum(m2 - mu * mu, 0.0)
    return mu, var


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None, sm_scale: Optional[float] = None):
    """Reference GQA attention. q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D).

    Rectangular Sq != Sk supported (cross-attention); causal then aligns the
    last query with the last key (standard self-attn when Sq == Sk).
    """
    B, Hq, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    group = Hq // Hkv
    scale = sm_scale if sm_scale is not None else 1.0 / (D ** 0.5)
    kx = jnp.repeat(k, group, axis=1)
    vx = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        kx.astype(jnp.float32)) * scale
    qpos = jnp.arange(Sq)[:, None] + (Sk - Sq)
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    logits = jnp.where(mask, logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vx.astype(jnp.float32))
    return out.astype(q.dtype)


def ssd_scan_ref(x, dt, A, Bm, Cm, D_skip=None):
    """Naive sequential Mamba2 SSD recurrence (the semantics oracle).

    x:  (B, S, H, P)   inputs per head
    dt: (B, S, H)      positive step sizes (softplus already applied)
    A:  (H,)           negative per-head decay rates
    Bm: (B, S, G, N)   input projections (G groups, H % G == 0)
    Cm: (B, S, G, N)   output projections
    D_skip: (H,) or None — skip connection
    Returns y: (B, S, H, P).

        state_t = exp(dt_t A_h) state_{t-1} + dt_t * (B_t ⊗ x_t)
        y_t     = C_t · state_t (+ D_h x_t)
    """
    B, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=2)  # (B,S,H,N)
    Ch = jnp.repeat(Cm, rep, axis=2)

    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Af = A.astype(jnp.float32)

    def step(state, inp):
        x_t, dt_t, b_t, c_t = inp  # (B,H,P) (B,H) (B,H,N) (B,H,N)
        dA = jnp.exp(dt_t * Af)  # (B,H)
        state = state * dA[..., None, None] + (dt_t[..., None, None]
                                               * x_t[..., :, None] * b_t[..., None, :])
        y_t = jnp.einsum("bhpn,bhn->bhp", state, c_t)
        return state, y_t

    state0 = jnp.zeros((B, H, P, N), jnp.float32)
    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
          jnp.moveaxis(Bh.astype(jnp.float32), 1, 0), jnp.moveaxis(Ch.astype(jnp.float32), 1, 0))
    _, ys = jax.lax.scan(step, state0, xs)
    y = jnp.moveaxis(ys, 0, 1)  # (B,S,H,P)
    if D_skip is not None:
        y = y + D_skip.astype(jnp.float32)[None, None, :, None] * xf
    return y.astype(x.dtype)


def rmsnorm_ref(x, w, eps: float = 1e-6):
    """RMSNorm over the last axis."""
    xf = x.astype(jnp.float32)
    rms = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * rms * w.astype(jnp.float32)).astype(x.dtype)


def decode_attention_ref(q, k_cache, v_cache, valid, sm_scale=None):
    """Single-token GQA attention oracle. q: (B, Hkv, G, D); caches
    (B, Hkv, S, D); valid: (S,) bool -> (B, Hkv, G, D)."""
    D = q.shape[-1]
    scale = sm_scale if sm_scale is not None else 1.0 / (D ** 0.5)
    s = jnp.einsum("bkgd,bksd->bkgs", q.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * scale
    s = jnp.where(valid[None, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bksd->bkgd", p, v_cache.astype(jnp.float32))
    return o.astype(q.dtype)
