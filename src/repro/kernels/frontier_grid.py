"""The paper's hot loop as a Pallas TPU kernel: survival-integral moments for a
grid of candidate splits.

Why a kernel: at fleet scale the scheduler re-evaluates mu(w), sigma^2(w) for
thousands of candidate splits x hundreds/thousands of channels every rebalance
tick (posteriors move every step). That is a dense (F x T x K) computation of
erf/exp/log with two reductions — VPU-bound, and exactly the kind of loop worth
tiling into VMEM instead of bouncing (F, T, K) intermediates through HBM.

Tiling: the candidate axis F is blocked (block_f rows per program); each
program holds a (block_f, T) survival accumulator in VMEM and streams the K
channels in registers via a fori_loop, adding each channel's log-CDF. T and K
are small enough (T<=2048, K<=4096) that one tile's working set
block_f*(T)*4B stays well under the ~16 MB v5e VMEM budget for block_f<=256.

Per-candidate integration grids (t in [0, tmax_f]) keep accuracy uniform
across candidates whose means differ by orders of magnitude.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["frontier_grid"]

from .ref import _CDF_FLOOR  # single source: kernel must match its oracle

_SQRT2 = 1.4142135623730951


def _frontier_kernel(w_ref, mu_ref, sg_ref, mu_out_ref, var_out_ref, *,
                     num_t: int, z: float, num_k: int):
    w = w_ref[...]            # (bf, K)
    mus = mu_ref[...]         # (1, K)
    sgs = sg_ref[...]         # (1, K)
    means = w * mus           # (bf, K)
    stds = w * sgs

    tmax = jnp.maximum(jnp.max(means + z * stds, axis=-1, keepdims=True), 1e-12)  # (bf,1)
    # per-candidate time grid (bf, T): tmax * linspace(0,1,T)
    frac = jax.lax.broadcasted_iota(jnp.float32, (1, num_t), 1) / (num_t - 1)
    ts = tmax * frac          # (bf, T)

    def add_channel(kk, logF):
        mean_k = jax.lax.dynamic_slice_in_dim(means, kk, 1, axis=1)  # (bf,1)
        std_k = jax.lax.dynamic_slice_in_dim(stds, kk, 1, axis=1)
        ok = std_k > 0.0
        zsc = (ts - mean_k) / jnp.where(ok, std_k, 1.0)
        cdf = 0.5 * (1.0 + jax.lax.erf(zsc / _SQRT2))
        point = (ts >= mean_k).astype(jnp.float32)
        cdf = jnp.where(ok, cdf, point)
        return logF + jnp.log(jnp.clip(cdf, _CDF_FLOOR, 1.0))

    logF = jax.lax.fori_loop(0, num_k, add_channel,
                             jnp.zeros_like(ts))
    surv = 1.0 - jnp.exp(logF)  # (bf, T)

    dt = tmax[:, 0] / (num_t - 1)  # (bf,)
    mu = (jnp.sum(surv, -1) - 0.5 * (surv[:, 0] + surv[:, -1])) * dt
    tsurv = ts * surv
    m2 = 2.0 * (jnp.sum(tsurv, -1) - 0.5 * (tsurv[:, 0] + tsurv[:, -1])) * dt
    mu_out_ref[...] = mu
    var_out_ref[...] = jnp.maximum(m2 - mu * mu, 0.0)


@functools.partial(jax.jit, static_argnames=("num_t", "z", "block_f", "interpret"))
def frontier_grid(W, mus, sigmas, *, num_t: int = 1024, z: float = 10.0,
                  block_f: int = 128, interpret: bool = False):
    """(mu, var) arrays of shape (F,) for candidate splits W: (F, K).

    F must be divisible by block_f (ops.py pads with copies of row 0 otherwise).
    """
    F, K = W.shape
    block_f = min(block_f, F)
    assert F % block_f == 0, (F, block_f)
    W = W.astype(jnp.float32)
    mus2 = jnp.asarray(mus, jnp.float32)[None, :]
    sgs2 = jnp.asarray(sigmas, jnp.float32)[None, :]

    kernel = functools.partial(_frontier_kernel, num_t=num_t, z=z, num_k=K)
    return pl.pallas_call(
        kernel,
        grid=(F // block_f,),
        in_specs=[
            pl.BlockSpec((block_f, K), lambda i: (i, 0)),
            pl.BlockSpec((1, K), lambda i: (0, 0)),
            pl.BlockSpec((1, K), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_f,), lambda i: (i,)),
            pl.BlockSpec((block_f,), lambda i: (i,)),
        ],
        out_shape=[jax.ShapeDtypeStruct((F,), jnp.float32),
                   jax.ShapeDtypeStruct((F,), jnp.float32)],
        interpret=interpret,
    )(W, mus2, sgs2)
