"""The paper's hot loop as a Pallas TPU kernel: survival-integral moments for a
grid of candidate splits, with an optional fused analytic-gradient pass —
generalized over pluggable completion-time families (normal / lognormal /
drift / empirical / defective, selected by a **static** ``dist_id`` so every
family compiles to its own specialized kernel).

Why a kernel: at fleet scale the scheduler re-evaluates mu(w), sigma^2(w) for
thousands of candidate splits x hundreds/thousands of channels every rebalance
tick (posteriors move every step). That is a dense (F x T x K) computation of
erf/exp/log with two reductions — VPU-bound, and exactly the kind of loop worth
tiling into VMEM instead of bouncing (F, T, K) intermediates through HBM.

Tiling: the candidate axis F is blocked (block_f rows per program); each
program holds a (block_f, T) survival accumulator in VMEM and streams the K
channels in registers via a fori_loop, adding each channel's log-CDF. T and K
are small enough (T<=2048, K<=4096) that one tile's working set
block_f*(T)*4B stays well under the ~16 MB v5e VMEM budget for block_f<=256.
The fused gradient kernel additionally carries per-channel (block_f, K)
accumulators — two for the scale-like families, FOUR for ``drift`` (see the
derivation below) — plus the (block_f, K) gradient outputs, which is why
``kernels.autotune`` keys its working-set model and cache on
``(shape, backend, fused, dist_id)`` and picks a smaller block_f for the
fused and drift variants.

Per-candidate integration grids (t in [0, tmax_f]) keep accuracy uniform
across candidates whose means differ by orders of magnitude; ``tmax`` uses the
family's *effective* moments, max_k(mean_k(w) + z std_k(w)).

Differentiating the family-parametric survival integral
-------------------------------------------------------

The kernel computes, per candidate row w (weights over K channels with
per-unit-work statistics mu_k, sigma_k and family shape parameters
``extra[:, k]``):

    F(t)   = prod_k C_k(t; w_k)                 joint CDF of the max
    mu     = int_0^tmax (1 - F(t)) dt           survival-integral mean
    m2     = 2 int_0^tmax t (1 - F(t)) dt       second moment
    var    = m2 - mu^2

discretized by trapezoid quadrature on t_j = tmax * j/(T-1). For the Normal
family C_k(t) = Phi((t - w mu_k)/(w sigma_k)); the other families substitute
their own CDF (see ``core.distributions``). The adjoints stay a streaming
two-pass computation for EVERY family because each family's log-CDF
derivatives are affine in t after factoring out a pdf-like numerator D_k(t):

    d log C_k / d w_k |_t = g_jk * (alpha_k + beta_k t),
    d log C_k / d t   |_t = g_jk * (gamma0_k + gamma1_k t) / t,
    g_jk = gate_jk * D_k(t_j) / C_k(t_j)        (inverse-Mills-style ratio)

with per-channel constants (family_coeffs):

    normal      alpha=0,              beta=-1/(w^2 sigma),  gamma1=1/(w sigma)
    lognormal   alpha=-1/(w s_l),     beta=0,               gamma0=1/s_l
    drift       alpha=-rho mu/(2 s),  beta=-1/(w^2 sigma),  gamma1=1/(w sigma)
    empirical   alpha=0,              beta=-1/w^2,          gamma1=1/w
    defective   alpha=0,              beta=-1/(w^2 b),      gamma1=1/(w b)

(defective is the normal family with the retry-inflated moments (a, b)
substituted for (mu, sigma) — a pure scale family in w; see
``distributions._defective_ab``.)

(lognormal's z-score lives in log-space, so its dw-derivative is t-free;
drift's z = (t - mu g(w))/(w sigma) with g = w(1 + rho w/2) contributes both
a t-free and a t-linear term — that family alone needs all four
accumulators.) With a_jk = omega_j F(t_j) g_jk (omega_j trapezoid weights)
the fixed-grid adjoints contract into per-channel sums

    P0_k  = sum_j a_jk              Pv0_k = sum_j a_jk (t_j - mu)
    P1_k  = sum_j a_jk t_j          Pv1_k = sum_j a_jk t_j (t_j - mu)

    dmu/dw_k  (fixed grid) = -dt (alpha_k P0_k + beta_k P1_k)
    dvar/dw_k (fixed grid) = -2 dt (alpha_k Pv0_k + beta_k Pv1_k)

Parameter adjoints (the closed estimation loop)
-----------------------------------------------

The channel statistics are learned online, so the solve must also be
differentiable in mu_k, sigma_k and the family extras (drift's rho_k,
defective's failure probability p_k). The SAME contraction covers them: for any per-channel parameter theta_k,

    d log C_k / d theta_k |_t = g_jk * (a_k + b_k t + c_k z_jk)

is affine in the widened feature basis {1, t, z} (family_param_coeffs):

    normal      dz/dmu = -1/sigma                          {1}
                dz/dsigma = mu/sigma^2 - t/(w sigma^2)     {1, t}
    lognormal   dz/dtheta = -(dbase/dtheta)/s_l
                            - z (ds_l/dtheta)/s_l          {1, z}
    drift       dz/dmu = -g(w)/(w sigma)                   {1}
                dz/dsigma = mu g/(w sigma^2) - t/(w s^2)   {1, t}
                dz/drho = -mu w/(2 sigma)                  {1}
    empirical   (mus/sigmas unused; mixture extras are solve constants)
    defective   dz/dtheta = -(da/dtheta)/b
                            - z (db/dtheta)/b              {1, z}
                (theta in {mu, sigma, p}; lam is a pricing
                constant with documented-zero cotangent)

The z feature belongs to the families whose *spread* moves with the
statistics: lognormal's moment-matched shape s_l(mu, sigma) and defective's
composite b(mu, sigma, p), so dz/dmu picks up a term proportional to z
itself — which contracts against two more accumulators

    Pz_k  = sum_j a_jk z_jk         Pvz_k = sum_j a_jk z_jk (t_j - mu)

    dmu/dtheta_k  (fixed grid) = -dt (a_k P0 + b_k P1 + c_k Pz)_k
    dvar/dtheta_k (fixed grid) = -2 dt (a_k Pv0 + b_k Pv1 + c_k Pvz)_k

and every parameter also carries the moving-grid term below with
dtmax/dtheta_a = dreach_a/dtheta (family_dreach_params: w for mu, z_span*w
for sigma, mu w^2/2 for rho) on the argmax channel. So full-parameter mode
(static ``param_grads=True``) is the same two-pass streaming kernel with at
most SIX per-channel accumulators instead of four, six extra (block_f, K)
output tiles, and an unchanged K-loop count — the accumulators are shared
across w/mu/sigma/rho; only the epilogue contractions differ. The
``empirical`` family's mixture parameters are deliberately NOT adjointed
(re-fit from data each tick, never descended); its mus/sigmas cotangents
are exactly zero because the mixture CDF never reads them.

The Pv* accumulators fold the m2 and -2 mu dmu cotangents together per grid
point — the same combination autodiff's backward makes — which avoids the
catastrophic cancellation of accumulating them separately when var << mu^2.

Because the grid itself moves with w (t_j = tmax(w) * j/(T-1), dt ∝ tmax),
each output also carries a tmax term on the argmax channel
a = argmax_k(mean_k + z std_k), where dtmax/dw_a = dreach_a (family_dreach;
mu_a + z sigma_a for the normal/lognormal families):

    dmu/dtmax  = mu/tmax  - (dt/tmax)  sum_k (gamma0_k P0_k + gamma1_k P1_k)
    dvar/dtmax = 2 var/tmax
                 - (2 dt/tmax) sum_k (gamma0_k Pv0_k + gamma1_k Pv1_k)

(The continuum limit of dmu/dtmax is surv(tmax) ~ 0 at z=10; these discrete
forms keep exact parity with autodiff through the quadrature.) Degenerate
point-mass channels (w=0, sigma=0, spread-free mixtures) contribute no direct
term (their CDF — right-continuous per ``distributions.point_mass_cdf`` — is
flat a.e.) but still receive the tmax term when they set the grid end; CDF
values clipped to the [1e-37, 1] floor/ceiling follow jnp.clip's gradient
conventions (0 below the floor, 0.5 exactly at saturation).

The fused kernel computes the forward pass (one K-loop building log F), then a
second K-loop accumulating the P*/Pv* sums per channel from the shared
(block_f, T) joint-CDF tile — so ``(mu, var, dmu_dW, dvar_dW)`` costs ~2
forward passes in one launch, instead of a forward plus a full autodiff
replay through the quadrature graph.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["frontier_grid", "frontier_grid_with_grads"]

from .ref import _CDF_FLOOR  # single source: kernel must match its oracle
from repro.core import distributions as dists


def _nearest_valid_block_f(F: int, block_f: int) -> int:
    """The divisor of F closest to the requested block_f (ties go smaller:
    a smaller tile always fits where the larger one would have)."""
    divisors = [d for d in range(1, F + 1) if F % d == 0]
    return min(divisors, key=lambda d: (abs(d - block_f), d))


def _check_block(F: int, K: int, block_f: int, dist_id: str,
                 mode: str) -> None:
    # a real error, not an assert: asserts vanish under python -O and callers
    # outside ops.py would get a silent wrong-shape launch
    if F % block_f:
        raise ValueError(
            f"launch shape invalid: F={F} not divisible by block_f={block_f} "
            f"(K={K}, dist_id={dist_id!r}, mode={mode!r}); nearest valid "
            f"block_f is {_nearest_valid_block_f(F, block_f)}. "
            f"ops.frontier_moments pads W with copies of row 0 to guarantee "
            f"divisibility — call through it, or pass a block_f dividing F.")


def _slice_k(arr, kk):
    # channels always live on the LAST axis: (bf, K) weight/stat tiles,
    # (E, K) shared extras and (E, bf, K) per-row extras all slice the same
    return jax.lax.dynamic_slice_in_dim(arr, kk, 1, axis=arr.ndim - 1)


def _frontier_kernel(w_ref, mu_ref, sg_ref, ex_ref, mu_out_ref, var_out_ref, *,
                     num_t: int, z: float, num_k: int, dist_id: str):
    w = w_ref[...]            # (bf, K)
    mus = mu_ref[...]         # (1, K) shared | (bf, K) per-row
    sgs = sg_ref[...]         # (1, K) shared | (bf, K) per-row
    ex = ex_ref[...]          # (E, K) shared | (E, bf, K) per-row
    means_eff, stds_eff = dists.family_effective_moments(dist_id, w, mus, sgs, ex)

    tmax = jnp.maximum(jnp.max(means_eff + z * stds_eff, axis=-1,
                               keepdims=True), 1e-12)  # (bf, 1)
    # per-candidate time grid (bf, T): tmax * linspace(0,1,T)
    frac = jax.lax.broadcasted_iota(jnp.float32, (1, num_t), 1) / (num_t - 1)
    ts = tmax * frac          # (bf, T)

    def add_channel(kk, logF):
        cdf = dists.family_cdf(dist_id, ts, _slice_k(w, kk), _slice_k(mus, kk),
                               _slice_k(sgs, kk), _slice_k(ex, kk))
        return logF + jnp.log(jnp.clip(cdf, _CDF_FLOOR, 1.0))

    logF = jax.lax.fori_loop(0, num_k, add_channel,
                             jnp.zeros_like(ts))
    surv = 1.0 - jnp.exp(logF)  # (bf, T)

    dt = tmax[:, 0] / (num_t - 1)  # (bf,)
    mu = (jnp.sum(surv, -1) - 0.5 * (surv[:, 0] + surv[:, -1])) * dt
    tsurv = ts * surv
    m2 = 2.0 * (jnp.sum(tsurv, -1) - 0.5 * (tsurv[:, 0] + tsurv[:, -1])) * dt
    mu_out_ref[...] = mu
    var_out_ref[...] = jnp.maximum(m2 - mu * mu, 0.0)


def _family_extra(dist_id: str, extra, K: int, F=None):
    """Validated (E, K) extra, or (E, F, K) when statistics are per-row."""
    E = dists.extra_rows(dist_id)
    if extra is None:
        extra = jnp.zeros((E, K) if F is None else (E, F, K), jnp.float32)
    extra = jnp.asarray(extra, jnp.float32)
    want = (E, K) if F is None else (E, F, K)
    if extra.shape != want:
        raise ValueError(f"extra for {dist_id!r} must be {want}, "
                         f"got {extra.shape}")
    return extra


def _stat_specs(F: int, K: int, E: int, block_f: int, per_row: bool):
    """BlockSpecs for (mus, sigmas, extra): shared stats broadcast one tile
    to every program; per-row stats tile along F exactly like W."""
    if per_row:
        return [pl.BlockSpec((block_f, K), lambda i: (i, 0)),
                pl.BlockSpec((block_f, K), lambda i: (i, 0)),
                pl.BlockSpec((E, block_f, K), lambda i: (0, i, 0))]
    return [pl.BlockSpec((1, K), lambda i: (0, 0)),
            pl.BlockSpec((1, K), lambda i: (0, 0)),
            pl.BlockSpec((E, K), lambda i: (0, 0))]


@functools.partial(jax.jit, static_argnames=("num_t", "z", "block_f",
                                             "interpret", "dist_id"))
def frontier_grid(W, mus, sigmas, extra=None, *, num_t: int = 1024,
                  z: float = 10.0, block_f: int = 128,
                  interpret: bool = False, dist_id: str = "normal"):
    """(mu, var) arrays of shape (F,) for candidate splits W: (F, K).

    ``dist_id`` statically selects the completion-time family; ``extra`` is
    its (E, K) per-channel shape-parameter array (zeros when the family has
    none). ``mus``/``sigmas`` may also be (F, K) — per-row channel
    statistics, the stage-stacked layout where every candidate row carries
    its own fleet (``extra`` then (E, F, K)); the stat tiles ride the same
    F-blocking as W instead of broadcasting one tile to every program. F
    must be divisible by block_f (ops.py pads with copies of row 0
    otherwise).
    """
    F, K = W.shape
    block_f = min(block_f, F)
    _check_block(F, K, block_f, dist_id, "fwd")
    W = W.astype(jnp.float32)
    mus = jnp.asarray(mus, jnp.float32)
    per_row = mus.ndim == 2
    mus2 = mus if per_row else mus[None, :]
    sgs2 = jnp.asarray(sigmas, jnp.float32)
    sgs2 = sgs2 if per_row else sgs2[None, :]
    ex = _family_extra(dist_id, extra, K, F if per_row else None)
    E = ex.shape[0]

    kernel = functools.partial(_frontier_kernel, num_t=num_t, z=z, num_k=K,
                               dist_id=dist_id)
    return pl.pallas_call(
        kernel,
        grid=(F // block_f,),
        in_specs=[
            pl.BlockSpec((block_f, K), lambda i: (i, 0)),
        ] + _stat_specs(F, K, E, block_f, per_row),
        out_specs=[
            pl.BlockSpec((block_f,), lambda i: (i,)),
            pl.BlockSpec((block_f,), lambda i: (i,)),
        ],
        out_shape=[jax.ShapeDtypeStruct((F,), jnp.float32),
                   jax.ShapeDtypeStruct((F,), jnp.float32)],
        interpret=interpret,
    )(W, mus2, sgs2, ex)


def _frontier_grad_kernel(w_ref, mu_ref, sg_ref, ex_ref,
                          mu_out_ref, var_out_ref, dmu_out_ref, dvar_out_ref,
                          *param_out_refs, num_t: int, z: float, num_k: int,
                          dist_id: str, param_grads: bool):
    """Fused forward + analytic adjoint (see module docstring for the math).

    Pass 1 is the forward K-loop building the joint log-CDF; pass 2 streams K
    again, turning the shared (bf, T) joint-CDF tile into the per-channel
    P*/Pv* accumulator pairs — one per live feature in
    ``distributions.family_features(dist_id, param_grads)``, so unused
    accumulators never exist in the compiled program. Grad accumulators live
    in the same VMEM tile as the forward state — no (F, T, K) residuals ever
    leave the program. With ``param_grads`` the same two passes additionally
    emit the mus/sigmas/extra-row-0 adjoints (six more (bf, K) outputs):
    the parameter cotangents contract the SAME accumulators against
    different per-channel constants, so full-parameter mode costs extra
    epilogue arithmetic and output tiles, not a third K-loop.
    """
    w = w_ref[...]            # (bf, K)
    mus = mu_ref[...]         # (1, K) shared | (bf, K) per-row
    sgs = sg_ref[...]         # (1, K) shared | (bf, K) per-row
    ex = ex_ref[...]          # (E, K) shared | (E, bf, K) per-row
    means_eff, stds_eff = dists.family_effective_moments(dist_id, w, mus, sgs, ex)
    reach = means_eff + z * stds_eff

    amax = jnp.max(reach, axis=-1, keepdims=True)            # (bf, 1)
    tmax = jnp.maximum(amax, 1e-12)
    frac = jax.lax.broadcasted_iota(jnp.float32, (1, num_t), 1) / (num_t - 1)
    ts = tmax * frac          # (bf, T)

    def add_channel(kk, logF):
        cdf = dists.family_cdf(dist_id, ts, _slice_k(w, kk), _slice_k(mus, kk),
                               _slice_k(sgs, kk), _slice_k(ex, kk))
        return logF + jnp.log(jnp.clip(cdf, _CDF_FLOOR, 1.0))

    logF = jax.lax.fori_loop(0, num_k, add_channel, jnp.zeros_like(ts))
    F_t = jnp.exp(logF)
    surv = 1.0 - F_t

    dt = tmax[:, 0] / (num_t - 1)  # (bf,)
    mu = (jnp.sum(surv, -1) - 0.5 * (surv[:, 0] + surv[:, -1])) * dt
    tsurv = ts * surv
    m2 = 2.0 * (jnp.sum(tsurv, -1) - 0.5 * (tsurv[:, 0] + tsurv[:, -1])) * dt
    var_raw = m2 - mu * mu
    mu_out_ref[...] = mu
    var_out_ref[...] = jnp.maximum(var_raw, 0.0)

    # pass 2: per-channel accumulators off the shared F(t) tile. wF folds the
    # trapezoid weights into the joint CDF once.
    idx = jax.lax.broadcasted_iota(jnp.int32, (1, num_t), 1)
    wq = jnp.where((idx == 0) | (idx == num_t - 1), 0.5, 1.0)
    wF = wq * F_t                                            # (bf, T)
    tmu = ts - mu[:, None]                                   # (bf, T)
    use_1, use_t, use_z = dists.family_features(dist_id, params=param_grads)

    def grad_channel(kk, carry):
        cdf_raw, D, ok, zsc = dists.family_adjoint_parts(
            dist_id, ts, _slice_k(w, kk), _slice_k(mus, kk),
            _slice_k(sgs, kk), _slice_k(ex, kk))
        Cc = jnp.clip(cdf_raw, _CDF_FLOOR, 1.0)
        gate = jnp.where(cdf_raw >= 1.0, 0.5, 1.0) * (cdf_raw > _CDF_FLOOR) * ok
        a = wF * (gate * D / Cc)                             # (bf, T)
        updates = []
        if use_1:
            updates.append(jnp.sum(a, -1, keepdims=True))            # P0
            updates.append(jnp.sum(a * tmu, -1, keepdims=True))      # Pv0
        if use_t:
            updates.append(jnp.sum(a * ts, -1, keepdims=True))       # P1
            updates.append(jnp.sum(a * ts * tmu, -1, keepdims=True))  # Pv1
        if use_z:
            updates.append(jnp.sum(a * zsc, -1, keepdims=True))      # Pz
            updates.append(jnp.sum(a * zsc * tmu, -1, keepdims=True))  # Pvz
        return tuple(jax.lax.dynamic_update_slice_in_dim(acc, upd, kk, axis=1)
                     for acc, upd in zip(carry, updates))

    zeros_fk = jnp.zeros_like(w)
    n_acc = 2 * (int(use_1) + int(use_t) + int(use_z))
    accs = list(jax.lax.fori_loop(0, num_k, grad_channel,
                                  (zeros_fk,) * n_acc))
    P0, Pv0 = (accs.pop(0), accs.pop(0)) if use_1 else (0.0, 0.0)
    P1, Pv1 = (accs.pop(0), accs.pop(0)) if use_t else (0.0, 0.0)
    Pz, Pvz = (accs.pop(0), accs.pop(0)) if use_z else (0.0, 0.0)

    # epilogue: combine fixed-grid and moving-grid (tmax) terms with the
    # family's per-channel constants — module docstring "Differentiating the
    # family-parametric survival integral"
    alpha, beta, gamma0, gamma1 = dists.family_coeffs(dist_id, w, mus, sgs, ex)
    dtc = dt[:, None]
    tmx = tmax[:, 0]
    b_mu = (mu - dt * jnp.sum(gamma0 * P0 + gamma1 * P1, -1)) / tmx
    b_var = 2.0 * (var_raw
                   - dt * jnp.sum(gamma0 * Pv0 + gamma1 * Pv1, -1)) / tmx
    ind = (reach == amax).astype(jnp.float32)
    tie = (ind / jnp.sum(ind, -1, keepdims=True)
           * (amax > 1e-12).astype(jnp.float32))
    var_pos = (var_raw > 0.0)[:, None]

    def contract(c1, ct, cz, dreach):
        gvec = dreach * tie
        dmu_th = (-dtc * (c1 * P0 + ct * P1 + cz * Pz)
                  + b_mu[:, None] * gvec)
        dvar_th = jnp.where(
            var_pos,
            -2.0 * dtc * (c1 * Pv0 + ct * Pv1 + cz * Pvz)
            + b_var[:, None] * gvec, 0.0)
        return dmu_th, dvar_th

    dreach_w = dists.family_dreach(dist_id, w, mus, sgs, ex, z)
    dmu, dvar = contract(alpha, beta, zeros_fk, dreach_w)
    dmu_out_ref[...] = dmu
    dvar_out_ref[...] = dvar
    if not param_grads:
        return
    (dmuM_ref, dvarM_ref, dmuS_ref, dvarS_ref, dmuE_ref, dvarE_ref) = \
        param_out_refs
    c_mu, c_sigma, c_rho = dists.family_param_coeffs(dist_id, w, mus, sgs, ex)
    dr_mu, dr_sigma, dr_rho = dists.family_dreach_params(
        dist_id, w, mus, sgs, ex, z)
    dmuM_ref[...], dvarM_ref[...] = contract(*c_mu, dr_mu)
    dmuS_ref[...], dvarS_ref[...] = contract(*c_sigma, dr_sigma)
    if dists.family_has_extra_grads(dist_id):
        dmuE_ref[...], dvarE_ref[...] = contract(*c_rho, dr_rho)
    else:
        dmuE_ref[...] = zeros_fk
        dvarE_ref[...] = zeros_fk


@functools.partial(jax.jit, static_argnames=("num_t", "z", "block_f",
                                             "interpret", "dist_id",
                                             "param_grads"))
def frontier_grid_with_grads(W, mus, sigmas, extra=None, *, num_t: int = 1024,
                             z: float = 10.0, block_f: int = 64,
                             interpret: bool = False,
                             dist_id: str = "normal",
                             param_grads: bool = False):
    """Fused ``(mu, var, dmu_dW, dvar_dW)`` for candidate splits W: (F, K).

    One launch returns the moments AND their analytic adjoints w.r.t. every
    split weight (matching ``ref.frontier_grid_with_grads_ref``) for the
    family statically selected by ``dist_id``. With ``param_grads=True`` the
    same single launch additionally emits the channel-statistic adjoints —
    ``(dmu_dmus, dvar_dmus, dmu_dsigmas, dvar_dsigmas, dmu_dex, dvar_dex)``,
    all (F, K), ``d*_dex`` being extra row 0 (drift's rho, defective's p; zeros for
    families without differentiable extra) — the full-parameter mode the estimation
    loop's custom VJP rides. ``mus``/``sigmas`` may be (F, K) per-row
    statistics (``extra`` then (E, F, K)) exactly as in
    :func:`frontier_grid`; the adjoint outputs are per-row either way, so
    only the input tiling changes. F must be divisible by block_f (ops.py
    pads with copies of row 0 otherwise).
    """
    F, K = W.shape
    block_f = min(block_f, F)
    _check_block(F, K, block_f, dist_id, "pgrad" if param_grads else "grad")
    W = W.astype(jnp.float32)
    mus = jnp.asarray(mus, jnp.float32)
    per_row = mus.ndim == 2
    mus2 = mus if per_row else mus[None, :]
    sgs2 = jnp.asarray(sigmas, jnp.float32)
    sgs2 = sgs2 if per_row else sgs2[None, :]
    ex = _family_extra(dist_id, extra, K, F if per_row else None)
    E = ex.shape[0]

    kernel = functools.partial(_frontier_grad_kernel, num_t=num_t, z=z,
                               num_k=K, dist_id=dist_id,
                               param_grads=param_grads)
    n_fk_outs = 8 if param_grads else 2
    return pl.pallas_call(
        kernel,
        grid=(F // block_f,),
        in_specs=[
            pl.BlockSpec((block_f, K), lambda i: (i, 0)),
        ] + _stat_specs(F, K, E, block_f, per_row),
        out_specs=[
            pl.BlockSpec((block_f,), lambda i: (i,)),
            pl.BlockSpec((block_f,), lambda i: (i,)),
        ] + [pl.BlockSpec((block_f, K), lambda i: (i, 0))] * n_fk_outs,
        out_shape=[jax.ShapeDtypeStruct((F,), jnp.float32),
                   jax.ShapeDtypeStruct((F,), jnp.float32)]
        + [jax.ShapeDtypeStruct((F, K), jnp.float32)] * n_fk_outs,
        interpret=interpret,
    )(W, mus2, sgs2, ex)
