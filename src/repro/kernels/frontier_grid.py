"""The paper's hot loop as a Pallas TPU kernel: survival-integral moments for a
grid of candidate splits, with an optional fused analytic-gradient pass.

Why a kernel: at fleet scale the scheduler re-evaluates mu(w), sigma^2(w) for
thousands of candidate splits x hundreds/thousands of channels every rebalance
tick (posteriors move every step). That is a dense (F x T x K) computation of
erf/exp/log with two reductions — VPU-bound, and exactly the kind of loop worth
tiling into VMEM instead of bouncing (F, T, K) intermediates through HBM.

Tiling: the candidate axis F is blocked (block_f rows per program); each
program holds a (block_f, T) survival accumulator in VMEM and streams the K
channels in registers via a fori_loop, adding each channel's log-CDF. T and K
are small enough (T<=2048, K<=4096) that one tile's working set
block_f*(T)*4B stays well under the ~16 MB v5e VMEM budget for block_f<=256.
The fused gradient kernel additionally carries two (block_f, K) accumulators
and the (block_f, K) gradient outputs (~3x the forward working set), which is
why ``kernels.autotune`` picks a smaller block_f for it.

Per-candidate integration grids (t in [0, tmax_f]) keep accuracy uniform
across candidates whose means differ by orders of magnitude.

Differentiating the survival integral
-------------------------------------

The kernel computes, per candidate row w (weights over K channels, with
per-channel rates mu_k, sigma_k, scaled means m_k = w_k mu_k and stds
s_k = w_k sigma_k):

    F(t)   = prod_k Phi((t - m_k)/s_k)          joint CDF of the max
    mu     = int_0^tmax (1 - F(t)) dt           survival-integral mean
    m2     = 2 int_0^tmax t (1 - F(t)) dt       second moment
    var    = m2 - mu^2

discretized by trapezoid quadrature on t_j = tmax * j/(T-1), with
tmax = max_k(m_k + z s_k). The adjoints reduce to ONE extra Gaussian-pdf
accumulator per channel evaluated on the same grid. Writing z_k = (t-m_k)/s_k
and the inverse-Mills-style ratio r_k(t) = phi(z_k)/Phi(z_k):

    d logF / d w_k |_t  = r_k(t) * dz_k/dw_k,   dz_k/dw_k = -t/(w_k^2 sigma_k)

so with a_jk = omega_j F(t_j) r_k(t_j) (omega_j the trapezoid weights):

    dmu/dw_k  (fixed grid) = (dt / (w_k^2 sigma_k)) * P1_k,
                             P1_k = sum_j a_jk t_j
    dvar/dw_k (fixed grid) = (2 dt / (w_k^2 sigma_k)) * Pv_k,
                             Pv_k = sum_j a_jk t_j (t_j - mu)

Pv folds the m2 and -2 mu dmu cotangents together per grid point — the same
combination autodiff's backward makes — which avoids the catastrophic
cancellation of accumulating them separately when var << mu^2.

Because the grid itself moves with w (t_j = tmax(w) * j/(T-1), dt ∝ tmax),
each output also carries a tmax term on the argmax channel
a = argmax_k(m_k + z s_k), where dtmax/dw_a = mu_a + z sigma_a:

    dmu/dtmax  = mu/tmax  - (dt/tmax)   sum_k P1_k / s_k
    dvar/dtmax = 2 var/tmax - (2 dt/tmax) sum_k Pv_k / s_k

(The continuum limit of dmu/dtmax is surv(tmax) ~ 0 at z=10; these discrete
forms keep exact parity with autodiff through the quadrature.) Zero-std
channels contribute no direct term (their point-mass CDF is flat a.e.) but
still receive the tmax term when they set the grid end; CDF values clipped to
the [1e-37, 1] floor/ceiling follow jnp.clip's gradient conventions (0 below
the floor, 0.5 exactly at saturation).

The fused kernel computes the forward pass (one K-loop building log F), then a
second K-loop accumulating P1/Pv per channel from the shared (block_f, T)
joint-CDF tile — so ``(mu, var, dmu_dW, dvar_dW)`` costs ~2 forward passes in
one launch, instead of a forward plus a full autodiff replay through the
quadrature graph.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["frontier_grid", "frontier_grid_with_grads"]

from .ref import _CDF_FLOOR, _INV_SQRT2PI  # single source: kernel must match its oracle

_SQRT2 = 1.4142135623730951


def _check_block(F: int, block_f: int) -> None:
    # a real error, not an assert: asserts vanish under python -O and callers
    # outside ops.py would get a silent wrong-shape launch
    if F % block_f:
        raise ValueError(
            f"F={F} must be divisible by block_f={block_f} "
            f"(ops.frontier_moments pads with copies of row 0 to guarantee this)")


def _frontier_kernel(w_ref, mu_ref, sg_ref, mu_out_ref, var_out_ref, *,
                     num_t: int, z: float, num_k: int):
    w = w_ref[...]            # (bf, K)
    mus = mu_ref[...]         # (1, K)
    sgs = sg_ref[...]         # (1, K)
    means = w * mus           # (bf, K)
    stds = w * sgs

    tmax = jnp.maximum(jnp.max(means + z * stds, axis=-1, keepdims=True), 1e-12)  # (bf,1)
    # per-candidate time grid (bf, T): tmax * linspace(0,1,T)
    frac = jax.lax.broadcasted_iota(jnp.float32, (1, num_t), 1) / (num_t - 1)
    ts = tmax * frac          # (bf, T)

    def add_channel(kk, logF):
        mean_k = jax.lax.dynamic_slice_in_dim(means, kk, 1, axis=1)  # (bf,1)
        std_k = jax.lax.dynamic_slice_in_dim(stds, kk, 1, axis=1)
        ok = std_k > 0.0
        zsc = (ts - mean_k) / jnp.where(ok, std_k, 1.0)
        cdf = 0.5 * (1.0 + jax.lax.erf(zsc / _SQRT2))
        point = (ts >= mean_k).astype(jnp.float32)
        cdf = jnp.where(ok, cdf, point)
        return logF + jnp.log(jnp.clip(cdf, _CDF_FLOOR, 1.0))

    logF = jax.lax.fori_loop(0, num_k, add_channel,
                             jnp.zeros_like(ts))
    surv = 1.0 - jnp.exp(logF)  # (bf, T)

    dt = tmax[:, 0] / (num_t - 1)  # (bf,)
    mu = (jnp.sum(surv, -1) - 0.5 * (surv[:, 0] + surv[:, -1])) * dt
    tsurv = ts * surv
    m2 = 2.0 * (jnp.sum(tsurv, -1) - 0.5 * (tsurv[:, 0] + tsurv[:, -1])) * dt
    mu_out_ref[...] = mu
    var_out_ref[...] = jnp.maximum(m2 - mu * mu, 0.0)


@functools.partial(jax.jit, static_argnames=("num_t", "z", "block_f", "interpret"))
def frontier_grid(W, mus, sigmas, *, num_t: int = 1024, z: float = 10.0,
                  block_f: int = 128, interpret: bool = False):
    """(mu, var) arrays of shape (F,) for candidate splits W: (F, K).

    F must be divisible by block_f (ops.py pads with copies of row 0 otherwise).
    """
    F, K = W.shape
    block_f = min(block_f, F)
    _check_block(F, block_f)
    W = W.astype(jnp.float32)
    mus2 = jnp.asarray(mus, jnp.float32)[None, :]
    sgs2 = jnp.asarray(sigmas, jnp.float32)[None, :]

    kernel = functools.partial(_frontier_kernel, num_t=num_t, z=z, num_k=K)
    return pl.pallas_call(
        kernel,
        grid=(F // block_f,),
        in_specs=[
            pl.BlockSpec((block_f, K), lambda i: (i, 0)),
            pl.BlockSpec((1, K), lambda i: (0, 0)),
            pl.BlockSpec((1, K), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_f,), lambda i: (i,)),
            pl.BlockSpec((block_f,), lambda i: (i,)),
        ],
        out_shape=[jax.ShapeDtypeStruct((F,), jnp.float32),
                   jax.ShapeDtypeStruct((F,), jnp.float32)],
        interpret=interpret,
    )(W, mus2, sgs2)


def _frontier_grad_kernel(w_ref, mu_ref, sg_ref,
                          mu_out_ref, var_out_ref, dmu_out_ref, dvar_out_ref,
                          *, num_t: int, z: float, num_k: int):
    """Fused forward + analytic adjoint (see module docstring for the math).

    Pass 1 is the forward K-loop building the joint log-CDF; pass 2 streams K
    again, turning the shared (bf, T) joint-CDF tile into the per-channel
    P1/Pv accumulators. Grad accumulators live in the same VMEM tile as the
    forward state — no (F, T, K) residuals ever leave the program.
    """
    w = w_ref[...]            # (bf, K)
    mus = mu_ref[...]         # (1, K)
    sgs = sg_ref[...]         # (1, K)
    means = w * mus           # (bf, K)
    stds = w * sgs
    reach = means + z * stds

    amax = jnp.max(reach, axis=-1, keepdims=True)            # (bf, 1)
    tmax = jnp.maximum(amax, 1e-12)
    frac = jax.lax.broadcasted_iota(jnp.float32, (1, num_t), 1) / (num_t - 1)
    ts = tmax * frac          # (bf, T)

    def add_channel(kk, logF):
        mean_k = jax.lax.dynamic_slice_in_dim(means, kk, 1, axis=1)  # (bf,1)
        std_k = jax.lax.dynamic_slice_in_dim(stds, kk, 1, axis=1)
        ok = std_k > 0.0
        zsc = (ts - mean_k) / jnp.where(ok, std_k, 1.0)
        cdf = 0.5 * (1.0 + jax.lax.erf(zsc / _SQRT2))
        point = (ts >= mean_k).astype(jnp.float32)
        cdf = jnp.where(ok, cdf, point)
        return logF + jnp.log(jnp.clip(cdf, _CDF_FLOOR, 1.0))

    logF = jax.lax.fori_loop(0, num_k, add_channel, jnp.zeros_like(ts))
    F_t = jnp.exp(logF)
    surv = 1.0 - F_t

    dt = tmax[:, 0] / (num_t - 1)  # (bf,)
    mu = (jnp.sum(surv, -1) - 0.5 * (surv[:, 0] + surv[:, -1])) * dt
    tsurv = ts * surv
    m2 = 2.0 * (jnp.sum(tsurv, -1) - 0.5 * (tsurv[:, 0] + tsurv[:, -1])) * dt
    var_raw = m2 - mu * mu
    mu_out_ref[...] = mu
    var_out_ref[...] = jnp.maximum(var_raw, 0.0)

    # pass 2: per-channel Gaussian-pdf accumulators off the shared F(t) tile.
    # wF folds the trapezoid weights into the joint CDF once.
    idx = jax.lax.broadcasted_iota(jnp.int32, (1, num_t), 1)
    wq = jnp.where((idx == 0) | (idx == num_t - 1), 0.5, 1.0)
    wF = wq * F_t                                            # (bf, T)
    tv = ts * (ts - mu[:, None])                             # (bf, T)

    def grad_channel(kk, carry):
        P1, Pv = carry                                       # (bf, K) each
        mean_k = jax.lax.dynamic_slice_in_dim(means, kk, 1, axis=1)
        std_k = jax.lax.dynamic_slice_in_dim(stds, kk, 1, axis=1)
        ok = std_k > 0.0
        zsc = (ts - mean_k) / jnp.where(ok, std_k, 1.0)
        cdf = 0.5 * (1.0 + jax.lax.erf(zsc / _SQRT2))
        Cc = jnp.clip(cdf, _CDF_FLOOR, 1.0)
        phi = jnp.exp(-0.5 * zsc * zsc) * _INV_SQRT2PI
        gate = jnp.where(cdf >= 1.0, 0.5, 1.0) * (cdf > _CDF_FLOOR) * ok
        a = wF * (gate * phi / Cc)                           # (bf, T)
        p1 = jnp.sum(a * ts, -1, keepdims=True)              # (bf, 1)
        pv = jnp.sum(a * tv, -1, keepdims=True)
        return (jax.lax.dynamic_update_slice_in_dim(P1, p1, kk, axis=1),
                jax.lax.dynamic_update_slice_in_dim(Pv, pv, kk, axis=1))

    zeros_fk = jnp.zeros_like(w)
    P1, Pv = jax.lax.fori_loop(0, num_k, grad_channel, (zeros_fk, zeros_fk))

    # epilogue: combine fixed-grid and moving-grid (tmax) terms — module
    # docstring "Differentiating the survival integral"
    ok = stds > 0.0
    inv_w2s = jnp.where(ok, 1.0 / jnp.where(ok, w * stds, 1.0), 0.0)
    inv_s = jnp.where(ok, 1.0 / jnp.where(ok, stds, 1.0), 0.0)
    dtc = dt[:, None]
    tmx = tmax[:, 0]
    b_mu = (mu - dt * jnp.sum(P1 * inv_s, -1)) / tmx
    b_var = 2.0 * (var_raw - dt * jnp.sum(Pv * inv_s, -1)) / tmx
    ind = (reach == amax).astype(jnp.float32)
    gvec = ((mus + z * sgs) * ind / jnp.sum(ind, -1, keepdims=True)
            * (amax > 1e-12).astype(jnp.float32))
    dmu = dtc * P1 * inv_w2s + b_mu[:, None] * gvec
    dvar = jnp.where((var_raw > 0.0)[:, None],
                     2.0 * dtc * Pv * inv_w2s + b_var[:, None] * gvec, 0.0)
    dmu_out_ref[...] = dmu
    dvar_out_ref[...] = dvar


@functools.partial(jax.jit, static_argnames=("num_t", "z", "block_f", "interpret"))
def frontier_grid_with_grads(W, mus, sigmas, *, num_t: int = 1024,
                             z: float = 10.0, block_f: int = 64,
                             interpret: bool = False):
    """Fused ``(mu, var, dmu_dW, dvar_dW)`` for candidate splits W: (F, K).

    One launch returns the moments AND their analytic adjoints w.r.t. every
    split weight (matching ``ref.frontier_grid_with_grads_ref``). F must be
    divisible by block_f (ops.py pads with copies of row 0 otherwise).
    """
    F, K = W.shape
    block_f = min(block_f, F)
    _check_block(F, block_f)
    W = W.astype(jnp.float32)
    mus2 = jnp.asarray(mus, jnp.float32)[None, :]
    sgs2 = jnp.asarray(sigmas, jnp.float32)[None, :]

    kernel = functools.partial(_frontier_grad_kernel, num_t=num_t, z=z, num_k=K)
    return pl.pallas_call(
        kernel,
        grid=(F // block_f,),
        in_specs=[
            pl.BlockSpec((block_f, K), lambda i: (i, 0)),
            pl.BlockSpec((1, K), lambda i: (0, 0)),
            pl.BlockSpec((1, K), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_f,), lambda i: (i,)),
            pl.BlockSpec((block_f,), lambda i: (i,)),
            pl.BlockSpec((block_f, K), lambda i: (i, 0)),
            pl.BlockSpec((block_f, K), lambda i: (i, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((F,), jnp.float32),
                   jax.ShapeDtypeStruct((F,), jnp.float32),
                   jax.ShapeDtypeStruct((F, K), jnp.float32),
                   jax.ShapeDtypeStruct((F, K), jnp.float32)],
        interpret=interpret,
    )(W, mus2, sgs2)
