"""Fused RMSNorm Pallas kernel (rows tiled into VMEM, f32 accumulation)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["rmsnorm"]


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)  # (br, D)
    w = w_ref[...].astype(jnp.float32)  # (1, D)
    rms = jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    o_ref[...] = (x * rms * w).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x, w, *, eps: float = 1e-6, block_rows: int = 256, interpret: bool = False):
    """x: (..., D) normalized over the last axis; w: (D,) scale."""
    orig_shape = x.shape
    D = orig_shape[-1]
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, D)
    block_rows = min(block_rows, rows)
    pad = (-rows) % block_rows
    if pad:
        x2 = jnp.concatenate([x2, jnp.zeros((pad, D), x2.dtype)], 0)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=((rows + pad) // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
                  pl.BlockSpec((1, D), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows + pad, D), x.dtype),
        interpret=interpret,
    )(x2, w[None, :])
    return out[:rows].reshape(orig_shape)
