"""Sharded checkpointing with atomic commit and async writes."""
from .store import (CheckpointManager, latest_step, restore,
                    restore_pipeline, save, save_pipeline)

__all__ = ["CheckpointManager", "latest_step", "restore",
           "restore_pipeline", "save", "save_pipeline"]
