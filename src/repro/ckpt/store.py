"""Checkpointing: sharded npz + JSON metadata, atomic pointer, async writer.

Layout:
    <dir>/step_000123/arrays.npz      flattened pytree leaves (key = json path)
    <dir>/step_000123/meta.json       step, rng seed, scheduler posteriors, ...
    <dir>/LATEST                      atomic pointer file (rename-committed)

Restore is exact: pytree structure is rebuilt from the saved key paths and
every leaf is bit-compared in tests. The scheduler's NIG posteriors ride in
meta.json so a restarted job keeps its learned channel statistics (the paper's
on-the-fly estimates survive failures).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional, Tuple

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "CheckpointManager"]

_SEP = "/"


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten_like(template, flat: dict):
    paths_leaves = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths_leaves[0]:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = flat[key]
        assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(paths_leaves[1], leaves)


def save(directory: str, step: int, tree, meta: Optional[dict] = None) -> str:
    """Write checkpoint for ``step``; commit via atomic LATEST rename."""
    os.makedirs(directory, exist_ok=True)
    name = f"step_{step:08d}"
    tmp = os.path.join(directory, f".tmp_{name}")
    final = os.path.join(directory, name)
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    np.savez(os.path.join(tmp, "arrays.npz"), **_flatten(tree))
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump({"step": step, **(meta or {})}, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    ptr_tmp = os.path.join(directory, ".LATEST.tmp")
    with open(ptr_tmp, "w") as f:
        f.write(name)
    os.replace(ptr_tmp, os.path.join(directory, "LATEST"))
    return final


def latest_step(directory: str) -> Optional[int]:
    ptr = os.path.join(directory, "LATEST")
    if not os.path.exists(ptr):
        return None
    with open(ptr) as f:
        return int(f.read().strip().split("_")[-1])


def restore(directory: str, template, step: Optional[int] = None) -> Tuple[Any, dict]:
    """Load (tree, meta); ``template`` supplies structure/dtypes/shapes."""
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with np.load(os.path.join(path, "arrays.npz")) as z:
        flat = {k: z[k] for k in z.files}
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    return _unflatten_like(template, flat), meta


class CheckpointManager:
    """Interval-based async checkpointing with bounded retention."""

    def __init__(self, directory: str, interval: int = 100, keep: int = 3):
        self.dir = directory
        self.interval = interval
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def maybe_save(self, step: int, tree, meta: Optional[dict] = None,
                   blocking: bool = False) -> bool:
        if step % self.interval != 0:
            return False
        host_tree = jax.tree.map(np.asarray, tree)  # device->host before async
        if self._thread is not None:
            self._thread.join()

        def work():
            save(self.dir, step, host_tree, meta)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()
        return True

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(int(d.split("_")[-1]) for d in os.listdir(self.dir)
                       if d.startswith("step_"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)
