"""Checkpointing: sharded npz + JSON metadata, atomic pointer, async writer.

Layout:
    <dir>/step_000123/arrays.npz      flattened pytree leaves (key = json path)
    <dir>/step_000123/meta.json       step, rng seed, scheduler posteriors, ...
    <dir>/LATEST                      atomic pointer file (rename-committed)

Restore is exact: pytree structure is rebuilt from the saved key paths and
every leaf is bit-compared in tests. The scheduler's NIG posteriors ride in
meta.json so a restarted job keeps its learned channel statistics (the paper's
on-the-fly estimates survive failures).

Whole-pipeline checkpoints (:func:`save_pipeline` / :func:`restore_pipeline`)
bundle everything a partitioning loop owns into ONE manifest: the balancer's
state_dict (posteriors, family selection + hysteresis, cached solve, cadence
phase), any in-flight per-channel progress, and the autotune cache snapshot.

Kill/restore tick-parity contract: a replica killed after its step-t
checkpoint and restored from it produces a bitwise-identical step t+1 —
same weights, same family selection, same posterior update — because every
input to the next tick (balancer state, solver warm start, autotune plan
choice) is either in the manifest or deterministic code. Enforced by
``tests/test_fault.py``; breaking it means a failover replays a DIFFERENT
schedule than the primary would have run.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional, Tuple

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "save_pipeline",
           "restore_pipeline", "CheckpointManager"]

_SEP = "/"


def _pipeline_kind(balancer) -> str:
    """Manifest kind for a pipeline snapshot — dispatch is by state SHAPE.

    "engine" = the continuous-batching WorkflowEngine (restores against
    code-side templates), "workflow" = the per-stage WorkflowBalancer
    (restores against its DAG), "balancer" = any single-fleet decider with
    a ``UncertaintyAwareBalancer``-shaped state_dict (the batcher included).
    """
    name = type(balancer).__name__
    if name == "WorkflowEngine":
        return "engine"
    return "workflow" if name == "WorkflowBalancer" else "balancer"


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten_like(template, flat: dict):
    paths_leaves = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths_leaves[0]:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if key not in flat:
            raise ValueError(
                f"checkpoint restore: leaf {key!r} missing from the saved "
                f"arrays (template and checkpoint structures diverged; "
                f"saved keys: {sorted(flat)[:8]}...)")
        arr = flat[key]
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            # a bare assert here vanished under `python -O` and surfaced as
            # a reshape error three layers up — name the leaf and both shapes
            raise ValueError(
                f"checkpoint restore: leaf {key!r} shape mismatch — "
                f"expected {tuple(np.shape(leaf))} (template), found "
                f"{tuple(arr.shape)} (checkpoint); the run being restored "
                f"was saved with a different fleet/model shape")
        leaves.append(arr.astype(np.asarray(leaf).dtype))
    return jax.tree_util.tree_unflatten(paths_leaves[1], leaves)


def save(directory: str, step: int, tree, meta: Optional[dict] = None) -> str:
    """Write checkpoint for ``step``; commit via atomic LATEST rename."""
    os.makedirs(directory, exist_ok=True)
    name = f"step_{step:08d}"
    tmp = os.path.join(directory, f".tmp_{name}")
    final = os.path.join(directory, name)
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    np.savez(os.path.join(tmp, "arrays.npz"), **_flatten(tree))
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump({"step": step, **(meta or {})}, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    ptr_tmp = os.path.join(directory, ".LATEST.tmp")
    with open(ptr_tmp, "w") as f:
        f.write(name)
    os.replace(ptr_tmp, os.path.join(directory, "LATEST"))
    return final


def latest_step(directory: str) -> Optional[int]:
    """Step of the LATEST pointer, or None when there is no usable one.

    A corrupt or empty pointer (the crash the atomic rename protects against
    landed mid-write anyway — power loss between rename and fsync, or a
    truncated copy) falls back to the newest complete step directory on
    disk instead of raising: restore-after-crash is exactly when this path
    runs, and a garbage pointer must not make a good checkpoint unreachable.
    """
    ptr = os.path.join(directory, "LATEST")
    if os.path.exists(ptr):
        try:
            with open(ptr) as f:
                text = f.read().strip()
            if text:
                return int(text.split("_")[-1])
        except (OSError, ValueError):
            pass
    # pointer missing/corrupt: scan for complete step dirs (meta.json is
    # written last inside the tmp dir, so its presence marks completeness)
    if not os.path.isdir(directory):
        return None
    steps = []
    for d in os.listdir(directory):
        if d.startswith("step_") and os.path.exists(
                os.path.join(directory, d, "meta.json")):
            try:
                steps.append(int(d.split("_")[-1]))
            except ValueError:
                continue
    return max(steps) if steps else None


def restore(directory: str, template, step: Optional[int] = None) -> Tuple[Any, dict]:
    """Load (tree, meta); ``template`` supplies structure/dtypes/shapes."""
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with np.load(os.path.join(path, "arrays.npz")) as z:
        flat = {k: z[k] for k in z.files}
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    return _unflatten_like(template, flat), meta


def save_pipeline(directory: str, step: int, balancer, *,
                  inflight: Optional[dict] = None, autotune: bool = True,
                  tree=None, meta: Optional[dict] = None) -> str:
    """One crash-consistent manifest for a whole partitioning pipeline.

    Bundles, in a single atomically-committed step directory:

    * ``balancer.state_dict()`` — posteriors, family selection/hysteresis,
      cached solve + key, refresh cadence phase, failure sets;
    * ``inflight`` — per-channel progress of the currently executing step
      ({"done": ..., "failed": ...} or any JSON-serializable dict), so a
      restore can re-price the remaining work via ``resolve_inflight``;
    * the process-wide autotune cache (``kernels.autotune.cache_state()``),
      so the restored replica re-runs the SAME kernel plans — plan choice
      affects float reduction order, and tick parity is bitwise;
    * optionally an arbitrary array ``tree`` (model state) alongside.

    See the module docstring for the kill/restore tick-parity contract this
    manifest exists to uphold. Restore with :func:`restore_pipeline`.
    """
    from ..kernels import autotune as _autotune  # lazy: layering
    from ..obs import events as _obs_events  # lazy: layering
    manifest = {
        "kind": _pipeline_kind(balancer),
        "balancer": balancer.state_dict(),
        "inflight": inflight,
        "autotune": _autotune.cache_state() if autotune else None,
    }
    path = save(directory, step, tree if tree is not None else {},
                meta={**(meta or {}), "pipeline": manifest})
    _obs_events.ckpt_save(step, manifest["kind"], path)
    return path


def restore_pipeline(directory: str, *, dag=None, template=None,
                     templates=None, step: Optional[int] = None,
                     autotune: bool = True):
    """Restore a :func:`save_pipeline` manifest.

    Returns ``(balancer, inflight, meta)`` (plus the restored ``tree`` in
    ``meta["tree"]`` when a ``template`` is supplied). ``dag`` is required
    for workflow-kind checkpoints and ``templates`` (name -> StageDAG) for
    engine-kind ones — graph structure is code-side configuration, only the
    learned/derived state rides in the manifest. When ``autotune`` is True
    the saved kernel-plan cache is loaded into the process so the next tick
    runs identical plans (the bitwise half of the parity contract).
    """
    from ..sched.balancer import (UncertaintyAwareBalancer,
                                  WorkflowBalancer)  # lazy: layering
    tree, meta = restore(directory, template if template is not None else {},
                         step=step)
    manifest = meta.get("pipeline")
    if manifest is None:
        raise ValueError(
            f"checkpoint in {directory} has no 'pipeline' manifest — it was "
            f"written by save(), not save_pipeline()")
    if manifest["kind"] == "engine":
        from ..serve.engine import WorkflowEngine  # lazy: layering
        if templates is None:
            raise ValueError("engine-kind checkpoint needs the templates= "
                             "mapping the engine was built against")
        balancer = WorkflowEngine.from_state_dict(manifest["balancer"],
                                                  templates)
    elif manifest["kind"] == "workflow":
        if dag is None:
            raise ValueError("workflow-kind checkpoint needs the dag= the "
                             "balancer was built against")
        balancer = WorkflowBalancer.from_state_dict(manifest["balancer"], dag)
    else:
        balancer = UncertaintyAwareBalancer.from_state_dict(
            manifest["balancer"])
    if autotune and manifest.get("autotune"):
        from ..kernels import autotune as _autotune  # lazy: layering
        _autotune.load_cache_state(manifest["autotune"])
    from ..obs import events as _obs_events  # lazy: layering
    _obs_events.ckpt_restore(int(meta.get("step", -1)), manifest["kind"],
                             directory)
    if template is not None:
        meta = dict(meta)
        meta["tree"] = tree
    return balancer, manifest.get("inflight"), meta


class CheckpointManager:
    """Interval-based async checkpointing with bounded retention."""

    def __init__(self, directory: str, interval: int = 100, keep: int = 3):
        self.dir = directory
        self.interval = interval
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def maybe_save(self, step: int, tree, meta: Optional[dict] = None,
                   blocking: bool = False) -> bool:
        if step % self.interval != 0:
            return False
        host_tree = jax.tree.map(np.asarray, tree)  # device->host before async
        if self._thread is not None:
            self._thread.join()

        def work():
            save(self.dir, step, host_tree, meta)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()
        return True

    def maybe_save_pipeline(self, step: int, balancer, *,
                            inflight: Optional[dict] = None, tree=None,
                            meta: Optional[dict] = None,
                            blocking: bool = False) -> bool:
        """Interval-gated :func:`save_pipeline` through the async writer.

        The balancer state_dict and autotune snapshot are captured on the
        CALLER's thread — the manifest reflects this exact tick boundary
        even if the balancer keeps mutating while the write runs.
        """
        if step % self.interval != 0:
            return False
        from ..kernels import autotune as _autotune  # lazy: layering
        manifest = {
            "kind": _pipeline_kind(balancer),
            "balancer": balancer.state_dict(),
            "inflight": inflight,
            "autotune": _autotune.cache_state(),
        }
        host_tree = (jax.tree.map(np.asarray, tree)
                     if tree is not None else {})
        if self._thread is not None:
            self._thread.join()

        def work():
            save(self.dir, step, host_tree,
                 meta={**(meta or {}), "pipeline": manifest})
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()
        return True

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(int(d.split("_")[-1]) for d in os.listdir(self.dir)
                       if d.startswith("step_"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)
