"""Chaos harness: drive a partitioning loop through churn and crash cycles.

Two fault axes, composable in one trace:

* **Channel churn** — the ClusterSim churn schedule (fail / throttle /
  recover / load regimes) hits the fleet mid-trace; the balancer reacts by
  re-solving over the survivors (``resolve_inflight``) so dead channels get
  exactly zero share while their posteriors survive for re-admission.
* **Process crashes** — every ``kill_every`` ticks the live balancer AND the
  sim-world snapshot are thrown away and rebuilt from the last
  ``ckpt.store.save_pipeline`` manifest, exactly what a failover replica
  does. With ``verify_parity=True`` the harness computes the would-be
  survivor's next decision before the kill and asserts the restored
  replica's decision is bitwise identical — the kill/restore tick-parity
  contract (see ckpt/store.py), enforced continuously instead of once in a
  unit test.

The harness is the engine under ``tests/test_fault.py``'s chaos smoke and
the ``scripts/ci.sh`` chaos tier; ``benchmarks/fault_trace.py`` uses the
same churn machinery but scores solver quality instead of crash safety.
"""
from __future__ import annotations

import os
import tempfile
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np

from ..ckpt.store import restore_pipeline, save_pipeline
from ..obs import names as obs_names
from ..obs import trace as obs
from ..sched.balancer import UncertaintyAwareBalancer
from .cluster import ClusterSim, WorkflowSim

__all__ = ["ChaosResult", "run_chaos_trace", "run_workflow_chaos_trace"]


@dataclass
class ChaosResult:
    """Outcome of one chaos trace (all fields JSON-serializable)."""

    ticks: int
    kills: int
    parity_checks: int          # kill/restore decisions compared bitwise
    joins: List[float]          # per-tick join latencies
    events: List[Tuple[int, str, str]]  # (tick, kind, detail)
    final_failed: List[int] = field(default_factory=list)

    def summary(self) -> dict:
        return {
            "ticks": self.ticks, "kills": self.kills,
            "parity_checks": self.parity_checks,
            "mean_join": float(np.mean(self.joins)) if self.joins else 0.0,
            "events": len(self.events),
            "final_failed": list(self.final_failed),
        }


def _decide(bal: UncertaintyAwareBalancer, sim: ClusterSim) -> np.ndarray:
    """One tick's split: the steady-state solve, re-solved over survivors
    when the sim shows dead channels (zero sunk work — each tick is a fresh
    instance of the whole job)."""
    failed = [i for i, c in enumerate(sim.channels) if c.failed]
    if failed:
        return bal.resolve_inflight(np.zeros(bal.num_channels),
                                    failed=failed)
    return bal.weights()


def run_chaos_trace(num_channels: int = 6, ticks: int = 24,
                    kill_every: int = 8, churn=None, seed: int = 0,
                    dist: str = "normal", family="normal",
                    lam: float = 0.05, ckpt_dir: Optional[str] = None,
                    verify_parity: bool = True) -> ChaosResult:
    """Run a partitioned trace under churn + kill/restore cycles.

    ``churn``: iterable of ``(step, action, idx, value)`` tuples fed to
    :meth:`ClusterSim.schedule_churn` (value may be None for fail/recover).
    ``kill_every=0`` disables crashes (churn-only trace). Every tick is
    checkpointed (manifest = balancer state + sim-world snapshot), so a
    kill at tick t restores the tick-t boundary exactly.

    Raises AssertionError if ``verify_parity`` and a restored replica's
    next decision diverges bitwise from the would-be survivor's.
    """
    own_dir = ckpt_dir is None
    if own_dir:
        tmp = tempfile.TemporaryDirectory(prefix="repro_chaos_")
        ckpt_dir = tmp.name
    sim = ClusterSim.heterogeneous(num_channels, seed=seed, dist=dist)
    for ev in (churn or ()):
        step, action, idx, value = (tuple(ev) + (None, None))[:4]
        sim.schedule_churn(step, action, idx, value)
    bal = UncertaintyAwareBalancer(num_channels=num_channels, lam=lam,
                                   family=family, explore=0.0)
    joins: List[float] = []
    events: List[Tuple[int, str, str]] = []
    kills = parity = 0
    try:
        for t in range(1, ticks + 1):
            w = _decide(bal, sim)
            join_t, durs = sim.run_step(w)
            bal.observe(durs, w)
            joins.append(float(join_t))
            save_pipeline(ckpt_dir, t, bal,
                          inflight={"sim": sim.state_dict(),
                                    "tick": t})
            if kill_every and t % kill_every == 0 and t < ticks:
                with obs.span(obs_names.SPAN_CHAOS_CYCLE, step=t,
                              kind="balancer", parity=verify_parity):
                    if verify_parity:
                        # survivor's next decision, computed on an isolated
                        # clone so the live balancer's caches stay untouched
                        survivor = UncertaintyAwareBalancer.from_state_dict(
                            bal.state_dict())
                        sim_sv = ClusterSim.from_state_dict(sim.state_dict())
                        w_expect = _decide(survivor, sim_sv)
                    # the crash: drop the live objects, restore the manifest
                    bal2, inflight, _ = restore_pipeline(ckpt_dir)
                    sim2 = ClusterSim.from_state_dict(inflight["sim"])
                    if verify_parity:
                        w_got = _decide(
                            UncertaintyAwareBalancer.from_state_dict(
                                bal2.state_dict()),
                            ClusterSim.from_state_dict(sim2.state_dict()))
                        if not np.array_equal(np.asarray(w_expect),
                                              np.asarray(w_got)):
                            raise AssertionError(
                                f"kill/restore parity broken at tick {t}: "
                                f"survivor {w_expect} vs replica {w_got}")
                        parity += 1
                    bal, sim = bal2, sim2
                    kills += 1
                    events.append((t, "kill_restore",
                                   f"restored step {t} from {ckpt_dir}"))
    finally:
        if own_dir:
            tmp.cleanup()
    return ChaosResult(
        ticks=ticks, kills=kills, parity_checks=parity, joins=joins,
        events=events,
        final_failed=[i for i, c in enumerate(sim.channels) if c.failed])


def _sync_workflow_failures(bal, sim: WorkflowSim) -> None:
    """Propagate the sim's channel health into the workflow balancer —
    the heartbeat a real scheduler gets, stage-addressed."""
    failed = bal.failed_channels()
    for name, stage_sim in sim.stage_sims.items():
        known = set(failed.get(name, ()))
        for i, c in enumerate(stage_sim.channels):
            if c.failed and i not in known:
                bal.handle_failure(name, i)
            elif not c.failed and i in known:
                bal.handle_recovery(name, i)


def run_workflow_chaos_trace(dag, ticks: int = 12, kill_every: int = 4,
                             churn=None, seed: int = 0, family="normal",
                             lam_var: float = 0.0,
                             ckpt_dir: Optional[str] = None,
                             verify_parity: bool = True) -> ChaosResult:
    """The DAG twin of :func:`run_chaos_trace`: a :class:`WorkflowBalancer`
    driving a :class:`WorkflowSim` through stage-addressed churn schedules
    (``WorkflowSim.schedule_churn`` — fail/throttle/recover/set_load firing
    before the step's draws) and kill/restore cycles through the
    workflow-kind checkpoint manifest.

    ``churn``: iterable of ``(step, action, stage, idx, value)`` tuples
    (stage None broadcasts set_load workflow-wide). Joins are per-tick DAG
    makespans. Parity compares the restored replica's next full weights
    dict bitwise against the would-be survivor's.
    """
    from ..sched.balancer import WorkflowBalancer  # lazy: layering

    own_dir = ckpt_dir is None
    if own_dir:
        tmp = tempfile.TemporaryDirectory(prefix="repro_chaos_wf_")
        ckpt_dir = tmp.name
    sim = WorkflowSim.from_dag(dag, seed=seed)
    for ev in (churn or ()):
        step, action, stage, idx, value = (tuple(ev) + (None, None, None))[:5]
        sim.schedule_churn(step, action, stage=stage, idx=idx, value=value)
    bal = WorkflowBalancer(dag, lam_var=lam_var, family=family,
                           pgd_steps=12, restarts=0, num_t=128)
    joins: List[float] = []
    events: List[Tuple[int, str, str]] = []
    kills = parity = 0

    def _decide_wf(b, s):
        _sync_workflow_failures(b, s)
        return b.weights()

    try:
        for t in range(1, ticks + 1):
            ws = _decide_wf(bal, sim)
            makespan, _, durs = sim.run_dag_step(dag, ws)
            bal.observe(durs, ws)
            joins.append(float(makespan))
            save_pipeline(ckpt_dir, t, bal,
                          inflight={"sim": sim.state_dict(), "tick": t})
            if kill_every and t % kill_every == 0 and t < ticks:
                with obs.span(obs_names.SPAN_CHAOS_CYCLE, step=t,
                              kind="workflow", parity=verify_parity):
                    if verify_parity:
                        survivor = WorkflowBalancer.from_state_dict(
                            bal.state_dict(), dag)
                        sim_sv = WorkflowSim.from_state_dict(sim.state_dict())
                        w_expect = _decide_wf(survivor, sim_sv)
                    bal2, inflight, _ = restore_pipeline(ckpt_dir, dag=dag)
                    sim2 = WorkflowSim.from_state_dict(inflight["sim"])
                    if verify_parity:
                        w_got = _decide_wf(
                            WorkflowBalancer.from_state_dict(
                                bal2.state_dict(), dag),
                            WorkflowSim.from_state_dict(sim2.state_dict()))
                        for name in dag.names:
                            if not np.array_equal(np.asarray(w_expect[name]),
                                                  np.asarray(w_got[name])):
                                raise AssertionError(
                                    f"workflow kill/restore parity broken at "
                                    f"tick {t}, stage {name!r}: survivor "
                                    f"{w_expect[name]} vs replica "
                                    f"{w_got[name]}")
                        parity += 1
                    bal, sim = bal2, sim2
                    kills += 1
                    events.append((t, "kill_restore",
                                   f"restored step {t} from {ckpt_dir}"))
    finally:
        if own_dir:
            tmp.cleanup()
    final_failed = sorted({(name, i)
                           for name, s in sim.stage_sims.items()
                           for i, c in enumerate(s.channels) if c.failed})
    return ChaosResult(
        ticks=ticks, kills=kills, parity_checks=parity, joins=joins,
        events=events,
        final_failed=[f"{name}:{i}" for name, i in final_failed])
