"""Cluster simulator: stochastic channels for paper-experiment reproduction."""
from .cluster import Channel, ClusterSim, WorkflowSim

__all__ = ["Channel", "ClusterSim", "WorkflowSim"]
