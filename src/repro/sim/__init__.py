"""Cluster simulator: stochastic channels for paper-experiment reproduction."""
from .cluster import Channel, ClusterSim

__all__ = ["Channel", "ClusterSim"]
