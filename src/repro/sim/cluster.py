"""Discrete cluster simulator: channels with stochastic service rates.

Reproduces the paper's experimental conditions (contended VMs, jittery WAN
paths) without hardware: channel i processing work fraction w completes in
``w * rate`` where rate ~ the channel's distribution. Three per-channel
regimes generate ground truth for the corresponding solver families:

  * ``normal``    — the paper's model (contended compute),
  * ``lognormal`` — heavy-tailed WAN transfer times, moment-matched to
                    (mu, sigma) exactly like ``core.distributions.LogNormal``,
  * ``drift``     — within-work straggle: the effective rate inflates over
                    the executed share, T = w*r + rho*mu*w^2/2 (matching the
                    drift family's mean model E[T] = w mu (1 + rho w/2)),

plus slow per-step mu drift (multi-tenant hotspots) and failure injection for
the fault-tolerance benchmarks.

Used by: benchmarks/fig34_convex_opt.py, fig56_file_transfer.py,
cluster_scale.py, and the examples. Everything is seeded and reproducible;
``run_step`` optionally takes an explicit rng/seed so fleet benchmarks can
replay identical traces across policies.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple, Union

import numpy as np

from ..core.distributions import lognormal_shape_np
from ..obs import events as obs_events
from ..obs import names as obs_names
from ..obs import trace as obs

__all__ = ["Channel", "ClusterSim", "WorkflowSim"]

_DISTS = ("normal", "lognormal", "drift", "defective")

# churn-schedule verbs run_step understands (fault-tolerance traces)
_CHURN_ACTIONS = ("fail", "recover", "throttle", "set_load")


@dataclass
class Channel:
    mu: float                      # mean seconds per unit work
    sigma: float                   # std seconds per unit work
    dist: str = "normal"           # normal | lognormal | drift | defective
    drift: float = 0.0             # per-step multiplicative mu drift (hotspots)
    rho: float = 0.0               # within-work drift rate (dist == "drift")
    fail_p: float = 0.0            # per-attempt failure prob (dist=="defective")
    resume_frac: float = 1.0       # fraction of an attempt a failure costs
    failed: bool = False

    def __post_init__(self):
        if self.dist not in _DISTS:
            raise ValueError(f"dist must be one of {_DISTS}, got {self.dist!r}")
        if not 0.0 <= self.fail_p <= 1.0:
            raise ValueError(f"fail_p must lie in [0, 1], got {self.fail_p}")
        if not 0.0 <= self.resume_frac <= 1.0:
            raise ValueError(f"resume_frac must lie in [0, 1], "
                             f"got {self.resume_frac}")

    def sample(self, rng: np.random.Generator, work: float) -> float:
        """Single-channel draw (the vectorized path in run_step is primary)."""
        if self.failed or work <= 0:
            return 0.0
        if self.dist == "lognormal":
            s_l, base = lognormal_shape_np(self.mu, self.sigma)
            r = rng.lognormal(base, s_l)
        else:
            r = rng.normal(self.mu, self.sigma)
        dur = work * r
        if self.dist == "drift":
            dur += 0.5 * self.rho * self.mu * work * work
        elif self.dist == "defective" and self.fail_p > 0:
            # physical retry process: geometric number of failed attempts,
            # each costing resume_frac of an attempt's (random) duration
            nfail = int(rng.geometric(1.0 - min(self.fail_p, 1.0 - 1e-9))) - 1
            lost = nfail * self.mu + np.sqrt(nfail) * self.sigma \
                * rng.standard_normal()
            dur += self.resume_frac * work * lost
        return max(dur, 1e-9)


@dataclass
class ClusterSim:
    """``load_factor`` is a fleet-wide multiplicative service-time regime
    (1.0 = nominal): bursty-traffic benchmarks switch it mid-trace
    (:meth:`set_load`) to model congestion regimes on top of the per-channel
    stochastic rates — the mean AND the spread scale together, exactly what
    a contended VM / saturated WAN does."""

    channels: list
    seed: int = 0
    step_count: int = 0
    load_factor: float = 1.0
    churn: dict = field(default_factory=dict)  # step -> [(action, idx, value)]
    rng: np.random.Generator = field(init=False)

    def __post_init__(self):
        self.rng = np.random.default_rng(self.seed)

    def set_load(self, factor: float):
        """Switch the fleet-wide congestion regime (regime-switching traces)."""
        if factor <= 0:
            raise ValueError(f"load factor must be positive, got {factor}")
        self.load_factor = float(factor)

    @classmethod
    def heterogeneous(cls, n: int, mu_range=(10.0, 40.0), cov_range=(0.02, 0.3),
                      seed: int = 0, dist: str = "normal",
                      rho_range=(0.1, 0.8),
                      fail_range=(0.02, 0.15)) -> "ClusterSim":
        """Random fleet; ``dist`` selects the regime (drift draws per-channel
        rho from ``rho_range``; defective draws per-channel attempt-failure
        probability from ``fail_range``)."""
        rng = np.random.default_rng(seed)
        chans = []
        for _ in range(n):
            mu = rng.uniform(*mu_range)
            sigma = mu * rng.uniform(*cov_range)
            rho = rng.uniform(*rho_range) if dist == "drift" else 0.0
            fp = rng.uniform(*fail_range) if dist == "defective" else 0.0
            chans.append(Channel(mu=mu, sigma=sigma, dist=dist, rho=rho,
                                 fail_p=fp))
        return cls(channels=chans, seed=seed + 1)

    # ------------------------------------------------------------- churn
    def schedule_churn(self, step: int, action: str, idx: Optional[int] = None,
                       value: Optional[float] = None):
        """Queue a churn event for the ``step``-th future :meth:`run_step`
        call (1-based, matching ``step_count`` after its increment).

        Actions: ``"fail"`` / ``"recover"`` (channel ``idx`` dies / returns),
        ``"throttle"`` (channel ``idx`` slows by factor ``value``),
        ``"set_load"`` (fleet-wide congestion regime switches to ``value``).
        Events fire BEFORE the step's draws, so a channel failed at step t
        contributes nothing to step t — the same visibility a heartbeat
        timeout gives a real scheduler.
        """
        if action not in _CHURN_ACTIONS:
            raise ValueError(f"churn action must be one of {_CHURN_ACTIONS}, "
                             f"got {action!r}")
        if action in ("fail", "recover", "throttle") and idx is None:
            raise ValueError(f"churn action {action!r} needs a channel idx")
        if action in ("throttle", "set_load") and value is None:
            raise ValueError(f"churn action {action!r} needs a value")
        self.churn.setdefault(int(step), []).append((action, idx, value))

    def _apply_churn(self):
        for action, idx, value in self.churn.pop(self.step_count, ()):
            obs_events.churn(action, -1 if idx is None else idx, "sim",
                             detail=value)
            if action == "fail":
                self.inject_failure(idx)
            elif action == "recover":
                self.recover(idx)
            elif action == "throttle":
                self.inject_slowdown(idx, value)
            else:
                self.set_load(value)

    @property
    def true_params(self) -> Tuple[np.ndarray, np.ndarray]:
        return (np.asarray([c.mu for c in self.channels]),
                np.asarray([c.sigma for c in self.channels]))

    def _resolve_rng(self, rng) -> np.random.Generator:
        if rng is None:
            return self.rng
        if isinstance(rng, np.random.Generator):
            return rng
        return np.random.default_rng(rng)

    @obs.traced(obs_names.SPAN_SIM_STEP, sim="cluster")
    def run_step(self, weights,
                 rng: Union[None, int, np.random.Generator] = None
                 ) -> Tuple[float, np.ndarray]:
        """Execute one partitioned step: returns (join_time, per-channel durations).

        join_time = max over active channels (the paper's completion time).

        Boundary conventions (this is the host edge of the stack): ``weights``
        may be any array-like — numpy, jax arrays, lists — and need not be
        normalized; they are converted with ``np.asarray`` and scaled to sum
        to 1 here (all-zero weights stay zero). ``rng`` optionally overrides
        the simulator's own stream — pass a seed int or a Generator to make a
        single step reproducible independent of sim history (fleet benchmarks
        replaying one trace across policies).

        All draws are vectorized — at 1024 channels a per-channel Python loop
        dominated the fleet benchmarks, not the solver. All-Normal fleets take
        exactly one vectorized draw (stream-compatible with the pre-family
        simulator); mixed fleets add one lognormal draw for those channels.
        """
        self.step_count += 1
        self._apply_churn()
        r = self._resolve_rng(rng)
        w = np.asarray(weights, np.float64).reshape(-1)
        if w.shape[0] != len(self.channels):
            raise ValueError(f"got {w.shape[0]} weights for "
                             f"{len(self.channels)} channels")
        total = w.sum()
        if total > 0:
            w = w / total
        mu = np.asarray([c.mu for c in self.channels])
        sigma = np.asarray([c.sigma for c in self.channels])
        active = np.asarray([not c.failed for c in self.channels]) & (w > 0)
        rates = r.normal(mu, sigma)
        ln_mask = np.asarray([c.dist == "lognormal" for c in self.channels])
        if ln_mask.any():
            s_l, base = lognormal_shape_np(mu, sigma)
            rates = np.where(ln_mask, r.lognormal(base, s_l), rates)
        durs = w * rates
        rho = np.asarray([c.rho if c.dist == "drift" else 0.0
                          for c in self.channels])
        if rho.any():
            durs = durs + 0.5 * rho * mu * w * w
        pf = np.asarray([c.fail_p if c.dist == "defective" else 0.0
                         for c in self.channels])
        if pf.any():
            # retry inflation: geometric failed-attempt count per channel,
            # each failure costing resume_frac of an attempt's random length
            # (all-normal fleets take zero extra draws — stream-compatible)
            lam = np.asarray([c.resume_frac for c in self.channels])
            q = np.clip(1.0 - pf, 1e-9, 1.0)
            nfail = r.geometric(q) - 1
            lost = nfail * mu + np.sqrt(nfail) * sigma \
                * r.standard_normal(len(self.channels))
            durs = durs + np.where(pf > 0, lam * w * lost, 0.0)
        if self.load_factor != 1.0:  # congestion regime: times scale fleet-wide
            durs = durs * self.load_factor
        durs = np.where(active, np.maximum(durs, 1e-9), 0.0)
        for c in self.channels:  # slow drift (multi-tenant hotspots)
            if c.drift:
                c.mu *= (1.0 + c.drift)
        return float(durs.max(initial=0.0)), durs

    # ------------------------------------------------------------ persistence
    def state_dict(self) -> dict:
        """Full world snapshot — channel physics, churn queue AND the rng
        bit-generator state, so a restored sim replays the exact trace the
        dead one would have produced (the sim side of the kill/restore
        tick-parity contract)."""
        return {
            "seed": self.seed,
            "step_count": self.step_count,
            "load_factor": self.load_factor,
            "churn": {str(k): [list(e) for e in v]
                      for k, v in self.churn.items()},
            "channels": [{
                "mu": float(c.mu), "sigma": float(c.sigma), "dist": c.dist,
                "drift": float(c.drift), "rho": float(c.rho),
                "fail_p": float(c.fail_p),
                "resume_frac": float(c.resume_frac), "failed": bool(c.failed),
            } for c in self.channels],
            "rng_state": self.rng.bit_generator.state,
        }

    @classmethod
    def from_state_dict(cls, d: dict) -> "ClusterSim":
        sim = cls(channels=[Channel(**c) for c in d["channels"]],
                  seed=d.get("seed", 0),
                  step_count=d.get("step_count", 0),
                  load_factor=d.get("load_factor", 1.0),
                  churn={int(k): [tuple(e) for e in v]
                         for k, v in d.get("churn", {}).items()})
        if d.get("rng_state") is not None:
            sim.rng.bit_generator.state = d["rng_state"]
        return sim

    def inject_failure(self, idx: int):
        self.channels[idx].failed = True

    def inject_slowdown(self, idx: int, factor: float):
        self.channels[idx].mu *= factor
        self.channels[idx].sigma *= factor

    def recover(self, idx: int, mu: Optional[float] = None,
                sigma: Optional[float] = None):
        c = self.channels[idx]
        c.failed = False
        if mu is not None:
            c.mu = mu
        if sigma is not None:
            c.sigma = sigma


@dataclass
class WorkflowSim:
    """DAG-trace generator: one ClusterSim fleet per workflow stage.

    Ground truth for the ``repro.workflow`` subsystem: a stage's release
    time is driven by its upstream completions (max over predecessors), its
    duration by its own stochastic fleet, and the trace's makespan is the
    max over sink completions — the discrete-event twin of
    ``StageDAG.compose_moments``, with no Gaussian-max approximation.

    ``stage_sims`` maps stage name -> ClusterSim. Stages execute in the
    DAG's topological order with a shared rng stream when ``rng`` is passed
    (reproducible traces independent of per-stage sim history — the same
    convention as ``ClusterSim.run_step``).
    """

    stage_sims: dict
    seed: int = 0
    step_count: int = 0
    # step -> [(action, stage, idx, value)] — the DAG twin of ClusterSim.churn
    churn: dict = field(default_factory=dict)

    @classmethod
    def from_dag(cls, dag, seed: int = 0) -> "WorkflowSim":
        """Fleet physics matched to the DAG's stage statistics: stage s gets
        channels with exactly its (mus, sigmas) under its family's regime
        (empirical-family stages fall back to the moment-matched normal —
        the mixture is an estimator-side object, not a generator)."""
        sims = {}
        for i, s in enumerate(dag.stages):
            dist = s.dist_id if s.dist_id in _DISTS else "normal"
            rho = np.zeros(s.k)
            fail_p, resume = np.zeros(s.k), np.ones(s.k)
            if dist in ("drift", "defective"):
                from ..core.distributions import resolve_family
                ex = np.asarray(resolve_family(s.family, s.k)[1], np.float64)
                if dist == "drift":
                    rho = ex[0]
                else:
                    fail_p, resume = ex[0], ex[1]
            chans = [Channel(mu=float(s.mus[j]), sigma=float(s.sigmas[j]),
                             dist=dist, rho=float(rho[j]),
                             fail_p=float(fail_p[j]),
                             resume_frac=float(resume[j]))
                     for j in range(s.k)]
            sims[s.name] = ClusterSim(channels=chans, seed=seed + 1 + i)
        return cls(stage_sims=sims, seed=seed)

    # ------------------------------------------------------------- churn
    def schedule_churn(self, step: int, action: str,
                       stage: Optional[str] = None, idx: Optional[int] = None,
                       value: Optional[float] = None):
        """Queue a churn event for the ``step``-th future :meth:`tick`
        (1-based — :meth:`run_dag_step` and the serving engine both tick
        once per step), mirroring :meth:`ClusterSim.schedule_churn` with a
        stage address in front: ``"fail"`` / ``"recover"`` / ``"throttle"``
        hit channel ``idx`` of ``stage``'s fleet; ``"set_load"`` switches
        ``stage``'s congestion regime, or — with ``stage=None`` — every
        stage fleet at once (workflow-wide regime switches, the bursty
        serving benchmark's knob). Events fire BEFORE the step's draws,
        exactly like the single-fleet schedule.
        """
        if action not in _CHURN_ACTIONS:
            raise ValueError(f"churn action must be one of {_CHURN_ACTIONS}, "
                             f"got {action!r}")
        if stage is not None and stage not in self.stage_sims:
            raise ValueError(f"unknown stage {stage!r} "
                             f"(stages: {sorted(self.stage_sims)})")
        if action in ("fail", "recover", "throttle"):
            if stage is None:
                raise ValueError(f"churn action {action!r} needs a stage")
            if idx is None:
                raise ValueError(f"churn action {action!r} needs a "
                                 f"channel idx")
        if action in ("throttle", "set_load") and value is None:
            raise ValueError(f"churn action {action!r} needs a value")
        self.churn.setdefault(int(step), []).append((action, stage, idx,
                                                     value))

    @obs.traced(obs_names.SPAN_SIM_STEP, sim="workflow")
    def tick(self):
        """Advance the workflow clock one step and fire due churn events
        before the step's draws. Called at the top of :meth:`run_dag_step`;
        the serving engine calls it directly (one tick per engine tick even
        when many instances execute within it)."""
        self.step_count += 1
        for action, stage, idx, value in self.churn.pop(self.step_count, ()):
            obs_events.churn(action, -1 if idx is None else idx, "sim",
                             detail=(stage if stage is not None else value))
            targets = ([self.stage_sims[stage]] if stage is not None
                       else list(self.stage_sims.values()))
            for sim in targets:
                if action == "fail":
                    sim.inject_failure(idx)
                elif action == "recover":
                    sim.recover(idx)
                elif action == "throttle":
                    sim.inject_slowdown(idx, value)
                else:
                    sim.set_load(value)

    def set_load(self, factor: float, stage: Optional[str] = None):
        """Immediate congestion-regime switch on one stage fleet or, with
        ``stage=None``, on every stage fleet (the scheduled counterpart is
        ``schedule_churn(step, "set_load", value=...)``)."""
        targets = ([self.stage_sims[stage]] if stage is not None
                   else self.stage_sims.values())
        for sim in targets:
            sim.set_load(factor)

    def run_dag_step(self, dag, weights: dict,
                     rng: Union[None, int, np.random.Generator] = None):
        """Execute one workflow instance.

        ``weights``: per-stage split vectors ({name: (K_s,)}).
        Returns ``(makespan, completions, durations)`` — completions the
        per-stage absolute finish times, durations the per-stage per-channel
        busy times. The invariant ``completion[v] >= completion[u]`` holds
        for every edge (u, v) by construction (release = max over preds).
        """
        self.tick()
        r = (np.random.default_rng(rng) if isinstance(rng, int) else rng)
        completions, durations = {}, {}
        for name in dag.topo_order:
            release = max((completions[u] for u in dag.predecessors(name)),
                          default=0.0)
            join_t, durs = self.stage_sims[name].run_step(weights[name],
                                                          rng=r)
            completions[name] = release + join_t
            durations[name] = durs
        makespan = max(completions[n] for n in dag.sinks)
        return makespan, completions, durations

    # ------------------------------------------------------------ persistence
    def state_dict(self) -> dict:
        """Full workflow-world snapshot: every stage fleet's
        :meth:`ClusterSim.state_dict` (rng streams included) plus the
        workflow clock and pending churn queue — the sim side of the serving
        engine's kill/restore tick-parity contract."""
        return {
            "seed": self.seed,
            "step_count": self.step_count,
            "churn": {str(k): [list(e) for e in v]
                      for k, v in self.churn.items()},
            "stages": {name: sim.state_dict()
                       for name, sim in self.stage_sims.items()},
        }

    @classmethod
    def from_state_dict(cls, d: dict) -> "WorkflowSim":
        return cls(
            stage_sims={name: ClusterSim.from_state_dict(sd)
                        for name, sd in d["stages"].items()},
            seed=d.get("seed", 0),
            step_count=d.get("step_count", 0),
            churn={int(k): [tuple(e) for e in v]
                   for k, v in d.get("churn", {}).items()})
