"""Discrete cluster simulator: channels with stochastic service rates.

Reproduces the paper's experimental conditions (contended VMs, jittery WAN
paths) without hardware: channel i processing work fraction w completes in
``w * rate`` where rate ~ the channel's distribution (Normal by default,
log-normal / shifted regimes for robustness studies, plus drift and failure
injection for the fault-tolerance benchmarks).

Used by: benchmarks/fig34_convex_opt.py, fig56_file_transfer.py,
cluster_scale.py, and the examples. Everything is seeded and reproducible.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple

import numpy as np

__all__ = ["Channel", "ClusterSim"]


@dataclass
class Channel:
    mu: float                      # mean seconds per unit work
    sigma: float                   # std seconds per unit work
    dist: str = "normal"           # normal | lognormal
    drift: float = 0.0             # per-step multiplicative drift (hotspots)
    failed: bool = False

    def sample(self, rng: np.random.Generator, work: float) -> float:
        if self.failed or work <= 0:
            return 0.0
        if self.dist == "normal":
            r = rng.normal(self.mu, self.sigma)
        else:
            s2 = np.log1p((self.sigma / self.mu) ** 2)
            r = rng.lognormal(np.log(self.mu) - s2 / 2, np.sqrt(s2))
        return max(work * r, 1e-9)


@dataclass
class ClusterSim:
    channels: list
    seed: int = 0
    step_count: int = 0
    rng: np.random.Generator = field(init=False)

    def __post_init__(self):
        self.rng = np.random.default_rng(self.seed)

    @classmethod
    def heterogeneous(cls, n: int, mu_range=(10.0, 40.0), cov_range=(0.02, 0.3),
                      seed: int = 0, dist: str = "normal") -> "ClusterSim":
        rng = np.random.default_rng(seed)
        chans = []
        for _ in range(n):
            mu = rng.uniform(*mu_range)
            sigma = mu * rng.uniform(*cov_range)
            chans.append(Channel(mu=mu, sigma=sigma, dist=dist))
        return cls(channels=chans, seed=seed + 1)

    @property
    def true_params(self) -> Tuple[np.ndarray, np.ndarray]:
        return (np.asarray([c.mu for c in self.channels]),
                np.asarray([c.sigma for c in self.channels]))

    def run_step(self, weights: Sequence[float]) -> Tuple[float, np.ndarray]:
        """Execute one partitioned step: returns (join_time, per-channel durations).

        join_time = max over active channels (the paper's completion time).
        All-Normal fleets take a single vectorized draw — at 1024 channels the
        per-channel Python loop dominated the fleet benchmarks, not the solver.
        """
        self.step_count += 1
        w = np.asarray(weights, np.float64)
        if all(c.dist == "normal" for c in self.channels):
            mu = np.asarray([c.mu for c in self.channels])
            sigma = np.asarray([c.sigma for c in self.channels])
            active = np.asarray([not c.failed for c in self.channels]) & (w > 0)
            rates = self.rng.normal(mu, sigma)
            durs = np.where(active, np.maximum(w * rates, 1e-9), 0.0)
        else:
            durs = np.array([c.sample(self.rng, w[i])
                             for i, c in enumerate(self.channels)])
        for c in self.channels:  # slow drift (multi-tenant hotspots)
            if c.drift:
                c.mu *= (1.0 + c.drift)
        return float(durs.max(initial=0.0)), durs

    def inject_failure(self, idx: int):
        self.channels[idx].failed = True

    def inject_slowdown(self, idx: int, factor: float):
        self.channels[idx].mu *= factor
        self.channels[idx].sigma *= factor

    def recover(self, idx: int, mu: Optional[float] = None,
                sigma: Optional[float] = None):
        c = self.channels[idx]
        c.failed = False
        if mu is not None:
            c.mu = mu
        if sigma is not None:
            c.sigma = sigma
