"""Runtime sanitizer tier: NaN/Inf and domain-invariant checks, off by default.

Set ``REPRO_SANITIZE=1`` and every frontier entry point grows teeth:

* **Eager boundary checks** on concrete inputs (outside any trace):
  ``ops.frontier_moments`` / ``frontier_moments_with_grads`` validate that
  weights, statistics and family extras are finite, weights are nonnegative
  with row mass <= 1, and variances are nonnegative; the Clark-fold /
  quadrature oracles in ``core.maxstat`` validate their fold inputs and that
  the integration grid is monotone (tmax > 0). Violations raise
  :class:`SanitizeError` at the call site that introduced them — instead of
  a NaN surfacing three layers later as a mysteriously flat frontier.
* **In-trace checks** via ``jax.experimental.checkify``: the PGD solvers
  (``core.partitioner._pgd_multi``, ``workflow.solve._pgd_phase``) take a
  static ``sanitize`` flag that plants ``checkify.check`` calls inside the
  ``fori_loop`` bodies (iterate and gradient finiteness, simplex mass).
  Their public callers wrap the jitted solver in ``checkify.checkify`` via
  :func:`run_checked` — in-trace checks REQUIRE that functionalization; an
  unwrapped ``checkify.check`` inside jit is a trace-time error, which is
  why the flag defaults to False and flips only on the ``run_checked`` path.

The ``sanitizer`` CI tier (``scripts/ci.sh --full``) runs tier-1 fast under
``REPRO_SANITIZE=1``; checks cost one extra O(input) pass per boundary and
a retrace of the solvers, so the default tier keeps them off. See
docs/INVARIANTS.md for the invariant catalogue these checks enforce at
runtime (the lint rules enforce the static half).
"""
from __future__ import annotations

import os
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import checkify

__all__ = [
    "ENV_VAR",
    "SanitizeError",
    "enabled",
    "all_concrete",
    "assert_finite",
    "assert_nonneg",
    "assert_prob",
    "assert_weight_rows",
    "assert_monotone_grid",
    "check_frontier_inputs",
    "check_fold_inputs",
    "check_finite",
    "check_weight_rows",
    "run_checked",
]

ENV_VAR = "REPRO_SANITIZE"

# slack for float32 round-off: PGD projections land within ulps of the
# simplex, and finite-difference probes in tests nudge one weight by up to
# 1e-3 — the tolerance must sit clearly above that nudge, not equal to it
_MASS_ATOL = 5e-3
_NEG_ATOL = 1e-5


class SanitizeError(ValueError):
    """A sanitizer invariant failed on concrete (non-traced) values."""


def enabled() -> bool:
    """True when the sanitizer tier is switched on for this process."""
    return os.environ.get(ENV_VAR, "") == "1"


def all_concrete(*arrays) -> bool:
    """True when no argument is a JAX tracer (eager checks are legal)."""
    return not any(isinstance(a, jax.core.Tracer) for a in arrays)


# --------------------------------------------------------------------- eager
def assert_finite(name: str, *arrays) -> None:
    """Every element of every array is finite (no NaN/Inf)."""
    for a in arrays:
        a = np.asarray(a)
        if not np.all(np.isfinite(a)):
            bad = int(np.size(a) - np.sum(np.isfinite(a)))
            raise SanitizeError(
                f"sanitize: {name} contains {bad} non-finite value(s) "
                f"(shape {a.shape})")


def assert_nonneg(name: str, a, atol: float = _NEG_ATOL) -> None:
    """Elements >= -atol (variances, sigmas, weights)."""
    a = np.asarray(a)
    lo = float(a.min()) if a.size else 0.0
    if lo < -atol:
        raise SanitizeError(
            f"sanitize: {name} must be nonnegative, min is {lo:.3e}")


def assert_prob(name: str, a, atol: float = _NEG_ATOL) -> None:
    """Elements are probabilities: finite and inside [0 - atol, 1 + atol].

    Guards the defective family's failure probabilities (and any other
    survival/failure rate crossing a frontier boundary): a p outside [0, 1]
    silently flips the sign of the retry-inflation terms instead of failing.
    """
    assert_finite(name, a)
    a = np.asarray(a)
    if not a.size:
        return
    lo, hi = float(a.min()), float(a.max())
    if lo < -atol or hi > 1.0 + atol:
        raise SanitizeError(
            f"sanitize: {name} must lie in [0, 1], range is "
            f"[{lo:.3e}, {hi:.3e}]")


def assert_weight_rows(W, atol: float = _MASS_ATOL) -> None:
    """Candidate-split rows: finite, nonnegative, row mass <= 1 + atol.

    Row mass < 1 is legal (sub-splits and zero-padded stage rows assign the
    remainder nowhere); mass meaningfully above 1 means the caller skipped
    the simplex projection and every downstream moment is silently scaled.
    """
    assert_finite("W", W)
    assert_nonneg("W", W)
    sums = np.asarray(W).sum(axis=-1)
    hi = float(sums.max()) if sums.size else 0.0
    if hi > 1.0 + atol:
        raise SanitizeError(
            f"sanitize: split weights leave the simplex — max row mass "
            f"{hi:.6f} > 1 (off-simplex W scales every downstream moment)")


def assert_monotone_grid(name: str, ts) -> None:
    """Integration grid strictly increasing (a non-monotone CDF grid flips
    the sign of the survival quadrature)."""
    ts = np.asarray(ts)
    if ts.ndim and ts.shape[-1] > 1 and not np.all(np.diff(ts, axis=-1) > 0):
        raise SanitizeError(
            f"sanitize: {name} integration grid is not strictly increasing "
            f"(tmax <= 0 or non-finite reach)")


# repro: allow[RPA001] finiteness/positivity are family-agnostic; the one
# dist_id branch (defective's probability domain) falls back to the generic
# checks for every other family
def check_frontier_inputs(W, mus, sigmas, extra=None, dist_id=None) -> None:
    """Boundary validation for the frontier entry points (eager tier).

    No-op unless the sanitizer is enabled AND every input is concrete —
    inside a trace the in-trace checkify tier owns these invariants.
    ``dist_id`` turns on family-specific domain checks: for ``defective``,
    the failure probabilities (extra row 0) and the pricing fraction (row 1)
    must be probabilities, and the retry-conditioned moments (a, b) they
    induce must stay finite (a p at the q-floor inflates them by ~1e6 but
    never to Inf — anything non-finite means corrupted stats, not a hot
    channel).
    """
    arrays = (W, mus, sigmas) if extra is None else (W, mus, sigmas, extra)
    if not (enabled() and all_concrete(*arrays)):
        return
    assert_weight_rows(W)
    assert_finite("mus", mus)
    assert_finite("sigmas", sigmas)
    assert_nonneg("sigmas", sigmas)
    if extra is not None:
        assert_finite("family extra", extra)
        if dist_id == "defective":
            from repro.core.distributions import defective_moments_np
            ex = np.asarray(extra)
            p, lam = ex[0], ex[1]
            assert_prob("failure probabilities p", p)
            assert_prob("failure pricing lam", lam)
            a, b = defective_moments_np(np.asarray(mus), np.asarray(sigmas),
                                        p, lam)
            assert_finite("defective conditioned moments", a, b)


def check_fold_inputs(means, stds) -> None:
    """Clark-fold / quadrature oracle boundary validation (eager tier)."""
    if not (enabled() and all_concrete(means, stds)):
        return
    assert_finite("fold means", means)
    assert_finite("fold stds", stds)
    assert_nonneg("fold stds", stds)


# ------------------------------------------------------------------ in-trace
def check_finite(x, name: str) -> None:
    """checkify.check that ``x`` is all-finite. ONLY under run_checked."""
    checkify.check(jnp.all(jnp.isfinite(x)),
                   f"sanitize: {name} became non-finite inside the solve")


def check_weight_rows(W, name: str, atol: float = _MASS_ATOL) -> None:
    """checkify.check of the simplex invariant. ONLY under run_checked."""
    checkify.check(jnp.all(jnp.isfinite(W)),
                   f"sanitize: {name} became non-finite inside the solve")
    checkify.check(jnp.min(W) >= -_NEG_ATOL,
                   f"sanitize: {name} left the nonnegative orthant")
    checkify.check(jnp.max(jnp.sum(W, axis=-1)) <= 1.0 + atol,
                   f"sanitize: {name} row mass exceeded the simplex")


def run_checked(fn, *args, **kwargs):
    """Run ``fn`` under checkify and raise its first failed check.

    The solvers' static ``sanitize=True`` flag is only legal on this path:
    it functionalizes the in-trace ``checkify.check`` calls that would
    otherwise be a trace-time error under plain jit.
    """
    err, out = checkify.checkify(fn)(*args, **kwargs)
    err.throw()
    return out
