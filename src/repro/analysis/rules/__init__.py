"""Rule modules for the repro invariant linter.

Importing this package registers every rule with the framework registry
(:func:`repro.analysis.framework.register`). One module per invariant group:

* :mod:`.family`     — RPA001/RPA002: family-threading completeness
* :mod:`.vjp`        — RPA010-RPA012: custom-VJP fwd/bwd contract
* :mod:`.staticargs` — RPA020-RPA022: jit static-argument / tracer discipline
* :mod:`.vmem`       — RPA030-RPA032: Pallas VMEM/BlockSpec budget audit
* :mod:`.contracts`  — RPA040/RPA050: documented zero cotangents, deprecated
  imports
* :mod:`.famcov`     — RPA060: every FAMILIES entry reaches all threading
  sites (ref, kernels, VJP, autotune, sim ground truth)
* :mod:`.fidelity`   — RPA070: frontier_moments call sites must thread the
  fidelity knob, not hard-code ``num_t``
* :mod:`.serving`    — RPA080: no per-instance frontier_moments loops on the
  serving path (stack rows, one launch per family group)
* :mod:`.observability` — RPA090/RPA091: span/event names come from the
  ``repro.obs.names`` registry; no wall-clock ``time.time()`` in timing
  paths

See docs/INVARIANTS.md for the catalogue with rationale and history.
"""
from . import (contracts, famcov, family, fidelity, observability,  # noqa: F401
               serving, staticargs, vjp, vmem)
