"""Family-threading completeness (RPA001, RPA002).

PR 3 made the completion-time family pluggable: every layer between the
public API and the kernels must accept ``family=`` (or the lowered static
``dist_id``) and pass it on, or the call silently falls back to the normal
family — numerically plausible, quietly wrong for lognormal/drift/empirical
fleets. These rules make the convention structural:

* **RPA001** — a function whose signature carries channel statistics (both
  ``mus`` and ``sigmas`` parameters) must also carry ``family`` or
  ``dist_id``. Pure layout helpers that never evaluate a CDF are the
  legitimate exceptions; they take a pragma.
* **RPA002** — inside a family-aware function, any call that hands ``mus``
  or ``sigmas`` to another family-aware callable must forward ``family=`` /
  ``dist_id=`` (keyword, positionally, or via ``**kwargs``) — otherwise the
  callee applies ITS default and the caller's family stops at this frame.
"""
from __future__ import annotations

import ast
from typing import Iterator

from ..framework import (
    Finding,
    Project,
    call_name,
    keyword_or_positional,
    param_names,
    register,
)

_STATS = {"mus", "sigmas"}
_FAMILY = {"family", "dist_id"}


@register
class FamilyThreadingRule:
    CODES = {
        "RPA001": "function takes mus/sigmas but no family/dist_id parameter",
        "RPA002": "mus/sigmas passed on without forwarding family/dist_id",
    }

    def run(self, project: Project) -> Iterator[Finding]:
        index = project.family_aware_callables()
        for ctx in project.files:
            for node in ast.walk(ctx.tree):
                if not isinstance(node, (ast.FunctionDef,
                                         ast.AsyncFunctionDef)):
                    continue
                names = set(param_names(node.args))
                if not _STATS <= names:
                    continue
                if not _FAMILY & names:
                    yield ctx.finding(
                        node, "RPA001",
                        f"'{node.name}' takes mus/sigmas but no "
                        f"family/dist_id parameter — callees will apply the "
                        f"normal-family default")
                    continue
                yield from self._check_forwarding(ctx, node, index)

    def _check_forwarding(self, ctx, node, index) -> Iterator[Finding]:
        for call in ast.walk(node):
            if not isinstance(call, ast.Call):
                continue
            callee = call_name(call)
            if callee is None or callee == node.name:
                continue
            callee_args = index.get(callee)
            if callee_args is None:
                continue
            if not _passes_stats(call):
                continue
            if keyword_or_positional(call, callee_args, _FAMILY):
                continue
            yield ctx.finding(
                call, "RPA002",
                f"'{node.name}' passes mus/sigmas to family-aware "
                f"'{callee}' without forwarding family/dist_id — the "
                f"callee's default family takes over here")


def _passes_stats(call: ast.Call) -> bool:
    """True when any argument is literally the local name mus or sigmas."""
    for arg in call.args:
        if isinstance(arg, ast.Name) and arg.id in _STATS:
            return True
    for kw in call.keywords:
        if isinstance(kw.value, ast.Name) and kw.value.id in _STATS:
            return True
    return False
