"""VMEM/BlockSpec audit for Pallas launch wrappers (RPA030-RPA032).

ROADMAP item 2 flagged the fused-pgrad six-accumulator working set as a
latent hazard: a ``block_f`` default that fits the forward kernel can
overflow VMEM the moment differentiation swaps in the full-parameter fused
launch. This rule runs the SAME working-set model the runtime autotuner uses
(:func:`repro.kernels.autotune.vmem_bytes`) at lint time, over every
family x mode x stacked combination, so the "pgrad needs its own safe block"
footnote is a hard check instead of tribal knowledge.

A *launch wrapper* is any function whose body calls ``pl.pallas_call``. Its
modes come from its signature: a ``param_grads`` parameter means the fused
kernel (``grad`` and ``pgrad`` modes), otherwise forward-only. The audit
point is the repo's reference fleet shape K=1024 channels x T=1024 grid
points — the documented scale target every default must survive.

* **RPA030** — the wrapper's default ``block_f`` overflows the VMEM budget
  for at least one audited combination; the message names every failing
  (family, mode, stacked) tuple and the largest candidate block that fits
  them all.
* **RPA031** — the wrapper derives its grid from ``block_f`` (``F //
  block_f``) but neither it nor a same-file helper it passes ``block_f`` to
  performs a divisibility check (``%``): a non-multiple F silently drops the
  tail rows of the launch.
* **RPA032** — NO candidate block fits some audited combination: the kernel
  cannot launch at reference scale at all and the budget model or kernel
  working set needs rework.
"""
from __future__ import annotations

import ast
from typing import Iterator, List, Optional, Tuple

from ..framework import Finding, Project, call_name, param_names, register

# reference fleet shape the defaults must survive (see module docstring)
_AUDIT_K = 1024
_AUDIT_T = 1024


def _audit_modes(has_param_grads: bool) -> List[Tuple[str, bool, bool]]:
    if has_param_grads:
        return [("grad", True, False), ("pgrad", True, True)]
    return [("fwd", False, False)]


def _block_f_default(fn) -> Optional[int]:
    """The int default of the wrapper's ``block_f`` parameter, if any."""
    a = fn.args
    pos = a.posonlyargs + a.args
    for param, default in zip(pos[len(pos) - len(a.defaults):], a.defaults):
        if param.arg == "block_f" and isinstance(default, ast.Constant) \
                and isinstance(default.value, int):
            return default.value
    for param, default in zip(a.kwonlyargs, a.kw_defaults):
        if param.arg == "block_f" and isinstance(default, ast.Constant) \
                and isinstance(default.value, int):
            return default.value
    return None


def _calls_pallas(fn) -> Optional[ast.Call]:
    for node in ast.walk(fn):
        if isinstance(node, ast.Call) and call_name(node) == "pallas_call":
            return node
    return None


def _has_mod_on(fn, name: str) -> bool:
    for node in ast.walk(fn):
        if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Mod):
            for side in (node.left, node.right):
                if isinstance(side, ast.Name) and side.id == name:
                    return True
    return False


def _grid_uses(call: ast.Call, name: str) -> bool:
    for kw in call.keywords:
        if kw.arg != "grid":
            continue
        for node in ast.walk(kw.value):
            if isinstance(node, ast.Name) and node.id == name:
                return True
    return False


@register
class VmemBlockSpecRule:
    CODES = {
        "RPA030": "default block_f overflows the VMEM working-set budget",
        "RPA031": "grid derived from block_f without a divisibility guard",
        "RPA032": "no candidate block_f fits the VMEM budget at all",
    }

    def run(self, project: Project) -> Iterator[Finding]:
        # imported lazily so the linter works (minus this rule's model) even
        # when jax is absent from the interpreter running it
        try:
            from repro.core.distributions import FAMILIES
            from repro.kernels import autotune
        except ImportError:
            return
        budget = autotune._VMEM_BUDGET_BYTES

        for ctx in project.files:
            defs = {n.name: n for n in ast.walk(ctx.tree)
                    if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}
            for fn in defs.values():
                pallas = _calls_pallas(fn)
                if pallas is None:
                    continue
                yield from self._check_guard(ctx, fn, defs, pallas)
                bf = _block_f_default(fn)
                if bf is None:
                    continue
                yield from self._check_budget(ctx, fn, bf, FAMILIES,
                                              autotune, budget)

    def _check_guard(self, ctx, fn, defs, pallas) -> Iterator[Finding]:
        if not _grid_uses(pallas, "block_f"):
            return
        if _has_mod_on(fn, "block_f"):
            return
        # a same-file helper the wrapper hands block_f to may own the check
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                helper = defs.get(call_name(node) or "")
                if helper is None or helper is fn:
                    continue
                passes_bf = any(isinstance(a, ast.Name) and a.id == "block_f"
                                for a in node.args) or \
                    any(isinstance(kw.value, ast.Name)
                        and kw.value.id == "block_f"
                        for kw in node.keywords)
                if passes_bf and any(_has_mod_on(helper, p)
                                     for p in param_names(helper.args)):
                    return
        yield ctx.finding(
            fn, "RPA031",
            f"'{fn.name}' launches with grid derived from block_f but never "
            f"checks F % block_f — a non-multiple F silently drops rows")

    def _check_budget(self, ctx, fn, bf, families, autotune,
                      budget) -> Iterator[Finding]:
        modes = _audit_modes("param_grads" in param_names(fn.args))
        failing = []
        infeasible = []
        for fam in families:
            for mode, fused, params in modes:
                for stacked in (False, True):
                    need = autotune.vmem_bytes(bf, _AUDIT_K, _AUDIT_T, fused,
                                               fam, params, stacked)
                    if need > budget:
                        failing.append((fam, mode, stacked, need))
                    fits = [c for c in autotune.BLOCK_F_CANDIDATES
                            if autotune.vmem_bytes(c, _AUDIT_K, _AUDIT_T,
                                                   fused, fam, params,
                                                   stacked) <= budget]
                    if not fits:
                        infeasible.append((fam, mode, stacked))
        if failing:
            safe = [c for c in autotune.BLOCK_F_CANDIDATES
                    if all(autotune.vmem_bytes(
                        c, _AUDIT_K, _AUDIT_T, fused, fam, params, stacked)
                        <= budget
                        for fam in families
                        for _, fused, params in modes
                        for stacked in (False, True))]
            combos = ", ".join(
                f"{fam}/{mode}{':stk' if stacked else ''}"
                f"={need / 2**20:.1f}MB"
                for fam, mode, stacked, need in failing[:4])
            more = f" (+{len(failing) - 4} more)" if len(failing) > 4 else ""
            hint = (f"largest block fitting every combo is {max(safe)}"
                    if safe else "no candidate fits every combo")
            yield ctx.finding(
                fn, "RPA030",
                f"'{fn.name}' default block_f={bf} overflows the "
                f"{budget / 2**20:.1f}MB VMEM budget at "
                f"K={_AUDIT_K}/T={_AUDIT_T} for {combos}{more}; {hint}")
        for fam, mode, stacked in infeasible:
            yield ctx.finding(
                fn, "RPA032",
                f"'{fn.name}': no candidate block_f fits the VMEM budget for "
                f"{fam}/{mode}{':stk' if stacked else ''} at "
                f"K={_AUDIT_K}/T={_AUDIT_T} — working set needs rework")
