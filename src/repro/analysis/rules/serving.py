"""Serving-path batching discipline (RPA080).

PR 9 rebuilt the serving tier around continuous batching: every live
workflow instance's remaining stages ride ONE stacked
``ops.frontier_moments*`` launch per completion-time family per tick
(``workflow.solve.stack_rows`` + ``serve.engine.row_pgd_step``). The
anti-pattern that PR deleted was the per-instance / per-stage Python loop
paying one kernel launch — dispatch, autotune probe, jit-cache lookup —
per workflow, which is exactly the cost the stacked ``(F, K)`` row layout
exists to amortize.

* **RPA080** — in a file under a ``serve`` directory, a
  ``frontier_moments`` / ``frontier_moments_with_grads`` call must not
  appear lexically inside a ``for`` / ``while`` loop (comprehensions
  included): stack the rows and launch once per family group instead. The
  per-family-group loop is fine — its body calls the stacked helper, not
  the kernel entry point. Tests are exempt; a deliberate exception (e.g. a
  documented baseline) takes a pragma.
"""
from __future__ import annotations

import ast
import os
from typing import Iterator

from ..framework import Finding, Project, call_name, register

_TARGETS = {"frontier_moments", "frontier_moments_with_grads"}
_LOOPS = (ast.For, ast.AsyncFor, ast.While, ast.ListComp, ast.SetComp,
          ast.DictComp, ast.GeneratorExp)


def _serving_path(path: str) -> bool:
    parts = os.path.normpath(path).split(os.sep)
    return "serve" in parts and "tests" not in parts


@register
class ServingBatchRule:
    CODES = {
        "RPA080": "frontier_moments launched inside a per-instance Python "
                  "loop under serve/ — stack rows, one launch per family "
                  "group",
    }

    def run(self, project: Project) -> Iterator[Finding]:
        for ctx in project.files:
            if not _serving_path(ctx.path):
                continue
            seen = set()
            for loop in ast.walk(ctx.tree):
                if not isinstance(loop, _LOOPS):
                    continue
                for node in ast.walk(loop):
                    if not isinstance(node, ast.Call):
                        continue
                    if call_name(node) not in _TARGETS:
                        continue
                    if id(node) in seen:
                        continue
                    seen.add(id(node))
                    yield ctx.finding(
                        node, "RPA080",
                        f"'{call_name(node)}' inside a loop on the serving "
                        f"path pays one kernel launch per iteration — stack "
                        f"the rows (workflow.solve.stack_rows) and launch "
                        f"once per family group per tick")
