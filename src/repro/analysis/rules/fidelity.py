"""Fidelity-knob threading (RPA070).

PR 8 made quadrature resolution the solve's price knob: the multi-fidelity
ladder in ``workflow.solve`` runs presolve/triage at a coarse ``num_t`` and
final scoring at ``eval_num_t``, and every layer between the public API and
``ops.frontier_moments`` / ``ops.frontier_moments_with_grads`` threads the
resolution it was given. A call site that hard-codes ``num_t=<literal>``
opts out of the ladder: it pins one rung no matter what fidelity the caller
asked for, and its autotune entry silently keys to the pinned ``T`` (the
coarse/fine rungs have distinct keys by design — see kernels/autotune.py).

* **RPA070** — a ``frontier_moments`` / ``frontier_moments_with_grads``
  call passing a literal constant ``num_t=`` must thread a variable (a
  parameter, a module-level knob, a config value) instead. Fixed-resolution
  figure reproductions are the legitimate exception; they take a pragma
  naming the figure. Files under a ``tests`` directory are exempt — a test
  pins its quadrature on purpose.
"""
from __future__ import annotations

import ast
import os
from typing import Iterator

from ..framework import Finding, Project, call_name, register

_TARGETS = {"frontier_moments", "frontier_moments_with_grads"}


def _in_tests(path: str) -> bool:
    parts = os.path.normpath(path).split(os.sep)
    return "tests" in parts


def _is_literal_int(node: ast.AST) -> bool:
    """A bare integer constant (the hard-coded rung this rule exists for).

    Arithmetic over constants (``2 * 1024``) counts too — it is still a
    pinned resolution, just spelled with more characters.
    """
    if isinstance(node, ast.Constant):
        return isinstance(node.value, int) and not isinstance(node.value,
                                                              bool)
    if isinstance(node, ast.BinOp):
        return _is_literal_int(node.left) and _is_literal_int(node.right)
    if isinstance(node, ast.UnaryOp):
        return _is_literal_int(node.operand)
    return False


@register
class FidelityKnobRule:
    CODES = {
        "RPA070": "frontier_moments call hard-codes num_t instead of "
                  "threading the fidelity knob",
    }

    def run(self, project: Project) -> Iterator[Finding]:
        for ctx in project.files:
            if _in_tests(ctx.path):
                continue
            for node in ast.walk(ctx.tree):
                if not isinstance(node, ast.Call):
                    continue
                if call_name(node) not in _TARGETS:
                    continue
                for kw in node.keywords:
                    if kw.arg == "num_t" and _is_literal_int(kw.value):
                        yield ctx.finding(
                            node, "RPA070",
                            f"'{call_name(node)}' pins num_t="
                            f"{ast.unparse(kw.value)} — thread the caller's "
                            f"fidelity knob (presolve_num_t / num_t / "
                            f"eval_num_t) so the multi-fidelity ladder "
                            f"reaches this launch")
