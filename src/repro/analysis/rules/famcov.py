"""Family-coverage completeness (RPA060).

RPA001/002 catch a *call site* that drops the family spec; they cannot catch
a whole *family* that was added to ``core.distributions.FAMILIES`` but never
taught to one of the layers that must understand every ``dist_id``. That is
exactly how a new family ships half-implemented: the kernels fall through to
a default branch, the sim has no generating regime for it, and the first
symptom is a benchmark whose "ground truth" quietly ran a different
distribution than the solver priced.

**RPA060** — every family name in the ``FAMILIES`` tuple (parsed from
``core/distributions.py``, never imported) must appear as a word in each of
the threading sites:

* ``kernels/ref.py``           — the quadrature oracle,
* ``kernels/frontier_grid.py`` — both Pallas kernels,
* ``kernels/ops.py``           — the custom-VJP wrapper,
* ``kernels/autotune.py``      — plan keys + sweep coverage,
* ``sim/cluster.py``           — the ground-truth generator.

A site that legitimately handles a family through a fully generic path can
carry a ``# repro: allow[RPA060]`` pragma at the top of the file with the
justification (none do today — every current family names its branch or its
coefficient-table row in all five).
"""
from __future__ import annotations

import ast
import re
from typing import Iterator, Optional, Sequence

from ..framework import Finding, Project, register

# site suffix -> what the mention proves there
_SITES = (
    ("kernels/ref.py", "reference oracle"),
    ("kernels/frontier_grid.py", "Pallas kernels"),
    ("kernels/ops.py", "custom VJP"),
    ("kernels/autotune.py", "autotune keys/sweep"),
    ("sim/cluster.py", "sim ground truth"),
)

_FAMILIES_SRC = "core/distributions.py"


def _parse_families(source: str) -> Optional[Sequence[str]]:
    """The FAMILIES tuple, read statically from the distributions module."""
    tree = ast.parse(source)
    for node in ast.walk(tree):
        if not isinstance(node, ast.Assign):
            continue
        if not any(isinstance(t, ast.Name) and t.id == "FAMILIES"
                   for t in node.targets):
            continue
        try:
            value = ast.literal_eval(node.value)
        except ValueError:
            return None
        if isinstance(value, (tuple, list)) and \
                all(isinstance(v, str) for v in value):
            return tuple(value)
    return None


@register
class FamilyCoverageRule:
    CODES = {
        "RPA060": "family in FAMILIES is never mentioned in a threading site",
    }

    def run(self, project: Project) -> Iterator[Finding]:
        by_suffix = {}
        for ctx in project.files:
            norm = ctx.path.replace("\\", "/")
            for suffix, role in _SITES:
                if norm.endswith(suffix):
                    by_suffix[suffix] = ctx
            if norm.endswith(_FAMILIES_SRC):
                by_suffix[_FAMILIES_SRC] = ctx
        dist_ctx = by_suffix.get(_FAMILIES_SRC)
        if dist_ctx is None:
            return  # partial lint run without the registry — nothing to check
        families = _parse_families(dist_ctx.source)
        if not families:
            yield dist_ctx.finding(
                1, "RPA060",
                "FAMILIES tuple is not a literal tuple of strings — the "
                "coverage rule cannot enumerate the registry")
            return
        for suffix, role in _SITES:
            ctx = by_suffix.get(suffix)
            if ctx is None:
                continue
            for fam in families:
                if re.search(rf"\b{re.escape(fam)}\b", ctx.source):
                    continue
                yield ctx.finding(
                    1, "RPA060",
                    f"family '{fam}' (core.distributions.FAMILIES) is never "
                    f"mentioned in {suffix} ({role}) — a dist_id this layer "
                    f"does not know falls through to a default branch and "
                    f"silently prices the wrong distribution")
