"""Static-argument / tracer-leak discipline for jitted functions (RPA020-22).

Inside ``jax.jit``, Python-level control flow runs at TRACE time: branching
on a traced argument raises a ConcretizationTypeError at best, silently bakes
in one branch at worst (when the value happens to be concrete during tracing
but varies at runtime). The kernels package threads ``dist_id`` /
``param_grads`` / ``block_f`` through grid math and family dispatch, so
every one of those names must be declared in ``static_argnames``:

* **RPA020** — a parameter of a jit-decorated function appears in a Python
  ``if``/``while``/conditional-expression test but not in
  ``static_argnames``.
* **RPA021** — assignment to ``self.<attr>`` inside a jit-decorated function:
  the attribute escapes the trace holding a tracer, poisoning later calls
  (the classic leaked-tracer failure).
* **RPA022** — ``static_argnames`` names a parameter the function does not
  have: a stale entry from a renamed signature, silently ignored by older
  JAX and an error in newer — either way a lie about the launch contract.

Detection covers ``@jax.jit``, ``@jit`` and the
``functools.partial(jax.jit, static_argnames=...)`` spelling used throughout
this repo (which is also how the Pallas wrappers are jitted).
"""
from __future__ import annotations

import ast
from typing import Iterator

from ..framework import (
    Finding,
    Project,
    jit_static_argnames,
    param_names,
    register,
)


def _test_exprs(fn) -> Iterator[ast.AST]:
    """Condition expressions of if/while/ternary in ``fn`` (nested defs kept:
    they are traced as part of the same jit)."""
    for node in ast.walk(fn):
        if isinstance(node, (ast.If, ast.While, ast.IfExp)):
            yield node.test


@register
class StaticArgsRule:
    CODES = {
        "RPA020": "jit parameter used in Python control flow but not static",
        "RPA021": "attribute assignment inside jitted function leaks tracers",
        "RPA022": "static_argnames entry is not a parameter of the function",
    }

    def run(self, project: Project) -> Iterator[Finding]:
        for ctx in project.files:
            for fn in ast.walk(ctx.tree):
                if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    continue
                static = jit_static_argnames(fn)
                if static is None:
                    continue
                params = set(param_names(fn.args))

                for name in sorted(static - params):
                    yield ctx.finding(
                        fn, "RPA022",
                        f"static_argnames entry '{name}' is not a parameter "
                        f"of jitted '{fn.name}'")

                flagged = set()
                for test in _test_exprs(fn):
                    for node in ast.walk(test):
                        if (isinstance(node, ast.Name)
                                and node.id in params
                                and node.id not in static
                                and node.id not in flagged):
                            flagged.add(node.id)
                            yield ctx.finding(
                                node, "RPA020",
                                f"'{node.id}' drives Python control flow in "
                                f"jitted '{fn.name}' but is not in "
                                f"static_argnames — add it or hoist the "
                                f"branch out of the trace")

                for node in ast.walk(fn):
                    targets = []
                    if isinstance(node, ast.Assign):
                        targets = node.targets
                    elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
                        targets = [node.target]
                    for t in targets:
                        if (isinstance(t, ast.Attribute)
                                and isinstance(t.value, ast.Name)
                                and t.value.id == "self"):
                            yield ctx.finding(
                                node, "RPA021",
                                f"assignment to self.{t.attr} inside jitted "
                                f"'{fn.name}' — traced values escaping the "
                                f"trace become leaked tracers")
