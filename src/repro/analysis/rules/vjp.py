"""Custom-VJP contract checker (RPA010-RPA012).

The frontier stack's differentiation surface is a hand-written
``jax.custom_vjp`` (PR 4's full-parameter adjoint): JAX checks almost none of
its internal consistency at registration time, and an arity mismatch between
the primal's differentiable arguments and the backward's cotangent tuple
surfaces as a shape error deep inside a jit — or not at all when a residual
silently stops being read. Three structural checks:

* **RPA010** — a function declared with ``@jax.custom_vjp`` (bare or via
  ``functools.partial(jax.custom_vjp, nondiff_argnums=...)``) that never has
  ``.defvjp(fwd, bwd)`` called on it: the primal silently behaves as an
  ordinary function and autodiff replays the quadrature.
* **RPA011** — the backward's returned cotangent tuple length differs from
  the primal's differentiable-argument count
  (``len(positional params) - len(nondiff_argnums)``).
* **RPA012** — residual mismatch: the backward unpacks a different number of
  residuals than the forward packs, or an unpacked residual name is never
  read afterwards (stale state the forward is still paying to save).

All resolution is same-module by name — exactly how the kernels package
declares its VJPs — so the rule is precise where it matters and silent on
exotic cross-module registrations.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Tuple

from ..framework import (
    Finding,
    FileContext,
    Project,
    decorator_entries,
    positional_params,
    register,
)


def _custom_vjp_info(node) -> Optional[Tuple[ast.AST, List[int]]]:
    """(decorator node, nondiff_argnums) when ``node`` is a custom_vjp primal."""
    for name, call in decorator_entries(node):
        if name.split(".")[-1] != "custom_vjp":
            continue
        nondiff: List[int] = []
        if call is not None:
            for kw in call.keywords:
                if kw.arg == "nondiff_argnums":
                    v = kw.value
                    if isinstance(v, (ast.Tuple, ast.List)):
                        nondiff = [e.value for e in v.elts
                                   if isinstance(e, ast.Constant)
                                   and isinstance(e.value, int)]
                    elif isinstance(v, ast.Constant) and isinstance(v.value, int):
                        nondiff = [v.value]
        return call if call is not None else node, nondiff
    return None


def _returned_tuples(fn) -> List[ast.Tuple]:
    """Return-statement tuples of ``fn`` itself (nested defs excluded)."""
    out: List[ast.Tuple] = []
    stack = list(fn.body)
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            continue
        if isinstance(node, ast.Return) and isinstance(node.value, ast.Tuple):
            out.append(node.value)
        stack.extend(ast.iter_child_nodes(node))
    return out


def _fwd_residual_count(fwd) -> Optional[int]:
    """Arity of the residual tuple in ``return out, (r0, r1, ...)``."""
    for tup in _returned_tuples(fwd):
        if len(tup.elts) == 2 and isinstance(tup.elts[1], ast.Tuple):
            return len(tup.elts[1].elts)
    return None


@register
class CustomVjpContractRule:
    CODES = {
        "RPA010": "custom_vjp primal never registered via defvjp(fwd, bwd)",
        "RPA011": "bwd cotangent tuple arity != primal diff-arg count",
        "RPA012": "fwd/bwd residual mismatch or residual unpacked but unused",
    }

    def run(self, project: Project) -> Iterator[Finding]:
        for ctx in project.files:
            yield from self._check_file(ctx)

    def _check_file(self, ctx: FileContext) -> Iterator[Finding]:
        defs: Dict[str, ast.FunctionDef] = {
            n.name: n for n in ast.walk(ctx.tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}
        defvjps: Dict[str, Tuple[str, str]] = {}
        for node in ast.walk(ctx.tree):
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr == "defvjp"
                    and isinstance(node.func.value, ast.Name)
                    and len(node.args) >= 2
                    and all(isinstance(a, ast.Name) for a in node.args[:2])):
                defvjps[node.func.value.id] = (node.args[0].id,
                                               node.args[1].id)

        for name, fn in defs.items():
            info = _custom_vjp_info(fn)
            if info is None:
                continue
            _, nondiff = info
            if name not in defvjps:
                yield ctx.finding(
                    fn, "RPA010",
                    f"custom_vjp '{name}' has no defvjp(fwd, bwd) "
                    f"registration — autodiff will replay the primal")
                continue
            diff_count = len(positional_params(fn.args)) - len(nondiff)
            fwd_name, bwd_name = defvjps[name]
            fwd, bwd = defs.get(fwd_name), defs.get(bwd_name)
            if bwd is not None:
                yield from self._check_bwd(ctx, name, bwd, diff_count,
                                           fwd=fwd)

    def _check_bwd(self, ctx, primal_name, bwd, diff_count,
                   fwd=None) -> Iterator[Finding]:
        for tup in _returned_tuples(bwd):
            if len(tup.elts) != diff_count:
                yield ctx.finding(
                    tup, "RPA011",
                    f"bwd '{bwd.name}' returns {len(tup.elts)} cotangents "
                    f"but custom_vjp '{primal_name}' has {diff_count} "
                    f"differentiable arguments")

        pos = positional_params(bwd.args)
        if len(pos) < 2:
            return
        res_param = pos[-2]
        unpack = self._residual_unpack(bwd, res_param)
        if unpack is None:
            return
        node, res_names = unpack
        packed = _fwd_residual_count(fwd) if fwd is not None else None
        if packed is not None and packed != len(res_names):
            yield ctx.finding(
                node, "RPA012",
                f"bwd '{bwd.name}' unpacks {len(res_names)} residuals but "
                f"fwd packs {packed}")
        used = self._names_loaded(bwd, exclude=node)
        for nm in res_names:
            if not nm.startswith("_") and nm not in used:
                yield ctx.finding(
                    node, "RPA012",
                    f"residual '{nm}' unpacked in bwd '{bwd.name}' but never "
                    f"used — fwd is saving state nobody reads")

    @staticmethod
    def _residual_unpack(bwd, res_param):
        """(assign node, names) for ``a, b, ... = res``; None when absent."""
        for node in ast.walk(bwd):
            if (isinstance(node, ast.Assign)
                    and isinstance(node.value, ast.Name)
                    and node.value.id == res_param
                    and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Tuple)
                    and all(isinstance(e, ast.Name)
                            for e in node.targets[0].elts)):
                return node, [e.id for e in node.targets[0].elts]
        return None

    @staticmethod
    def _names_loaded(bwd, exclude) -> set:
        used = set()
        for node in ast.walk(bwd):
            if node is exclude:
                continue
            if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load):
                used.add(node.id)
        return used
