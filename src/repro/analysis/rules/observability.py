"""Observability discipline (RPA090/RPA091).

PR 10 added the cross-layer tracing + decision-audit subsystem
(``repro/obs/``). Its contract only works if the names are stable: a
dashboard query, the Perfetto converter, and the CI schema validator all
key on span/event names, so an emit site inventing its own string drifts
out of every consumer silently. The central registry is
``repro.obs.names``; the tracer rejects unregistered names at runtime
(when tracing is on) and RPA090 rejects them statically (always).

* **RPA090** — a call to an obs emit entry point (``span`` /
  ``timed_span`` / ``event`` / ``traced``) must not pass a string literal
  as the name: use a ``repro.obs.names`` constant. The obs package itself
  and tests are exempt (they define and exercise the machinery).
* **RPA091** — no ``time.time()`` inside ``src/repro/``: every duration
  and span in the repo is measured on the monotonic clock
  (``time.perf_counter`` / ``perf_counter_ns``). Wall-clock time is
  subject to NTP steps and DST, which turns benchmark deltas and span
  durations into lies; a deliberate wall-clock need (e.g. naming an
  artifact by date) takes a pragma.
"""
from __future__ import annotations

import ast
import os
from typing import Iterator, Optional

from ..framework import Finding, Project, dotted_name, register

_EMITTERS = {"span", "timed_span", "event", "traced"}


def _parts(path: str):
    return os.path.normpath(path).split(os.sep)


def _obs_emit_name_literal(node: ast.Call) -> Optional[str]:
    """The literal string passed as an emit name, if any."""
    func = node.func
    # only attribute calls rooted at an obs-ish module count — a bare
    # ``event(...)`` in unrelated code (e.g. a sim's event queue) is not an
    # obs emit site
    dn = dotted_name(func)
    if dn is None:
        return None
    head, _, tail = dn.rpartition(".")
    if tail not in _EMITTERS:
        return None
    if not head or not (head == "obs" or head.endswith(".obs")
                        or head in ("trace", "TRACER")
                        or head.endswith("obs.trace")):
        return None
    if not node.args:
        return None
    first = node.args[0]
    if isinstance(first, ast.Constant) and isinstance(first.value, str):
        return first.value
    return None


@register
class ObservabilityRule:
    CODES = {
        "RPA090": "obs emit site names a span/event with a free string "
                  "literal — use a repro.obs.names constant",
        "RPA091": "time.time() in src/repro/ — durations must come from "
                  "the monotonic clock (time.perf_counter)",
    }

    def run(self, project: Project) -> Iterator[Finding]:
        for ctx in project.files:
            parts = _parts(ctx.path)
            in_repro = "repro" in parts and "tests" not in parts
            in_obs = in_repro and "obs" in parts
            for node in ast.walk(ctx.tree):
                if not isinstance(node, ast.Call):
                    continue
                if in_repro and not in_obs and "tests" not in parts:
                    lit = _obs_emit_name_literal(node)
                    if lit is not None:
                        yield ctx.finding(
                            node, "RPA090",
                            f"span/event name {lit!r} is a free string — "
                            f"name records with repro.obs.names constants "
                            f"so emit sites and consumers cannot drift")
                if in_repro and dotted_name(node.func) == "time.time":
                    yield ctx.finding(
                        node, "RPA091",
                        "time.time() is wall clock (NTP steps, DST) — "
                        "measure durations with time.perf_counter()")
