"""Documentation and import contracts (RPA040, RPA050).

* **RPA040** — documented-zero-cotangent check. The VJP contract promises
  zero cotangents for specific inputs (the empirical family's mixture extras
  are solve constants, never descended). A backward function returning an
  all-zeros cotangent (``jnp.zeros_like(x)`` built and never updated) is
  either implementing that contract — in which case its docstring must SAY
  so — or silently dropping a gradient someone expects to flow. The rule
  fires when a bwd returns an unmodified zeros cotangent and neither its
  docstring nor the enclosing module mentions the zero/stop-grad contract.
* **RPA050** — deprecated-import ban. ``repro.core.normal`` became a
  deprecation shim when the completion-time model went pluggable (PR 3); in-
  repo code must import from ``repro.core.distributions``. Generalizes the
  old one-off guard test in tests/test_workflow.py into a rule that covers
  every spelling (absolute, ``from repro.core import normal``, and the
  relative forms inside the core package). The shim itself is exempt, and
  its DeprecationWarning names this code.
"""
from __future__ import annotations

import ast
import os
from typing import Dict, Iterator, Optional

from ..framework import Finding, FileContext, Project, register

_ZERO_WORDS = ("zero", "stop-grad", "stop_grad", "stop gradient")


def _is_zeros_call(node: ast.AST) -> bool:
    return (isinstance(node, ast.Call)
            and isinstance(node.func, (ast.Attribute, ast.Name))
            and (node.func.attr if isinstance(node.func, ast.Attribute)
                 else node.func.id) in ("zeros_like", "zeros"))


def _assignments(fn) -> Dict[str, list]:
    """name -> list of value nodes assigned to it anywhere in ``fn``."""
    out: Dict[str, list] = {}
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    out.setdefault(t.id, []).append(node.value)
        elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
            if isinstance(node.target, ast.Name):
                out.setdefault(node.target.id, []).append(node.value)
    return out


def _documents_zero(*docstrings: Optional[str]) -> bool:
    for doc in docstrings:
        if doc and any(w in doc.lower() for w in _ZERO_WORDS):
            return True
    return False


@register
class ZeroCotangentDocRule:
    CODES = {
        "RPA040": "bwd returns an all-zeros cotangent nothing documents",
    }

    def run(self, project: Project) -> Iterator[Finding]:
        for ctx in project.files:
            module_doc = ast.get_docstring(ctx.tree)
            for fn in ast.walk(ctx.tree):
                if not isinstance(fn, (ast.FunctionDef,
                                       ast.AsyncFunctionDef)):
                    continue
                if "bwd" not in fn.name:
                    continue
                yield from self._check_bwd(ctx, fn, module_doc)

    def _check_bwd(self, ctx, fn, module_doc) -> Iterator[Finding]:
        if _documents_zero(ast.get_docstring(fn), module_doc):
            return
        assigns = _assignments(fn)
        for node in ast.walk(fn):
            if not (isinstance(node, ast.Return)
                    and isinstance(node.value, ast.Tuple)):
                continue
            for i, elt in enumerate(node.value.elts):
                zero = _is_zeros_call(elt)
                if (not zero and isinstance(elt, ast.Name)
                        and len(assigns.get(elt.id, [])) == 1
                        and _is_zeros_call(assigns[elt.id][0])):
                    zero = True
                if zero:
                    yield ctx.finding(
                        node, "RPA040",
                        f"bwd '{fn.name}' returns an all-zeros cotangent "
                        f"(position {i}) but neither its docstring nor the "
                        f"module documents the stop-gradient contract")


def _in_core_package(path: str) -> bool:
    parts = os.path.normpath(path).split(os.sep)
    return "core" in parts


@register
class DeprecatedNormalImportRule:
    CODES = {
        "RPA050": "import of deprecated repro.core.normal shim",
    }

    _MSG = ("imports the deprecated repro.core.normal shim — import from "
            "repro.core.distributions instead (the primitives moved when "
            "the completion-time model became a pluggable ChannelFamily)")

    def run(self, project: Project) -> Iterator[Finding]:
        for ctx in project.files:
            # the shim module is the one legitimate holder of the old name
            if os.path.normpath(ctx.path).endswith(
                    os.path.join("core", "normal.py")):
                continue
            yield from self._check_file(ctx)

    def _check_file(self, ctx: FileContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    if alias.name.endswith("core.normal"):
                        yield ctx.finding(node, "RPA050",
                                          f"'import {alias.name}' "
                                          f"{self._MSG}")
            elif isinstance(node, ast.ImportFrom):
                mod = node.module or ""
                tail = mod.split(".")[-1] if mod else ""
                if mod.endswith("core.normal"):
                    yield ctx.finding(node, "RPA050",
                                      f"'from {mod} import ...' {self._MSG}")
                elif (tail == "normal" and node.level >= 1
                      and _in_core_package(ctx.path)):
                    yield ctx.finding(node, "RPA050",
                                      f"relative import of '.normal' "
                                      f"{self._MSG}")
                elif any(a.name == "normal" for a in node.names) and (
                        tail == "core"
                        or (node.level >= 1 and not mod
                            and _in_core_package(ctx.path))):
                    yield ctx.finding(node, "RPA050",
                                      f"'from {mod or '.'} import normal' "
                                      f"{self._MSG}")
