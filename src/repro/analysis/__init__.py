"""Static analysis + runtime sanitizer tier for the frontier stack.

Two enforcement layers for conventions the rest of ``repro`` relies on but
Python cannot express in types:

* **Lint-time** (:mod:`repro.analysis.framework` + :mod:`repro.analysis.rules`):
  AST rules with ``RPA0xx`` codes checking family threading, custom-VJP
  fwd/bwd contracts, jit static-argument discipline, Pallas VMEM/BlockSpec
  budgets (reusing the :mod:`repro.kernels.autotune` working-set model), and
  deprecated-import bans. Run via ``python -m repro.analysis src tests
  benchmarks`` or ``scripts/lint.py``. Suppress a deliberate exception with
  ``# repro: allow[RPA0xx] justification``.

* **Run-time** (:mod:`repro.analysis.sanitize`): ``jax.experimental.checkify``
  backed NaN/Inf and domain-invariant checks (simplex weights, variances >= 0,
  valid Clark-fold inputs) threaded through ``ops.frontier_moments``, the PGD
  solver, and ``workflow.solve``; enabled by ``REPRO_SANITIZE=1`` and
  exercised by the ``sanitizer`` CI tier.

Every invariant either layer enforces is catalogued in ``docs/INVARIANTS.md``
with its rule code, rationale, and the PR that introduced the convention.
"""
from .framework import (  # noqa: F401
    Finding,
    FileContext,
    Project,
    all_rules,
    build_project,
    collect_files,
    format_json,
    format_text,
    register,
    rule_codes,
    run_paths,
    run_project,
)
