"""Rule registry, findings, pragmas and reporters for ``repro.analysis``.

The framework is deliberately small: a *rule* is a class with a ``CODES``
mapping (``{"RPA0xx": "one-line description"}``) and a ``run(project)``
generator yielding :class:`Finding`s; registration is the :func:`register`
decorator. A :class:`Project` is the parsed view of every ``*.py`` file under
the linted paths (one :class:`FileContext` per file: source, line table,
``ast`` tree, pragma map), built once and shared by all rules so each file is
read and parsed exactly once per lint run.

Suppression is per-line and per-code: a finding at ``(path, line)`` is
dropped when that line — or the contiguous comment block directly above it,
for statements whose flagged line has no room for a trailing comment —
carries an allowlist pragma::

    some_flagged_code()  # repro: allow[RPA001] one-line justification
    # repro: allow[RPA020,RPA021] pragma-above form, multiple codes

Pragmas must name the exact code (no wildcards): an allowlist entry is a
*documented exception* to a specific invariant, and the justification text
after the bracket is part of the contract (see docs/INVARIANTS.md).

Shared AST helpers used by several rules (decorator matching, parameter
extraction, dotted-name resolution) live here too so the rule modules stay
single-purpose.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import os
import re
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Tuple

__all__ = [
    "Finding",
    "FileContext",
    "Project",
    "register",
    "all_rules",
    "rule_codes",
    "collect_files",
    "build_project",
    "run_project",
    "run_paths",
    "format_text",
    "format_json",
    "call_name",
    "decorator_entries",
    "jit_static_argnames",
    "param_names",
    "positional_params",
]

_PRAGMA_RE = re.compile(r"#\s*repro:\s*allow\[([A-Za-z0-9_,\s]+)\]")


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One rule violation at a file/line, identified by its RPA code."""

    path: str
    line: int
    code: str
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}: {self.code} {self.message}"

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class FileContext:
    """Parsed view of one source file: tree, line table, pragma map."""

    def __init__(self, path: str, source: str):
        self.path = path
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=path)
        self.allow: Dict[int, set] = {}
        for lineno, text in enumerate(self.lines, start=1):
            m = _PRAGMA_RE.search(text)
            if m:
                self.allow[lineno] = {c.strip() for c in m.group(1).split(",")
                                      if c.strip()}

    def allowed(self, line: int, code: str) -> bool:
        """True when an allow pragma names ``code`` on the line itself or in
        the contiguous comment block directly above it (multi-line
        justifications are encouraged)."""
        if code in self.allow.get(line, ()):
            return True
        ln = line - 1
        while 1 <= ln <= len(self.lines) \
                and self.lines[ln - 1].lstrip().startswith("#"):
            if code in self.allow.get(ln, ()):
                return True
            ln -= 1
        return False

    def finding(self, node_or_line, code: str, message: str) -> Finding:
        line = getattr(node_or_line, "lineno", node_or_line)
        return Finding(path=self.path, line=int(line), code=code,
                       message=message)


class Project:
    """All files of one lint run, plus cross-file indexes rules may share."""

    def __init__(self, contexts: Sequence[FileContext]):
        self.files: Tuple[FileContext, ...] = tuple(contexts)
        self._family_aware: Optional[Dict[str, ast.arguments]] = None

    def family_aware_callables(self) -> Dict[str, ast.arguments]:
        """Bare name -> arguments for every def with a family/dist_id param.

        The cross-file index the family-threading rule resolves calls
        against: a callee that *can* accept a family is one the caller must
        forward its family to. Keyed by bare (unqualified) name because call
        sites spell ``ops.frontier_moments`` / ``frontier_moments`` /
        ``self.solve`` interchangeably; first definition wins on collisions,
        which is adequate at lint precision.
        """
        if self._family_aware is None:
            index: Dict[str, ast.arguments] = {}
            for ctx in self.files:
                for node in ast.walk(ctx.tree):
                    if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                        names = param_names(node.args)
                        if "family" in names or "dist_id" in names:
                            index.setdefault(node.name, node.args)
            self._family_aware = index
        return self._family_aware


_REGISTRY: List[type] = []


def register(cls: type) -> type:
    """Class decorator adding a rule to the global registry."""
    _REGISTRY.append(cls)
    return cls


def _ensure_rules_loaded() -> None:
    # importing the rules package runs every @register decorator exactly once
    from . import rules  # noqa: F401


def all_rules() -> list:
    """Fresh instances of every registered rule, registration order."""
    _ensure_rules_loaded()
    return [cls() for cls in _REGISTRY]


def rule_codes() -> Dict[str, str]:
    """Every known code -> one-line description (the --list-rules table)."""
    out: Dict[str, str] = {}
    for rule in all_rules():
        out.update(rule.CODES)
    return dict(sorted(out.items()))


# ---------------------------------------------------------------------------
# project construction / run loop
# ---------------------------------------------------------------------------

def collect_files(paths: Sequence[str]) -> List[str]:
    """Expand files/directories into a sorted list of ``*.py`` paths."""
    out: List[str] = []
    for path in paths:
        if os.path.isfile(path):
            out.append(path)
            continue
        for dirpath, dirnames, filenames in os.walk(path):
            dirnames[:] = [d for d in dirnames
                           if d != "__pycache__" and not d.startswith(".")]
            out.extend(os.path.join(dirpath, f) for f in filenames
                       if f.endswith(".py"))
    return sorted(set(out))


def build_project(paths: Sequence[str]) -> Tuple[Project, List[Finding]]:
    """Parse every file under ``paths``; unparseable files become findings.

    A syntax error is reported as ``RPA000`` rather than crashing the run:
    the linter gates CI, and a broken file is exactly what it must report.
    """
    contexts: List[FileContext] = []
    errors: List[Finding] = []
    for path in collect_files(paths):
        try:
            with open(path, encoding="utf-8") as f:
                source = f.read()
            contexts.append(FileContext(path, source))
        except (OSError, SyntaxError, ValueError) as e:
            line = getattr(e, "lineno", 1) or 1
            errors.append(Finding(path=path, line=int(line), code="RPA000",
                                  message=f"unparseable file: {e}"))
    return Project(contexts), errors


def run_project(project: Project,
                select: Optional[Sequence[str]] = None) -> List[Finding]:
    """Run every registered rule over ``project``; pragma-filtered, sorted."""
    selected = set(select) if select else None
    by_path = {ctx.path: ctx for ctx in project.files}
    findings: List[Finding] = []
    for rule in all_rules():
        if selected is not None and not selected & set(rule.CODES):
            continue
        for finding in rule.run(project):
            if selected is not None and finding.code not in selected:
                continue
            ctx = by_path.get(finding.path)
            if ctx is not None and ctx.allowed(finding.line, finding.code):
                continue
            findings.append(finding)
    return sorted(findings)


def run_paths(paths: Sequence[str],
              select: Optional[Sequence[str]] = None) -> List[Finding]:
    """Parse ``paths`` and run the full rule set (the CLI's core)."""
    project, errors = build_project(paths)
    return sorted(errors + run_project(project, select=select))


# ---------------------------------------------------------------------------
# reporters
# ---------------------------------------------------------------------------

def format_text(findings: Sequence[Finding]) -> str:
    lines = [f.format() for f in findings]
    n = len(findings)
    lines.append(f"{n} finding{'s' if n != 1 else ''}")
    return "\n".join(lines)


def format_json(findings: Sequence[Finding]) -> str:
    return json.dumps({"findings": [f.to_dict() for f in findings],
                       "count": len(findings)}, indent=1, sort_keys=True)


# ---------------------------------------------------------------------------
# shared AST helpers
# ---------------------------------------------------------------------------

def call_name(node: ast.Call) -> Optional[str]:
    """Bare callee name of a call: ``ops.frontier_moments(...)`` ->
    ``frontier_moments``; ``f(...)`` -> ``f``; anything else -> None."""
    func = node.func
    if isinstance(func, ast.Attribute):
        return func.attr
    if isinstance(func, ast.Name):
        return func.id
    return None


def dotted_name(node: ast.AST) -> Optional[str]:
    """Full dotted spelling of a Name/Attribute chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def decorator_entries(node) -> Iterator[Tuple[str, Optional[ast.Call]]]:
    """Yield ``(dotted_name, call_node_or_None)`` per decorator.

    ``@jax.jit`` yields ``("jax.jit", None)``;
    ``@functools.partial(jax.jit, static_argnames=...)`` yields
    ``("functools.partial", call)`` AND ``("jax.jit", call)`` so callers can
    match the transform regardless of the partial wrapping.
    """
    for dec in getattr(node, "decorator_list", []):
        if isinstance(dec, ast.Call):
            name = dotted_name(dec.func)
            if name is not None:
                yield name, dec
            if name is not None and name.split(".")[-1] == "partial" and dec.args:
                inner = dotted_name(dec.args[0])
                if inner is not None:
                    yield inner, dec
        else:
            name = dotted_name(dec)
            if name is not None:
                yield name, None


_JIT_NAMES = {"jit", "pjit"}


def jit_static_argnames(node) -> Optional[set]:
    """None when ``node`` is not jit-decorated, else its static_argnames set.

    Handles ``@jax.jit``, ``@jit``, and the ``partial(jax.jit, ...)`` forms;
    ``static_argnames`` may be a string or a tuple/list of string constants.
    Non-constant entries are ignored (unverifiable statically).
    """
    for name, call in decorator_entries(node):
        if name.split(".")[-1] not in _JIT_NAMES:
            continue
        static: set = set()
        if call is not None:
            for kw in call.keywords:
                if kw.arg != "static_argnames":
                    continue
                v = kw.value
                if isinstance(v, ast.Constant) and isinstance(v.value, str):
                    static.add(v.value)
                elif isinstance(v, (ast.Tuple, ast.List)):
                    static |= {e.value for e in v.elts
                               if isinstance(e, ast.Constant)
                               and isinstance(e.value, str)}
        return static
    return None


def param_names(args: ast.arguments) -> List[str]:
    """Every parameter name of a signature, in declaration order."""
    params = [a.arg for a in args.posonlyargs + args.args + args.kwonlyargs]
    if args.vararg:
        params.append(args.vararg.arg)
    if args.kwarg:
        params.append(args.kwarg.arg)
    return params


def positional_params(args: ast.arguments) -> List[str]:
    """Parameters reachable positionally (posonly + regular), in order."""
    return [a.arg for a in args.posonlyargs + args.args]


def keyword_or_positional(call: ast.Call, args: ast.arguments,
                          names: Iterable[str]) -> bool:
    """True when the call passes any of ``names`` to the callee signature
    ``args`` — as a keyword, positionally by index, or via ``**kwargs``."""
    wanted = set(names)
    for kw in call.keywords:
        if kw.arg is None:  # **kwargs splat: assume forwarded
            return True
        if kw.arg in wanted:
            return True
    pos = positional_params(args)
    n_given = len(call.args)
    for i, p in enumerate(pos):
        if p in wanted and i < n_given:
            return True
    return False
