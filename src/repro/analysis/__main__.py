"""CLI for the repro invariant linter: ``python -m repro.analysis PATH...``.

Exits 1 when any finding survives pragma filtering, 0 on a clean tree —
suitable as a CI gate (see ``scripts/ci.sh --lint``). ``--json`` switches the
report to a machine-readable document; ``--select RPA001,RPA050`` restricts
the run to specific codes (used by the test suite and by the RPA050
deprecated-import guard test).
"""
from __future__ import annotations

import argparse
import sys
from typing import Optional, Sequence

from .framework import format_json, format_text, rule_codes, run_paths


def main(argv: Optional[Sequence[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="AST invariant linter for the repro frontier stack "
                    "(rule catalogue: docs/INVARIANTS.md)")
    parser.add_argument("paths", nargs="*", default=["src"],
                        help="files or directories to lint (default: src)")
    parser.add_argument("--json", action="store_true",
                        help="emit findings as JSON instead of text")
    parser.add_argument("--select", default=None, metavar="CODES",
                        help="comma-separated RPA codes to run (default: all)")
    parser.add_argument("--list-rules", action="store_true",
                        help="print every registered rule code and exit")
    args = parser.parse_args(argv)

    if args.list_rules:
        for code, desc in rule_codes().items():
            print(f"{code}  {desc}")
        return 0

    select = None
    if args.select:
        select = [c.strip() for c in args.select.split(",") if c.strip()]
    findings = run_paths(args.paths or ["src"], select=select)
    print(format_json(findings) if args.json else format_text(findings))
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
