"""Train steps: the standard SPMD step and the paper's partitioned step.

``make_train_step``         — pjit path: fixed grad-accumulation via lax.scan,
                              AdamW update, loss/metrics. Used by the trainer
                              and by the dry-run train cells.
``make_partitioned_train_step`` — THE PAPER AS A TRAINING FEATURE: pods are
  the paper's channels. Each pod runs its own (variable!) number of
  grad-accumulation microsteps k_p — the integerized split f from the
  frontier — inside a manual-over-"pod" shard_map; a single cross-pod psum
  joins the outputs (optionally int8-compressed with error feedback for the
  DCN hop). The step's wall-clock is max over pods of pod work — exactly the
  paper's max-of-channels completion time, which the scheduler minimizes in
  (mu, sigma^2).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..compat import shard_map
from ..configs.base import ModelConfig
from ..optim.adamw import AdamWState, adamw_init, adamw_update
from ..optim.compress import dequantize_int8, quantize_int8
from .loss import softmax_xent

__all__ = ["TrainState", "init_state", "make_train_step",
           "make_partitioned_train_step", "forward"]


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState


def init_state(model, key) -> TrainState:
    params = model.init(key)
    return TrainState(params=params, opt=adamw_init(params))


def forward(model, cfg: ModelConfig, params, tokens, extra_embeds=None):
    """Uniform forward dispatch across LM / EncDec / VLM."""
    if cfg.is_encoder_decoder:
        return model.apply(params, tokens, extra_embeds)
    if cfg.num_patches:
        return model.apply(params, tokens, extra_embeds)
    return model.apply(params, tokens)


def make_loss_fn(model, cfg: ModelConfig, *, reduce: str = "mean") -> Callable:
    def loss_fn(params, tokens, labels, extra_embeds=None):
        logits = forward(model, cfg, params, tokens, extra_embeds)
        loss, metrics = softmax_xent(logits, labels, cfg.vocab_size)
        if reduce == "sum":
            total = loss * metrics["tokens"]
            return total, metrics
        return loss, metrics
    return loss_fn


def make_train_step(model, cfg: ModelConfig, lr, *, accum: int = 1,
                    weight_decay: float = 0.1, max_grad_norm: float = 1.0,
                    accum_dtype=jnp.float32):
    """Standard SPMD train step with optional fixed grad accumulation.

    accum_dtype: gradient-accumulator precision. f32 is the safe default;
    bf16 halves the accumulator read-modify-write traffic that dominates the
    memory roofline term of large-MoE training (EXPERIMENTS §Perf) at the
    cost of ~8 bits of gradient mantissa during accumulation.
    """
    loss_fn = make_loss_fn(model, cfg)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: TrainState, tokens, labels, extra_embeds=None):
        if accum == 1:
            (loss, metrics), grads = grad_fn(state.params, tokens, labels,
                                             extra_embeds)
        else:
            B = tokens.shape[0]
            mb = B // accum
            resh = lambda x: x.reshape(accum, mb, *x.shape[1:]) if x is not None else None
            tk, lb = resh(tokens), resh(labels)
            ee = resh(extra_embeds)

            def micro(carry, xs):
                g_acc, l_acc = carry
                if ee is None:
                    t, l = xs
                    (loss, m), g = grad_fn(state.params, t, l, None)
                else:
                    t, l, e = xs
                    (loss, m), g = grad_fn(state.params, t, l, e)
                g_acc = jax.tree.map(lambda a, b: a + b.astype(a.dtype), g_acc, g)
                return (g_acc, l_acc + loss), m

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype),
                              state.params)
            xs = (tk, lb) if ee is None else (tk, lb, ee)
            (grads, loss_sum), ms = jax.lax.scan(micro, (g0, jnp.float32(0)), xs)
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss = loss_sum / accum
            metrics = jax.tree.map(lambda x: x[-1], ms)
            metrics["loss"] = loss
        params, opt, om = adamw_update(state.params, grads, state.opt, lr,
                                       weight_decay=weight_decay,
                                       max_grad_norm=max_grad_norm)
        return TrainState(params, opt), {**metrics, **om}

    return train_step


def make_partitioned_train_step(model, cfg: ModelConfig, mesh, lr, *,
                                max_micro: int, weight_decay: float = 0.1,
                                max_grad_norm: float = 1.0,
                                compress_pod_reduce: bool = False,
                                pod_axis: str = "pod", grad_specs=None):
    """Uncertainty-partitioned train step (see module docstring).

    Inputs per call:
      tokens/labels: (max_micro, B_mb, S) with B_mb sharded over
        (pod, data) — each pod sees its own (max_micro, B_mb/|pod|, S) slab.
      k_pods: (|pod|,) int32 microstep counts from the partitioner; pod p
        processes slabs [0, k_p) and idles the rest — the realized split.
    """
    loss_fn = make_loss_fn(model, cfg, reduce="sum")
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    npods = mesh.shape[pod_axis]

    def _pin(tree):
        """Constrain grad accumulators to the params' FSDP/TP layout.

        Without this the accumulator (born from jnp.zeros inside the
        manual-pod region) defaults to REPLICATED, and the cross-pod psum
        moves full-model bytes instead of shard bytes (measured 16x bloat —
        EXPERIMENTS.md §Perf iteration 2)."""
        if grad_specs is None:
            return tree
        from jax.sharding import NamedSharding
        return jax.tree.map(
            lambda x, s: jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, s)), tree, grad_specs)

    def pod_body(params, tokens, labels, k):
        # manual over "pod"; auto over data/model. tokens: (max_micro, mb, S)
        g0 = _pin(jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))

        def cond(c):
            i = c[0]
            return i < k[0]

        def body(c):
            i, g_acc, loss_acc, tok_acc = c
            t = jax.lax.dynamic_index_in_dim(tokens, i, 0, keepdims=False)
            l = jax.lax.dynamic_index_in_dim(labels, i, 0, keepdims=False)
            (lsum, m), g = grad_fn(params, t, l, None)
            g_acc = _pin(jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                      g_acc, g))
            return i + 1, g_acc, loss_acc + lsum, tok_acc + m["tokens"]

        _, g_sum, loss_sum, tok_sum = jax.lax.while_loop(
            cond, body, (jnp.int32(0), g0, jnp.float32(0), jnp.float32(0)))

        if compress_pod_reduce:
            # int8 + error-free one-shot compression of the DCN hop:
            # all_gather(int8 q, f32 blockscales) then local dequant-sum.
            def creduce(g):
                q, s = quantize_int8(g)
                qg = jax.lax.all_gather(q, pod_axis)
                sg = jax.lax.all_gather(s, pod_axis)
                parts = [dequantize_int8(qg[i], sg[i], g.shape, jnp.float32)
                         for i in range(npods)]
                return functools.reduce(jnp.add, parts)
            g_tot = jax.tree.map(creduce, g_sum)
        else:
            g_tot = jax.lax.psum(g_sum, pod_axis)
        loss_tot = jax.lax.psum(loss_sum, pod_axis)
        tok_tot = jax.lax.psum(tok_sum, pod_axis)
        g_tot = jax.tree.map(lambda g: g / jnp.maximum(tok_tot, 1.0), g_tot)
        return g_tot, loss_tot / jnp.maximum(tok_tot, 1.0), tok_tot

    sharded = shard_map(
        pod_body, mesh=mesh,
        in_specs=(P(), P(None, pod_axis, None), P(None, pod_axis, None),
                  P(pod_axis)),
        out_specs=(P(), P(), P()),
        axis_names={pod_axis}, check_vma=False)

    def train_step(state: TrainState, tokens, labels, k_pods):
        grads, loss, tokens_done = sharded(state.params, tokens, labels, k_pods)
        params, opt, om = adamw_update(state.params, grads, state.opt, lr,
                                       weight_decay=weight_decay,
                                       max_grad_norm=max_grad_norm)
        return TrainState(params, opt), {"loss": loss, "tokens": tokens_done, **om}

    return train_step
