"""Training substrate: loss, steps (standard + paper-partitioned), loop."""
from .loss import softmax_xent
from .loop import Trainer, TrainerConfig
from .step import (TrainState, forward, init_state, make_partitioned_train_step,
                   make_train_step)

__all__ = ["softmax_xent", "Trainer", "TrainerConfig", "TrainState", "forward",
           "init_state", "make_partitioned_train_step", "make_train_step"]
