"""Cross-entropy loss, SPMD-safe over a vocab-sharded logits axis.

logsumexp and the label-logit gather are expressed as local reductions /
one-hot contractions so GSPMD lowers them to (local reduce + small psum)
instead of all-gathering (B, S, V) logits.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["softmax_xent"]


def softmax_xent(logits, labels, vocab_size: int):
    """logits: (B, S, Vp) (padded vocab); labels: (B, S) int32, -1 = masked.

    Returns (mean_loss, metrics dict). Padded vocab columns are excluded via
    a -inf additive mask (cheap: one iota compare, no materialized mask).
    """
    Vp = logits.shape[-1]
    lf = logits.astype(jnp.float32)
    pad_mask = jnp.arange(Vp) >= vocab_size
    lf = jnp.where(pad_mask[None, None, :], -1e30, lf)

    lmax = jax.lax.stop_gradient(jnp.max(lf, axis=-1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(lf - lmax), axis=-1)) + lmax[..., 0]

    valid = labels >= 0
    safe_labels = jnp.where(valid, labels, 0)
    onehot = jax.nn.one_hot(safe_labels, Vp, dtype=lf.dtype)
    picked = jnp.einsum("bsv,bsv->bs", lf, onehot)

    nll = (lse - picked) * valid.astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(valid), 1)
    loss = jnp.sum(nll) / denom
    acc = jnp.sum((jnp.argmax(lf, -1) == safe_labels) & valid) / denom
    return loss, {"loss": loss, "accuracy": acc, "tokens": denom}
