"""Training loop: checkpoint/restart, partitioner feedback, elastic hooks.

On real pods the per-pod step durations come from the runtime; in this CPU
container they come from sim.ClusterSim so the whole control loop (observe ->
re-partition -> assign) is exercised end-to-end. The loop is deliberately
host-side simple: all device work is inside the jitted step.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..ckpt.store import CheckpointManager, latest_step, restore
from ..configs.base import ModelConfig
from ..data.pipeline import SyntheticStream
from ..optim.adamw import cosine_schedule
from ..sched.balancer import UncertaintyAwareBalancer
from ..sim.cluster import ClusterSim
from .step import TrainState, init_state, make_partitioned_train_step, make_train_step

__all__ = ["TrainerConfig", "Trainer"]


@dataclass
class TrainerConfig:
    steps: int = 100
    batch: int = 8
    seq: int = 128
    lr: float = 3e-4
    warmup: int = 20
    accum: int = 1
    ckpt_dir: Optional[str] = None
    ckpt_interval: int = 50
    seed: int = 0
    log_every: int = 10
    # partitioned mode (the paper feature)
    partitioned: bool = False
    num_pods: int = 2
    microbatch: int = 2
    max_micro: int = 8
    lam: float = 0.05
    policy: str = "frontier"
    sim_mus: tuple = (1.0, 1.6)     # simulated per-pod sec/microbatch means
    sim_sigmas: tuple = (0.05, 0.4)


class Trainer:
    def __init__(self, model, cfg: ModelConfig, tcfg: TrainerConfig, mesh=None):
        self.model, self.cfg, self.tcfg, self.mesh = model, cfg, tcfg, mesh
        self.lr = cosine_schedule(tcfg.lr, tcfg.warmup, tcfg.steps)
        self.stream = SyntheticStream(cfg, tcfg.seq, tcfg.batch, seed=tcfg.seed)
        self.ckpt = (CheckpointManager(tcfg.ckpt_dir, tcfg.ckpt_interval)
                     if tcfg.ckpt_dir else None)
        self.balancer = None
        self.sim = None
        if tcfg.partitioned:
            assert mesh is not None and "pod" in mesh.axis_names
            self.balancer = UncertaintyAwareBalancer(
                tcfg.num_pods, lam=tcfg.lam, policy=tcfg.policy)
            self.sim = ClusterSim(
                channels=[__import__("repro.sim.cluster", fromlist=["Channel"])
                          .Channel(mu=m, sigma=s)
                          for m, s in zip(tcfg.sim_mus, tcfg.sim_sigmas)],
                seed=tcfg.seed)
            self._step_fn = jax.jit(make_partitioned_train_step(
                model, cfg, mesh, self.lr, max_micro=tcfg.max_micro))
        else:
            self._step_fn = jax.jit(make_train_step(
                model, cfg, self.lr, accum=tcfg.accum))

    # ------------------------------------------------------------------
    def init_or_restore(self, key) -> tuple:
        state = init_state(self.model, key)
        start = 0
        if self.ckpt and self.tcfg.ckpt_dir and latest_step(self.ckpt.dir) is not None:
            state, meta = restore(self.ckpt.dir, state)
            start = meta["step"]
            if self.balancer is not None and "balancer" in meta:
                self.balancer = UncertaintyAwareBalancer.from_state_dict(
                    meta["balancer"])
        return state, start

    def run(self, key=None, on_metrics: Optional[Callable] = None):
        key = key if key is not None else jax.random.PRNGKey(self.tcfg.seed)
        state, start = self.init_or_restore(key)
        history = []
        for step in range(start, self.tcfg.steps):
            batch = self.stream.batch_at(step)
            t0 = time.perf_counter()
            if self.tcfg.partitioned:
                state, metrics = self._partitioned_step(state, step, batch)
            else:
                ee = (jnp.asarray(batch.extra_embeds)
                      if batch.extra_embeds is not None else None)
                state, metrics = self._step_fn(state, jnp.asarray(batch.tokens),
                                               jnp.asarray(batch.labels), ee)
            metrics = {k: (float(v) if not isinstance(v, str) else v)
                       for k, v in metrics.items()}
            metrics["wall_s"] = time.perf_counter() - t0
            metrics["step"] = step
            history.append(metrics)
            if on_metrics:
                on_metrics(metrics)
            if self.ckpt:
                meta = {"balancer": self.balancer.state_dict()} if self.balancer else {}
                self.ckpt.maybe_save(step + 1, state, meta)
            if step % self.tcfg.log_every == 0:
                print(f"step {step:5d} loss {metrics.get('loss', float('nan')):.4f} "
                      f"wall {metrics['wall_s']*1e3:.0f}ms")
        if self.ckpt:
            self.ckpt.wait()
        return state, history

    # ------------------------------------------------------------------
    def _partitioned_step(self, state: TrainState, step: int, batch):
        t = self.tcfg
        k_pods = self.balancer.assign(t.max_micro * t.num_pods // 2)
        k_pods = np.clip(k_pods, 0, t.max_micro)
        tokens = np.asarray(batch.tokens)
        labels = np.asarray(batch.labels)
        # reshape host batch into (max_micro, num_pods*mb, S)
        need = t.max_micro * t.num_pods * t.microbatch
        reps = int(np.ceil(need / tokens.shape[0]))
        tokens = np.tile(tokens, (reps, 1))[:need]
        labels = np.tile(labels, (reps, 1))[:need]
        S = tokens.shape[1]
        tokens = tokens.reshape(t.max_micro, t.num_pods * t.microbatch, S)
        labels = labels.reshape(t.max_micro, t.num_pods * t.microbatch, S)
        state, metrics = self._step_fn(state, jnp.asarray(tokens),
                                       jnp.asarray(labels), jnp.asarray(k_pods))
        # simulated per-pod durations feed the posterior (real pods: runtime).
        # run_step normalizes the counts to work fractions; pod rates are sec
        # per *microbatch*, so scale the realized times back to seconds
        join_t, durs = self.sim.run_step(k_pods.astype(np.float64))
        total_work = float(k_pods.sum())
        join_t, durs = join_t * total_work, durs * total_work
        self.balancer.observe(durs, k_pods.astype(np.float64))
        metrics = dict(metrics)
        metrics["sim_join_time"] = join_t
        metrics["k_pods"] = str(k_pods.tolist())
        return state, metrics
