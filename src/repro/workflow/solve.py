"""Joint optimization of every stage split in a StageDAG.

The greedy baseline solves each stage alone (fastest expected stage time)
and composes whatever comes out. That is exactly what the paper shows to be
insufficient WITHIN a stage — variance matters at a join — lifted one level:
a stage feeding a join should trade a little expected time for variance,
because the join's ``E[max]`` pays for every branch's spread, and the only
way to see that is to optimize the end-to-end makespan through the
composition.

This solver does that with one batched kernel path:

1. **Stack**: every stage's iterate is one row of a ``(R*S, K_max)`` weight
   matrix (R = multi-starts, S = stages; stage fleets zero-padded to
   ``K_max`` — a ``w=0`` channel is a point mass that drops out of the
   survival product, so padding is exact, and a mask keeps padded weights at
   zero through the projection). Stages are grouped by completion-time
   family (``dist_id`` is a static kernel specialization); within a group
   every stage's statistics ride the per-row (stacked) layout of
   ``ops.frontier_moments_with_grads``, so ONE fused launch per family —
   not per stage — returns every stage's moments and analytic adjoints.
   An all-one-family DAG (the benchmark) is literally a single launch per
   PGD step.
2. **Compose**: the per-stage ``(mu_s, var_s)`` flow through
   ``dag.compose_moments`` (series sums + Clark joins) to the makespan;
   autodiff runs only over these O(S) Clark folds — the expensive
   d(moments)/dW part is the fused kernel adjoints (PR 2/4), chained by
   hand: ``dL/dW_s = dL/dmu_s * dmu_s/dW_s + dL/dvar_s * dvar_s/dW_s``.
3. **Descend**: projected gradient on the concatenation of all stage
   simplices (masked Held projection per stage block), cosine step decay,
   multi-start, warm-startable from a previous solve (the balancer's tick
   path).

**Multi-fidelity ladder (PR 8).** Quadrature resolution is the solve's
price knob, and most of the work does not need the fine rung:

* the stage-local presolve and the candidate triage run at a coarse
  ``presolve_num_t`` (default 128 points — the composed-makespan RANKING of
  candidates is far less sensitive to quadrature than the absolute moments,
  because the coarse/fine bias is shared across candidates);
* starts whose coarse composed score trails the coarse incumbent by more
  than ``prune_margin`` (relative) are dropped before any fine-fidelity
  work, and near-duplicate survivors (starts that presolved to the same
  frontier point) collapse to their best-scored representative —
  typically the refine descends one survivor, not every start;
* the composed refine runs at ``num_t`` under a plateau early-stop
  (``plateau_tol``/``plateau_patience``) instead of a fixed step count;
* the FINAL pick always scores the surviving candidate pool at evaluation
  resolution (``eval_num_t``, default max(num_t, 2048)) — coarse scores
  are triage-only and never decide the returned split.

**Incremental re-solves.** ``dirty`` names the stages whose estimation
state moved since the ``warm_start`` split was computed: only their rows
take PGD steps (a traced 0/1 mask gates the update — frozen rows still
contribute their moments to the composed makespan but pass through every
step and the final pick BITWISE, never re-projected or renormalized). An
empty dirty set short-circuits to the warm split verbatim with one forward
evaluation and no PGD launch at all.

Objective: ``makespan_mu + lam_var * makespan_var``; with ``risk_lam > 0``
and per-stage NIG posteriors, finalists additionally pay the delta-method
fragility of the predicted makespan under estimation error — the
``core.sensitivity`` machinery chained through the composition (the stage
parameter adjoints come from the same stacked full-parameter launch).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..analysis import sanitize as _san
from ..core.bayes import nig_estimate_ses
from ..core.distributions import resolve_family
from ..core.partitioner import optimize_weights
from ..kernels import autotune, ops
from ..obs import names as obs_names
from ..obs import trace as obs
from .dag import StageDAG, compose_structure

__all__ = ["DAGDecision", "solve_dag", "solve_dag_greedy", "evaluate_dag",
           "stack_rows"]

# default coarse rung of the fidelity ladder: presolve + triage quadrature
_COARSE_NUM_T = 128
# refine steps start from a PRESOLVED (near-frontier) iterate, where the
# presolve's cold-start step size overshoots and oscillates for most of the
# cosine schedule — a 10x smaller step descends monotonically (which is also
# what makes the plateau early-stop a sound criterion for the refine)
_PRESOLVE_LR = 0.05
_REFINE_LR = 0.005
# triage survivors whose weight stacks agree within this L-inf distance are
# the SAME candidate (independent starts converged to one frontier point);
# refining duplicates is pure waste, the best-scored representative stays
_DEDUPE_TOL = 5e-3


@dataclass(frozen=True)
class DAGDecision:
    """All stage splits plus the predicted end-to-end moments."""

    weights: Dict[str, np.ndarray]  # per-stage simplex weights (K_s,)
    makespan_mu: float
    makespan_var: float
    stage_mu: np.ndarray            # (S,) per-stage duration means
    stage_var: np.ndarray           # (S,)
    method: str
    family_groups: int = 1          # kernel launches per moment evaluation
    fragility: Optional[float] = None
    profile: Optional[dict] = None  # per-phase wall times + solver counters

    @property
    def relative_fragility(self) -> Optional[float]:
        if self.fragility is None:
            return None
        return float(self.fragility / max(self.makespan_mu, 1e-12))


# --------------------------------------------------------------------- stack
@dataclass(frozen=True)
class _Group:
    """Stages sharing one dist_id: one stacked launch serves them all."""

    dist_id: str
    idx: Tuple[int, ...]            # stage indices (canonical stage order)
    mus: np.ndarray                 # (n, Kmax) zero-padded
    sigmas: np.ndarray              # (n, Kmax)
    extra: np.ndarray               # (E, n, Kmax)


def stack_rows(rows, kmax: Optional[int] = None
               ) -> Tuple[List[_Group], np.ndarray, int]:
    """Variable-shape row-block bookkeeping for stacked family launches.

    ``rows`` is any sequence of ``(mus, sigmas, family)`` triples — a DAG's
    stages, or a serving engine's live (instance, remaining-stage) pairs.
    Channel counts may differ per row; every row zero-pads its channel axis
    to ``kmax`` (a ``w=0`` channel is a point mass that drops out of the
    survival product, so padding is EXACT — the returned mask keeps padded
    weights at zero through the simplex projection). Rows group by lowered
    ``dist_id`` (a static kernel specialization) in first-appearance order,
    so one ``ops.frontier_moments*`` launch per group serves every row in
    it; ``group.idx`` indexes back into ``rows``.

    Pass ``kmax`` to pin the channel axis across calls: a serving tick
    whose live set changes shape every tick would otherwise re-jit per
    distinct max-K. Returns ``(groups, mask (N, kmax), kmax)``.
    """
    rows = list(rows)
    ks = [int(np.asarray(m).shape[0]) for m, _, _ in rows]
    kmax = max(ks) if kmax is None else int(kmax)
    if ks and max(ks) > kmax:
        raise ValueError(f"row channel count {max(ks)} exceeds the pinned "
                         f"kmax={kmax}")
    N = len(rows)
    mask = np.zeros((N, kmax), np.float32)
    by_dist: Dict[str, List[int]] = {}
    lowered = []
    for i, (mus_i, _, family) in enumerate(rows):
        dist_id, extra = resolve_family(family, ks[i])
        lowered.append((dist_id, np.asarray(extra, np.float32)))
        by_dist.setdefault(dist_id, []).append(i)
        mask[i, :ks[i]] = 1.0
    groups = []
    for dist_id, idx in by_dist.items():
        n = len(idx)
        E = lowered[idx[0]][1].shape[0]
        mus = np.zeros((n, kmax), np.float32)
        sgs = np.zeros((n, kmax), np.float32)
        ex = np.zeros((E, n, kmax), np.float32)
        for j, i in enumerate(idx):
            k = ks[i]
            mus[j, :k] = rows[i][0]
            sgs[j, :k] = rows[i][1]
            ex[:, j, :k] = lowered[i][1]
        groups.append(_Group(dist_id, tuple(idx), mus, sgs, ex))
    return groups, mask, kmax


def _stage_groups(dag: StageDAG) -> Tuple[List[_Group], np.ndarray, int]:
    """Group stages by family; returns (groups, mask (S, Kmax), Kmax)."""
    return stack_rows([(s.mus, s.sigmas, s.family) for s in dag.stages])


def _project_simplex_masked(v, mask):
    """Held projection onto the simplex of the ACTIVE (mask=1) channels.

    Inactive entries (a stage's zero-padding up to K_max) are pinned far
    below every active value so they never enter the threshold computation
    and land exactly on zero after the clamp.
    """
    k = v.shape[-1]
    vm = jnp.where(mask > 0, v, -1e9)
    u = jnp.sort(vm)[::-1]
    css = jnp.cumsum(u) - 1.0
    idx = jnp.arange(1, k + 1, dtype=v.dtype)
    cond = u - css / idx > 0
    rho = jnp.max(jnp.where(cond, jnp.arange(k), -1))
    theta = css[rho] / (rho + 1.0)
    return jnp.maximum(vm - theta, 0.0)


def _stage_moments_grads(W, dist_ids, idxs, stats, num_t, impl, bfs):
    """Per-stage (mu, var, dmu_dW, dvar_dW) — one stacked launch per family.

    W: (R, S, Kmax). Rows of group g are the R x n_g stage iterates; the
    group's per-stage statistics tile over starts in the same (r, j) order.
    """
    R, S, kmax = W.shape
    smu = jnp.zeros((R, S))
    svar = jnp.zeros((R, S))
    dmu = jnp.zeros((R, S, kmax))
    dvar = jnp.zeros((R, S, kmax))
    for g, dist_id in enumerate(dist_ids):
        idx = jnp.asarray(idxs[g])
        mus_g, sgs_g, ex_g = stats[g]
        n = mus_g.shape[0]
        rows = W[:, idx, :].reshape(R * n, kmax)
        m, v, dm, dv = ops.frontier_moments_with_grads(
            rows, jnp.tile(mus_g, (R, 1)), jnp.tile(sgs_g, (R, 1)),
            num_t=num_t, impl=impl, block_f=bfs[g],
            family=(dist_id, jnp.tile(ex_g, (1, R, 1))))
        smu = smu.at[:, idx].set(m.reshape(R, n))
        svar = svar.at[:, idx].set(v.reshape(R, n))
        dmu = dmu.at[:, idx, :].set(dm.reshape(R, n, kmax))
        dvar = dvar.at[:, idx, :].set(dv.reshape(R, n, kmax))
    return smu, svar, dmu, dvar


@partial(jax.jit, static_argnames=("structure", "dist_ids", "idxs", "steps",
                                   "patience", "num_t", "impl", "bfs",
                                   "composed", "sanitize"))
def _pgd_phase(structure, dist_ids, idxs, stats, masks, W0, upd, lam_var,
               plateau_tol, steps: int, patience: int, num_t: int,
               impl: str, bfs, composed: bool, lr: float = _PRESOLVE_LR,
               warmup: int = 0, sanitize: bool = False):
    """One masked-PGD phase over the stacked stage simplices.

    ``composed=False`` descends each stage's LOCAL expected join time (the
    graph-blind presolve objective — the per-row loss decouples into a sum
    of stage means); ``composed=True`` descends the composed makespan
    (fused kernel adjoints chained with the composition's cotangents).

    ``upd`` is the traced (S,) dirty mask of an incremental re-solve: rows
    of frozen stages (``upd == 0``) contribute their moments to the
    composed objective but take no step — the update is gated by
    ``jnp.where`` so a frozen row passes through BITWISE (it is never
    re-projected; Held projection of an on-simplex point is not
    bit-stable). A traced mask means distinct dirty sets share one
    compiled solver.

    Plateau early-stop: the loop exits when the pool-best objective fails
    to improve by a relative ``plateau_tol`` for ``patience`` consecutive
    steps (``patience >= steps`` disables). Stalls only COUNT once the
    step index passes ``warmup``: a cold start under a large cosine step
    oscillates (the pool best can sit still for long windows while the
    iterates are mid-transit toward the real descent later in the
    schedule), so stall windows before the warmup are evidence of nothing.
    The cosine schedule keeps its ``steps``-length horizon, so early exit
    stops at a mid-schedule step size — the best-iterate tracking below
    makes that safe.

    Returns ``(W_final, W_best, best_loss, steps_run)``: ``W_best`` is the
    best-objective iterate seen per start at THIS phase's fidelity (the
    schedule can overshoot past it; both snapshots join the final pool so
    refinement can explore without ever losing ground).

    Static ``sanitize=True`` plants checkify invariant checks per step;
    legal only under ``analysis.sanitize.run_checked`` (see that module).
    """
    proj = jax.vmap(jax.vmap(_project_simplex_masked))
    masks_b = jnp.broadcast_to(masks, W0.shape)
    upd_b = (upd > 0)[None, :, None]

    def loss_one(smu_r, svar_r):
        mk_mu, mk_var = compose_structure(structure, smu_r, svar_r)
        return mk_mu + lam_var * mk_var

    val_grad = jax.vmap(jax.value_and_grad(loss_one, argnums=(0, 1)))

    def cond(c):
        i, W, Wb, row_best, pool_best, stall = c
        return (i < steps) & (stall < patience)

    def body(c):
        i, W, Wb, row_best, pool_best, stall = c
        smu, svar, dmu, dvar = _stage_moments_grads(
            W, dist_ids, idxs, stats, num_t, impl, bfs)
        if composed:
            losses, (g_mu, g_var) = val_grad(smu, svar)    # (R,), (R, S)
            G = g_mu[..., None] * dmu + g_var[..., None] * dvar
        else:
            losses = jnp.sum(smu, axis=1)
            G = dmu                                        # stage-local mean
        if sanitize:
            _san.check_finite(smu, "DAG stage means")
            _san.check_finite(G, "DAG PGD gradient")
        better = losses < row_best
        Wb = jnp.where(better[:, None, None], W, Wb)
        row_best = jnp.minimum(row_best, losses)
        cur = jnp.min(losses)
        moved = pool_best - cur > plateau_tol * jnp.abs(pool_best)
        stall = jnp.where(moved | (i < warmup), 0, stall + 1)
        pool_best = jnp.minimum(pool_best, cur)
        G = G / (jnp.linalg.norm(G, axis=-1, keepdims=True) + 1e-12)
        step = lr * 0.5 * (1.0 + jnp.cos(jnp.pi * i / steps))
        W = jnp.where(upd_b, proj(W - step * G, masks_b), W)
        if sanitize:
            _san.check_weight_rows(W, "DAG PGD iterate")
        return (i + 1, W, Wb, row_best, pool_best, stall)

    R = W0.shape[0]
    # 1e30, not inf: inf-inf poisons the first plateau comparison
    init = (jnp.int32(0), W0, W0, jnp.full((R,), 1e30, jnp.float32),
            jnp.float32(1e30), jnp.int32(0))
    i, W, Wb, row_best, _, _ = jax.lax.while_loop(cond, body, init)
    return W, Wb, row_best, i


@partial(jax.jit, static_argnames=("structure", "dist_ids", "idxs", "num_t",
                                   "impl", "bfs"))
def _score_dag(structure, dist_ids, idxs, stats, W, num_t: int, impl: str,
               bfs):
    """Composed (makespan mu, var) and stage moments for finalists W."""
    R, S, kmax = W.shape
    smu = jnp.zeros((R, S))
    svar = jnp.zeros((R, S))
    for g, dist_id in enumerate(dist_ids):
        idx = jnp.asarray(idxs[g])
        mus_g, sgs_g, ex_g = stats[g]
        n = mus_g.shape[0]
        rows = W[:, idx, :].reshape(R * n, kmax)
        m, v = ops.frontier_moments(
            rows, jnp.tile(mus_g, (R, 1)), jnp.tile(sgs_g, (R, 1)),
            num_t=num_t, impl=impl, block_f=bfs[g],
            family=(dist_id, jnp.tile(ex_g, (1, R, 1))))
        smu = smu.at[:, idx].set(m.reshape(R, n))
        svar = svar.at[:, idx].set(v.reshape(R, n))
    mk = jax.vmap(lambda m, v: jnp.stack(
        compose_structure(structure, m, v)))(smu, svar)
    return mk[:, 0], mk[:, 1], smu, svar


def _se_stacks(dag: StageDAG, groups, posteriors, kmax: int):
    """Per-group (se_mu, se_sigma) stacks, zero-padded like the stats."""
    ses = {}
    for name, nig in posteriors.items():
        se_mu, se_sg = nig_estimate_ses(nig)
        ses[name] = (np.asarray(se_mu, np.float64),
                     np.asarray(se_sg, np.float64))
    out = []
    for g in groups:
        n = len(g.idx)
        se_m = np.zeros((n, kmax))
        se_s = np.zeros((n, kmax))
        for j, i in enumerate(g.idx):
            s = dag.stages[i]
            if s.name in ses:
                se_m[j, :s.k], se_s[j, :s.k] = ses[s.name]
        out.append((se_m, se_s))
    return out


def _dag_fragility(structure, groups, stats, se_stacks, W, smu, svar,
                   num_t, impl, bfs):
    """Delta-method sd of the predicted makespan mean under estimation error.

    ``estimation_fragility`` chained through the composition: the stacked
    full-parameter launch gives every stage's d(mu_s, var_s)/d(mus, sigmas);
    the composition's cotangents d(mk_mu)/d(mu_s, var_s) come from autodiff
    over the Clark folds, taken at the smu/svar the candidates were SCORED
    at (the finalist evaluation is reused — only the parameter adjoints
    need a fresh launch, at the solve fidelity). Stage posteriors are
    independent, so the variance contributions add across stages AND
    channels.
    """
    R, S, kmax = W.shape
    gmk = jax.vmap(jax.grad(
        lambda m, v: compose_structure(structure, m, v)[0],
        argnums=(0, 1)))(smu, svar)
    g_mu, g_var = (np.asarray(g, np.float64) for g in gmk)   # (R, S)
    frag2 = np.zeros(R)
    for g, grp in enumerate(groups):
        idx = np.asarray(grp.idx)
        n = len(grp.idx)
        mus_g, sgs_g, ex_g = stats[g]
        rows = np.asarray(W[:, idx, :]).reshape(R * n, kmax)
        outs = ops.frontier_moments_with_grads(
            rows, np.tile(np.asarray(mus_g), (R, 1)),
            np.tile(np.asarray(sgs_g), (R, 1)),
            num_t=num_t, impl=impl, block_f=bfs[g],
            family=(grp.dist_id, jnp.tile(jnp.asarray(ex_g), (1, R, 1))),
            param_grads=True)
        dmu_m, dvar_m = (np.asarray(outs[4], np.float64).reshape(R, n, kmax),
                         np.asarray(outs[5], np.float64).reshape(R, n, kmax))
        dmu_s, dvar_s = (np.asarray(outs[6], np.float64).reshape(R, n, kmax),
                         np.asarray(outs[7], np.float64).reshape(R, n, kmax))
        se_m, se_s = se_stacks[g]
        cm = g_mu[:, idx, None] * dmu_m + g_var[:, idx, None] * dvar_m
        cs = g_mu[:, idx, None] * dmu_s + g_var[:, idx, None] * dvar_s
        frag2 += ((cm * se_m) ** 2).sum(axis=(1, 2)) \
            + ((cs * se_s) ** 2).sum(axis=(1, 2))
    return np.sqrt(frag2)


# --------------------------------------------------------------------- solve
def _dag_with_done(dag: StageDAG, done: Dict[str, np.ndarray]) -> StageDAG:
    """Rescale named stages' statistics to their remaining work.

    Per-stage :func:`core.distributions.remaining_work_stats`: a half-done
    stage re-solves a fresh unit simplex over ``r``-scaled statistics; a
    fully-done stage degenerates to all-zero stats (every channel a point
    mass at 0 — zero duration, gates nothing).
    """
    mus_by, sgs_by, fam_by = {}, {}, {}
    from ..core.distributions import family_from_extra, remaining_work_stats
    for s in dag.stages:
        if s.name not in done:
            continue
        dist_id, extra = resolve_family(s.family, s.k)
        mus_r, sgs_r, extra_r, _ = remaining_work_stats(
            dist_id, np.asarray(s.mus), np.asarray(s.sigmas),
            np.asarray(extra), np.asarray(done[s.name]))
        # Stage validation requires strictly positive means; a fully-done
        # stage floors to a negligible point mass instead of zero
        mus_by[s.name] = np.maximum(mus_r, 1e-9)
        sgs_by[s.name] = sgs_r
        # Stage validates family specs through get_family, which rejects
        # lowered tuples — raise the rescaled extras back to an instance
        fam_by[s.name] = family_from_extra(dist_id, extra_r)
    return dag.with_stats(mus_by, sgs_by, fam_by)


def _starts(dag: StageDAG, mask: np.ndarray, kmax: int, restarts: int,
            warm_start, key, upd: Optional[np.ndarray] = None) -> np.ndarray:
    """(R, S, Kmax) start stack: equal, inverse-mu, warm, Dirichlet.

    ``upd`` (S,) 0/1 marks the dirty stages of an incremental re-solve.
    When given, the warm row is taken VERBATIM (no renormalization — it
    must already be a valid simplex row, e.g. any previous solve's output)
    and every start's FROZEN rows are overwritten with the warm rows, so
    all candidates agree bitwise on the stages the solve must not move.
    """
    S = len(dag.stages)
    act = mask.astype(np.float64)
    eq = act / act.sum(axis=1, keepdims=True)
    inv = np.zeros_like(eq)
    for i, s in enumerate(dag.stages):
        # floor guards the fully-done (all-zero-stats) re-solve stages
        w = 1.0 / np.maximum(np.asarray(s.mus), 1e-12)
        inv[i, :s.k] = w / w.sum()
    starts = [eq, inv]
    if warm_start is not None:
        wm = np.zeros((S, kmax))
        for i, s in enumerate(dag.stages):
            w = np.asarray(warm_start[s.name], np.float64)
            if upd is None:
                w = np.maximum(w, 0.0)
                wm[i, :s.k] = w / max(w.sum(), 1e-12)
            else:
                wm[i, :s.k] = w
        starts.insert(0, wm)
    if restarts > 0:
        rng = np.random.default_rng(
            0 if key is None else int(np.asarray(
                jax.random.key_data(key)).ravel()[-1]))
        for _ in range(restarts):
            e = rng.exponential(size=(S, kmax)) * act
            starts.append(e / np.maximum(e.sum(axis=1, keepdims=True),
                                         1e-12))
    out = np.stack(starts)
    if upd is not None:
        frozen = upd <= 0
        out[:, frozen, :] = out[0, frozen, :]
    return out.astype(np.float32)


class _PhaseClock:
    """Sequential phase attribution on the span API (PR 10).

    ``lap(next)`` closes the open ``solver.phase`` span, books its duration
    into ``phase_us``, and opens the next phase — so the ladder profile the
    benchmarks report and the spans a trace viewer shows are the SAME
    measurement, not two hand timers drifting apart. ``timed_span`` always
    measures; it records into the trace ring buffer only under
    ``REPRO_TRACE=1``.
    """

    def __init__(self, phase_us: Dict[str, float]):
        self.phase_us = phase_us
        self._open = None

    def start(self, phase: str) -> None:
        self._open = obs.timed_span(obs_names.SPAN_SOLVER_PHASE,
                                    phase=phase).__enter__()

    def lap(self, next_phase: Optional[str] = None) -> None:
        sp = self._open
        sp.__exit__(None, None, None)
        self.phase_us[sp.attrs["phase"]] = round(sp.dur_us, 1)
        self._open = None
        if next_phase is not None:
            self.start(next_phase)


def solve_dag(dag: StageDAG, lam_var: float = 0.0, steps: int = 120,
              restarts: int = 2, num_t: int = 1024, impl: str = "xla",
              block_f: Optional[int] = None,
              key: Optional[jax.Array] = None,
              warm_start: Optional[Dict[str, np.ndarray]] = None,
              risk_lam: float = 0.0,
              posteriors: Optional[Dict[str, object]] = None,
              presolve_steps: Optional[int] = None,
              eval_num_t: Optional[int] = None,
              done: Optional[Dict[str, np.ndarray]] = None,
              presolve_num_t: Optional[int] = None,
              prune_margin: Optional[float] = 5e-3,
              plateau_tol: float = 1e-6,
              plateau_patience: Optional[int] = 8,
              dirty: Optional[object] = None) -> DAGDecision:
    """Jointly optimize every stage's split for the end-to-end makespan.

    Objective: ``makespan_mu + lam_var * makespan_var`` composed through the
    DAG (series sums, Clark joins), descended by masked projected gradient
    over the concatenated stage simplices through a multi-fidelity ladder:

    1. stage-local presolve at ``presolve_num_t`` quadrature points
       (default min(num_t, 128)) — every stage to its own frontier;
    2. coarse triage: {starts, presolve snapshots} scored on the COMPOSED
       objective at ``presolve_num_t``; starts whose best coarse score
       trails the incumbent by more than ``prune_margin`` (relative) are
       dropped before any fine-fidelity work, and near-duplicate survivors
       collapse to one representative (``prune_margin=None`` disables the
       margin prune; the incumbent and the warm start always survive);
    3. composed refine of the survivors at ``num_t`` — warm from the
       presolve, so it descends with a small step — under plateau
       early-stop (``plateau_tol`` relative improvement, ``plateau_patience``
       consecutive stalls counted after a schedule warmup;
       ``plateau_patience=None`` restores the fixed step count);
    4. final pick: the surviving pool (refine inits, best-seen iterates,
       refined iterates) scored at ``eval_num_t`` (default
       max(num_t, 2048)) — coarse scores are triage-only, the returned
       split is ALWAYS chosen at evaluation fidelity, so the refine can
       only improve on the presolve and a warm start is never lost to an
       overshooting step.

    Every moment/gradient evaluation runs through ONE stacked
    ``ops.frontier_moments*`` launch per completion-time family present in
    the DAG — stages are rows, never a Python loop over kernel launches.
    Each (fidelity, mode) pair resolves its own autotuned block shape:
    ``num_t`` is part of the autotune key schema, so coarse-rung entries
    never cross-contaminate fine-rung silicon sweeps.

    ``warm_start``: per-stage weights of a previous solve (the balancer's
    refresh ticks). ``dirty`` (requires ``warm_start``) is the incremental
    re-solve contract: only the named stages' rows take PGD steps; frozen
    stages contribute moments to the composed makespan but their rows pass
    through bitwise (exact pass-throughs — bit-identical for
    float32-representable warm rows, which any previous solve's output
    is). An EMPTY dirty set returns the warm split verbatim (bitwise, no
    PGD launch) with moments from a single forward evaluation.

    ``risk_lam > 0`` with per-stage ``posteriors`` ({stage name: NIGState})
    scores finalists risk-adjusted by the composed estimation fragility;
    the fragility of the winning candidate is reported on the decision
    whenever posteriors are given (the balancer's adaptive refresh sizes
    its cadence by it) — with ``risk_lam == 0`` only the winner's
    fragility is computed (one single-row launch), reusing the finalist
    evaluation's moments for the composition cotangents.

    ``done`` ({stage name: per-channel completed work fractions}) is the
    sunk-work mid-flight re-solve: each named stage's statistics are rescaled
    to its remaining work through ``distributions.remaining_work_stats``
    before grouping, and its returned weights are shares of THAT REMAINING
    work (stages not named are solved for their full unit of work). A stage
    whose work is entirely done keeps zero weights and zero duration moments
    — it no longer gates its joins.

    ``decision.profile`` carries per-phase wall times (``phase_us``) and
    solver counters (starts, survivors, pool size, steps run per phase) so
    fidelity-ladder wins stay attributable.
    """
    phase_us: Dict[str, float] = {}
    clock = _PhaseClock(phase_us)
    clock.start("starts")
    if done:
        dag = _dag_with_done(dag, done)
    S = len(dag.stages)
    pnt = min(presolve_num_t if presolve_num_t is not None
              else _COARSE_NUM_T, num_t)
    et = eval_num_t or max(num_t, 2048)

    upd_np = None
    if dirty is not None:
        dset = {str(n) for n in dirty}
        unknown = dset - {s.name for s in dag.stages}
        if unknown:
            raise KeyError(f"dirty stages not in the DAG: {sorted(unknown)}")
        if warm_start is None:
            raise ValueError("dirty= is an incremental re-solve and "
                             "requires warm_start")
        if not dset:
            # nothing moved: the warm split stands verbatim — one forward
            # evaluation for the reported moments, no PGD launch at all
            with obs.timed_span(obs_names.SPAN_SOLVER_PHASE,
                                phase="final_score") as sp:
                base = evaluate_dag(dag, warm_start, num_t=et, impl=impl)
            return DAGDecision(
                weights={s.name: np.asarray(warm_start[s.name],
                                            np.float64).copy()
                         for s in dag.stages},
                makespan_mu=base.makespan_mu,
                makespan_var=base.makespan_var,
                stage_mu=base.stage_mu, stage_var=base.stage_var,
                method="pgd-dag-noop", family_groups=base.family_groups,
                profile={"phase_us": {"final_score": round(sp.dur_us, 1)},
                         "noop": True, "starts": 0, "survivors": 0,
                         "pool": 1, "presolve_num_t": pnt,
                         "eval_num_t": et})
        upd_np = np.array([1.0 if s.name in dset else 0.0
                           for s in dag.stages], np.float32)

    groups, mask, kmax = _stage_groups(dag)
    dist_ids = tuple(g.dist_id for g in groups)
    idxs = tuple(g.idx for g in groups)
    stats = tuple((jnp.asarray(g.mus), jnp.asarray(g.sigmas),
                   jnp.asarray(g.extra)) for g in groups)
    W0 = jnp.asarray(_starts(dag, mask, kmax, restarts, warm_start, key,
                             upd=upd_np))
    R = int(W0.shape[0])
    upd = jnp.asarray(upd_np if upd_np is not None
                      else np.ones(S, np.float32))
    pre = presolve_steps if presolve_steps is not None else steps
    patience = (plateau_patience if plateau_patience is not None
                else max(steps, pre, 1))

    # every launch mode AND fidelity rung resolves its OWN block shape: the
    # fused pgrad working set is ~4x the grad one, the eval pass runs a
    # larger grid, and T is part of the autotune key so the coarse rung's
    # swept entries never shadow the fine rung's
    def _bf(g, rows, nt, fused, params):
        if block_f is not None:
            return max(min(block_f, rows), 1)
        return autotune.lookup(rows, kmax, nt, backend=impl, fused=fused,
                               dist_id=g.dist_id, params=params,
                               stacked=True)

    def _run_phase(W_in, bfs_p, composed, n_steps, nt, pat, lr, warmup):
        if _san.enabled():
            return _san.run_checked(
                partial(_pgd_phase, steps=n_steps, patience=pat, num_t=nt,
                        impl=impl, bfs=bfs_p, composed=composed, lr=lr,
                        warmup=warmup, sanitize=True),
                dag.structure, dist_ids, idxs, stats, jnp.asarray(mask),
                W_in, upd, jnp.float32(lam_var), jnp.float32(plateau_tol))
        return _pgd_phase(dag.structure, dist_ids, idxs, stats,
                          jnp.asarray(mask), W_in, upd,
                          jnp.float32(lam_var), jnp.float32(plateau_tol),
                          n_steps, pat, nt, impl, bfs_p, composed,
                          lr=lr, warmup=warmup)

    if _san.enabled():
        # sanitizer tier: eager boundary validation of the stage statistics
        # once, then both jitted phases under checkify (analysis.sanitize)
        _san.assert_weight_rows(np.asarray(W0))
        for g in groups:
            _san.assert_finite("stage mus", g.mus)
            _san.assert_finite("stage sigmas", g.sigmas)
            _san.assert_nonneg("stage sigmas", g.sigmas)

    clock.lap("presolve")

    # --- phase 1: stage-local presolve at the coarse rung; stall counting
    # waits out the first half of the cosine schedule (cold starts spend it
    # in large-step transit where the pool best moves in bursts)
    bfs_pre = tuple(_bf(g, R * len(g.idx), pnt, True, False) for g in groups)
    W1, _, _, n_pre = _run_phase(W0, bfs_pre, False, pre, pnt, patience,
                                 _PRESOLVE_LR, pre // 2)
    jax.block_until_ready(W1)
    clock.lap("triage")

    # --- coarse triage: composed scores of {starts, presolve} at the same
    # rung; the coarse/fine quadrature bias is shared across candidates, so
    # the RANKING is meaningful at far lower resolution than the moments
    pool0 = jnp.concatenate([W0, W1], axis=0)
    bfs_tri = tuple(_bf(g, 2 * R * len(g.idx), pnt, False, False)
                    for g in groups)
    c_mu, c_var, _, _ = _score_dag(dag.structure, dist_ids, idxs, stats,
                                   pool0, pnt, impl, bfs_tri)
    csc = np.asarray(c_mu, np.float64) + lam_var * np.asarray(c_var,
                                                              np.float64)
    per_start = np.minimum(csc[:R], csc[R:])
    W0h, W1h = np.asarray(W0), np.asarray(W1)
    Wch = np.where((csc[R:] <= csc[:R])[:, None, None], W1h, W0h)
    if prune_margin is None:
        keep = np.ones(R, bool)
    else:
        inc = float(per_start.min())
        keep = per_start <= inc + prune_margin * max(abs(inc), 1e-12)
        keep[int(np.argmin(per_start))] = True
    # collapse near-duplicate survivors: independent starts routinely
    # presolve to the SAME frontier point; only the best-scored
    # representative of each cluster goes on to fine-fidelity refinement
    chosen: List[int] = []
    for i in np.argsort(per_start, kind="stable"):
        if not keep[i]:
            continue
        if any(float(np.abs(Wch[i] - Wch[j]).max()) <= _DEDUPE_TOL
               for j in chosen):
            keep[i] = False
        else:
            chosen.append(int(i))
    if warm_start is not None:
        keep[0] = True   # the warm start is never lost to coarse triage
    survivors = int(keep.sum())
    Wr0 = jnp.asarray(Wch[np.flatnonzero(keep)])
    clock.lap("refine")

    # --- phase 2: composed refine of the survivors at solve fidelity; the
    # survivors are presolved (near-frontier) so the step is small, but the
    # fixed-size normalized-gradient steps still orbit the optimum until the
    # cosine decay shrinks them — stalls count from mid-schedule here too
    bfs_ref = tuple(_bf(g, survivors * len(g.idx), num_t, True, False)
                    for g in groups)
    Wf, Wb, _, n_ref = _run_phase(Wr0, bfs_ref, True, steps, num_t, patience,
                                  _REFINE_LR, steps // 2)
    jax.block_until_ready(Wf)
    clock.lap("final_score")

    # --- final pick at evaluation fidelity: refine inits (which include the
    # triage winners and any warm start), best-seen and final iterates
    cands = jnp.concatenate([Wr0, Wb, Wf], axis=0)
    ncand = int(cands.shape[0])
    bfs_eval = tuple(_bf(g, ncand * len(g.idx), et, False, False)
                     for g in groups)
    mk_mu, mk_var, smu, svar = _score_dag(dag.structure, dist_ids, idxs,
                                          stats, cands, et, impl, bfs_eval)
    score = np.asarray(mk_mu, np.float64) + lam_var * np.asarray(
        mk_var, np.float64)
    clock.lap("fragility" if posteriors is not None else None)

    method = ("pgd-dag-joint-inc" if upd_np is not None else "pgd-dag-joint")
    frag = None
    se_stacks = None
    if posteriors is not None:
        se_stacks = _se_stacks(dag, groups, posteriors, kmax)
        if risk_lam > 0.0:
            bfs_frag = tuple(_bf(g, ncand * len(g.idx), num_t, True, True)
                             for g in groups)
            frag = _dag_fragility(dag.structure, groups, stats, se_stacks,
                                  cands, smu, svar, num_t, impl, bfs_frag)
            score = score + risk_lam * frag
            method += "-risk"
    best = int(np.argmin(score))
    frag_best = None
    if frag is not None:
        frag_best = float(frag[best])
    elif posteriors is not None:
        # reported fragility only: one single-row pgrad launch for the
        # WINNER, reusing its eval-fidelity moments for the composition
        # cotangents instead of re-launching the whole candidate pool
        bfs_frag = tuple(_bf(g, len(g.idx), num_t, True, True)
                         for g in groups)
        fb = _dag_fragility(dag.structure, groups, stats, se_stacks,
                            cands[best:best + 1], smu[best:best + 1],
                            svar[best:best + 1], num_t, impl, bfs_frag)
        frag_best = float(fb[0])
    if posteriors is not None:
        clock.lap()

    Wbest = np.asarray(cands[best], np.float64)
    weights = {s.name: Wbest[i, :s.k] for i, s in enumerate(dag.stages)}
    profile = {"phase_us": phase_us, "starts": R, "survivors": survivors,
               "pool": ncand, "presolve_num_t": pnt, "eval_num_t": et,
               "presolve_steps_run": int(n_pre),
               "refine_steps_run": int(n_ref)}
    return DAGDecision(
        weights=weights,
        makespan_mu=float(mk_mu[best]), makespan_var=float(mk_var[best]),
        stage_mu=np.asarray(smu[best], np.float64),
        stage_var=np.asarray(svar[best], np.float64),
        method=method, family_groups=len(groups),
        fragility=frag_best, profile=profile)


def evaluate_dag(dag: StageDAG, weights: Dict[str, np.ndarray],
                 num_t: int = 2048, impl: str = "xla") -> DAGDecision:
    """Composed moments of an arbitrary per-stage split (shared evaluator:
    joint and greedy decisions are compared on the SAME quadrature)."""
    groups, mask, kmax = _stage_groups(dag)
    dist_ids = tuple(g.dist_id for g in groups)
    idxs = tuple(g.idx for g in groups)
    stats = tuple((jnp.asarray(g.mus), jnp.asarray(g.sigmas),
                   jnp.asarray(g.extra)) for g in groups)
    S = len(dag.stages)
    W = np.zeros((1, S, kmax), np.float32)
    for i, s in enumerate(dag.stages):
        w = np.maximum(np.asarray(weights[s.name], np.float64), 0.0)
        W[0, i, :s.k] = w / max(w.sum(), 1e-12)
    bfs = tuple(autotune.lookup(len(g.idx), kmax, num_t, backend=impl,
                                fused=False, dist_id=g.dist_id, stacked=True)
                for g in groups)
    mk_mu, mk_var, smu, svar = _score_dag(dag.structure, dist_ids, idxs,
                                          stats, jnp.asarray(W), num_t,
                                          impl, bfs)
    return DAGDecision(
        weights={s.name: np.asarray(W[0, i, :s.k], np.float64)
                 for i, s in enumerate(dag.stages)},
        makespan_mu=float(mk_mu[0]), makespan_var=float(mk_var[0]),
        stage_mu=np.asarray(smu[0], np.float64),
        stage_var=np.asarray(svar[0], np.float64),
        method="evaluate", family_groups=len(groups))


def solve_dag_greedy(dag: StageDAG, lam: float = 0.0, steps: int = 120,
                     restarts: int = 2, num_t: int = 1024,
                     impl: str = "xla",
                     eval_num_t: Optional[int] = None,
                     presolve_num_t: Optional[int] = None,
                     warm_start: Optional[Dict[str, np.ndarray]] = None,
                     dirty: Optional[object] = None) -> DAGDecision:
    """Stage-by-stage baseline: each stage solved alone (``mu + lam var`` on
    its OWN join time), blind to where it sits in the graph — a per-stage
    Python loop over independent solves, the thing the joint solver
    replaces. Composed moments evaluated with the shared evaluator.

    The joint solver's knobs ride along for like-for-like comparisons:
    ``presolve_num_t`` runs the per-stage solves at a coarse quadrature
    rung (default None keeps them at ``num_t`` — the tracked baseline);
    ``dirty`` (requires ``warm_start``) copies the warm split verbatim for
    stages outside the set and re-solves only the dirty ones, warm-started.
    """
    if dirty is not None:
        dset = {str(n) for n in dirty}
        unknown = dset - {s.name for s in dag.stages}
        if unknown:
            raise KeyError(f"dirty stages not in the DAG: {sorted(unknown)}")
        if warm_start is None:
            raise ValueError("dirty= is an incremental re-solve and "
                             "requires warm_start")
    else:
        dset = None
    solve_t = num_t if presolve_num_t is None else min(presolve_num_t, num_t)
    weights = {}
    with obs.timed_span(obs_names.SPAN_SOLVER_PHASE,
                        phase="stage_solves") as sp_solve:
        for s in dag.stages:
            if dset is not None and s.name not in dset:
                weights[s.name] = np.asarray(warm_start[s.name],
                                             np.float64).copy()
                continue
            dec = optimize_weights(
                s.mus, s.sigmas, lam=lam, steps=steps, restarts=restarts,
                num_t=solve_t, impl=impl, family=s.family,
                warm_start=(None if warm_start is None
                            else warm_start.get(s.name)),
                eval_num_t=num_t)
            weights[s.name] = dec.weights
    with obs.timed_span(obs_names.SPAN_SOLVER_PHASE,
                        phase="final_score") as sp_eval:
        out = evaluate_dag(dag, weights, num_t=eval_num_t or max(num_t, 2048),
                           impl=impl)
    profile = {"phase_us": {"stage_solves": round(sp_solve.dur_us, 1),
                            "final_score": round(sp_eval.dur_us, 1)},
               "solve_num_t": solve_t}
    return DAGDecision(
        weights=weights, makespan_mu=out.makespan_mu,
        makespan_var=out.makespan_var, stage_mu=out.stage_mu,
        stage_var=out.stage_var, method="greedy-per-stage",
        family_groups=out.family_groups, profile=profile)
