"""Joint optimization of every stage split in a StageDAG.

The greedy baseline solves each stage alone (fastest expected stage time)
and composes whatever comes out. That is exactly what the paper shows to be
insufficient WITHIN a stage — variance matters at a join — lifted one level:
a stage feeding a join should trade a little expected time for variance,
because the join's ``E[max]`` pays for every branch's spread, and the only
way to see that is to optimize the end-to-end makespan through the
composition.

This solver does that with one batched kernel path:

1. **Stack**: every stage's iterate is one row of a ``(R*S, K_max)`` weight
   matrix (R = multi-starts, S = stages; stage fleets zero-padded to
   ``K_max`` — a ``w=0`` channel is a point mass that drops out of the
   survival product, so padding is exact, and a mask keeps padded weights at
   zero through the projection). Stages are grouped by completion-time
   family (``dist_id`` is a static kernel specialization); within a group
   every stage's statistics ride the per-row (stacked) layout of
   ``ops.frontier_moments_with_grads``, so ONE fused launch per family —
   not per stage — returns every stage's moments and analytic adjoints.
   An all-one-family DAG (the benchmark) is literally a single launch per
   PGD step.
2. **Compose**: the per-stage ``(mu_s, var_s)`` flow through
   ``dag.compose_moments`` (series sums + Clark joins) to the makespan;
   autodiff runs only over these O(S) Clark folds — the expensive
   d(moments)/dW part is the fused kernel adjoints (PR 2/4), chained by
   hand: ``dL/dW_s = dL/dmu_s * dmu_s/dW_s + dL/dvar_s * dvar_s/dW_s``.
3. **Descend**: projected gradient on the concatenation of all stage
   simplices (masked Held projection per stage block), cosine step decay,
   multi-start, warm-startable from a previous solve (the balancer's tick
   path).

Objective: ``makespan_mu + lam_var * makespan_var``; with ``risk_lam > 0``
and per-stage NIG posteriors, finalists additionally pay the delta-method
fragility of the predicted makespan under estimation error — the
``core.sensitivity`` machinery chained through the composition (the stage
parameter adjoints come from the same stacked full-parameter launch).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..analysis import sanitize as _san
from ..core.bayes import nig_estimate_ses
from ..core.distributions import resolve_family
from ..core.partitioner import optimize_weights
from ..kernels import autotune, ops
from .dag import StageDAG, compose_structure

__all__ = ["DAGDecision", "solve_dag", "solve_dag_greedy", "evaluate_dag"]


@dataclass(frozen=True)
class DAGDecision:
    """All stage splits plus the predicted end-to-end moments."""

    weights: Dict[str, np.ndarray]  # per-stage simplex weights (K_s,)
    makespan_mu: float
    makespan_var: float
    stage_mu: np.ndarray            # (S,) per-stage duration means
    stage_var: np.ndarray           # (S,)
    method: str
    family_groups: int = 1          # kernel launches per moment evaluation
    fragility: Optional[float] = None

    @property
    def relative_fragility(self) -> Optional[float]:
        if self.fragility is None:
            return None
        return float(self.fragility / max(self.makespan_mu, 1e-12))


# --------------------------------------------------------------------- stack
@dataclass(frozen=True)
class _Group:
    """Stages sharing one dist_id: one stacked launch serves them all."""

    dist_id: str
    idx: Tuple[int, ...]            # stage indices (canonical stage order)
    mus: np.ndarray                 # (n, Kmax) zero-padded
    sigmas: np.ndarray              # (n, Kmax)
    extra: np.ndarray               # (E, n, Kmax)


def _stage_groups(dag: StageDAG) -> Tuple[List[_Group], np.ndarray, int]:
    """Group stages by family; returns (groups, mask (S, Kmax), Kmax)."""
    kmax = max(s.k for s in dag.stages)
    S = len(dag.stages)
    mask = np.zeros((S, kmax), np.float32)
    by_dist: Dict[str, List[int]] = {}
    lowered = []
    for i, s in enumerate(dag.stages):
        dist_id, extra = resolve_family(s.family, s.k)
        lowered.append((dist_id, np.asarray(extra, np.float32)))
        by_dist.setdefault(dist_id, []).append(i)
        mask[i, :s.k] = 1.0
    groups = []
    for dist_id, idx in by_dist.items():
        n = len(idx)
        E = lowered[idx[0]][1].shape[0]
        mus = np.zeros((n, kmax), np.float32)
        sgs = np.zeros((n, kmax), np.float32)
        ex = np.zeros((E, n, kmax), np.float32)
        for j, i in enumerate(idx):
            s = dag.stages[i]
            mus[j, :s.k] = s.mus
            sgs[j, :s.k] = s.sigmas
            ex[:, j, :s.k] = lowered[i][1]
        groups.append(_Group(dist_id, tuple(idx), mus, sgs, ex))
    return groups, mask, kmax


def _project_simplex_masked(v, mask):
    """Held projection onto the simplex of the ACTIVE (mask=1) channels.

    Inactive entries (a stage's zero-padding up to K_max) are pinned far
    below every active value so they never enter the threshold computation
    and land exactly on zero after the clamp.
    """
    k = v.shape[-1]
    vm = jnp.where(mask > 0, v, -1e9)
    u = jnp.sort(vm)[::-1]
    css = jnp.cumsum(u) - 1.0
    idx = jnp.arange(1, k + 1, dtype=v.dtype)
    cond = u - css / idx > 0
    rho = jnp.max(jnp.where(cond, jnp.arange(k), -1))
    theta = css[rho] / (rho + 1.0)
    return jnp.maximum(vm - theta, 0.0)


def _stage_moments_grads(W, dist_ids, idxs, stats, num_t, impl, bfs):
    """Per-stage (mu, var, dmu_dW, dvar_dW) — one stacked launch per family.

    W: (R, S, Kmax). Rows of group g are the R x n_g stage iterates; the
    group's per-stage statistics tile over starts in the same (r, j) order.
    """
    R, S, kmax = W.shape
    smu = jnp.zeros((R, S))
    svar = jnp.zeros((R, S))
    dmu = jnp.zeros((R, S, kmax))
    dvar = jnp.zeros((R, S, kmax))
    for g, dist_id in enumerate(dist_ids):
        idx = jnp.asarray(idxs[g])
        mus_g, sgs_g, ex_g = stats[g]
        n = mus_g.shape[0]
        rows = W[:, idx, :].reshape(R * n, kmax)
        m, v, dm, dv = ops.frontier_moments_with_grads(
            rows, jnp.tile(mus_g, (R, 1)), jnp.tile(sgs_g, (R, 1)),
            num_t=num_t, impl=impl, block_f=bfs[g],
            family=(dist_id, jnp.tile(ex_g, (1, R, 1))))
        smu = smu.at[:, idx].set(m.reshape(R, n))
        svar = svar.at[:, idx].set(v.reshape(R, n))
        dmu = dmu.at[:, idx, :].set(dm.reshape(R, n, kmax))
        dvar = dvar.at[:, idx, :].set(dv.reshape(R, n, kmax))
    return smu, svar, dmu, dvar


@partial(jax.jit, static_argnames=("structure", "dist_ids", "idxs",
                                   "presolve_steps", "steps", "num_t",
                                   "impl", "bfs", "sanitize"))
def _pgd_dag(structure, dist_ids, idxs, stats, masks, W0, lam_var,
             presolve_steps: int, steps: int, num_t: int, impl: str, bfs,
             lr: float = 0.05, sanitize: bool = False):
    """Two-phase joint PGD; every phase is the same stacked launch per step.

    Phase 1 (presolve) descends each stage's LOCAL expected join time — the
    graph-blind objective, all stages at once — so every stage reaches its
    own frontier before the graph enters; phase 2 descends the composed
    makespan (fused kernel adjoints chained with the composition's
    cotangents), which redistributes the mean/variance trade toward the
    joins. Returns ``(W_presolve, W_final)``: both snapshots join the final
    candidate pool so the refine can explore without ever losing the
    presolve solution.

    Static ``sanitize=True`` plants checkify invariant checks per step; legal
    only under ``analysis.sanitize.run_checked`` (see that module).
    """
    proj = jax.vmap(jax.vmap(_project_simplex_masked))
    masks_b = jnp.broadcast_to(masks, W0.shape)

    def loss_one(smu_r, svar_r):
        mk_mu, mk_var = compose_structure(structure, smu_r, svar_r)
        return mk_mu + lam_var * mk_var

    grad_compose = jax.vmap(jax.grad(loss_one, argnums=(0, 1)))

    def body(composed, n_steps, i, W):
        smu, svar, dmu, dvar = _stage_moments_grads(
            W, dist_ids, idxs, stats, num_t, impl, bfs)
        if composed:
            g_mu, g_var = grad_compose(smu, svar)      # (R, S) each
            G = g_mu[..., None] * dmu + g_var[..., None] * dvar
        else:
            G = dmu                                    # stage-local mean
        if sanitize:
            _san.check_finite(smu, "DAG stage means")
            _san.check_finite(G, "DAG PGD gradient")
        G = G / (jnp.linalg.norm(G, axis=-1, keepdims=True) + 1e-12)
        step = lr * 0.5 * (1.0 + jnp.cos(jnp.pi * i / n_steps))
        W = proj(W - step * G, masks_b)
        if sanitize:
            _san.check_weight_rows(W, "DAG PGD iterate")
        return W

    W1 = jax.lax.fori_loop(0, presolve_steps,
                           partial(body, False, presolve_steps), W0)
    Wf = jax.lax.fori_loop(0, steps, partial(body, True, steps), W1)
    return W1, Wf


@partial(jax.jit, static_argnames=("structure", "dist_ids", "idxs", "num_t",
                                   "impl", "bfs"))
def _score_dag(structure, dist_ids, idxs, stats, W, num_t: int, impl: str,
               bfs):
    """Composed (makespan mu, var) and stage moments for finalists W."""
    R, S, kmax = W.shape
    smu = jnp.zeros((R, S))
    svar = jnp.zeros((R, S))
    for g, dist_id in enumerate(dist_ids):
        idx = jnp.asarray(idxs[g])
        mus_g, sgs_g, ex_g = stats[g]
        n = mus_g.shape[0]
        rows = W[:, idx, :].reshape(R * n, kmax)
        m, v = ops.frontier_moments(
            rows, jnp.tile(mus_g, (R, 1)), jnp.tile(sgs_g, (R, 1)),
            num_t=num_t, impl=impl, block_f=bfs[g],
            family=(dist_id, jnp.tile(ex_g, (1, R, 1))))
        smu = smu.at[:, idx].set(m.reshape(R, n))
        svar = svar.at[:, idx].set(v.reshape(R, n))
    mk = jax.vmap(lambda m, v: jnp.stack(
        compose_structure(structure, m, v)))(smu, svar)
    return mk[:, 0], mk[:, 1], smu, svar


def _se_stacks(dag: StageDAG, groups, posteriors, kmax: int):
    """Per-group (se_mu, se_sigma) stacks, zero-padded like the stats."""
    ses = {}
    for name, nig in posteriors.items():
        se_mu, se_sg = nig_estimate_ses(nig)
        ses[name] = (np.asarray(se_mu, np.float64),
                     np.asarray(se_sg, np.float64))
    out = []
    for g in groups:
        n = len(g.idx)
        se_m = np.zeros((n, kmax))
        se_s = np.zeros((n, kmax))
        for j, i in enumerate(g.idx):
            s = dag.stages[i]
            if s.name in ses:
                se_m[j, :s.k], se_s[j, :s.k] = ses[s.name]
        out.append((se_m, se_s))
    return out


def _dag_fragility(structure, groups, stats, se_stacks, W, smu, svar,
                   num_t, impl, bfs):
    """Delta-method sd of the predicted makespan mean under estimation error.

    ``estimation_fragility`` chained through the composition: the stacked
    full-parameter launch gives every stage's d(mu_s, var_s)/d(mus, sigmas);
    the composition's cotangents d(mk_mu)/d(mu_s, var_s) come from autodiff
    over the Clark folds; stage posteriors are independent, so the variance
    contributions add across stages AND channels.
    """
    R, S, kmax = W.shape
    gmk = jax.vmap(jax.grad(
        lambda m, v: compose_structure(structure, m, v)[0],
        argnums=(0, 1)))(smu, svar)
    g_mu, g_var = (np.asarray(g, np.float64) for g in gmk)   # (R, S)
    frag2 = np.zeros(R)
    for g, grp in enumerate(groups):
        idx = np.asarray(grp.idx)
        n = len(grp.idx)
        mus_g, sgs_g, ex_g = stats[g]
        rows = np.asarray(W[:, idx, :]).reshape(R * n, kmax)
        outs = ops.frontier_moments_with_grads(
            rows, np.tile(np.asarray(mus_g), (R, 1)),
            np.tile(np.asarray(sgs_g), (R, 1)),
            num_t=num_t, impl=impl, block_f=bfs[g],
            family=(grp.dist_id, jnp.tile(jnp.asarray(ex_g), (1, R, 1))),
            param_grads=True)
        dmu_m, dvar_m = (np.asarray(outs[4], np.float64).reshape(R, n, kmax),
                         np.asarray(outs[5], np.float64).reshape(R, n, kmax))
        dmu_s, dvar_s = (np.asarray(outs[6], np.float64).reshape(R, n, kmax),
                         np.asarray(outs[7], np.float64).reshape(R, n, kmax))
        se_m, se_s = se_stacks[g]
        cm = g_mu[:, idx, None] * dmu_m + g_var[:, idx, None] * dvar_m
        cs = g_mu[:, idx, None] * dmu_s + g_var[:, idx, None] * dvar_s
        frag2 += ((cm * se_m) ** 2).sum(axis=(1, 2)) \
            + ((cs * se_s) ** 2).sum(axis=(1, 2))
    return np.sqrt(frag2)


# --------------------------------------------------------------------- solve
def _dag_with_done(dag: StageDAG, done: Dict[str, np.ndarray]) -> StageDAG:
    """Rescale named stages' statistics to their remaining work.

    Per-stage :func:`core.distributions.remaining_work_stats`: a half-done
    stage re-solves a fresh unit simplex over ``r``-scaled statistics; a
    fully-done stage degenerates to all-zero stats (every channel a point
    mass at 0 — zero duration, gates nothing).
    """
    mus_by, sgs_by, fam_by = {}, {}, {}
    from ..core.distributions import family_from_extra, remaining_work_stats
    for s in dag.stages:
        if s.name not in done:
            continue
        dist_id, extra = resolve_family(s.family, s.k)
        mus_r, sgs_r, extra_r, _ = remaining_work_stats(
            dist_id, np.asarray(s.mus), np.asarray(s.sigmas),
            np.asarray(extra), np.asarray(done[s.name]))
        # Stage validation requires strictly positive means; a fully-done
        # stage floors to a negligible point mass instead of zero
        mus_by[s.name] = np.maximum(mus_r, 1e-9)
        sgs_by[s.name] = sgs_r
        # Stage validates family specs through get_family, which rejects
        # lowered tuples — raise the rescaled extras back to an instance
        fam_by[s.name] = family_from_extra(dist_id, extra_r)
    return dag.with_stats(mus_by, sgs_by, fam_by)


def _starts(dag: StageDAG, mask: np.ndarray, kmax: int, restarts: int,
            warm_start, key) -> np.ndarray:
    """(R, S, Kmax) start stack: equal, inverse-mu, warm, Dirichlet."""
    S = len(dag.stages)
    act = mask.astype(np.float64)
    eq = act / act.sum(axis=1, keepdims=True)
    inv = np.zeros_like(eq)
    for i, s in enumerate(dag.stages):
        # floor guards the fully-done (all-zero-stats) re-solve stages
        w = 1.0 / np.maximum(np.asarray(s.mus), 1e-12)
        inv[i, :s.k] = w / w.sum()
    starts = [eq, inv]
    if warm_start is not None:
        wm = np.zeros((S, kmax))
        for i, s in enumerate(dag.stages):
            w = np.maximum(np.asarray(warm_start[s.name], np.float64), 0.0)
            wm[i, :s.k] = w / max(w.sum(), 1e-12)
        starts.insert(0, wm)
    if restarts > 0:
        rng = np.random.default_rng(
            0 if key is None else int(np.asarray(
                jax.random.key_data(key)).ravel()[-1]))
        for _ in range(restarts):
            e = rng.exponential(size=(S, kmax)) * act
            starts.append(e / np.maximum(e.sum(axis=1, keepdims=True),
                                         1e-12))
    return np.stack(starts).astype(np.float32)


def solve_dag(dag: StageDAG, lam_var: float = 0.0, steps: int = 120,
              restarts: int = 2, num_t: int = 1024, impl: str = "xla",
              block_f: Optional[int] = None,
              key: Optional[jax.Array] = None,
              warm_start: Optional[Dict[str, np.ndarray]] = None,
              risk_lam: float = 0.0,
              posteriors: Optional[Dict[str, object]] = None,
              presolve_steps: Optional[int] = None,
              eval_num_t: Optional[int] = None,
              done: Optional[Dict[str, np.ndarray]] = None) -> DAGDecision:
    """Jointly optimize every stage's split for the end-to-end makespan.

    Objective: ``makespan_mu + lam_var * makespan_var`` composed through the
    DAG (series sums, Clark joins), descended by masked projected gradient
    over the concatenated stage simplices in two phases — a stage-local
    presolve (every stage to its own frontier) then the composed refine
    (the graph redistributes the mean/variance trade toward the joins).
    Every moment/gradient evaluation runs through ONE stacked
    ``ops.frontier_moments*`` launch per completion-time family present in
    the DAG — stages are rows, never a Python loop over kernel launches.

    The final pick scores the union of {starts, presolve snapshot, refined
    iterates} at evaluation resolution (``eval_num_t``, default
    max(num_t, 2048)), so the refine can only improve on the presolve and a
    warm start is never lost to an overshooting step.

    ``warm_start``: per-stage weights of a previous solve (the balancer's
    refresh ticks). ``risk_lam > 0`` with per-stage ``posteriors``
    ({stage name: NIGState}) scores finalists risk-adjusted by the
    composed estimation fragility; the fragility of the winning candidate
    is reported on the decision whenever posteriors are given (the
    balancer's adaptive refresh sizes its cadence by it).

    ``done`` ({stage name: per-channel completed work fractions}) is the
    sunk-work mid-flight re-solve: each named stage's statistics are rescaled
    to its remaining work through ``distributions.remaining_work_stats``
    before grouping, and its returned weights are shares of THAT REMAINING
    work (stages not named are solved for their full unit of work). A stage
    whose work is entirely done keeps zero weights and zero duration moments
    — it no longer gates its joins.
    """
    if done:
        dag = _dag_with_done(dag, done)
    groups, mask, kmax = _stage_groups(dag)
    dist_ids = tuple(g.dist_id for g in groups)
    idxs = tuple(g.idx for g in groups)
    stats = tuple((jnp.asarray(g.mus), jnp.asarray(g.sigmas),
                   jnp.asarray(g.extra)) for g in groups)
    W0 = jnp.asarray(_starts(dag, mask, kmax, restarts, warm_start, key))
    R = W0.shape[0]
    bfs = tuple(
        autotune.lookup(R * len(g.idx), kmax, num_t, backend=impl,
                        fused=True, dist_id=g.dist_id, stacked=True)
        if block_f is None else max(min(block_f, R * len(g.idx)), 1)
        for g in groups)

    pre = presolve_steps if presolve_steps is not None else steps
    if _san.enabled():
        # sanitizer tier: eager boundary validation of the stage statistics,
        # then the jitted joint solver under checkify (see analysis.sanitize)
        _san.assert_weight_rows(np.asarray(W0))
        for g in groups:
            _san.assert_finite("stage mus", g.mus)
            _san.assert_finite("stage sigmas", g.sigmas)
            _san.assert_nonneg("stage sigmas", g.sigmas)
        W1, Wf = _san.run_checked(
            partial(_pgd_dag, presolve_steps=pre, steps=steps, num_t=num_t,
                    impl=impl, bfs=bfs, sanitize=True),
            dag.structure, dist_ids, idxs, stats, jnp.asarray(mask), W0,
            jnp.float32(lam_var))
    else:
        W1, Wf = _pgd_dag(dag.structure, dist_ids, idxs, stats,
                          jnp.asarray(mask), W0, jnp.float32(lam_var),
                          pre, steps, num_t, impl, bfs)
    cands = jnp.concatenate([W0, W1, Wf], axis=0)
    et = eval_num_t or max(num_t, 2048)

    # every launch mode resolves its OWN block shape: the fused pgrad
    # working set is ~4x the grad one and the eval pass runs a larger grid —
    # reusing the PGD-tuned block would bypass the budget model on both
    def _bf(g, rows, nt, fused, params):
        if block_f is not None:
            return max(min(block_f, rows), 1)
        return autotune.lookup(rows, kmax, nt, backend=impl, fused=fused,
                               dist_id=g.dist_id, params=params,
                               stacked=True)

    ncand = int(cands.shape[0])
    bfs_eval = tuple(_bf(g, ncand * len(g.idx), et, False, False)
                     for g in groups)
    mk_mu, mk_var, smu, svar = _score_dag(dag.structure, dist_ids, idxs,
                                          stats, cands, et, impl, bfs_eval)
    score = np.asarray(mk_mu, np.float64) + lam_var * np.asarray(
        mk_var, np.float64)
    method = "pgd-dag-joint"
    frag = None
    if posteriors is not None:
        se_stacks = _se_stacks(dag, groups, posteriors, kmax)
        bfs_frag = tuple(_bf(g, ncand * len(g.idx), num_t, True, True)
                         for g in groups)
        frag = _dag_fragility(dag.structure, groups, stats, se_stacks,
                              cands, smu, svar, num_t, impl, bfs_frag)
        if risk_lam > 0.0:
            score = score + risk_lam * frag
            method = "pgd-dag-joint-risk"
    best = int(np.argmin(score))
    Wb = np.asarray(cands[best], np.float64)
    weights = {s.name: Wb[i, :s.k] for i, s in enumerate(dag.stages)}
    return DAGDecision(
        weights=weights,
        makespan_mu=float(mk_mu[best]), makespan_var=float(mk_var[best]),
        stage_mu=np.asarray(smu[best], np.float64),
        stage_var=np.asarray(svar[best], np.float64),
        method=method, family_groups=len(groups),
        fragility=(float(frag[best]) if frag is not None else None))


def evaluate_dag(dag: StageDAG, weights: Dict[str, np.ndarray],
                 num_t: int = 2048, impl: str = "xla") -> DAGDecision:
    """Composed moments of an arbitrary per-stage split (shared evaluator:
    joint and greedy decisions are compared on the SAME quadrature)."""
    groups, mask, kmax = _stage_groups(dag)
    dist_ids = tuple(g.dist_id for g in groups)
    idxs = tuple(g.idx for g in groups)
    stats = tuple((jnp.asarray(g.mus), jnp.asarray(g.sigmas),
                   jnp.asarray(g.extra)) for g in groups)
    S = len(dag.stages)
    W = np.zeros((1, S, kmax), np.float32)
    for i, s in enumerate(dag.stages):
        w = np.maximum(np.asarray(weights[s.name], np.float64), 0.0)
        W[0, i, :s.k] = w / max(w.sum(), 1e-12)
    bfs = tuple(autotune.lookup(len(g.idx), kmax, num_t, backend=impl,
                                fused=False, dist_id=g.dist_id, stacked=True)
                for g in groups)
    mk_mu, mk_var, smu, svar = _score_dag(dag.structure, dist_ids, idxs,
                                          stats, jnp.asarray(W), num_t,
                                          impl, bfs)
    return DAGDecision(
        weights={s.name: np.asarray(W[0, i, :s.k], np.float64)
                 for i, s in enumerate(dag.stages)},
        makespan_mu=float(mk_mu[0]), makespan_var=float(mk_var[0]),
        stage_mu=np.asarray(smu[0], np.float64),
        stage_var=np.asarray(svar[0], np.float64),
        method="evaluate", family_groups=len(groups))


def solve_dag_greedy(dag: StageDAG, lam: float = 0.0, steps: int = 120,
                     restarts: int = 2, num_t: int = 1024,
                     impl: str = "xla",
                     eval_num_t: Optional[int] = None) -> DAGDecision:
    """Stage-by-stage baseline: each stage solved alone (``mu + lam var`` on
    its OWN join time), blind to where it sits in the graph — a per-stage
    Python loop over independent solves, the thing the joint solver
    replaces. Composed moments evaluated with the shared evaluator."""
    weights = {}
    for s in dag.stages:
        dec = optimize_weights(s.mus, s.sigmas, lam=lam, steps=steps,
                               restarts=restarts, num_t=num_t, impl=impl,
                               family=s.family)
        weights[s.name] = dec.weights
    out = evaluate_dag(dag, weights, num_t=eval_num_t or max(num_t, 2048),
                       impl=impl)
    return DAGDecision(
        weights=out.weights, makespan_mu=out.makespan_mu,
        makespan_var=out.makespan_var, stage_mu=out.stage_mu,
        stage_var=out.stage_var, method="greedy-per-stage",
        family_groups=out.family_groups)
