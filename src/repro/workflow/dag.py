"""Stage-DAG workflow specs: fork-join graphs of partitionable workloads.

The paper partitions ONE workload across K uncertain channels and joins once.
Real workflows are DAGs of such stages: every node is a workload with its own
channel fleet (its own ``(mus, sigmas)`` statistics and completion-time
``ChannelFamily``), every edge a precedence constraint, and the workflow
completion time composes along the graph. This module holds the spec +
validation + moment composition; ``workflow.solve`` optimizes all stage
splits jointly through it.

Composition rules (and where they are exact vs approximate)
-----------------------------------------------------------

Let ``D_v`` be stage v's own join time under its split ``w_v`` — the paper's
``max_i T_i(w_i)`` within the stage, with moments ``(mu_v, var_v)`` from the
survival-integral machinery (``ops.frontier_moments``). Stage v starts when
every predecessor has finished and its completion time is

    C_v = R_v + D_v,      R_v = max_{u in preds(v)} C_u      (R_v = 0 at
                                                              sources)

and the workflow makespan is ``M = max_{v in sinks} C_v``. Two rules cover
the whole graph:

* **series** (single predecessor): ``C_v = C_u + D_v`` with ``D_v``
  independent of everything upstream, so the moments ADD —
  ``E[C_v] = E[C_u] + mu_v`` and ``Var[C_v] = Var[C_u] + var_v``. Exact.
* **join** (several predecessors): ``R_v = max_u C_u``. We moment-match every
  ``C_u`` to a Gaussian and fold pairwise with Clark's (1961) exact
  two-Gaussian max (``core.maxstat.clark_max_moments_2``), re-matching the
  running max after each fold — the same sequential-Clark scheme
  ``core.maxstat.clark_max_moments_seq`` uses within a stage.

Approximation error at joins comes from two places:

1. **Non-normality**: the max of Gaussians is not Gaussian (it is
   right-skewed), so the sequential fold's re-matching loses the third
   moment. The error is O(overlap) — small when branch means are separated
   by more than a couple of their sds, largest for near-identical branches —
   and is bounded against a Monte-Carlo oracle in the tests
   (``tests/test_workflow.py::TestComposeMC``).
2. **Shared ancestors**: two branches below a common fork both inherit the
   fork's completion time, so their ``C_u`` are positively correlated while
   the fold treats them as independent. For a max, positive correlation can
   only LOWER ``E[max]`` relative to independence (the comonotone limit is
   ``max`` of identical variables), so the independence assumption biases the
   composed mean conservatively upward by at most the shared-ancestor
   variance contribution.

Two sanity invariants always hold in the approximation, matching the exact
quantities: Jensen's bound ``E[max_u C_u] >= max_u E[C_u]`` (Clark's formula
satisfies it term by term), and monotonicity of the makespan in every stage
mean. Everything here is pure jnp and differentiable — the joint solver
backprops the makespan through this composition onto every stage's split
weights (the kernel adjoints) with autodiff only over these O(S) Clark
folds.

Validation follows the partition-service conventions of workflow engines
(cycle detection with an explicit cycle path in the error, bounded depth):
a spec error raises :class:`DAGValidationError` at construction, never at
solve time.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.distributions import get_family, resolve_family
from ..core.maxstat import clark_max_moments_2

__all__ = ["DAGValidationError", "Stage", "StageDAG", "compose_structure",
           "linear_edges"]

MAX_DEPTH_DEFAULT = 64


class DAGValidationError(ValueError):
    """A workflow spec failed validation (cycle, depth, unknown node, ...)."""


@dataclass(frozen=True)
class Stage:
    """One workload node: a fleet of K channels with per-unit statistics.

    ``mus``/``sigmas`` are per-unit-work completion statistics exactly as in
    the single-workload solvers; ``family`` the stage's completion-time
    ``ChannelFamily`` (name or instance). Stages in one DAG may have
    different K and different families.
    """

    name: str
    mus: np.ndarray
    sigmas: np.ndarray
    family: object = "normal"

    def __post_init__(self):
        object.__setattr__(self, "mus", np.asarray(self.mus, np.float64))
        object.__setattr__(self, "sigmas",
                          np.asarray(self.sigmas, np.float64))
        if self.mus.ndim != 1 or self.mus.shape != self.sigmas.shape:
            raise DAGValidationError(
                f"stage {self.name!r}: mus/sigmas must be matching 1-D "
                f"arrays, got {self.mus.shape} vs {self.sigmas.shape}")
        if self.mus.shape[0] < 1:
            raise DAGValidationError(f"stage {self.name!r} has no channels")
        if not np.all(self.mus > 0):
            raise DAGValidationError(
                f"stage {self.name!r}: channel means must be positive")
        get_family(self.family)  # fail fast on an unknown family spec

    @property
    def k(self) -> int:
        return self.mus.shape[0]

    @property
    def dist_id(self) -> str:
        return resolve_family(self.family, self.k)[0]


def linear_edges(names: Sequence[str]) -> List[Tuple[str, str]]:
    """Edges of a simple pipeline: each stage precedes the next."""
    return [(a, b) for a, b in zip(names[:-1], names[1:])]


class StageDAG:
    """Validated stage graph + differentiable moment composition.

    ``stages`` order is the canonical stage index used by every (S,)-shaped
    array in the solver. ``edges`` are (upstream, downstream) name pairs.
    Construction validates: unique names, known endpoints, no self-loops or
    duplicate edges, acyclicity (the error names a cycle path), and a depth
    bound (longest chain of stages <= ``max_depth`` — runaway specs fail
    fast, the same guard workflow partition services apply before
    compilation).
    """

    def __init__(self, stages: Sequence[Stage],
                 edges: Iterable[Tuple[str, str]] = (),
                 max_depth: int = MAX_DEPTH_DEFAULT):
        stages = tuple(stages)
        names = [s.name for s in stages]
        if len(set(names)) != len(names):
            dupes = sorted({n for n in names if names.count(n) > 1})
            raise DAGValidationError(f"duplicate stage names: {dupes}")
        self.stages: Tuple[Stage, ...] = stages
        self.names: Tuple[str, ...] = tuple(names)
        self.index: Dict[str, int] = {n: i for i, n in enumerate(names)}
        self.edges: Tuple[Tuple[str, str], ...] = self._check_edges(edges)
        self._preds: Dict[str, List[str]] = {n: [] for n in names}
        self._succs: Dict[str, List[str]] = {n: [] for n in names}
        for u, v in self.edges:
            self._preds[v].append(u)
            self._succs[u].append(v)
        self.topo_order: Tuple[str, ...] = self._toposort()
        self.depth: int = self._longest_chain()
        if self.depth > max_depth:
            raise DAGValidationError(
                f"workflow depth {self.depth} exceeds the bound {max_depth} "
                f"(raise max_depth explicitly if this is intentional)")

    # ------------------------------------------------------------ validation
    def _check_edges(self, edges) -> Tuple[Tuple[str, str], ...]:
        seen, out = set(), []
        for e in edges:
            u, v = e
            for n in (u, v):
                if n not in self.index:
                    raise DAGValidationError(
                        f"edge ({u!r}, {v!r}) references unknown stage {n!r}")
            if u == v:
                raise DAGValidationError(f"self-loop on stage {u!r}")
            if (u, v) in seen:
                raise DAGValidationError(f"duplicate edge ({u!r}, {v!r})")
            seen.add((u, v))
            out.append((u, v))
        return tuple(out)

    def _toposort(self) -> Tuple[str, ...]:
        """Kahn's algorithm, deterministic (stage-declaration order breaks
        ties). On a cycle, raises with an explicit cycle path found by DFS."""
        indeg = {n: len(self._preds[n]) for n in self.names}
        ready = [n for n in self.names if indeg[n] == 0]
        order: List[str] = []
        while ready:
            n = ready.pop(0)
            order.append(n)
            for m in self._succs[n]:
                indeg[m] -= 1
                if indeg[m] == 0:
                    ready.append(m)
        if len(order) != len(self.names):
            raise DAGValidationError(
                "cycle detected: " + " -> ".join(self._find_cycle()))
        return tuple(order)

    def _find_cycle(self) -> List[str]:
        """DFS cycle extraction for the error message (a cycle exists)."""
        WHITE, GRAY, BLACK = 0, 1, 2
        color = {n: WHITE for n in self.names}
        stack: List[str] = []

        def dfs(n):
            color[n] = GRAY
            stack.append(n)
            for m in self._succs[n]:
                if color[m] == GRAY:
                    return stack[stack.index(m):] + [m]
                if color[m] == WHITE:
                    cyc = dfs(m)
                    if cyc:
                        return cyc
            stack.pop()
            color[n] = BLACK
            return None

        for n in self.names:
            if color[n] == WHITE:
                cyc = dfs(n)
                if cyc:
                    return cyc
        return ["<unreachable>"]  # pragma: no cover - caller guarantees cycle

    def _longest_chain(self) -> int:
        depth = {n: 1 for n in self.names}
        for n in self.topo_order:
            for m in self._succs[n]:
                depth[m] = max(depth[m], depth[n] + 1)
        return max(depth.values()) if depth else 0

    @classmethod
    def from_names(cls, names: Sequence[str],
                   edges: Iterable[Tuple[str, str]] = (),
                   max_depth: int = MAX_DEPTH_DEFAULT) -> "StageDAG":
        """Structure-only DAG (unit placeholder statistics): validation,
        topological order and precedence for callers that bring their own
        per-stage execution (e.g. the serving tier, whose stages learn
        statistics online)."""
        stages = [Stage(n, np.ones(1), np.full(1, 0.1)) for n in names]
        return cls(stages, edges, max_depth=max_depth)

    # ------------------------------------------------------------ structure
    def predecessors(self, name: str) -> Tuple[str, ...]:
        return tuple(self._preds[name])

    def successors(self, name: str) -> Tuple[str, ...]:
        return tuple(self._succs[name])

    @property
    def sources(self) -> Tuple[str, ...]:
        return tuple(n for n in self.names if not self._preds[n])

    @property
    def sinks(self) -> Tuple[str, ...]:
        return tuple(n for n in self.names if not self._succs[n])

    @property
    def structure(self):
        """Hashable composition structure: ``(topo, preds, sinks)`` as stage
        indices. This is the jit static key for the joint solver — two DAGs
        with the same structure (rebuilt per balancer tick with fresh
        statistics) share one compiled solve."""
        topo = tuple(self.index[n] for n in self.topo_order)
        preds = tuple(tuple(self.index[u] for u in self._preds[n])
                      for n in self.names)
        sinks = tuple(self.index[n] for n in self.sinks)
        return topo, preds, sinks

    def with_stats(self, mus_by_stage: Dict[str, np.ndarray],
                   sigmas_by_stage: Dict[str, np.ndarray],
                   family_by_stage: Dict[str, object] = None) -> "StageDAG":
        """Same graph, fresh statistics (the balancer's per-tick rebuild)."""
        family_by_stage = family_by_stage or {}
        stages = [Stage(name=s.name,
                        mus=mus_by_stage.get(s.name, s.mus),
                        sigmas=sigmas_by_stage.get(s.name, s.sigmas),
                        family=family_by_stage.get(s.name, s.family))
                  for s in self.stages]
        return StageDAG(stages, self.edges, max_depth=self.depth)

    # ------------------------------------------------------------ composition
    def compose_moments(self, stage_mu, stage_var, return_nodes: bool = False):
        """(makespan mu, var) from per-stage duration moments (stage-index
        ordered (S,) arrays). Differentiable; see the module docstring for
        the series/join rules and their approximation error."""
        return compose_structure(self.structure, stage_mu, stage_var,
                                 return_nodes=return_nodes)

    def critical_path(self) -> List[str]:
        """Expected-value critical path (stage means only; diagnostics).

        The longest source->sink chain by summed stage means — the
        deterministic skeleton the joint solve's gradients concentrate on
        (join folds pass the makespan cotangent mostly to the dominant
        branch).
        """
        means = {s.name: float(np.mean(s.mus)) for s in self.stages}
        best = {n: (means[n], [n]) for n in self.names}
        for n in self.topo_order:
            for m in self._succs[n]:
                cand = best[n][0] + means[m]
                if cand > best[m][0]:
                    best[m] = (cand, best[n][1] + [m])
        sink = max(self.sinks, key=lambda n: best[n][0])
        return best[sink][1]


# joins at least this wide fold via lax.scan instead of a Python-unrolled
# chain: an unrolled W-way fold is ~30*W HLO ops on one dependency chain,
# and XLA's passes go superlinear on it (a 170-way join alone pushed the
# 512-stage solve's compile past 20 minutes); the scan body compiles ONCE.
# Same sequential fold order, so the numerics match the unrolled path.
_SCAN_FOLD_MIN = 16


def _fold_max(items):
    """Sequential Clark fold of [(mu, var), ...] (moment-matched max)."""
    m, v = items[0]
    if len(items) < _SCAN_FOLD_MIN:
        for m2, v2 in items[1:]:
            m, v = clark_max_moments_2(m, jnp.sqrt(jnp.maximum(v, 1e-18)),
                                       m2, jnp.sqrt(jnp.maximum(v2, 1e-18)))
        return m, v

    def body(carry, mv):
        cm, cv = carry
        m2, v2 = mv
        return clark_max_moments_2(
            cm, jnp.sqrt(jnp.maximum(cv, 1e-18)),
            m2, jnp.sqrt(jnp.maximum(v2, 1e-18))), None

    rest = (jnp.stack([jnp.asarray(x[0]) for x in items[1:]]),
            jnp.stack([jnp.asarray(x[1]) for x in items[1:]]))
    (m, v), _ = jax.lax.scan(body, (m + jnp.zeros(()), v + jnp.zeros(())),
                             rest)
    return m, v


def compose_structure(structure, stage_mu, stage_var,
                      return_nodes: bool = False):
    """Pure-function composition over a hashable ``StageDAG.structure``.

    ``stage_mu``/``stage_var``: (S,) per-stage duration moments (any leading
    batch handled by vmap at the call site). Returns ``(mu, var)`` of the
    makespan, plus the per-node completion moments when ``return_nodes``.
    Series edges add moments; joins fold by Clark; the sink max is one more
    fold. O(edges) Clark folds — tiny next to one kernel launch, so autodiff
    through this is the cheap part of the joint solve's backward pass.
    """
    topo, preds, sinks = structure
    stage_mu = jnp.asarray(stage_mu)
    stage_var = jnp.asarray(stage_var)
    n = stage_mu.shape[-1]
    comp_mu: List[object] = [None] * n
    comp_var: List[object] = [None] * n
    for i in topo:
        ps = preds[i]
        if not ps:
            rel_mu, rel_var = 0.0, 0.0
        elif len(ps) == 1:
            rel_mu, rel_var = comp_mu[ps[0]], comp_var[ps[0]]
        else:
            rel_mu, rel_var = _fold_max([(comp_mu[p], comp_var[p])
                                         for p in ps])
        comp_mu[i] = rel_mu + stage_mu[i]
        comp_var[i] = rel_var + stage_var[i]
    if len(sinks) == 1:
        mk_mu, mk_var = comp_mu[sinks[0]], comp_var[sinks[0]]
    else:
        mk_mu, mk_var = _fold_max([(comp_mu[s], comp_var[s]) for s in sinks])
    if return_nodes:
        return (mk_mu, mk_var), (jnp.stack(comp_mu), jnp.stack(comp_var))
    return mk_mu, mk_var
