"""repro.workflow — stage-DAG workflows over uncertain channel fleets.

The paper's single split-join generalized to fork-join graphs: every stage
is a workload with its own channel fleet and completion-time family, moments
compose along the graph (series sums, Clark joins), and ALL stage splits are
optimized jointly for the end-to-end makespan through one stacked kernel
path (``workflow.solve``). The scheduler-facing twin is
``sched.WorkflowBalancer`` (live re-solves with online per-stage
estimation); simulation ground truth is ``sim.WorkflowSim``.
"""
from .dag import (DAGValidationError, Stage, StageDAG, compose_structure,
                  linear_edges)
from .solve import DAGDecision, evaluate_dag, solve_dag, solve_dag_greedy

__all__ = [
    "DAGValidationError",
    "Stage",
    "StageDAG",
    "compose_structure",
    "linear_edges",
    "DAGDecision",
    "evaluate_dag",
    "solve_dag",
    "solve_dag_greedy",
]
