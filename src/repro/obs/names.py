"""Central registry of every span kind, audit-event type and metric name.

Every ``obs.span`` / ``obs.timed_span`` / ``obs.event`` emit site MUST name
its record with a constant from this module — never a free string literal.
The tracer validates names against this registry at emit time (when
tracing is on), and lint rule RPA090 enforces the same statically, so a
dashboard reading ``solver.phase`` can never silently diverge from an emit
site that renamed itself ``solve.phase``.

Naming convention: ``<layer>.<thing>`` for spans, ``audit.<decision>`` for
events, ``repro_<snake>`` for Prometheus metric names. Attribute keys ride
free-form on each record (they are schema-checked per event type in
:mod:`repro.obs.export`, not here).
"""
from __future__ import annotations

# --------------------------------------------------------------------- spans
# Solver ladder phases inside workflow.solve.solve_dag (attr ``phase`` is one
# of starts/presolve/triage/refine/final_score/fragility).
SPAN_SOLVER_PHASE = "solver.phase"
# One stacked PGD solve over the rows of a family group
# (serve.engine.row_pgd_step); attrs family, rows, K, num_t.
SPAN_SOLVER_PGD = "solver.pgd"
# One ``ops.frontier_moments*`` / stacked fused launch: attrs family/dist_id,
# mode (fwd|grad|pgrad), F, K, num_t, block_f, impl, autotune (hit|miss|model).
SPAN_KERNEL_LAUNCH = "kernel.launch"
# One WorkflowEngine.tick; attrs live, queue, rows, launches.
SPAN_ENGINE_TICK = "engine.tick"
# A stage of the tick: attr ``stage`` in admission|stack_rows|launch|commit.
SPAN_ENGINE_STAGE = "engine.stage"
# A balancer refresh that actually re-solved (attr kind, stages/dirty count).
SPAN_SCHED_REFRESH = "sched.refresh"
# One ClusterSim.run_step / WorkflowSim.tick; attr sim in cluster|workflow.
SPAN_SIM_STEP = "sim.step"
# One kill/restore cycle in sim.chaos; attrs step, kind.
SPAN_CHAOS_CYCLE = "chaos.cycle"

SPAN_KINDS = frozenset({
    SPAN_SOLVER_PHASE, SPAN_SOLVER_PGD, SPAN_KERNEL_LAUNCH,
    SPAN_ENGINE_TICK, SPAN_ENGINE_STAGE, SPAN_SCHED_REFRESH,
    SPAN_SIM_STEP, SPAN_CHAOS_CYCLE,
})

# -------------------------------------------------------------- audit events
# Why a row/stage became dirty: attrs scope (engine|workflow), key, cause
# (drift|churn|fragility|new|slo), drift (float, when cause == drift).
EV_DIRTY = "audit.dirty"
# Fragility-gate outcome on a balancer refresh: attrs passed (bool),
# rel_frag, target.
EV_FRAGILITY = "audit.fragility_gate"
# BIC family switch in UncertaintyAwareBalancer._auto_select: attrs old,
# new, scores (name -> BIC), streak.
EV_FAMILY_SWITCH = "audit.family_switch"
# SLO-driven risk_lam escalation for a row: attrs instance, lam, base,
# headroom.
EV_SLO_LAM = "audit.slo_lam"
# Failure/recovery/throttle churn reaching a decider or sim: attrs kind
# (fail|recover|throttle|set_load), channel, source (sim|balancer|engine).
EV_CHURN = "audit.churn"
# Pipeline checkpoint committed: attrs step, kind, path.
EV_CKPT_SAVE = "audit.ckpt_save"
# Pipeline checkpoint restored — the FIRST record of a restored replica's
# fresh trace (trace state is never checkpointed): attrs step, kind, path.
EV_CKPT_RESTORE = "audit.ckpt_restore"
# A frontier kernel entry point was traced (jit compile / retrace), as
# opposed to launched eagerly: attrs mode, F, K, num_t, impl.
EV_KERNEL_COMPILE = "audit.kernel_compile"

EVENT_TYPES = frozenset({
    EV_DIRTY, EV_FRAGILITY, EV_FAMILY_SWITCH, EV_SLO_LAM, EV_CHURN,
    EV_CKPT_SAVE, EV_CKPT_RESTORE, EV_KERNEL_COMPILE,
})

ALL_NAMES = SPAN_KINDS | EVENT_TYPES

# ------------------------------------------------------------------- metrics
# Prometheus-style snapshot names (repro.obs.export.prometheus_snapshot).
METRIC_SPAN_COUNT = "repro_span_count"
METRIC_SPAN_US = "repro_span_duration_us"
METRIC_EVENT_COUNT = "repro_audit_event_count"
METRIC_DROPPED = "repro_trace_dropped_records"

METRIC_NAMES = frozenset({
    METRIC_SPAN_COUNT, METRIC_SPAN_US, METRIC_EVENT_COUNT, METRIC_DROPPED,
})
