"""Typed audit-event emitters: the *why* log.

Spans say how long things took; these say why they happened — which stage
went dirty and what drift pushed it over, whether the fragility gate let a
refresh through, which family BIC selection switched to and at what
scores, which row's SLO headroom escalated its risk lam, what churn hit
the fleet, and every checkpoint save/restore. Each helper owns the
attribute schema for its event type (validated in
:mod:`repro.obs.export`), guards the tracing-off fast path, and coerces
values to JSON-serializable scalars so numpy types never leak into the
event log.

All emitters are host-side only and draw from no RNG — see the
zero-perturbation contract in :mod:`repro.obs.trace`.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

from . import names, trace

__all__ = [
    "dirty", "fragility_gate", "family_switch", "slo_lam", "churn",
    "ckpt_save", "ckpt_restore", "kernel_compile",
]


def _f(x) -> Optional[float]:
    return None if x is None else float(x)


def dirty(scope: str, key, cause: str, drift=None) -> None:
    """A row/stage joined the dirty set: who, and which trigger fired."""
    if not trace.enabled():
        return
    trace.event(names.EV_DIRTY, scope=scope, key=str(key), cause=cause,
                drift=_f(drift))


def fragility_gate(passed: bool, rel_frag, target) -> None:
    """Balancer fragility gate verdict on a refresh tick."""
    if not trace.enabled():
        return
    trace.event(names.EV_FRAGILITY, passed=bool(passed),
                rel_frag=_f(rel_frag), target=_f(target))


def family_switch(old: str, new: str, scores: Dict[str, Any],
                  streak: int = 0) -> None:
    """BIC model selection changed the completion-time family."""
    if not trace.enabled():
        return
    trace.event(names.EV_FAMILY_SWITCH, old=str(old), new=str(new),
                scores={str(k): _f(v) for k, v in scores.items()},
                streak=int(streak))


def slo_lam(instance, lam, base, headroom=None) -> None:
    """A row's risk lam was escalated above base by SLO deadline pressure."""
    if not trace.enabled():
        return
    trace.event(names.EV_SLO_LAM, instance=str(instance), lam=_f(lam),
                base=_f(base), headroom=_f(headroom))


def churn(kind: str, channel, source: str, detail=None) -> None:
    """Failure/recovery/throttle/load churn observed at ``source``."""
    if not trace.enabled():
        return
    trace.event(names.EV_CHURN, kind=str(kind), channel=int(channel),
                source=source,
                detail=None if detail is None else str(detail))


def ckpt_save(step, kind: str, path: str) -> None:
    if not trace.enabled():
        return
    trace.event(names.EV_CKPT_SAVE, step=int(step), kind=str(kind),
                path=str(path))


def ckpt_restore(step, kind: str, path: str) -> None:
    """First record of a restored replica's fresh (never-restored) trace."""
    if not trace.enabled():
        return
    trace.event(names.EV_CKPT_RESTORE, step=int(step), kind=str(kind),
                path=str(path))


def kernel_compile(mode: str, F: int, K: int, num_t: int, impl: str) -> None:
    """A frontier entry point was hit with tracer args (jit compile)."""
    if not trace.enabled():
        return
    trace.event(names.EV_KERNEL_COMPILE, mode=str(mode), F=int(F),
                K=int(K), num_t=int(num_t), impl=str(impl))
