"""Low-overhead tracer: spans + audit events into a thread-safe ring buffer.

Off by default; ``REPRO_TRACE=1`` (read once at import, overridable with
:func:`set_enabled`) switches recording on. The design contract is
**zero perturbation** of the system under observation:

* timestamps come from ``time.perf_counter_ns`` — monotonic, never the
  wall clock, and never an RNG draw;
* nothing here touches a simulation/engine RNG stream, and trace state is
  deliberately absent from every ``state_dict`` — the kill/restore bitwise
  tick-parity contract (docs/INVARIANTS.md) holds with tracing enabled,
  and a restored replica starts a fresh trace whose first record is the
  restore audit event;
* emit sites on jit boundaries only record on concrete (host-side) values,
  so tracing can never change a jit cache key or plant a side effect in a
  traced computation.

The off path is a single attribute load + truth test: :func:`span` returns
a shared no-op context manager and :func:`event` returns immediately.
:func:`timed_span` is the one deliberate exception — it ALWAYS measures
(its ``dur_us`` replaces a pre-existing hand timer, so the cost is the
timer the caller already paid) but records only when tracing is on; that
is what makes spans the single timing source of truth for profiles like
``solve_dag``'s ``phase_us`` without forcing tracing on for benchmarks.

Records are plain dicts (schema in docs/OBSERVABILITY.md, validated by
:func:`repro.obs.export.validate_records`); the ring buffer drops the
oldest records past ``capacity`` and counts the drops.
"""
from __future__ import annotations

import functools
import os
import threading
import time
from collections import deque
from contextlib import contextmanager
from typing import Any, Dict, Iterator, List, Optional

from . import names

__all__ = [
    "ENV_VAR", "Tracer", "TRACER", "enabled", "set_enabled", "span",
    "timed_span", "event", "traced", "set_tick", "current_tick", "mark",
    "records", "dropped", "clear", "capture",
]

ENV_VAR = "REPRO_TRACE"
_DEFAULT_CAPACITY = 1 << 16


def _now_us() -> float:
    return time.perf_counter_ns() / 1000.0


class _NoopSpan:
    """Shared do-nothing context manager for the tracing-off fast path."""

    __slots__ = ()
    dur_us = 0.0

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False


_NOOP = _NoopSpan()


class _Span:
    """Context manager measuring one span; records on exit when asked."""

    __slots__ = ("_tracer", "name", "attrs", "_record", "_t0_ns", "dur_us")

    def __init__(self, tracer: "Tracer", name: str, attrs: Dict[str, Any],
                 record: bool):
        self._tracer = tracer
        self.name = name
        self.attrs = attrs
        self._record = record
        self._t0_ns = 0
        self.dur_us = 0.0

    def __enter__(self) -> "_Span":
        self._t0_ns = time.perf_counter_ns()
        return self

    def __exit__(self, *exc) -> bool:
        t1 = time.perf_counter_ns()
        self.dur_us = (t1 - self._t0_ns) / 1000.0
        if self._record:
            self._tracer._emit({
                "type": "span",
                "name": self.name,
                "ts_us": self._t0_ns / 1000.0,
                "dur_us": self.dur_us,
                "tick": self._tracer._tick,
                "tid": threading.get_ident(),
                "attrs": self.attrs,
            })
        return False


class Tracer:
    """Ring buffer of span/event records with a zero-cost disabled path."""

    def __init__(self, capacity: int = _DEFAULT_CAPACITY):
        self._buf: deque = deque(maxlen=capacity)
        self._lock = threading.Lock()
        self._seq = 0
        self._appended = 0
        self._tick: Optional[int] = None
        self._enabled = os.environ.get(ENV_VAR, "") == "1"

    # ------------------------------------------------------------- switches
    def enabled(self) -> bool:
        return self._enabled

    def set_enabled(self, flag: bool) -> None:
        self._enabled = bool(flag)

    def set_tick(self, tick: Optional[int]) -> None:
        """Correlation id stamped on every subsequent record."""
        self._tick = None if tick is None else int(tick)

    def current_tick(self) -> Optional[int]:
        return self._tick

    # --------------------------------------------------------------- emit
    def _emit(self, rec: Dict[str, Any]) -> None:
        if rec["name"] not in names.ALL_NAMES:
            raise ValueError(
                f"unregistered trace name {rec['name']!r} — add it to "
                f"repro.obs.names (see RPA090)")
        with self._lock:
            self._seq += 1
            self._appended += 1
            rec["seq"] = self._seq
            self._buf.append(rec)

    def event(self, name: str, **attrs: Any) -> None:
        if not self._enabled:
            return
        self._emit({
            "type": "event",
            "name": name,
            "ts_us": _now_us(),
            "tick": self._tick,
            "tid": threading.get_ident(),
            "attrs": attrs,
        })

    def span(self, name: str, **attrs: Any):
        if not self._enabled:
            return _NOOP
        return _Span(self, name, attrs, record=True)

    def timed_span(self, name: str, **attrs: Any) -> _Span:
        """A span that always measures; recorded only when tracing is on."""
        return _Span(self, name, attrs, record=self._enabled)

    # ------------------------------------------------------------- readout
    def mark(self) -> int:
        with self._lock:
            return self._seq

    def records(self, since: int = 0) -> List[Dict[str, Any]]:
        with self._lock:
            return [r for r in self._buf if r["seq"] > since]

    def dropped(self) -> int:
        with self._lock:
            return self._appended - len(self._buf)

    def clear(self) -> None:
        with self._lock:
            self._buf.clear()
            self._appended = 0


TRACER = Tracer()


# ------------------------------------------------------------ module facade
def enabled() -> bool:
    return TRACER._enabled


def set_enabled(flag: bool) -> None:
    TRACER.set_enabled(flag)


def span(name: str, **attrs: Any):
    if not TRACER._enabled:
        return _NOOP
    return _Span(TRACER, name, attrs, record=True)


def timed_span(name: str, **attrs: Any) -> _Span:
    return TRACER.timed_span(name, **attrs)


def event(name: str, **attrs: Any) -> None:
    TRACER.event(name, **attrs)


def set_tick(tick: Optional[int]) -> None:
    TRACER.set_tick(tick)


def current_tick() -> Optional[int]:
    return TRACER.current_tick()


def mark() -> int:
    return TRACER.mark()


def records(since: int = 0) -> List[Dict[str, Any]]:
    return TRACER.records(since)


def dropped() -> int:
    return TRACER.dropped()


def clear() -> None:
    TRACER.clear()


def traced(name: str, **attrs: Any):
    """Decorator form: spans every call of the wrapped function."""
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            if not TRACER._enabled:
                return fn(*args, **kwargs)
            with TRACER.span(name, **attrs):
                return fn(*args, **kwargs)
        return wrapper
    return deco


@contextmanager
def capture() -> Iterator[List[Dict[str, Any]]]:
    """Force-record within the block; yields a list filled on exit.

    Enables tracing for the dynamic extent regardless of ``REPRO_TRACE``
    and hands back exactly the records emitted inside the block — the tool
    benchmarks use to aggregate phase spans without turning tracing on for
    the whole process.
    """
    prev = TRACER._enabled
    tok = TRACER.mark()
    TRACER.set_enabled(True)
    out: List[Dict[str, Any]] = []
    try:
        yield out
    finally:
        TRACER.set_enabled(prev)
        out.extend(TRACER.records(since=tok))
