"""Exporters for trace records: JSONL, Chrome/Perfetto, Prometheus text.

Record schema (one dict per span/event, produced by
:mod:`repro.obs.trace`):

    {"type": "span",  "name": <names.SPAN_*>, "ts_us": float,
     "dur_us": float, "tick": int|None, "tid": int, "seq": int,
     "attrs": {...}}
    {"type": "event", "name": <names.EV_*>,   "ts_us": float,
     "tick": int|None, "tid": int, "seq": int, "attrs": {...}}

``validate_records`` is the schema gate CI's trace tier runs over the
exported JSONL; ``to_perfetto`` emits the Chrome ``trace_event`` JSON that
chrome://tracing and https://ui.perfetto.dev load directly (complete
``"X"`` events for spans, instant ``"i"`` events for the audit log);
``prometheus_snapshot`` folds the same records into counter/summary text
built on :class:`repro.serve.telemetry.StreamingStat`.
"""
from __future__ import annotations

import json
from collections import defaultdict
from typing import Any, Dict, Iterable, List, Optional

from . import names, trace

__all__ = [
    "write_jsonl", "read_jsonl", "validate_records", "to_perfetto",
    "write_perfetto", "prometheus_snapshot", "phase_totals",
    "span_kinds", "event_types",
]

_COMMON_KEYS = {"type", "name", "ts_us", "tick", "tid", "seq", "attrs"}


# --------------------------------------------------------------------- JSONL
def write_jsonl(records: Iterable[Dict[str, Any]], path: str) -> int:
    """One record per line; returns the number written."""
    n = 0
    with open(path, "w") as f:
        for rec in records:
            f.write(json.dumps(rec, sort_keys=True) + "\n")
            n += 1
    return n


def read_jsonl(path: str) -> List[Dict[str, Any]]:
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


# ---------------------------------------------------------------- validation
def validate_records(records: Iterable[Dict[str, Any]]) -> int:
    """Raise ``ValueError`` on the first malformed record; return count.

    Checks every record against the schema above: known type, a name from
    the central registry (RPA090's runtime half), monotonic-clock fields
    present and numeric, spans carrying a nonnegative duration, and a
    JSON-serializable attrs dict.
    """
    n = 0
    for rec in records:
        n += 1
        where = f"record {n} ({rec.get('name')!r})"
        if rec.get("type") not in ("span", "event"):
            raise ValueError(f"{where}: bad type {rec.get('type')!r}")
        if rec.get("name") not in names.ALL_NAMES:
            raise ValueError(f"{where}: name not in repro.obs.names registry")
        if rec["type"] == "span" and rec["name"] not in names.SPAN_KINDS:
            raise ValueError(f"{where}: span with an event name")
        if rec["type"] == "event" and rec["name"] not in names.EVENT_TYPES:
            raise ValueError(f"{where}: event with a span name")
        for key in ("ts_us", "tid", "seq"):
            if not isinstance(rec.get(key), (int, float)):
                raise ValueError(f"{where}: missing/bad {key}")
        if rec.get("tick") is not None and not isinstance(rec["tick"], int):
            raise ValueError(f"{where}: bad tick {rec['tick']!r}")
        if rec["type"] == "span":
            dur = rec.get("dur_us")
            if not isinstance(dur, (int, float)) or dur < 0:
                raise ValueError(f"{where}: bad span dur_us {dur!r}")
        if not isinstance(rec.get("attrs"), dict):
            raise ValueError(f"{where}: attrs must be a dict")
        json.dumps(rec["attrs"])  # must serialize
    return n


def span_kinds(records: Iterable[Dict[str, Any]]) -> set:
    return {r["name"] for r in records if r["type"] == "span"}


def event_types(records: Iterable[Dict[str, Any]]) -> set:
    return {r["name"] for r in records if r["type"] == "event"}


# ------------------------------------------------------------------ Perfetto
def to_perfetto(records: Iterable[Dict[str, Any]],
                process_name: str = "repro") -> Dict[str, Any]:
    """Chrome ``trace_event`` document (loadable by ui.perfetto.dev)."""
    tids = {}
    events: List[Dict[str, Any]] = [{
        "name": "process_name", "ph": "M", "pid": 0,
        "args": {"name": process_name},
    }]
    for rec in records:
        tid = tids.setdefault(rec["tid"], len(tids))
        args = dict(rec["attrs"])
        if rec.get("tick") is not None:
            args["tick"] = rec["tick"]
        if rec["type"] == "span":
            events.append({
                "name": rec["name"], "cat": rec["name"].split(".")[0],
                "ph": "X", "ts": rec["ts_us"], "dur": rec["dur_us"],
                "pid": 0, "tid": tid, "args": args,
            })
        else:
            events.append({
                "name": rec["name"], "cat": "audit", "ph": "i",
                "ts": rec["ts_us"], "pid": 0, "tid": tid, "s": "p",
                "args": args,
            })
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_perfetto(records: Iterable[Dict[str, Any]], path: str,
                   process_name: str = "repro") -> int:
    doc = to_perfetto(records, process_name=process_name)
    with open(path, "w") as f:
        json.dump(doc, f)
    return len(doc["traceEvents"])


# ---------------------------------------------------------------- Prometheus
def prometheus_snapshot(records: Iterable[Dict[str, Any]],
                        dropped: Optional[int] = None) -> str:
    """Counters + duration summaries in Prometheus text exposition format.

    Built on the serving tier's :class:`StreamingStat` so span-duration
    quantiles come from the same reservoir estimator the engine telemetry
    already trusts. These stats are constructed fresh per snapshot with
    their own seeded RNG — nothing here touches a checkpointed stream.
    """
    from ..serve.telemetry import StreamingStat  # deferred: avoid cycle

    span_stats: Dict[str, Any] = {}
    event_counts: Dict[str, int] = defaultdict(int)
    for rec in records:
        if rec["type"] == "span":
            st = span_stats.get(rec["name"])
            if st is None:
                st = span_stats[rec["name"]] = StreamingStat()
            st.add(rec["dur_us"])
        else:
            event_counts[rec["name"]] += 1

    lines = [
        f"# HELP {names.METRIC_SPAN_COUNT} spans recorded per kind",
        f"# TYPE {names.METRIC_SPAN_COUNT} counter",
    ]
    for name in sorted(span_stats):
        st = span_stats[name].summary()
        lines.append(f'{names.METRIC_SPAN_COUNT}{{kind="{name}"}} '
                     f'{st["count"]}')
    lines += [
        f"# HELP {names.METRIC_SPAN_US} span duration microseconds",
        f"# TYPE {names.METRIC_SPAN_US} summary",
    ]
    for name in sorted(span_stats):
        st = span_stats[name].summary()
        for q in ("p50", "p90", "p99"):
            lines.append(
                f'{names.METRIC_SPAN_US}{{kind="{name}",quantile='
                f'"0.{q[1:]}"}} {st[q]:.3f}')
        lines.append(f'{names.METRIC_SPAN_US}_sum{{kind="{name}"}} '
                     f'{st["mean"] * st["count"]:.3f}')
        lines.append(f'{names.METRIC_SPAN_US}_count{{kind="{name}"}} '
                     f'{st["count"]}')
    lines += [
        f"# HELP {names.METRIC_EVENT_COUNT} audit events per type",
        f"# TYPE {names.METRIC_EVENT_COUNT} counter",
    ]
    for name in sorted(event_counts):
        lines.append(f'{names.METRIC_EVENT_COUNT}{{type="{name}"}} '
                     f'{event_counts[name]}')
    if dropped is None:
        dropped = trace.dropped()
    lines += [
        f"# HELP {names.METRIC_DROPPED} records dropped by the ring buffer",
        f"# TYPE {names.METRIC_DROPPED} counter",
        f"{names.METRIC_DROPPED} {dropped}",
    ]
    return "\n".join(lines) + "\n"


# ------------------------------------------------------------- aggregations
def phase_totals(records: Iterable[Dict[str, Any]],
                 name: str = names.SPAN_SOLVER_PHASE,
                 attr: str = "phase") -> Dict[str, int]:
    """Sum span durations (in integer microseconds) keyed by one attribute.

    The span-derived replacement for hand-rolled ``phase_us`` profiles:
    ``phase_totals(cap)`` over a captured ``solve_dag`` gives exactly the
    ladder attribution the dag_scale benchmark reports.
    """
    out: Dict[str, int] = defaultdict(int)
    for rec in records:
        if rec["type"] == "span" and rec["name"] == name:
            key = rec["attrs"].get(attr)
            if key is not None:
                out[str(key)] += int(round(rec["dur_us"]))
    return dict(out)
