"""Cross-layer tracing + decision-audit subsystem (PR 10).

Spans (how long), audit events (why), and exporters (JSONL / Perfetto /
Prometheus) for the whole stack — solver ladder phases, stacked kernel
launches, engine tick stages, balancer refreshes, sim steps — under a
hard zero-perturbation contract: no RNG draws, no jit-cache-key effects,
no trace state in any checkpoint. ``REPRO_TRACE=1`` turns recording on;
off is a no-op fast path. See docs/OBSERVABILITY.md.

``repro.obs.export`` is imported on demand (not here) so the serving tier
can import ``repro.obs`` without a cycle through ``repro.serve``.
"""
from . import events, names  # noqa: F401
from .trace import (TRACER, Tracer, capture, clear, current_tick,  # noqa: F401
                    dropped, enabled, event, mark, records, set_enabled,
                    set_tick, span, timed_span, traced)

__all__ = [
    "names", "events", "Tracer", "TRACER", "enabled", "set_enabled",
    "span", "timed_span", "event", "traced", "set_tick", "current_tick",
    "mark", "records", "dropped", "clear", "capture",
]
