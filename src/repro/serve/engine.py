"""Serving engine: prefill + decode with KV caches, the partitioned batcher
(the paper's file-transfer scenario mapped to request routing), and the
continuous-batching :class:`WorkflowEngine`.

The engine is the serving-tier answer to the question the paper answers for
one workflow: a production system prices partition splits for MANY
concurrent workflows at once, the way an inference server batches decode
steps across requests. Every live workflow *instance* — its remaining
stages, its posterior-specific ``(mus, sigmas, extra)``, its sunk work —
becomes rows of ONE shared stacked ``ops.frontier_moments_with_grads``
launch per completion-time family per tick (``workflow.solve.stack_rows``
does the row-block bookkeeping), so solver cost is amortized across the
whole live set instead of paid per workflow. The per-instance Python loop
this replaces is now a lint error under ``serve/`` (RPA080).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from time import perf_counter
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..kernels import autotune, ops
from ..obs import events as obs_events
from ..obs import names as obs_names
from ..obs import trace as obs
from ..sched.balancer import (InstanceHeads, UncertaintyAwareBalancer,
                              integerize)
from ..sim.cluster import ClusterSim, WorkflowSim
from ..workflow.solve import _project_simplex_masked, stack_rows
from .telemetry import ServeTelemetry

__all__ = ["ServeEngine", "PartitionedBatcher", "WorkflowEngine",
           "row_pgd_step"]


class ServeEngine:
    """Single-replica engine: batched prefill then greedy decode."""

    def __init__(self, model, cfg: ModelConfig):
        self.model = model
        self.cfg = cfg
        self._prefill = jax.jit(lambda p, t, cl: model.prefill(p, t, cache_len=cl),
                                static_argnums=2)
        self._step = jax.jit(model.decode_step)

    def generate(self, params, prompts: jnp.ndarray, max_new: int) -> jnp.ndarray:
        """prompts: (B, S) int32. Greedy continuation of max_new tokens."""
        B, S = prompts.shape
        logits, cache = self._prefill(params, prompts, S + max_new)
        tok = jnp.argmax(logits[:, -1:, :self.cfg.vocab_size], axis=-1)
        outs = [tok]
        for _ in range(max_new - 1):
            logits, cache = self._step(params, cache, tok)
            tok = jnp.argmax(logits[:, :, :self.cfg.vocab_size], axis=-1)
            outs.append(tok)
        return jnp.concatenate(outs, axis=1)


@dataclass
class ReplicaGroup:
    """A serving channel: model replica set with its own speed distribution."""
    name: str
    engine: Optional[ServeEngine] = None
    params: Optional[dict] = None


class PartitionedBatcher:
    """Split request batches across replica groups by the paper's frontier.

    The batch of R requests is the workflow D; replica groups are channels;
    the response is complete when the *slowest* group returns (the join).
    The balancer learns per-group (mu, sigma) per-request service rates online
    and re-partitions every batch.
    """

    def __init__(self, groups: List[ReplicaGroup], lam: float = 0.05,
                 policy: str = "frontier", sim: Optional[ClusterSim] = None,
                 seed: int = 0, impl: str = "xla", num_t: int = 1024,
                 refresh_every: int = 1, family="normal",
                 risk_lam: float = 0.0, adaptive_refresh: bool = False,
                 block_f=None):
        self.groups = groups
        # forward the solver knobs so serving ticks run the kernel-backed
        # (and, with impl="pallas", compiled) fused solve path online;
        # ``family`` swaps the completion-time model the frontier solves
        # under (e.g. "lognormal" for heavy-tailed WAN-style service times,
        # or "auto" to let the balancer BIC-select the model from the
        # observed rate history and switch it with hysteresis)
        self.balancer = UncertaintyAwareBalancer(len(groups), lam=lam,
                                                 policy=policy, impl=impl,
                                                 num_t=num_t,
                                                 refresh_every=refresh_every,
                                                 family=family,
                                                 risk_lam=risk_lam,
                                                 adaptive_refresh=adaptive_refresh,
                                                 block_f=block_f)
        self.sim = sim or ClusterSim.heterogeneous(len(groups), seed=seed)
        self.last_tick: Optional[dict] = None

    def split(self, num_requests: int) -> np.ndarray:
        return integerize(self.balancer.weights(), num_requests)

    @property
    def selected_family(self) -> str:
        """dist_id of the family the balancer is currently solving under
        (moves over time when ``family="auto"``)."""
        return self.balancer.selected_family.dist_id

    def run_batch(self, prompts: np.ndarray, max_new: int = 8,
                  execute: bool = False) -> Tuple[float, np.ndarray, list]:
        """Route one batch. Returns (join_latency, counts, responses).

        execute=True runs the actual models (tiny configs in examples);
        latency always comes from the simulator channels (this container has
        one CPU — the timing physics live in sim, as the paper's did in
        background-process contention). Per-tick telemetry — including the
        family the solve ran under, which is the interesting signal in
        ``family="auto"`` mode — lands in ``self.last_tick``.
        """
        R = prompts.shape[0]
        counts = self.split(R)
        fam = self.selected_family
        responses = [None] * len(self.groups)
        if execute:
            off = 0
            for gi, c in enumerate(counts):
                if c == 0:
                    continue
                g = self.groups[gi]
                chunk = jnp.asarray(prompts[off:off + c])
                responses[gi] = np.asarray(
                    g.engine.generate(g.params, chunk, max_new))
                off += c
        join_t, durs = self.sim.run_step(counts.astype(np.float64) / max(R, 1))
        self.balancer.observe(durs, counts.astype(np.float64) / max(R, 1))
        self.last_tick = {
            "family": fam,
            "join_latency": float(join_t),
            "counts": counts,
            "effective_refresh": self.balancer.effective_refresh,
        }
        return join_t, counts, responses

    # ------------------------------------------------------------ persistence
    def state_dict(self) -> dict:
        """Balancer AND sim-world snapshot: a batcher restored from this
        replays bitwise-identical ticks (same splits, same simulated
        durations, same posterior updates) — see ckpt/store.py's
        kill/restore tick-parity contract. Replica groups (model handles)
        are code-side configuration, like the workflow balancer's DAG."""
        return {"balancer": self.balancer.state_dict(),
                "sim": self.sim.state_dict()}

    def load_state_dict(self, d: dict):
        self.balancer = UncertaintyAwareBalancer.from_state_dict(
            d["balancer"])
        self.sim = ClusterSim.from_state_dict(d["sim"])
        return self

    @classmethod
    def from_state_dict(cls, d: dict,
                        groups: List[ReplicaGroup]) -> "PartitionedBatcher":
        return cls(groups).load_state_dict(d)


# --------------------------------------------------------------------------
# continuous-batching workflow engine
# --------------------------------------------------------------------------

@jax.jit
def _row_step(W, dmu, dvar, lam, mask, lr):
    """One normalized-gradient PGD step on every row's masked simplex.

    Per-row objective is stage-local ``mu + lam_row * var`` (``lam_row``
    carries each instance's SLO urgency); the gradient is L2-normalized per
    row so one shared step size serves instances whose stages live at very
    different time scales — the same normalization the DAG solver uses.
    """
    G = dmu + lam[:, None] * dvar
    G = G / (jnp.linalg.norm(G, axis=-1, keepdims=True) + 1e-12)
    return jax.vmap(_project_simplex_masked)(W - lr * G, mask)


def row_pgd_step(W, mus, sigmas, dist_id, extra, lam, mask, *, num_t,
                 impl: str = "xla", lr: float = 0.02,
                 block_f: Optional[int] = None):
    """One fused moments+gradients launch + PGD step over a stacked row set.

    This is the batched tick's unit of work as a pure function: ``W`` /
    ``mus`` / ``sigmas`` are ``(F, K)`` stacked rows of ONE family
    (``dist_id`` static, ``extra`` the ``(E, F, K)`` per-row shape
    parameters), ``lam`` the per-row risk weight, ``mask`` the per-row
    active-channel mask. Returns ``(mu, var, W_next)`` as numpy — the
    moments are evaluated at the INCOMING ``W`` (they price the current
    split; the stepped ``W_next`` is priced next tick). Also the
    per-instance baseline unit in ``benchmarks/serve_trace.py`` — the
    benchmark's looped baseline calls this once per instance, the engine
    once per family group.
    """
    F, K = W.shape
    if block_f is None:
        block_f = autotune.lookup(F, K, num_t, backend=impl, fused=True,
                                  dist_id=dist_id, stacked=True)
    m, v, dm, dv = ops.frontier_moments_with_grads(
        jnp.asarray(W, jnp.float32), jnp.asarray(mus, jnp.float32),
        jnp.asarray(sigmas, jnp.float32), num_t=num_t, impl=impl,
        block_f=block_f, family=(dist_id, jnp.asarray(extra, jnp.float32)))
    W2 = _row_step(jnp.asarray(W, jnp.float32), dm, dv,
                   jnp.asarray(lam, jnp.float32),
                   jnp.asarray(mask, jnp.float32),
                   jnp.float32(lr))
    return np.asarray(m, np.float64), np.asarray(v, np.float64), \
        np.asarray(W2, np.float64)


@dataclass
class _EngineRow:
    """One (instance, remaining stage) pair of the current solve tick."""

    iid: int
    stage: str
    key: str                      # heads key: "template/stage"
    k: int
    mus: np.ndarray               # (k,) posterior point estimates
    sigmas: np.ndarray            # (k,)
    family: object                # the head's selected ChannelFamily
    lam: float                    # instance risk weight (SLO urgency)
    w: np.ndarray                 # (k,) incoming split (priced this launch)
    mu: Optional[float] = None    # set by the launch
    var: Optional[float] = None


@dataclass
class _Instance:
    """One live workflow instance: its progress, splits and solve state."""

    iid: int
    template: str
    deadline: float               # SLO bound on the makespan (sim seconds)
    admitted_tick: int
    elapsed: float = 0.0          # makespan so far (max stage completion)
    completions: dict = field(default_factory=dict)   # stage -> finish time
    weights: dict = field(default_factory=dict)       # stage -> (K_s,)
    stage_mu: dict = field(default_factory=dict)      # last priced moments
    stage_var: dict = field(default_factory=dict)
    steps_left: int = 0           # pending PGD descents (dirty when > 0)
    lam: float = 0.0              # risk weight at the last solve
    stat_snap: dict = field(default_factory=dict)     # stats at last solve


class WorkflowEngine:
    """Admission-queue continuous-batching engine over workflow instances.

    ``templates`` maps template name -> :class:`~repro.workflow.dag.StageDAG`
    (the workflow shapes this engine serves); each template gets one shared
    :class:`WorkflowSim` stage-fleet world (instances of a template contend
    for the same physical channels, tick by tick). A request enters via
    :meth:`submit` (template + optional SLO deadline), waits in the
    admission queue while the live set is full, and once admitted becomes a
    live instance with its own forked estimation heads
    (:class:`~repro.sched.balancer.InstanceHeads`).

    One :meth:`tick` runs the continuous-batching cycle:

    1. **admit** — pending requests fill free live slots.
    2. **solve** — every dirty instance's remaining stages become rows of
       one stacked fused launch per completion-time family
       (``stack_rows`` groups them; the row axis pads to an
       ``autotune.bucket_rows`` bucket so the jit/autotune caches stay
       warm across fluctuating live counts). Each row descends one
       normalized-PGD step on its stage simplex; moments from the SAME
       launch feed telemetry and SLO prediction — no second launch.
    3. **execute** — each instance runs its released wave (stages whose
       predecessors completed) on the template's sim fleet; observations
       feed the instance head AND the template prototype.
    4. **retire** — finished instances record join latency and SLO
       verdicts and free their slot.

    **Dirtiness (the engine-level ``dirty=`` contract).** An instance is
    dirty while ``steps_left > 0``: admission starts it at
    ``settle_steps``, and a settled instance re-dirties only when its
    posteriors drift past ``dirty_tol`` (relative, vs the stats its last
    solve priced) or its SLO urgency moves by more than ``dirty_tol``
    relative. Clean instances contribute NO rows — their splits stand
    verbatim, so solver cost tracks the drift rate, not the live count.

    **SLO -> risk.** Each instance's row weight is ``lam_var + slo_gain *
    min(predicted_remaining / slack, slo_lam_cap)``: an instance burning
    its deadline budget pays increasingly for variance, which is exactly
    the paper's mean-variance frontier driven by urgency.
    """

    def __init__(self, templates: Dict[str, object], *, max_live: int = 256,
                 lam_var: float = 0.0, slo_gain: float = 0.5,
                 slo_lam_cap: float = 4.0, settle_steps: int = 6,
                 dirty_tol: float = 0.05, lr: float = 0.02,
                 num_t: int = 256, impl: str = "xla", seed: int = 0,
                 prior_obs: int = 0, telemetry_capacity: int = 2048):
        if not templates:
            raise ValueError("WorkflowEngine needs at least one template")
        self.templates = dict(templates)
        self.max_live = int(max_live)
        self.lam_var = float(lam_var)
        self.slo_gain = float(slo_gain)
        self.slo_lam_cap = float(slo_lam_cap)
        self.settle_steps = int(settle_steps)
        self.dirty_tol = float(dirty_tol)
        self.lr = float(lr)
        self.num_t = int(num_t)
        self.impl = impl
        self.seed = int(seed)
        self.sims: Dict[str, WorkflowSim] = {
            name: WorkflowSim.from_dag(dag, seed=seed + 1000 * i)
            for i, (name, dag) in enumerate(self.templates.items())}
        prototypes = {}
        for name, dag in self.templates.items():
            for s in dag.stages:
                prototypes[f"{name}/{s.name}"] = UncertaintyAwareBalancer(
                    num_channels=s.k, family=s.family,
                    prior_mean=float(np.mean(s.mus)), explore=0.0)
                if prior_obs:
                    # optional warm prior: feed the template's declared
                    # stats as synthetic observations so first admissions
                    # price heterogeneous channels instead of a flat prior
                    w = np.full(s.k, 1.0 / s.k)
                    for _ in range(prior_obs):
                        prototypes[f"{name}/{s.name}"].observe(
                            s.mus * w, w)
        self.heads = InstanceHeads(prototypes)
        # the pinned channel axis: every stacked launch pads to this K so
        # the jit cache keys only by row bucket, never by the live mix
        self.kmax = max(s.k for dag in self.templates.values()
                        for s in dag.stages)
        self.telemetry = ServeTelemetry(capacity=telemetry_capacity,
                                        seed=seed)
        self._queue: deque = deque()
        self._live: Dict[int, _Instance] = {}
        self._next_iid = 0
        self.tick_count = 0
        self.last_tick: Optional[dict] = None
        self.last_rows: List[_EngineRow] = []

    # ------------------------------------------------------------ admission
    def submit(self, template: str, deadline: Optional[float] = None) -> int:
        """Enqueue one workflow request; returns its instance id.

        ``deadline`` is the SLO bound on the instance's end-to-end makespan
        in simulated seconds (None = no SLO: the instance solves at the
        engine's base ``lam_var``).
        """
        if template not in self.templates:
            raise ValueError(f"unknown template {template!r} "
                             f"(templates: {sorted(self.templates)})")
        iid = self._next_iid
        self._next_iid += 1
        self._queue.append({"iid": iid, "template": template,
                            "deadline": (float("inf") if deadline is None
                                         else float(deadline)),
                            "queued_tick": self.tick_count})
        return iid

    @property
    def queue_depth(self) -> int:
        return len(self._queue)

    @property
    def live_count(self) -> int:
        return len(self._live)

    def set_load(self, factor: float, template: Optional[str] = None):
        """Regime switch on one template's sim world or all of them."""
        sims = ([self.sims[template]] if template is not None
                else self.sims.values())
        for sim in sims:
            sim.set_load(factor)

    def _admit(self) -> int:
        admitted = 0
        while self._queue and len(self._live) < self.max_live:
            req = self._queue.popleft()
            iid, tpl = req["iid"], req["template"]
            dag = self.templates[tpl]
            self.heads.admit(iid, [f"{tpl}/{s.name}" for s in dag.stages])
            inst = _Instance(iid=iid, template=tpl,
                             deadline=req["deadline"],
                             admitted_tick=self.tick_count,
                             steps_left=self.settle_steps)
            for s in dag.stages:
                inst.weights[s.name] = np.full(s.k, 1.0 / s.k)
            self._live[iid] = inst
            # dirty-set membership is auditable from birth: admission IS
            # the first dirty interval (steps_left = settle_steps)
            obs_events.dirty("engine", str(iid), "admit")
            self.telemetry.bump("admitted")
            self.telemetry.add("queue_wait_ticks",
                               self.tick_count - req["queued_tick"])
            admitted += 1
        return admitted

    # ------------------------------------------------------------ solve
    def _predicted_remaining(self, inst: _Instance) -> float:
        """Longest-path predicted time over the instance's remaining stages
        (host-side, O(S)): last-priced stage means where a solve has run,
        else the head's naive equal-split estimate."""
        dag = self.templates[inst.template]
        lp: Dict[str, float] = {}
        best = 0.0
        for name in dag.topo_order:
            if name in inst.completions:
                continue
            if name in inst.stage_mu:
                mu_s = inst.stage_mu[name]
            else:
                mus, _ = self.heads.estimates(inst.iid,
                                              f"{inst.template}/{name}")
                mu_s = float(np.mean(mus)) / max(len(mus), 1)
            rel = max((lp[u] for u in dag.predecessors(name) if u in lp),
                      default=0.0)
            lp[name] = rel + float(mu_s)
            best = max(best, lp[name])
        return best

    def _row_lam(self, inst: _Instance) -> float:
        if not np.isfinite(inst.deadline):
            return self.lam_var
        slack = max(inst.deadline - inst.elapsed, 1e-9)
        urgency = self._predicted_remaining(inst) / slack
        return self.lam_var + self.slo_gain * min(urgency, self.slo_lam_cap)

    def _maybe_redirty(self, inst: _Instance) -> None:
        """Posterior / urgency drift check for a settled instance."""
        tpl = inst.template
        for name in self.templates[tpl].names:
            if name in inst.completions or name not in inst.stat_snap:
                continue
            mus, sigmas = self.heads.estimates(inst.iid, f"{tpl}/{name}")
            mu0, sg0 = inst.stat_snap[name]
            drift = max(float(np.max(np.abs(mus - mu0) / np.abs(mu0))),
                        float(np.max(np.abs(sigmas - sg0)
                                     / np.maximum(np.abs(mu0), 1e-12))))
            if drift > self.dirty_tol:
                inst.steps_left = self.settle_steps
                obs_events.dirty("engine", f"{inst.iid}/{name}", "drift",
                                 drift)
                return
        lam_now = self._row_lam(inst)
        if abs(lam_now - inst.lam) > self.dirty_tol * max(abs(inst.lam),
                                                          1.0):
            inst.steps_left = self.settle_steps
            obs_events.dirty("engine", str(inst.iid), "slo",
                             abs(lam_now - inst.lam))

    def _gather_rows(self) -> List[_EngineRow]:
        rows: List[_EngineRow] = []
        for inst in self._live.values():
            if inst.steps_left <= 0:
                self._maybe_redirty(inst)
            if inst.steps_left <= 0:
                continue
            lam_i = self._row_lam(inst)
            if obs.enabled() and lam_i > self.lam_var:
                obs_events.slo_lam(inst.iid, lam_i, self.lam_var,
                                   headroom=inst.deadline - inst.elapsed)
            tpl = inst.template
            for s in self.templates[tpl].stages:
                if s.name in inst.completions:
                    continue  # sunk work: completed stages leave the solve
                key = f"{tpl}/{s.name}"
                mus, sigmas = self.heads.estimates(inst.iid, key)
                rows.append(_EngineRow(
                    iid=inst.iid, stage=s.name, key=key, k=s.k,
                    mus=np.asarray(mus, np.float64),
                    sigmas=np.asarray(sigmas, np.float64),
                    family=self.heads.family(inst.iid, key),
                    lam=lam_i, w=inst.weights[s.name]))
        return rows

    def _solve_tick(self, rows: List[_EngineRow]) -> int:
        """One batched solve: ONE fused launch per family group, padded to
        the row bucket; write stepped splits and priced moments back."""
        t0 = perf_counter()
        groups, mask, kmax = stack_rows(
            [(r.mus, r.sigmas, r.family) for r in rows], kmax=self.kmax)
        launches = 0
        for g in groups:
            n = len(g.idx)
            F = autotune.bucket_rows(n)
            E = g.extra.shape[0]
            W = np.zeros((F, kmax), np.float32)
            mus = np.zeros((F, kmax), np.float32)
            sgs = np.zeros((F, kmax), np.float32)
            ex = np.zeros((E, F, kmax), np.float32)
            msk = np.zeros((F, kmax), np.float32)
            lam = np.zeros(F, np.float32)
            for j, ridx in enumerate(g.idx):
                r = rows[ridx]
                W[j, :r.k] = r.w
                msk[j] = mask[ridx]
                lam[j] = r.lam
            mus[:n], sgs[:n], ex[:, :n] = g.mus, g.sigmas, g.extra
            if F > n:  # pad rows repeat row 0 (sliced off after the launch)
                W[n:], mus[n:], sgs[n:] = W[0], mus[0], sgs[0]
                ex[:, n:] = ex[:, :1]
                msk[n:], lam[n:] = msk[0], lam[0]
            with obs.span(obs_names.SPAN_SOLVER_PGD, family=g.dist_id,
                          rows=n, F=F, K=kmax, num_t=self.num_t):
                m, v, W2 = row_pgd_step(W, mus, sgs, g.dist_id, ex, lam,
                                        msk, num_t=self.num_t,
                                        impl=self.impl, lr=self.lr)
            launches += 1
            self.telemetry.bump("launches")
            self.telemetry.add("rows_per_launch", n)
            self.telemetry.add("row_occupancy", n / F)
            for j, ridx in enumerate(g.idx):
                r = rows[ridx]
                inst = self._live[r.iid]
                inst.weights[r.stage] = np.asarray(W2[j, :r.k], np.float64)
                inst.stage_mu[r.stage] = float(m[j])
                inst.stage_var[r.stage] = float(v[j])
                inst.stat_snap[r.stage] = (r.mus.copy(), r.sigmas.copy())
                r.mu, r.var = float(m[j]), float(v[j])
        # one descent consumed; the urgency each row solved under is the
        # baseline the next re-dirty check compares against
        for r in rows:
            self._live[r.iid].lam = r.lam
        for iid in {r.iid for r in rows}:
            self._live[iid].steps_left -= 1
        self.telemetry.add("solver_tick_us", (perf_counter() - t0) * 1e6)
        return launches

    # ------------------------------------------------------------ execute
    def _execute(self) -> List[dict]:
        retired: List[dict] = []
        for iid in list(self._live):
            inst = self._live[iid]
            dag = self.templates[inst.template]
            sim = self.sims[inst.template]
            ready = [s for s in dag.stages
                     if s.name not in inst.completions
                     and all(u in inst.completions
                             for u in dag.predecessors(s.name))]
            for s in ready:
                release = max((inst.completions[u]
                               for u in dag.predecessors(s.name)),
                              default=0.0)
                w = inst.weights[s.name]
                join_t, durs = sim.stage_sims[s.name].run_step(w)
                inst.completions[s.name] = release + join_t
                self.heads.observe(iid, f"{inst.template}/{s.name}",
                                   durs, w)
            if inst.completions:
                inst.elapsed = max(inst.completions.values())
            if len(inst.completions) == len(dag.stages):
                miss = inst.elapsed > inst.deadline
                self.telemetry.bump("retired")
                if miss:
                    self.telemetry.bump("slo_misses")
                self.telemetry.add("join_latency_s", inst.elapsed)
                retired.append({"iid": iid, "template": inst.template,
                                "join_latency_s": inst.elapsed,
                                "slo_miss": bool(miss),
                                "ticks_in_flight":
                                    self.tick_count - inst.admitted_tick})
                self.heads.retire(iid)
                del self._live[iid]
        return retired

    # ------------------------------------------------------------ tick
    def tick(self, arrivals=()) -> dict:
        """One engine tick: admit -> batched solve -> execute -> retire.

        ``arrivals``: template names (or ``(template, deadline)`` pairs) to
        submit before admission — convenience for trace-driven callers.
        """
        self.tick_count += 1
        obs.set_tick(self.tick_count)
        with obs.span(obs_names.SPAN_ENGINE_TICK) as sp_tick:
            for sim in self.sims.values():
                sim.tick()  # scheduled churn fires before this tick's draws
            for a in arrivals:
                if isinstance(a, (tuple, list)):
                    self.submit(a[0], a[1])
                else:
                    self.submit(a)
            with obs.span(obs_names.SPAN_ENGINE_STAGE, stage="admission"):
                admitted = self._admit()
            with obs.span(obs_names.SPAN_ENGINE_STAGE, stage="stack_rows"):
                rows = self._gather_rows()
            with obs.span(obs_names.SPAN_ENGINE_STAGE, stage="launch"):
                launches = self._solve_tick(rows) if rows else 0
            self.last_rows = rows
            with obs.span(obs_names.SPAN_ENGINE_STAGE, stage="commit"):
                retired = self._execute()
            self.telemetry.bump("ticks")
            self.telemetry.add("live_instances", len(self._live))
            self.last_tick = {
                "tick": self.tick_count,
                "admitted": admitted,
                "retired": retired,
                "live": len(self._live),
                "queue": len(self._queue),
                "rows": len(rows),
                "launches": launches,
            }
            if obs.enabled():
                sp_tick.attrs.update(live=len(self._live),
                                     queue=len(self._queue),
                                     rows=len(rows), launches=launches)
        return self.last_tick

    # ------------------------------------------------------------ state
    def state_dict(self) -> dict:
        """Everything the kill/restore tick-parity contract needs: the
        admission queue, every live instance (splits, progress, solve
        state), all estimation heads, every template's sim world (rng
        streams included) and the telemetry reservoirs. Templates stay
        code-side, like the workflow balancer's DAG."""
        return {
            "kind": "engine",
            "config": {
                "max_live": self.max_live, "lam_var": self.lam_var,
                "slo_gain": self.slo_gain, "slo_lam_cap": self.slo_lam_cap,
                "settle_steps": self.settle_steps,
                "dirty_tol": self.dirty_tol, "lr": self.lr,
                "num_t": self.num_t, "impl": self.impl, "seed": self.seed,
            },
            "tick_count": self.tick_count,
            "next_iid": self._next_iid,
            "queue": [dict(q) for q in self._queue],
            "instances": {str(iid): {
                "template": i.template,
                "deadline": (None if not np.isfinite(i.deadline)
                             else i.deadline),
                "admitted_tick": i.admitted_tick,
                "elapsed": i.elapsed,
                "completions": {k: float(v)
                                for k, v in i.completions.items()},
                "weights": {k: np.asarray(v).tolist()
                            for k, v in i.weights.items()},
                "stage_mu": dict(i.stage_mu),
                "stage_var": dict(i.stage_var),
                "steps_left": i.steps_left,
                "lam": i.lam,
                "stat_snap": {k: [np.asarray(m).tolist(),
                                  np.asarray(s).tolist()]
                              for k, (m, s) in i.stat_snap.items()},
            } for iid, i in self._live.items()},
            "heads": self.heads.state_dict(),
            "sims": {name: sim.state_dict()
                     for name, sim in self.sims.items()},
            "telemetry": self.telemetry.state_dict(),
        }

    def load_state_dict(self, d: dict) -> "WorkflowEngine":
        self.tick_count = int(d["tick_count"])
        self._next_iid = int(d["next_iid"])
        self._queue = deque(dict(q) for q in d.get("queue", []))
        self._live = {}
        for iid_s, s in d.get("instances", {}).items():
            iid = int(iid_s)
            inst = _Instance(
                iid=iid, template=s["template"],
                deadline=(float("inf") if s["deadline"] is None
                          else float(s["deadline"])),
                admitted_tick=int(s["admitted_tick"]),
                elapsed=float(s["elapsed"]),
                completions={k: float(v)
                             for k, v in s["completions"].items()},
                weights={k: np.asarray(v, np.float64)
                         for k, v in s["weights"].items()},
                stage_mu={k: float(v) for k, v in s["stage_mu"].items()},
                stage_var={k: float(v) for k, v in s["stage_var"].items()},
                steps_left=int(s["steps_left"]),
                lam=float(s["lam"]),
                stat_snap={k: (np.asarray(m, np.float64),
                               np.asarray(sg, np.float64))
                           for k, (m, sg) in s["stat_snap"].items()})
            self._live[iid] = inst
        self.heads = InstanceHeads.from_state_dict(d["heads"])
        self.sims = {name: WorkflowSim.from_state_dict(sd)
                     for name, sd in d["sims"].items()}
        self.telemetry = ServeTelemetry.from_state_dict(d["telemetry"])
        return self

    @classmethod
    def from_state_dict(cls, d: dict,
                        templates: Dict[str, object]) -> "WorkflowEngine":
        cfg = dict(d.get("config", {}))
        return cls(templates, **cfg).load_state_dict(d)
