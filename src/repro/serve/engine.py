"""Serving engine: prefill + decode with KV caches, plus the partitioned
batcher (the paper's file-transfer scenario mapped to request routing).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..sched.balancer import UncertaintyAwareBalancer, integerize
from ..sim.cluster import ClusterSim

__all__ = ["ServeEngine", "PartitionedBatcher", "PipelineBatcher"]


class ServeEngine:
    """Single-replica engine: batched prefill then greedy decode."""

    def __init__(self, model, cfg: ModelConfig):
        self.model = model
        self.cfg = cfg
        self._prefill = jax.jit(lambda p, t, cl: model.prefill(p, t, cache_len=cl),
                                static_argnums=2)
        self._step = jax.jit(model.decode_step)

    def generate(self, params, prompts: jnp.ndarray, max_new: int) -> jnp.ndarray:
        """prompts: (B, S) int32. Greedy continuation of max_new tokens."""
        B, S = prompts.shape
        logits, cache = self._prefill(params, prompts, S + max_new)
        tok = jnp.argmax(logits[:, -1:, :self.cfg.vocab_size], axis=-1)
        outs = [tok]
        for _ in range(max_new - 1):
            logits, cache = self._step(params, cache, tok)
            tok = jnp.argmax(logits[:, :, :self.cfg.vocab_size], axis=-1)
            outs.append(tok)
        return jnp.concatenate(outs, axis=1)


@dataclass
class ReplicaGroup:
    """A serving channel: model replica set with its own speed distribution."""
    name: str
    engine: Optional[ServeEngine] = None
    params: Optional[dict] = None


class PartitionedBatcher:
    """Split request batches across replica groups by the paper's frontier.

    The batch of R requests is the workflow D; replica groups are channels;
    the response is complete when the *slowest* group returns (the join).
    The balancer learns per-group (mu, sigma) per-request service rates online
    and re-partitions every batch.
    """

    def __init__(self, groups: List[ReplicaGroup], lam: float = 0.05,
                 policy: str = "frontier", sim: Optional[ClusterSim] = None,
                 seed: int = 0, impl: str = "xla", num_t: int = 1024,
                 refresh_every: int = 1, family="normal",
                 risk_lam: float = 0.0, adaptive_refresh: bool = False,
                 block_f=None):
        self.groups = groups
        # forward the solver knobs so serving ticks run the kernel-backed
        # (and, with impl="pallas", compiled) fused solve path online;
        # ``family`` swaps the completion-time model the frontier solves
        # under (e.g. "lognormal" for heavy-tailed WAN-style service times,
        # or "auto" to let the balancer BIC-select the model from the
        # observed rate history and switch it with hysteresis)
        self.balancer = UncertaintyAwareBalancer(len(groups), lam=lam,
                                                 policy=policy, impl=impl,
                                                 num_t=num_t,
                                                 refresh_every=refresh_every,
                                                 family=family,
                                                 risk_lam=risk_lam,
                                                 adaptive_refresh=adaptive_refresh,
                                                 block_f=block_f)
        self.sim = sim or ClusterSim.heterogeneous(len(groups), seed=seed)
        self.last_tick: Optional[dict] = None

    def split(self, num_requests: int) -> np.ndarray:
        return integerize(self.balancer.weights(), num_requests)

    @property
    def selected_family(self) -> str:
        """dist_id of the family the balancer is currently solving under
        (moves over time when ``family="auto"``)."""
        return self.balancer.selected_family.dist_id

    def run_batch(self, prompts: np.ndarray, max_new: int = 8,
                  execute: bool = False) -> Tuple[float, np.ndarray, list]:
        """Route one batch. Returns (join_latency, counts, responses).

        execute=True runs the actual models (tiny configs in examples);
        latency always comes from the simulator channels (this container has
        one CPU — the timing physics live in sim, as the paper's did in
        background-process contention). Per-tick telemetry — including the
        family the solve ran under, which is the interesting signal in
        ``family="auto"`` mode — lands in ``self.last_tick``.
        """
        R = prompts.shape[0]
        counts = self.split(R)
        fam = self.selected_family
        responses = [None] * len(self.groups)
        if execute:
            off = 0
            for gi, c in enumerate(counts):
                if c == 0:
                    continue
                g = self.groups[gi]
                chunk = jnp.asarray(prompts[off:off + c])
                responses[gi] = np.asarray(
                    g.engine.generate(g.params, chunk, max_new))
                off += c
        join_t, durs = self.sim.run_step(counts.astype(np.float64) / max(R, 1))
        self.balancer.observe(durs, counts.astype(np.float64) / max(R, 1))
        self.last_tick = {
            "family": fam,
            "join_latency": float(join_t),
            "counts": counts,
            "effective_refresh": self.balancer.effective_refresh,
        }
        return join_t, counts, responses

    # ------------------------------------------------------------ persistence
    def state_dict(self) -> dict:
        """Balancer AND sim-world snapshot: a batcher restored from this
        replays bitwise-identical ticks (same splits, same simulated
        durations, same posterior updates) — see ckpt/store.py's
        kill/restore tick-parity contract. Replica groups (model handles)
        are code-side configuration, like the workflow balancer's DAG."""
        return {"balancer": self.balancer.state_dict(),
                "sim": self.sim.state_dict()}

    def load_state_dict(self, d: dict):
        self.balancer = UncertaintyAwareBalancer.from_state_dict(
            d["balancer"])
        self.sim = ClusterSim.from_state_dict(d["sim"])
        return self

    @classmethod
    def from_state_dict(cls, d: dict,
                        groups: List[ReplicaGroup]) -> "PartitionedBatcher":
        return cls(groups).load_state_dict(d)


class PipelineBatcher:
    """A serving pipeline of :class:`PartitionedBatcher` stages over a
    fork-join graph — the workflow subsystem's request-routing twin.

    Each stage is a full PartitionedBatcher (its own replica groups, its own
    online balancer — per-stage ``family="auto"`` / ``risk_lam`` /
    ``adaptive_refresh`` all apply stage-locally). A batch enters at the
    source stages and a stage starts only when every upstream stage has
    returned (release = max over predecessor completions), so the end-to-end
    latency composes exactly like ``StageDAG.compose_moments`` predicts —
    series sums, joins max.

    ``stages``: {name: PartitionedBatcher} or an ordered sequence of
    (name, batcher) pairs / bare batchers (auto-named ``stage0..``);
    ``edges``: precedence pairs — omitted means a linear pipeline in the
    given order. Structure is validated by the workflow DAG machinery
    (cycles, unknown names, bounded depth) at construction.
    """

    def __init__(self, stages, edges=None):
        from ..workflow.dag import StageDAG, linear_edges

        if isinstance(stages, dict):
            named = list(stages.items())
        else:
            named = [(s if isinstance(s, tuple) else (f"stage{i}", s))
                     for i, s in enumerate(stages)]
        self.names = [n for n, _ in named]
        self.batchers = dict(named)
        self.graph = StageDAG.from_names(
            self.names, linear_edges(self.names) if edges is None else edges)
        self.last_tick: Optional[dict] = None

    @property
    def selected_families(self) -> dict:
        return {n: b.selected_family for n, b in self.batchers.items()}

    # ------------------------------------------------------------ persistence
    def state_dict(self) -> dict:
        """Per-stage batcher snapshots (graph structure stays code-side)."""
        return {"stages": {n: b.state_dict()
                           for n, b in self.batchers.items()}}

    def load_state_dict(self, d: dict):
        for n, sd in d["stages"].items():
            if n not in self.batchers:
                raise ValueError(f"state_dict stage {n!r} not in this "
                                 f"pipeline (stages: {self.names})")
            self.batchers[n].load_state_dict(sd)
        return self

    def run_batch(self, prompts: np.ndarray, max_new: int = 8,
                  execute: bool = False):
        """Route one batch through the whole pipeline.

        Returns ``(end_latency, counts_by_stage, completions_by_stage)``.
        Each stage re-partitions the SAME request batch across its own
        replica groups and observes its own durations; the pipeline only
        adds the precedence composition on top.
        """
        completions: dict = {}
        counts_by_stage: dict = {}
        stage_ticks: dict = {}
        for name in self.graph.topo_order:
            release = max((completions[u]
                           for u in self.graph.predecessors(name)),
                          default=0.0)
            join_t, counts, _ = self.batchers[name].run_batch(
                prompts, max_new=max_new, execute=execute)
            completions[name] = release + join_t
            counts_by_stage[name] = counts
            stage_ticks[name] = self.batchers[name].last_tick
        end = max(completions[n] for n in self.graph.sinks)
        self.last_tick = {
            "end_latency": float(end),
            "completions": dict(completions),
            "stages": stage_ticks,
        }
        return end, counts_by_stage, completions
