"""Streaming serving telemetry: bounded-memory percentiles and counters.

A continuous-batching engine cannot keep every observation — at
millions-of-requests/day scale the join-latency trace alone would dwarf the
solver state — but its SLO story is told in tails, not means. So every
metric streams through a :class:`StreamingStat`: an exact running mean and
variance (Welford) plus a fixed-capacity uniform reservoir (Vitter's
algorithm R) that quantile queries read from. The reservoir is an unbiased
uniform sample of the full stream, so its empirical quantiles are
consistent estimates of the stream's — the same contract a t-digest gives,
with a simpler (and exactly serializable) state.

Telemetry is part of the engine's kill/restore tick-parity surface: the
reservoir VALUES and the sampler's rng state both ride ``state_dict``, so a
restored engine's percentiles — and its subsequent sampling decisions — are
bitwise identical to the replica that died.
"""
from __future__ import annotations

import numpy as np

__all__ = ["StreamingStat", "ServeTelemetry"]


class StreamingStat:
    """Reservoir-sampled quantiles + exact Welford mean/variance."""

    def __init__(self, capacity: int = 1024, seed: int = 0):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self.seed = int(seed)
        self._rng = np.random.default_rng(self.seed)
        self._res: list = []
        self._n = 0
        self._mean = 0.0
        self._m2 = 0.0
        self._max = -np.inf
        self._min = np.inf

    def add(self, x: float) -> None:
        x = float(x)
        self._n += 1
        d = x - self._mean
        self._mean += d / self._n
        self._m2 += d * (x - self._mean)
        self._max = max(self._max, x)
        self._min = min(self._min, x)
        if len(self._res) < self.capacity:
            self._res.append(x)
        else:
            # algorithm R: element n replaces a reservoir slot w.p. cap/n
            j = int(self._rng.integers(0, self._n))
            if j < self.capacity:
                self._res[j] = x

    @property
    def count(self) -> int:
        return self._n

    def mean(self) -> float:
        return float(self._mean) if self._n else 0.0

    def var(self) -> float:
        return float(self._m2 / self._n) if self._n else 0.0

    def max(self) -> float:
        return float(self._max) if self._n else 0.0

    def min(self) -> float:
        return float(self._min) if self._n else 0.0

    def quantile(self, q: float) -> float:
        if not self._res:
            return 0.0
        return float(np.quantile(np.asarray(self._res, np.float64), q))

    def summary(self) -> dict:
        return {
            "count": self._n,
            "mean": self.mean(),
            "var": self.var(),
            "min": self.min(),
            "max": self.max(),
            "p50": self.quantile(0.50),
            "p90": self.quantile(0.90),
            "p99": self.quantile(0.99),
        }

    def merge(self, other: "StreamingStat") -> "StreamingStat":
        """Fold another stat into this one (for sharded-replica rollups).

        The moment fields combine exactly — weighted (parallel) Welford:
        with ``n = n1 + n2`` and ``d = mean2 - mean1``,

            mean = mean1 + d * n2 / n
            m2   = m2_1 + m2_2 + d^2 * n1 * n2 / n

        so merged mean/var/min/max/count equal those of the concatenated
        stream bit-for-bit (up to float round-off). The reservoir cannot
        combine exactly — each side kept only a uniform sample — so it is
        subsampled: every kept slot is drawn from side 1 with probability
        ``n1 / n`` (without replacement within each side), which preserves
        the every-element-equally-likely invariant quantile queries rest
        on. The draws come from ``self``'s own rng, never a simulation
        stream; merging is deterministic given both states.
        """
        if other.capacity != self.capacity:
            raise ValueError(
                f"reservoir capacities differ: {self.capacity} vs "
                f"{other.capacity}")
        if other._n == 0:
            return self
        if self._n == 0:
            self._res = list(other._res)
            self._n = other._n
            self._mean = other._mean
            self._m2 = other._m2
            self._max = other._max
            self._min = other._min
            return self
        n1, n2 = self._n, other._n
        n = n1 + n2
        d = other._mean - self._mean
        self._mean += d * n2 / n
        self._m2 += other._m2 + d * d * n1 * n2 / n
        self._max = max(self._max, other._max)
        self._min = min(self._min, other._min)
        self._n = n
        pool1 = list(self._res)
        pool2 = list(other._res)
        self._rng.shuffle(pool1)
        self._rng.shuffle(pool2)
        merged: list = []
        want = min(self.capacity, len(pool1) + len(pool2))
        i = j = 0
        while len(merged) < want:
            # weight each side by how many stream elements its pool stands
            # in for, so the merged reservoir stays uniform over the union
            w1 = n1 if i < len(pool1) else 0
            w2 = n2 if j < len(pool2) else 0
            if self._rng.random() * (w1 + w2) < w1:
                merged.append(pool1[i])
                i += 1
            else:
                merged.append(pool2[j])
                j += 1
        self._res = merged
        return self

    # ------------------------------------------------------------ state
    def state_dict(self) -> dict:
        return {
            "capacity": self.capacity,
            "seed": self.seed,
            "reservoir": list(self._res),
            "n": self._n,
            "mean": self._mean,
            "m2": self._m2,
            "max": None if not self._n else self._max,
            "min": None if not self._n else self._min,
            "rng_state": self._rng.bit_generator.state,
        }

    @classmethod
    def from_state_dict(cls, d: dict) -> "StreamingStat":
        s = cls(capacity=d["capacity"], seed=d.get("seed", 0))
        s._res = [float(x) for x in d["reservoir"]]
        s._n = int(d["n"])
        s._mean = float(d["mean"])
        s._m2 = float(d["m2"])
        s._max = -np.inf if d.get("max") is None else float(d["max"])
        s._min = np.inf if d.get("min") is None else float(d["min"])
        if d.get("rng_state") is not None:
            s._rng.bit_generator.state = d["rng_state"]
        return s


# metric name -> what one sample means (doc + construction table)
_METRICS = {
    "join_latency_s": "retired instance's end-to-end makespan (sim seconds)",
    "queue_wait_ticks": "admission-queue residence of an admitted instance",
    "solver_tick_us": "wall-clock of one batched solve tick (all launches)",
    "rows_per_launch": "real (un-padded) rows riding one family launch",
    "row_occupancy": "real rows / padded rows of one launch (bucket fill)",
    "live_instances": "live-instance count sampled once per tick",
}


class ServeTelemetry:
    """The engine's metric bundle: one :class:`StreamingStat` per metric
    in ``_METRICS`` plus monotone counters (admitted / retired / launches /
    slo_misses / ticks). ``summary()`` is the BENCH_serve_trace payload."""

    def __init__(self, capacity: int = 2048, seed: int = 0):
        self.stats = {name: StreamingStat(capacity=capacity, seed=seed + i)
                      for i, name in enumerate(_METRICS)}
        self.counters = {"admitted": 0, "retired": 0, "launches": 0,
                         "slo_misses": 0, "ticks": 0}

    def add(self, name: str, value: float) -> None:
        self.stats[name].add(value)

    def bump(self, name: str, by: int = 1) -> None:
        self.counters[name] += int(by)

    def summary(self) -> dict:
        out = {name: stat.summary() for name, stat in self.stats.items()}
        out["counters"] = dict(self.counters)
        return out

    # ------------------------------------------------------------ state
    def state_dict(self) -> dict:
        return {"stats": {n: s.state_dict() for n, s in self.stats.items()},
                "counters": dict(self.counters)}

    @classmethod
    def from_state_dict(cls, d: dict) -> "ServeTelemetry":
        t = cls()
        for name, sd in d.get("stats", {}).items():
            t.stats[name] = StreamingStat.from_state_dict(sd)
        t.counters.update(d.get("counters", {}))
        return t
