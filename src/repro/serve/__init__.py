"""Serving substrate: engine + the paper-partitioned request batcher."""
from .engine import (PartitionedBatcher, PipelineBatcher, ReplicaGroup,
                     ServeEngine)

__all__ = ["PartitionedBatcher", "PipelineBatcher", "ReplicaGroup",
           "ServeEngine"]
