"""Serving substrate: engine + the paper-partitioned request batcher."""
from .engine import PartitionedBatcher, ReplicaGroup, ServeEngine

__all__ = ["PartitionedBatcher", "ReplicaGroup", "ServeEngine"]
