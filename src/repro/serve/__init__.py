"""Serving substrate: engine, the paper-partitioned request batcher, and
the continuous-batching workflow engine."""
from .engine import (PartitionedBatcher, ReplicaGroup, ServeEngine,
                     WorkflowEngine, row_pgd_step)
from .telemetry import ServeTelemetry, StreamingStat

__all__ = ["PartitionedBatcher", "ReplicaGroup", "ServeEngine",
           "WorkflowEngine", "row_pgd_step", "ServeTelemetry",
           "StreamingStat"]
