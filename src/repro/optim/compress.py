"""Int8 gradient compression with error feedback for the cross-pod all-reduce.

At 2+ pods the gradient all-reduce crosses the DCN (slow links). Compressing
the pod-axis reduction 4x (bf16/f32 -> int8 + per-block scales) cuts the
collective term of the roofline proportionally; error feedback (residual
carried to the next step) keeps convergence unbiased in expectation.

compress/decompress are pure and jit-able; apply_compressed_psum wraps the
pattern "quantize -> psum -> dequantize + residual update" for use inside
shard_map train steps.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

__all__ = ["quantize_int8", "dequantize_int8", "ef_compress", "EFState", "ef_init"]

_BLOCK = 256


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Blockwise symmetric int8 quantization over the LAST axis.

    Sharding-preserving by construction: leading axes are untouched and the
    last axis is only reshaped (blocks, _BLOCK), so a (data, model)-sharded
    gradient stays sharded — a flatten-everything formulation forces GSPMD to
    all-gather each leaf (measured 10x collective blow-up; EXPERIMENTS §Perf).
    """
    xf = x.astype(jnp.float32)
    if xf.ndim == 0:
        xf = xf[None]
    last = xf.shape[-1]
    pad = (-last) % _BLOCK
    if pad:
        xf = jnp.pad(xf, [(0, 0)] * (xf.ndim - 1) + [(0, pad)])
    blocks = xf.reshape(*xf.shape[:-1], (last + pad) // _BLOCK, _BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array, shape, dtype) -> jax.Array:
    deq = (q.astype(jnp.float32) * scale)
    deq = deq.reshape(*deq.shape[:-2], -1)  # merge block axes
    last = shape[-1] if shape else 1
    deq = deq[..., :last]
    return deq.reshape(shape).astype(dtype)


class EFState(NamedTuple):
    residual: dict  # f32 pytree like grads


def ef_init(grads) -> EFState:
    return EFState(residual=jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads))


def ef_compress(grads, ef: EFState):
    """Error-feedback compression: returns (quantized pytree, new EFState).

    q = Q(g + r);  r' = (g + r) - deQ(q)
    """
    def one(g, r):
        tot = g.astype(jnp.float32) + r
        q, s = quantize_int8(tot)
        deq = dequantize_int8(q, s, g.shape, jnp.float32)
        return (q, s), tot - deq

    flat = jax.tree.map(one, grads, ef.residual,
                        is_leaf=lambda x: isinstance(x, jnp.ndarray))
    qs = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda t: isinstance(t, tuple))
    res = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda t: isinstance(t, tuple))
    return qs, EFState(residual=res)
