"""Optimizers and distributed-optimization tricks."""
from .adamw import (AdamWState, adamw_init, adamw_update, clip_by_global_norm,
                    cosine_schedule, global_norm)
from .compress import EFState, dequantize_int8, ef_compress, ef_init, quantize_int8

__all__ = ["AdamWState", "adamw_init", "adamw_update", "clip_by_global_norm",
           "cosine_schedule", "global_norm", "EFState", "dequantize_int8",
           "ef_compress", "ef_init", "quantize_int8"]
