"""AdamW with decoupled weight decay, global-norm clipping and LR schedules.

Pure-pytree implementation (no optax dependency in this offline container).
Moments are f32 regardless of param dtype (mixed-precision convention:
bf16 params / f32 optimizer state, both sharded like the params — FSDP keeps
the 1000-node memory story honest, see EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWState", "adamw_init", "adamw_update", "cosine_schedule",
           "global_norm", "clip_by_global_norm"]


class AdamWState(NamedTuple):
    step: jax.Array   # ()
    m: dict           # f32 pytree like params
    v: dict           # f32 pytree like params


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


def cosine_schedule(base_lr: float, warmup: int, total: int, min_frac: float = 0.1):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * jnp.minimum(step / max(warmup, 1), 1.0)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, base_lr * cos)
    return lr


def adamw_update(params, grads, state: AdamWState, lr, *,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1, max_grad_norm: float = 1.0):
    """One AdamW step. ``lr`` is a schedule fn or a float."""
    grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
    step = state.step + 1
    lr_t = lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)
    b1t = 1 - b1 ** step.astype(jnp.float32)
    b2t = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m_n = b1 * m + (1 - b1) * gf
        v_n = b2 * v + (1 - b2) * gf * gf
        update = (m_n / b1t) / (jnp.sqrt(v_n / b2t) + eps)
        decay = weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
        p_n = p.astype(jnp.float32) - lr_t * (update + decay)
        return p_n.astype(p.dtype), m_n, v_n

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    params_n = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    m_n = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    v_n = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return params_n, AdamWState(step=step, m=m_n, v=v_n), {"grad_norm": gnorm, "lr": lr_t}
