"""Mamba2-2.7B — 64L, d2560, attn-free SSD, state=128. [arXiv:2405.21060]"""
from .base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b", family="ssm",
    num_layers=64, d_model=2560, num_heads=1, num_kv_heads=1, head_dim=64,
    d_ff=0, vocab_size=50280,
    pattern=(LayerSpec("mamba", "none"),),
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_groups=1,
)
