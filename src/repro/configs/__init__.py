"""Config registry: one module per assigned architecture (``--arch <id>``)."""
from importlib import import_module

from .base import SHAPES, LayerSpec, ModelConfig, ShapeSpec, shape_applicable

ARCHS = (
    "qwen3-moe-235b-a22b",
    "deepseek-v2-lite-16b",
    "nemotron-4-340b",
    "qwen3-8b",
    "smollm-360m",
    "h2o-danube-1.8b",
    "whisper-large-v3",
    "mamba2-2.7b",
    "jamba-1.5-large-398b",
    "internvl2-76b",
)


def _modname(arch: str) -> str:
    return arch.replace("-", "_").replace(".", "_")


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCHS}")
    return import_module(f".{_modname(arch)}", __package__).CONFIG


__all__ = ["ARCHS", "SHAPES", "LayerSpec", "ModelConfig", "ShapeSpec",
           "get_config", "shape_applicable"]
