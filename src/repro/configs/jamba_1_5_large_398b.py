"""Jamba-1.5-Large 398B — 72L hybrid: 1 attn per 8 layers (1:7), MoE 16e top-2
every other layer. [arXiv:2403.19887; hf]

Mamba layers use our Mamba2/SSD mixer (DESIGN.md §3 notes the mamba1->SSD
substitution; the assignment's ssm entry pins SSD as the house SSM).
"""
from .base import LayerSpec, ModelConfig

# 8-layer repeating unit: attention at position 4, mamba elsewhere;
# MoE replaces the MLP on every other layer (odd positions).
_PATTERN = tuple(
    LayerSpec("attn" if i == 4 else "mamba", "moe" if i % 2 == 1 else "dense")
    for i in range(8)
)

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    num_layers=72, d_model=8192, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=24576, moe_d_ff=24576, vocab_size=65536,
    pattern=_PATTERN,
    num_experts=16, top_k=2,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_groups=1,
    mlp_act="swiglu", rope_theta=1e4,
)
