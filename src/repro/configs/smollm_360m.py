"""SmolLM-360M — 32L, d960, 15H GQA(kv=5), llama-arch small.

[hf:HuggingFaceTB/SmolLM-360M; hf]
"""
from .base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m", family="dense",
    num_layers=32, d_model=960, num_heads=15, num_kv_heads=5, head_dim=64,
    d_ff=2560, vocab_size=49152,
    pattern=(LayerSpec("attn", "dense"),),
    mlp_act="swiglu", rope_theta=1e4,
)
