"""Whisper-large-v3 backbone — 32L enc + 32L dec, d1280, 20H, enc-dec.

[arXiv:2212.04356; unverified] Conv/mel frontend is a STUB: input_specs()
provides (B, 1500, d) precomputed frame embeddings.
"""
from .base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", family="audio",
    num_layers=32, num_encoder_layers=32, encoder_seq=1500,
    d_model=1280, num_heads=20, num_kv_heads=20, head_dim=64,
    d_ff=5120, vocab_size=51866,
    pattern=(LayerSpec("attn", "dense"),),
    mlp_act="gelu", rope_theta=1e4,
)
