"""DeepSeek-V2-Lite 16B — 27L, d2048, MLA kv_lora=512, 64 routed + 2 shared, top-6.

[arXiv:2405.04434; hf-verified] Assignment says "64e top-6" and "160 routed";
we implement 64 routed + 2 shared (the primary spec; see DESIGN.md §3).
Layer 0 uses a dense MLP (d_ff=10944), layers 1..26 are MoE.
"""
from .base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    num_layers=27, d_model=2048, num_heads=16, num_kv_heads=16, head_dim=128,
    d_ff=10944, moe_d_ff=1408, vocab_size=102400,
    pattern=(LayerSpec("mla", "moe"),), first_layer_dense=True,
    num_experts=64, num_shared_experts=2, top_k=6,
    kv_lora_rank=512, qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128,
    mlp_act="swiglu", rope_theta=1e4,
)
