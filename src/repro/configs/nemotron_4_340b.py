"""Nemotron-4-340B — 96L, d18432, 96H GQA(kv=8), squared-ReLU MLP.

[arXiv:2402.16819; unverified tier]
"""
from .base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b", family="dense",
    num_layers=96, d_model=18432, num_heads=96, num_kv_heads=8, head_dim=192,
    d_ff=73728, vocab_size=256000,
    pattern=(LayerSpec("attn", "dense"),),
    mlp_act="relu2", rope_theta=1e4,
)
