"""Model/arch configuration dataclasses and the shape matrix.

Every assigned architecture is expressed as a ModelConfig built from a small
set of orthogonal features (mixer type, mlp type, MoE, MLA, SSD, enc-dec,
modality stub). Layer stacks are described by a repeating ``pattern`` of
LayerSpec entries so heterogeneous stacks (Jamba's 1:7 attn:mamba interleave)
scan cleanly.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

__all__ = ["LayerSpec", "ModelConfig", "ShapeSpec", "SHAPES", "round_up"]


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class LayerSpec:
    """One position in the repeating layer pattern."""

    mixer: str  # "attn" | "mla" | "mamba"
    mlp: str    # "dense" | "moe" | "none"


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    pattern: Tuple[LayerSpec, ...] = (LayerSpec("attn", "dense"),)
    first_layer_dense: bool = False   # deepseek: layer 0 uses dense MLP
    # --- activations / norms ---
    mlp_act: str = "swiglu"           # swiglu | relu2 | gelu
    qk_norm: bool = False
    rope_theta: float = 1e6
    norm_eps: float = 1e-6
    # --- attention ---
    window: Optional[int] = None      # sliding-window attention
    # --- MoE ---
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    # --- MLA (DeepSeek) ---
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128
    # --- SSM (Mamba2 SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_groups: int = 1
    ssm_conv_width: int = 4
    ssd_chunk: int = 128
    # --- encoder-decoder (whisper) ---
    num_encoder_layers: int = 0
    encoder_seq: int = 0              # precomputed frame embeddings (stub frontend)
    # --- vlm ---
    num_patches: int = 0              # prepended patch embeddings (stub frontend)
    # --- numerics / impl ---
    param_dtype: str = "bfloat16"
    activation_dtype: str = "bfloat16"
    attention_impl: str = "xla"       # xla | pallas | pallas_interpret
    ssd_impl: str = "xla"
    remat: bool = True
    remat_policy: str = "full"    # full | dots (save matmul outputs)
    logical_vocab: int = 0            # unpadded vocab (0 = same as vocab_size)

    # ------------------------------------------------------------------
    @property
    def pattern_len(self) -> int:
        return len(self.pattern)

    @property
    def num_repeats(self) -> int:
        n = self.num_layers - (1 if self.first_layer_dense else 0)
        assert n % self.pattern_len == 0, (self.name, n, self.pattern_len)
        return n // self.pattern_len

    @property
    def ssm_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_inner // self.ssm_head_dim

    @property
    def padded_vocab(self) -> int:
        return round_up(self.vocab_size, 256)

    @property
    def is_encoder_decoder(self) -> bool:
        return self.num_encoder_layers > 0

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def tiny(self, repeats: int = 2) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        kw = dict(
            num_layers=repeats * self.pattern_len + (1 if self.first_layer_dense else 0),
            d_model=64, num_heads=4, num_kv_heads=2 if self.num_kv_heads > 1 else 1,
            head_dim=16, d_ff=128, vocab_size=512,
            param_dtype="float32", activation_dtype="float32",
            window=min(self.window, 32) if self.window else None,
        )
        if self.num_experts:
            kw.update(num_experts=4, top_k=min(self.top_k, 2), moe_d_ff=64,
                      num_shared_experts=min(self.num_shared_experts, 1))
        if self.kv_lora_rank:
            kw.update(kv_lora_rank=32, qk_nope_head_dim=16, qk_rope_head_dim=8,
                      v_head_dim=16)
        if self.ssm_state:
            kw.update(ssm_state=16, ssm_head_dim=8, ssd_chunk=16)
        if self.num_encoder_layers:
            kw.update(num_encoder_layers=repeats, encoder_seq=24)
        if self.num_patches:
            kw.update(num_patches=8)
        return self.replace(**kw)


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """(applies?, reason) — encodes the assignment's skip rules."""
    if shape.name == "long_500k":
        sub_quadratic = (cfg.family in ("ssm", "hybrid")) or (cfg.window is not None)
        if not sub_quadratic:
            return False, "pure full-attention arch: 500k decode needs sub-quadratic attention"
    return True, ""
