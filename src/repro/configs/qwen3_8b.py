"""Qwen3-8B — 36L, d4096, 32H GQA(kv=8), qk_norm. [hf:Qwen/Qwen3-8B; hf]"""
from .base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-8b", family="dense",
    num_layers=36, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=12288, vocab_size=151936,
    pattern=(LayerSpec("attn", "dense"),),
    mlp_act="swiglu", qk_norm=True, rope_theta=1e6,
)
