"""Qwen3-MoE-235B-A22B — 94L, d4096, 64H GQA(kv=4), 128 experts top-8.

[hf:Qwen/Qwen3-30B-A3B family scaled per assignment; hf-verified tier]
"""
from .base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    num_layers=94, d_model=4096, num_heads=64, num_kv_heads=4, head_dim=128,
    d_ff=1536, moe_d_ff=1536, vocab_size=151936,
    pattern=(LayerSpec("attn", "moe"),),
    num_experts=128, top_k=8, mlp_act="swiglu", qk_norm=True, rope_theta=1e6,
)
