"""InternVL2-76B backbone (InternLM2/llama-arch 80L LM) + stub ViT frontend.

[arXiv:2404.16821; unverified] input_specs() provides (B, 256, d) patch
embeddings prepended to token embeddings.
"""
from .base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b", family="vlm",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=28672, vocab_size=128256, num_patches=256,
    pattern=(LayerSpec("attn", "dense"),),
    mlp_act="swiglu", rope_theta=5e5,
)
