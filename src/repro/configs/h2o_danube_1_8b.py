"""h2o-danube-1.8B — 24L, d2560, 32H GQA(kv=8), sliding-window attention.

[arXiv:2401.16818; hf] SWA window 4096 => the only dense arch eligible for
the long_500k cell (cache is window-sized).
"""
from .base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b", family="dense",
    num_layers=24, d_model=2560, num_heads=32, num_kv_heads=8, head_dim=80,
    d_ff=6912, vocab_size=32000, window=4096,
    pattern=(LayerSpec("attn", "dense"),),
    mlp_act="swiglu", rope_theta=1e4,
)
