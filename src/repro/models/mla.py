"""Multi-head Latent Attention (DeepSeek-V2) — compressed-KV attention.

Prefill/train: the latent c_kv (kv_lora_rank + rope dims per token) is
up-projected to per-head K/V and attention runs through the normal flash path.
Decode: only the latent is cached — (kv_lora + rope_dim) floats per token
instead of 2*Hkv*hd — and scores are computed with the absorbed-matmul trick
(q_nope absorbed through W_uk so the cache is consumed directly).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..kernels import ops
from .layers import dense_init, dtype_of, rms_norm, rmsnorm_init, rope

__all__ = ["mla_init", "mla_apply", "mla_decode"]


def mla_init(key, cfg: ModelConfig):
    d, h = cfg.d_model, cfg.num_heads
    nope, rd, vd, lora = (cfg.qk_nope_head_dim, cfg.qk_rope_head_dim,
                          cfg.v_head_dim, cfg.kv_lora_rank)
    dt = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 5)
    return {
        "wq": dense_init(ks[0], (d, h * (nope + rd)), dt),
        "w_dkv": dense_init(ks[1], (d, lora + rd), dt),   # down-proj + shared rope key
        "kv_norm": rmsnorm_init(lora, dt),
        "w_uk": dense_init(ks[2], (lora, h * nope), dt),  # latent -> K(nope)
        "w_uv": dense_init(ks[3], (lora, h * vd), dt),    # latent -> V
        "wo": dense_init(ks[4], (h * vd, d), dt),
    }


def _latent(p, x, cfg: ModelConfig, positions):
    """c_kv: (B,S,lora) normalized latent; k_rope: (B,S,1,rd) shared across heads."""
    lora, rd = cfg.kv_lora_rank, cfg.qk_rope_head_dim
    ckv = x @ p["w_dkv"]
    c, k_rope = ckv[..., :lora], ckv[..., lora:]
    c = rms_norm(c, p["kv_norm"], cfg.norm_eps)
    k_rope = rope(k_rope[..., None, :], positions, cfg.rope_theta)
    return c, k_rope


def _queries(p, x, cfg: ModelConfig, positions):
    B, S, _ = x.shape
    h, nope, rd = cfg.num_heads, cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    q = (x @ p["wq"]).reshape(B, S, h, nope + rd)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_apply(p, x, cfg: ModelConfig, positions):
    """Full-sequence MLA (decompressed path). x: (B,S,d)."""
    B, S, _ = x.shape
    h, nope, rd, vd = (cfg.num_heads, cfg.qk_nope_head_dim,
                       cfg.qk_rope_head_dim, cfg.v_head_dim)
    q_nope, q_rope = _queries(p, x, cfg, positions)
    c, k_rope = _latent(p, x, cfg, positions)
    k_nope = (c @ p["w_uk"]).reshape(B, S, h, nope)
    v = (c @ p["w_uv"]).reshape(B, S, h, vd)
    q = jnp.concatenate([q_nope, q_rope], -1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (B, S, h, rd))], -1)
    out = ops.attention(
        q.swapaxes(1, 2), k.swapaxes(1, 2), v.swapaxes(1, 2),
        causal=True, sm_scale=(nope + rd) ** -0.5, impl=cfg.attention_impl,
    ).swapaxes(1, 2).reshape(B, S, h * vd)
    return out @ p["wo"], (c, k_rope[:, :, 0, :])  # latents for cache


def mla_decode(p, x, cfg: ModelConfig, c_cache, rope_cache, slot_pos, pos):
    """One-token decode against the latent cache (absorbed matmuls).

    x: (B,1,d); c_cache: (B,S,lora); rope_cache: (B,S,rd); slot_pos: (S,).
    score_s = q_nope^T (W_uk c_s) + q_rope^T k_rope_s
            = (q_nope W_uk^T)·c_s + q_rope·k_rope_s   <- absorbed form
    """
    B = x.shape[0]
    h, nope, rd, vd = (cfg.num_heads, cfg.qk_nope_head_dim,
                       cfg.qk_rope_head_dim, cfg.v_head_dim)
    lora = cfg.kv_lora_rank
    q_nope, q_rope = _queries(p, x, cfg, jnp.full((B, 1), pos))
    q_nope, q_rope = q_nope[:, 0], q_rope[:, 0]               # (B,h,*)
    # absorb: (B,h,nope) @ (lora, h*nope) -> (B,h,lora)
    w_uk = p["w_uk"].reshape(lora, h, nope)
    q_abs = jnp.einsum("bhn,lhn->bhl", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))
    s = (jnp.einsum("bhl,bsl->bhs", q_abs, c_cache.astype(jnp.float32))
         + jnp.einsum("bhr,bsr->bhs", q_rope.astype(jnp.float32),
                      rope_cache.astype(jnp.float32))) * ((nope + rd) ** -0.5)
    valid = (slot_pos >= 0) & (slot_pos <= pos)
    s = jnp.where(valid[None, None, :], s, -jnp.inf)
    probs = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhs,bsl->bhl", probs, c_cache.astype(jnp.float32))  # (B,h,lora)
    w_uv = p["w_uv"].reshape(lora, h, vd)
    o = jnp.einsum("bhl,lhv->bhv", ctx, w_uv.astype(jnp.float32))
    return (o.reshape(B, 1, h * vd).astype(x.dtype) @ p["wo"])
