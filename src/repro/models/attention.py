"""GQA attention: init, full-sequence apply (train/prefill), decode step.

Full-sequence attention dispatches through kernels.ops (XLA ref path on CPU,
Pallas flash kernel on TPU). The decode step is a matvec per head; when the
KV cache's sequence axis is sharded (long-context decode) the step runs a
shard_map flash-decode: each shard computes partial attention over its cache
chunk and the shards combine with a log-sum-exp psum — no 500k all-gather.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..compat import shard_map
from ..configs.base import ModelConfig
from ..kernels import ops
from .layers import dense_init, dtype_of, rms_norm, rmsnorm_init, rope

__all__ = ["attn_init", "attn_apply", "attn_decode", "sharded_lse_decode"]


def attn_init(key, cfg: ModelConfig):
    d, hq, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dt = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, hq * hd), dt),
        "wk": dense_init(ks[1], (d, hkv * hd), dt),
        "wv": dense_init(ks[2], (d, hkv * hd), dt),
        "wo": dense_init(ks[3], (hq * hd, d), dt),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(hd, dt)
        p["k_norm"] = rmsnorm_init(hd, dt)
    return p


def _project_qkv(p, x, cfg: ModelConfig, positions):
    B, S, _ = x.shape
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, S, hq, hd)
    k = (x @ p["wk"]).reshape(B, S, hkv, hd)
    v = (x @ p["wv"]).reshape(B, S, hkv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_apply(p, x, cfg: ModelConfig, positions, *, causal: bool = True,
               kv_override: Optional[Tuple[jax.Array, jax.Array]] = None,
               return_kv: bool = False):
    """Full-sequence attention. x: (B, S, d). kv_override supplies cross-attn
    K/V (already headed, (B, Skv, Hkv, hd)); return_kv exposes K/V for caching."""
    B, S, _ = x.shape
    if kv_override is None:
        q, k, v = _project_qkv(p, x, cfg, positions)
    else:
        hq, hd = cfg.num_heads, cfg.head_dim
        q = (x @ p["wq"]).reshape(B, S, hq, hd)
        if cfg.qk_norm:
            q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        q = rope(q, positions, cfg.rope_theta)
        k, v = kv_override
    # kernels expect (B, H, S, D)
    out = ops.attention(
        q.swapaxes(1, 2), k.swapaxes(1, 2), v.swapaxes(1, 2),
        causal=causal, window=cfg.window, impl=cfg.attention_impl,
    ).swapaxes(1, 2).reshape(B, S, cfg.num_heads * cfg.head_dim)
    y = out @ p["wo"]
    if return_kv:
        return y, (k, v)
    return y


def attn_decode(p, x, cfg: ModelConfig, k_cache, v_cache, slot_pos, pos, *,
                seq_shard_axes: Optional[Tuple[str, ...]] = None,
                mesh=None, manual_extra: Tuple[str, ...] = ()):
    """One-token decode. x: (B, 1, d); caches: (B, Hkv, S, hd) with the new
    token already inserted; slot_pos: (S,) absolute position per slot (< 0 =
    empty); pos: scalar current position. Returns (B, 1, d)."""
    B = x.shape[0]
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, 1, hq, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
    q = rope(q, jnp.full((B, 1), pos), cfg.rope_theta)[:, 0]  # (B, Hq, hd)

    valid = slot_pos >= 0
    valid &= slot_pos <= pos
    if cfg.window is not None:
        valid &= slot_pos > pos - cfg.window

    if seq_shard_axes and mesh is not None:
        y = sharded_lse_decode(q, k_cache, v_cache, valid, hq // hkv,
                               axes=seq_shard_axes, mesh=mesh,
                               extra_manual=manual_extra)
    elif cfg.attention_impl != "xla":
        # Pallas flash-decode: streams the cache through VMEM once instead of
        # materializing the score chain (EXPERIMENTS §Perf D2)
        y = ops.decode_attention(
            q.reshape(B, hkv, hq // hkv, hd), k_cache, v_cache, valid,
            impl=cfg.attention_impl).reshape(B, hq, hd)
    else:
        y = _local_decode(q, k_cache, v_cache, valid, hq // hkv)
    return (y.reshape(B, 1, hq * hd) @ p["wo"])


def _local_decode(q, k_cache, v_cache, valid, group):
    """q: (B,Hq,hd); caches: (B,Hkv,S,hd); valid: (S,). -> (B,Hq,hd).

    The cache is consumed in its stored dtype with f32 accumulation inside
    the dot (preferred_element_type) — an explicit .astype(f32) materializes
    a full f32 copy of the cache per layer and doubles decode HBM traffic
    (EXPERIMENTS §Perf, decode iteration 1)."""
    B, Hq, hd = q.shape
    Hkv = k_cache.shape[1]
    qg = q.reshape(B, Hkv, group, hd)
    s = jnp.einsum("bkgd,bksd->bkgs", qg, k_cache,
                   preferred_element_type=jnp.float32) * (hd ** -0.5)
    s = jnp.where(valid[None, None, None, :], s, -jnp.inf)
    p_ = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bksd->bkgd", p_.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, Hq, hd).astype(q.dtype)


def sharded_lse_decode(q, k_cache, v_cache, valid, group, *, axes, mesh,
                       extra_manual=()):
    """Flash-decode over a sequence-sharded KV cache.

    Each shard attends over its local cache chunk, then shards combine with a
    max/psum log-sum-exp reduction — collective volume is O(B*Hq*hd) per step
    instead of O(S) for an all-gathered cache.

    extra_manual: additional mesh axes to mark manual (replicated here) —
    leaving an axis auto inside this region trips an XLA partitioner CHECK.
    """
    seq_spec = P(None, None, axes, None)

    def local(qb, kb, vb, validb):
        B, Hq, hd = qb.shape
        Hkv = kb.shape[1]
        qg = qb.reshape(B, Hkv, group, hd)
        s = jnp.einsum("bkgd,bksd->bkgs", qg.astype(jnp.float32),
                       kb.astype(jnp.float32)) * (hd ** -0.5)
        s = jnp.where(validb[None, None, None, :], s, -jnp.inf)
        m = jnp.max(s, axis=-1, keepdims=True)                      # local max
        m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
        p_ = jnp.where(jnp.isfinite(s), jnp.exp(s - m_safe), 0.0)
        l = jnp.sum(p_, axis=-1, keepdims=True)
        o = jnp.einsum("bkgs,bksd->bkgd", p_, vb.astype(jnp.float32))
        g = jax.lax.pmax(m_safe, axes)                              # global max
        scale = jnp.where(l > 0, jnp.exp(m_safe - g), 0.0)          # (B,K,G,1)
        l_g = jax.lax.psum(l * scale, axes)
        o_g = jax.lax.psum(o * scale, axes)                         # bcast on d
        o_g = o_g / jnp.maximum(l_g, 1e-30)
        return o_g.reshape(B, Hq, hd).astype(qb.dtype)

    manual = (set(axes) if not isinstance(axes, str) else {axes})
    manual |= set(extra_manual)
    return shard_map(
        local, mesh=mesh,
        in_specs=(P(None, None, None), seq_spec, seq_spec, P(axes)),
        out_specs=P(None, None, None),
        axis_names=manual,
        check_vma=False,
    )(q, k_cache, v_cache, valid)
