"""Whisper-style encoder-decoder backbone (conv/mel frontend is a STUB).

Per the assignment, ``input_specs()`` supplies precomputed frame embeddings
(B, encoder_seq, d_model) — the conv1d+mel frontend is out of scope. The
backbone is faithful in shape: bidirectional encoder, causal decoder with
cross-attention every layer. Positional encoding is sinusoidal for both
stacks (simplification vs whisper's learned decoder embeddings — documented
in DESIGN.md; learned tables would pin max decode length below the assigned
32k shape cell).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import attention as attn
from .layers import (dtype_of, embed_init, embed_lookup, lm_head,
                     mlp_apply, mlp_init, rms_norm, rmsnorm_init)
from .transformer import ShardCtx, _place_seq, _prefill_slot_pos

__all__ = ["EncDec"]


def sinusoid(S: int, d: int, dtype):
    pos = jnp.arange(S)[:, None].astype(jnp.float32)
    dim = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    ang = pos / (10000.0 ** (2 * dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1).astype(dtype)


class EncDec:
    """Encoder-decoder LM (whisper-large-v3 backbone)."""

    def __init__(self, cfg: ModelConfig, ctx: Optional[ShardCtx] = None):
        self.cfg = cfg
        self.ctx = ctx or ShardCtx()

    # --------------------------------------------------------------- init
    def _enc_block_init(self, key):
        cfg = self.cfg
        ks = jax.random.split(key, 2)
        dt = dtype_of(cfg.param_dtype)
        return {"ln1": rmsnorm_init(cfg.d_model, dt),
                "mixer": attn.attn_init(ks[0], cfg),
                "ln2": rmsnorm_init(cfg.d_model, dt),
                "mlp": mlp_init(ks[1], cfg)}

    def _dec_block_init(self, key):
        cfg = self.cfg
        ks = jax.random.split(key, 3)
        dt = dtype_of(cfg.param_dtype)
        return {"ln1": rmsnorm_init(cfg.d_model, dt),
                "self": attn.attn_init(ks[0], cfg),
                "ln_x": rmsnorm_init(cfg.d_model, dt),
                "cross": attn.attn_init(ks[1], cfg),
                "ln2": rmsnorm_init(cfg.d_model, dt),
                "mlp": mlp_init(ks[2], cfg)}

    def init(self, key) -> dict:
        cfg = self.cfg
        kE, ke, kd = jax.random.split(key, 3)
        enc_keys = jax.random.split(ke, cfg.num_encoder_layers)
        dec_keys = jax.random.split(kd, cfg.num_layers)
        dt = dtype_of(cfg.param_dtype)
        return {
            "embed": embed_init(kE, cfg),
            "enc_blocks": jax.vmap(self._enc_block_init)(enc_keys),
            "dec_blocks": jax.vmap(self._dec_block_init)(dec_keys),
            "enc_norm": rmsnorm_init(cfg.d_model, dt),
            "final_norm": rmsnorm_init(cfg.d_model, dt),
        }

    # --------------------------------------------------------------- encode
    def encode(self, params, frames):
        """frames: (B, F, d) precomputed embeddings (stub frontend)."""
        cfg, ctx = self.cfg, self.ctx
        B, F, d = frames.shape
        x = frames.astype(dtype_of(cfg.activation_dtype)) + sinusoid(F, d, frames.dtype)
        x = ctx.hidden(x)
        positions = jnp.broadcast_to(jnp.arange(F), (B, F))

        def unit(x, p):
            h = rms_norm(x, p["ln1"], cfg.norm_eps)
            x = x + attn.attn_apply(p["mixer"], h, cfg, positions, causal=False)
            h = rms_norm(x, p["ln2"], cfg.norm_eps)
            x = ctx.hidden(x + mlp_apply(p["mlp"], h, cfg.mlp_act))
            return x, None

        body = jax.checkpoint(lambda x, p: unit(x, p)) if cfg.remat else unit
        x, _ = jax.lax.scan(body, x, params["enc_blocks"])
        return rms_norm(x, params["enc_norm"], cfg.norm_eps)

    def _cross_kv(self, p_cross, enc_out):
        cfg = self.cfg
        B, F, _ = enc_out.shape
        hkv, hd = cfg.num_kv_heads, cfg.head_dim
        k = (enc_out @ p_cross["wk"]).reshape(B, F, hkv, hd)
        v = (enc_out @ p_cross["wv"]).reshape(B, F, hkv, hd)
        return k, v

    def _dec_block(self, p, x, positions, enc_out, collect: bool = False):
        cfg, ctx = self.cfg, self.ctx
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        entry = None
        if collect:
            m, (k, v) = attn.attn_apply(p["self"], h, cfg, positions, return_kv=True)
            entry = {"k": k.swapaxes(1, 2), "v": v.swapaxes(1, 2)}
        else:
            m = attn.attn_apply(p["self"], h, cfg, positions)
        x = x + m
        h = rms_norm(x, p["ln_x"], cfg.norm_eps)
        ck, cv = self._cross_kv(p["cross"], enc_out)
        # cross attention: bidirectional over encoder frames (no rope on kv)
        x = x + attn.attn_apply(p["cross"], h, cfg, positions, causal=False,
                                kv_override=(ck, cv))
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        x = ctx.hidden(x + mlp_apply(p["mlp"], h, cfg.mlp_act))
        return x, entry

    def apply(self, params, tokens, frames):
        """Teacher-forced decode over full target seq. Returns logits."""
        cfg, ctx = self.cfg, self.ctx
        enc_out = self.encode(params, frames)
        B, S = tokens.shape
        x = embed_lookup(params["embed"], tokens, cfg)
        x = x + sinusoid(S, cfg.d_model, x.dtype)
        x = ctx.hidden(x)
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))

        def unit(x, p):
            y, _ = self._dec_block(p, x, positions, enc_out)
            return y, None

        body = jax.checkpoint(lambda x, p: unit(x, p)) if cfg.remat else unit
        x, _ = jax.lax.scan(body, x, params["dec_blocks"])
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = lm_head(params["embed"], x, cfg)
        return ctx.act(logits, ctx.bspec, None, ctx.tp_axis)

    # --------------------------------------------------------------- serving
    def cache_init(self, batch: int, cache_len: int, enc_frames: int, dtype=None):
        cfg = self.cfg
        dt = dtype or dtype_of(cfg.activation_dtype)
        L = cfg.num_layers
        kv = (L, batch, cfg.num_kv_heads, cache_len, cfg.head_dim)
        xkv = (L, batch, cfg.num_kv_heads, enc_frames, cfg.head_dim)
        return {"k": jnp.zeros(kv, dt), "v": jnp.zeros(kv, dt),
                "xk": jnp.zeros(xkv, dt), "xv": jnp.zeros(xkv, dt),
                "slot_pos": jnp.full((cache_len,), -1, jnp.int32),
                "pos": jnp.zeros((), jnp.int32)}

    def prefill(self, params, tokens, frames, cache_len: Optional[int] = None):
        """Encode + teacher-forced pass building self- and cross-KV caches."""
        cfg, ctx = self.cfg, self.ctx
        enc_out = self.encode(params, frames)
        B, S = tokens.shape
        cache_len = cache_len or S
        x = embed_lookup(params["embed"], tokens, cfg)
        x = x + sinusoid(S, cfg.d_model, x.dtype)
        x = ctx.hidden(x)
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))

        def body(x, p):
            y, entry = self._dec_block(p, x, positions, enc_out, collect=True)
            ck, cv = self._cross_kv(p["cross"], enc_out)
            return y, {**entry, "xk": ck.swapaxes(1, 2), "xv": cv.swapaxes(1, 2)}

        x, entries = jax.lax.scan(body, x, params["dec_blocks"])
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = lm_head(params["embed"], x, cfg)
        cache = {"k": _place_seq(entries["k"], cache_len, 3),
                 "v": _place_seq(entries["v"], cache_len, 3),
                 "xk": entries["xk"], "xv": entries["xv"],
                 "slot_pos": _prefill_slot_pos(S, cache_len),
                 "pos": jnp.asarray(S, jnp.int32)}
        return ctx.act(logits, ctx.bspec, None, ctx.tp_axis), cache

    def decode_step(self, params, cache, tokens):
        """tokens: (B,1). Cross-KV comes from the cache (computed at prefill)."""
        cfg, ctx = self.cfg, self.ctx
        pos = cache["pos"]
        cache_len = cache["slot_pos"].shape[0]
        slot = jnp.minimum(pos, cache_len - 1).astype(jnp.int32)
        slot_pos = jax.lax.dynamic_update_slice(
            cache["slot_pos"], pos[None].astype(jnp.int32), (slot,))
        B = tokens.shape[0]
        x = embed_lookup(params["embed"], tokens, cfg)
        x = x + jax.lax.dynamic_slice_in_dim(
            sinusoid(cache_len, cfg.d_model, x.dtype), slot, 1, 0)[None]

        def body(x, pcs):
            p, kc_all, vc_all, xk, xv = pcs
            h = rms_norm(x, p["ln1"], cfg.norm_eps)
            hkv, hd = cfg.num_kv_heads, cfg.head_dim
            k_new = (h @ p["self"]["wk"]).reshape(B, 1, hkv, hd)
            v_new = (h @ p["self"]["wv"]).reshape(B, 1, hkv, hd)
            from .layers import rope as _rope
            k_new = _rope(k_new, jnp.full((B, 1), pos), cfg.rope_theta)
            kc = jax.lax.dynamic_update_slice(kc_all, k_new.swapaxes(1, 2).astype(kc_all.dtype), (0, 0, slot, 0))
            vc = jax.lax.dynamic_update_slice(vc_all, v_new.swapaxes(1, 2).astype(vc_all.dtype), (0, 0, slot, 0))
            x = x + attn.attn_decode(p["self"], h, cfg, kc, vc, slot_pos, pos)
            # cross attention against precomputed frames (all valid)
            h = rms_norm(x, p["ln_x"], cfg.norm_eps)
            xvalid = jnp.zeros((xk.shape[2],), jnp.int32)  # slot_pos=0 -> all valid
            x = x + attn.attn_decode(p["cross"], h, cfg, xk, xv, xvalid, pos)
            h = rms_norm(x, p["ln2"], cfg.norm_eps)
            x = x + mlp_apply(p["mlp"], h, cfg.mlp_act)
            return x, (kc, vc)

        x, (k_new, v_new) = jax.lax.scan(
            body, x, (params["dec_blocks"], cache["k"], cache["v"],
                      cache["xk"], cache["xv"]))
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = lm_head(params["embed"], x, cfg)
        new_cache = {**cache, "k": k_new, "v": v_new, "slot_pos": slot_pos,
                     "pos": pos + 1}
        return ctx.act(logits, ctx.bspec, None, ctx.tp_axis), new_cache
