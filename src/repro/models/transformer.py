"""Config-driven decoder LM covering dense / MoE / MLA / SSM / hybrid stacks.

The layer stack is ``num_repeats`` copies of ``cfg.pattern`` (a tuple of
LayerSpec). Per-pattern-position parameters are stacked over repeats and the
stack runs under ``jax.lax.scan`` — one pattern unit in the HLO regardless of
depth, which keeps the 40-cell x 2-mesh dry-run compile matrix tractable and
is the production choice anyway (layer-stacked weights = clean FSDP).

Sharding is injected via ShardCtx: activation constraints at block boundaries,
shard_map MoE over the TP axis, optional sequence-sharded flash-decode.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import LayerSpec, ModelConfig
from . import attention as attn
from . import mla as mla_mod
from . import moe as moe_mod
from . import ssm as ssm_mod
from .layers import (dtype_of, embed_init, embed_lookup, lm_head, mlp_apply,
                     mlp_init, rms_norm, rmsnorm_init, rope)

__all__ = ["ShardCtx", "LM"]


@dataclass(frozen=True)
class ShardCtx:
    """Static sharding context threaded through model code (None = local)."""

    mesh: Any = None
    batch_axes: Tuple[str, ...] = ()
    tp_axis: Optional[str] = "model"
    fsdp_axis: Optional[str] = "data"
    decode_seq_axes: Optional[Tuple[str, ...]] = None  # seq-sharded KV decode
    seq_axis: Optional[str] = None  # Megatron-style sequence parallelism on
    # the residual stream: hidden (B, S, d) sharded on S over this axis between
    # blocks (activation memory / collective-layout optimization, §Perf).
    manual_extra: Tuple[str, ...] = ()  # mesh axes to absorb (replicated) into
    # manual shard_map regions — an axis left auto inside one trips an XLA
    # 0.8.2 partitioner CHECK. The dry-run passes every non-TP/FSDP axis.

    def act(self, x, *spec):
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, P(*spec)))

    def hidden(self, x):
        """Sharding constraint for the (B, S, d) residual stream."""
        return self.act(x, self.bspec, self.seq_axis, None)

    @property
    def bspec(self):
        return self.batch_axes if self.batch_axes else None


def _place_seq(entry, cache_len: int, seq_axis: int):
    """Place a length-S prefill tensor into a cache_len ring buffer along
    ``seq_axis`` (keeps the last cache_len positions, ring-rotated so that
    position p sits at slot p % cache_len)."""
    S = entry.shape[seq_axis]
    if S == cache_len:
        return entry
    if S < cache_len:
        pad_shape = list(entry.shape)
        pad_shape[seq_axis] = cache_len - S
        return jnp.concatenate([entry, jnp.zeros(pad_shape, entry.dtype)], seq_axis)
    tail = jax.lax.slice_in_dim(entry, S - cache_len, S, axis=seq_axis)
    return jnp.roll(tail, shift=(S - cache_len) % cache_len, axis=seq_axis)


def _prefill_slot_pos(S: int, cache_len: int):
    if S >= cache_len:
        idx = jnp.arange(S - cache_len, S)
        return jnp.zeros((cache_len,), jnp.int32).at[idx % cache_len].set(idx)
    return jnp.where(jnp.arange(cache_len) < S, jnp.arange(cache_len), -1).astype(jnp.int32)


class LM:
    """Decoder-only LM (also the backbone for the VLM wrapper)."""

    def __init__(self, cfg: ModelConfig, ctx: Optional[ShardCtx] = None):
        self.cfg = cfg
        self.ctx = ctx or ShardCtx()

    # ------------------------------------------------------------- init
    def _block_init(self, key, spec: LayerSpec):
        cfg = self.cfg
        ks = jax.random.split(key, 2)
        dt = dtype_of(cfg.param_dtype)
        p = {"ln1": rmsnorm_init(cfg.d_model, dt)}
        if spec.mixer == "attn":
            p["mixer"] = attn.attn_init(ks[0], cfg)
        elif spec.mixer == "mla":
            p["mixer"] = mla_mod.mla_init(ks[0], cfg)
        elif spec.mixer == "mamba":
            p["mixer"] = ssm_mod.mamba_init(ks[0], cfg)
        else:
            raise ValueError(spec.mixer)
        if spec.mlp == "dense":
            p["ln2"] = rmsnorm_init(cfg.d_model, dt)
            p["mlp"] = mlp_init(ks[1], cfg)
        elif spec.mlp == "moe":
            p["ln2"] = rmsnorm_init(cfg.d_model, dt)
            p["mlp"] = moe_mod.moe_init(ks[1], cfg)
        return p

    def init(self, key) -> dict:
        cfg = self.cfg
        kE, kF, kB = jax.random.split(key, 3)
        params = {"embed": embed_init(kE, cfg),
                  "final_norm": rmsnorm_init(cfg.d_model, dtype_of(cfg.param_dtype))}
        if cfg.first_layer_dense:
            spec0 = LayerSpec(cfg.pattern[0].mixer, "dense")
            params["first"] = self._block_init(kF, spec0)
        blocks = {}
        for i, spec in enumerate(cfg.pattern):
            keys = jax.random.split(jax.random.fold_in(kB, i), cfg.num_repeats)
            blocks[f"pos{i}"] = jax.vmap(lambda k, s=spec: self._block_init(k, s))(keys)
        params["blocks"] = blocks
        return params

    # ------------------------------------------------------------- forward
    def _mlp_part(self, p, x, spec: LayerSpec):
        cfg, ctx = self.cfg, self.ctx
        if spec.mlp == "none":
            return x
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        if spec.mlp == "dense":
            o = mlp_apply(p["mlp"], h2, cfg.mlp_act)
        else:
            o = moe_mod.moe_apply(p["mlp"], h2, cfg, ctx.mesh,
                                  tp_axis=ctx.tp_axis, fsdp_axis=ctx.fsdp_axis,
                                  batch_axes=ctx.batch_axes,
                                  manual_extra=ctx.manual_extra)
        return ctx.hidden(x + o)

    def _block_apply(self, p, x, spec: LayerSpec, positions, collect: bool = False):
        cfg, ctx = self.cfg, self.ctx
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        entry = None
        if spec.mixer == "attn":
            if collect:
                m, (k, v) = attn.attn_apply(p["mixer"], h, cfg, positions,
                                            return_kv=True)
                entry = {"k": k.swapaxes(1, 2), "v": v.swapaxes(1, 2)}
            else:
                m = attn.attn_apply(p["mixer"], h, cfg, positions)
        elif spec.mixer == "mla":
            m, (c, kr) = mla_mod.mla_apply(p["mixer"], h, cfg, positions)
            if collect:
                entry = {"c": c, "rope": kr}
        else:
            if collect:
                m, (ssm_s, conv_s) = ssm_mod.mamba_apply(p["mixer"], h, cfg,
                                                         return_state=True)
                entry = {"ssm": ssm_s, "conv": conv_s}
            else:
                m = ssm_mod.mamba_apply(p["mixer"], h, cfg)
        x = ctx.hidden(x + m)
        x = self._mlp_part(p, x, spec)
        return x, entry

    def _stack_apply(self, params, x, positions, collect: bool = False):
        cfg = self.cfg
        first_entry = None
        if cfg.first_layer_dense:
            spec0 = LayerSpec(cfg.pattern[0].mixer, "dense")
            x, first_entry = self._block_apply(params["first"], x, spec0,
                                               positions, collect)

        def unit(x, slices):
            entries = {}
            for i, spec in enumerate(cfg.pattern):
                x, e = self._block_apply(slices[f"pos{i}"], x, spec, positions,
                                         collect)
                if collect:
                    entries[f"pos{i}"] = e
            return x, entries

        if cfg.remat:
            policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                      if cfg.remat_policy == "dots" else None)
            body = jax.checkpoint(unit, policy=policy)
        else:
            body = unit

        def scan_body(x, slices):
            return body(x, slices)

        x, entries = jax.lax.scan(scan_body, x, params["blocks"])
        return x, (entries if collect else None), first_entry

    def apply(self, params, tokens, *, extra_embeds=None):
        """tokens: (B, S_text) -> logits (B, S, padded_vocab).

        extra_embeds: (B, Np, d) prepended patch/frame embeddings (VLM stub).
        """
        cfg, ctx = self.cfg, self.ctx
        x = embed_lookup(params["embed"], tokens, cfg)
        if extra_embeds is not None:
            x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
        B, S, _ = x.shape
        x = ctx.hidden(x)
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        x, _, _ = self._stack_apply(params, x, positions)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = lm_head(params["embed"], x, cfg)
        return ctx.act(logits, ctx.bspec, None, ctx.tp_axis)

    # ------------------------------------------------------------- serving
    def cache_init(self, batch: int, cache_len: int, dtype=None) -> dict:
        """Empty cache sized for ``cache_len`` slots (SWA archs: pass window)."""
        cfg = self.cfg
        dt = dtype or dtype_of(cfg.activation_dtype)
        R = cfg.num_repeats

        def one(spec: LayerSpec, stacked: bool):
            lead = (R,) if stacked else ()
            if spec.mixer == "attn":
                kv = (*lead, batch, cfg.num_kv_heads, cache_len, cfg.head_dim)
                return {"k": jnp.zeros(kv, dt), "v": jnp.zeros(kv, dt)}
            if spec.mixer == "mla":
                return {"c": jnp.zeros((*lead, batch, cache_len, cfg.kv_lora_rank), dt),
                        "rope": jnp.zeros((*lead, batch, cache_len, cfg.qk_rope_head_dim), dt)}
            ssm = (*lead, batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state)
            conv = (*lead, batch, cfg.ssm_conv_width - 1, cfg.ssm_inner)
            return {"ssm": jnp.zeros(ssm, jnp.float32), "conv": jnp.zeros(conv, dt)}

        cache = {"blocks": {f"pos{i}": one(s, True) for i, s in enumerate(cfg.pattern)},
                 "slot_pos": jnp.full((cache_len,), -1, jnp.int32),
                 "pos": jnp.zeros((), jnp.int32)}
        if cfg.first_layer_dense:
            cache["first"] = one(LayerSpec(cfg.pattern[0].mixer, "dense"), False)
        return cache

    def _block_decode(self, p, c, x, spec: LayerSpec, slot_pos, pos, slot):
        cfg, ctx = self.cfg, self.ctx
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        if spec.mixer == "attn":
            B = x.shape[0]
            hkv, hd = cfg.num_kv_heads, cfg.head_dim
            k_new = (h @ p["mixer"]["wk"]).reshape(B, 1, hkv, hd)
            v_new = (h @ p["mixer"]["wv"]).reshape(B, 1, hkv, hd)
            if cfg.qk_norm:
                k_new = rms_norm(k_new, p["mixer"]["k_norm"], cfg.norm_eps)
            k_new = rope(k_new, jnp.full((B, 1), pos), cfg.rope_theta)
            kc = jax.lax.dynamic_update_slice(
                c["k"], k_new.swapaxes(1, 2).astype(c["k"].dtype), (0, 0, slot, 0))
            vc = jax.lax.dynamic_update_slice(
                c["v"], v_new.swapaxes(1, 2).astype(c["v"].dtype), (0, 0, slot, 0))
            m = attn.attn_decode(p["mixer"], h, cfg, kc, vc, slot_pos, pos,
                                 seq_shard_axes=ctx.decode_seq_axes, mesh=ctx.mesh,
                                 manual_extra=ctx.manual_extra)
            c = {"k": kc, "v": vc}
        elif spec.mixer == "mla":
            cl, kr = mla_mod._latent(p["mixer"], h, cfg, jnp.full((x.shape[0], 1), pos))
            cc = jax.lax.dynamic_update_slice(
                c["c"], cl.astype(c["c"].dtype), (0, slot, 0))
            rc = jax.lax.dynamic_update_slice(
                c["rope"], kr[:, :, 0, :].astype(c["rope"].dtype), (0, slot, 0))
            m = mla_mod.mla_decode(p["mixer"], h, cfg, cc, rc, slot_pos, pos)
            c = {"c": cc, "rope": rc}
        else:
            m, (s_new, cv_new) = ssm_mod.mamba_decode(p["mixer"], h, cfg,
                                                      c["ssm"], c["conv"])
            c = {"ssm": s_new, "conv": cv_new}
        x = x + m
        x = self._mlp_part(p, x, spec)
        return x, c

    def decode_step(self, params, cache, tokens):
        """One decode step. tokens: (B, 1). Returns (logits (B,1,V), cache)."""
        cfg, ctx = self.cfg, self.ctx
        pos = cache["pos"]
        cache_len = cache["slot_pos"].shape[0]
        if cfg.window is not None:
            slot = (pos % cache_len).astype(jnp.int32)   # SWA ring buffer
        else:
            # full attention: append (caller sizes the cache; clamp is a guard)
            slot = jnp.minimum(pos, cache_len - 1).astype(jnp.int32)
        slot_pos = jax.lax.dynamic_update_slice(
            cache["slot_pos"], pos[None].astype(jnp.int32), (slot,))

        x = embed_lookup(params["embed"], tokens, cfg)
        x = ctx.hidden(x)

        c0 = None
        if cfg.first_layer_dense:
            spec0 = LayerSpec(cfg.pattern[0].mixer, "dense")
            x, c0 = self._block_decode(params["first"], cache["first"], x,
                                       spec0, slot_pos, pos, slot)

        def scan_body(x, pc):
            p_slice, c_slice = pc
            new_c = {}
            for i, spec in enumerate(cfg.pattern):
                x, nc = self._block_decode(p_slice[f"pos{i}"], c_slice[f"pos{i}"],
                                           x, spec, slot_pos, pos, slot)
                new_c[f"pos{i}"] = nc
            return x, new_c

        x, new_blocks = jax.lax.scan(scan_body, x, (params["blocks"], cache["blocks"]))
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = lm_head(params["embed"], x, cfg)
        new_cache = {"blocks": new_blocks, "slot_pos": slot_pos, "pos": pos + 1}
        if cfg.first_layer_dense:
            new_cache["first"] = c0
        return ctx.act(logits, ctx.bspec, None, ctx.tp_axis), new_cache

    def prefill(self, params, tokens, cache_len: Optional[int] = None, *,
                extra_embeds=None):
        """Forward pass that also builds a decode-ready cache in one shot
        (per-layer K/V collected inside the same scan — no token replay)."""
        cfg = self.cfg
        B = tokens.shape[0]
        S = tokens.shape[1] + (extra_embeds.shape[1] if extra_embeds is not None else 0)
        cache_len = cache_len or S

        x = embed_lookup(params["embed"], tokens, cfg)
        if extra_embeds is not None:
            x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
        x = self.ctx.act(x, self.ctx.bspec, None, None)
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        x, entries, first_entry = self._stack_apply(params, x, positions, collect=True)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = lm_head(params["embed"], x, cfg)
        logits = self.ctx.act(logits, self.ctx.bspec, None, self.ctx.tp_axis)

        def to_cache(entry, stacked: bool):
            if entry is None:
                return None
            off = 1 if stacked else 0
            if "k" in entry:  # attn: (R?, B, Hkv, S, hd) -> ring
                return {k: _place_seq(vv, cache_len, 2 + off) for k, vv in entry.items()}
            if "c" in entry:  # mla: (R?, B, S, lora)
                return {k: _place_seq(vv, cache_len, 1 + off) for k, vv in entry.items()}
            return entry      # mamba states need no seq placement

        cache = {"blocks": {k: to_cache(v, True) for k, v in (entries or {}).items()},
                 "slot_pos": _prefill_slot_pos(S, cache_len),
                 "pos": jnp.asarray(S, jnp.int32)}
        if cfg.first_layer_dense:
            cache["first"] = to_cache(first_entry, False)
        return logits, cache
