"""Top-k MoE with expert parallelism folded into the tensor-parallel axis.

Design (TPU adaptation — see DESIGN.md §4): at the MoE block input the
activations are replicated across the "model" (TP) axis, as in any Megatron-
style block. Each TP rank owns E/tp experts. Because every rank already holds
every local token, expert *dispatch is a local gather* (no all-to-all): each
rank selects the (token, expert) copies routed to its own experts into a
capacity-bounded (E_local, C, d) buffer, runs its experts' FFNs, scatters the
weighted results back to token order, and the cross-rank combine rides the
same single psum a dense TP FFN needs. Collective volume per MoE layer is
therefore identical to a dense TP layer — the roofline's collective term sees
no all-to-all by construction.

Expert weight banks are additionally FSDP-sharded over "data"; they are
all-gathered per layer inside the block (standard FSDP prefetch pattern —
under scan-over-layers this is one gather per layer step).

Implemented with shard_map over the "model" axis (and "data"/"pod" mapped for
batch locality); the sort/capacity bookkeeping is plain local jnp, so there
are no GSPMD-propagation surprises to debug across the 40-cell matrix.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..compat import shard_map
from ..configs.base import ModelConfig
from .layers import dense_init, dtype_of

__all__ = ["moe_init", "moe_apply"]


def moe_init(key, cfg: ModelConfig):
    d, e, ff = cfg.d_model, cfg.num_experts, cfg.moe_d_ff or cfg.d_ff
    dt = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    p = {
        "router": dense_init(ks[0], (d, e), jnp.float32),  # router kept f32
        "moe_up": dense_init(ks[1], (e, d, ff), dt),
        "moe_gate": dense_init(ks[2], (e, d, ff), dt),
        "moe_down": dense_init(ks[3], (e, ff, d), dt),
    }
    if cfg.num_shared_experts:
        sf = ff * cfg.num_shared_experts
        p["shared_up"] = dense_init(ks[4], (d, sf), dt)
        p["shared_gate"] = dense_init(ks[5], (d, sf), dt)
        p["shared_down"] = dense_init(jax.random.fold_in(key, 7), (sf, d), dt)
    return p


def _expert_ffn(x, up, gate, down):
    """x: (E_loc, C, d); weights: (E_loc, d, ff) / (E_loc, ff, d)."""
    u = jnp.einsum("ecd,edf->ecf", x, up)
    g = jnp.einsum("ecd,edf->ecf", x, gate)
    h = jax.nn.silu(g) * u
    return jnp.einsum("ecf,efd->ecd", h, down)


def _local_moe(x, router_w, up, gate, down, *, cfg: ModelConfig, tp: int,
               my_rank, fsdp_axis: Optional[str]):
    """Per-device body. x: (T, d) local tokens (replicated over model axis);
    up/gate/down: this rank's expert slab, sharded on d/ff over fsdp_axis.

    Note on the rejected "2D weight sharding" alternative (compute on weight
    shards + psum activation partials, no slab gathers): with tokens sharded
    over the FSDP axis it is incorrect (partials would mix different tokens),
    and with tokens replicated the x-gather + full-width y psum costs more
    wire than the 3 slab gathers it removes (napkin math in EXPERIMENTS
    §Perf). The slab gather is structural at accum>1 under the HBM budget.
    """
    T, d = x.shape
    E, k = cfg.num_experts, cfg.top_k
    e_loc = E // tp
    cap = max(int(T * k * cfg.capacity_factor / E), 1)

    if fsdp_axis is not None:
        # FSDP all-gather of this layer's expert slab
        up = jax.lax.all_gather(up, fsdp_axis, axis=1, tiled=True)
        gate = jax.lax.all_gather(gate, fsdp_axis, axis=1, tiled=True)
        down = jax.lax.all_gather(down, fsdp_axis, axis=2, tiled=True)

    logits = (x.astype(jnp.float32) @ router_w)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)                    # (T, k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)    # renormalize

    flat_e = top_e.reshape(-1)                                # (T*k,)
    flat_w = top_w.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T), k)

    mine = (flat_e // e_loc) == my_rank
    local_e = jnp.where(mine, flat_e - my_rank * e_loc, e_loc)  # e_loc = trash bin
    order = jnp.argsort(local_e, stable=True)
    se, st, sw = local_e[order], flat_t[order], flat_w[order]
    counts = jnp.bincount(se, length=e_loc + 1)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
    pos_in_e = jnp.arange(se.shape[0]) - starts[se]
    keep = (se < e_loc) & (pos_in_e < cap)
    slot = jnp.where(keep, se * cap + pos_in_e, e_loc * cap)   # overflow slot
    nslots = e_loc * cap

    # Dispatch via slot->token indirection: ONE gather of nslots rows (the
    # capacity buffer), never materializing the (T*k, d) duplicated-token
    # matrix. The naive gather-then-scatter formulation moved ~25x more HBM
    # bytes per MoE layer (f32-promoted, T*k rows) — EXPERIMENTS §Perf.
    slot_token = jnp.zeros((nslots + 1,), jnp.int32).at[slot].set(
        st.astype(jnp.int32))
    slot_valid = jnp.zeros((nslots + 1,), jnp.bool_).at[slot].set(keep)
    xbuf = x[slot_token[:-1]] * slot_valid[:-1, None].astype(x.dtype)
    h = _expert_ffn(xbuf.reshape(e_loc, cap, d), up, gate, down)
    h_ext = jnp.concatenate([h.reshape(nslots, d),
                             jnp.zeros((1, d), h.dtype)], 0)  # sentinel row

    # Combine: per-token (T, k) slot matrix -> gather + weighted sum (no
    # scatter-add read-modify-write on a (T, d) f32 buffer).
    slot_of_copy = jnp.full((T * k,), nslots, jnp.int32).at[order].set(
        jnp.where(keep, slot, nslots).astype(jnp.int32))
    w_of_copy = jnp.zeros((T * k,), flat_w.dtype).at[order].set(
        jnp.where(keep, sw, 0.0))
    hk = h_ext[slot_of_copy.reshape(T, k)]                  # (T, k, d)
    y = jnp.einsum("tkd,tk->td", hk,
                   w_of_copy.reshape(T, k).astype(h_ext.dtype))
    return y.astype(x.dtype)  # partial: summed over ranks by the caller's psum


def moe_apply(p, x, cfg: ModelConfig, mesh=None, *, tp_axis: str = "model",
              fsdp_axis: Optional[str] = None, batch_axes=(), manual_extra=()):
    """x: (B, S, d) -> (B, S, d). mesh=None (or tp=1 mesh) runs the same code
    on one shard — identical math, used by CPU smoke tests."""
    B, S, d = x.shape
    xt = x.reshape(B * S, d)

    if mesh is None or tp_axis not in getattr(mesh, "axis_names", ()):
        y = _local_moe(xt, p["router"], p["moe_up"], p["moe_gate"], p["moe_down"],
                       cfg=cfg, tp=1, my_rank=0, fsdp_axis=None)
    else:
        tp = mesh.shape[tp_axis]
        fa = fsdp_axis if (fsdp_axis and mesh.shape.get(fsdp_axis, 1) > 1) else None

        def body(xb, rw, up, gate, down):
            rank = jax.lax.axis_index(tp_axis)
            y = _local_moe(xb, rw, up, gate, down, cfg=cfg, tp=tp, my_rank=rank,
                           fsdp_axis=fa)
            return jax.lax.psum(y, tp_axis)

        # Manual over TP + FSDP + every batch axis the caller exposes: leaving
        # a mesh axis in auto-land inside this region trips an XLA partitioner
        # CHECK ("invalid binary instruction opcode copy"). The partitioned
        # train step passes batch_axes without "pod" (already manual outside).
        espec = P(tp_axis, fa, None)
        dspec = P(tp_axis, None, fa)
        ba = tuple(batch_axes or ())
        manual = {tp_axis} | ({fa} if fa else set()) | set(ba) | set(manual_extra)
        token_axes = ba + ((fa,) if fa and fa not in ba else ())
        prod = 1
        for a in token_axes:
            prod *= mesh.shape[a]
        # tokens sharded over batch/FSDP axes when divisible (training,
        # prefill); tiny decode batches replicate instead (B=1 long-context).
        xspec = (P(token_axes, None) if token_axes and xt.shape[0] % prod == 0
                 else P(None, None))
        y = shard_map(
            body, mesh=mesh,
            in_specs=(xspec, P(None, None), espec, espec, dspec),
            out_specs=xspec,
            axis_names=manual, check_vma=False,
        )(xt, p["router"], p["moe_up"], p["moe_gate"], p["moe_down"])

    if cfg.num_shared_experts:
        u = xt @ p["shared_up"]
        g = xt @ p["shared_gate"]
        y = y + (jax.nn.silu(g) * u) @ p["shared_down"]
    return y.reshape(B, S, d)
