"""Common building blocks: init helpers, norms, RoPE, dense MLPs, embeddings.

Parameters are plain nested dicts of jnp arrays (pytrees). Initializers take a
PRNG key and a ModelConfig; apply functions are pure. Leaf names are load-
bearing: launch/shardings.py maps names -> PartitionSpecs.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..kernels import ops

__all__ = [
    "dense_init", "rmsnorm_init", "rms_norm", "rope", "mlp_init", "mlp_apply",
    "embed_init", "embed_lookup", "lm_head", "dtype_of",
]


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


def dense_init(key, shape, dtype, scale: Optional[float] = None):
    """Truncated-normal fan-in init."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else fan_in ** -0.5
    return (jax.random.truncated_normal(key, -3, 3, shape, jnp.float32) * std).astype(dtype)


def rmsnorm_init(d: int, dtype):
    return jnp.ones((d,), dtype)


def rms_norm(x, w, eps: float = 1e-6, impl: str = "xla"):
    return ops.rmsnorm(x, w, eps=eps, impl=impl)


def rope(x, positions, theta: float):
    """Rotary embedding. x: (..., S, H, D) or (..., S, D); positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # (..., S, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    if x.ndim == cos.ndim + 1:  # head axis present: (..., S, H, D)
        cos, sin = cos[..., None, :], sin[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin],
                           axis=-1).astype(x.dtype)


# ---------------------------------------------------------------- dense MLP
def mlp_init(key, cfg: ModelConfig, d_ff: Optional[int] = None):
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    dt = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[0], (d, ff), dt), "w_down": dense_init(ks[1], (ff, d), dt)}
    if cfg.mlp_act == "swiglu":
        p["w_gate"] = dense_init(ks[2], (d, ff), dt)
    return p


def mlp_apply(p, x, act: str):
    """x: (..., d) -> (..., d)."""
    up = x @ p["w_up"]
    if act == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * up
    elif act == "relu2":
        r = jax.nn.relu(up)
        h = r * r
    elif act == "gelu":
        h = jax.nn.gelu(up)
    else:
        raise ValueError(act)
    return h @ p["w_down"]


# ---------------------------------------------------------------- embeddings
def embed_init(key, cfg: ModelConfig):
    dt = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 2)
    p = {"embedding": dense_init(ks[0], (cfg.padded_vocab, cfg.d_model), dt, scale=1.0)}
    p["head"] = dense_init(ks[1], (cfg.d_model, cfg.padded_vocab), dt)
    return p


def embed_lookup(p, tokens, cfg: ModelConfig):
    """One-hot contraction lookup. With the table sharded on vocab (P("model",
    fsdp)) GSPMD lowers this to a local masked matmul + psum — the one gather
    formulation that partitions robustly across every mesh in the matrix
    (jnp.take trips GSPMD's gather partitioner inside scan bodies). The
    (B, S, V_shard) one-hot is microbatch-bounded: ~hundreds of MB transient
    at the assigned shapes."""
    adt = dtype_of(cfg.activation_dtype)
    onehot = jax.nn.one_hot(tokens, cfg.padded_vocab, dtype=adt)
    return onehot @ p["embedding"].astype(adt)


def lm_head(p, x, cfg: ModelConfig):
    return x @ p["head"].astype(x.dtype)
