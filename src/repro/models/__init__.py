"""Model zoo: config-driven families sharing one substrate.

``build_model(cfg, ctx)`` returns the right wrapper:
  * LM      — decoder-only (dense / moe / mla / ssm / hybrid)
  * EncDec  — whisper-style encoder-decoder (audio)
  * VLM     — patch-embedding stub + LM backbone (vlm)
All expose init / apply / prefill / decode_step / cache_init.
"""
from ..configs.base import ModelConfig
from .transformer import LM, ShardCtx
from .vlm import VLM
from .whisper import EncDec

__all__ = ["LM", "EncDec", "VLM", "ShardCtx", "build_model"]


def build_model(cfg: ModelConfig, ctx: ShardCtx = None):
    if cfg.is_encoder_decoder:
        return EncDec(cfg, ctx)
    if cfg.num_patches:
        return VLM(cfg, ctx)
    return LM(cfg, ctx)
