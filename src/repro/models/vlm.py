"""InternVL2-style VLM wrapper: LM backbone + stub ViT frontend.

Per the assignment, the modality frontend is a STUB — ``input_specs()``
provides precomputed patch embeddings (B, num_patches, d_model) which are
prepended to the token embeddings; the backbone is the standard causal LM.
Decode is delegated to the LM (patches only participate via the prefilled
cache).
"""
from __future__ import annotations

from typing import Optional

from ..configs.base import ModelConfig
from .transformer import LM, ShardCtx

__all__ = ["VLM"]


class VLM:
    def __init__(self, cfg: ModelConfig, ctx: Optional[ShardCtx] = None):
        assert cfg.num_patches > 0
        self.cfg = cfg
        self.lm = LM(cfg, ctx)

    def init(self, key):
        return self.lm.init(key)

    def apply(self, params, tokens, patch_embeds):
        """tokens: (B, S - num_patches); patch_embeds: (B, num_patches, d)."""
        return self.lm.apply(params, tokens, extra_embeds=patch_embeds)

    def prefill(self, params, tokens, patch_embeds, cache_len=None):
        return self.lm.prefill(params, tokens, cache_len=cache_len,
                               extra_embeds=patch_embeds)

    def decode_step(self, params, cache, tokens):
        return self.lm.decode_step(params, cache, tokens)

    def cache_init(self, batch, cache_len, dtype=None):
        return self.lm.cache_init(batch, cache_len, dtype)
