"""Mamba2 (SSD) mixer block: in-proj, depthwise conv, SSD scan, gated norm.

Full-sequence path dispatches to kernels.ops.ssd (chunked block decomposition,
Pallas on TPU / scan-over-chunks XLA elsewhere — both O(S·chunk), which is
what makes the 500k-token cells lowerable). Decode is the O(1) recurrence on
the carried (H, P, N) state plus a ring conv state.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..kernels import ops
from .layers import dense_init, dtype_of, rms_norm, rmsnorm_init

__all__ = ["mamba_init", "mamba_apply", "mamba_decode", "mamba_state_init"]


def mamba_init(key, cfg: ModelConfig):
    d, di = cfg.d_model, cfg.ssm_inner
    H, N, G, cw = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_groups, cfg.ssm_conv_width
    dt = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    return {
        "w_in_x": dense_init(ks[0], (d, di), dt),
        "w_in_z": dense_init(ks[1], (d, di), dt),
        "w_bc": dense_init(ks[2], (d, 2 * G * N), dt),     # B and C projections
        "w_dt": dense_init(ks[3], (d, H), dt),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "conv": (jax.random.normal(ks[4], (cw, di), jnp.float32) * (cw ** -0.5)).astype(dt),
        "ssm_norm": rmsnorm_init(di, dt),
        "w_out": dense_init(ks[5], (di, d), dt),
    }


def _depthwise_conv(x, w):
    """Causal depthwise conv. x: (B, S, C); w: (width, C)."""
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :] for i in range(width))
    return out


def mamba_apply(p, x, cfg: ModelConfig, *, return_state: bool = False):
    """x: (B, S, d) -> (B, S, d) [, (ssm_state, conv_state) for prefill]."""
    B, S, _ = x.shape
    H, P, N, G = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_groups
    xi_raw = x @ p["w_in_x"]                               # (B,S,di)
    z = x @ p["w_in_z"]
    xi = jax.nn.silu(_depthwise_conv(xi_raw, p["conv"]))
    bc = x @ p["w_bc"]
    Bm = bc[..., :G * N].reshape(B, S, G, N)
    Cm = bc[..., G * N:].reshape(B, S, G, N)
    dt = jax.nn.softplus(x.astype(jnp.float32) @ p["w_dt"].astype(jnp.float32)
                         + p["dt_bias"])                   # (B,S,H)
    A = -jnp.exp(p["A_log"])                               # (H,) negative
    out = ops.ssd(xi.reshape(B, S, H, P), dt, A, Bm, Cm, p["D"],
                  chunk=cfg.ssd_chunk, impl=cfg.ssd_impl,
                  return_final_state=return_state)
    y, final_state = out if return_state else (out, None)
    y = y.reshape(B, S, H * P)
    y = rms_norm(y * jax.nn.silu(z), p["ssm_norm"], cfg.norm_eps)
    y = y @ p["w_out"]
    if return_state:
        w = cfg.ssm_conv_width
        pad = jnp.zeros((B, max(w - 1 - S, 0), cfg.ssm_inner), xi_raw.dtype)
        conv_state = jnp.concatenate([pad, xi_raw[:, max(S - (w - 1), 0):, :]], axis=1)
        return y, (final_state, conv_state)
    return y


def mamba_state_init(cfg: ModelConfig, batch: int, dtype) -> Tuple[jax.Array, jax.Array]:
    """(ssm_state, conv_state): ((B,H,P,N) f32, (B, width-1, di))."""
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    ssm = jnp.zeros((batch, H, P, N), jnp.float32)
    conv = jnp.zeros((batch, cfg.ssm_conv_width - 1, cfg.ssm_inner), dtype)
    return ssm, conv


def mamba_decode(p, x, cfg: ModelConfig, ssm_state, conv_state):
    """One-token recurrence. x: (B,1,d). Returns (y, (ssm_state, conv_state))."""
    B = x.shape[0]
    H, P, N, G = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_groups
    xt = x[:, 0]
    xi = xt @ p["w_in_x"]                                  # (B,di)
    z = xt @ p["w_in_z"]
    # ring conv: state holds last width-1 inputs
    hist = jnp.concatenate([conv_state, xi[:, None, :]], axis=1)  # (B,width,di)
    xi = jax.nn.silu(jnp.einsum("bwc,wc->bc", hist.astype(jnp.float32),
                                p["conv"].astype(jnp.float32))).astype(x.dtype)
    conv_state = hist[:, 1:]
    bc = xt @ p["w_bc"]
    Bm = bc[..., :G * N].reshape(B, G, N)
    Cm = bc[..., G * N:].reshape(B, G, N)
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=1).astype(jnp.float32)   # (B,H,N)
    Ch = jnp.repeat(Cm, rep, axis=1).astype(jnp.float32)
    dt = jax.nn.softplus(xt.astype(jnp.float32) @ p["w_dt"].astype(jnp.float32)
                         + p["dt_bias"])                   # (B,H)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A)                                   # (B,H)
    xh = xi.reshape(B, H, P).astype(jnp.float32)
    ssm_state = (ssm_state * dA[..., None, None]
                 + dt[..., None, None] * xh[..., :, None] * Bh[..., None, :])
    y = jnp.einsum("bhpn,bhn->bhp", ssm_state, Ch) + p["D"][None, :, None] * xh
    y = y.reshape(B, H * P).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["ssm_norm"], cfg.norm_eps)
    return (y @ p["w_out"])[:, None, :], (ssm_state, conv_state)
