"""Training CLI: local smoke runs on CPU, production meshes on real pods.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --tiny \
        --steps 50 --batch 8 --seq 128 [--partitioned --pods 2]
"""
import argparse


from ..configs import ARCHS, get_config
from ..models import build_model
from ..models.transformer import ShardCtx
from ..train import Trainer, TrainerConfig
from .mesh import make_local_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="smollm-360m")
    ap.add_argument("--tiny", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--partitioned", action="store_true",
                    help="paper-partitioned per-pod microbatching")
    ap.add_argument("--pods", type=int, default=2)
    ap.add_argument("--policy", default="frontier",
                    choices=("frontier", "equal", "inverse_mu"))
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.tiny:
        cfg = cfg.tiny()
    mesh = None
    ctx = None
    if args.partitioned:
        mesh = make_local_mesh(("pod", "data", "model"))
        ctx = ShardCtx(mesh=mesh, batch_axes=("data",))
    model = build_model(cfg, ctx)
    tcfg = TrainerConfig(steps=args.steps, batch=args.batch, seq=args.seq,
                         lr=args.lr, ckpt_dir=args.ckpt_dir,
                         partitioned=args.partitioned, num_pods=args.pods,
                         policy=args.policy)
    Trainer(model, cfg, tcfg, mesh=mesh).run()


if __name__ == "__main__":
    main()
