"""Serving CLI: paper-partitioned request batching across replica groups.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --tiny \
        --batches 50 --requests 64 --policy frontier

``--engine`` switches to the continuous-batching :class:`WorkflowEngine`:
instead of one replica fleet per batch, every tick admits queued workflow
instances (two templates, mixed families) and prices ALL their stage splits
through one stacked launch per family group:

    PYTHONPATH=src python -m repro.launch.serve --engine --batches 40 \
        --arrival-rate 8 --deadline 4.0
"""
import argparse

import jax
import numpy as np

from ..configs import ARCHS, get_config
from ..models import build_model
from ..obs import trace as obs
from ..serve import PartitionedBatcher, ReplicaGroup, ServeEngine
from ..sim.cluster import Channel, ClusterSim


def _engine_templates():
    from ..workflow.dag import Stage, StageDAG, linear_edges
    pipeline = StageDAG([
        Stage("prefill", mus=[1.0, 1.4, 1.9], sigmas=[0.2, 0.25, 0.35]),
        Stage("decode", mus=[2.0, 2.6, 3.3, 4.0],
              sigmas=[0.3, 0.4, 0.5, 0.6]),
    ], edges=linear_edges(["prefill", "decode"]))
    diamond = StageDAG([
        Stage("shard", mus=[1.2, 1.6, 2.1], sigmas=[0.25, 0.3, 0.4],
              family="lognormal"),
        Stage("rank_a", mus=[2.4, 3.0, 3.7], sigmas=[0.5, 0.6, 0.7],
              family="lognormal"),
        Stage("rank_b", mus=[2.1, 2.7, 3.4], sigmas=[0.45, 0.55, 0.65],
              family="lognormal"),
        Stage("blend", mus=[1.1, 1.5], sigmas=[0.2, 0.3],
              family="lognormal"),
    ], edges=[("shard", "rank_a"), ("shard", "rank_b"),
              ("rank_a", "blend"), ("rank_b", "blend")])
    return {"pipeline": pipeline, "diamond": diamond}


def _run_engine(args) -> None:
    from ..serve import WorkflowEngine
    templates = _engine_templates()
    eng = WorkflowEngine(templates, max_live=args.max_live, lam_var=0.02,
                         num_t=256, prior_obs=4)
    rng = np.random.default_rng(0)
    names = list(templates)
    for t in range(args.batches):
        arrivals = []
        for _ in range(int(rng.poisson(args.arrival_rate))):
            tpl = names[int(rng.integers(len(names)))]
            arrivals.append((tpl, args.deadline) if args.deadline else tpl)
        out = eng.tick(arrivals)
        if t % 10 == 0:
            print(f"tick {t:3d} live={out['live']} queue={out['queue']} "
                  f"rows={out['rows']} launches={out['launches']} "
                  f"retired={len(out['retired'])}")
    s = eng.telemetry.summary()
    c = s["counters"]
    print(f"engine: {c['ticks']} ticks, {c['retired']}/{c['admitted']} "
          f"retired, {c['slo_misses']} SLO misses, "
          f"{c['launches']} launches "
          f"(rows/launch p50 {s['rows_per_launch']['p50']:.0f})")
    print(f"join latency p50 {s['join_latency_s']['p50']:.3f}s "
          f"p99 {s['join_latency_s']['p99']:.3f}s; "
          f"solver tick p50 {s['solver_tick_us']['p50']:.0f}us")
    if args.trace:
        _export_trace(args.trace)


def _export_trace(prefix: str) -> None:
    """Dump the tracer's ring buffer as JSONL + a Perfetto-loadable trace.

    Writes ``<prefix>.jsonl`` and ``<prefix>.perfetto.json``; a no-op
    message is printed when tracing was never enabled (REPRO_TRACE unset),
    so --trace without the env var doesn't silently produce empty files.
    """
    from ..obs import export as obs_export
    recs = obs.records()
    if not recs:
        print("trace: no records captured — run with REPRO_TRACE=1")
        return
    jsonl = f"{prefix}.jsonl"
    perfetto = f"{prefix}.perfetto.json"
    obs_export.validate_records(recs)
    obs_export.write_jsonl(recs, jsonl)
    obs_export.write_perfetto(recs, perfetto)
    print(f"trace: {len(recs)} records "
          f"({len(obs_export.span_kinds(recs))} span kinds, "
          f"{len(obs_export.event_types(recs))} event types, "
          f"{obs.dropped()} dropped) -> {jsonl}, {perfetto}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="smollm-360m")
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--batches", type=int, default=50)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--policy", default="frontier",
                    choices=("frontier", "equal", "inverse_mu"))
    ap.add_argument("--execute", action="store_true",
                    help="run real tiny-model generation per group")
    # closed-estimation-loop knobs (PR 4), threaded end-to-end into the
    # batcher's balancer: online family selection, risk-adjusted candidate
    # scoring, sensitivity-sized refresh cadence
    ap.add_argument("--family", default="normal",
                    choices=("normal", "lognormal", "drift", "auto"),
                    help="completion-time family for the frontier solve "
                         "(auto = online BIC selection with hysteresis)")
    ap.add_argument("--risk-lam", type=float, default=0.0,
                    help="fragility weight: candidates scored mu + lam var "
                         "+ risk_lam * estimation-fragility")
    ap.add_argument("--adaptive-refresh", action="store_true",
                    help="size the re-solve cadence by posterior "
                         "sensitivity instead of a fixed refresh_every")
    ap.add_argument("--refresh-every", type=int, default=1,
                    help="re-solve cadence cap (the adaptive mode "
                         "stretches toward this as estimates firm up)")
    # continuous-batching engine mode (PR 9)
    ap.add_argument("--engine", action="store_true",
                    help="serve workflow instances through the "
                         "continuous-batching WorkflowEngine instead of "
                         "the per-batch PartitionedBatcher")
    ap.add_argument("--max-live", type=int, default=64,
                    help="engine mode: live-instance capacity")
    ap.add_argument("--arrival-rate", type=float, default=6.0,
                    help="engine mode: mean Poisson arrivals per tick")
    ap.add_argument("--deadline", type=float, default=None,
                    help="engine mode: SLO deadline (sim seconds) attached "
                         "to every request")
    # cross-layer tracing (PR 10)
    ap.add_argument("--trace", default=None, metavar="PREFIX",
                    help="export the run's trace to PREFIX.jsonl and "
                         "PREFIX.perfetto.json (enables tracing for the "
                         "run; REPRO_TRACE=1 also works)")
    args = ap.parse_args()
    if args.trace:
        obs.set_enabled(True)

    if args.engine:
        _run_engine(args)
        return

    cfg = get_config(args.arch)
    if args.tiny:
        cfg = cfg.tiny()
    groups = [ReplicaGroup("fast"), ReplicaGroup("slow")]
    if args.execute:
        for g in groups:
            m = build_model(cfg)
            g.engine = ServeEngine(m, cfg)
            g.params = m.init(jax.random.PRNGKey(0))
    sim = ClusterSim([Channel(mu=20.0, sigma=2.0), Channel(mu=14.0, sigma=5.0)])
    b = PartitionedBatcher(groups, policy=args.policy, sim=sim,
                           family=args.family, risk_lam=args.risk_lam,
                           adaptive_refresh=args.adaptive_refresh,
                           refresh_every=args.refresh_every)
    lat = []
    rng = np.random.default_rng(0)
    for i in range(args.batches):
        prompts = rng.integers(0, cfg.vocab_size,
                               (args.requests, 16)).astype(np.int32)
        t, counts, _ = b.run_batch(prompts, max_new=args.max_new,
                                   execute=args.execute)
        lat.append(t)
        if i % 10 == 0:
            tick = b.last_tick
            print(f"batch {i:3d} split={counts.tolist()} join={t:.2f}s "
                  f"family={tick['family']} "
                  f"refresh={tick['effective_refresh']}")
    lat = np.asarray(lat)
    print(f"policy={args.policy} family={args.family} "
          f"risk_lam={args.risk_lam}: mean join {lat.mean():.3f}s  "
          f"var {lat.var():.4f}  p99 {np.percentile(lat, 99):.3f}s")
    if args.trace:
        _export_trace(args.trace)


if __name__ == "__main__":
    main()
