"""Serving CLI: paper-partitioned request batching across replica groups.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --tiny \
        --batches 50 --requests 64 --policy frontier
"""
import argparse

import jax
import numpy as np

from ..configs import ARCHS, get_config
from ..models import build_model
from ..serve import PartitionedBatcher, ReplicaGroup, ServeEngine
from ..sim.cluster import Channel, ClusterSim


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="smollm-360m")
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--batches", type=int, default=50)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--policy", default="frontier",
                    choices=("frontier", "equal", "inverse_mu"))
    ap.add_argument("--execute", action="store_true",
                    help="run real tiny-model generation per group")
    # closed-estimation-loop knobs (PR 4), threaded end-to-end into the
    # batcher's balancer: online family selection, risk-adjusted candidate
    # scoring, sensitivity-sized refresh cadence
    ap.add_argument("--family", default="normal",
                    choices=("normal", "lognormal", "drift", "auto"),
                    help="completion-time family for the frontier solve "
                         "(auto = online BIC selection with hysteresis)")
    ap.add_argument("--risk-lam", type=float, default=0.0,
                    help="fragility weight: candidates scored mu + lam var "
                         "+ risk_lam * estimation-fragility")
    ap.add_argument("--adaptive-refresh", action="store_true",
                    help="size the re-solve cadence by posterior "
                         "sensitivity instead of a fixed refresh_every")
    ap.add_argument("--refresh-every", type=int, default=1,
                    help="re-solve cadence cap (the adaptive mode "
                         "stretches toward this as estimates firm up)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.tiny:
        cfg = cfg.tiny()
    groups = [ReplicaGroup("fast"), ReplicaGroup("slow")]
    if args.execute:
        for g in groups:
            m = build_model(cfg)
            g.engine = ServeEngine(m, cfg)
            g.params = m.init(jax.random.PRNGKey(0))
    sim = ClusterSim([Channel(mu=20.0, sigma=2.0), Channel(mu=14.0, sigma=5.0)])
    b = PartitionedBatcher(groups, policy=args.policy, sim=sim,
                           family=args.family, risk_lam=args.risk_lam,
                           adaptive_refresh=args.adaptive_refresh,
                           refresh_every=args.refresh_every)
    lat = []
    rng = np.random.default_rng(0)
    for i in range(args.batches):
        prompts = rng.integers(0, cfg.vocab_size,
                               (args.requests, 16)).astype(np.int32)
        t, counts, _ = b.run_batch(prompts, max_new=args.max_new,
                                   execute=args.execute)
        lat.append(t)
        if i % 10 == 0:
            tick = b.last_tick
            print(f"batch {i:3d} split={counts.tolist()} join={t:.2f}s "
                  f"family={tick['family']} "
                  f"refresh={tick['effective_refresh']}")
    lat = np.asarray(lat)
    print(f"policy={args.policy} family={args.family} "
          f"risk_lam={args.risk_lam}: mean join {lat.mean():.3f}s  "
          f"var {lat.var():.4f}  p99 {np.percentile(lat, 99):.3f}s")


if __name__ == "__main__":
    main()
