import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines: jax locks the device count on first init.
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell we jit the appropriate step with explicit in_shardings,
``.lower().compile()`` against the production mesh (16x16 single-pod /
2x16x16 multi-pod), print memory_analysis() and cost_analysis(), run the
static roofline analyzer over the compiled HLO, and persist everything to
``experiments/dryrun/<arch>__<shape>__<mesh>.json`` for EXPERIMENTS.md.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b \
        --shape train_4k --mesh single            # one cell
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import ARCHS, SHAPES, get_config, shape_applicable
from ..models import build_model
from ..models.transformer import ShardCtx
from ..optim.adamw import cosine_schedule
from ..train.step import init_state, make_train_step
from .mesh import batch_axes, make_production_mesh
from .roofline import analyze_hlo, count_params, model_flops, roofline_terms
from .shardings import cache_specs, named, param_specs, state_specs

DEFAULT_OUT = "experiments/dryrun"


def _sds(tree):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def _div_ok(n, mesh, axes):
    prod = 1
    for a in axes:
        prod *= mesh.shape[a]
    return n % prod == 0


def build_partitioned_cell(arch: str, mesh, *, max_micro: int = 8,
                           compress: bool = False, seq_parallel: bool = False):
    """THE PAPER CELL: lower the uncertainty-partitioned train step (per-pod
    variable microstep counts + cross-pod join) on the multi-pod mesh."""
    from ..train.step import make_partitioned_train_step

    assert "pod" in mesh.axis_names, "partitioned step needs the pod axis"
    cfg = get_config(arch)
    shape = SHAPES["train_4k"]
    npods = mesh.shape["pod"]
    mb = shape.global_batch // max_micro          # per-microstep global batch
    ctx = ShardCtx(mesh=mesh, batch_axes=("data",),
                   seq_axis="model" if seq_parallel else None)
    model = build_model(cfg, ctx)
    lr = cosine_schedule(3e-4, 100, 10_000)
    state_sds = jax.eval_shape(lambda k: init_state(model, k),
                               jax.random.PRNGKey(0))
    sspec = state_specs(state_sds, mesh, cfg)
    step = make_partitioned_train_step(model, cfg, mesh, lr,
                                       max_micro=max_micro,
                                       compress_pod_reduce=compress,
                                       grad_specs=sspec.params)
    dspec = P(None, ("pod", "data"), None)
    tokens = jax.ShapeDtypeStruct((max_micro, mb, shape.seq_len), jnp.int32)
    kspec = jax.ShapeDtypeStruct((npods,), jnp.int32)
    args = (state_sds, tokens, tokens, kspec)
    shardings = (named(mesh, sspec), NamedSharding(mesh, dspec),
                 NamedSharding(mesh, dspec), NamedSharding(mesh, P("pod")))
    meta = {"arch": arch, "shape": "train_4k(partitioned)", "kind": "train",
            "max_micro": max_micro, "compress_pod_reduce": compress,
            "mesh": dict(mesh.shape)}
    return step, args, shardings, meta


def build_cell(arch: str, shape_name: str, mesh, *, accum: int = 8,
               seq_parallel: bool = False, remat: bool = True,
               attention_impl: str = "xla", capacity_factor: float = None,
               remat_policy: str = "full", accum_dtype: str = "float32"):
    """Returns (fn, example_args, in_shardings, meta) ready to lower."""
    cfg = get_config(arch).replace(remat=remat, attention_impl=attention_impl,
                                   remat_policy=remat_policy)
    if capacity_factor is not None:
        cfg = cfg.replace(capacity_factor=capacity_factor)
    shape = SHAPES[shape_name]
    ba = batch_axes(mesh)
    B, S = shape.global_batch, shape.seq_len
    batch_shardable = _div_ok(B, mesh, ba)
    bspec_axes = ba if batch_shardable else ()

    # long-context attn decode: shard the cache sequence instead of batch.
    # Intra-pod axis only: two-axis manual LSE-combine trips an XLA 0.8.2
    # partitioner CHECK, and replicating the cache across pods is the sane
    # production layout anyway (decode requests are pod-local).
    seq_axes = None
    if shape.kind == "decode" and not batch_shardable and cfg.family in ("hybrid",):
        seq_axes = ("data",)

    extra = tuple(a for a in mesh.axis_names
                  if a not in ("model", "data") and a not in bspec_axes)
    ctx = ShardCtx(mesh=mesh, batch_axes=bspec_axes,
                   seq_axis="model" if seq_parallel else None,
                   decode_seq_axes=seq_axes, manual_extra=extra)
    model = build_model(cfg, ctx)
    bspec = P(bspec_axes or None, None)
    espec = P(bspec_axes or None, None, None)
    adt = jnp.bfloat16

    meta = {"arch": arch, "shape": shape_name, "kind": shape.kind,
            "global_batch": B, "seq_len": S, "mesh": dict(mesh.shape),
            "batch_shardable": batch_shardable,
            "cache_seq_axes": list(seq_axes) if seq_axes else None}

    if shape.kind == "train":
        accum = min(accum, B)
        lr = cosine_schedule(3e-4, 100, 10_000)
        step = make_train_step(model, cfg, lr, accum=accum,
                               accum_dtype=getattr(jnp, accum_dtype))
        meta["accum"] = accum
        meta["accum_dtype"] = accum_dtype
        state_sds = jax.eval_shape(lambda k: init_state(model, k),
                                   jax.random.PRNGKey(0))
        sspec = state_specs(state_sds, mesh, cfg)
        tokens = jax.ShapeDtypeStruct((B, S), jnp.int32)
        labels = jax.ShapeDtypeStruct((B, S), jnp.int32)
        args = [state_sds, tokens, labels]
        shardings = [named(mesh, sspec), NamedSharding(mesh, bspec),
                     NamedSharding(mesh, bspec)]
        if cfg.num_patches or cfg.is_encoder_decoder:
            n_extra = cfg.num_patches or cfg.encoder_seq
            if cfg.num_patches:
                tokens = jax.ShapeDtypeStruct((B, S - cfg.num_patches), jnp.int32)
                args[1] = tokens
                args[2] = jax.ShapeDtypeStruct((B, S), jnp.int32)
            args.append(jax.ShapeDtypeStruct((B, n_extra, cfg.d_model), adt))
            shardings.append(NamedSharding(mesh, espec))
        return step, tuple(args), tuple(shardings), meta

    params_sds = jax.eval_shape(lambda k: model.init(k), jax.random.PRNGKey(0))
    pspec = param_specs(params_sds, mesh, cfg)

    if shape.kind == "prefill":
        if cfg.is_encoder_decoder:
            fn = lambda p, t, f: model.prefill(p, t, f)
            args = (params_sds, jax.ShapeDtypeStruct((B, S), jnp.int32),
                    jax.ShapeDtypeStruct((B, cfg.encoder_seq, cfg.d_model), adt))
            shardings = (named(mesh, pspec), NamedSharding(mesh, bspec),
                         NamedSharding(mesh, espec))
        elif cfg.num_patches:
            fn = lambda p, t, e: model.prefill(p, t, e)
            args = (params_sds,
                    jax.ShapeDtypeStruct((B, S - cfg.num_patches), jnp.int32),
                    jax.ShapeDtypeStruct((B, cfg.num_patches, cfg.d_model), adt))
            shardings = (named(mesh, pspec), NamedSharding(mesh, bspec),
                         NamedSharding(mesh, espec))
        else:
            fn = lambda p, t: model.prefill(p, t)
            args = (params_sds, jax.ShapeDtypeStruct((B, S), jnp.int32))
            shardings = (named(mesh, pspec), NamedSharding(mesh, bspec))
        return fn, args, shardings, meta

    # ---- decode: one token against a seq_len cache
    cache_len = min(S, cfg.window) if cfg.window else S
    meta["cache_len"] = cache_len
    if cfg.is_encoder_decoder:
        cache_sds = jax.eval_shape(
            lambda: model.cache_init(B, cache_len, cfg.encoder_seq))
    else:
        cache_sds = jax.eval_shape(lambda: model.cache_init(B, cache_len))
    cspec = cache_specs(cache_sds, mesh, cfg, seq_axes=seq_axes)
    if not batch_shardable:  # e.g. long_500k batch=1: replicate batch dims
        pass  # cache_specs already consulted seq_axes; batch axes dropped below
    fn = model.decode_step
    tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    args = (params_sds, cache_sds, tok)
    shardings = (named(mesh, pspec), named(mesh, cspec),
                 NamedSharding(mesh, bspec))
    return fn, args, shardings, meta


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str = DEFAULT_OUT,
             partitioned: bool = False, tag: str = "", **opts) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name.replace("(partitioned)", "")] if not partitioned \
        else SHAPES["train_4k"]
    mesh_tag = {"single": "pod16x16", "multi": "pod2x16x16"}[mesh_kind]
    record = {"arch": arch,
              "shape": shape_name if not partitioned else "train_4k(partitioned)",
              "mesh": mesh_tag}
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        record.update(status="skipped", reason=why)
        return _dump(record, out_dir)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = 1
    for v in mesh.shape.values():
        chips *= v
    try:
        t0 = time.perf_counter()
        if partitioned:
            fn, args, shardings, meta = build_partitioned_cell(
                arch, mesh, compress=opts.get("compress", False),
                seq_parallel=opts.get("seq_parallel", False))
        else:
            opts.pop("compress", None)
            if opts.get("remat_policy") is None:
                opts.pop("remat_policy", None)
            if opts.get("accum_dtype") is None:
                opts.pop("accum_dtype", None)
            fn, args, shardings, meta = build_cell(arch, shape_name, mesh, **opts)
        meta["tag"] = tag
        with jax.set_mesh(mesh):
            lowered = jax.jit(fn, in_shardings=shardings).lower(*args)
            t_lower = time.perf_counter() - t0
            compiled = lowered.compile()
            t_compile = time.perf_counter() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        stats = analyze_hlo(compiled.as_text())
        terms = roofline_terms(stats, chips)
        total_p, active_p = count_params(cfg)
        mf = model_flops(cfg, shape)
        hlo_flops_global = stats.flops * chips
        record.update(
            status="ok", meta=meta,
            lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
            memory_analysis={
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
            },
            cost_analysis={k: cost.get(k) for k in ("flops", "bytes accessed")},
            hlo_stats=stats.to_dict(), roofline=terms,
            params={"total": total_p, "active": active_p},
            model_flops=mf,
            useful_flops_ratio=(mf / hlo_flops_global) if hlo_flops_global else None,
        )
        print(f"[OK] {arch} {shape_name} {mesh_tag}: compile {t_compile:.0f}s "
              f"dominant={terms['dominant']} "
              f"bound={terms['step_lower_bound_s']*1e3:.1f}ms "
              f"frac={terms['roofline_fraction']:.3f}")
    except Exception as e:  # noqa: BLE001 — a failed cell is a bug to record
        record.update(status="error", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-4000:])
        print(f"[FAIL] {arch} {shape_name} {mesh_tag}: {e}")
    return _dump(record, out_dir)


def _dump(record: dict, out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    tag = record.get("meta", {}).get("tag", "") if isinstance(record.get("meta"), dict) else ""
    suffix = f"__{tag}" if tag else ""
    path = os.path.join(
        out_dir, f"{record['arch']}__{record['shape']}__{record['mesh']}{suffix}.json")
    with open(path, "w") as f:
        json.dump(record, f, indent=1, default=str)
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi", "both"), default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--accum", type=int, default=8)
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--partitioned", action="store_true",
                    help="lower the paper's per-pod partitioned train step")
    ap.add_argument("--compress", action="store_true",
                    help="int8 error-free cross-pod gradient reduction")
    ap.add_argument("--capacity-factor", type=float, default=None)
    ap.add_argument("--remat-policy", default="full", choices=("full", "dots"))
    ap.add_argument("--accum-dtype", default="float32",
                    choices=("float32", "bfloat16"))
    ap.add_argument("--tag", default="", help="suffix for the output filename")
    args = ap.parse_args()
    meshes = ("single", "multi") if args.mesh == "both" else (args.mesh,)
    cells = ([(a, s) for a in ARCHS for s in SHAPES]
             if args.all else [(args.arch, args.shape or "train_4k")])
    failures = 0
    for arch, shape in cells:
        for mk in meshes:
            rec = run_cell(arch, shape, mk, out_dir=args.out, accum=args.accum,
                           seq_parallel=args.seq_parallel,
                           remat=not args.no_remat,
                           partitioned=args.partitioned,
                           compress=args.compress,
                           capacity_factor=args.capacity_factor,
                           remat_policy=args.remat_policy,
                           accum_dtype=args.accum_dtype,
                           tag=args.tag)
            failures += rec["status"] == "error"
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
