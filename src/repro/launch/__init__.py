"""Launch layer: production meshes, sharding rules, dry-run, roofline, CLIs."""
from .mesh import batch_axes, make_local_mesh, make_production_mesh
from .roofline import HW, analyze_hlo, count_params, model_flops, roofline_terms
from .shardings import batch_specs, cache_specs, named, param_specs, state_specs

__all__ = ["batch_axes", "make_local_mesh", "make_production_mesh", "HW",
           "analyze_hlo", "count_params", "model_flops", "roofline_terms",
           "batch_specs", "cache_specs", "named", "param_specs", "state_specs"]
