"""Static roofline analysis of compiled (post-SPMD) HLO.

Why not just ``compiled.cost_analysis()``: XLA's cost analysis counts each
while-loop *body once*, but our layer stacks run under lax.scan (and train
steps under grad-accumulation scans), so FLOPs/bytes/collectives would be
undercounted by the trip count (~100x). This module parses the HLO text,
builds the computation call graph (entry -> while bodies -> fusions), derives
per-computation execution multipliers from loop trip counts, and accumulates:

  * FLOPs           — 2 * prod(out dims) * prod(contracting dims) per dot,
                      recursing into fusion computations.
  * HBM bytes       — materialized-buffer traffic: per top-level op, operand
                      bytes + output bytes (fusion internals elided, matching
                      what fusion actually saves).
  * Collective wire bytes per chip — ring model per op type from output
    shape and replica group size g:
        all-reduce      2 (g-1)/g * size
        all-gather        (g-1)/g * size        (size = gathered output)
        reduce-scatter    (g-1)/g * size_in = (g-1) * size_out
        all-to-all        (g-1)/g * size
        collective-permute          size
    Groups whose device ids span >= 256 cross pods (DCN), tracked separately.

Hardware model (TPU v5e per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (one link direction assumed — conservative), 25 GB/s DCN.
The compiled module is the per-device program, so all three terms are
per-chip seconds directly comparable as roofline components.

Known approximations (documented in EXPERIMENTS.md):
  * while trip count = max integer literal in the loop condition computation
    (exact for lax.scan; dynamic while loops fall back to 1).
  * only ``dot`` FLOPs are counted (elementwise/reduce FLOPs are noise next
    to matmuls at these shapes).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

__all__ = ["analyze_hlo", "HloStats", "roofline_terms", "model_flops", "HW"]

HW = {
    "peak_flops": 197e12,      # bf16 per chip
    "hbm_bw": 819e9,           # bytes/s per chip
    "ici_bw": 50e9,            # bytes/s per link (1 link assumed)
    "dcn_bw": 25e9,            # bytes/s per chip across pods
}

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1,
                "f8e5m2": 1, "s64": 8, "s32": 4, "s16": 2, "s8": 1,
                "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1, "c64": 8,
                "c128": 16, "token": 0}

_SHAPE_RE = re.compile(r"\b([a-z]\w*)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_OPCODE_RE = re.compile(r"^(?:\([^)]*\)|\S+)\s+([\w\-]+)\(")
_OPERANDS_RE = re.compile(r"\(([^)]*)\)")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](T\(([\d,]+)\))?")
_CALL_RE = re.compile(r"(?:calls|to_apply|body|condition)=%([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(text: str) -> int:
    """Sum bytes of every shape literal in ``text``."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_shape_dims(text: str) -> Optional[List[int]]:
    m = _SHAPE_RE.search(text)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class _Op:
    name: str
    defn: str           # full rhs text
    opcode: str
    out_bytes: int


@dataclass
class _Computation:
    name: str
    ops: Dict[str, _Op] = field(default_factory=dict)
    order: List[str] = field(default_factory=list)


def _parse_computations(hlo: str) -> Dict[str, _Computation]:
    comps: Dict[str, _Computation] = {}
    cur: Optional[_Computation] = None
    for line in hlo.splitlines():
        stripped = line.strip()
        header = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{", stripped)
        if header and not stripped.startswith("//") and "=" not in stripped.split("(")[0]:
            cur = _Computation(name=header.group(1))
            comps[cur.name] = cur
            if stripped.startswith("ENTRY"):
                comps["__entry__"] = cur
            continue
        if cur is None:
            continue
        m = _DEF_RE.match(stripped)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        opm = _OPCODE_RE.match(rhs)
        opcode = opm.group(1) if opm else ""
        if rhs.startswith("("):  # tuple output: shapes up to the closing paren
            out_text = rhs[:rhs.index(")") + 1]
        else:
            out_text = rhs.split("(")[0]
        out_bytes = _shape_bytes(out_text)
        cur.ops[name] = _Op(name=name, defn=rhs, opcode=opcode, out_bytes=out_bytes)
        cur.order.append(name)
    return comps


def _group_size(defn: str) -> Tuple[int, bool]:
    """(group size, crosses_pod) from replica_groups annotation.

    A group crosses pods iff its member ids span >= 256 (pods are the
    slowest-varying 256-id blocks of the 512-device mesh). Iota-form groups
    ([G,S]<=[dims]T(perm)) are decoded exactly with numpy.
    """
    import numpy as _np

    m = _GROUPS_IOTA_RE.search(defn)
    if m:
        num_groups, group_size = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        ids = _np.arange(int(_np.prod(dims))).reshape(dims)
        if m.group(5):
            perm = [int(x) for x in m.group(5).split(",")]
            ids = ids.transpose(perm)
        groups = ids.reshape(num_groups, group_size)
        spans = groups.max(axis=1) - groups.min(axis=1)
        return group_size, bool((spans >= 256).any())
    m = _GROUPS_LIST_RE.search(defn)
    if m:
        ids = [int(x) for x in m.group(1).split(",")]
        crosses = (max(ids) - min(ids)) >= 256
        return max(len(ids), 1), crosses
    return 1, False


def _operand_names(operand_text: str) -> List[str]:
    """Operand instruction names from an operand list.

    Handles typed ("f32[8,32]{1,0} %name, ..."), bare ("name.1, other.1"),
    and mixed styles. Shape/layout literals are stripped first because their
    commas would break a naive split; the last token of each remaining
    segment is the instruction name (with or without a "%" prefix).
    """
    text = _SHAPE_RE.sub("", operand_text)
    text = re.sub(r"\{[\d,]*\}", "", text)
    names = []
    for seg in text.split(","):
        seg = seg.strip()
        if seg:
            names.append(seg.split()[-1].lstrip("%"))
    return names


def _dot_flops(op: _Op, comp: _Computation) -> float:
    out_dims = _first_shape_dims(op.defn) or []
    out_elems = 1
    for d in out_dims:
        out_elems *= d
    mlhs = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.defn)
    operands = _OPERANDS_RE.search(op.defn)
    contract = 1
    if mlhs and operands:
        otext = operands.group(1)
        # typed dumps carry the lhs shape inline; bare dumps need the producer
        lhs_dims = _first_shape_dims(otext)
        if lhs_dims is None:
            names = _operand_names(otext)
            lhs = comp.ops.get(names[0]) if names else None
            lhs_dims = _first_shape_dims(lhs.defn) if lhs else None
        if lhs_dims:
            for idx in mlhs.group(1).split(","):
                if idx:
                    contract *= lhs_dims[int(idx)]
    return 2.0 * out_elems * contract


@dataclass
class HloStats:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    ici_bytes: float = 0.0
    dcn_bytes: float = 0.0
    collective_counts: Dict[str, int] = field(default_factory=dict)
    collective_bytes_by_type: Dict[str, float] = field(default_factory=dict)
    notes: List[str] = field(default_factory=list)

    def to_dict(self) -> dict:
        return {k: getattr(self, k) for k in
                ("flops", "hbm_bytes", "ici_bytes", "dcn_bytes",
                 "collective_counts", "collective_bytes_by_type", "notes")}


def _trip_count(cond: _Computation) -> int:
    best = 1
    for op in cond.ops.values():
        for c in _CONST_RE.findall(op.defn):
            best = max(best, int(c))
    return best


def analyze_hlo(hlo: str) -> HloStats:
    comps = _parse_computations(hlo)
    entry = comps.get("__entry__")
    stats = HloStats()
    if entry is None:
        stats.notes.append("no ENTRY computation found")
        return stats

    def walk(comp: _Computation, mult: float, as_fusion: bool, seen: tuple):
        if comp.name in seen:
            return
        seen = seen + (comp.name,)
        for opname in comp.order:
            op = comp.ops[opname]
            if op.opcode == "dot":
                stats.flops += mult * _dot_flops(op, comp)
            if any(op.opcode.startswith(c) for c in _COLLECTIVES):
                base = op.opcode.split(".")[0]
                for c in _COLLECTIVES:
                    if op.opcode.startswith(c):
                        base = c
                        break
                if op.opcode.endswith("-done"):
                    continue  # counted at -start
                g, crosses = _group_size(op.defn)
                size = op.out_bytes
                if base == "all-reduce":
                    wire = 2.0 * (g - 1) / max(g, 1) * size
                elif base == "all-gather":
                    wire = (g - 1) / max(g, 1) * size
                elif base == "reduce-scatter":
                    wire = (g - 1) * size
                elif base == "all-to-all":
                    wire = (g - 1) / max(g, 1) * size
                else:  # collective-permute
                    wire = size
                stats.collective_counts[base] = (
                    stats.collective_counts.get(base, 0) + 1)
                stats.collective_bytes_by_type[base] = (
                    stats.collective_bytes_by_type.get(base, 0.0) + mult * wire)
                if crosses:
                    stats.dcn_bytes += mult * wire
                else:
                    stats.ici_bytes += mult * wire
            if op.opcode == "while":
                body = cond = None
                mcalls = re.search(r"body=%([\w.\-]+)", op.defn)
                mcond = re.search(r"condition=%([\w.\-]+)", op.defn)
                if mcalls:
                    body = comps.get(mcalls.group(1))
                if mcond:
                    cond = comps.get(mcond.group(1))
                trips = _trip_count(cond) if cond else 1
                if body:
                    walk(body, mult * trips, False, seen)
                if cond:
                    walk(cond, mult * trips, False, seen)
            elif op.opcode in ("fusion", "call", "conditional", "map"):
                for callee in _CALL_RE.findall(op.defn):
                    sub = comps.get(callee)
                    if sub and not sub.name.startswith("region"):
                        # fusion internals: FLOPs count, memory does not
                        walk_fused(sub, mult, seen)

    def walk_fused(comp: _Computation, mult: float, seen: tuple):
        if comp.name in seen:
            return
        seen = seen + (comp.name,)
        for opname in comp.order:
            op = comp.ops[opname]
            if op.opcode == "dot":
                stats.flops += mult * _dot_flops(op, comp)
            for callee in _CALL_RE.findall(op.defn):
                sub = comps.get(callee)
                if sub:
                    walk_fused(sub, mult, seen)

    def mem_walk(comp: _Computation, mult: float, seen: tuple):
        if comp.name in seen:
            return
        seen = seen + (comp.name,)
        for opname in comp.order:
            op = comp.ops[opname]
            if op.opcode in ("parameter", "constant", "get-tuple-element",
                             "tuple", "bitcast", "while", "copy", "copy-start",
                             "copy-done", "partition-id", "replica-id"):
                # copies are CPU-backend aliasing artifacts (in-place on TPU)
                pass
            elif op.opcode in ("dynamic-update-slice", "dynamic-slice",
                               "gather", "scatter"):
                # in-place / indexed on TPU: traffic ~ the touched slice, not
                # the whole buffer. For DUS the update operand is the slice.
                operands = _OPERANDS_RE.search(op.defn)
                touched = op.out_bytes
                if op.opcode == "dynamic-update-slice" and operands:
                    otext = operands.group(1)
                    shapes = _SHAPE_RE.findall(otext)
                    if len(shapes) >= 2:  # typed dump: update shape is inline
                        touched = _shape_bytes(
                            "{}[{}]".format(shapes[1][0], shapes[1][1]))
                    else:
                        parts = _operand_names(otext)
                        if len(parts) >= 2 and parts[1] in comp.ops:
                            touched = comp.ops[parts[1]].out_bytes
                stats.hbm_bytes += mult * 2 * touched
            else:
                # operand bytes: inline shapes when the dump carries them,
                # else sum of producer output bytes.
                operands = _OPERANDS_RE.search(op.defn)
                in_bytes = 0
                if operands:
                    otext = operands.group(1)
                    if _SHAPE_RE.search(otext):
                        in_bytes = _shape_bytes(otext)
                    else:
                        for o in _operand_names(otext):
                            prod = comp.ops.get(o)
                            if prod is not None:
                                in_bytes += prod.out_bytes
                if op.opcode == "fusion":
                    # TPU-fusion traffic model: a fusion streams ~O(out) data;
                    # operands that are whole loop-carried stacks (sliced
                    # inside) or elementwise upcast chains do not re-read
                    # their full size. Cap fused in-traffic at 2x out.
                    in_bytes = min(in_bytes, 2 * op.out_bytes)
                stats.hbm_bytes += mult * (op.out_bytes + in_bytes)
            if op.opcode == "while":
                mb = re.search(r"body=%([\w.\-]+)", op.defn)
                mc = re.search(r"condition=%([\w.\-]+)", op.defn)
                trips = _trip_count(comps[mc.group(1)]) if mc and mc.group(1) in comps else 1
                if mb and mb.group(1) in comps:
                    mem_walk(comps[mb.group(1)], mult * trips, seen)

    walk(entry, 1.0, False, ())
    mem_walk(entry, 1.0, ())
    return stats


# ----------------------------------------------------------------- terms
def roofline_terms(stats: HloStats, chips: int) -> dict:
    compute_s = stats.flops / HW["peak_flops"]
    memory_s = stats.hbm_bytes / HW["hbm_bw"]
    ici_s = stats.ici_bytes / HW["ici_bw"]
    dcn_s = stats.dcn_bytes / HW["dcn_bw"]
    coll_s = ici_s + dcn_s
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": coll_s, "ici_s": ici_s, "dcn_s": dcn_s}
    dom = max(("compute_s", "memory_s", "collective_s"), key=lambda k: terms[k])
    bound = max(terms["compute_s"], terms["memory_s"], terms["collective_s"])
    terms["dominant"] = dom
    terms["step_lower_bound_s"] = bound
    terms["roofline_fraction"] = compute_s / bound if bound > 0 else 0.0
    terms["chips"] = chips
    return terms


# ----------------------------------------------------------------- model flops
def count_params(cfg) -> Tuple[float, float]:
    """(total, active) parameter counts from the config (analytic)."""
    d = cfg.d_model
    emb = cfg.padded_vocab * d * 2
    per_attn = (d * cfg.num_heads * cfg.head_dim
                + 2 * d * cfg.num_kv_heads * cfg.head_dim
                + cfg.num_heads * cfg.head_dim * d)
    if cfg.kv_lora_rank:
        nope, rd, vd, lora = (cfg.qk_nope_head_dim, cfg.qk_rope_head_dim,
                              cfg.v_head_dim, cfg.kv_lora_rank)
        per_attn = (d * cfg.num_heads * (nope + rd) + d * (lora + rd)
                    + lora * cfg.num_heads * (nope + vd)
                    + cfg.num_heads * vd * d)
    per_mamba = (3 * d * cfg.ssm_inner + d * 2 * cfg.ssm_groups * cfg.ssm_state
                 + d * cfg.ssm_heads) if cfg.ssm_state else 0.0
    mlp_mult = 3 if cfg.mlp_act == "swiglu" else 2
    n_attn = n_mamba = n_moe = n_dense = 0
    for _ in range(cfg.num_repeats):
        for s in cfg.pattern:
            n_attn += s.mixer in ("attn", "mla")
            n_mamba += s.mixer == "mamba"
            n_moe += s.mlp == "moe"
            n_dense += s.mlp == "dense"
    n_dense += 1 if cfg.first_layer_dense else 0
    n_attn += 1 if cfg.first_layer_dense else 0
    moe_ff = cfg.moe_d_ff or cfg.d_ff
    dense_mlp = n_dense * mlp_mult * d * cfg.d_ff
    moe_total = n_moe * (cfg.num_experts + cfg.num_shared_experts) * 3 * d * moe_ff
    moe_active = n_moe * (cfg.top_k + cfg.num_shared_experts) * 3 * d * moe_ff
    total = emb + n_attn * per_attn + n_mamba * per_mamba + dense_mlp + moe_total
    active = emb + n_attn * per_attn + n_mamba * per_mamba + dense_mlp + moe_active
    if cfg.is_encoder_decoder:
        enc = cfg.num_encoder_layers * (per_attn + mlp_mult * d * cfg.d_ff)
        cross = cfg.num_layers * per_attn
        total += enc + cross
        active += enc + cross
    return float(total), float(active)


def model_flops(cfg, shape) -> float:
    """Global useful FLOPs per step.

    Parameter term: 6*N_active*D (train) / 2*N_active*D (prefill) /
    2*N_active*B (decode). Mixer state term (not captured by N): attention
    score+value FLOPs (window/causal-aware), SSD chunk+state FLOPs — these
    are real useful work that grows with context, so they belong in the
    "useful" numerator when judging the compiled HLO.
    """
    _, active = count_params(cfg)
    B, S = shape.global_batch, shape.seq_len
    D = B * S
    n_attn = n_mamba = 0
    for _ in range(cfg.num_repeats):
        for sp in cfg.pattern:
            n_attn += sp.mixer in ("attn", "mla")
            n_mamba += sp.mixer == "mamba"
    n_attn += 1 if cfg.first_layer_dense else 0
    hqhd = cfg.num_heads * (cfg.head_dim if not cfg.kv_lora_rank
                            else cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)
    kv_span_full = min(S, cfg.window) if cfg.window else S

    if shape.kind == "decode":
        span = kv_span_full
        attn = n_attn * 4.0 * B * span * hqhd
        ssd = n_mamba * B * (4.0 * cfg.ssm_inner * cfg.ssm_state
                             + 2.0 * cfg.ssm_inner * cfg.ssm_state)
        param_term = 2.0 * active * B
        return param_term + attn + ssd

    # causal full attention averages S/2 keys per query; SWA averages window
    avg_span = kv_span_full / (1.0 if cfg.window else 2.0)
    attn_fwd = n_attn * 4.0 * D * avg_span * hqhd
    # SSD per token (per layer): chunk matmuls 2L(N+P) + state in/out 4PN,
    # times H heads => d_inner * (2L(N/P + 1) + 4N)
    L, N, Pd = cfg.ssd_chunk, cfg.ssm_state, cfg.ssm_head_dim
    ssd_fwd = (n_mamba * D * cfg.ssm_inner * (2.0 * L * (N / Pd + 1) + 4.0 * N)
               if cfg.ssm_state else 0.0)
    if cfg.is_encoder_decoder:
        F = cfg.encoder_seq
        attn_fwd += cfg.num_encoder_layers * 4.0 * B * F * F * hqhd  # enc self
        attn_fwd += cfg.num_layers * 4.0 * D * F * hqhd             # cross
    if shape.kind == "train":
        return 6.0 * active * D + 3.0 * (attn_fwd + ssd_fwd)
    return 2.0 * active * D + attn_fwd + ssd_fwd
