"""PartitionSpec rules: parameter trees, optimizer state, KV caches, batches.

Strategy (TPU v5e, DESIGN.md §4):
  * TP over "model": attention heads, FFN hidden, vocab, MoE experts (EP),
    SSD heads. Output projections are row-sharded (psum joins).
  * FSDP over "data": every weight matrix additionally sharded on a non-TP
    dim; optimizer moments follow params.
  * "pod" axis: pure DP (weights replicated) — it is the paper's channel
    axis, joined once per step by the gradient reduction.
  * Any proposed axis that does not divide the dim falls back to replication
    (e.g. 15 or 20 attention heads vs tp=16 -> attention replicated, noted
    per-arch in the roofline).

Rules are name-based over the param tree (leaf names are part of the module
contract); stacked scan layers are detected by rank and get a leading None.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig
from .mesh import batch_axes

__all__ = ["param_specs", "state_specs", "cache_specs", "batch_specs",
           "named", "spec_tree_to_shardings"]


def _div(n: int, mesh, axis: Optional[str]):
    """axis if it exists in mesh and divides n, else None (replicate)."""
    if axis is None or axis not in mesh.axis_names:
        return None
    return axis if n % mesh.shape[axis] == 0 else None


def _leaf_spec(path: str, shape, mesh, cfg: ModelConfig, tp: str, fsdp: str):
    """Base PartitionSpec for one named leaf (no stacking dim)."""
    nd = len(shape)
    name = path.split("/")[-1]

    def col2(rows, cols):  # (rows sharded fsdp, cols sharded tp)
        return P(_div(rows, mesh, fsdp), _div(cols, mesh, tp))

    def row2(rows, cols):  # (rows sharded tp, cols sharded fsdp)
        return P(_div(rows, mesh, tp), _div(cols, mesh, fsdp))

    if name == "embedding":      # (V, d): one-hot contraction -> shard vocab
        return P(_div(shape[0], mesh, tp), _div(shape[1], mesh, fsdp))
    if name == "head":           # (d, V)
        return col2(*shape[-2:])
    if name in ("wq", "wk", "wv", "w_up", "w_gate", "shared_up", "shared_gate",
                "w_in_x", "w_in_z", "w_dt", "w_uk", "w_uv"):
        return col2(*shape[-2:])
    if name in ("wo", "w_down", "shared_down", "w_out"):
        return row2(*shape[-2:])
    if name in ("w_dkv", "w_bc"):   # small, column dims must stay whole
        return P(_div(shape[-2], mesh, fsdp), None)
    if name in ("moe_up", "moe_gate"):   # (E, d, ff): EP on E, FSDP on d
        return P(_div(shape[0], mesh, tp), _div(shape[1], mesh, fsdp), None)
    if name == "moe_down":               # (E, ff, d): FSDP on d
        return P(_div(shape[0], mesh, tp), None, _div(shape[2], mesh, fsdp))
    if name == "router":
        return P(None, None)
    if name == "conv":                   # (width, d_inner)
        return P(None, _div(shape[1], mesh, tp))
    if name in ("A_log", "D", "dt_bias"):
        return P(_div(shape[0], mesh, tp))
    if name == "ssm_norm":
        return P(_div(shape[0], mesh, tp))
    if nd == 1:                          # other norm scales
        return P(None)
    return P(*([None] * nd))             # conservative default


def param_specs(params, mesh, cfg: ModelConfig, *, tp: str = "model",
                fsdp: str = "data"):
    """PartitionSpec tree mirroring a param tree."""
    def one(path_entries, leaf):
        path = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path_entries)
        name = path.split("/")[-1]
        base_rank = {"embedding": 2, "head": 2, "router": 2, "conv": 2,
                     "A_log": 1, "D": 1, "dt_bias": 1, "ssm_norm": 1,
                     "moe_up": 3, "moe_gate": 3, "moe_down": 3}.get(name)
        if base_rank is None:
            base_rank = 1 if (name.startswith("ln") or "norm" in name) else 2
        stacked = leaf.ndim == base_rank + 1
        base_shape = leaf.shape[1:] if stacked else leaf.shape
        spec = _leaf_spec(path, base_shape, mesh, cfg, tp, fsdp)
        if stacked:
            spec = P(None, *spec)
        return spec

    return jax.tree_util.tree_map_with_path(one, params)


def state_specs(state, mesh, cfg: ModelConfig):
    """Specs for a TrainState: opt moments follow params; step is replicated."""
    pspec = param_specs(state.params, mesh, cfg)
    return type(state)(
        params=pspec,
        opt=type(state.opt)(step=P(),
                            m=param_specs(state.opt.m, mesh, cfg),
                            v=param_specs(state.opt.v, mesh, cfg)))


def cache_specs(cache, mesh, cfg: ModelConfig, *, seq_axes=None,
                tp: str = "model"):
    """Specs for a decode cache tree.

    seq_axes: shard cache *sequence* dim over these axes (long-context decode,
    batch too small to shard) — otherwise the batch dim is sharded.
    """
    ba_all = batch_axes(mesh)

    def _ba_for(b: int):
        prod = 1
        for a in ba_all:
            prod *= mesh.shape[a]
        return (ba_all or None) if (ba_all and b % prod == 0) else None

    def one(path_entries, leaf):
        path = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path_entries)
        name = path.split("/")[-1]
        if name in ("slot_pos",):
            return P(seq_axes) if seq_axes else P(None)
        if name == "pos":
            return P()
        stacked = (path.startswith("blocks")
                   or (name in ("k", "v", "xk", "xv") and leaf.ndim == 5)
                   or (name in ("c", "rope") and leaf.ndim == 4))
        lead = (None,) if stacked else ()
        if name in ("k", "v", "xk", "xv"):   # (R?, B, Hkv, S, hd)
            b = leaf.shape[1 if stacked else 0]
            hkv = leaf.shape[2 if stacked else 1]
            if seq_axes:
                return P(*lead, None, _div(hkv, mesh, tp), seq_axes, None)
            return P(*lead, _ba_for(b), _div(hkv, mesh, tp), None, None)
        if name in ("c", "rope"):            # (R?, B, S, dim) — MLA latent
            b = leaf.shape[1 if stacked else 0]
            if seq_axes:
                return P(*lead, None, seq_axes, None)
            return P(*lead, _ba_for(b), None, None)
        if name == "ssm":                    # (R?, B, H, P, N)
            b = leaf.shape[2 - 1 if stacked else 0]
            h = leaf.shape[2 if stacked else 1]
            return P(*lead, _ba_for(b) if not seq_axes else None,
                     _div(h, mesh, tp), None, None)
        if name == "conv":                   # (R?, B, w-1, d_inner)
            b = leaf.shape[1 if stacked else 0]
            di = leaf.shape[-1]
            return P(*lead, _ba_for(b) if not seq_axes else None, None,
                     _div(di, mesh, tp))
        return P(*([None] * leaf.ndim))

    return jax.tree_util.tree_map_with_path(one, cache)


def batch_specs(mesh, *, with_extra: bool = False, extra_rank: int = 3):
    ba = batch_axes(mesh) or None
    toks = P(ba, None)
    if with_extra:
        return toks, P(ba, *([None] * (extra_rank - 1)))
    return toks


def named(mesh, spec_tree):
    """PartitionSpec tree -> NamedSharding tree."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
