"""Production mesh definitions (functions, never module-level constants —
importing this module must not touch jax device state).

Target hardware: TPU v5e pods, 256 chips each (16 x 16 ICI torus).
  single-pod : (16, 16)      axes ("data", "model")
  multi-pod  : (2, 16, 16)   axes ("pod", "data", "model"), pods joined by DCN

"data" carries DP + FSDP (weights/optimizer sharded over it); "model" carries
TP + EP; "pod" carries the paper's channels (pure DP + the partitioner split).
"""
from __future__ import annotations

from typing import Tuple

import jax

try:  # jax >= 0.5 exposes explicit axis types; 0.4.x predates them
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None

__all__ = ["make_production_mesh", "make_local_mesh", "batch_axes"]


def _make_mesh(shape, axes):
    """make_mesh with Auto axis types when the installed jax supports them."""
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_local_mesh(axes: Tuple[str, ...] = ("data", "model")):
    """1-device mesh with production axis names (CPU smoke tests)."""
    return _make_mesh((1,) * len(axes), axes)


def batch_axes(mesh) -> Tuple[str, ...]:
    """Mesh axes that shard the batch dimension (everything but TP)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
