"""Workflow-DAG partitioning at scale: joint solve vs stage-by-stage greedy.

The acceptance experiment for the ``repro.workflow`` subsystem: a 32-stage
fork-join DAG (source -> 10 parallel 3-stage branches -> sink), K=256
channels per stage, solved two ways:

* ``greedy``  — each stage alone on its own expected join time (a per-stage
  Python loop of independent ``optimize_weights`` solves — every stage pays
  its own kernel launches and nobody sees the graph);
* ``joint``   — ``workflow.solve.solve_dag``: all 32 stage splits descend
  the composed end-to-end makespan together through the multi-fidelity
  ladder (coarse presolve/triage rung, pruned+deduped survivors, fine
  refine under plateau early-stop, eval-fidelity final pick), every
  moment/gradient evaluation ONE stacked ``ops.frontier_moments*`` launch
  over all stages (``family_groups == 1`` on this all-one-family graph —
  the "no per-stage kernel loop" contract, asserted here).

Reported: predicted makespan moments under the shared evaluator (identical
quadrature for both methods), realized makespan over paired simulation
trials (same rng trace for both splits), solve wall times
(median + real p90 over ``repeats`` warm solves), the joint solver's
per-phase wall breakdown (starts / presolve / triage / refine /
final-score — so fidelity-ladder wins stay attributable), and the
``joint_vs_greedy_wallclock_ratio`` the PR 8 acceptance gates on
(joint ≤ greedy at full scale, with the makespan win preserved).

A second, joint-only **scale point** at 512 stages × K=256 (170 branches)
proves the stacked-row path scales 10×: same ladder, same single-launch
contract, entry name ``joint_solve_xla_scale``. The smoke run keeps the
512-stage STRUCTURE but shrinks everything else (K, quadrature, steps) so
the composition/compile path is exercised without the full-scale cost.

``--json`` writes machine-readable ``BENCH_dag_scale.json`` at the repo
root; ``scripts/bench_smoke.sh`` runs the reduced scale and
``scripts/ci.sh`` asserts the schema keys.
"""
import argparse
import json
import os

import numpy as np

from .common import emit, save_table, timeit_stats

STAGES_BRANCHES = 10   # parallel branches between source and sink
BRANCH_LEN = 3         # stages per branch -> S = 2 + 10*3 = 32
TICK_K = 256           # channels per stage
TICK_T = 256           # survival-integral points per candidate
PGD_STEPS = 60
MC_TRIALS = 200
FULL_REPEATS = 5       # timed warm solves per method (median + real p90)
SMOKE_REPEATS = 3

SCALE_BRANCHES = 170   # scale point: S = 2 + 170*3 = 512 stages
SCALE_REPEATS = 3

# the machine-readable contract of BENCH_dag_scale*.json — declared next to
# the writer; scripts/ci.sh imports these to validate the emitted files
SCHEMA_KEYS = ("bench", "smoke", "stages", "channels", "joint", "greedy",
               "improvement_pct", "realized_improvement_pct",
               "family_groups", "single_batched_path",
               "joint_phase_us", "joint_vs_greedy_wallclock_ratio",
               "scale_point", "entries")
ENTRY_KEYS = ("name", "impl", "S", "K", "num_t", "median_us", "p90_us",
              "repeats")
# the solver phases every joint entry must attribute its wall time across
PHASE_KEYS = ("starts", "presolve", "triage", "refine", "final_score")

_JSON_ENTRIES = []


def _record(name, impl, S, K, num_t, med_us, p90_us, repeats):
    _JSON_ENTRIES.append({
        "name": name, "impl": impl, "S": S, "K": K, "num_t": num_t,
        "median_us": round(med_us, 2), "p90_us": round(p90_us, 2),
        "repeats": repeats})


def make_dag(branches=STAGES_BRANCHES, branch_len=BRANCH_LEN, k=TICK_K,
             seed=0, family="normal"):
    """source -> ``branches`` parallel ``branch_len``-stage chains -> sink.

    Branch statistics draw from the same ranges (statistically similar
    branches make the join's E[max] variance-sensitive — the regime where
    graph-blind solving leaves the most on the table), with wide per-channel
    spread heterogeneity so every stage has a real mean/variance frontier.
    """
    from repro.workflow import Stage, StageDAG

    rng = np.random.default_rng(seed)

    def mk(name):
        mus = rng.uniform(10.0, 40.0, k)
        sigmas = mus * rng.uniform(0.05, 0.5, k)
        return Stage(name, mus, sigmas, family=family)

    stages = [mk("src")]
    edges = []
    for b in range(branches):
        prev = "src"
        for j in range(branch_len):
            s = mk(f"b{b}_{j}")
            stages.append(s)
            edges.append((prev, s.name))
            prev = s.name
        edges.append((prev, "sink"))
    stages.append(mk("sink"))
    return StageDAG(stages, edges)


def _mc_makespan(dag, weights, trials, seed=0):
    """Paired-trace realized makespan: one rng stream per trial, replayed
    identically across policies by seeding per trial."""
    from repro.sim import WorkflowSim

    sim = WorkflowSim.from_dag(dag, seed=seed)
    ts = [sim.run_dag_step(dag, weights, rng=10_000 + t)[0]
          for t in range(trials)]
    return float(np.mean(ts)), float(np.var(ts))


def _phase_us(decision):
    """The solver's own per-phase wall breakdown, rounded for the JSON."""
    prof = decision.profile or {}
    return {k: round(float(v), 1)
            for k, v in prof.get("phase_us", {}).items()}


def _scale_point(smoke, rows):
    """Joint-only 512-stage solve: the stacked-row path at 10x the stages.

    Greedy at this scale would be 512 sequential per-stage solves — the
    pathology the joint path exists to avoid — so only the joint solve is
    timed. Smoke keeps the 512-stage structure (the composition and its
    compile path are what the scale point guards) but shrinks channels,
    quadrature and steps.
    """
    from repro.workflow import solve_dag

    if smoke:
        k, num_t, steps, repeats = 8, 64, 6, 1
    else:
        k, num_t, steps, repeats = TICK_K, TICK_T, PGD_STEPS, SCALE_REPEATS
    dag = make_dag(SCALE_BRANCHES, BRANCH_LEN, k, seed=1)
    S = len(dag.stages)

    result = {}

    def once():
        result["dec"] = solve_dag(dag, steps=steps, restarts=1, num_t=num_t)

    med, p90 = timeit_stats(once, repeats=repeats, warmup=1)
    dec = result["dec"]
    rows.append((S, k, num_t, "joint_solve_xla_scale", med))
    _record("joint_solve_xla_scale", "xla", S, k, num_t, med, p90, repeats)
    emit(f"dag_scale_{S}st_{k}ch_joint_solve_xla_scale", med)
    return {
        "stages": S, "channels": k, "num_t": num_t, "steps": steps,
        "median_us": round(med, 2), "p90_us": round(p90, 2),
        "repeats": repeats,
        "makespan_mu": dec.makespan_mu,
        "method": dec.method,
        "family_groups": dec.family_groups,
        "phase_us": _phase_us(dec),
    }


def run(smoke=False) -> dict:
    from repro.workflow import solve_dag, solve_dag_greedy
    from repro.workflow.solve import _stage_groups

    if smoke:
        branches, blen, k, num_t, steps, trials = 2, 3, 32, 128, 30, 50
        repeats = SMOKE_REPEATS
    else:
        branches, blen, k, num_t, steps, trials = (
            STAGES_BRANCHES, BRANCH_LEN, TICK_K, TICK_T, PGD_STEPS,
            MC_TRIALS)
        repeats = FULL_REPEATS
    dag = make_dag(branches, blen, k)
    S = len(dag.stages)
    groups, _, _ = _stage_groups(dag)
    # the acceptance contract: one family on this graph -> one stacked
    # launch serves every stage's moment evaluation each PGD step
    assert len(groups) == 1, [g.dist_id for g in groups]

    rows = []

    def bench(name, fn):
        result = {}

        def once():
            result["v"] = fn()

        # warmup=1: the first call pays jit compilation; the timed repeats
        # measure the warm solve the balancer's refresh ticks actually pay
        med, p90 = timeit_stats(once, repeats=repeats, warmup=1)
        rows.append((S, k, num_t, name, med))
        _record(name, "xla", S, k, num_t, med, p90, repeats)
        emit(f"dag_scale_{S}st_{k}ch_{name}", med)
        return result["v"], med

    # joint: all S stages through one stacked fused launch per PGD step
    joint, joint_med = bench(
        "joint_solve_xla",
        lambda: solve_dag(dag, steps=steps, restarts=1, num_t=num_t))
    # phase attribution from the tracer itself: one extra warm solve under
    # obs.capture(), totals read back from the solver.phase spans through
    # the export path — the same spans that feed decision.profile, but
    # aggregated the way any external trace consumer would see them. Kept
    # OUTSIDE the timed repeats so capture overhead never touches the
    # joint-vs-greedy ratio.
    from repro.obs import trace as obs
    from repro.obs.export import phase_totals
    with obs.capture() as recs:
        solve_dag(dag, steps=steps, restarts=1, num_t=num_t)
    joint_phase = {k: float(v) for k, v in phase_totals(recs).items()}
    # greedy: the per-stage solve loop
    greedy, greedy_med = bench(
        "greedy_solve_xla",
        lambda: solve_dag_greedy(dag, steps=steps, restarts=1,
                                 num_t=num_t))

    ratio = joint_med / greedy_med
    emit(f"dag_scale_{S}st_{k}ch_wallclock_ratio", ratio,
         f"joint={joint_med:.0f}us;greedy={greedy_med:.0f}us")

    imp = 100.0 * (1.0 - joint.makespan_mu / greedy.makespan_mu)
    emit(f"dag_scale_{S}st_{k}ch_improvement_pct", imp,
         f"joint={joint.makespan_mu:.4f};greedy={greedy.makespan_mu:.4f}")

    mc_joint = _mc_makespan(dag, joint.weights, trials)
    mc_greedy = _mc_makespan(dag, greedy.weights, trials)
    mc_imp = 100.0 * (1.0 - mc_joint[0] / mc_greedy[0])
    emit(f"dag_scale_{S}st_{k}ch_realized_improvement_pct", mc_imp,
         f"trials={trials}")

    scale = _scale_point(smoke, rows)

    save_table("dag_scale_smoke.csv" if smoke else "dag_scale.csv",
               "S,K,num_t,path,us", rows)
    return {
        "bench": "dag_scale",
        "smoke": smoke,
        "stages": S,
        "channels": k,
        "joint": {"makespan_mu": joint.makespan_mu,
                  "makespan_var": joint.makespan_var,
                  "mc_makespan_mu": mc_joint[0],
                  "mc_makespan_var": mc_joint[1],
                  "method": joint.method},
        "greedy": {"makespan_mu": greedy.makespan_mu,
                   "makespan_var": greedy.makespan_var,
                   "mc_makespan_mu": mc_greedy[0],
                   "mc_makespan_var": mc_greedy[1],
                   "method": greedy.method},
        "improvement_pct": round(imp, 4),
        "realized_improvement_pct": round(mc_imp, 4),
        "family_groups": joint.family_groups,
        "single_batched_path": joint.family_groups == 1,
        "joint_phase_us": joint_phase,
        "joint_vs_greedy_wallclock_ratio": round(ratio, 4),
        "scale_point": scale,
        "entries": _JSON_ENTRIES,
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", action="store_true",
                    help="emit machine-readable BENCH_dag_scale.json")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced scale (8 stages, K=32) for smoke runs")
    ap.add_argument("--out", default=None,
                    help="JSON output path (default: repo-root "
                         "BENCH_dag_scale.json, or _smoke variant)")
    args = ap.parse_args()

    res = run(smoke=args.smoke)
    if args.json:
        root = os.path.join(os.path.dirname(__file__), "..")
        default = ("BENCH_dag_scale_smoke.json" if args.smoke
                   else "BENCH_dag_scale.json")
        path = args.out or os.path.abspath(os.path.join(root, default))
        with open(path, "w") as f:
            json.dump(res, f, indent=1, sort_keys=True)
        print(f"wrote {path}")
    print({key: res[key] for key in ("improvement_pct",
                                     "realized_improvement_pct",
                                     "joint_vs_greedy_wallclock_ratio",
                                     "family_groups")})
    if not args.smoke:
        # acceptance gates LAST, after every artifact is on disk: the joint
        # solve must beat graph-blind greedy on expected makespan AND
        # wall-clock, through a single batched stage-moment path (smoke
        # scale is solve-starved — the margins only mean anything at the
        # tracked full scale)
        assert res["single_batched_path"], res["family_groups"]
        assert res["improvement_pct"] >= 0.088, res["improvement_pct"]
        assert res["joint_vs_greedy_wallclock_ratio"] <= 1.0, \
            res["joint_vs_greedy_wallclock_ratio"]
        assert res["scale_point"]["stages"] == 512, res["scale_point"]


if __name__ == "__main__":
    main()
