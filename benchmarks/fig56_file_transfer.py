"""Paper Figures 5 & 6: dual-path file transmission (NY->SG direct vs
NY->London->SG overlay), 20 024 trials with randomized f over 72 hours.

The two WAN paths are simulated channels with Normal per-unit-transfer times
(the paper's Fig 5 validates exactly this normality on the real Internet).
We replicate the protocol: per trial draw f uniformly from {0, 0.1, ..., 1},
transfer the two shards in parallel, record the join time; then:
  * Fig 5: normality check of the f=0.5 histogram (moment tests),
  * Fig 6: empirical mu(f), sigma^2(f) vs the theory curves from repro.core.
"""
import numpy as np

from .common import emit, save_table, timeit


def run() -> dict:
    from repro.core import curve_2ch
    from repro.sim import Channel, ClusterSim

    # path stats (sec per file): direct Pacific path faster but jittery at
    # peak hours; Europe overlay slower but steadier. Chosen so that at
    # f=0.5 one path clearly bottlenecks — the regime in which the paper's
    # Fig 5 observed Normal join times (max of well-separated normals).
    MU_I, SG_I = 26.0, 1.6    # NY -> London -> SG overlay
    MU_J, SG_J = 16.0, 3.0    # NY -> SG via Pacific
    sim = ClusterSim([Channel(MU_I, SG_I), Channel(MU_J, SG_J)], seed=42)

    fs = np.round(np.arange(0.0, 1.01, 0.1), 2)
    rng = np.random.default_rng(7)
    samples = {f: [] for f in fs}
    for _ in range(20_024):                       # the paper's trial count
        f = fs[rng.integers(0, len(fs))]
        t, _ = sim.run_step([f, 1 - f])
        samples[f].append(t)

    # Fig 5: f=0.5 completion times approximately Normal (skew/kurtosis small)
    h = np.asarray(samples[0.5])
    skew = float(np.mean(((h - h.mean()) / h.std()) ** 3))
    kurt = float(np.mean(((h - h.mean()) / h.std()) ** 4) - 3.0)
    assert abs(skew) < 0.35 and abs(kurt) < 0.6, (skew, kurt)
    save_table("fig5_hist_f05.csv", "t", [(x,) for x in h])

    # Fig 6: empirical vs theoretical moments
    th_f, th_mu, th_var = curve_2ch(MU_I, SG_I, MU_J, SG_J, num_f=11)
    rows = []
    max_rel_mu = 0.0
    for i, f in enumerate(fs):
        e_mu, e_var = np.mean(samples[f]), np.var(samples[f])
        t_mu, t_var = float(th_mu[i]), float(th_var[i])
        rows.append((f, e_mu, e_var, t_mu, t_var, len(samples[f])))
        if t_mu > 0:
            max_rel_mu = max(max_rel_mu, abs(e_mu - t_mu) / t_mu)
    save_table("fig6_file_transfer.csv",
               "f,emp_mu,emp_var,theory_mu,theory_var,n", rows)
    assert max_rel_mu < 0.05, f"empirical mu deviates {max_rel_mu:.1%} from theory"

    e_mus = np.array([r[1] for r in rows])
    e_vars = np.array([r[2] for r in rows])
    assert e_mus.min() < min(e_mus[0], e_mus[-1])    # paper's headline again
    assert e_vars.min() < min(e_vars[0], e_vars[-1])

    us = timeit(lambda: [sim.run_step([0.5, 0.5]) for _ in range(100)], repeats=3)
    emit("fig56_transfer_100trials", us,
         f"skew={skew:.3f};kurt={kurt:.3f};max_rel_mu_err={max_rel_mu:.3f}")
    return {"skew": skew, "kurt": kurt, "max_rel_mu_err": max_rel_mu}


if __name__ == "__main__":
    print(run())
