"""Benchmark harness — one module per paper table/figure + beyond-paper.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks.common.emit) and
writes detailed tables under experiments/bench/.

    PYTHONPATH=src python -m benchmarks.run [--only fig1,cluster]
"""
import argparse
import sys
import traceback

from . import (cluster_scale, fig1_theory, fig2_frontier, fig34_convex_opt,
               fig56_file_transfer, partitioned_training, roofline_table)

SUITES = {
    "fig1": fig1_theory,
    "fig2": fig2_frontier,
    "fig34": fig34_convex_opt,
    "fig56": fig56_file_transfer,
    "cluster": cluster_scale,
    "parttrain": partitioned_training,
    "roofline": roofline_table,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    chosen = [s.strip() for s in args.only.split(",") if s.strip()] or list(SUITES)
    print("name,us_per_call,derived")
    failures = []
    for name in chosen:
        try:
            SUITES[name].run()
        except Exception as e:  # noqa: BLE001 — report, keep going
            failures.append((name, e))
            traceback.print_exc()
    if failures:
        print(f"FAILED suites: {[n for n, _ in failures]}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
