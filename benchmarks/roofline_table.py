"""Aggregate the dry-run JSON records into the EXPERIMENTS.md roofline table.

Reads experiments/dryrun/*.json (written by repro.launch.dryrun) and emits a
CSV + markdown table with the three roofline terms, dominant bottleneck,
MODEL_FLOPS ratio and memory analysis per (arch x shape x mesh).
"""
import glob
import json
import os

from .common import emit, save_table

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def load_records():
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def run() -> dict:
    recs = load_records()
    rows = []
    ok = skipped = failed = 0
    for r in recs:
        if r["status"] == "skipped":
            skipped += 1
            rows.append((r["arch"], r["shape"], r["mesh"], "SKIP",
                         "", "", "", "", "", r.get("reason", "")))
            continue
        if r["status"] != "ok":
            failed += 1
            rows.append((r["arch"], r["shape"], r["mesh"], "FAIL",
                         "", "", "", "", "", r.get("error", "")[:80]))
            continue
        ok += 1
        t = r["roofline"]
        rows.append((
            r["arch"], r["shape"], r["mesh"], "ok",
            f"{t['compute_s']:.4f}", f"{t['memory_s']:.4f}",
            f"{t['collective_s']:.4f}", t["dominant"].replace("_s", ""),
            f"{t['roofline_fraction']:.4f}",
            f"{r.get('useful_flops_ratio') or 0:.3f}",
        ))
    path = save_table(
        "roofline_table.csv",
        "arch,shape,mesh,status,compute_s,memory_s,collective_s,dominant,"
        "roofline_fraction,useful_flops_ratio", rows)
    emit("roofline_cells_ok", float(ok), f"skipped={skipped};failed={failed}")
    assert failed == 0, f"{failed} dry-run cells failed"
    return {"ok": ok, "skipped": skipped, "failed": failed, "table": path}


if __name__ == "__main__":
    print(run())
