"""Failure-trace benchmark: failure-aware vs failure-blind partitioning.

The fleet is heterogeneous AND flaky: every channel's attempts fail with a
per-channel probability (drawn around ~8% mean — "churn" here is attempt
churn, the retry physics the ``defective`` family prices). Two solvers get
the SAME true base statistics (no estimation noise — the comparison isolates
the pricing model):

* **blind** — solves the frontier under the normal family: it sees the mean
  and spread of a clean attempt and nothing else, so it loads flaky channels
  as if they were reliable;
* **aware** — solves under ``Defective(p, pricing="retry")``: the
  geometric-retry inflation of both mean and variance is inside the
  survival integral, so flaky channels are discounted *before* the first
  failure is observed.

Both weight vectors then replay the IDENTICAL seeded trace (per-tick
Generator seeded ``(seed, tick)``, shared across policies) through the
defective-regime ``ClusterSim``; the realized per-tick join time is the
score. The gap is the price of ignoring failure physics — the fault-domain
twin of fig2's frontier-vs-uniform gap.

``--json`` writes ``BENCH_fault_trace.json`` (schema: bench / smoke / ticks
/ channels / mean_fail_p / makespan{blind,aware}{mean,var,p50,p99} /
improvement_pct / entries); ``scripts/bench_smoke.sh`` runs the small config
and asserts the aware solver wins, ``scripts/ci.sh`` asserts the schema.
"""
import argparse
import json
import os

import numpy as np

from .common import emit, save_table

CHANNELS = 12
TICKS = 300
FAIL_RANGE = (0.02, 0.15)   # per-channel attempt-failure probs (mean ~8.5%)
LAM = 0.05                  # frontier risk weight (same for both policies)

# the machine-readable contract of BENCH_fault_trace*.json — declared next
# to the writer; scripts/ci.sh imports these to validate the emitted files
SCHEMA_KEYS = ("bench", "smoke", "ticks", "channels", "mean_fail_p",
               "makespan", "improvement_pct", "entries")
ENTRY_KEYS = ("name", "policy", "ticks", "mean_s", "var_s2", "p99_s")


def run(ticks: int = TICKS, channels: int = CHANNELS, seed: int = 0,
        smoke: bool = False) -> dict:
    from repro.core.distributions import Defective
    from repro.core.partitioner import optimize_weights
    from repro.sim import ClusterSim

    sim = ClusterSim.heterogeneous(channels, seed=seed, dist="defective",
                                   fail_range=FAIL_RANGE)
    mus, sigmas = sim.true_params
    p = np.array([c.fail_p for c in sim.channels])

    w_blind = optimize_weights(mus, sigmas, lam=LAM,
                               family="normal").weights
    w_aware = optimize_weights(mus, sigmas, lam=LAM,
                               family=Defective(p.astype(np.float32),
                                                pricing="retry")).weights

    joins = {"blind": [], "aware": []}
    rows = []
    for t in range(ticks):
        # one Generator per (policy, tick), seeded identically: both
        # policies face the exact same rate + retry draws each tick
        jb = sim.run_step(w_blind, rng=np.random.default_rng((seed, t)))[0]
        ja = sim.run_step(w_aware, rng=np.random.default_rng((seed, t)))[0]
        joins["blind"].append(jb)
        joins["aware"].append(ja)
        rows.append((t, round(jb, 6), round(ja, 6)))

    stats = {}
    for name, xs in joins.items():
        xs = np.asarray(xs)
        stats[name] = {"mean": float(xs.mean()), "var": float(xs.var()),
                       "p50": float(np.percentile(xs, 50)),
                       "p99": float(np.percentile(xs, 99))}
    improvement = 100.0 * (stats["blind"]["mean"] - stats["aware"]["mean"]) \
        / stats["blind"]["mean"]
    save_table("fault_trace_smoke.csv" if smoke else "fault_trace.csv",
               "tick,join_blind,join_aware", rows)
    out = {
        "bench": "fault_trace",
        "smoke": smoke,
        "ticks": ticks,
        "channels": channels,
        "mean_fail_p": float(p.mean()),
        "makespan": stats,
        "improvement_pct": float(improvement),
        "entries": [
            {"name": f"fault_trace_{name}", "policy": name, "ticks": ticks,
             "mean_s": stats[name]["mean"], "var_s2": stats[name]["var"],
             "p99_s": stats[name]["p99"]}
            for name in ("blind", "aware")
        ],
    }
    emit("fault_trace_improvement_pct", float(improvement),
         f"ticks={ticks};channels={channels};mean_p={p.mean():.3f}")
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", action="store_true",
                    help="emit machine-readable BENCH_fault_trace.json")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced scale (fewer ticks) for smoke runs")
    ap.add_argument("--ticks", type=int, default=None)
    ap.add_argument("--channels", type=int, default=CHANNELS)
    ap.add_argument("--out", default=None,
                    help="JSON output path (default: repo-root "
                         "BENCH_fault_trace.json, or _smoke variant)")
    args = ap.parse_args()

    ticks = args.ticks or (80 if args.smoke else TICKS)
    res = run(ticks=ticks, channels=args.channels, smoke=args.smoke)
    if args.json:
        root = os.path.join(os.path.dirname(__file__), "..")
        default = ("BENCH_fault_trace_smoke.json" if args.smoke
                   else "BENCH_fault_trace.json")
        path = args.out or os.path.abspath(os.path.join(root, default))
        with open(path, "w") as f:
            json.dump(res, f, indent=1, sort_keys=True)
        print(f"wrote {path}")
    print({k: res[k] for k in ("makespan", "improvement_pct",
                               "mean_fail_p")})


if __name__ == "__main__":
    main()
