"""Shared benchmark utilities: timing + CSV emission."""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "bench")


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.2f},{derived}")


def timeit(fn, *args, repeats: int = 5, warmup: int = 2) -> float:
    """Median wall-time per call in microseconds (fn must block)."""
    return timeit_stats(fn, *args, repeats=repeats, warmup=warmup)[0]


def timeit_stats(fn, *args, repeats: int = 5, warmup: int = 2):
    """(median_us, p90_us) wall-time per call (fn must block).

    p90 is what the perf-trajectory JSON tracks: scheduler ticks sit on the
    step critical path, so the tail matters as much as the median.
    """
    for _ in range(warmup):
        fn(*args)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn(*args)
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    p90 = times[min(len(times) - 1, int(round(0.9 * (len(times) - 1))))]
    return times[len(times) // 2], p90


def save_table(fname: str, header: str, rows) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, fname)
    with open(path, "w") as f:
        f.write(header + "\n")
        for r in rows:
            f.write(",".join(str(x) for x in r) + "\n")
    return path
