"""Beyond-paper: K-channel partitioning at fleet scale (64 / 256 / 1024
channels) with online Bayesian estimation, straggler injection and elastic
recovery — the 1000-node operating regime the framework targets.

Three sections:

1. Policy comparison on realized join-time mean / variance / p99:
     equal        — map-reduce style uniform split (paper's foil),
     inverse_mu   — deterministic load balance (ignores variance),
     frontier     — the paper's mean-variance partitioner (K-channel PGD,
                    warm-started between refresh ticks).
   Also benchmarks the scheduler tick cost (posterior update + re-partition)
   at each fleet size — the number that must stay off the step critical path.

2. Rebalance-tick FORWARD kernel comparison at K=1024 channels x F=4096
   candidate splits: the legacy vmap-over-``max_moments_quad`` path (which
   materializes the (F, T, K) survival grid in HBM — it cannot even run
   unchunked at this size) against the batched ``ops.frontier_moments`` path.

3. Rebalance-tick PGD comparison (forward + gradient) at the same scale: the
   PR 1 objective — jax.grad autodiff-replayed through the chunked quadrature
   — against the fused analytic-VJP launch (``frontier_moments_with_grads``).
   This is the number the custom-VJP work buys; the acceptance bar is the
   fused path >= 1.5x the autodiff path at equal num_t.

4. Family ticks: the same K=1024 x F=4096 forward + fused launches under the
   ``lognormal`` and ``drift`` completion-time families (heavy-tailed WAN
   regimes and straggler-aware frontiers) — the scenario-diverse numbers the
   distribution-generic stack buys. Entries carry a ``family`` field.

5. Auto-family tick: the closed estimation loop's tick cost — BIC-score the
   observed (rate, work) history across all K channels (vectorized fits,
   batch GMM EM included), instantiate the winner, run the fused solve under
   it — vs the identical fused solve with the family fixed up front.
   Acceptance: within 1.2x (``auto_family_tick_overhead`` in the JSON).

``--json`` additionally writes machine-readable ``BENCH_cluster_scale.json``
(median/p90 per tick, impl, block_f, family, speedups) at the repo root so
the perf trajectory is tracked from this PR on; ``scripts/bench_smoke.sh``
runs the tick sections at reduced scale.
"""
import argparse
import json
import os
import time

import numpy as np

from .common import emit, save_table, timeit_stats

TICK_K = 1024      # channels per rebalance tick (fleet size)
TICK_F = 4096      # candidate splits per tick
TICK_T = 256       # survival-integral points per candidate
VMAP_CHUNK = 512   # legacy path OOMs beyond this (4 GB+ intermediates)
PGD_LAM = 0.05     # scalarization weight in the PGD-tick objective
TICK_FAMILIES = ("lognormal", "drift")  # non-normal fleet-tick regimes

# the machine-readable contract of BENCH_cluster_scale*.json — declared next
# to the writer; scripts/ci.sh imports these to validate the emitted files
SCHEMA_KEYS = ("bench", "smoke", "pgd_speedup_vs_autodiff",
               "auto_family_tick_overhead", "entries")
ENTRY_KEYS = ("name", "impl", "K", "F", "num_t", "family", "median_us",
              "p90_us", "repeats")

_JSON_ENTRIES = []


def _record(name, impl, block_f, num_k, num_f, num_t, med_us, p90_us,
            repeats, family="normal"):
    # repeats is recorded because p90 of 1-2 samples is just the max/only
    # sample — trajectory readers need to know how much tail is in the tail
    _JSON_ENTRIES.append({
        "name": name, "impl": impl, "block_f": block_f, "K": num_k,
        "F": num_f, "num_t": num_t, "family": family,
        "median_us": round(med_us, 2),
        "p90_us": round(p90_us, 2), "repeats": repeats})


def _make_bench(rows, prefix, emit_prefix, num_k, num_f, num_t,
                family="normal"):
    """Shared timing/record closure for the tick sections: times a blocking
    thunk, appends the CSV row, records the JSON entry and emits the line."""
    import jax

    def bench(name, impl, block_f, fn, repeats=2):
        result = {}

        def once():  # keep the last timed output: no extra eval to fetch it
            result["v"] = jax.block_until_ready(fn())

        med, p90 = timeit_stats(once, repeats=repeats, warmup=1)
        rows.append((num_k, num_f, num_t, f"{prefix}{name}", med))
        _record(f"{prefix}{name}", impl, block_f, num_k, num_f, num_t,
                med, p90, repeats, family=family)
        emit(f"{emit_prefix}{num_k}ch_{num_f}cand_{name}", med)
        return result["v"]

    return bench


def _run_policy(n, policy, steps=120, seed=0, inject=True, dist="normal",
                family="normal"):
    from repro.sched import UncertaintyAwareBalancer
    from repro.sim import ClusterSim

    sim = ClusterSim.heterogeneous(n, seed=seed, dist=dist)
    bal = UncertaintyAwareBalancer(n, lam=0.02, policy=policy, family=family,
                               refresh_every=(1 if n <= 64 else 10),
                               pgd_steps=(150 if n <= 256 else 60))
    times = []
    tick_costs = []
    for i in range(steps):
        t0 = time.perf_counter()
        w = bal.weights()
        tick_costs.append(time.perf_counter() - t0)
        t, durs = sim.run_step(w)
        bal.observe(durs, w)
        if inject and i == steps // 2:
            sim.inject_slowdown(0, 3.0)   # mid-run hotspot on channel 0
        if i >= 30:
            times.append(t)
    times = np.asarray(times)
    return (times.mean(), times.var(), np.percentile(times, 99),
            np.mean(tick_costs) * 1e6)


def _tick_problem(num_k, num_f, seed=0):
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    e = rng.exponential(size=(num_f, num_k))
    W = jnp.asarray(e / e.sum(1, keepdims=True), jnp.float32)
    mus = jnp.asarray(rng.uniform(10, 40, num_k), jnp.float32)
    sgs = jnp.asarray(mus * rng.uniform(0.02, 0.3, num_k), jnp.float32)
    return W, mus, sgs


def tick_kernel_compare(num_k=TICK_K, num_f=TICK_F, num_t=TICK_T,
                        with_interpret=True):
    """One rebalance tick's FORWARD candidate sweep, three ways."""
    import jax
    import jax.numpy as jnp

    from repro.core.maxstat import max_moments_quad
    from repro.kernels import autotune, ops

    W, mus, sgs = _tick_problem(num_k, num_f)
    rows = []
    bench = _make_bench(rows, "fwd_tick_", "tick_", num_k, num_f, num_t)

    # legacy: vmap the survival-integral oracle over candidates. Materializes
    # (F, T, K); at 4096x256x1024 that is >4 GB per intermediate, so it MUST
    # be driven in chunks — the HBM bounce the kernel removes.
    vq = jax.jit(jax.vmap(lambda w: max_moments_quad(w * mus, w * sgs,
                                                     num=num_t)))
    chunk = min(VMAP_CHUNK, num_f)

    def vmap_quad():
        outs = [vq(W[i:i + chunk]) for i in range(0, num_f, chunk)]
        return (jnp.concatenate([o[0] for o in outs]),
                jnp.concatenate([o[1] for o in outs]))

    mu_ref, var_ref = bench(f"vmap_quad_chunked{chunk}", "xla", chunk,
                            vmap_quad)

    impls = ["xla"] + (["pallas_interpret"] if with_interpret else [])
    for impl in impls:
        bf = autotune.lookup(num_f, num_k, num_t, backend=impl, fused=False)
        f = jax.jit(lambda W, impl=impl, bf=bf: ops.frontier_moments(
            W, mus, sgs, num_t=num_t, impl=impl, block_f=bf))
        repeats = 1 if impl == "pallas_interpret" else 2
        mu_i, var_i = bench(impl, impl, bf, lambda: f(W), repeats=repeats)
        # same tick, same numbers: the kernel is a faster route to the same
        # frontier, not a different approximation (grids differ slightly from
        # the shared-grid oracle; 1e-2 relative is the documented agreement)
        np.testing.assert_allclose(np.asarray(mu_i), np.asarray(mu_ref),
                                   rtol=1e-2)
        np.testing.assert_allclose(np.asarray(var_i), np.asarray(var_ref),
                                   rtol=5e-2, atol=1e-3)
    return rows


def tick_pgd_compare(num_k=TICK_K, num_f=TICK_F, num_t=TICK_T,
                     with_interpret=False, sweep=True):
    """One PGD tick (forward + gradient over the candidate block), two ways:

    autodiff_quad — PR 1's objective: jax.grad through the chunked-quadrature
                    forward (full autodiff replay of the survival integral);
    fused_<impl>  — the analytic-adjoint launch returning
                    (mu, var, dmu_dW, dvar_dW) in one pass.
    """
    import jax
    import jax.numpy as jnp

    from repro.kernels import autotune, ops, ref

    W, mus, sgs = _tick_problem(num_k, num_f)
    rows = []

    # autotune sweep for the fused xla tick (persists to the JSON cache); the
    # PGD gradient is the latency budget, so this is the shape worth timing
    if sweep:
        entry = autotune.sweep(num_f, num_k, num_t, backend="xla", fused=True,
                               repeats=1,
                               candidates=(64, 128, 256))
        emit(f"autotune_fused_xla_F{num_f}_K{num_k}_T{num_t}",
             entry["us"], f"block_f={entry['block_f']}")

    bf_auto = autotune.lookup(num_f, num_k, num_t, backend="xla", fused=False)

    # PR 1 baseline: grad of the scalarized objective through the chunked
    # quadrature graph (rows independent => grad-of-sum is per-row grads).
    # Uses the pristine ref path: the custom VJP must not help it.
    def legacy_obj(W):
        pad = (-num_f) % bf_auto
        Wp = jnp.concatenate([W, jnp.tile(W[:1], (pad, 1))], 0) if pad else W
        blocks = Wp.reshape(-1, bf_auto, num_k)
        mu, var = jax.lax.map(
            lambda wb: ref.frontier_grid_ref(wb, mus, sgs, num_t=num_t),
            blocks)
        mu, var = mu.reshape(-1)[:num_f], var.reshape(-1)[:num_f]
        return jnp.sum(mu + PGD_LAM * var)

    autodiff_tick = jax.jit(jax.grad(legacy_obj))

    bench = _make_bench(rows, "pgd_tick_", "pgd_tick_", num_k, num_f, num_t)
    g_auto = bench("autodiff_quad", "xla", bf_auto,
                   lambda: autodiff_tick(W))

    impls = ["xla"] + (["pallas_interpret"] if with_interpret else [])
    fused_meds = {}
    for impl in impls:
        bf = autotune.lookup(num_f, num_k, num_t, backend=impl, fused=True)
        fused = jax.jit(lambda W, impl=impl, bf=bf:
                        ops.frontier_moments_with_grads(
                            W, mus, sgs, num_t=num_t, impl=impl, block_f=bf))
        repeats = 1 if impl == "pallas_interpret" else 2
        outs = bench(f"fused_{impl}", impl, bf, lambda: fused(W),
                     repeats=repeats)
        fused_meds[impl] = rows[-1][4]
        g_fused = np.asarray(outs[2]) + PGD_LAM * np.asarray(outs[3])
        # the speedup must not come from computing a different gradient
        rel = (np.linalg.norm(g_fused - np.asarray(g_auto))
               / np.linalg.norm(np.asarray(g_auto)))
        emit(f"pgd_tick_grad_parity_{impl}", rel * 1e6, "norm_rel_x1e6")
        assert rel <= 1e-4, f"gradient parity broke on {impl}: {rel}"
    if not with_interpret:
        emit("pgd_tick_fused_pallas_interpret", 0.0,
             "SKIPPED full scale (interpreter-only backend; smoke covers it)")

    auto_med = next(r[4] for r in rows if r[3] == "pgd_tick_autodiff_quad")
    speedup = auto_med / fused_meds["xla"]
    emit(f"pgd_tick_{num_k}ch_{num_f}cand_speedup", speedup,
         "fused_xla_vs_autodiff")
    return rows, speedup


def tick_family_compare(num_k=TICK_K, num_f=TICK_F, num_t=TICK_T,
                        families=TICK_FAMILIES):
    """Fleet ticks under the non-normal completion-time families.

    For each family: the forward candidate sweep and the fused
    moments+gradient launch at full fleet scale (xla backend) — lognormal is
    the heavy-tailed WAN/file-transfer regime, drift the straggler-aware
    frontier (per-channel drift rates on ~3% of the fleet, the mixed-fleet
    shape the straggler policy produces). Gradient parity vs autodiff through
    the family quadrature is asserted at every scale, so the family speed
    numbers are for the SAME gradients a replayed autodiff would produce.
    """
    import jax
    import jax.numpy as jnp

    from repro.core.distributions import Drift, resolve_family
    from repro.kernels import autotune, ops, ref

    W, mus, sgs = _tick_problem(num_k, num_f)
    rng = np.random.default_rng(11)
    rows = []
    for fam_name in families:
        if fam_name == "drift":
            rho = np.where(rng.random(num_k) < 0.03,
                           rng.uniform(0.5, 2.0, num_k), 0.0)
            family = Drift(rho.astype(np.float32))
        else:
            family = fam_name
        dist_id, extra = resolve_family(family, num_k)
        extra = jnp.asarray(extra, jnp.float32)
        bench = _make_bench(rows, f"{fam_name}_tick_", "fam_tick_", num_k,
                            num_f, num_t, family=fam_name)

        bf_fwd = autotune.lookup(num_f, num_k, num_t, backend="xla",
                                 fused=False, dist_id=dist_id)
        fwd = jax.jit(lambda W, bf=bf_fwd: ops.frontier_moments(
            W, mus, sgs, num_t=num_t, impl="xla", block_f=bf,
            family=(dist_id, extra)))
        bench("fwd_xla", "xla", bf_fwd, lambda: fwd(W))

        bf_fused = autotune.lookup(num_f, num_k, num_t, backend="xla",
                                   fused=True, dist_id=dist_id)
        fused = jax.jit(lambda W, bf=bf_fused: ops.frontier_moments_with_grads(
            W, mus, sgs, num_t=num_t, impl="xla", block_f=bf,
            family=(dist_id, extra)))
        outs = bench("fused_xla", "xla", bf_fused, lambda: fused(W))

        # parity spot-check vs autodiff through the family quadrature on a
        # candidate slice (full-batch autodiff at F=4096 is the 49 s legacy
        # tick — the normal-family section already times that axis)
        ns = min(num_f, 64)
        Ws = W[:ns]
        dmu_a = jax.grad(lambda Wx: jnp.sum(ref.frontier_grid_ref(
            Wx, mus, sgs, num_t=num_t, dist_id=dist_id, extra=extra)[0]))(Ws)
        g_fused = np.asarray(outs[2])[:ns]
        rel = (np.linalg.norm(g_fused - np.asarray(dmu_a))
               / np.linalg.norm(np.asarray(dmu_a)))
        emit(f"fam_tick_grad_parity_{fam_name}", rel * 1e6, "norm_rel_x1e6")
        assert rel <= 1e-4, f"family gradient parity broke on {fam_name}: {rel}"
    return rows


def tick_auto_family_compare(num_k=TICK_K, num_f=TICK_F, num_t=TICK_T,
                             window=96):
    """One ``family="auto"`` rebalance tick vs the fixed-family fused solve.

    The auto tick is everything the closed loop adds on the tick path: BIC-
    score the (rate, work) history (vectorized fits — batch GMM EM included)
    across all K channels, instantiate the winning family, THEN run the
    fused moments+gradient launch under it. The baseline runs the identical
    launch with the family fixed up front. Acceptance: auto within 1.2x of
    fixed — model selection must ride the tick, not dominate it.
    """
    import jax
    import jax.numpy as jnp

    from repro.core.bayes import fit_selected_family, score_families
    from repro.core.distributions import lognormal_shape_np, resolve_family
    from repro.kernels import autotune, ops

    W, mus, sgs = _tick_problem(num_k, num_f)
    rng = np.random.default_rng(7)
    # lognormal-generated history: the selector has a real (non-default)
    # family to find, so the scoring pass does full work
    mu_h = np.asarray(mus, np.float64)
    sg_h = mu_h * rng.uniform(0.25, 0.5, num_k)
    s_l, base = lognormal_shape_np(mu_h, sg_h)
    rates = rng.lognormal(base, s_l, size=(window, num_k)).astype(np.float32)
    works = rng.uniform(0.5 / num_k, 2.0 / num_k,
                        size=(window, num_k)).astype(np.float32)
    mask = np.ones((window, num_k), np.float32)

    rows = []
    bench = _make_bench(rows, "auto_tick_", "auto_tick_", num_k, num_f,
                        num_t, family="auto")

    # fixed-family baseline: family resolved once, outside the tick
    fixed_fam = fit_selected_family(score_families(rates, works, mask))
    dist_id, extra = resolve_family(fixed_fam, num_k)
    extra = jnp.asarray(extra, jnp.float32)
    bf = autotune.lookup(num_f, num_k, num_t, backend="xla", fused=True,
                         dist_id=dist_id)
    fused = jax.jit(lambda W, ex, bf=bf: ops.frontier_moments_with_grads(
        W, mus, sgs, num_t=num_t, impl="xla", block_f=bf,
        family=(dist_id, ex)))
    bench(f"fixed_{dist_id}_fused_xla", "xla", bf, lambda: fused(W, extra))
    fixed_med = rows[-1][4]

    def auto_tick():
        scores = score_families(rates, works, mask)
        fam = fit_selected_family(scores)
        d_id, ex = resolve_family(fam, num_k)
        assert d_id == dist_id  # same winner -> same compiled kernel
        return fused(W, jnp.asarray(ex, jnp.float32))

    bench("score_plus_fused_xla", "xla", bf, auto_tick)
    auto_med = rows[-1][4]
    ratio = auto_med / fixed_med
    emit(f"auto_tick_{num_k}ch_{num_f}cand_overhead", ratio,
         f"auto_vs_fixed_{dist_id};accept<=1.2")
    return rows, ratio


def run(smoke=False, ticks_only=False, with_interpret=None) -> dict:
    rows = []
    out = {}
    if not ticks_only:
        for n in (64, 256, 1024):
            for policy in ("equal", "inverse_mu", "frontier"):
                steps = 120 if n <= 256 else 60
                mu, var, p99, tick_us = _run_policy(n, policy, steps=steps)
                rows.append((n, policy, mu, var, p99, tick_us))
                out[(n, policy)] = (mu, var, p99)
                emit(f"cluster_{n}ch_{policy}", tick_us,
                     f"join_mu={mu:.3f};join_var={var:.4f};p99={p99:.3f}")
        # family-matched fleets: sim generates lognormal / drifting ground
        # truth, the frontier solves under the SAME family (the
        # scenario-diverse regimes the distribution-generic stack opens).
        # The drift fleet's per-channel rates are unknown to the scheduler,
        # so the solve uses the rho_range midpoint as a drift-aware prior
        # (deployments estimate per-channel rates via StragglerPolicy).
        from repro.core import Drift
        fam_for = {"lognormal": "lognormal", "drift": Drift(0.45)}
        for dist in ("lognormal", "drift"):
            for policy in ("equal", "frontier"):
                mu, var, p99, tick_us = _run_policy(
                    64, policy, steps=100, dist=dist,
                    family=(fam_for[dist] if policy == "frontier"
                            else "normal"))
                rows.append((64, f"{dist}_{policy}", mu, var, p99, tick_us))
                out[(64, f"{dist}_{policy}")] = (mu, var, p99)
                emit(f"cluster_64ch_{dist}_{policy}", tick_us,
                     f"join_mu={mu:.3f};join_var={var:.4f};p99={p99:.3f}")
        save_table("cluster_scale.csv", "n,policy,join_mu,join_var,p99,tick_us",
                   rows)

    if smoke:
        num_k, num_f, num_t = 64, 256, 128
    else:
        num_k, num_f, num_t = TICK_K, TICK_F, TICK_T
    # the interpreted backend is benchmarked at full scale only on the cheap
    # forward tick; the fused interpret tick is smoke-scale (it is a
    # correctness backend — minutes per launch at F=4096 measures nothing)
    interp_fused = smoke if with_interpret is None else with_interpret

    tick_rows = tick_kernel_compare(num_k, num_f, num_t, with_interpret=True)
    pgd_rows, speedup = tick_pgd_compare(num_k, num_f, num_t,
                                         with_interpret=interp_fused)
    fam_rows = tick_family_compare(num_k, num_f, num_t)
    auto_rows, auto_ratio = tick_auto_family_compare(num_k, num_f, num_t)
    # smoke rows go to their own table: they must never clobber the tracked
    # full-scale perf-trajectory CSV
    csv_name = ("cluster_tick_kernel_smoke.csv" if smoke
                else "cluster_tick_kernel.csv")
    save_table(csv_name, "K,F,num_t,path,us_per_tick",
               tick_rows + pgd_rows + fam_rows + auto_rows)

    if not ticks_only:
        for n in (64, 256, 1024):
            eq, fr = out[(n, "equal")], out[(n, "frontier")]
            assert fr[0] < eq[0], f"frontier should beat equal mean at n={n}"
            assert fr[2] < eq[2], f"frontier should beat equal p99 at n={n}"
    return {f"{n}:{p}": out[(n, p)] for n in (64, 256, 1024)
            for p in ("equal", "frontier") if (n, p) in out} | {
                "pgd_speedup_vs_autodiff": speedup,
                "auto_family_tick_overhead": auto_ratio}


def _write_json(path, payload):
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    print(f"wrote {path}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", action="store_true",
                    help="emit machine-readable BENCH_cluster_scale.json")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced scale (K=64, F=256, T=128) for smoke runs")
    ap.add_argument("--ticks-only", action="store_true",
                    help="skip the (slow) policy-comparison section")
    ap.add_argument("--out", default=None,
                    help="JSON output path (default: repo-root "
                         "BENCH_cluster_scale.json, or _smoke variant)")
    args = ap.parse_args()

    res = run(smoke=args.smoke, ticks_only=args.ticks_only)
    if args.json:
        root = os.path.join(os.path.dirname(__file__), "..")
        default = ("BENCH_cluster_scale_smoke.json" if args.smoke
                   else "BENCH_cluster_scale.json")
        path = args.out or os.path.abspath(os.path.join(root, default))
        _write_json(path, {
            "bench": "cluster_scale",
            "smoke": args.smoke,
            "pgd_speedup_vs_autodiff": round(
                res["pgd_speedup_vs_autodiff"], 3),
            "auto_family_tick_overhead": round(
                res["auto_family_tick_overhead"], 3),
            "entries": _JSON_ENTRIES,
        })
    print(res)
    if not args.smoke:
        # acceptance gate LAST, after every artifact is on disk: model
        # selection must ride the tick, not dominate it — but a noisy run
        # should still leave a data point in the trajectory, not a hole
        # (smoke scale is solve-starved; the ratio only means anything at
        # the tracked full scale)
        ratio = res["auto_family_tick_overhead"]
        assert ratio <= 1.2, \
            f"auto-family tick overhead {ratio:.3f}x exceeds the 1.2x bound"


if __name__ == "__main__":
    main()
