"""Beyond-paper: K-channel partitioning at fleet scale (64 / 256 / 1024
channels) with online Bayesian estimation, straggler injection and elastic
recovery — the 1000-node operating regime the framework targets.

Compares policies on realized join-time mean / variance / p99:
  equal        — map-reduce style uniform split (paper's foil),
  inverse_mu   — deterministic load balance (ignores variance),
  frontier     — the paper's mean-variance partitioner (K-channel PGD).
Also benchmarks the scheduler tick cost (posterior update + re-partition) at
each fleet size — the number that must stay off the step critical path.
"""
import time

import numpy as np

from .common import emit, save_table, timeit


def _run_policy(n, policy, steps=120, seed=0, inject=True):
    from repro.sched import UncertaintyAwareBalancer
    from repro.sim import ClusterSim

    sim = ClusterSim.heterogeneous(n, seed=seed)
    bal = UncertaintyAwareBalancer(n, lam=0.02, policy=policy,
                               refresh_every=(1 if n <= 64 else 10),
                               pgd_steps=(150 if n <= 256 else 60))
    times = []
    tick_costs = []
    for i in range(steps):
        t0 = time.perf_counter()
        w = bal.weights()
        tick_costs.append(time.perf_counter() - t0)
        t, durs = sim.run_step(w)
        bal.observe(durs, w)
        if inject and i == steps // 2:
            sim.inject_slowdown(0, 3.0)   # mid-run hotspot on channel 0
        if i >= 30:
            times.append(t)
    times = np.asarray(times)
    return (times.mean(), times.var(), np.percentile(times, 99),
            np.mean(tick_costs) * 1e6)


def run() -> dict:
    rows = []
    out = {}
    for n in (64, 256, 1024):
        for policy in ("equal", "inverse_mu", "frontier"):
            steps = 120 if n <= 256 else 60
            mu, var, p99, tick_us = _run_policy(n, policy, steps=steps)
            rows.append((n, policy, mu, var, p99, tick_us))
            out[(n, policy)] = (mu, var, p99)
            emit(f"cluster_{n}ch_{policy}", tick_us,
                 f"join_mu={mu:.3f};join_var={var:.4f};p99={p99:.3f}")
    save_table("cluster_scale.csv", "n,policy,join_mu,join_var,p99,tick_us", rows)

    for n in (64, 256, 1024):
        eq, fr = out[(n, "equal")], out[(n, "frontier")]
        assert fr[0] < eq[0], f"frontier should beat equal mean at n={n}"
        assert fr[2] < eq[2], f"frontier should beat equal p99 at n={n}"
    return {f"{n}:{p}": out[(n, p)] for n in (64, 256, 1024)
            for p in ("equal", "frontier")}


if __name__ == "__main__":
    print(run())
