"""Beyond-paper: K-channel partitioning at fleet scale (64 / 256 / 1024
channels) with online Bayesian estimation, straggler injection and elastic
recovery — the 1000-node operating regime the framework targets.

Two sections:

1. Policy comparison on realized join-time mean / variance / p99:
     equal        — map-reduce style uniform split (paper's foil),
     inverse_mu   — deterministic load balance (ignores variance),
     frontier     — the paper's mean-variance partitioner (K-channel PGD,
                    warm-started between refresh ticks).
   Also benchmarks the scheduler tick cost (posterior update + re-partition)
   at each fleet size — the number that must stay off the step critical path.

2. Rebalance-tick kernel comparison at K=1024 channels x F=4096 candidate
   splits: the legacy vmap-over-``max_moments_quad`` path (which materializes
   the (F, T, K) survival grid in HBM — it cannot even run unchunked at this
   size) against the batched ``ops.frontier_moments`` path under both the
   "xla" and "pallas_interpret" impls. On real TPU hardware ``impl="pallas"``
   runs the same kernel compiled (follow-up: ROADMAP).
"""
import time

import numpy as np

from .common import emit, save_table, timeit

TICK_K = 1024      # channels per rebalance tick (fleet size)
TICK_F = 4096      # candidate splits per tick
TICK_T = 256       # survival-integral points per candidate
VMAP_CHUNK = 512   # legacy path OOMs beyond this (4 GB+ intermediates)


def _run_policy(n, policy, steps=120, seed=0, inject=True):
    from repro.sched import UncertaintyAwareBalancer
    from repro.sim import ClusterSim

    sim = ClusterSim.heterogeneous(n, seed=seed)
    bal = UncertaintyAwareBalancer(n, lam=0.02, policy=policy,
                               refresh_every=(1 if n <= 64 else 10),
                               pgd_steps=(150 if n <= 256 else 60))
    times = []
    tick_costs = []
    for i in range(steps):
        t0 = time.perf_counter()
        w = bal.weights()
        tick_costs.append(time.perf_counter() - t0)
        t, durs = sim.run_step(w)
        bal.observe(durs, w)
        if inject and i == steps // 2:
            sim.inject_slowdown(0, 3.0)   # mid-run hotspot on channel 0
        if i >= 30:
            times.append(t)
    times = np.asarray(times)
    return (times.mean(), times.var(), np.percentile(times, 99),
            np.mean(tick_costs) * 1e6)


def tick_kernel_compare(num_k=TICK_K, num_f=TICK_F, num_t=TICK_T):
    """One rebalance tick's candidate sweep, three ways. Returns the rows."""
    import jax
    import jax.numpy as jnp

    from repro.core.maxstat import max_moments_quad
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    e = rng.exponential(size=(num_f, num_k))
    W = jnp.asarray(e / e.sum(1, keepdims=True), jnp.float32)
    mus = jnp.asarray(rng.uniform(10, 40, num_k), jnp.float32)
    sgs = jnp.asarray(mus * rng.uniform(0.02, 0.3, num_k), jnp.float32)

    rows = []

    def bench(name, fn, repeats=2):
        result = {}

        def once():  # keep the last timed output: no extra eval to fetch it
            result["v"] = jax.block_until_ready(fn())

        us = timeit(once, repeats=repeats, warmup=1)
        rows.append((num_k, num_f, num_t, name, us))
        emit(f"tick_{num_k}ch_{num_f}cand_{name}", us)
        return result["v"]

    # legacy: vmap the survival-integral oracle over candidates. Materializes
    # (F, T, K); at 4096x256x1024 that is >4 GB per intermediate, so it MUST
    # be driven in chunks — the HBM bounce the kernel removes.
    vq = jax.jit(jax.vmap(lambda w: max_moments_quad(w * mus, w * sgs,
                                                     num=num_t)))

    def vmap_quad():
        outs = [vq(W[i:i + VMAP_CHUNK]) for i in range(0, num_f, VMAP_CHUNK)]
        return (jnp.concatenate([o[0] for o in outs]),
                jnp.concatenate([o[1] for o in outs]))

    mu_ref, var_ref = bench(f"vmap_quad_chunked{VMAP_CHUNK}", vmap_quad)

    for impl in ("xla", "pallas_interpret"):
        f = jax.jit(lambda W, impl=impl: ops.frontier_moments(
            W, mus, sgs, num_t=num_t, impl=impl, block_f=256))
        repeats = 1 if impl == "pallas_interpret" else 2
        mu_i, var_i = bench(impl, lambda: f(W), repeats=repeats)
        # same tick, same numbers: the kernel is a faster route to the same
        # frontier, not a different approximation (grids differ slightly from
        # the shared-grid oracle; 1e-2 relative is the documented agreement)
        np.testing.assert_allclose(np.asarray(mu_i), np.asarray(mu_ref),
                                   rtol=1e-2)
        np.testing.assert_allclose(np.asarray(var_i), np.asarray(var_ref),
                                   rtol=5e-2, atol=1e-3)
    return rows


def run() -> dict:
    rows = []
    out = {}
    for n in (64, 256, 1024):
        for policy in ("equal", "inverse_mu", "frontier"):
            steps = 120 if n <= 256 else 60
            mu, var, p99, tick_us = _run_policy(n, policy, steps=steps)
            rows.append((n, policy, mu, var, p99, tick_us))
            out[(n, policy)] = (mu, var, p99)
            emit(f"cluster_{n}ch_{policy}", tick_us,
                 f"join_mu={mu:.3f};join_var={var:.4f};p99={p99:.3f}")
    save_table("cluster_scale.csv", "n,policy,join_mu,join_var,p99,tick_us", rows)

    tick_rows = tick_kernel_compare()
    save_table("cluster_tick_kernel.csv", "K,F,num_t,path,us_per_tick",
               tick_rows)

    for n in (64, 256, 1024):
        eq, fr = out[(n, "equal")], out[(n, "frontier")]
        assert fr[0] < eq[0], f"frontier should beat equal mean at n={n}"
        assert fr[2] < eq[2], f"frontier should beat equal p99 at n={n}"
    return {f"{n}:{p}": out[(n, p)] for n in (64, 256, 1024)
            for p in ("equal", "frontier")}


if __name__ == "__main__":
    print(run())
