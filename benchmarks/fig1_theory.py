"""Paper Figure 1 (a, b): theoretical mu(f) and sigma^2(f) curves.

Reproduces the exact parameterization mu_i=30, sigma_i=2, mu_j=20, sigma_j=6
and validates the paper's qualitative claims:
  * both minima lie far below the best single channel,
  * the minima occur at different f (=> an efficient range, not a point).
Also benchmarks the evaluation cost of the curve (jnp oracle vs the Pallas
frontier kernel in interpret mode — the TPU path's semantics).
"""
import numpy as np

from .common import emit, save_table, timeit


def run() -> dict:
    import jax.numpy as jnp

    from repro.core import frontier_2ch
    from repro.kernels import ops

    res = frontier_2ch(30.0, 2.0, 20.0, 6.0, num_f=201, num_t=2048)
    i_mu, i_var = int(np.argmin(res.mu)), int(np.argmin(res.var))
    rows = list(zip(res.f, res.mu, res.var, res.efficient))
    save_table("fig1_theory.csv", "f,mu,var,efficient", rows)

    # paper-claim assertions
    assert res.mu[i_mu] < 20.0, "partition must beat the fastest channel"
    assert res.var[i_var] < 4.0, "partition must beat the most stable channel"
    assert i_mu != i_var, "mu and var minima at different f (paper Fig 1)"

    def eval_curve():
        W = jnp.stack([jnp.linspace(0, 1, 201), 1 - jnp.linspace(0, 1, 201)], -1)
        # repro: allow[RPA070] paper Fig 1 reproduction — the figure's
        # quadrature is part of what is being reproduced, not a solve knob
        m, v = ops.frontier_moments(W, jnp.array([30.0, 20.0]),
                                    jnp.array([2.0, 6.0]), num_t=2048)
        m.block_until_ready()

    us = timeit(eval_curve, repeats=3, warmup=1)
    emit("fig1_theory_curve_201f", us,
         f"f*mu={res.f[i_mu]:.2f};mu_min={res.mu[i_mu]:.2f};"
         f"f*var={res.f[i_var]:.2f};var_min={res.var[i_var]:.3f}")
    return {"f_mu": float(res.f[i_mu]), "mu_min": float(res.mu[i_mu]),
            "f_var": float(res.f[i_var]), "var_min": float(res.var[i_var])}


if __name__ == "__main__":
    print(run())
