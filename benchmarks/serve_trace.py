"""Serving-path end-to-end benchmark: ``PartitionedBatcher`` under a
synthetic bursty request trace (the ROADMAP "real request traces" item).

The trace is Poisson arrivals whose rate switches between a calm and a burst
regime (two-state Markov chain, seeded); each regime switch also moves the
fleet-wide congestion factor of the simulator (``ClusterSim.set_load``), so
the batcher faces exactly the non-stationarity the closed estimation loop is
for: service statistics that change while the frontier solve is running.

Per tick we drive one batch through the batcher (autotuned ``block_f`` — the
solver resolves its launch shapes through ``kernels.autotune`` whenever
``block_f`` is None), record the join latency, the family the solve ran
under (``family="auto"`` BIC selection with hysteresis) and the batcher's
adaptive refresh cadence, and aggregate latency mean/variance per regime.

``--json`` writes machine-readable ``BENCH_serve_trace.json`` at the repo
root (schema: bench / smoke / ticks / groups / family_mode / latency{mean,
var,p50,p99} / per_family_ticks / regimes{calm,burst}{ticks,latency_mean} /
entries) so the serving-path perf trajectory is tracked alongside
``BENCH_cluster_scale.json``; ``scripts/bench_smoke.sh`` runs the small
config and ``scripts/ci.sh`` asserts the schema keys.
"""
import argparse
import json
import os

import numpy as np

from .common import emit, save_table

GROUPS = 6          # replica groups (channels)
TICKS = 400         # batches driven through the batcher
LAM_CALM = 24.0     # mean requests/tick, calm regime
LAM_BURST = 96.0    # mean requests/tick, burst regime
P_ENTER_BURST = 0.05   # per-tick calm -> burst probability
P_EXIT_BURST = 0.15    # per-tick burst -> calm probability
BURST_LOAD = 1.6    # fleet-wide congestion factor while bursting

# the machine-readable contract of BENCH_serve_trace*.json — declared next
# to the writer; scripts/ci.sh imports these to validate the emitted files
SCHEMA_KEYS = ("bench", "smoke", "ticks", "groups", "family_mode", "latency",
               "per_family_ticks", "regimes", "entries")
ENTRY_KEYS = ("name", "family", "ticks", "mean_s", "var_s2", "p99_s")


def run(ticks: int = TICKS, groups: int = GROUPS, seed: int = 0,
        family="auto", smoke: bool = False) -> dict:
    from repro.serve.engine import PartitionedBatcher, ReplicaGroup
    from repro.sim import ClusterSim

    rng = np.random.default_rng(seed)
    # lognormal ground truth: WAN-ish heavy-tailed service times, the regime
    # where the auto-selector has something real to find
    sim = ClusterSim.heterogeneous(groups, seed=seed, dist="lognormal",
                                   cov_range=(0.2, 0.5))
    batcher = PartitionedBatcher(
        [ReplicaGroup(name=f"g{i}") for i in range(groups)],
        lam=0.02, sim=sim, family=family, adaptive_refresh=True,
        refresh_every=8)

    burst = False
    lat, fams, regimes, rows = [], [], [], []
    for t in range(ticks):
        if burst and rng.random() < P_EXIT_BURST:
            burst = False
            sim.set_load(1.0)
        elif not burst and rng.random() < P_ENTER_BURST:
            burst = True
            sim.set_load(BURST_LOAD)
        lam = LAM_BURST if burst else LAM_CALM
        n_req = max(int(rng.poisson(lam)), 1)
        prompts = np.zeros((n_req, 4), np.int32)   # routing-only batch
        join_t, counts, _ = batcher.run_batch(prompts, execute=False)
        tick = batcher.last_tick
        lat.append(join_t)
        fams.append(tick["family"])
        regimes.append("burst" if burst else "calm")
        rows.append((t, regimes[-1], n_req, tick["family"],
                     round(join_t, 6), tick["effective_refresh"]))

    lat = np.asarray(lat)
    per_family = {f: int(sum(1 for x in fams if x == f)) for f in set(fams)}
    reg = {}
    for name in ("calm", "burst"):
        m = np.asarray([r == name for r in regimes])
        reg[name] = {"ticks": int(m.sum()),
                     "latency_mean": (float(lat[m].mean()) if m.any()
                                      else None)}
    save_table("serve_trace_smoke.csv" if smoke else "serve_trace.csv",
               "tick,regime,requests,family,join_latency,effective_refresh",
               rows)
    family_mode = family if isinstance(family, str) else "instance"
    out = {
        "bench": "serve_trace",
        "smoke": smoke,
        "ticks": ticks,
        "groups": groups,
        "family_mode": family_mode,
        "latency": {
            "mean": float(lat.mean()),
            "var": float(lat.var()),
            "p50": float(np.percentile(lat, 50)),
            "p99": float(np.percentile(lat, 99)),
        },
        "per_family_ticks": per_family,
        "regimes": reg,
        "entries": [
            {"name": "serve_trace_join_latency", "family": family_mode,
             "ticks": ticks, "mean_s": float(lat.mean()),
             "var_s2": float(lat.var()), "p99_s": float(np.percentile(lat, 99))},
        ],
    }
    # simulated-time seconds, NOT wall-clock us: the value matches the name
    emit("serve_trace_latency_mean_s", float(lat.mean()),
         f"ticks={ticks};families={per_family}")
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", action="store_true",
                    help="emit machine-readable BENCH_serve_trace.json")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced scale (fewer ticks) for smoke runs")
    ap.add_argument("--ticks", type=int, default=None)
    ap.add_argument("--groups", type=int, default=GROUPS)
    ap.add_argument("--out", default=None,
                    help="JSON output path (default: repo-root "
                         "BENCH_serve_trace.json, or _smoke variant)")
    args = ap.parse_args()

    ticks = args.ticks or (60 if args.smoke else TICKS)
    res = run(ticks=ticks, groups=args.groups, smoke=args.smoke)
    if args.json:
        root = os.path.join(os.path.dirname(__file__), "..")
        default = ("BENCH_serve_trace_smoke.json" if args.smoke
                   else "BENCH_serve_trace.json")
        path = args.out or os.path.abspath(os.path.join(root, default))
        with open(path, "w") as f:
            json.dump(res, f, indent=1, sort_keys=True)
        print(f"wrote {path}")
    print({k: res[k] for k in ("latency", "per_family_ticks", "regimes")})


if __name__ == "__main__":
    main()
