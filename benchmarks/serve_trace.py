"""Serving-path end-to-end benchmark: the continuous-batching
:class:`~repro.serve.engine.WorkflowEngine` under a bursty request trace.

Traffic is Poisson arrivals over THREE workflow templates spanning three
completion-time families (normal ETL, lognormal training diamond, drifting
media pipeline); the arrival rate switches between a calm and a burst
regime (two-state Markov chain, seeded) and each switch also moves the
fleet-wide congestion factor of every template's sim world
(``WorkflowEngine.set_load``). A stage-addressed churn schedule
(``WorkflowSim.schedule_churn``) throttles and fails channels mid-trace, so
the engine faces non-stationary statistics exactly where the per-instance
estimation heads and the dirty-instance re-solve protocol earn their keep.

The headline number is ``batched_vs_looped_ratio``: at sampled ticks the
engine's actual row set (``engine.last_rows``) is solved twice — once the
engine's way (ONE stacked ``row_pgd_step`` launch per family group) and
once as the per-instance loop this engine replaced (one launch per live
workflow). Both paths are warmed before timing so the ratio compares
steady-state dispatch cost, not compilation. The full-scale run holds >=256
concurrent live instances and ``scripts/ci.sh`` asserts the ratio >= 4
there.

``--json`` writes machine-readable ``BENCH_serve_trace.json`` at the repo
root (schema: ``SCHEMA_KEYS`` below — join-latency percentiles from the
engine's streaming reservoirs, solver-tick wall-clock, rows-per-launch
occupancy, live-instance high-water mark, SLO verdicts, per-regime
latency); ``scripts/bench_smoke.sh`` runs the small config and
``scripts/ci.sh`` asserts the schema keys and the acceptance gates.

Under ``REPRO_TRACE=1`` the run also exports its full cross-layer trace
(``TRACE_serve_trace*.jsonl`` + a Perfetto-loadable ``.perfetto.json``) and
adds a ``trace`` section to the JSON: record counts, the span kinds and
audit event types observed, and the traced-vs-untraced solver wall-clock
overhead the zero-perturbation contract bounds below 5% (ci.sh's ``trace``
tier asserts all of it).
"""
import argparse
import json
import os

import numpy as np

from .common import emit, save_table, timeit

TICKS = 120
SMOKE_TICKS = 24
MAX_LIVE = 320          # live-set capacity (full scale: >=256 held live)
SMOKE_MAX_LIVE = 48
PREFILL = 400           # requests queued before tick 1 fills the live set
SMOKE_PREFILL = 64
LAM_CALM = 24.0         # mean arrivals/tick, calm regime
LAM_BURST = 96.0        # mean arrivals/tick, burst regime
P_ENTER_BURST = 0.05    # per-tick calm -> burst probability
P_EXIT_BURST = 0.15    # per-tick burst -> calm probability
BURST_LOAD = 1.6        # fleet-wide congestion factor while bursting
RATIO_SAMPLES = 3       # ticks whose row set is re-timed batched vs looped
NUM_T = 128

# the machine-readable contract of BENCH_serve_trace*.json — declared next
# to the writer; scripts/ci.sh imports these to validate the emitted files
SCHEMA_KEYS = ("bench", "smoke", "ticks", "templates", "max_live",
               "latency", "solver_tick_us", "rows_per_launch",
               "row_occupancy", "live_instances", "queue_wait_ticks",
               "batched_vs_looped_ratio", "slo", "regimes", "counters",
               "entries")
ENTRY_KEYS = ("name", "family", "ticks", "mean_s", "var_s2", "p99_s")


def _templates() -> dict:
    """Three workflow shapes across three completion-time families."""
    from repro.core.distributions import Drift
    from repro.workflow.dag import Stage, StageDAG, linear_edges

    etl = StageDAG([
        Stage("extract", mus=[1.0, 1.3, 1.7, 2.2, 2.6, 3.0],
              sigmas=[0.20, 0.25, 0.30, 0.40, 0.45, 0.50]),
        Stage("transform", mus=[2.0, 2.4, 3.0, 3.5],
              sigmas=[0.30, 0.35, 0.50, 0.55]),
        Stage("load", mus=[1.1, 1.6, 2.1], sigmas=[0.20, 0.30, 0.35]),
    ], edges=linear_edges(["extract", "transform", "load"]))
    train = StageDAG([
        Stage("prep", mus=[1.5, 1.9, 2.3, 2.8],
              sigmas=[0.30, 0.35, 0.40, 0.50], family="lognormal"),
        Stage("fit_a", mus=[2.5, 3.0, 3.6, 4.2, 4.9],
              sigmas=[0.50, 0.60, 0.70, 0.80, 0.90], family="lognormal"),
        Stage("fit_b", mus=[2.2, 2.8, 3.3, 3.9, 4.5],
              sigmas=[0.45, 0.55, 0.65, 0.75, 0.85], family="lognormal"),
        Stage("merge", mus=[1.2, 1.7, 2.2], sigmas=[0.25, 0.30, 0.40],
              family="lognormal"),
    ], edges=[("prep", "fit_a"), ("prep", "fit_b"),
              ("fit_a", "merge"), ("fit_b", "merge")])
    media = StageDAG([
        Stage("render", mus=[1.8, 2.2, 2.7, 3.2, 3.8, 4.4],
              sigmas=[0.35, 0.40, 0.50, 0.60, 0.70, 0.80],
              family=Drift(0.35)),
        Stage("encode", mus=[1.4, 1.8, 2.3, 2.9],
              sigmas=[0.25, 0.30, 0.40, 0.50], family=Drift(0.20)),
    ], edges=linear_edges(["render", "encode"]))
    return {"etl": etl, "train": train, "media": media}


def _naive_makespan(dag) -> float:
    """Longest path of equal-split stage means — the deadline yardstick."""
    lp = {}
    for name in dag.topo_order:
        s = dag.stages[dag.names.index(name)]
        rel = max((lp[u] for u in dag.predecessors(name)), default=0.0)
        lp[name] = rel + float(np.mean(s.mus)) / s.k
    return max(lp.values())


def _launch_rows(rows, kmax: int, num_t: int, impl: str) -> int:
    """Solve one row set the engine's way: stack, pad to the row bucket,
    ONE ``row_pgd_step`` launch per family group. Mirrors
    ``WorkflowEngine._solve_tick`` so the timed work is the same."""
    from repro.kernels import autotune
    from repro.serve.engine import row_pgd_step
    from repro.workflow.solve import stack_rows

    groups, mask, km = stack_rows(
        [(r.mus, r.sigmas, r.family) for r in rows], kmax=kmax)
    for g in groups:
        n = len(g.idx)
        F = autotune.bucket_rows(n)
        E = g.extra.shape[0]
        W = np.zeros((F, km), np.float32)
        mus = np.zeros((F, km), np.float32)
        sgs = np.zeros((F, km), np.float32)
        ex = np.zeros((E, F, km), np.float32)
        msk = np.zeros((F, km), np.float32)
        lam = np.zeros(F, np.float32)
        for j, ridx in enumerate(g.idx):
            r = rows[ridx]
            W[j, :r.k] = r.w
            msk[j] = mask[ridx]
            lam[j] = r.lam
        mus[:n], sgs[:n], ex[:, :n] = g.mus, g.sigmas, g.extra
        if F > n:
            W[n:], mus[n:], sgs[n:] = W[0], mus[0], sgs[0]
            ex[:, n:] = ex[:, :1]
            msk[n:], lam[n:] = msk[0], lam[0]
        row_pgd_step(W, mus, sgs, g.dist_id, ex, lam, msk,
                     num_t=num_t, impl=impl)
    return len(groups)


def _solve_batched(rows, kmax: int, num_t: int, impl: str) -> None:
    _launch_rows(rows, kmax, num_t, impl)


def _solve_looped(rows, kmax: int, num_t: int, impl: str) -> None:
    """The pre-engine baseline: one launch per live workflow instance (the
    per-instance Python loop RPA080 bans under serve/ — legal here as the
    documented benchmark baseline, outside the serving path)."""
    by_iid = {}
    for r in rows:
        by_iid.setdefault(r.iid, []).append(r)
    for inst_rows in by_iid.values():
        _launch_rows(inst_rows, kmax, num_t, impl)


def _measure_ratio(rows, kmax: int, num_t: int, impl: str):
    """(batched_us, looped_us) on one captured row set, compile excluded
    (``timeit`` warms each path before timing)."""
    b_us = timeit(_solve_batched, rows, kmax, num_t, impl,
                  repeats=3, warmup=1)
    l_us = timeit(_solve_looped, rows, kmax, num_t, impl,
                  repeats=3, warmup=1)
    return b_us, l_us


def _trace_overhead_pct(rows, kmax: int, num_t: int, impl: str) -> float:
    """Traced-vs-untraced wall-clock on the engine's own solver work.

    Times the stacked ``row_pgd_step`` dispatch (the hot path every tick
    pays) with tracing force-disabled, then force-enabled, min-of-repeats
    each so scheduler noise doesn't masquerade as tracing cost. This is
    the number the zero-perturbation contract bounds (< 5%); ci.sh's
    trace tier asserts it.
    """
    from repro.obs import trace as obs

    def best(repeats=5):
        return min(timeit(_solve_batched, rows, kmax, num_t, impl,
                          repeats=1, warmup=1) for _ in range(repeats))

    was = obs.enabled()
    try:
        obs.set_enabled(False)
        off_us = best()
        obs.set_enabled(True)
        on_us = best()
    finally:
        obs.set_enabled(was)
    return 100.0 * (on_us - off_us) / max(off_us, 1e-9)


def run(ticks: int = TICKS, seed: int = 0, smoke: bool = False) -> dict:
    from repro.serve.engine import WorkflowEngine

    templates = _templates()
    max_live = SMOKE_MAX_LIVE if smoke else MAX_LIVE
    prefill = SMOKE_PREFILL if smoke else PREFILL
    lam_calm = LAM_CALM / 4 if smoke else LAM_CALM
    lam_burst = LAM_BURST / 4 if smoke else LAM_BURST
    eng = WorkflowEngine(templates, max_live=max_live, lam_var=0.02,
                         slo_gain=0.5, settle_steps=4, dirty_tol=0.08,
                         num_t=NUM_T, seed=seed, prior_obs=4)

    # stage-addressed churn mid-trace: a throttled channel, a hard failure
    # with recovery, and a template-local load regime — the estimation heads
    # watch the world move under them
    t1, t2, t3 = max(2, ticks // 4), max(3, ticks // 2), max(4, 3 * ticks // 4)
    eng.sims["etl"].schedule_churn(t1, "throttle", stage="extract", idx=1,
                                   value=2.0)
    eng.sims["etl"].schedule_churn(t3, "recover", stage="extract", idx=1)
    eng.sims["train"].schedule_churn(t2, "fail", stage="fit_a", idx=0)
    eng.sims["train"].schedule_churn(t3, "recover", stage="fit_a", idx=0)
    eng.sims["media"].schedule_churn(t2, "set_load", value=1.3)
    eng.sims["media"].schedule_churn(t3, "set_load", value=1.0)

    rng = np.random.default_rng(seed)
    names = list(templates)
    est = {n: _naive_makespan(d) for n, d in templates.items()}

    def _request():
        tpl = names[int(rng.integers(len(names)))]
        # half the traffic carries an SLO deadline scaled off the naive
        # makespan: tight ones miss under burst load, loose ones never do
        if rng.random() < 0.5:
            return (tpl, est[tpl] * float(rng.uniform(0.8, 2.5)))
        return tpl

    for _ in range(prefill):
        req = _request()
        if isinstance(req, tuple):
            eng.submit(req[0], req[1])
        else:
            eng.submit(req)

    burst = False
    reg_joins = {"calm": [], "burst": []}
    tpl_joins = {n: [] for n in names}
    trace_rows = []
    batched_us = looped_us = 0.0
    samples = 0
    sample_every = max(3, ticks // (RATIO_SAMPLES + 1))
    for t in range(ticks):
        if burst and rng.random() < P_EXIT_BURST:
            burst = False
            eng.set_load(1.0)
        elif not burst and rng.random() < P_ENTER_BURST:
            burst = True
            eng.set_load(BURST_LOAD)
        lam = lam_burst if burst else lam_calm
        arrivals = [_request() for _ in range(int(rng.poisson(lam)))]
        out = eng.tick(arrivals)
        regime = "burst" if burst else "calm"
        for r in out["retired"]:
            reg_joins[regime].append(r["join_latency_s"])
            tpl_joins[r["template"]].append(r["join_latency_s"])
        trace_rows.append((t, regime, len(arrivals), out["admitted"],
                           out["live"], out["queue"], out["rows"],
                           out["launches"]))
        # re-time this tick's actual row set batched vs per-instance-looped
        if (samples < RATIO_SAMPLES and t >= 2 and eng.last_rows
                and (t + 1) % sample_every == 0
                and len({r.iid for r in eng.last_rows}) >= 4):
            b_us, l_us = _measure_ratio(eng.last_rows, eng.kmax,
                                        NUM_T, eng.impl)
            batched_us += b_us
            looped_us += l_us
            samples += 1

    assert samples > 0, "trace never yielded a sampleable row set"
    ratio = looped_us / max(batched_us, 1e-9)
    tel = eng.telemetry.summary()
    counters = tel.pop("counters")
    save_table("serve_trace_smoke.csv" if smoke else "serve_trace.csv",
               "tick,regime,arrivals,admitted,live,queue,rows,launches",
               trace_rows)
    reg = {name: {"ticks": int(sum(1 for r in trace_rows if r[1] == name)),
                  "latency_mean": (float(np.mean(js)) if js else None)}
           for name, js in reg_joins.items()}
    out = {
        "bench": "serve_trace",
        "smoke": smoke,
        "ticks": ticks,
        "templates": {n: {"stages": len(d.stages),
                          "family": d.stages[0].dist_id,
                          "retired": len(tpl_joins[n])}
                      for n, d in templates.items()},
        "max_live": max_live,
        "latency": tel["join_latency_s"],
        "solver_tick_us": tel["solver_tick_us"],
        "rows_per_launch": tel["rows_per_launch"],
        "row_occupancy": tel["row_occupancy"],
        "live_instances": tel["live_instances"],
        "queue_wait_ticks": tel["queue_wait_ticks"],
        "batched_vs_looped_ratio": float(round(ratio, 3)),
        "slo": {
            "misses": counters["slo_misses"],
            "retired": counters["retired"],
            "miss_rate": (counters["slo_misses"] / counters["retired"]
                          if counters["retired"] else 0.0),
        },
        "regimes": reg,
        "counters": counters,
        "entries": [
            {"name": f"serve_join_{n}", "family": d.stages[0].dist_id,
             "ticks": ticks,
             "mean_s": (float(np.mean(tpl_joins[n]))
                        if tpl_joins[n] else 0.0),
             "var_s2": (float(np.var(tpl_joins[n]))
                        if tpl_joins[n] else 0.0),
             "p99_s": (float(np.percentile(tpl_joins[n], 99))
                       if tpl_joins[n] else 0.0)}
            for n, d in templates.items()
        ],
    }
    emit("serve_engine_solver_tick_us", tel["solver_tick_us"]["p50"],
         f"rows_p50={tel['rows_per_launch']['p50']};"
         f"live_max={tel['live_instances']['max']}")
    emit("serve_engine_batched_vs_looped", ratio,
         f"samples={samples};launches={counters['launches']}")

    # cross-layer trace section (PR 10): only when the run was traced
    # (REPRO_TRACE=1). Exports the whole trace as JSONL + Perfetto at the
    # repo root, validates it against the event schema, and measures the
    # traced-vs-untraced solver overhead the zero-perturbation contract
    # bounds. Conditional so untraced runs keep the exact prior schema.
    from repro.obs import trace as obs
    if obs.enabled():
        from repro.obs import export as obs_export
        recs = obs.records()
        obs_export.validate_records(recs)
        root = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
        suffix = "_smoke" if smoke else ""
        jsonl = os.path.join(root, f"TRACE_serve_trace{suffix}.jsonl")
        perfetto = os.path.join(root,
                                f"TRACE_serve_trace{suffix}.perfetto.json")
        obs_export.write_jsonl(recs, jsonl)
        obs_export.write_perfetto(recs, perfetto)
        overhead = _trace_overhead_pct(eng.last_rows, eng.kmax, NUM_T,
                                       eng.impl)
        out["trace"] = {
            "records": len(recs),
            "dropped": obs.dropped(),
            "span_kinds": sorted(obs_export.span_kinds(recs)),
            "event_types": sorted(obs_export.event_types(recs)),
            "overhead_pct": float(round(overhead, 3)),
            "jsonl": os.path.basename(jsonl),
            "perfetto": os.path.basename(perfetto),
        }
        emit("serve_engine_trace_overhead_pct", overhead,
             f"records={len(recs)};"
             f"span_kinds={len(out['trace']['span_kinds'])};"
             f"event_types={len(out['trace']['event_types'])}")
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", action="store_true",
                    help="emit machine-readable BENCH_serve_trace.json")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced scale (fewer ticks, smaller live set)")
    ap.add_argument("--ticks", type=int, default=None)
    ap.add_argument("--out", default=None,
                    help="JSON output path (default: repo-root "
                         "BENCH_serve_trace.json, or _smoke variant)")
    args = ap.parse_args()

    ticks = args.ticks or (SMOKE_TICKS if args.smoke else TICKS)
    res = run(ticks=ticks, smoke=args.smoke)
    if args.json:
        root = os.path.join(os.path.dirname(__file__), "..")
        default = ("BENCH_serve_trace_smoke.json" if args.smoke
                   else "BENCH_serve_trace.json")
        path = args.out or os.path.abspath(os.path.join(root, default))
        with open(path, "w") as f:
            json.dump(res, f, indent=1, sort_keys=True)
        print(f"wrote {path}")
    print({k: res[k] for k in ("latency", "batched_vs_looped_ratio",
                               "live_instances", "slo")})
    if "trace" in res:
        print({"trace": res["trace"]})


if __name__ == "__main__":
    main()
