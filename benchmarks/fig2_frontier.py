"""Paper Figure 2: parametric (mu, sigma^2) curve + the efficient frontier.

Validates the parabola-like shape (some mu values admit two variances) and
that the efficient set is the lower-left arc. Benchmarks frontier extraction.
"""
import numpy as np

from .common import emit, save_table, timeit


def run() -> dict:
    from repro.core import frontier_2ch, select_on_frontier

    res = frontier_2ch(30.0, 2.0, 20.0, 6.0, num_f=401, num_t=2048)
    save_table("fig2_frontier.csv", "f,mu,var,efficient",
               zip(res.f, res.mu, res.var, res.efficient))

    # parabola check: mu values between the min and the lower endpoint are
    # attained at two different f (the curve folds back — paper Fig 2)
    mu_mid = (res.mu.min() + min(res.mu[0], res.mu[-1])) / 2
    crossings = np.sum(np.diff(np.sign(res.mu - mu_mid)) != 0)
    assert crossings >= 2, "parametric curve should fold (paper Fig 2)"

    n_eff = int(res.efficient.sum())
    assert 2 <= n_eff < len(res.f), "frontier is a proper arc"

    # scalarized picks move along the frontier monotonically with lambda
    picks = [select_on_frontier(res, lam)[1] for lam in (0.0, 0.5, 5.0)]
    mus = [p[1] for p in picks]
    vars_ = [p[2] for p in picks]
    assert mus == sorted(mus) and vars_ == sorted(vars_, reverse=True)

    us = timeit(lambda: frontier_2ch(30.0, 2.0, 20.0, 6.0, num_f=401), repeats=3)
    emit("fig2_frontier_401f", us, f"n_efficient={n_eff}")
    return {"n_efficient": n_eff}


if __name__ == "__main__":
    print(run())
