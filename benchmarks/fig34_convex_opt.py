"""Paper Figures 3 & 4: partitioned convex optimization (the paper's first
laboratory experiment), reproduced end-to-end.

A least-squares logistic-regression objective over synthetic data D is split
into unequal workloads D_i = f|D|, D_j = (1-f)|D|. Each "machine" REALLY runs
a JAX L2-regularized Newton/GD solve to its global optimum on its share, and
the joined solution is theta = f theta_i + (1-f) theta_j (paper's equation).
Per-trial completion times come from the contended-channel simulator with the
paper's two-VM setup (the paper generated contention with background
processes; this container has one core, so the timing physics live in
sim.ClusterSim with Normal per-unit-work rates).

Outputs: mu(f), sigma^2(f) tables + joined-solution quality, validating that
both completion moments dip below the unpartitioned (f=0 / f=1) workflow.
"""
import numpy as np

from .common import emit, save_table, timeit


def _make_problem(n=2048, d=16, seed=0):
    import jax
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    w_true = rng.normal(size=(d,))
    X = rng.normal(size=(n, d))
    y = (1 / (1 + np.exp(-X @ w_true)) > rng.uniform(size=n)).astype(np.float32)
    return jnp.asarray(X, jnp.float32), jnp.asarray(y), w_true


def _solve(X, y, steps=300, lr=0.5, reg=1e-3):
    """Least-squares-on-probabilities objective (quadratic, convex — the
    paper's choice) minimized by gradient descent with momentum."""
    import jax
    import jax.numpy as jnp

    def loss(w):
        p = jax.nn.sigmoid(X @ w)
        return jnp.mean((p - y) ** 2) + reg * jnp.sum(w * w)

    g = jax.jit(jax.grad(loss))
    w = jnp.zeros((X.shape[1],))
    v = jnp.zeros_like(w)
    for _ in range(steps):
        v = 0.9 * v - lr * g(w)
        w = w + v
    return w, float(loss(w))


def run() -> dict:
    import jax.numpy as jnp

    from repro.sim import Channel, ClusterSim

    X, y, _ = _make_problem()
    n = X.shape[0]
    # the paper's two 2667MHz VMs with induced contention:
    make_sim = lambda seed: ClusterSim(
        [Channel(mu=30.0, sigma=2.0), Channel(mu=20.0, sigma=6.0)], seed=seed)

    fs = np.round(np.arange(0.0, 1.01, 0.1), 2)
    rows = []
    quality = {}
    for f in fs:
        ni = int(round(f * n))
        # real partitioned optimization (once per f — deterministic)
        if 0 < ni < n:
            wi, _ = _solve(X[:ni], y[:ni])
            wj, _ = _solve(X[ni:], y[ni:])
            w = f * wi + (1 - f) * wj
        elif ni == 0:
            w, _ = _solve(X, y)
        else:
            w, _ = _solve(X, y)
        import jax
        p = jax.nn.sigmoid(X @ w)
        quality[float(f)] = float(jnp.mean((p - y) ** 2))

        # completion-time distribution over many contended trials
        sim = make_sim(seed=int(f * 100) + 1)
        times = [sim.run_step([f, 1 - f])[0] for _ in range(2000)]
        rows.append((f, np.mean(times), np.var(times), quality[float(f)]))

    save_table("fig34_convex_opt.csv", "f,mu,var,joined_mse", rows)
    mus = np.array([r[1] for r in rows])
    vrs = np.array([r[2] for r in rows])
    # paper claim: interior minima beat both unpartitioned endpoints
    assert mus.min() < min(mus[0], mus[-1])
    assert vrs.min() < min(vrs[0], vrs[-1])
    # joined solutions stay near the full-data optimum (convexity)
    full = quality[0.0]
    worst = max(quality.values())
    assert worst < full * 2.0 + 0.05

    us = timeit(lambda: _solve(X[: n // 2], y[: n // 2], steps=50), repeats=3)
    emit("fig34_convex_opt_halfsolve", us,
         f"mu_min={mus.min():.2f}@f={fs[int(np.argmin(mus))]};"
         f"var_min={vrs.min():.3f}@f={fs[int(np.argmin(vrs))]}")
    return {"mu_min_f": float(fs[int(np.argmin(mus))]),
            "var_min_f": float(fs[int(np.argmin(vrs))])}


if __name__ == "__main__":
    print(run())
