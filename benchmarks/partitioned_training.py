"""Beyond-paper: the partitioner inside a real training loop.

Trains a tiny LM for a few hundred simulated steps on 2 heterogeneous pods
(one fast/stable, one slow/noisy) under three scheduling policies and compares
realized per-step join times AND training throughput (tokens/s against the
simulated clock). This is paper Fig 3/4 logic transplanted onto the gradient
pipeline: the join is the cross-pod gradient reduction.
"""
import numpy as np

from .common import emit, save_table


def _run(policy: str, steps: int = 150, seed: int = 0):
    from repro.sched import UncertaintyAwareBalancer
    from repro.sim import Channel, ClusterSim

    # per-pod sec per *microbatch*: pod0 fast+stable, pod1 slow+noisy
    sim = ClusterSim([Channel(mu=0.9, sigma=0.05), Channel(mu=1.5, sigma=0.45)],
                     seed=seed)
    bal = UncertaintyAwareBalancer(2, lam=0.05, policy=policy)
    total_micro = 8
    join_times, done = [], 0
    for i in range(steps):
        k = bal.assign(total_micro)
        # run_step normalizes counts to batch fractions; channel rates are
        # sec per *microbatch*, so scale the realized times back to seconds
        t, durs = sim.run_step(k.astype(np.float64))
        t, durs = t * total_micro, durs * total_micro
        bal.observe(durs, k.astype(np.float64))
        if i >= 20:
            join_times.append(t)
            done += int(k.sum())
    jt = np.asarray(join_times)
    return jt.mean(), jt.var(), done / jt.sum()


def run() -> dict:
    rows = []
    res = {}
    for policy in ("equal", "inverse_mu", "frontier"):
        mu, var, thr = _run(policy)
        rows.append((policy, mu, var, thr))
        res[policy] = (mu, var, thr)
        emit(f"parttrain_{policy}", mu * 1e6,
             f"join_var={var:.4f};microbatches_per_s={thr:.3f}")
    save_table("partitioned_training.csv", "policy,join_mu,join_var,micro_per_s",
               rows)
    assert res["frontier"][0] < res["equal"][0]
    assert res["frontier"][2] > res["equal"][2]  # higher throughput
    return res


if __name__ == "__main__":
    print(run())
