import os
import sys

# tests must see ONE device (the dry-run sets 512 for itself in-process)
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# make the _hypothesis_fallback shim importable from test modules
sys.path.insert(0, os.path.dirname(__file__))
