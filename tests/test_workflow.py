"""Workflow subsystem: StageDAG validation + composition, the stacked
per-row-statistics kernel layout (``stack_rows``), the joint solver, and
the runtime twins (WorkflowBalancer / WorkflowSim)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.distributions import Drift
from repro.core.maxstat import clark_max_moments_2
from repro.kernels import ops
from repro.sched import WorkflowBalancer
from repro.sim import WorkflowSim
from repro.workflow import (DAGValidationError, Stage, StageDAG, evaluate_dag,
                            linear_edges, solve_dag, solve_dag_greedy)


def _mk_stage(name, k, seed=0, cov=(0.05, 0.4), family="normal"):
    rng = np.random.default_rng(seed)
    mus = rng.uniform(10, 40, k)
    return Stage(name, mus, mus * rng.uniform(*cov, k), family=family)


def _diamond(seed=0, family="normal"):
    stages = [_mk_stage("a", 4, seed), _mk_stage("b", 3, seed + 1,
                                                 family=family),
              _mk_stage("c", 5, seed + 2, family=family),
              _mk_stage("d", 4, seed + 3)]
    return StageDAG(stages, [("a", "b"), ("a", "c"), ("b", "d"), ("c", "d")])


class TestDAGValidation:
    def test_duplicate_names_rejected(self):
        with pytest.raises(DAGValidationError, match="duplicate"):
            StageDAG([_mk_stage("a", 2), _mk_stage("a", 2)])

    def test_unknown_edge_endpoint_rejected(self):
        with pytest.raises(DAGValidationError, match="unknown"):
            StageDAG([_mk_stage("a", 2)], [("a", "ghost")])

    def test_self_loop_rejected(self):
        with pytest.raises(DAGValidationError, match="self-loop"):
            StageDAG([_mk_stage("a", 2)], [("a", "a")])

    def test_duplicate_edge_rejected(self):
        with pytest.raises(DAGValidationError, match="duplicate edge"):
            StageDAG([_mk_stage("a", 2), _mk_stage("b", 2)],
                     [("a", "b"), ("a", "b")])

    def test_cycle_rejected_with_path(self):
        stages = [_mk_stage(n, 2) for n in "abc"]
        with pytest.raises(DAGValidationError, match="cycle detected: .*a"):
            StageDAG(stages, [("a", "b"), ("b", "c"), ("c", "a")])

    def test_depth_bound(self):
        names = [f"s{i}" for i in range(6)]
        stages = [_mk_stage(n, 2) for n in names]
        with pytest.raises(DAGValidationError, match="depth"):
            StageDAG(stages, linear_edges(names), max_depth=4)
        assert StageDAG(stages, linear_edges(names), max_depth=6).depth == 6

    def test_bad_stage_stats(self):
        with pytest.raises(DAGValidationError):
            Stage("x", np.ones(3), np.ones(2))
        with pytest.raises(DAGValidationError):
            Stage("x", np.asarray([1.0, -1.0]), np.ones(2))

    def test_topology_accessors(self):
        dag = _diamond()
        assert dag.topo_order[0] == "a" and dag.topo_order[-1] == "d"
        assert dag.sources == ("a",) and dag.sinks == ("d",)
        assert set(dag.predecessors("d")) == {"b", "c"}
        assert set(dag.successors("a")) == {"b", "c"}
        assert dag.depth == 3
        path = dag.critical_path()
        assert path[0] == "a" and path[-1] == "d" and len(path) == 3


class TestComposition:
    def test_series_adds_moments(self):
        dag = StageDAG([_mk_stage("x", 2), _mk_stage("y", 2)], [("x", "y")])
        mu, var = dag.compose_moments(jnp.asarray([3.0, 4.0]),
                                      jnp.asarray([0.5, 0.7]))
        assert np.isclose(float(mu), 7.0) and np.isclose(float(var), 1.2)

    def test_join_matches_clark(self):
        """Two independent source branches into a sink: the release is
        exactly one Clark fold of the branch completions."""
        dag = StageDAG([_mk_stage("p", 2), _mk_stage("q", 2),
                        _mk_stage("s", 2)], [("p", "s"), ("q", "s")])
        smu = jnp.asarray([10.0, 11.0, 2.0])
        svar = jnp.asarray([4.0, 1.0, 0.1])
        mu, var = dag.compose_moments(smu, svar)
        rel_mu, rel_var = clark_max_moments_2(10.0, 2.0, 11.0, 1.0)
        assert np.isclose(float(mu), float(rel_mu) + 2.0, rtol=1e-6)
        assert np.isclose(float(var), float(rel_var) + 0.1, rtol=1e-5)

    def test_jensen_bound_at_joins(self):
        """E[max] >= max E: the composed mean dominates the deterministic
        critical-path mean, with equality only as spreads vanish."""
        dag = _diamond()
        smu = jnp.asarray([5.0, 8.0, 8.0, 3.0])
        svar = jnp.asarray([1.0, 4.0, 4.0, 0.5])
        mu, _ = dag.compose_moments(smu, svar)
        assert float(mu) >= 5.0 + 8.0 + 3.0
        mu0, _ = dag.compose_moments(smu, jnp.zeros(4))
        assert float(mu0) == pytest.approx(16.0, rel=1e-6)

    def test_differentiable_and_monotone(self):
        dag = _diamond()
        smu = jnp.asarray([5.0, 8.0, 7.5, 3.0])
        svar = jnp.asarray([1.0, 2.0, 2.0, 0.5])
        g = jax.grad(lambda m: dag.compose_moments(m, svar)[0])(smu)
        assert np.all(np.isfinite(np.asarray(g)))
        assert np.all(np.asarray(g) >= -1e-6)      # makespan monotone in mus
        assert float(g[0]) == pytest.approx(1.0, abs=1e-5)  # series stage
        # (S,) batched under vmap (the solver's multi-start layout)
        mus = jnp.stack([smu, smu * 1.1])
        out = jax.vmap(lambda m: dag.compose_moments(m, svar)[0])(mus)
        assert out.shape == (2,) and float(out[1]) > float(out[0])


class TestStackedKernelLayout:
    """Per-row channel statistics through every impl and both launch modes."""

    def _problem(self, F=5, K=6, seed=0):
        rng = np.random.default_rng(seed)
        e = rng.exponential(size=(F, K))
        W = (e / e.sum(1, keepdims=True)).astype(np.float32)
        MUS = rng.uniform(10, 40, (F, K)).astype(np.float32)
        SGS = (MUS * rng.uniform(0.05, 0.35, (F, K))).astype(np.float32)
        return W, MUS, SGS

    @pytest.mark.parametrize("impl", ["xla", "pallas_interpret"])
    def test_forward_matches_per_row_loop(self, impl):
        W, MUS, SGS = self._problem()
        mu, var = ops.frontier_moments(W, MUS, SGS, num_t=512, impl=impl)
        for f in range(W.shape[0]):
            m, v = ops.frontier_moments(W[f:f + 1], MUS[f], SGS[f],
                                        num_t=512, impl=impl)
            np.testing.assert_allclose(float(mu[f]), float(m[0]), rtol=1e-5)
            np.testing.assert_allclose(float(var[f]), float(v[0]),
                                       rtol=1e-4, atol=1e-5)

    @pytest.mark.parametrize("impl", ["xla", "pallas_interpret"])
    def test_fused_param_grads_match_per_row_loop(self, impl):
        W, MUS, SGS = self._problem(F=4, K=5)
        outs = ops.frontier_moments_with_grads(W, MUS, SGS, num_t=512,
                                               impl=impl, param_grads=True)
        assert len(outs) == 10
        for f in range(W.shape[0]):
            o = ops.frontier_moments_with_grads(
                W[f:f + 1], MUS[f], SGS[f], num_t=512, impl=impl,
                param_grads=True)
            for i in range(10):
                np.testing.assert_allclose(
                    np.asarray(outs[i][f]), np.asarray(o[i][0]),
                    rtol=5e-4, atol=5e-5)

    def test_chunked_path_matches_single_block(self):
        W, MUS, SGS = self._problem(F=6, K=4)
        Wb, Mb, Sb = (np.tile(a, (20, 1)) for a in (W, MUS, SGS))
        mu_c, var_c = ops.frontier_moments(Wb, Mb, Sb, num_t=256,
                                           block_f=16)
        mu_1, var_1 = ops.frontier_moments(W, MUS, SGS, num_t=256)
        np.testing.assert_allclose(np.asarray(mu_c[:6]), np.asarray(mu_1),
                                   rtol=1e-5)
        np.testing.assert_allclose(np.asarray(var_c[:6]), np.asarray(var_1),
                                   rtol=1e-4, atol=1e-6)

    def test_stacked_drift_extra(self):
        """Per-row drift rho: the (E, F, K) extra stack through both the
        ref oracle and the interpreted kernel."""
        W, MUS, SGS = self._problem(F=3, K=4, seed=2)
        rng = np.random.default_rng(3)
        EX = rng.uniform(0.1, 0.8, (1, 3, 4)).astype(np.float32)
        mu, var = ops.frontier_moments(W, MUS, SGS, num_t=512,
                                       family=("drift", jnp.asarray(EX)))
        mu_i, var_i = ops.frontier_moments(
            W, MUS, SGS, num_t=512, impl="pallas_interpret",
            family=("drift", jnp.asarray(EX)))
        np.testing.assert_allclose(np.asarray(mu), np.asarray(mu_i),
                                   rtol=1e-4)
        for f in range(3):
            m, _ = ops.frontier_moments(W[f:f + 1], MUS[f], SGS[f],
                                        num_t=512, family=Drift(EX[0, f]))
            np.testing.assert_allclose(float(mu[f]), float(m[0]), rtol=1e-5)

    def test_custom_vjp_per_row_cotangents(self):
        """jax.grad through stacked stats returns per-row (F, K) cotangents
        matching finite differences — no cross-row mixing."""
        W, MUS, SGS = self._problem(F=3, K=4, seed=1)
        W, MUS, SGS = jnp.asarray(W), jnp.asarray(MUS), jnp.asarray(SGS)

        def loss(W, MUS, SGS):
            mu, var = ops.frontier_moments(W, MUS, SGS, num_t=1024)
            return jnp.sum(mu * jnp.asarray([1.0, 2.0, 3.0]))

        gW, gM, gS = jax.grad(loss, argnums=(0, 1, 2))(W, MUS, SGS)
        assert gM.shape == MUS.shape and gS.shape == SGS.shape
        # FD on the largest-magnitude mus entry (f64 recompute via oracle)
        f, k = np.unravel_index(int(jnp.argmax(jnp.abs(gM))), gM.shape)
        eps = 1e-2
        coeff = [1.0, 2.0, 3.0][f]

        def row_mu(muval):
            mus_f = np.asarray(MUS[f], np.float64).copy()
            mus_f[k] = muval
            m, _ = ops.frontier_moments(np.asarray(W[f])[None, :], mus_f,
                                        np.asarray(SGS[f]), num_t=1024)
            return coeff * float(m[0])

        fd = (row_mu(float(MUS[f, k]) + eps)
              - row_mu(float(MUS[f, k]) - eps)) / (2 * eps)
        assert abs(fd - float(gM[f, k])) <= 2e-2 * max(abs(fd), 1e-3)
        # a row's stats must not receive other rows' cotangents: zero the
        # row's output weight and its stat gradient vanishes
        g0 = jax.grad(lambda M: ops.frontier_moments(
            W, M, SGS, num_t=256)[0][1] * 0.0 + jnp.sum(
                ops.frontier_moments(W, M, SGS, num_t=256)[0][:1]))(MUS)
        np.testing.assert_allclose(np.asarray(g0[1]), 0.0, atol=1e-12)
        np.testing.assert_allclose(np.asarray(g0[2]), 0.0, atol=1e-12)


class TestJointSolve:
    def test_simplex_and_padding_invariants(self):
        dag = _diamond()
        dec = solve_dag(dag, steps=40, restarts=1, num_t=256)
        for s in dag.stages:
            w = dec.weights[s.name]
            assert w.shape == (s.k,)
            assert abs(w.sum() - 1.0) < 1e-5 and (w >= 0).all()
        assert dec.family_groups == 1
        assert dec.makespan_mu > 0 and dec.makespan_var >= 0

    def test_joint_not_worse_than_greedy(self):
        dag = _diamond(seed=5)
        joint = solve_dag(dag, steps=80, restarts=2, num_t=512)
        greedy = solve_dag_greedy(dag, steps=80, restarts=2, num_t=512)
        # identical evaluator on both: the joint objective can only win
        assert joint.makespan_mu <= greedy.makespan_mu * (1 + 1e-3)

    def test_warm_start_stays_near_solution(self):
        dag = _diamond(seed=2)
        dec = solve_dag(dag, steps=60, restarts=1, num_t=256)
        dec2 = solve_dag(dag, steps=10, restarts=0, num_t=256,
                         warm_start=dec.weights)
        assert dec2.makespan_mu <= dec.makespan_mu * 1.01

    def test_mixed_families_group_per_dist(self):
        dag = _diamond(seed=3, family="lognormal")  # b, c lognormal; a, d normal
        dec = solve_dag(dag, steps=30, restarts=0, num_t=256)
        assert dec.family_groups == 2
        ev = evaluate_dag(dag, dec.weights, num_t=512)
        assert ev.makespan_mu == pytest.approx(dec.makespan_mu, rel=0.05)

    def test_risk_lam_reports_fragility(self):
        from repro.core.bayes import nig_init, nig_update_batch

        dag = _diamond(seed=4)
        posteriors = {}
        rng = np.random.default_rng(0)
        for s in dag.stages:
            nig = nig_init(s.k, m0=float(np.mean(s.mus)))
            for _ in range(5):
                rates = rng.normal(s.mus, s.sigmas).astype(np.float32)
                nig = nig_update_batch(nig, jnp.asarray(rates),
                                       jnp.ones(s.k, jnp.float32))
            posteriors[s.name] = nig
        dec = solve_dag(dag, steps=30, restarts=0, num_t=256,
                        risk_lam=0.5, posteriors=posteriors)
        assert dec.method == "pgd-dag-joint-risk"
        assert dec.fragility is not None and dec.fragility > 0
        assert dec.relative_fragility < 1.0

    def test_evaluate_matches_manual_composition(self):
        """The shared evaluator = per-stage oracle moments + compose."""
        from repro.core.maxstat import max_moments_quad_w

        dag = _diamond(seed=6)
        weights = {s.name: np.full(s.k, 1.0 / s.k) for s in dag.stages}
        ev = evaluate_dag(dag, weights, num_t=2048)
        smu, svar = [], []
        for s in dag.stages:
            m, v = max_moments_quad_w(weights[s.name], s.mus, s.sigmas,
                                      num=2048)
            smu.append(float(m))
            svar.append(float(v))
        mk_mu, mk_var = dag.compose_moments(jnp.asarray(smu),
                                            jnp.asarray(svar))
        assert ev.makespan_mu == pytest.approx(float(mk_mu), rel=5e-3)
        assert ev.makespan_var == pytest.approx(float(mk_var), rel=5e-2,
                                                abs=1e-3)


class TestMultiFidelity:
    """PR 8: the fidelity ladder, candidate pruning, and incremental
    (dirty-set) re-solves. The bitwise contracts here are pinned in
    docs/INVARIANTS.md."""

    def test_ladder_final_pick_matches_full_fidelity(self):
        """Coarse scores are triage-only: running the whole ladder at the
        solve fidelity (no coarse rung, no prune, no early stop) must land
        within 1e-3 relative composed makespan of the default ladder."""
        dag = _diamond(seed=12)
        mf = solve_dag(dag, steps=60, restarts=1, num_t=512)
        full = solve_dag(dag, steps=60, restarts=1, num_t=512,
                         presolve_num_t=512, prune_margin=None,
                         plateau_patience=None)
        assert mf.makespan_mu == pytest.approx(full.makespan_mu, rel=1e-3)

    def test_coarse_rung_ranking_resolution(self):
        """The coarse rung's MOMENTS are biased vs the fine rung (that's why
        they never decide the winner) but by far less than the margins the
        triage prunes on."""
        dag = _diamond(seed=12)
        w = {s.name: np.full(s.k, 1.0 / s.k) for s in dag.stages}
        coarse = evaluate_dag(dag, w, num_t=128)
        fine = evaluate_dag(dag, w, num_t=2048)
        gap = abs(coarse.makespan_mu - fine.makespan_mu) / fine.makespan_mu
        assert gap < 1e-3

    def test_profile_attributes_ladder_phases(self):
        dag = _diamond(seed=13)
        dec = solve_dag(dag, steps=30, restarts=1, num_t=256)
        prof = dec.profile
        assert {"starts", "presolve", "triage", "refine",
                "final_score"} <= set(prof["phase_us"])
        assert prof["presolve_num_t"] == 128      # min(default 128, num_t)
        assert prof["eval_num_t"] == 2048         # max(num_t, 2048)
        assert 1 <= prof["survivors"] <= prof["pool"]
        assert 1 <= prof["refine_steps_run"] <= 30

    def test_plateau_early_stop_saves_steps(self):
        """A huge plateau_tol makes every post-warmup step a stall, so the
        refine must cut out right after the warmup + patience window instead
        of running the full budget; patience=None restores the fixed count."""
        dag = _diamond(seed=13)
        stopped = solve_dag(dag, steps=60, restarts=0, num_t=128,
                            plateau_tol=0.5, plateau_patience=2)
        fixed = solve_dag(dag, steps=60, restarts=0, num_t=128,
                          plateau_patience=None)
        assert stopped.profile["refine_steps_run"] < 60
        assert fixed.profile["refine_steps_run"] == 60

    def test_empty_dirty_is_bitwise_noop(self, monkeypatch):
        """An empty dirty set returns the warm split verbatim from one
        forward evaluation — launching PGD at all is the bug."""
        import repro.workflow.solve as solve_mod

        dag = _diamond(seed=14)
        dec = solve_dag(dag, steps=30, restarts=0, num_t=256)

        def boom(*a, **k):
            raise AssertionError("PGD launched on an empty dirty set")

        monkeypatch.setattr(solve_mod, "_pgd_phase", boom)
        dec2 = solve_dag(dag, steps=30, restarts=0, num_t=256,
                         warm_start=dec.weights, dirty=set())
        assert dec2.method == "pgd-dag-noop"
        assert dec2.profile["noop"] and dec2.profile["starts"] == 0
        for s in dag.stages:
            assert np.array_equal(dec.weights[s.name], dec2.weights[s.name])
        assert dec2.makespan_mu == pytest.approx(dec.makespan_mu, rel=5e-3)

    def test_single_dirty_stage_freezes_other_rows_bitwise(self):
        dag = _diamond(seed=15)
        dec = solve_dag(dag, steps=30, restarts=0, num_t=256)
        dec2 = solve_dag(dag, steps=20, restarts=0, num_t=256,
                         warm_start=dec.weights, dirty={"b"})
        assert dec2.method == "pgd-dag-joint-inc"
        for s in dag.stages:
            if s.name == "b":
                continue
            assert np.array_equal(dec.weights[s.name], dec2.weights[s.name]), \
                f"frozen stage {s.name} moved"

    def test_dirty_validation(self):
        dag = _diamond(seed=16)
        with pytest.raises(ValueError, match="warm_start"):
            solve_dag(dag, steps=5, num_t=128, dirty={"b"})
        dec = solve_dag(dag, steps=5, restarts=0, num_t=128)
        with pytest.raises(KeyError, match="ghost"):
            solve_dag(dag, steps=5, num_t=128, warm_start=dec.weights,
                      dirty={"ghost"})

    def test_greedy_rides_the_same_knobs(self):
        dag = _diamond(seed=15)
        base = solve_dag_greedy(dag, steps=20, restarts=0, num_t=256)
        inc = solve_dag_greedy(dag, steps=10, restarts=0, num_t=256,
                               presolve_num_t=128,
                               warm_start=base.weights, dirty={"c"})
        for s in dag.stages:
            if s.name == "c":
                continue
            assert np.array_equal(base.weights[s.name], inc.weights[s.name])
        with pytest.raises(ValueError, match="warm_start"):
            solve_dag_greedy(dag, steps=5, num_t=128, dirty={"c"})

    def test_autotune_keys_separate_fidelity_rungs(self):
        """Coarse and fine rungs must resolve distinct autotune entries — a
        silicon sweep at one fidelity can never shadow another's plan."""
        from repro.kernels.autotune import _key

        coarse = _key(8, 64, 128, "xla", False, stacked=True)
        fine = _key(8, 64, 2048, "xla", False, stacked=True)
        assert coarse != fine
        assert "T128" in coarse and "T2048" in fine


class TestIncrementalBalancer:
    """WorkflowBalancer's fragility-gated dirty sets (PR 8)."""

    def _spied(self, monkeypatch):
        """Wrap workflow.solve.solve_dag, recording each call's dirty= —
        the balancer imports it lazily inside weights(), so patching the
        solve module intercepts every solver call."""
        import repro.workflow.solve as solve_mod

        calls = []
        real = solve_mod.solve_dag

        def spy(dag, **kw):
            calls.append(kw.get("dirty"))
            return real(dag, **kw)

        monkeypatch.setattr(solve_mod, "solve_dag", spy)
        return calls

    def _bal(self, dag):
        # risk_lam > 0 makes the composed fragility ride every solve, and
        # the huge refresh_target_rel keeps the incremental gate open
        return WorkflowBalancer(dag, refresh_every=1, pgd_steps=10,
                                num_t=128, restarts=0, family="normal",
                                risk_lam=1e-6, refresh_target_rel=100.0)

    def test_drifted_stage_dirties_only_itself(self, monkeypatch):
        calls = self._spied(monkeypatch)
        dag = _diamond(seed=17)
        bal = self._bal(dag)

        w0 = bal.weights()
        assert calls == [None]          # first solve is always full

        w0b = bal.weights()
        assert len(calls) == 1          # no drift: empty dirty, no solver call
        for n in w0:
            assert np.array_equal(w0[n], w0b[n])

        # move ONE stage's posterior far past dirty_tol; the others see no
        # observations and stay inside their snapshots
        for _ in range(4):
            bal.observe({"b": np.full(3, 5.0)}, {"b": w0["b"]})
        w1 = bal.weights()
        assert calls[-1] == {"b"}
        for n in w0:
            if n != "b":
                assert np.array_equal(w0[n], w1[n]), f"frozen {n} moved"

    def test_state_dict_round_trips_snapshots(self, monkeypatch):
        calls = self._spied(monkeypatch)
        dag = _diamond(seed=18)
        bal = self._bal(dag)
        w0 = bal.weights()
        sd = bal.state_dict()
        assert set(sd["solve_stats"]) == set(dag.names)
        assert set(sd["solve_fams"]) == set(dag.names)

        b2 = WorkflowBalancer.from_state_dict(sd, dag)
        n_calls = len(calls)
        w2 = b2.weights()
        # the restored replica inherits the snapshots: nothing drifted, so
        # its first tick is the cached split with NO solver call — the same
        # incremental decision the original would have made
        assert len(calls) == n_calls
        for n in w0:
            assert np.array_equal(w0[n], w2[n])


class TestComposeMC:
    """Satellite acceptance: composed (mu, var) vs large-sample simulation."""

    def _random_dag(self, seed=11):
        """Random 5-stage DAG: seeded structure over a topological order."""
        rng = np.random.default_rng(seed)
        names = [f"s{i}" for i in range(5)]
        stages = [_mk_stage(n, int(rng.integers(2, 6)), seed + i,
                            cov=(0.1, 0.3))
                  for i, n in enumerate(names)]
        edges = []
        for j in range(1, 5):
            preds = [i for i in range(j) if rng.random() < 0.6] or [j - 1]
            edges += [(names[i], names[j]) for i in preds]
        return StageDAG(stages, edges)

    @pytest.mark.mc_oracle
    def test_composed_moments_match_simulation(self):
        dag = self._random_dag()
        weights = {s.name: np.full(s.k, 1.0 / s.k) for s in dag.stages}
        ev = evaluate_dag(dag, weights, num_t=4096)

        # vectorized 1e6-sample DAG simulation straight from the stage
        # completion model (normal per-channel rates, release = max preds)
        N = 1_000_000
        rng = np.random.default_rng(3)
        comp = {}
        for s in dag.stages:
            w = weights[s.name]
            rates = rng.normal(s.mus, s.sigmas, size=(N, s.k))
            dur = (w * rates).max(axis=1)
            rel = 0.0
            preds = dag.predecessors(s.name)
            if preds:
                rel = comp[preds[0]]
                for p in preds[1:]:
                    rel = np.maximum(rel, comp[p])
                # Jensen sanity at every join: E[max] >= max E
                if len(preds) > 1:
                    assert rel.mean() >= max(comp[p].mean()
                                             for p in preds) - 1e-9
            comp[s.name] = rel + dur
        mk = comp[dag.sinks[0]]
        for p in dag.sinks[1:]:
            mk = np.maximum(mk, comp[p])
        # tolerance: mu is tight (series sums exact, Clark joins near-exact
        # for independent branches); var absorbs the shared-ancestor
        # dependence the composition ignores
        assert abs(ev.makespan_mu - mk.mean()) / mk.mean() < 0.02
        assert abs(ev.makespan_var - mk.var()) / mk.var() < 0.25


class TestWorkflowRuntime:
    def test_workflow_sim_precedence_and_reproducibility(self):
        dag = _diamond(seed=7)
        weights = {s.name: np.full(s.k, 1.0 / s.k) for s in dag.stages}
        sim = WorkflowSim.from_dag(dag, seed=3)
        mk, comp, durs = sim.run_dag_step(dag, weights, rng=5)
        for u, v in dag.edges:
            assert comp[v] >= comp[u]
        assert mk == pytest.approx(max(comp[n] for n in dag.sinks))
        sim2 = WorkflowSim.from_dag(dag, seed=3)
        mk2, _, _ = sim2.run_dag_step(dag, weights, rng=5)
        assert mk == pytest.approx(mk2)

    def test_workflow_balancer_ticks_and_cache(self):
        dag = _diamond(seed=8)
        sim = WorkflowSim.from_dag(dag, seed=4)
        bal = WorkflowBalancer(dag, refresh_every=4, pgd_steps=15,
                               num_t=128, restarts=0)
        w0 = bal.weights()
        assert set(w0) == set(dag.names)
        mk, comp, durs = sim.run_dag_step(dag, w0)
        bal.observe(durs, w0)                    # obs_count -> 1
        first_w = bal.weights()                  # fresh solve at obs 1
        first = bal.last_decision
        for _ in range(2):                       # obs 2, 3: inside cadence
            mk, comp, durs = sim.run_dag_step(dag, bal.weights())
            bal.observe(durs, bal.weights())
            bal.weights()
        assert bal.last_decision is first        # cached, no re-solve
        mk, comp, durs = sim.run_dag_step(dag, bal.weights())
        bal.observe(durs, bal.weights())         # obs_count -> 4 == cadence
        bal.weights()                            # fresh joint solve
        assert bal.last_decision is not first

    def test_workflow_balancer_min_weight_floor(self):
        dag = _diamond(seed=9)
        bal = WorkflowBalancer(dag, pgd_steps=10, num_t=128, restarts=0,
                               min_weight=0.05)
        for w in bal.weights().values():
            assert (w >= 0.05 - 1e-9).all()
            assert abs(w.sum() - 1.0) < 1e-9

    def test_stack_rows_groups_by_family(self):
        from repro.workflow.solve import stack_rows

        rows = [(np.array([1.0, 2.0]), np.array([0.1, 0.2]), "normal"),
                (np.array([1.0, 2.0, 3.0]), np.array([0.1, 0.2, 0.3]),
                 "lognormal"),
                (np.array([2.0, 1.0]), np.array([0.2, 0.1]), "normal")]
        groups, mask, kmax = stack_rows(rows)
        assert kmax == 3
        by = {g.dist_id: g for g in groups}
        assert set(by) == {"normal", "lognormal"}
        assert by["normal"].idx == (0, 2)       # original row positions
        assert by["lognormal"].idx == (1,)
        # ragged K pads with zeros; the mask marks the real channels
        np.testing.assert_array_equal(mask, [[1, 1, 0], [1, 1, 1],
                                             [1, 1, 0]])
        assert by["normal"].mus.shape == (2, 3)
        np.testing.assert_array_equal(by["normal"].mus[:, 2], [0.0, 0.0])
        assert by["normal"].extra.shape[1:] == (2, 3)

    def test_stack_rows_pinned_kmax_and_overflow(self):
        from repro.workflow.solve import stack_rows

        rows = [(np.array([1.0, 2.0, 3.0]), np.array([0.1, 0.2, 0.3]),
                 "normal")]
        # a serving engine pins kmax so jit keys stay stable across ticks
        _, mask, kmax = stack_rows(rows, kmax=5)
        assert kmax == 5 and mask.shape == (1, 5)
        with pytest.raises(ValueError, match="kmax"):
            stack_rows(rows, kmax=2)


class TestNoDeprecatedNormalShim:
    def test_no_in_repo_module_imports_core_normal(self):
        """The deprecated ``core.normal`` shim stays one release for
        external callers, but nothing inside the package may ride it.

        Enforced by lint rule RPA050 (AST-based, so string mentions in
        docstrings/comments don't false-positive the way the old text scan
        did); this test pins the rule to the real source tree.
        """
        import pathlib

        import repro
        from repro.analysis import run_paths

        root = pathlib.Path(repro.__file__).parent
        findings = run_paths([str(root)], select=["RPA050"])
        assert not findings, [f.format() for f in findings]
