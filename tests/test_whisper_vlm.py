"""Enc-dec (whisper) and VLM decode-consistency + frontend-stub contracts."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model

KEY = jax.random.PRNGKey(0)


def test_whisper_prefill_decode_consistency():
    cfg = get_config("whisper-large-v3").tiny()
    model = build_model(cfg)
    params = model.init(KEY)
    B, S = 2, 17
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    frames = jax.random.normal(KEY, (B, cfg.encoder_seq, cfg.d_model))
    full = model.apply(params, tokens, frames)
    _, cache = model.prefill(params, tokens[:, :16], frames, cache_len=32)
    lg, cache2 = jax.jit(model.decode_step)(params, cache, tokens[:, 16:17])
    np.testing.assert_allclose(np.asarray(lg[:, 0]), np.asarray(full[:, 16]),
                               atol=5e-4, rtol=5e-3)
    assert int(cache2["pos"]) == 17  # 16 prefilled + 1 decoded


def test_whisper_decoder_sees_encoder():
    """Perturbing the frames must change the decoder logits (cross-attn live)."""
    cfg = get_config("whisper-large-v3").tiny()
    model = build_model(cfg)
    params = model.init(KEY)
    tokens = jax.random.randint(KEY, (1, 8), 0, cfg.vocab_size)
    frames = jax.random.normal(KEY, (1, cfg.encoder_seq, cfg.d_model))
    l1 = model.apply(params, tokens, frames)
    l2 = model.apply(params, tokens, frames + 1.0)
    assert float(jnp.max(jnp.abs(l1 - l2))) > 1e-3


def test_vlm_patches_affect_text_logits():
    cfg = get_config("internvl2-76b").tiny()
    model = build_model(cfg)
    params = model.init(KEY)
    tokens = jax.random.randint(KEY, (1, 8), 0, cfg.vocab_size)
    patches = jax.random.normal(KEY, (1, cfg.num_patches, cfg.d_model))
    l1 = model.apply(params, tokens, patches)
    l2 = model.apply(params, tokens, patches + 1.0)
    # text positions come AFTER patches -> causal attention sees them
    text_region = slice(cfg.num_patches, None)
    assert float(jnp.max(jnp.abs(l1[:, text_region] - l2[:, text_region]))) > 1e-3


def test_vlm_decode_continuation():
    cfg = get_config("internvl2-76b").tiny()
    model = build_model(cfg)
    params = model.init(KEY)
    text = jax.random.randint(KEY, (2, 9), 0, cfg.vocab_size)
    patches = jax.random.normal(KEY, (2, cfg.num_patches, cfg.d_model))
    _, cache = model.prefill(params, text[:, :8], patches, cache_len=32)
    lg, _ = jax.jit(model.decode_step)(params, cache, text[:, 8:9])
    ref = model.apply(params, text, patches)[:, cfg.num_patches + 8]
    np.testing.assert_allclose(np.asarray(lg[:, 0]), np.asarray(ref),
                               atol=5e-4, rtol=5e-3)


def test_stub_frontend_shapes_match_assignment():
    """The assignment pins the stub contracts: whisper gets (B, 1500, d)
    frame embeddings; internvl gets (B, 256, d) patch embeddings."""
    w = get_config("whisper-large-v3")
    assert w.encoder_seq == 1500 and w.is_encoder_decoder
    v = get_config("internvl2-76b")
    assert v.num_patches == 256
