"""Unit tests for the static HLO roofline analyzer and launch helpers."""
import jax
import jax.numpy as jnp

from repro.launch.roofline import (_group_size, analyze_hlo, count_params,
                                   model_flops, roofline_terms)


def test_scan_flops_loop_multiplied():
    """The analyzer must multiply while-body FLOPs by the trip count —
    the raw cost_analysis() does not (the reason this module exists)."""
    def f(w, x):
        def body(x, wl):
            return x @ wl, None
        x, _ = jax.lax.scan(body, x, w)
        return x.sum()

    w = jax.ShapeDtypeStruct((5, 32, 32), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 32), jnp.float32)
    compiled = jax.jit(f).lower(w, x).compile()
    stats = analyze_hlo(compiled.as_text())
    expected = 5 * 2 * 8 * 32 * 32  # 5 iterations x dot flops
    assert abs(stats.flops - expected) / expected < 0.05


def test_group_size_iota_decoding():
    g, crosses = _group_size("replica_groups=[2,4]<=[8]")
    assert g == 4 and not crosses
    # transposed iota over a (2,16,16) mesh: model-axis groups (contiguous)
    g, crosses = _group_size("replica_groups=[32,16]<=[512]")
    assert g == 16 and not crosses
    # pod-axis groups: members 256 apart -> DCN
    g, crosses = _group_size("replica_groups=[256,2]<=[2,256]T(1,0)")
    assert g == 2 and crosses
    g, crosses = _group_size("replica_groups={{0,256},{1,257}}")
    assert g == 2 and crosses


def test_roofline_terms_dominant():
    from repro.launch.roofline import HloStats
    s = HloStats(flops=197e12, hbm_bytes=819e9 * 2, ici_bytes=0, dcn_bytes=0)
    t = roofline_terms(s, 4)
    assert t["dominant"] == "memory_s"
    assert abs(t["compute_s"] - 1.0) < 1e-6
    assert abs(t["roofline_fraction"] - 0.5) < 1e-6


def test_count_params_matches_claimed_sizes():
    from repro.configs import get_config
    for arch, lo, hi in [("qwen3-moe-235b-a22b", 220e9, 250e9),
                         ("nemotron-4-340b", 320e9, 360e9),
                         ("qwen3-8b", 7e9, 9e9),
                         ("smollm-360m", 0.3e9, 0.5e9),
                         ("jamba-1.5-large-398b", 370e9, 430e9)]:
        total, active = count_params(get_config(arch))
        assert lo < total < hi, (arch, total)
        assert active <= total


def test_model_flops_kinds_ordering():
    from repro.configs import SHAPES, get_config
    cfg = get_config("qwen3-8b")
    train = model_flops(cfg, SHAPES["train_4k"])
    prefill = model_flops(cfg, SHAPES["prefill_32k"])
    decode = model_flops(cfg, SHAPES["decode_32k"])
    assert train > prefill > decode > 0


def test_param_specs_divisibility_fallback():
    """Non-divisible dims must fall back to replication, never crash."""
    from repro.configs import get_config
    from repro.launch.mesh import make_local_mesh
    from repro.launch.shardings import param_specs
    from repro.models import build_model

    from jax.sharding import PartitionSpec as P

    cfg = get_config("smollm-360m").tiny()  # 4 heads etc on a 1x1 mesh
    mesh = make_local_mesh(("data", "model"))
    model = build_model(cfg)
    params = jax.eval_shape(lambda k: model.init(k), jax.random.PRNGKey(0))
    specs = param_specs(params, mesh, cfg)
    spec_leaves = jax.tree_util.tree_leaves(specs,
                                            is_leaf=lambda x: isinstance(x, P))
    assert len(jax.tree.leaves(params)) == len(spec_leaves)
    assert all(isinstance(s, P) for s in spec_leaves)
    # full production arch on the production mesh: every spec constructible
    from repro.launch.mesh import make_production_mesh  # noqa: F401 (docs)
