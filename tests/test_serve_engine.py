"""Continuous-batching serving engine (PR 9 acceptance surface).

Anchors:
  * mixed-family instance batching — one tick over templates spanning three
    completion-time families issues AT MOST ONE stacked
    ``frontier_moments_with_grads`` launch per family group (spied at the
    ops entry point), never one per instance;
  * per-row moment parity — every engine row's priced ``(mu, var)`` matches
    a solo unpadded ``ops.frontier_moments`` solve of the same instance
    split at 1e-3 (the kmax/bucket padding is exact: a zero-weight channel
    is a point mass at zero and the pad rows are sliced off);
  * admission-queue backpressure, SLO-driven per-row risk weights, and the
    dirty-instance protocol (settled instances contribute zero rows).
"""
import numpy as np
import pytest

from repro.core.distributions import Drift
from repro.kernels import ops
from repro.serve import WorkflowEngine
from repro.workflow.dag import Stage, StageDAG, linear_edges


def _templates():
    """Three tiny templates across three completion-time families."""
    normal = StageDAG([
        Stage("a", mus=[1.0, 1.5], sigmas=[0.2, 0.3]),
        Stage("b", mus=[2.0, 2.5, 3.0], sigmas=[0.3, 0.4, 0.5]),
    ], edges=linear_edges(["a", "b"]))
    logn = StageDAG([
        Stage("x", mus=[1.2, 1.8], sigmas=[0.25, 0.35],
              family="lognormal"),
    ])
    drift = StageDAG([
        Stage("r", mus=[1.5, 2.0, 2.4], sigmas=[0.3, 0.35, 0.4],
              family=Drift(0.3)),
    ])
    return {"normal_wf": normal, "logn_wf": logn, "drift_wf": drift}


def _engine(**kw):
    kw.setdefault("max_live", 8)
    kw.setdefault("settle_steps", 2)
    kw.setdefault("num_t", 128)
    kw.setdefault("seed", 3)
    return WorkflowEngine(_templates(), **kw)


class TestBatchedLaunches:
    def test_one_stacked_launch_per_family_group(self, monkeypatch):
        eng = _engine()
        for tpl in ("normal_wf", "normal_wf", "logn_wf", "drift_wf",
                    "drift_wf"):
            eng.submit(tpl)
        calls = []
        orig = ops.frontier_moments_with_grads

        def spy(W, mus, sigmas, *, family, **kw):
            calls.append((family[0], tuple(W.shape)))
            return orig(W, mus, sigmas, family=family, **kw)

        monkeypatch.setattr(ops, "frontier_moments_with_grads", spy)
        out = eng.tick()
        fams = [c[0] for c in calls]
        # 5 admitted instances, 7 remaining stages, 3 families -> exactly
        # one launch per family group, NEVER one per instance (the three
        # single-stage instances retire within the tick, after the solve)
        assert out["admitted"] == 5 and out["rows"] == 7
        assert len(fams) == len(set(fams)), f"duplicate family launch: {fams}"
        assert set(fams) == {"normal", "lognormal", "drift"}
        assert out["launches"] == len(fams)
        # every launch is padded to one row bucket over the pinned kmax
        assert {s for _, s in calls} <= {(8, eng.kmax)}

    def test_row_moments_match_solo_solves(self):
        eng = _engine()
        for tpl in ("normal_wf", "logn_wf", "drift_wf"):
            eng.submit(tpl)
        eng.tick()
        assert eng.last_rows
        for r in eng.last_rows:
            mu, var = ops.frontier_moments(
                np.asarray(r.w, np.float32)[None],
                np.asarray(r.mus, np.float32)[None],
                np.asarray(r.sigmas, np.float32)[None],
                num_t=eng.num_t, impl=eng.impl, family=r.family)
            assert float(mu[0]) == pytest.approx(r.mu, rel=1e-3)
            assert float(var[0]) == pytest.approx(r.var, rel=1e-3, abs=1e-5)


class TestAdmission:
    def test_queue_backpressure_and_wait_telemetry(self):
        eng = _engine(max_live=2)
        for _ in range(5):
            eng.submit("logn_wf")
        out = eng.tick()
        # single-stage instances retire the tick they run, freeing slots
        assert out["admitted"] == 2
        assert out["queue"] == 3
        out = eng.tick()
        assert out["admitted"] == 2 and out["queue"] == 1
        tel = eng.telemetry
        assert tel.counters["admitted"] == 4
        assert tel.stats["queue_wait_ticks"].count == 4
        assert tel.stats["queue_wait_ticks"].max() >= 1.0  # someone waited

    def test_unknown_template_rejected(self):
        eng = _engine()
        with pytest.raises(ValueError, match="unknown template"):
            eng.submit("nope")

    def test_duplicate_head_admission_rejected(self):
        from repro.sched.balancer import InstanceHeads, \
            UncertaintyAwareBalancer
        heads = InstanceHeads({"t/s": UncertaintyAwareBalancer(
            num_channels=2, explore=0.0)})
        heads.admit(0, ["t/s"])
        with pytest.raises(ValueError, match="already"):
            heads.admit(0, ["t/s"])


class TestSloAndDirtiness:
    def test_deadline_pressure_raises_row_lam(self):
        eng = _engine(lam_var=0.01, slo_gain=1.0)
        relaxed = eng.submit("normal_wf")                 # no SLO
        urgent = eng.submit("normal_wf", deadline=0.5)    # nearly no slack
        eng.tick()
        lam = {r.iid: r.lam for r in eng.last_rows}
        assert lam[relaxed] == pytest.approx(eng.lam_var)
        assert lam[urgent] > lam[relaxed]
        # urgency is capped so a blown deadline cannot send lam to infinity
        assert lam[urgent] <= eng.lam_var + eng.slo_gain * eng.slo_lam_cap

    def test_settled_instances_contribute_no_rows(self):
        # settle after one descent; a huge dirty_tol means posterior drift
        # never re-dirties, so tick 2 must launch NOTHING while the
        # instance is still live
        eng = _engine(settle_steps=1, dirty_tol=1e9)
        eng.submit("normal_wf")
        out1 = eng.tick()
        assert out1["launches"] >= 1 and out1["live"] == 1
        out2 = eng.tick()
        assert out2["rows"] == 0 and out2["launches"] == 0

    def test_urgency_drift_redirties(self):
        # a deadline instance burns slack as stages complete, so its SLO
        # urgency moves every tick; with a tiny dirty_tol that drift alone
        # re-enters the settled instance into the solve
        eng = _engine(settle_steps=1, dirty_tol=1e-6, slo_gain=1.0)
        eng.submit("normal_wf", deadline=3.0)
        out1 = eng.tick()
        assert out1["launches"] >= 1
        out2 = eng.tick()
        assert out2["rows"] >= 1 and out2["launches"] >= 1

    def test_posterior_drift_redirties(self):
        # the drift branch itself: a settled instance whose remaining
        # stage's priced statistics moved past dirty_tol re-seeds its
        # descent budget
        eng = _engine(settle_steps=3, dirty_tol=0.05)
        eng.submit("normal_wf")
        eng.tick()
        inst = next(iter(eng._live.values()))
        inst.steps_left = 0
        mu0, sg0 = inst.stat_snap["b"]
        inst.stat_snap["b"] = (mu0 * 2.0, sg0)   # 100% relative drift
        eng._maybe_redirty(inst)
        assert inst.steps_left == eng.settle_steps


class TestEngineState:
    def test_state_dict_json_round_trip_tick_parity(self):
        import json

        eng = _engine()
        for tpl in ("normal_wf", "logn_wf", "drift_wf"):
            eng.submit(tpl, deadline=6.0)
        eng.tick()
        state = json.loads(json.dumps(eng.state_dict()))
        eng2 = WorkflowEngine.from_state_dict(state, _templates())
        o1, o2 = eng.tick(), eng2.tick()
        assert o1 == o2
        for iid, inst in eng._live.items():
            for name, w in inst.weights.items():
                np.testing.assert_array_equal(
                    w, eng2._live[iid].weights[name])
