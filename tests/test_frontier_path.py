"""The kernel-backed solve path: batched ``ops.frontier_moments`` as the one
moment evaluator — padding glue, impl agreement, K-channel frontier vs the
survival-integral oracle, and warm-started balancer refreshes."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (clark_max_moments_seq, frontier_2ch, frontier_kch,
                        max_moments_quad, optimize_weights, simplex_candidates)
from repro.kernels import ops, ref
from repro.sched import UncertaintyAwareBalancer


def _problem(k, seed=0, cov=(0.05, 0.3)):
    rng = np.random.default_rng(seed)
    mus = rng.uniform(10, 40, k)
    sigmas = mus * rng.uniform(*cov, k)
    return mus, sigmas


def _candidates(F, k, seed=0):
    rng = np.random.default_rng(seed)
    e = rng.exponential(size=(F, k))
    return e / e.sum(axis=1, keepdims=True)


class TestFrontierMomentsPadding:
    @pytest.mark.parametrize("F,block_f", [(7, 64), (100, 64), (129, 128),
                                           (128, 128), (1, 128)])
    @pytest.mark.parametrize("impl", ["xla", "pallas_interpret"])
    def test_any_F_matches_unblocked_ref(self, F, block_f, impl):
        """ops.frontier_moments owns the padding: F need not divide block_f."""
        k = 5
        W = _candidates(F, k)
        mus, sigmas = _problem(k)
        mu, var = ops.frontier_moments(jnp.asarray(W, jnp.float32),
                                       jnp.asarray(mus, jnp.float32),
                                       jnp.asarray(sigmas, jnp.float32),
                                       num_t=512, impl=impl, block_f=block_f)
        assert mu.shape == (F,) and var.shape == (F,)
        m_ref, v_ref = ref.frontier_grid_ref(W, mus, sigmas, num_t=512)
        np.testing.assert_allclose(mu, m_ref, rtol=1e-4)
        np.testing.assert_allclose(var, v_ref, rtol=1e-2, atol=1e-4)

    def test_impls_agree(self):
        """Acceptance: pallas_interpret vs xla to <= 1e-3 relative."""
        k, F = 8, 333
        W = _candidates(F, k, seed=3)
        mus, sigmas = _problem(k, seed=3)
        args = (jnp.asarray(W, jnp.float32), jnp.asarray(mus, jnp.float32),
                jnp.asarray(sigmas, jnp.float32))
        m_x, v_x = ops.frontier_moments(*args, num_t=1024, impl="xla")
        m_p, v_p = ops.frontier_moments(*args, num_t=1024,
                                        impl="pallas_interpret", block_f=128)
        np.testing.assert_allclose(m_p, m_x, rtol=1e-3)
        np.testing.assert_allclose(v_p, v_x, rtol=1e-3, atol=1e-5)

    def test_frontier_2ch_impls_agree(self):
        r_x = frontier_2ch(30.0, 2.0, 20.0, 6.0, num_f=101, impl="xla")
        r_p = frontier_2ch(30.0, 2.0, 20.0, 6.0, num_f=101,
                           impl="pallas_interpret")
        np.testing.assert_allclose(r_p.mu, r_x.mu, rtol=1e-3)
        np.testing.assert_allclose(r_p.var, r_x.var, rtol=1e-3, atol=1e-6)
        assert (r_p.efficient == r_x.efficient).all()


class TestFrontierKch:
    @pytest.mark.parametrize("k", [2, 3, 6, 16])
    def test_matches_quad_oracle(self, k):
        """Batched kernel moments == the paper's survival integral, for every
        K — including K > 2 where sequential Clark is only approximate."""
        mus, sigmas = _problem(k, seed=k)
        res = frontier_kch(mus, sigmas, num_f=48, num_t=2048,
                           include_pgd=False)
        assert res.f.shape[1] == k
        np.testing.assert_allclose(res.f.sum(axis=1), 1.0, atol=1e-6)
        assert res.efficient.any()
        idx = np.unique(np.linspace(0, len(res.mu) - 1, 7).astype(int))
        for i in idx:
            m, v = max_moments_quad(jnp.asarray(res.f[i] * mus, jnp.float32),
                                    jnp.asarray(res.f[i] * sigmas, jnp.float32),
                                    num=2048)
            np.testing.assert_allclose(res.mu[i], float(m), rtol=1e-3)
            np.testing.assert_allclose(res.var[i], float(v), rtol=1e-2,
                                       atol=1e-4)

    def test_oracle_tighter_than_sequential_clark(self):
        """For K>2 the batched integral stays with the oracle where the Clark
        fold drifts (the reason the solve path uses the kernel, not Clark)."""
        k = 5
        mus = np.full(k, 20.0)           # identical channels: Clark's worst case
        sigmas = np.full(k, 5.0)
        w = np.full(k, 1.0 / k)
        m_q, _ = max_moments_quad(jnp.asarray(w * mus, jnp.float32),
                                  jnp.asarray(w * sigmas, jnp.float32), num=4096)
        m_c, _ = clark_max_moments_seq(jnp.asarray(w * mus, jnp.float32),
                                       jnp.asarray(w * sigmas, jnp.float32))
        m_k, _ = ops.frontier_moments(jnp.asarray(w, jnp.float32)[None, :],
                                      jnp.asarray(mus, jnp.float32),
                                      jnp.asarray(sigmas, jnp.float32),
                                      num_t=4096)
        kernel_err = abs(float(m_k[0]) - float(m_q)) / float(m_q)
        clark_err = abs(float(m_c) - float(m_q)) / float(m_q)
        assert kernel_err < 1e-3
        assert kernel_err < clark_err

    def test_include_pgd_appends_optimized_candidate(self):
        mus, sigmas = _problem(6, seed=1)
        grid_only = frontier_kch(mus, sigmas, num_f=48, num_t=512,
                                 include_pgd=False)
        with_pgd = frontier_kch(mus, sigmas, num_f=48, num_t=512,
                                include_pgd=True, pgd_steps=100)
        assert with_pgd.f.shape[0] == grid_only.f.shape[0] + 1
        # the PGD point can only improve the best scalarized value
        assert with_pgd.mu.min() <= grid_only.mu.min() + 1e-6

    def test_simplex_candidates_cover_vertices(self):
        W = simplex_candidates(8, 64)
        np.testing.assert_allclose(W.sum(axis=1), 1.0, atol=1e-9)
        assert (W >= 0).all()
        for v in np.eye(8):   # single-channel assignments are exact candidates
            assert (np.abs(W - v).sum(axis=1) < 1e-12).any()


class TestWarmStart:
    def test_warm_start_converges_to_cold_solution(self):
        mus, sigmas = _problem(8, seed=5)
        cold = optimize_weights(mus, sigmas, lam=0.05, steps=150, restarts=2)
        rng = np.random.default_rng(0)
        near = cold.weights + rng.normal(0, 0.02, 8)
        warm = optimize_weights(mus, sigmas, lam=0.05, steps=150, restarts=2,
                                warm_start=near)
        np.testing.assert_allclose(warm.weights, cold.weights, atol=2e-2)
        assert warm.mu <= cold.mu * 1.01

    def test_balancer_warm_refresh_matches_cold_solve(self):
        """A refresh tick warm-started from _cached_w must land on the same
        weights as a cold solve from the identical posterior state."""
        b = UncertaintyAwareBalancer(6, lam=0.05, refresh_every=1,
                                     pgd_steps=120)
        rng = np.random.default_rng(2)
        true_mu = rng.uniform(10, 30, 6)
        for _ in range(15):
            w = b.weights()
            durs = np.maximum(w * rng.normal(true_mu, 0.05 * true_mu), 1e-9)
            b.observe(durs, w)
        w_warm = b.weights()          # warm-started from the previous solve
        cold = UncertaintyAwareBalancer.from_state_dict(b.state_dict())
        w_cold = cold.weights()       # same posteriors, no cached solve
        np.testing.assert_allclose(w_warm, w_cold, atol=2e-2)

    def test_balancer_impl_knob(self):
        """impl="pallas_interpret" drives the same decisions as "xla"."""
        obs = [np.array([12.0, 20.0, 28.0]), np.array([11.5, 21.0, 27.0]),
               np.array([12.5, 19.5, 29.0])]
        ws = {}
        for impl in ("xla", "pallas_interpret"):
            b = UncertaintyAwareBalancer(3, lam=0.05, impl=impl, pgd_steps=80)
            for d in obs:
                b.observe(d, np.full(3, 1.0 / 3))
            ws[impl] = b.weights()
        np.testing.assert_allclose(ws["pallas_interpret"], ws["xla"],
                                   atol=1e-3)
