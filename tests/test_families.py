"""Distribution-generic frontier stack: the pluggable completion-time
families (normal / lognormal / drift / empirical) through the quadrature
oracles, the fused kernels, the custom VJP, the solvers, the scheduler, the
simulator and the serving batcher.

Acceptance anchors:
  * lognormal and drift match a numpy Monte-Carlo oracle on (mu, var) to
    <= 1e-3 relative;
  * gradients match finite differences (and autodiff through the family
    quadrature) on all families;
  * frontier_moments / frontier_kch / UncertaintyAwareBalancer accept
    ``family=``;
  * the autotune cache key separates forward/fused/per-family variants and
    survives the v2 -> v3 key-schema bumps;
  * safe_cdf / family point-mass conventions at w=0 are single-sourced and
    right-continuous.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Defective, Drift, Empirical, frontier_kch,
                        get_family, max_moments_quad_w, point_mass_cdf,
                        resolve_family, safe_cdf)
from repro.core import distributions as dists
from repro.core.partitioner import optimize_weights, predict_moments
from repro.kernels import autotune, ops, ref
from repro.kernels.frontier_grid import frontier_grid, frontier_grid_with_grads
from repro.sched import StragglerPolicy, UncertaintyAwareBalancer
from repro.sim import Channel, ClusterSim


def _problem(k, seed=0, cov=(0.05, 0.3)):
    rng = np.random.default_rng(seed)
    mus = rng.uniform(10, 40, k).astype(np.float32)
    sigmas = (mus * rng.uniform(*cov, k)).astype(np.float32)
    return jnp.asarray(mus), jnp.asarray(sigmas)


def _candidates(F, k, seed=0):
    rng = np.random.default_rng(seed)
    e = rng.exponential(size=(F, k))
    return jnp.asarray(e / e.sum(axis=1, keepdims=True), jnp.float32)


def _rel(a, b):
    a, b = np.asarray(a), np.asarray(b)
    return float(np.linalg.norm(a - b) / max(np.linalg.norm(b), 1e-30))


def _families(k, seed=0):
    """One spec of each family, with per-channel parameters where they exist."""
    rng = np.random.default_rng(seed)
    mus, sigmas = _problem(k, seed=seed)
    emp = Empirical.from_samples(
        rng.normal(np.asarray(mus)[None, :], np.asarray(sigmas)[None, :],
                   size=(3000, k)))
    return [("normal", "normal"),
            ("lognormal", "lognormal"),
            ("drift", Drift(rng.uniform(0.1, 0.7, k).astype(np.float32))),
            ("empirical", emp),
            ("defective",
             Defective(rng.uniform(0.05, 0.35, k).astype(np.float32),
                       pricing="retry"))]


class TestMonteCarloOracle:
    """Acceptance: quadrature (mu, var) vs numpy MC ground truth <= 1e-3."""

    @pytest.mark.mc_oracle
    @pytest.mark.parametrize("dist_id", ["lognormal", "drift"])
    def test_matches_mc_oracle(self, dist_id):
        rng = np.random.default_rng(1)
        k = 4
        mus = rng.uniform(10, 40, k)
        sigmas = mus * rng.uniform(0.1, 0.3, k)
        w = rng.dirichlet(np.ones(k))
        extra = (np.full((1, k), 0.6, np.float32) if dist_id == "drift"
                 else np.zeros((1, k), np.float32))
        # streaming MC: N large enough that se(var)/var ~ 4e-4 << 1e-3
        N, chunk = 10_000_000, 1_000_000
        mc = np.random.default_rng(8)
        s = s2 = 0.0
        for _ in range(N // chunk):
            T = dists.family_sample(dist_id, mc, w, mus, sigmas, extra,
                                    chunk).max(axis=1)
            s += T.sum()
            s2 += (T * T).sum()
        mu_mc = s / N
        var_mc = s2 / N - mu_mc * mu_mc
        fam = (Drift(extra[0]) if dist_id == "drift" else dist_id)
        mu_q, var_q = ops.frontier_moments(
            jnp.asarray(w, jnp.float32)[None, :], jnp.asarray(mus, jnp.float32),
            jnp.asarray(sigmas, jnp.float32), num_t=4096, family=fam)
        assert abs(float(mu_q[0]) - mu_mc) / mu_mc <= 1e-3
        assert abs(float(var_q[0]) - var_mc) / var_mc <= 1e-3

    def test_empirical_recovers_normal_moments(self):
        """A mixture fitted on Normal data reproduces the normal family's
        frontier moments (sanity for the EM fit + mixture quadrature)."""
        k = 3
        mus, sigmas = _problem(k, seed=4, cov=(0.1, 0.2))
        rng = np.random.default_rng(0)
        emp = Empirical.from_samples(
            rng.normal(np.asarray(mus)[None, :], np.asarray(sigmas)[None, :],
                       size=(20000, k)))
        W = _candidates(6, k)
        mu_n, var_n = ops.frontier_moments(W, mus, sigmas, num_t=2048)
        mu_e, var_e = ops.frontier_moments(W, mus, sigmas, num_t=2048,
                                           family=emp)
        np.testing.assert_allclose(mu_e, mu_n, rtol=2e-2)
        np.testing.assert_allclose(var_e, var_n, rtol=2e-1)


class TestFamilyGradients:
    @pytest.mark.parametrize("fam_id", ["normal", "lognormal", "drift",
                                        "empirical", "defective"])
    def test_analytic_matches_autodiff(self, fam_id):
        """The fused analytic adjoint == jax.grad through the family
        quadrature, zero-weight rows included."""
        k, F, num_t = 5, 9, 512
        mus, sigmas = _problem(k, seed=3)
        fam = dict(_families(k, seed=3))[fam_id]
        dist_id, extra = resolve_family(fam, k)
        extra = jnp.asarray(extra, jnp.float32)
        W = _candidates(F, k, seed=F).at[0, 0].set(0.0)
        _, _, dmu, dvar = ops.frontier_moments_with_grads(
            W, mus, sigmas, num_t=num_t, family=fam)
        dmu_a = jax.grad(lambda W: jnp.sum(ref.frontier_grid_ref(
            W, mus, sigmas, num_t=num_t, dist_id=dist_id, extra=extra)[0]))(W)
        dvar_a = jax.grad(lambda W: jnp.sum(ref.frontier_grid_ref(
            W, mus, sigmas, num_t=num_t, dist_id=dist_id, extra=extra)[1]))(W)
        assert _rel(dmu, dmu_a) <= 1e-4
        assert _rel(dvar, dvar_a) <= 1e-4
        assert float(dmu[0, 0]) == 0.0  # zero-weight channel: no direct grad

    @pytest.mark.parametrize("fam_id", ["normal", "lognormal", "drift",
                                        "empirical", "defective"])
    def test_finite_differences(self, fam_id):
        """Acceptance: gradients match central differences on all families."""
        k = 5
        mus, sigmas = _problem(k, seed=9)
        fam = dict(_families(k, seed=9))[fam_id]
        w = np.full(k, 1.0 / k, np.float32)
        lam, num_t, eps = 0.05, 1024, 1e-3

        def f(w):
            mu, var = ops.frontier_moments(jnp.asarray(w)[None, :], mus,
                                           sigmas, num_t=num_t, family=fam)
            return float(mu[0] + lam * var[0])

        _, _, dmu, dvar = ops.frontier_moments_with_grads(
            jnp.asarray(w)[None, :], mus, sigmas, num_t=num_t, family=fam)
        g = np.asarray(dmu + lam * dvar)[0]
        # difference the 3 largest-|g| coordinates: central differences on an
        # f32 quadrature have ~2e-6 absolute noise, so small components drown
        # (the autodiff-parity test above carries the digits; this guards
        # sign/scale against an independent evaluation)
        for i in np.argsort(-np.abs(g))[:3]:
            wp, wm = w.copy(), w.copy()
            wp[i] += eps
            wm[i] -= eps
            fd = (f(wp) - f(wm)) / (2 * eps)
            np.testing.assert_allclose(g[i], fd, rtol=5e-2)

    @pytest.mark.parametrize("fam_id", ["lognormal", "drift", "empirical",
                                        "defective"])
    def test_custom_vjp_bitwise(self, fam_id):
        """jax.grad of frontier_moments rides the fused kernel's outputs
        bitwise for every family (the registered custom VJP)."""
        k = 4
        mus, sigmas = _problem(k, seed=5)
        fam = dict(_families(k, seed=5))[fam_id]
        W = _candidates(8, k, seed=2)
        g = jax.grad(lambda W: jnp.sum(ops.frontier_moments(
            W, mus, sigmas, num_t=256, family=fam)[0]))(W)
        _, _, dmu, _ = ops.frontier_moments_with_grads(
            W, mus, sigmas, num_t=256, family=fam)
        np.testing.assert_array_equal(np.asarray(g), np.asarray(dmu))


class TestFamilyKernels:
    @pytest.mark.parametrize("fam_id", ["normal", "lognormal", "drift",
                                        "empirical", "defective"])
    @pytest.mark.parametrize("fused", [False, True])
    def test_pallas_interpret_matches_ref(self, fam_id, fused):
        k, F, num_t, bf = 5, 8, 256, 4
        mus, sigmas = _problem(k, seed=F)
        fam = dict(_families(k, seed=F))[fam_id]
        dist_id, extra = resolve_family(fam, k)
        extra = jnp.asarray(extra, jnp.float32)
        W = _candidates(F, k, seed=k)
        if fused:
            outs_k = frontier_grid_with_grads(W, mus, sigmas, extra,
                                              num_t=num_t, block_f=bf,
                                              interpret=True, dist_id=dist_id)
            outs_r = ref.frontier_grid_with_grads_ref(W, mus, sigmas,
                                                      num_t=num_t,
                                                      dist_id=dist_id,
                                                      extra=extra)
            names = ("mu", "var", "dmu", "dvar")
        else:
            outs_k = frontier_grid(W, mus, sigmas, extra, num_t=num_t,
                                   block_f=bf, interpret=True, dist_id=dist_id)
            outs_r = ref.frontier_grid_ref(W, mus, sigmas, num_t=num_t,
                                           dist_id=dist_id, extra=extra)
            names = ("mu", "var")
        for name, a, b in zip(names, outs_k, outs_r):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=1e-4,
                atol=1e-5 * float(np.max(np.abs(np.asarray(b)))) + 1e-12,
                err_msg=f"{fam_id}:{name}")

    def test_drift_rho_zero_is_normal(self):
        """Drift with rho=0 must reduce exactly to the normal family."""
        k = 4
        mus, sigmas = _problem(k, seed=1)
        W = _candidates(6, k)
        out_n = ops.frontier_moments_with_grads(W, mus, sigmas, num_t=512)
        out_d = ops.frontier_moments_with_grads(W, mus, sigmas, num_t=512,
                                                family=Drift(0.0))
        for a, b in zip(out_d, out_n):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6, atol=1e-8)

    def test_lognormal_moment_matched_single_channel(self):
        """One channel, full weight: the lognormal is moment-matched to
        (mu, sigma), so the survival integral must return exactly those
        moments — the family changes the SHAPE, not the marginal moments."""
        mu0, sg0 = 25.0, 7.0
        W = jnp.asarray([[1.0]], jnp.float32)
        m, v = ops.frontier_moments(W, jnp.asarray([mu0], jnp.float32),
                                    jnp.asarray([sg0], jnp.float32),
                                    num_t=4096, family="lognormal")
        np.testing.assert_allclose(float(m[0]), mu0, rtol=1e-3)
        np.testing.assert_allclose(float(v[0]), sg0 * sg0, rtol=5e-3)

    def test_lognormal_joint_differs_from_normal(self):
        """Same marginal moments, different shape: the JOINT max moments must
        move measurably at high CoV (the reason the family matters at all)."""
        k = 6
        mus, sigmas = _problem(k, seed=2, cov=(0.2, 0.3))
        W = _candidates(16, k)
        mu_n, var_n = ops.frontier_moments(W, mus, sigmas, num_t=2048)
        mu_l, var_l = ops.frontier_moments(W, mus, sigmas, num_t=2048,
                                           family="lognormal")
        assert float(np.max(np.abs(np.asarray(mu_l) - np.asarray(mu_n))
                            / np.asarray(mu_n))) > 5e-4
        assert float(np.max(np.abs(np.asarray(var_l) - np.asarray(var_n))
                            / np.asarray(var_n))) > 1e-2


class TestFamilySolvers:
    def test_frontier_kch_accepts_families(self):
        mus, sigmas = _problem(5, seed=6)
        for _, fam in _families(5, seed=6):
            res = frontier_kch(np.asarray(mus), np.asarray(sigmas), num_f=32,
                               num_t=512, include_pgd=False, family=fam)
            assert res.efficient.any()
            # spot-check against the family-generic single-split oracle
            i = int(np.argmin(res.mu))
            m, v = max_moments_quad_w(res.f[i], mus, sigmas, num=2048,
                                      family=fam)
            np.testing.assert_allclose(res.mu[i], float(m), rtol=5e-3)

    def test_drift_solver_shifts_work_off_straggler(self):
        """Pricing drift into the objective must move weight away from the
        drifting channel relative to the normal-family solve."""
        mus = np.array([20.0, 20.0, 20.0])
        sigmas = np.array([2.0, 2.0, 2.0])
        rho = np.array([2.5, 0.0, 0.0], np.float32)
        dec_n = optimize_weights(mus, sigmas, lam=0.0, steps=120, restarts=0)
        dec_d = optimize_weights(mus, sigmas, lam=0.0, steps=120, restarts=0,
                                 family=Drift(rho))
        assert dec_d.weights[0] < dec_n.weights[0] - 0.02
        # under the drift model, the drift-aware split beats the oblivious one
        mu_obl, _ = max_moments_quad_w(dec_n.weights, mus, sigmas, num=4096,
                                       family=Drift(rho))
        assert dec_d.mu <= float(mu_obl) + 1e-6

    def test_predict_moments_family(self):
        mus, sigmas = _problem(3, seed=7)
        w = np.full(3, 1.0 / 3)
        m_n, _ = predict_moments(w, mus, sigmas)
        m_d, _ = predict_moments(w, mus, sigmas, family=Drift(1.0))
        assert m_d > m_n  # drift inflates the joint mean


class TestPointMassConventions:
    """Satellite: safe_cdf / family point-mass edge cases, w=0 channels."""

    def test_right_continuous_at_mean(self):
        # the single-sourced convention: 1 exactly AT the mean, 0 below
        assert float(point_mass_cdf(jnp.float32(5.0), 5.0)) == 1.0
        assert float(point_mass_cdf(jnp.float32(4.999999), 5.0)) == 0.0
        assert float(safe_cdf(jnp.float32(5.0), 5.0, 0.0)) == 1.0
        assert float(safe_cdf(jnp.float32(4.0), 5.0, 0.0)) == 0.0
        assert float(safe_cdf(jnp.float32(6.0), 5.0, 0.0)) == 1.0

    @pytest.mark.parametrize("fam_id", ["normal", "lognormal", "drift",
                                        "empirical", "defective"])
    def test_w_zero_channel_is_finished(self, fam_id):
        """A w=0 channel is a point mass at 0: CDF 1 for every t >= 0, so it
        cannot move the joint moments — for ANY family."""
        k = 3
        mus, sigmas = _problem(k, seed=11)
        fam = dict(_families(k, seed=11))[fam_id]
        dist_id, extra = resolve_family(fam, k)
        cdf0 = dists.family_cdf(dist_id, jnp.asarray([0.0, 1.0, 50.0]),
                                jnp.float32(0.0), mus[0], sigmas[0],
                                jnp.asarray(extra, jnp.float32)[:, :1])
        np.testing.assert_array_equal(np.asarray(cdf0), 1.0)
        # joint moments with/without the zero-weight channel agree
        W2 = jnp.asarray([[0.6, 0.4]], jnp.float32)
        W3 = jnp.asarray([[0.6, 0.4, 0.0]], jnp.float32)
        fam2 = (dist_id, jnp.asarray(extra, jnp.float32)[:, :2])
        mu3, var3 = ops.frontier_moments(W3, mus, sigmas, num_t=2048,
                                         family=(dist_id,
                                                 jnp.asarray(extra,
                                                             jnp.float32)))
        mu2, var2 = ops.frontier_moments(W2, mus[:2], sigmas[:2], num_t=2048,
                                         family=fam2)
        np.testing.assert_allclose(mu3, mu2, rtol=1e-5)
        np.testing.assert_allclose(var3, var2, rtol=1e-4, atol=1e-6)

    def test_sigma_zero_channel_is_point_mass_at_mean(self):
        """sigma=0, w>0: deterministic channel at its effective mean; the
        survival integral must see a step there (family-aware safe_cdf)."""
        mus = jnp.asarray([20.0, 30.0], jnp.float32)
        sigmas = jnp.asarray([2.0, 0.0], jnp.float32)
        w = jnp.asarray([0.3, 0.7], jnp.float32)
        m, v = max_moments_quad_w(w, mus, sigmas, num=4096)
        # channel 1 is a point mass at 21 >> channel 0's mean 6 +- 0.6:
        # the max is essentially the constant 21
        np.testing.assert_allclose(float(m), 21.0, rtol=1e-3)
        assert float(v) < 0.1


class TestAutotuneFamilyCache:
    """Satellite: cache keys must separate forward/fused/per-family variants
    and survive the v2 -> v3 key-schema bumps."""

    def test_keys_do_not_collide(self, tmp_path):
        path = str(tmp_path / "cache.json")
        autotune.clear_cache()
        try:
            variants = [(False, "normal"), (True, "normal"),
                        (False, "drift"), (True, "drift"),
                        (False, "lognormal"), (True, "empirical"),
                        (False, "defective"), (True, "defective")]
            keys = {autotune._key(256, 8, 128, "xla", fused, dist)
                    for fused, dist in variants}
            assert len(keys) == len(variants)
            # seed distinct entries through lookup and verify isolation
            # (F=256 so every seeded block_f <= F survives lookup's clamp)
            for i, (fused, dist) in enumerate(variants):
                autotune._CACHE[autotune._key(256, 8, 128, "xla", fused, dist)] = {
                    "block_f": 2 ** (i + 1), "source": "sweep"}
            for i, (fused, dist) in enumerate(variants):
                assert autotune.lookup(256, 8, 128, backend="xla", fused=fused,
                                       dist_id=dist, cache_path=path) == 2 ** (i + 1)
        finally:
            autotune.clear_cache()

    def test_legacy_keys_migrate_as_normal_family(self, tmp_path):
        """A pre-family JSON cache (un-versioned keys) keeps serving its
        swept winners — as normal-family entries — after the schema bump."""
        path = str(tmp_path / "cache.json")
        legacy = {"xla:F8:K3:T64:fused0": {"block_f": 4, "source": "sweep"},
                  "xla:F8:K3:T64:fused1": {"block_f": 2, "source": "sweep"}}
        with open(path, "w") as f:
            json.dump(legacy, f)
        autotune.clear_cache()
        try:
            assert autotune.lookup(8, 3, 64, backend="xla", fused=False,
                                   cache_path=path) == 4
            assert autotune.lookup(8, 3, 64, backend="xla", fused=True,
                                   cache_path=path) == 2
            # other families DON'T inherit the legacy entry (fall to model)
            bf_drift = autotune.lookup(8, 3, 64, backend="xla", fused=True,
                                       dist_id="drift", cache_path=path)
            assert bf_drift == autotune.pick_block_f(8, 3, 64, backend="xla",
                                                     fused=True,
                                                     dist_id="drift")
        finally:
            autotune.clear_cache()

    def test_sweep_round_trip_v3(self, tmp_path):
        path = str(tmp_path / "cache.json")
        autotune.clear_cache()
        try:
            entry = autotune.sweep(8, 3, 64, backend="xla", fused=False,
                                   repeats=1, candidates=(4, 8),
                                   cache_path=path, dist_id="lognormal")
            on_disk = json.load(open(path))
            assert "v3:xla:F8:K3:T64:modefwd:famlognormal" in on_disk
            autotune.clear_cache()
            assert autotune.lookup(8, 3, 64, backend="xla",
                                   dist_id="lognormal",
                                   cache_path=path) == entry["block_f"]
        finally:
            autotune.clear_cache()

    def test_v2_keys_migrate_with_mode_mapping(self, tmp_path):
        """A v2 JSON cache keeps serving its swept winners after the v3
        (mode-aware) bump: fused0 -> fwd, fused1 -> grad — and the new pgrad
        mode never inherits a v2 entry (its working set is larger; a stale
        fused block could overflow it)."""
        path = str(tmp_path / "cache.json")
        v2 = {"v2:xla:F8:K3:T64:fused0:famdrift": {"block_f": 4,
                                                   "source": "sweep"},
              "v2:xla:F8:K3:T64:fused1:famdrift": {"block_f": 2,
                                                   "source": "sweep"}}
        with open(path, "w") as f:
            json.dump(v2, f)
        autotune.clear_cache()
        try:
            assert autotune.lookup(8, 3, 64, backend="xla", fused=False,
                                   dist_id="drift", cache_path=path) == 4
            assert autotune.lookup(8, 3, 64, backend="xla", fused=True,
                                   dist_id="drift", cache_path=path) == 2
            bf_pgrad = autotune.lookup(8, 3, 64, backend="xla", fused=True,
                                       dist_id="drift", params=True,
                                       cache_path=path)
            assert bf_pgrad == autotune.pick_block_f(
                8, 3, 64, backend="xla", fused=True, dist_id="drift",
                params=True)
        finally:
            autotune.clear_cache()

    def test_pgrad_mode_needs_no_more_room_than_budget(self):
        """The full-parameter launch's working set exceeds the W-grad one, so
        the model's pgrad pick can only shrink — and must still fit VMEM."""
        b_grad = autotune.vmem_bytes(64, 1024, 256, fused=True,
                                     dist_id="lognormal")
        b_pgrad = autotune.vmem_bytes(64, 1024, 256, fused=True,
                                      dist_id="lognormal", params=True)
        assert b_pgrad > b_grad
        bf_g = autotune.pick_block_f(4096, 1024, 256, backend="pallas",
                                     fused=True, dist_id="lognormal")
        bf_p = autotune.pick_block_f(4096, 1024, 256, backend="pallas",
                                     fused=True, dist_id="lognormal",
                                     params=True)
        assert bf_p <= bf_g
        assert autotune.vmem_bytes(bf_p, 1024, 256, fused=True,
                                   dist_id="lognormal", params=True) \
            <= int(16 * 1024 * 1024 * 0.75)

    def test_drift_needs_smaller_fused_blocks(self):
        """Drift's four accumulators shrink the model's safe pick vs the
        two-accumulator families at fleet scale."""
        b_norm = autotune.vmem_bytes(64, 1024, 256, fused=True,
                                     dist_id="normal")
        b_drift = autotune.vmem_bytes(64, 1024, 256, fused=True,
                                      dist_id="drift")
        assert b_drift > b_norm
        assert (autotune.pick_block_f(4096, 4096, 256, backend="pallas",
                                      fused=True, dist_id="drift")
                <= autotune.pick_block_f(4096, 4096, 256, backend="pallas",
                                         fused=True, dist_id="normal"))


class TestSimBoundary:
    """Satellite: run_step accepts jax arrays / unnormalized weights and an
    explicit seed/rng."""

    def test_jax_array_and_unnormalized_weights(self):
        sim = ClusterSim.heterogeneous(4, seed=3)
        t1, d1 = sim.run_step(jnp.asarray([2.0, 2.0, 2.0, 2.0]), rng=123)
        sim2 = ClusterSim.heterogeneous(4, seed=3)
        t2, d2 = sim2.run_step(np.asarray([0.25] * 4), rng=123)
        assert t1 == t2
        np.testing.assert_allclose(d1, d2)

    def test_explicit_rng_reproducible_independent_of_history(self):
        sim = ClusterSim.heterogeneous(3, seed=0)
        sim.run_step([1.0, 1.0, 1.0])          # advance internal stream
        t1, _ = sim.run_step([0.5, 0.3, 0.2], rng=7)
        sim2 = ClusterSim.heterogeneous(3, seed=0)
        t2, _ = sim2.run_step([0.5, 0.3, 0.2], rng=7)
        assert t1 == t2

    def test_all_zero_weights_stay_zero(self):
        sim = ClusterSim.heterogeneous(3, seed=1)
        t, d = sim.run_step(np.zeros(3))
        assert t == 0.0 and (d == 0.0).all()

    def test_lognormal_and_drift_fleets_vectorized(self):
        for dist in ("lognormal", "drift"):
            sim = ClusterSim.heterogeneous(64, seed=5, dist=dist)
            t, d = sim.run_step(np.full(64, 1.0 / 64))
            assert t > 0 and (d[d > 0] > 0).all()
        # drift ground truth: higher share -> superlinear duration growth
        # (weights are normalized at the boundary, so a dummy channel holds
        # the remaining share)
        mk = lambda: ClusterSim(channels=[
            Channel(mu=10.0, sigma=1e-9, dist="drift", rho=1.0),
            Channel(mu=1e-6, sigma=1e-12)], seed=0)
        _, d_full = mk().run_step([1.0, 0.0])
        _, d_half = mk().run_step([0.5, 0.5])
        # E[T(1)] = 15, E[T(0.5)] = 6.25: ratio 2.4 >> 2 (linear would be 2)
        assert d_full[0] / d_half[0] > 2.2

    def test_wrong_length_raises(self):
        sim = ClusterSim.heterogeneous(3, seed=1)
        with pytest.raises(ValueError, match="weights"):
            sim.run_step([0.5, 0.5])


class TestSchedulerFamilies:
    def test_balancer_accepts_family(self):
        obs = [np.array([12.0, 20.0, 28.0]), np.array([11.5, 21.0, 27.0]),
               np.array([12.5, 19.5, 29.0])]
        ws = {}
        for fam in ("normal", "lognormal"):
            b = UncertaintyAwareBalancer(3, lam=0.05, pgd_steps=60, family=fam)
            for d in obs:
                b.observe(d, np.full(3, 1.0 / 3))
            ws[fam] = b.weights()
            np.testing.assert_allclose(ws[fam].sum(), 1.0, atol=1e-6)
        # both favor the fast channel; exact weights differ by family
        assert ws["lognormal"][0] > ws["lognormal"][2]

    def test_family_change_invalidates_cached_solve(self):
        b = UncertaintyAwareBalancer(3, lam=0.05, pgd_steps=60,
                                     refresh_every=1000)
        b.observe([10.0, 20.0, 30.0], np.full(3, 1.0 / 3))
        w_n = b.weights()
        w_d = b.weights(family=Drift(np.array([3.0, 0.0, 0.0], np.float32)))
        assert not np.allclose(w_n, w_d)  # refresh_every alone would cache

    def test_min_weight_floor_applies_on_cached_ticks(self):
        """Cached and fresh frontier ticks must return identical
        post-processing: the min_weight floor used to be skipped on the
        cache-hit path."""
        b = UncertaintyAwareBalancer(3, lam=0.01, pgd_steps=60,
                                     refresh_every=50, min_weight=0.15)
        b.observe([1.0, 15.0, 40.0], np.full(3, 1.0 / 3))
        w_fresh = b.weights()   # solve tick (fills the cache)
        w_cached = b.weights()  # cache hit
        np.testing.assert_allclose(w_fresh, w_cached)
        # the floor renormalizes, so the guaranteed lower bound is
        # min_weight / (1 + k * min_weight)
        assert w_fresh.min() >= 0.15 / (1 + 3 * 0.15) - 1e-9

    def test_state_dict_round_trips_family(self):
        b = UncertaintyAwareBalancer(3, lam=0.1, family="lognormal")
        b.observe([10.0, 20.0, 30.0], [1.0, 1.0, 1.0])
        b2 = UncertaintyAwareBalancer.from_state_dict(b.state_dict())
        assert get_family(b2.family).dist_id == "lognormal"
        np.testing.assert_allclose(b.weights(), b2.weights(), atol=1e-6)

    def test_straggler_drift_mitigation_keeps_channel(self):
        """Drift mode: a detected straggler keeps (reduced) work instead of
        being quarantined to zero."""
        b = UncertaintyAwareBalancer(3, lam=0.01, pgd_steps=60)
        pol = StragglerPolicy(b, z_threshold=2.5, mitigation="drift")
        for _ in range(30):
            pol.record([10.0, 10.2, 9.8], np.full(3, 1.0 / 3))
        w_before = pol.weights()
        for _ in range(4):  # channel 0 straggles hard
            pol.record([40.0, 10.2, 9.8], np.full(3, 1.0 / 3))
        assert 0 in pol.drift_rhos and pol.drift_rhos[0] > 0
        assert not pol.quarantined
        w_after = pol.weights()
        assert 0.0 < w_after[0] < w_before[0]  # discounted, not dropped
        # recovery: clean steps decay rho back toward the normal family
        for _ in range(30):
            pol.record([10.0, 10.2, 9.8], np.full(3, 1.0 / 3))
        assert 0 not in pol.drift_rhos

    def test_straggler_quarantine_mode_unchanged(self):
        b = UncertaintyAwareBalancer(2)
        pol = StragglerPolicy(b, z_threshold=2.5, quarantine_after=2)
        for _ in range(30):
            pol.record([10.0, 12.0], [0.5, 0.5])
        for _ in range(3):
            pol.record([10.0, 60.0], [0.5, 0.5])
        assert 1 in pol.quarantined
        assert pol.weights()[1] == 0.0


class TestDefectiveFamily:
    """Tentpole: fault tolerance as channel physics. The defective family
    prices a per-channel attempt-failure probability ``p`` (extra row 0) and
    a retry/resume cost ``lam`` (extra row 1) into retry-inflated per-unit
    moments (a, b); T(w) ~ N(w a, (w b)^2) is a pure scale family, so the
    whole stack treats it like ``normal`` with (a, b) substituted."""

    def test_p_zero_reduces_to_normal(self):
        """p = 0 is the healthy fleet: (a, b) = (mu, sigma) identically, so
        moments AND gradients must agree with the normal family to fp
        round-off (b = sqrt(sigma^2) may differ by an ulp)."""
        k = 4
        mus, sigmas = _problem(k, seed=13)
        W = _candidates(6, k)
        out_n = ops.frontier_moments_with_grads(W, mus, sigmas, num_t=512)
        out_d = ops.frontier_moments_with_grads(W, mus, sigmas, num_t=512,
                                                family=Defective(0.0))
        for a, b in zip(out_d, out_n):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6, atol=1e-8)

    def test_rejects_out_of_domain(self):
        with pytest.raises(ValueError, match=r"\[0, 1\]"):
            Defective([-0.1, 0.2])
        with pytest.raises(ValueError, match=r"\[0, 1\]"):
            Defective([0.1, 1.2])
        with pytest.raises(ValueError, match="pricing"):
            Defective(0.1, pricing="refund")
        with pytest.raises(ValueError, match="pricing"):
            Defective(0.1, pricing=1.5)
        with pytest.raises(ValueError, match="failure"):
            get_family("defective")  # p is not optional: build Defective(p)

    def test_pricing_orders_the_cost(self):
        """resume (lam=0.5) re-runs only half an attempt per failure, so it
        must sit strictly between healthy and full-retry pricing."""
        k = 3
        mus, sigmas = _problem(k, seed=17)
        W = _candidates(4, k)
        p = np.full(k, 0.2, np.float32)
        mu_0, _ = ops.frontier_moments(W, mus, sigmas, num_t=512)
        mu_r, _ = ops.frontier_moments(W, mus, sigmas, num_t=512,
                                       family=Defective(p, pricing="resume"))
        mu_f, _ = ops.frontier_moments(W, mus, sigmas, num_t=512,
                                       family=Defective(p, pricing="retry"))
        assert float(np.min(np.asarray(mu_r) - np.asarray(mu_0))) > 0.0
        assert float(np.min(np.asarray(mu_f) - np.asarray(mu_r))) > 0.0

    @pytest.mark.mc_oracle
    def test_per_channel_moments_match_physical_process(self):
        """Acceptance: the analytic (a, b) equal the mean/std of the PHYSICAL
        retry process (failures actually drawn, N ~ Geom) to <= 1e-3."""
        rng = np.random.default_rng(2)
        k = 4
        mus = rng.uniform(10, 40, k)
        sigmas = mus * rng.uniform(0.1, 0.3, k)
        p = np.array([0.0, 0.05, 0.15, 0.4], np.float32)
        lam = 1.0
        w = rng.dirichlet(np.ones(k))
        extra = np.stack([p, np.full(k, lam, np.float32)])
        a, b = dists.defective_moments_np(mus, sigmas, p, lam)
        N, chunk = 20_000_000, 1_000_000
        mc = np.random.default_rng(9)
        s = np.zeros(k)
        s2 = np.zeros(k)
        for _ in range(N // chunk):
            T = dists.family_sample("defective", mc, w, mus, sigmas, extra,
                                    chunk)
            s += T.sum(axis=0)
            s2 += (T * T).sum(axis=0)
        mu_mc = s / N
        var_mc = s2 / N - mu_mc * mu_mc
        np.testing.assert_allclose(w * a, mu_mc, rtol=1e-3)
        np.testing.assert_allclose((w * b) ** 2, var_mc, rtol=1e-3)

    @pytest.mark.mc_oracle
    def test_join_matches_mc_oracle(self):
        """The join quadrature vs MC through the MODEL law (the
        moment-matched Gaussian) <= 1e-3 — same contract as the other
        families' oracle test."""
        rng = np.random.default_rng(3)
        k = 4
        mus = rng.uniform(10, 40, k)
        sigmas = mus * rng.uniform(0.1, 0.3, k)
        p = np.array([0.02, 0.1, 0.25, 0.0], np.float32)
        w = rng.dirichlet(np.ones(k))
        a, b = dists.defective_moments_np(mus, sigmas, p, 1.0)
        N, chunk = 10_000_000, 1_000_000
        mc = np.random.default_rng(10)
        s = s2 = 0.0
        for _ in range(N // chunk):
            T = mc.normal(w * a, w * b, size=(chunk, k)).max(axis=1)
            s += T.sum()
            s2 += (T * T).sum()
        mu_mc = s / N
        var_mc = s2 / N - mu_mc * mu_mc
        mu_q, var_q = ops.frontier_moments(
            jnp.asarray(w, jnp.float32)[None, :], jnp.asarray(mus, jnp.float32),
            jnp.asarray(sigmas, jnp.float32), num_t=4096,
            family=Defective(p, pricing="retry"))
        assert abs(float(mu_q[0]) - mu_mc) / mu_mc <= 1e-3
        assert abs(float(var_q[0]) - var_mc) / var_mc <= 1e-3

    @pytest.mark.mc_oracle
    def test_join_shape_approximation_is_close(self):
        """Against the PHYSICAL process the model inherits the Gaussian
        per-channel shape approximation, so the JOIN tolerance is loose and
        documented (the per-channel moments themselves are exact — see
        test_per_channel_moments_match_physical_process)."""
        rng = np.random.default_rng(4)
        k = 4
        mus = rng.uniform(10, 40, k)
        sigmas = mus * rng.uniform(0.1, 0.2, k)
        p = np.array([0.05, 0.1, 0.15, 0.08], np.float32)
        w = rng.dirichlet(np.ones(k))
        extra = np.stack([p, np.ones(k, np.float32)])
        N, chunk = 2_000_000, 500_000
        mc = np.random.default_rng(11)
        s = s2 = 0.0
        for _ in range(N // chunk):
            T = dists.family_sample("defective", mc, w, mus, sigmas, extra,
                                    chunk).max(axis=1)
            s += T.sum()
            s2 += (T * T).sum()
        mu_mc = s / N
        var_mc = s2 / N - mu_mc * mu_mc
        mu_q, var_q = ops.frontier_moments(
            jnp.asarray(w, jnp.float32)[None, :], jnp.asarray(mus, jnp.float32),
            jnp.asarray(sigmas, jnp.float32), num_t=4096,
            family=Defective(p, pricing="retry"))
        # the join MEAN is what the solver minimizes: within 5% of the
        # physical process. The join VARIANCE under-prices the multimodal
        # retry tail (retries put probability spikes at +mu, +2mu, ... that
        # the moment-matched Gaussian flattens), so only a factor-scale
        # envelope is promised — the per-channel moments are exact, the
        # join shape is an approximation by design.
        assert abs(float(mu_q[0]) - mu_mc) / mu_mc <= 5e-2
        assert 0.3 <= float(var_q[0]) / var_mc <= 1.6

    @pytest.mark.parametrize("impl", ["xla", "pallas_interpret"])
    @pytest.mark.parametrize("p_edge", [0.0, 0.95])
    def test_p_gradient_matches_fd(self, impl, p_edge):
        """The custom VJP's analytic d/dp (extra row 0) matches finite
        differences on both impls, including the p = 0 healthy edge and the
        p -> 1 retry-divergence edge."""
        rng = np.random.default_rng(6)
        k = 4
        mus = rng.uniform(0.8, 2.0, k).astype(np.float32)
        sigmas = rng.uniform(0.1, 0.4, k).astype(np.float32)
        W = jnp.asarray(rng.dirichlet(np.ones(k), 5), jnp.float32)
        p = np.array([p_edge, 0.1, 0.2, 0.05], np.float32)
        extra = Defective(p, pricing="retry").extra(k)

        def loss(e):
            m, v = ops.frontier_moments(W, mus, sigmas, num_t=512, impl=impl,
                                        family=("defective", e))
            return m.sum() + 0.1 * v.sum()

        g = jax.grad(loss)(jnp.asarray(extra))
        h = 1e-3
        for i in range(k):
            if p[i] == 0.0:
                # one-sided forward difference: stepping to p = -h would
                # leave the family's domain (the sanitizer rejects it, and
                # the analytic grad is the one-sided limit at the boundary)
                ep = extra.copy()
                ep[0, i] += h
                fd = (loss(jnp.asarray(ep)) - loss(jnp.asarray(extra))) / h
            else:
                ep, em = extra.copy(), extra.copy()
                ep[0, i] += h
                em[0, i] -= h
                fd = (loss(jnp.asarray(ep)) - loss(jnp.asarray(em))) / (2 * h)
            np.testing.assert_allclose(float(g[0, i]), float(fd), rtol=5e-2,
                                       err_msg=f"channel {i} (p={p[i]})")

    def test_lam_row_cotangent_is_zero_by_contract(self):
        """Pricing (extra row 1) is a hyperparameter chosen by the retry
        policy, not a fitted quantity: the VJP documents a ZERO cotangent for
        it (only row 0 is populated), so nothing ever descends on lam."""
        k = 3
        mus, sigmas = _problem(k, seed=19)
        W = _candidates(4, k)
        extra = Defective(np.full(k, 0.2, np.float32)).extra(k)
        g = jax.grad(lambda e: jnp.sum(ops.frontier_moments(
            W, mus, sigmas, num_t=256, family=("defective", e))[0]))(
                jnp.asarray(extra))
        np.testing.assert_array_equal(np.asarray(g[1]), 0.0)
        assert float(np.max(np.abs(np.asarray(g[0])))) > 0.0

    def test_autotune_v3_key_round_trip(self, tmp_path):
        path = str(tmp_path / "cache.json")
        autotune.clear_cache()
        try:
            entry = autotune.sweep(8, 3, 64, backend="xla", fused=False,
                                   repeats=1, candidates=(4, 8),
                                   cache_path=path, dist_id="defective")
            on_disk = json.load(open(path))
            assert "v3:xla:F8:K3:T64:modefwd:famdefective" in on_disk
            autotune.clear_cache()
            assert autotune.lookup(8, 3, 64, backend="xla",
                                   dist_id="defective",
                                   cache_path=path) == entry["block_f"]
        finally:
            autotune.clear_cache()

    def test_solver_shifts_work_off_flaky_channel(self):
        """Pricing the failure physics must move weight away from the flaky
        channel relative to the failure-blind normal solve — the same
        acceptance shape as the drift solver test."""
        mus = np.array([20.0, 20.0, 20.0])
        sigmas = np.array([2.0, 2.0, 2.0])
        p = np.array([0.3, 0.0, 0.0], np.float32)
        dec_n = optimize_weights(mus, sigmas, lam=0.0, steps=120, restarts=0)
        dec_d = optimize_weights(mus, sigmas, lam=0.0, steps=120, restarts=0,
                                 family=Defective(p, pricing="retry"))
        assert dec_d.weights[0] < dec_n.weights[0] - 0.02
        mu_obl, _ = max_moments_quad_w(dec_n.weights, mus, sigmas, num=4096,
                                       family=Defective(p, pricing="retry"))
        assert dec_d.mu <= float(mu_obl) + 1e-6


class TestServeFamilies:
    def test_partitioned_batcher_accepts_family(self):
        from repro.serve.engine import PartitionedBatcher, ReplicaGroup

        groups = [ReplicaGroup(name=f"g{i}") for i in range(3)]
        pb = PartitionedBatcher(groups, lam=0.02, family="lognormal", seed=4)
        assert get_family(pb.balancer.family).dist_id == "lognormal"
        prompts = np.zeros((24, 4), np.int32)
        for _ in range(3):
            join_t, counts, _ = pb.run_batch(prompts)
            assert counts.sum() == 24 and join_t > 0.0
